#!/usr/bin/env python
"""Docs consistency checks (the CI ``docs-check`` job).

Two gates, no dependencies beyond the stdlib:

1. **Markdown link check** — every relative link in README.md, DESIGN.md,
   EXPERIMENTS.md, PAPER.md, PAPERS.md, docs/*.md, and benchmarks/README.md
   must resolve to an existing file, and a ``#fragment`` into a markdown
   file must match one of its headings (GitHub slug rules).  On top of
   resolution, ``REQUIRED_LINKS`` lists links that must *exist*: README.md
   must link docs/TESTING.md (the test-tier map is part of the product
   surface — removing the pointer is a docs regression, not a cleanup).

2. **§-reference audit** — every ``§`` reference in ``src/repro/serving/``
   and ``src/repro/core/scheduler.py`` must resolve to a real section:

   * ``§"Some Title"``         -> a heading of docs/ARCHITECTURE.md,
                                  docs/SERVING.md, or DESIGN.md containing
                                  the quoted title;
   * ``ARCHITECTURE[.md] §N``  -> the ``## N.`` section of ARCHITECTURE.md;
   * ``§N`` / ``DESIGN §N``    -> the ``## §N`` numbered design note;
   * ``§IV`` / ``§III-C`` ...  -> roman numerals are PAPER sections, exempt
                                  (the paper is not a repo file).

Findings are reported through the shared static-analysis API
(``repro.analysis.base``, stdlib-only): uniform ``file:line rule message``
lines, ``--json`` for machines — the same surface as check_static.py and
check_trace.py (docs/STATIC_ANALYSIS.md).

Run:  python scripts/check_docs.py [--json]    (exit 1 on any failure)
"""
from __future__ import annotations

import argparse
import os
import re
import sys
from pathlib import Path
from typing import List

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.analysis.base import Finding, render_json, render_text

ROOT = Path(__file__).resolve().parent.parent

LINK_DOCS = ["README.md", "DESIGN.md", "EXPERIMENTS.md", "PAPER.md",
             "PAPERS.md", "benchmarks/README.md"]
# (source doc, target path relative to the source doc's directory): the
# source must contain at least one markdown link to the target
REQUIRED_LINKS = [
    ("README.md", "docs/TESTING.md"),
    ("README.md", "docs/ARCHITECTURE.md"),
    ("README.md", "docs/SERVING.md"),
    ("README.md", "docs/OBSERVABILITY.md"),
    ("README.md", "docs/KV_CACHE.md"),
    ("README.md", "docs/FLEET.md"),
    ("README.md", "docs/STATIC_ANALYSIS.md"),
    ("docs/SERVING.md", "OBSERVABILITY.md"),
    ("docs/SERVING.md", "KV_CACHE.md"),
    ("docs/SERVING.md", "FLEET.md"),
    ("docs/TESTING.md", "STATIC_ANALYSIS.md"),
]
SECTION_DOCS = ["docs/ARCHITECTURE.md", "docs/SERVING.md", "docs/FLEET.md",
                "DESIGN.md"]
AUDIT_GLOBS = ["src/repro/serving/**/*.py", "src/repro/core/scheduler.py"]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^(#{1,6})\s+(.*?)\s*$", re.M)
_QUOTED_REF = re.compile(r"§\\?\"([^\"\\]+)\\?\"")
_NUM_REF = re.compile(r"§\s*(\d+)")
_ROMAN_REF = re.compile(r"§\s*[IVX]+(?:-[A-Z])?\b")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, spaces -> hyphens, drop the rest."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def headings(path: Path) -> list[str]:
    return [m.group(2) for m in _HEADING.finditer(path.read_text())]


def check_links() -> List[Finding]:
    findings: List[Finding] = []
    docs = [ROOT / d for d in LINK_DOCS] + sorted((ROOT / "docs").glob("*.md"))
    for doc in docs:
        if not doc.exists():
            continue
        text = doc.read_text()
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, frag = target.partition("#")
            dest = (doc.parent / path_part).resolve() if path_part \
                else doc.resolve()
            rel = str(doc.relative_to(ROOT))
            line = text.count("\n", 0, m.start()) + 1
            if not dest.exists():
                findings.append(Finding(
                    file=rel, line=line, rule="docs-link",
                    message=f"broken link -> {target}"))
                continue
            if frag and dest.suffix == ".md":
                slugs = {github_slug(h) for h in headings(dest)}
                if frag not in slugs:
                    findings.append(Finding(
                        file=rel, line=line, rule="docs-link",
                        message=f"dead anchor -> {target}"))
    return findings


def check_required_links() -> List[Finding]:
    findings: List[Finding] = []
    for src, target in REQUIRED_LINKS:
        doc = ROOT / src
        if not doc.exists():
            findings.append(Finding(
                file=src, line=1, rule="docs-required-link",
                message="required-link source missing"))
            continue
        links = {m.group(1).partition("#")[0]
                 for m in _LINK.finditer(doc.read_text())}
        if target not in links:
            findings.append(Finding(
                file=src, line=1, rule="docs-required-link",
                message=f"must link {target} (required link)"))
    return findings


def check_section_refs() -> list[str]:
    arch = ROOT / "docs/ARCHITECTURE.md"
    design = ROOT / "DESIGN.md"
    all_headings = [h for p in (ROOT / d for d in SECTION_DOCS)
                    if p.exists() for h in headings(p)]
    arch_nums = {m.group(1) for m in
                 re.finditer(r"^##\s+(\d+)\.", arch.read_text(), re.M)}
    design_nums = {m.group(1) for m in
                   re.finditer(r"^##\s+§(\d+)", design.read_text(), re.M)}

    findings: List[Finding] = []
    files: list[Path] = []
    for g in AUDIT_GLOBS:
        files.extend(sorted(ROOT.glob(g)))
    for f in files:
        rel = str(f.relative_to(ROOT))
        lines = f.read_text().splitlines()
        for i, line in enumerate(lines, 1):
            if "§" not in line:
                continue
            # a wrapped docstring can put the doc name at the end of the
            # PREVIOUS line ("...see ARCHITECTURE.md\n§6 ..."), so the
            # doc-name context window spans both lines; quoted titles may
            # not wrap (the regex is line-local by design — keep §"..."
            # on one line)
            context = (lines[i - 2] + " " + line) if i > 1 else line
            for m in _QUOTED_REF.finditer(line):
                title = m.group(1)
                if not any(title in h for h in all_headings):
                    findings.append(Finding(
                        file=rel, line=i, rule="docs-section-ref",
                        message=f"§\"{title}\" matches no heading of "
                                f"{', '.join(SECTION_DOCS)}"))
            stripped = _QUOTED_REF.sub("", line)
            if _ROMAN_REF.search(stripped):
                stripped = _ROMAN_REF.sub("", stripped)   # paper sections
            for m in _NUM_REF.finditer(stripped):
                n = m.group(1)
                if "ARCHITECTURE" in context:
                    if n not in arch_nums:
                        findings.append(Finding(
                            file=rel, line=i, rule="docs-section-ref",
                            message=f"ARCHITECTURE §{n} has no "
                                    f"'## {n}.' section"))
                elif n not in design_nums:
                    findings.append(Finding(
                        file=rel, line=i, rule="docs-section-ref",
                        message=f"§{n} has no '## §{n}' note in DESIGN.md"))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable JSON instead of text")
    args = ap.parse_args(argv)
    findings = check_links() + check_required_links() + check_section_refs()
    if args.json:
        print(render_json(findings))
    elif findings:
        print(render_text(findings))
        print(f"{len(findings)} docs-check failure(s)", file=sys.stderr)
    else:
        print("docs-check: links and §-references all resolve")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
