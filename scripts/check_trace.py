#!/usr/bin/env python3
"""Validate an emitted trace/metrics pair against the instrumentation-point
catalog (CI ``obs-smoke``; docs/OBSERVABILITY.md).

Three checks, any failure exits 1:

1. **Trace schema** — the file is Chrome ``trace_event`` JSON Perfetto can
   load: a ``traceEvents`` list whose entries carry ``name``/``ph``/``pid``/
   ``tid``, with ``ts``+``dur`` on every ``ph="X"`` complete event and
   non-negative durations.
2. **Metrics schema** — every JSON-lines row has ``name``/``kind`` and the
   per-kind value fields (counters/gauges a ``value``, histograms
   ``count``/``sum`` + quantile keys, lifecycles an ``events`` chain).
3. **Coverage** (``--expect MODE``) — every span and metric name the
   catalog (:mod:`repro.obs.points`) registers for MODE appears at least
   once.  A refactor that silently drops a call site passes every
   functional test; this is the guard that notices.

Findings are reported through the shared static-analysis API
(``repro.analysis.base``): uniform ``file:line rule message`` lines,
``--json`` for machines — the same surface as check_static.py and
check_docs.py (docs/STATIC_ANALYSIS.md).

Usage:
  python scripts/check_trace.py --trace t.json --metrics m.jsonl \
      --expect resident-fused-lockstep [--json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.analysis.base import Finding, render_json, render_text  # noqa: E402
from repro.obs.points import EXPECTED_POINTS  # noqa: E402


def check_trace_schema(path: str) -> Tuple[List[Dict[str, Any]],
                                           List[Finding]]:
    findings: List[Finding] = []

    def bad(msg: str, line: int = 0) -> None:
        findings.append(Finding(file=path, line=line, rule="trace-schema",
                                message=msg))

    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        bad(f"unreadable ({e})")
        return [], findings
    events = data.get("traceEvents") if isinstance(data, dict) else data
    if not isinstance(events, list):
        bad("no traceEvents list")
        return [], findings
    for i, e in enumerate(events):
        ctx = f"event #{i} ({e.get('name', '?')!r})"
        for field in ("name", "ph", "pid", "tid"):
            if field not in e:
                bad(f"{ctx}: missing {field!r}")
        if e.get("ph") == "X":
            if "ts" not in e or "dur" not in e:
                bad(f"{ctx}: complete event without ts/dur")
            elif e["dur"] < 0:
                bad(f"{ctx}: negative duration {e['dur']}")
        elif e.get("ph") not in ("M", "i", "X"):
            bad(f"{ctx}: unexpected phase {e.get('ph')!r}")
    return events, findings


def check_metrics_schema(path: str) -> Tuple[List[Dict[str, Any]],
                                             List[Finding]]:
    rows: List[Dict[str, Any]] = []
    findings: List[Finding] = []

    def bad(msg: str, line: int = 0) -> None:
        findings.append(Finding(file=path, line=line, rule="metrics-schema",
                                message=msg))

    try:
        with open(path) as f:
            lines = f.readlines()
    except OSError as e:
        bad(f"unreadable ({e})")
        return rows, findings
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            row = json.loads(line)
        except json.JSONDecodeError as e:
            bad(f"bad JSON ({e})", i + 1)
            continue
        ctx = f"({row.get('name', '?')!r})"
        kind = row.get("kind")
        if "name" not in row or kind is None:
            bad(f"{ctx}: missing name/kind", i + 1)
            continue
        if kind in ("counter", "gauge") and "value" not in row:
            bad(f"{ctx}: {kind} without value", i + 1)
        elif kind == "histogram":
            for field in ("count", "sum"):
                if field not in row:
                    bad(f"{ctx}: histogram without {field!r}", i + 1)
            if not any(k.startswith("p") and k[1:].replace(".", "").isdigit()
                       for k in row):
                bad(f"{ctx}: histogram without quantile keys", i + 1)
        elif kind == "lifecycle":
            ev = row.get("events")
            if not isinstance(ev, list) or not ev:
                bad(f"{ctx}: lifecycle without events chain", i + 1)
        rows.append(row)
    return rows, findings


def check_coverage(mode: str, events: List[Dict[str, Any]],
                   rows: List[Dict[str, Any]], trace_path: str,
                   metrics_path: str) -> List[Finding]:
    findings: List[Finding] = []
    expected = EXPECTED_POINTS.get(mode)
    if expected is None:
        return [Finding(
            file="src/repro/obs/points.py", line=1, rule="obs-coverage",
            message=f"unknown --expect mode {mode!r}; catalog has: "
                    f"{sorted(EXPECTED_POINTS)}")]
    seen_spans = {e.get("name") for e in events if e.get("ph") in ("X", "i")}
    for name in expected["spans"]:
        if name not in seen_spans:
            findings.append(Finding(
                file=trace_path or "<trace>", line=0, rule="obs-coverage",
                message=f"[{mode}] required span {name!r} emitted ZERO "
                        f"events — instrumentation point lost?",
                symbol=name))
    seen_metrics = {r.get("name") for r in rows}
    for name in expected["metrics"]:
        if name not in seen_metrics:
            findings.append(Finding(
                file=metrics_path or "<metrics>", line=0,
                rule="obs-coverage",
                message=f"[{mode}] required metric {name!r} has no "
                        f"snapshot row — instrumentation point lost?",
                symbol=name))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="Chrome trace_event JSON (from --trace-out)")
    ap.add_argument("--metrics", default=None, metavar="FILE",
                    help="metrics JSON-lines snapshot (from --metrics-out)")
    ap.add_argument("--expect", default=None, metavar="MODE",
                    help=f"validate coverage for one serving mode: "
                         f"{sorted(EXPECTED_POINTS)}")
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable JSON instead of text")
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics:
        ap.error("nothing to check: pass --trace and/or --metrics")

    findings: List[Finding] = []
    events: List[Dict[str, Any]] = []
    rows: List[Dict[str, Any]] = []
    summary: Dict[str, Any] = {}
    if args.trace:
        events, got = check_trace_schema(args.trace)
        findings.extend(got)
        spans = sum(1 for e in events if e.get("ph") == "X")
        summary["trace"] = {"events": len(events), "spans": spans}
        if not args.json:
            print(f"trace {args.trace}: {len(events)} events "
                  f"({spans} spans)")
    if args.metrics:
        rows, got = check_metrics_schema(args.metrics)
        findings.extend(got)
        kinds: Dict[str, int] = {}
        for r in rows:
            kinds[r.get("kind", "?")] = kinds.get(r.get("kind", "?"), 0) + 1
        summary["metrics"] = {"rows": len(rows), "kinds": kinds}
        if not args.json:
            print(f"metrics {args.metrics}: {len(rows)} rows {kinds}")
    if args.expect:
        findings.extend(check_coverage(args.expect, events, rows,
                                       args.trace, args.metrics))

    if args.json:
        print(render_json(findings, extra=summary))
        return 1 if findings else 0
    if findings:
        print(render_text(findings), file=sys.stderr)
        print(f"{len(findings)} problem(s)", file=sys.stderr)
        return 1
    print("OK: schema valid"
          + (f", all {args.expect!r} instrumentation points emitted"
             if args.expect else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
