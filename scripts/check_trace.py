#!/usr/bin/env python3
"""Validate an emitted trace/metrics pair against the instrumentation-point
catalog (CI ``obs-smoke``; docs/OBSERVABILITY.md).

Three checks, any failure exits 1:

1. **Trace schema** — the file is Chrome ``trace_event`` JSON Perfetto can
   load: a ``traceEvents`` list whose entries carry ``name``/``ph``/``pid``/
   ``tid``, with ``ts``+``dur`` on every ``ph="X"`` complete event and
   non-negative durations.
2. **Metrics schema** — every JSON-lines row has ``name``/``kind`` and the
   per-kind value fields (counters/gauges a ``value``, histograms
   ``count``/``sum`` + quantile keys, lifecycles an ``events`` chain).
3. **Coverage** (``--expect MODE``) — every span and metric name the
   catalog (:mod:`repro.obs.points`) registers for MODE appears at least
   once.  A refactor that silently drops a call site passes every
   functional test; this is the guard that notices.

Usage:
  python scripts/check_trace.py --trace t.json --metrics m.jsonl \
      --expect resident-fused-lockstep
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.obs.points import EXPECTED_POINTS  # noqa: E402


def check_trace_schema(path: str, errors: List[str]) -> List[Dict[str, Any]]:
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        errors.append(f"trace {path}: unreadable ({e})")
        return []
    events = data.get("traceEvents") if isinstance(data, dict) else data
    if not isinstance(events, list):
        errors.append(f"trace {path}: no traceEvents list")
        return []
    for i, e in enumerate(events):
        ctx = f"trace event #{i} ({e.get('name', '?')!r})"
        for field in ("name", "ph", "pid", "tid"):
            if field not in e:
                errors.append(f"{ctx}: missing {field!r}")
        if e.get("ph") == "X":
            if "ts" not in e or "dur" not in e:
                errors.append(f"{ctx}: complete event without ts/dur")
            elif e["dur"] < 0:
                errors.append(f"{ctx}: negative duration {e['dur']}")
        elif e.get("ph") not in ("M", "i", "X"):
            errors.append(f"{ctx}: unexpected phase {e.get('ph')!r}")
    return events if isinstance(events, list) else []


def check_metrics_schema(path: str, errors: List[str]) -> List[Dict[str, Any]]:
    rows: List[Dict[str, Any]] = []
    try:
        with open(path) as f:
            lines = f.readlines()
    except OSError as e:
        errors.append(f"metrics {path}: unreadable ({e})")
        return rows
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            row = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"metrics line {i + 1}: bad JSON ({e})")
            continue
        ctx = f"metrics line {i + 1} ({row.get('name', '?')!r})"
        kind = row.get("kind")
        if "name" not in row or kind is None:
            errors.append(f"{ctx}: missing name/kind")
            continue
        if kind in ("counter", "gauge") and "value" not in row:
            errors.append(f"{ctx}: {kind} without value")
        elif kind == "histogram":
            for field in ("count", "sum"):
                if field not in row:
                    errors.append(f"{ctx}: histogram without {field!r}")
            if not any(k.startswith("p") and k[1:].replace(".", "").isdigit()
                       for k in row):
                errors.append(f"{ctx}: histogram without quantile keys")
        elif kind == "lifecycle":
            ev = row.get("events")
            if not isinstance(ev, list) or not ev:
                errors.append(f"{ctx}: lifecycle without events chain")
        rows.append(row)
    return rows


def check_coverage(mode: str, events: List[Dict[str, Any]],
                   rows: List[Dict[str, Any]], errors: List[str]) -> None:
    expected = EXPECTED_POINTS.get(mode)
    if expected is None:
        errors.append(f"unknown --expect mode {mode!r}; catalog has: "
                      f"{sorted(EXPECTED_POINTS)}")
        return
    seen_spans = {e.get("name") for e in events if e.get("ph") in ("X", "i")}
    for name in expected["spans"]:
        if name not in seen_spans:
            errors.append(f"[{mode}] required span {name!r} emitted ZERO "
                          f"events — instrumentation point lost?")
    seen_metrics = {r.get("name") for r in rows}
    for name in expected["metrics"]:
        if name not in seen_metrics:
            errors.append(f"[{mode}] required metric {name!r} has no "
                          f"snapshot row — instrumentation point lost?")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="Chrome trace_event JSON (from --trace-out)")
    ap.add_argument("--metrics", default=None, metavar="FILE",
                    help="metrics JSON-lines snapshot (from --metrics-out)")
    ap.add_argument("--expect", default=None, metavar="MODE",
                    help=f"validate coverage for one serving mode: "
                         f"{sorted(EXPECTED_POINTS)}")
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics:
        ap.error("nothing to check: pass --trace and/or --metrics")

    errors: List[str] = []
    events: List[Dict[str, Any]] = []
    rows: List[Dict[str, Any]] = []
    if args.trace:
        events = check_trace_schema(args.trace, errors)
        spans = sum(1 for e in events if e.get("ph") == "X")
        print(f"trace {args.trace}: {len(events)} events ({spans} spans)")
    if args.metrics:
        rows = check_metrics_schema(args.metrics, errors)
        kinds: Dict[str, int] = {}
        for r in rows:
            kinds[r.get("kind", "?")] = kinds.get(r.get("kind", "?"), 0) + 1
        print(f"metrics {args.metrics}: {len(rows)} rows {kinds}")
    if args.expect:
        check_coverage(args.expect, events, rows, errors)

    if errors:
        for e in errors:
            print(f"FAIL: {e}", file=sys.stderr)
        print(f"{len(errors)} problem(s)", file=sys.stderr)
        return 1
    print("OK: schema valid"
          + (f", all {args.expect!r} instrumentation points emitted"
             if args.expect else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
