#!/usr/bin/env python
"""repro-lint driver: run the custom static-analysis checkers (CI
``static-smoke``; docs/STATIC_ANALYSIS.md).

Five checkers prove invariants the functional tests only sample:

* ``twin-consistency``  — resident_*/paged_* twins trace to the same
  canonical op sequence as their scan bodies (the bit-identity hazard
  ROADMAP names, caught at analysis time).
* ``dtype-discipline``  — dequant affine arithmetic is f32; bf16 appears
  only as a dot operand (the PR-4 rule).
* ``jit-host-boundary`` — no obs spans/metrics, ``.item()``, numpy host
  calls, or other Python side effects reachable inside jitted closures,
  scan bodies, or Pallas kernels.
* ``lock-discipline``   — shared mutable attributes of the resident
  prefetcher, block manager, and obs objects are written under their Lock
  or sit in a declared single-writer allowlist.
* ``catalog-sync``      — every obs point in the catalog has an emit site,
  every emit site is cataloged, and the codec/decoder-backend registries
  are complete.

Findings already reviewed live in ``scripts/static_baseline.json`` with a
one-line justification each; the gate is *empty delta*: any finding not in
the baseline exits 1.  ``--update-baseline`` absorbs the current findings
(then edit the justifications before committing).  If ``ruff`` is on PATH
(installed via the ``dev`` extra in CI) it runs as the generic-lint layer
and its diagnostics join the same report; locally it is skipped when absent.

Run:  python scripts/check_static.py [--checks a,b] [--json] [--no-ruff]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
from pathlib import Path
from typing import List

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.analysis.base import (Baseline, CHECKERS, Finding, REPO_ROOT,
                                 render_json, render_text, resolve)

BASELINE_PATH = REPO_ROOT / "scripts" / "static_baseline.json"
RUFF_TARGETS = ["src", "scripts", "tests", "benchmarks"]


def run_ruff(root: Path) -> List[Finding]:
    """Generic-lint layer: ruff with the pyproject minimal config.

    Gated on availability — the container may not ship ruff (it is a dev
    extra, installed in CI); the custom checkers are the mandatory layer.
    """
    exe = shutil.which("ruff")
    if exe is None:
        print("note: ruff not on PATH; skipping generic-lint layer "
              "(CI installs it via the dev extra)", file=sys.stderr)
        return []
    targets = [t for t in RUFF_TARGETS if (root / t).exists()]
    proc = subprocess.run(
        [exe, "check", "--output-format", "json", *targets],
        cwd=root, capture_output=True, text=True)
    if proc.returncode not in (0, 1):
        return [Finding(file="<ruff>", line=0, rule="ruff",
                        message=f"ruff failed: {proc.stderr.strip()[:200]}")]
    out: List[Finding] = []
    for d in json.loads(proc.stdout or "[]"):
        path = Path(d["filename"])
        try:
            file = str(path.relative_to(root))
        except ValueError:
            file = d["filename"]
        out.append(Finding(
            file=file, line=d.get("location", {}).get("row", 0),
            rule=f"ruff/{d.get('code')}", message=d.get("message", "")))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--checks", default=None, metavar="A,B",
                    help=f"comma-separated subset of {sorted(CHECKERS)} "
                         f"(default: all)")
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable JSON instead of text")
    ap.add_argument("--baseline", default=str(BASELINE_PATH), metavar="FILE",
                    help="reviewed suppression file (default: %(default)s)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="absorb current findings into the baseline file "
                         "(edit the justification placeholders afterwards)")
    ap.add_argument("--no-ruff", action="store_true",
                    help="skip the generic ruff layer even if installed")
    args = ap.parse_args(argv)

    names = sorted(CHECKERS) if args.checks is None \
        else [n.strip() for n in args.checks.split(",") if n.strip()]
    for n in names:
        if n not in CHECKERS:
            ap.error(f"unknown checker {n!r}; have {sorted(CHECKERS)}")

    findings: List[Finding] = []
    counts = {}
    for n in names:
        got = resolve(n)(REPO_ROOT)
        counts[n] = len(got)
        findings.extend(got)
    if not args.no_ruff:
        got = run_ruff(REPO_ROOT)
        counts["ruff"] = len(got)
        findings.extend(got)

    baseline = Baseline() if args.no_baseline \
        else Baseline.load(Path(args.baseline))
    new, accepted, stale = baseline.split(findings)

    if args.update_baseline:
        added = baseline.absorb(new)
        for fp in stale:
            del baseline.entries[fp]
        baseline.save(Path(args.baseline))
        print(f"baseline updated: +{added} absorbed, -{len(stale)} stale "
              f"pruned -> {args.baseline}")
        return 0

    if args.json:
        print(render_json(new, extra={
            "checkers": counts,
            "accepted": len(accepted),
            "stale_baseline": stale,
        }))
    else:
        per = " ".join(f"{k}:{v}" for k, v in counts.items())
        print(f"check_static: {per} ({len(accepted)} baselined)")
        if new:
            print(render_text(new))
        for fp in stale:
            print(f"note: stale baseline entry (matches nothing): {fp}",
                  file=sys.stderr)
    if new:
        if not args.json:
            print(f"{len(new)} non-baselined finding(s) — fix them or "
                  f"baseline with a justification "
                  f"(docs/STATIC_ANALYSIS.md)", file=sys.stderr)
        return 1
    if not args.json:
        print("static-smoke: all checkers clean (empty delta vs baseline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
