"""EntroLLM quickstart: mixed quantization -> Huffman -> parallel decode.

Runs in under a minute on CPU.  Shows the three paper mechanisms on a small
transformer: (1) per-layer mixed symmetric/asymmetric quantization,
(2) model-global Huffman coding with the storage container,
(3) lock-step parallel decoding, verified bit-exact against the quantized
weights (the paper's losslessness claim).

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro.configs import registry
from repro.core import quant
from repro.core.quant import Granularity
from repro.core.store import CompressedModel
from repro.models import api

# 1. a small model (reduced glm4 family), with trained-LLM-like weights
cfg = registry.reduced(registry.get("glm4-9b"))
rng = np.random.default_rng(0)
sch = api.build(cfg).schema(cfg)
params = {n: (rng.standard_t(2.5, size=s.shape) * 0.02).astype(np.float32)
          for n, s in sch.items()}
n_params = sum(v.size for v in params.values())
print(f"model: {cfg.name}, {n_params/1e6:.2f}M params")

# 2. inspect the mixed quantization decision per tensor (paper Alg. 1 l. 5)
for name in list(params)[:3]:
    scheme = quant.choose_scheme(params[name])
    print(f"  {name}: {scheme.value}")

# 3. compress: quantize (8-bit, per-layer scales) + global Huffman encode
t0 = time.perf_counter()
cm = CompressedModel.compress(params, bits=8,
                              granularity=Granularity.PER_CHANNEL)
st = cm.stats()
print(f"\ncompressed in {time.perf_counter()-t0:.2f}s:")
print(f"  entropy bound     : {st.entropy_bits:.2f} bits/weight")
print(f"  effective bits    : {st.effective_bits:.2f} (nominal 8)")
print(f"  vs uint8 storage  : -{st.reduction_vs_quant*100:.1f}%")
print(f"  vs fp16 storage   : -{st.reduction_vs_fp16*100:.1f}%")

# 4. save / load the container, parallel-decode, verify losslessness
import tempfile, os
with tempfile.TemporaryDirectory() as d:
    path = os.path.join(d, "model.npz")
    cm.save(path)
    print(f"\ncontainer on disk: {os.path.getsize(path)/1e6:.2f} MB")
    cm2 = CompressedModel.load(path)

t0 = time.perf_counter()
decoded = cm2.decode_all()
print(f"parallel decode: {time.perf_counter()-t0:.2f}s")
for name, q in decoded.items():
    direct = quant.quantize(params[name], 8, Granularity.PER_CHANNEL)
    assert (q == direct.q).all(), name
print("decoded symbols == directly-quantized symbols for every tensor "
      "(lossless)")

# 5. serve one batch with quantized weights resident (dequant fused in matmul)
from repro.serving import engine
import jax.numpy as jnp
serve_params = engine.load_params_from_compressed(cm2, quantized=True)
eng = engine.Engine(cfg, serve_params, engine.ServeConfig(max_len=24))
prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
out = eng.generate(prompt, 8)
print(f"\ngenerated token grid {out.shape} with int8-resident weights:")
print(np.asarray(out))
