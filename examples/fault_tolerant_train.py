"""Fault-tolerance drill: crash mid-run, restore, verify bit-exact resume.

Simulates the failure model of a 1000-node run on one host:
  1. train N steps with async checkpointing;
  2. "crash" (drop all state);
  3. restore the latest committed checkpoint onto a (potentially different)
     device layout;
  4. continue — final weights must equal an uninterrupted run bit-for-bit,
     because the data pipeline is a pure function of the step index;
  5. inject a NaN loss and watch the watchdog roll back.

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import tempfile

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer, CheckpointConfig
from repro.configs import registry
from repro.data.pipeline import DataConfig, SyntheticSource
from repro.distributed.fault_tolerance import NanWatchdog
from repro.models import api
from repro.training import optimizer as opt, train_loop

cfg = registry.reduced(registry.get("stablelm-12b"))
mod = api.build(cfg)
tc = train_loop.TrainConfig(opt=opt.AdamWConfig(
    schedule=opt.Schedule(base_lr=1e-3, warmup_steps=2, total_steps=24)))
src = SyntheticSource(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8,
                                 seed=0))
step_fn = jax.jit(train_loop.make_train_step(cfg, tc))

# --- reference: uninterrupted 12-step run --------------------------------
p = mod.init(cfg, jax.random.PRNGKey(0))
s = opt.init_state(tc.opt, p)
for i in range(12):
    p, s, m = step_fn(p, s, src.batch(i))
ref = {k: np.asarray(v, np.float32) for k, v in p.items()}
print(f"reference run: 12 steps, final loss {float(m['loss']):.4f}")

# --- crash at step 7, restore, resume ------------------------------------
with tempfile.TemporaryDirectory() as d:
    ck = Checkpointer(CheckpointConfig(root=d, keep=2))
    p1 = mod.init(cfg, jax.random.PRNGKey(0))
    s1 = opt.init_state(tc.opt, p1)
    for i in range(7):
        p1, s1, _ = step_fn(p1, s1, src.batch(i))
        if (i + 1) % 3 == 0:
            ck.save_async(i + 1, (p1, s1))
    ck.wait()
    print(f"crash at step 7; latest committed checkpoint: step "
          f"{ck.latest_step()}")
    del p1, s1                                     # the crash

    template = (mod.init(cfg, jax.random.PRNGKey(0)),
                opt.init_state(tc.opt, mod.init(cfg, jax.random.PRNGKey(0))))
    start, (p2, s2) = ck.restore(like=template)
    print(f"restored at step {start}; replaying the data stream from there")
    for i in range(start, 12):
        p2, s2, m = step_fn(p2, s2, src.batch(i))

    drift = max(float(np.abs(ref[k] - np.asarray(p2[k], np.float32)).max())
                for k in ref)
    print(f"resume drift vs uninterrupted run: {drift:.2e} "
          f"({'BIT-EXACT' if drift == 0 else 'nonzero'})")

    # --- NaN watchdog drill ----------------------------------------------
    wd = NanWatchdog(ck, template)
    rolled = wd(99, p2, s2, {"loss": float("nan"), "grad_norm": 1.0})
    print(f"NaN injected at step 99 -> watchdog rollback to step "
          f"{ck.latest_step()}: {'OK' if rolled is not None else 'FAILED'}")
