"""Batched-request serving with EntroLLM weights — uint8 vs uint4 vs dense.

The paper's deployment story end-to-end: one compressed container on "disk",
one parallel decode at engine start, then batched generation with integer
weights resident in memory and dequantization fused into every matmul.
Compares greedy outputs across weight formats (they should mostly agree with
the dense-served quantized model — identical math, different residency) and
prints the bandwidth-roofline projection for a TPU v5e.

    PYTHONPATH=src python examples/compress_serve.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.quant import Granularity
from repro.core.store import CompressedModel
from repro.models import api
from repro.serving import engine

ARCH = "qwen3-1.7b"
BATCH, PROMPT_LEN, GEN = 4, 24, 12

cfg = registry.reduced(registry.get(ARCH))
rng = np.random.default_rng(0)
sch = api.build(cfg).schema(cfg)
params = {n: (rng.standard_t(2.5, size=s.shape) * 0.02).astype(np.float32)
          for n, s in sch.items()}

prompts = jnp.asarray(rng.integers(0, cfg.vocab, (BATCH, PROMPT_LEN)),
                      jnp.int32)

for bits in (8, 4):
    cm = CompressedModel.compress(params, bits=bits,
                                  granularity=Granularity.PER_CHANNEL)
    st = cm.stats()

    t0 = time.perf_counter()
    qt = engine.load_params_from_compressed(cm, quantized=True)
    t_decode = time.perf_counter() - t0
    dense = engine.load_params_from_compressed(cm, quantized=False)

    sc = engine.ServeConfig(max_len=PROMPT_LEN + GEN)
    out_q, mq = engine.Engine(cfg, qt, sc).generate(prompts, GEN,
                                                    echo_metrics=True)
    out_d, md = engine.Engine(cfg, dense, sc).generate(prompts, GEN,
                                                       echo_metrics=True)
    agree = float((np.asarray(out_q) == np.asarray(out_d)).mean())

    hbm_ratio = {8: 2.0, 4: 4.0}[bits]     # fp16 bytes / int bytes
    print(f"== uint{bits} ==")
    print(f"  effective bits {st.effective_bits:.2f} "
          f"(storage -{st.reduction_vs_fp16*100:.0f}% vs fp16); "
          f"one-time parallel decode {t_decode:.2f}s")
    print(f"  int-resident serving: {mq['tok_per_s']:.1f} tok/s | "
          f"dense serving: {md['tok_per_s']:.1f} tok/s (CPU has no "
          f"low-precision bandwidth win; TPU decode-phase bound: "
          f"{hbm_ratio:.0f}x fewer weight bytes)")
    print(f"  greedy-token agreement int vs dense: {agree*100:.0f}%")
    print(f"  sample: {np.asarray(out_q[0])[:8]}")
