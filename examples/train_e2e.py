"""End-to-end training driver: ~100M-parameter model, a few hundred steps.

Exercises the full training substrate on this host: synthetic Markov data
pipeline, AdamW (+ optional EntroLLM-uint8 moments), grad-accum microbatching,
async checkpoints, NaN watchdog, straggler watchdog — then saves an
EntroLLM-compressed checkpoint and verifies a restore round-trip.

    PYTHONPATH=src python examples/train_e2e.py --steps 300
    PYTHONPATH=src python examples/train_e2e.py --steps 30 --quick  # smoke
"""
import argparse
import os
import tempfile
import time

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer, CheckpointConfig
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, SyntheticSource
from repro.distributed.fault_tolerance import (CheckpointHook, NanWatchdog,
                                               StepTimeWatchdog)
from repro.models import api
from repro.training import optimizer as opt, train_loop


def model_100m() -> ArchConfig:
    """~100M dense decoder (qwen family structure)."""
    return ArchConfig(
        name="repro-100m", family="dense", n_layers=8, d_model=512,
        n_heads=8, n_kv_heads=4, d_ff=2048, vocab=32_000, head_dim=64,
        qk_norm=True, source="examples/train_e2e.py")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--seq-len", type=int, default=256)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--microbatches", type=int, default=2)
    p.add_argument("--q8-opt", action="store_true",
                   help="EntroLLM-uint8 optimizer moments")
    p.add_argument("--quick", action="store_true",
                   help="shrink to a smoke-test size")
    args = p.parse_args()

    cfg = model_100m()
    if args.quick:
        cfg = ArchConfig(**{**cfg.__dict__, "name": "repro-100m-quick",
                            "n_layers": 2, "d_model": 128, "d_ff": 256,
                            "vocab": 2048})
        args.seq_len = min(args.seq_len, 64)
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    n = sum(int(np.prod(v.shape)) for v in params.values())
    print(f"{cfg.name}: {n/1e6:.1f}M params, {args.steps} steps, "
          f"seq {args.seq_len}, batch {args.batch}")

    tc = train_loop.TrainConfig(
        opt=opt.AdamWConfig(
            schedule=opt.Schedule(base_lr=3e-3,
                                  warmup_steps=max(args.steps // 20, 2),
                                  total_steps=args.steps),
            quantized_state=args.q8_opt),
        microbatches=args.microbatches)
    state = opt.init_state(tc.opt, params)
    src = SyntheticSource(DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                                     global_batch=args.batch, seed=0))

    with tempfile.TemporaryDirectory() as ckdir:
        ck = Checkpointer(CheckpointConfig(root=ckdir, keep=2))
        watchdog = StepTimeWatchdog()
        hooks = (
            lambda i, p, s, m: watchdog.tick(i) and None,
            CheckpointHook(ck, every=max(args.steps // 3, 10)),
            NanWatchdog(ck, (params, state)),
        )
        t0 = time.perf_counter()
        params, state, info = train_loop.train(
            cfg, tc, params, state, iter(src), args.steps, hooks=hooks)
        wall = time.perf_counter() - t0
        losses = [h["loss"] for h in info["history"]]
        toks = args.steps * args.batch * args.seq_len
        print(f"loss: {losses[0]:.3f} -> {min(losses):.3f} "
              f"| {info['steps_per_s']:.2f} steps/s "
              f"| {toks/wall/1e3:.1f}K tok/s | stragglers flagged: "
              f"{len(watchdog.flagged)}")
        assert min(losses) < losses[0] - 0.3, "loss must fall substantially"

        # EntroLLM-compressed final checkpoint + restore round trip
        ck2 = Checkpointer(CheckpointConfig(
            root=os.path.join(ckdir, "entro"), compress="entro"))
        ck2.save(args.steps, params)
        step, restored = ck2.restore(like=params)
        err = max(float(np.abs(np.asarray(params[k], np.float32)
                               - np.asarray(restored[k], np.float32)).max())
                  for k in params)
        print(f"entro-compressed checkpoint round trip: step={step}, "
              f"max |err| = {err:.2e} (8-bit grid)")


if __name__ == "__main__":
    main()
