"""Multi-device sharded serving vs the single-device engine.

The paper's thesis is that entropy-coded weights should stay
compressed/quantized in device memory; this harness measures the multi-device
extension of that residency: the streaming loader places each QT triple
sharded along its output-channel axis across a ``data x model`` mesh
(``--mesh``, forced host-platform CPU devices by default), so per-device HBM
holds ``~1/|mesh|`` of the weight bytes, while the exact serving profile
gathers weights at their use site so greedy decode stays BIT-IDENTICAL to
the single-device engine (asserted here on every run).

Reported per engine: resident weight bytes per device (min/max/total), KV
cache bytes per device, decode and e2e tok/s.

Usage:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
            PYTHONPATH=src python -m benchmarks.sharded_serving [--quick]
        (or `python -m benchmarks.run sharded`)
"""
from __future__ import annotations

import argparse
import os
import sys

# must precede the first jax backend init; harmless if the operator already
# forced a device count
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()


def _fmt_bytes(n: float) -> str:
    return f"{n / 2**20:.2f} MiB"


def run(arch: str = "qwen3-1.7b", mesh_spec: str = "2x4", bits: int = 8,
        batch: int = 4, prompt_len: int = 32, gen: int = 16) -> dict:
    import jax
    import numpy as np
    from repro.configs import registry
    from repro.core.quant import Granularity
    from repro.core.store import CompressedModel
    from repro.launch import mesh as mesh_lib
    from repro.models import api
    from repro.serving import engine

    mesh = mesh_lib.make_serve_mesh(*mesh_lib.parse_mesh_spec(mesh_spec))

    cfg = registry.reduced(registry.get(arch))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    host = {k: np.asarray(v, np.float32) for k, v in params.items()}
    cm = CompressedModel.compress(host, bits=bits,
                                  granularity=Granularity.PER_CHANNEL)

    sc = engine.ServeConfig(max_len=prompt_len + gen)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab, (batch, prompt_len)).astype(np.int32)

    results = {}
    outs = {}
    for mode in ("single", "sharded"):
        placer = (engine.make_param_placer(cfg, mesh)
                  if mode == "sharded" else None)
        p = engine.load_params_from_compressed(cm, quantized=True,
                                               placer=placer)
        eng = engine.Engine(cfg, p, sc,
                            mesh=mesh if mode == "sharded" else None)
        out, metrics = eng.generate(prompt, gen, echo_metrics=True)
        outs[mode] = np.asarray(out)
        wb = engine.per_device_bytes(p)
        results[mode] = dict(
            weight_bytes=wb,
            decode_tok_per_s=metrics["decode_tok_per_s"],
            e2e_tok_per_s=metrics["e2e_tok_per_s"])
        lo, hi, tot = min(wb.values()), max(wb.values()), sum(wb.values())
        print(f"{mode:8s} [{len(wb)} device(s)]: weights "
              f"{_fmt_bytes(lo)}-{_fmt_bytes(hi)} per device "
              f"({_fmt_bytes(tot)} total), "
              f"{metrics['decode_tok_per_s']:.1f} decode tok/s, "
              f"{metrics['e2e_tok_per_s']:.1f} e2e tok/s")

    assert np.array_equal(outs["single"], outs["sharded"]), \
        "sharded greedy decode must be bit-identical to single-device"
    print("greedy bit-identity: OK "
          f"({outs['single'].shape[0]}x{outs['single'].shape[1]} tokens)")

    single_max = max(results["single"]["weight_bytes"].values())
    shard_max = max(results["sharded"]["weight_bytes"].values())
    print(f"per-device weight HBM: {_fmt_bytes(single_max)} -> "
          f"{_fmt_bytes(shard_max)} "
          f"({single_max / max(shard_max, 1):.2f}x smaller residency)")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--mesh", default="2x4", metavar="DxM")
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quick", action="store_true",
                    help="small shapes for CI smoke")
    args = ap.parse_args(argv)
    if args.quick:
        args.prompt_len, args.gen, args.batch = 16, 8, 2
    run(args.arch, args.mesh, args.bits, args.batch, args.prompt_len,
        args.gen)
    return 0


if __name__ == "__main__":
    sys.exit(main())
