"""Continuous batching vs lockstep serving under synthetic Poisson traffic.

The paper's Jetson speedups assume the accelerator stays busy; this harness
measures whether the serving layer can actually keep it busy when requests
arrive *independently*.  A seeded Poisson process emits N requests (ragged
prompt lengths, ragged ``max_new_tokens``, greedy); the same trace is served
two ways:

  lockstep    — the pre-batching engine's only option for independent
                arrivals: one ``Engine.generate`` call per request, in
                arrival order (request i starts at
                ``max(arrival_i, finish_{i-1})``).
  continuous  — :class:`~repro.serving.batching.ContinuousEngine` with
                ``--slots`` slots: arrivals are queued as their timestamps
                come due, admitted into free slots mid-flight (chunked
                prefill), and detach on completion.

Reported per strategy: queue wait, TTFT, p50/p99 end-to-end latency, and
aggregate tok/s (total generated tokens / makespan).  Every request's greedy
tokens are asserted bit-identical between the two paths — batching must
never change what a request decodes, only when.

Usage:  PYTHONPATH=src python -m benchmarks.serving_traffic [--dry-run]
        (or `python -m benchmarks.run traffic`)
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Optional

import numpy as np

# the ONE shared percentile rule (linear interpolation, agrees with
# np.percentile) — repro.obs.metrics is stdlib-only so this import is free
from repro.obs.metrics import percentile as _percentile


def run_lockstep(eng, trace):
    """Serial Engine.generate per arrival — the lockstep baseline."""
    import jax.numpy as jnp
    t0 = time.monotonic()
    outs, rows = [], []
    for arrival, prompt, max_new in trace:
        now = time.monotonic() - t0
        if now < arrival:
            time.sleep(arrival - now)
            now = arrival
        start = time.monotonic() - t0               # generate begins
        out, m = eng.generate(jnp.asarray(prompt[None]), max_new,
                              echo_metrics=True)
        done = time.monotonic() - t0
        outs.append(np.asarray(out)[0].tolist())
        rows.append(dict(queue_wait=start - arrival,
                         ttft=start - arrival + m["ttft_s"],
                         latency=done - arrival, n_tokens=max_new))
    makespan = time.monotonic() - t0
    return outs, rows, makespan


def run_continuous(ce, trace):
    """Feed the trace through the ContinuousEngine as timestamps come due."""
    from repro.serving.batching import replay
    requests, shed, makespan = replay(ce, trace)
    done = [r for r in requests if r is not None]
    outs = [r.output for r in done]
    rows = [dict(queue_wait=r.queue_wait_s, ttft=r.ttft_s,
                 latency=r.latency_s, n_tokens=len(r.output),
                 outcome="admitted")
            for r in done]
    return outs, rows, makespan, shed


def _report(name, rows, makespan, shed=0):
    toks = sum(r["n_tokens"] for r in rows)
    lat = [r["latency"] for r in rows]
    # queue wait labeled by outcome: shed requests never waited through to
    # admission, so their waits are not mixed into the admitted percentiles
    wait = [r["queue_wait"] for r in rows
            if r.get("outcome", "admitted") == "admitted"]
    print(f"  {name:<11} {toks:4d} tok in {makespan:6.2f}s "
          f"= {toks / max(makespan, 1e-9):7.1f} tok/s | "
          f"queue wait[admitted] p50 {_percentile(wait, 50)*1e3:6.1f}ms | "
          f"ttft p50 {_percentile([r['ttft'] for r in rows], 50)*1e3:6.1f}ms | "
          f"latency p50/p99 {_percentile(lat, 50)*1e3:7.1f}/"
          f"{_percentile(lat, 99)*1e3:7.1f}ms"
          + (f" | {shed} shed" if shed else ""))
    return toks / max(makespan, 1e-9)


def run(model: str = "qwen3-1.7b", *, n_requests: int = 16, slots: int = 8,
        rate_per_s: float = 100.0, prompt_max: int = 24, gen_max: int = 12,
        prefill_chunk: int = 8, check_speedup: Optional[float] = None,
        seed: int = 0, verbose: bool = True) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs import registry
    from repro.models import api
    from repro.serving import engine as serving_engine
    from repro.serving.batching import ContinuousEngine, poisson_trace

    cfg = registry.reduced(registry.get(model))
    params = api.build(cfg).init(cfg, jax.random.PRNGKey(0))
    max_len = prompt_max + gen_max + prefill_chunk
    sc = serving_engine.ServeConfig(max_len=max_len)
    eng = serving_engine.Engine(cfg, params, sc)
    trace = poisson_trace(n_requests, rate_per_s=rate_per_s,
                          prompt_max=prompt_max, gen_max=gen_max,
                          vocab=cfg.vocab, seed=seed)

    # warm both paths so the comparison measures serving, not XLA compiles:
    # every (prompt_len) shape for lockstep, the slot/chunk shapes for CB
    for _, prompt, _ in trace:
        eng.generate(jnp.asarray(prompt[None]), 2)
    warm = ContinuousEngine(cfg, params, sc, n_slots=slots,
                            max_queue=n_requests,
                            prefill_chunk=prefill_chunk, steps=eng.steps)
    for _, prompt, max_new in trace[:2]:
        warm.submit(prompt, max_new)
    warm.run()

    if verbose:
        print(f"{cfg.name}: {n_requests} Poisson arrivals @ {rate_per_s}/s, "
              f"prompts ≤{prompt_max}, gen ≤{gen_max}, {slots} slots")
    outs_l, rows_l, span_l = run_lockstep(eng, trace)
    ce = ContinuousEngine(cfg, params, sc, n_slots=slots,
                          max_queue=n_requests, prefill_chunk=prefill_chunk,
                          steps=eng.steps)
    outs_c, rows_c, span_c, shed_c = run_continuous(ce, trace)

    for i, (a, b) in enumerate(zip(outs_l, outs_c)):
        assert a == b, (f"request {i}: continuous batching changed greedy "
                        f"tokens\n  lockstep   {a}\n  continuous {b}")
    tps_l = _report("lockstep", rows_l, span_l) if verbose else \
        sum(r["n_tokens"] for r in rows_l) / max(span_l, 1e-9)
    tps_c = _report("continuous", rows_c, span_c, shed_c) if verbose else \
        sum(r["n_tokens"] for r in rows_c) / max(span_c, 1e-9)
    speedup = tps_c / max(tps_l, 1e-9)
    if verbose:
        print(f"  aggregate speedup: {speedup:.2f}x "
              f"({len(outs_c)} requests bit-identical)")
    if check_speedup is not None:
        assert speedup >= check_speedup, \
            f"continuous batching {speedup:.2f}x < required {check_speedup}x"
    return dict(speedup=speedup, tok_per_s_lockstep=tps_l,
                tok_per_s_continuous=tps_c)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="qwen3-1.7b")
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--slots", type=int, default=8)
    p.add_argument("--rate", type=float, default=100.0,
                   help="Poisson arrival rate, requests/s")
    p.add_argument("--prompt-max", type=int, default=24)
    p.add_argument("--gen-max", type=int, default=12)
    p.add_argument("--prefill-chunk", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--check", type=float, default=None, metavar="X",
                   help="fail unless continuous >= X times lockstep tok/s")
    p.add_argument("--dry-run", action="store_true",
                   help="tiny CI smoke: few requests, no speedup gate")
    args = p.parse_args(argv)
    if args.dry_run:
        run(args.arch, n_requests=4, slots=2, rate_per_s=200.0, prompt_max=10,
            gen_max=5, prefill_chunk=4, seed=args.seed)
        return 0
    run(args.arch, n_requests=args.requests, slots=args.slots,
        rate_per_s=args.rate, prompt_max=args.prompt_max,
        gen_max=args.gen_max, prefill_chunk=args.prefill_chunk,
        check_speedup=args.check, seed=args.seed)
    return 0


if __name__ == "__main__":
    sys.exit(main())
