"""Continuous batching vs lockstep serving under synthetic Poisson traffic.

The paper's Jetson speedups assume the accelerator stays busy; this harness
measures whether the serving layer can actually keep it busy when requests
arrive *independently*.  A seeded Poisson process emits N requests (ragged
prompt lengths, ragged ``max_new_tokens``, greedy); the same trace is served
two ways:

  lockstep    — the pre-batching engine's only option for independent
                arrivals: one ``Engine.generate`` call per request, in
                arrival order (request i starts at
                ``max(arrival_i, finish_{i-1})``).
  continuous  — :class:`~repro.serving.batching.ContinuousEngine` with
                ``--slots`` slots: arrivals are queued as their timestamps
                come due, admitted into free slots mid-flight (chunked
                prefill), and detach on completion.

Reported per strategy: queue wait, TTFT, p50/p99 end-to-end latency, and
aggregate tok/s (total generated tokens / makespan).  Every request's greedy
tokens are asserted bit-identical between the two paths — batching must
never change what a request decodes, only when.

Usage:  PYTHONPATH=src python -m benchmarks.serving_traffic [--dry-run]
        (or `python -m benchmarks.run traffic`)
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Optional

import numpy as np

# the ONE shared percentile rule (linear interpolation, agrees with
# np.percentile) — repro.obs.metrics is stdlib-only so this import is free
from repro.obs.metrics import percentile as _percentile


def run_lockstep(eng, trace):
    """Serial Engine.generate per arrival — the lockstep baseline."""
    import jax.numpy as jnp
    t0 = time.monotonic()
    outs, rows = [], []
    for arrival, prompt, max_new in trace:
        now = time.monotonic() - t0
        if now < arrival:
            time.sleep(arrival - now)
            now = arrival
        start = time.monotonic() - t0               # generate begins
        out, m = eng.generate(jnp.asarray(prompt[None]), max_new,
                              echo_metrics=True)
        done = time.monotonic() - t0
        outs.append(np.asarray(out)[0].tolist())
        rows.append(dict(queue_wait=start - arrival,
                         ttft=start - arrival + m["ttft_s"],
                         latency=done - arrival, n_tokens=max_new))
    makespan = time.monotonic() - t0
    return outs, rows, makespan


def run_continuous(ce, trace):
    """Feed the trace through the ContinuousEngine as timestamps come due."""
    from repro.serving.batching import replay
    requests, shed, makespan = replay(ce, trace)
    done = [r for r in requests if r is not None]
    outs = [r.output for r in done]
    rows = [dict(queue_wait=r.queue_wait_s, ttft=r.ttft_s,
                 latency=r.latency_s, n_tokens=len(r.output),
                 outcome="admitted")
            for r in done]
    return outs, rows, makespan, shed


def _report(name, rows, makespan, shed=0):
    toks = sum(r["n_tokens"] for r in rows)
    lat = [r["latency"] for r in rows]
    # queue wait labeled by outcome: shed requests never waited through to
    # admission, so their waits are not mixed into the admitted percentiles
    wait = [r["queue_wait"] for r in rows
            if r.get("outcome", "admitted") == "admitted"]
    print(f"  {name:<11} {toks:4d} tok in {makespan:6.2f}s "
          f"= {toks / max(makespan, 1e-9):7.1f} tok/s | "
          f"queue wait[admitted] p50 {_percentile(wait, 50)*1e3:6.1f}ms | "
          f"ttft p50 {_percentile([r['ttft'] for r in rows], 50)*1e3:6.1f}ms | "
          f"latency p50/p99 {_percentile(lat, 50)*1e3:7.1f}/"
          f"{_percentile(lat, 99)*1e3:7.1f}ms"
          + (f" | {shed} shed" if shed else ""))
    return toks / max(makespan, 1e-9)


def run(model: str = "qwen3-1.7b", *, n_requests: int = 16, slots: int = 8,
        rate_per_s: float = 100.0, prompt_max: int = 24, gen_max: int = 12,
        prefill_chunk: int = 8, check_speedup: Optional[float] = None,
        seed: int = 0, verbose: bool = True) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs import registry
    from repro.models import api
    from repro.serving import engine as serving_engine
    from repro.serving.batching import ContinuousEngine, poisson_trace

    cfg = registry.reduced(registry.get(model))
    params = api.build(cfg).init(cfg, jax.random.PRNGKey(0))
    max_len = prompt_max + gen_max + prefill_chunk
    sc = serving_engine.ServeConfig(max_len=max_len)
    eng = serving_engine.Engine(cfg, params, sc)
    trace = poisson_trace(n_requests, rate_per_s=rate_per_s,
                          prompt_max=prompt_max, gen_max=gen_max,
                          vocab=cfg.vocab, seed=seed)

    # warm both paths so the comparison measures serving, not XLA compiles:
    # every (prompt_len) shape for lockstep, the slot/chunk shapes for CB
    for _, prompt, _ in trace:
        eng.generate(jnp.asarray(prompt[None]), 2)
    warm = ContinuousEngine(cfg, params, sc, n_slots=slots,
                            max_queue=n_requests,
                            prefill_chunk=prefill_chunk, steps=eng.steps)
    for _, prompt, max_new in trace[:2]:
        warm.submit(prompt, max_new)
    warm.run()

    if verbose:
        print(f"{cfg.name}: {n_requests} Poisson arrivals @ {rate_per_s}/s, "
              f"prompts ≤{prompt_max}, gen ≤{gen_max}, {slots} slots")
    outs_l, rows_l, span_l = run_lockstep(eng, trace)
    ce = ContinuousEngine(cfg, params, sc, n_slots=slots,
                          max_queue=n_requests, prefill_chunk=prefill_chunk,
                          steps=eng.steps)
    outs_c, rows_c, span_c, shed_c = run_continuous(ce, trace)

    for i, (a, b) in enumerate(zip(outs_l, outs_c)):
        assert a == b, (f"request {i}: continuous batching changed greedy "
                        f"tokens\n  lockstep   {a}\n  continuous {b}")
    tps_l = _report("lockstep", rows_l, span_l) if verbose else \
        sum(r["n_tokens"] for r in rows_l) / max(span_l, 1e-9)
    tps_c = _report("continuous", rows_c, span_c, shed_c) if verbose else \
        sum(r["n_tokens"] for r in rows_c) / max(span_c, 1e-9)
    speedup = tps_c / max(tps_l, 1e-9)
    if verbose:
        print(f"  aggregate speedup: {speedup:.2f}x "
              f"({len(outs_c)} requests bit-identical)")
    if check_speedup is not None:
        assert speedup >= check_speedup, \
            f"continuous batching {speedup:.2f}x < required {check_speedup}x"
    return dict(speedup=speedup, tok_per_s_lockstep=tps_l,
                tok_per_s_continuous=tps_c)


def run_paged(model: str = "qwen3-1.7b", *, n_requests: int = 8,
              slots: int = 4, prompt_max: int = 16, gen_max: int = 8,
              prefill_chunk: int = 8, kv_bits: int = 4, kv_block: int = 8,
              prefix_pool: int = 2, prefix_len: Optional[int] = None,
              check_ratio: Optional[float] = None,
              check_drift: Optional[float] = None, seed: int = 0,
              verbose: bool = True) -> dict:
    """Paged KV cache vs the PR 2 slot pool, at a fixed KV HBM budget.

    Three runs over one prefix-shared trace (``prefix_pool`` shared system
    prompts), all greedy and deterministic:

      slot pool    — the reference ``SlotBatchManager`` engine;
      dense paged  — ``bits=16`` block pool + prefix sharing, asserted
                     BIT-IDENTICAL to the slot pool (the drift contract);
      quantized    — ``kv_bits`` block pool sized to the slot pool's byte
                     budget: the freed bytes become extra concurrent slots
                     (``ratio`` = paged slots / baseline slots at the same
                     budget) at the cost of a bounded greedy-token
                     divergence rate, which is measured and reported.
    """
    import jax
    from repro.configs import registry
    from repro.core.spec import KVCompressionSpec
    from repro.models import api
    from repro.serving import engine as serving_engine
    from repro.serving.batching import ContinuousEngine, poisson_trace
    from repro.serving.kvcache import kv_cache_bytes, kv_pool_bytes

    assert prefill_chunk % kv_block == 0, \
        f"prefix sharing needs chunk % block == 0 ({prefill_chunk}, {kv_block})"
    cfg = registry.reduced(registry.get(model))
    params = api.build(cfg).init(cfg, jax.random.PRNGKey(0))
    if prefix_len is None:
        prefix_len = 2 * kv_block
    budget_len = max(prompt_max, prefix_len + 1) + gen_max + prefill_chunk
    # strict dense bit-identity needs identical attention reduction shapes:
    # gathered length = max_blocks * block == max_len (docs/KV_CACHE.md)
    max_len = -(-budget_len // kv_block) * kv_block
    sc = serving_engine.ServeConfig(max_len=max_len)
    trace = poisson_trace(n_requests, rate_per_s=1e9, prompt_max=prompt_max,
                          gen_max=gen_max, vocab=cfg.vocab, seed=seed,
                          prefix_pool=prefix_pool, prefix_len=prefix_len)

    def serve(kv_spec=None, n_slots=slots, kv_blocks=None):
        ce = ContinuousEngine(cfg, params, sc, n_slots=n_slots,
                              max_queue=n_requests,
                              prefill_chunk=prefill_chunk,
                              kv_spec=kv_spec, kv_blocks=kv_blocks)
        for _, prompt, max_new in trace:
            ce.submit(prompt, max_new)
        t0 = time.monotonic()
        ce.run()
        span = time.monotonic() - t0
        outs = [list(r.output) for r in
                sorted(ce.finished, key=lambda r: r.rid)]
        return ce, outs, span

    if verbose:
        print(f"{cfg.name}: {n_requests} requests, {prefix_pool} shared "
              f"prefixes x {prefix_len} tok, prompts ≤{prompt_max}, "
              f"gen ≤{gen_max}, max_len {max_len}")
    _, ref_outs, _ = serve()
    budget = kv_cache_bytes(cfg, slots, max_len)

    dense_spec = KVCompressionSpec(bits=16, block_size=kv_block, sharing=True)
    de, dense_outs, _ = serve(dense_spec)
    assert dense_outs == ref_outs, \
        "dense paged mode changed greedy tokens vs the slot pool"
    dstats = de.slots.stats()
    if verbose:
        print(f"  dense paged [{dense_spec.describe()}]: BIT-IDENTICAL to "
              f"the slot pool; prefix hit rate "
              f"{dstats['prefix_hit_rate']*100:.0f}% "
              f"({dstats['shared_hits']}/{dstats['shared_hits'] + dstats['shared_misses']})")

    q_spec = KVCompressionSpec(bits=kv_bits, block_size=kv_block,
                               codec="rans", sharing=True)
    block_bytes = kv_pool_bytes(cfg, 1, kv_block, kv_bits)
    n_blocks = budget // block_bytes
    blocks_per_req = max_len // kv_block
    slots_q = (n_blocks - 1) // blocks_per_req        # -1: the trash block
    ratio = slots_q / slots
    qe, q_outs, q_span = serve(q_spec, n_slots=min(slots_q, n_requests),
                               kv_blocks=n_blocks)
    pool_q = qe.slots.pool_bytes
    diverged = total = 0
    for ref, q in zip(ref_outs, q_outs):
        total += len(ref)
        diverged += sum(a != b for a, b in zip(ref, q))
    drift = diverged / max(total, 1)
    qstats = qe.slots.stats()
    toks = sum(len(o) for o in q_outs)
    if verbose:
        print(f"  quantized  [{q_spec.describe()}]: pool {pool_q} B vs "
              f"slot-pool budget {budget} B -> {n_blocks} blocks = "
              f"{slots_q} concurrent slots ({ratio:.1f}x the {slots}-slot "
              f"baseline at the same KV HBM budget)")
        print(f"  quantized drift: {diverged}/{total} greedy tokens diverge "
              f"({drift*100:.0f}%) | prefix hit rate "
              f"{qstats['prefix_hit_rate']*100:.0f}% | {toks} tok in "
              f"{q_span:.2f}s")
    assert pool_q <= budget, (pool_q, budget)
    if check_ratio is not None:
        assert ratio >= check_ratio, \
            (f"quantized KV fits only {ratio:.2f}x the baseline slots at the "
             f"same budget; required {check_ratio}x")
    if check_drift is not None:
        assert drift <= check_drift, \
            f"greedy drift {drift:.2f} above bound {check_drift}"
    return dict(ratio=ratio, slots_q=slots_q, drift=drift,
                prefix_hit_rate=qstats["prefix_hit_rate"],
                pool_bytes=pool_q, budget_bytes=budget)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="qwen3-1.7b")
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--slots", type=int, default=8)
    p.add_argument("--rate", type=float, default=100.0,
                   help="Poisson arrival rate, requests/s")
    p.add_argument("--prompt-max", type=int, default=24)
    p.add_argument("--gen-max", type=int, default=12)
    p.add_argument("--prefill-chunk", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--check", type=float, default=None, metavar="X",
                   help="fail unless continuous >= X times lockstep tok/s")
    p.add_argument("--paged", action="store_true",
                   help="paged-KV mode: slot pool vs dense-paged "
                        "(bit-identity gate) vs quantized-paged at the same "
                        "KV HBM budget (concurrency + drift gates)")
    p.add_argument("--kv-bits", type=int, default=4)
    p.add_argument("--kv-block", type=int, default=8)
    p.add_argument("--prefix-pool", type=int, default=2,
                   help="distinct shared system prompts in the trace")
    p.add_argument("--prefix-len", type=int, default=None)
    p.add_argument("--check-ratio", type=float, default=None, metavar="X",
                   help="with --paged: fail unless quantized KV fits >= X "
                        "times the baseline slots at the same budget")
    p.add_argument("--check-drift", type=float, default=None, metavar="D",
                   help="with --paged: fail unless greedy token divergence "
                        "<= D (fraction)")
    p.add_argument("--dry-run", action="store_true",
                   help="tiny CI smoke: few requests, no speedup gate")
    args = p.parse_args(argv)
    if args.paged:
        kw = dict(kv_bits=args.kv_bits, kv_block=args.kv_block,
                  prefix_pool=args.prefix_pool, prefix_len=args.prefix_len,
                  check_ratio=args.check_ratio, check_drift=args.check_drift,
                  seed=args.seed)
        if args.dry_run:
            run_paged(args.arch, n_requests=4, slots=2, prompt_max=12,
                      gen_max=5, prefill_chunk=args.kv_block, **kw)
        else:
            run_paged(args.arch, n_requests=args.requests, slots=args.slots,
                      prompt_max=args.prompt_max, gen_max=args.gen_max,
                      prefill_chunk=args.prefill_chunk, **kw)
        return 0
    if args.dry_run:
        run(args.arch, n_requests=4, slots=2, rate_per_s=200.0, prompt_max=10,
            gen_max=5, prefill_chunk=4, seed=args.seed)
        return 0
    run(args.arch, n_requests=args.requests, slots=args.slots,
        rate_per_s=args.rate, prompt_max=args.prompt_max,
        gen_max=args.gen_max, prefill_chunk=args.prefill_chunk,
        check_speedup=args.check, seed=args.seed)
    return 0


if __name__ == "__main__":
    sys.exit(main())
