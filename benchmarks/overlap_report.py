"""Decode/compute overlap report for compressed-resident serving.

The compressed-resident pipeline's whole bet (paper §IV; docs/SERVING.md
§"Compressed-resident serving") is that layer *l+1*'s entropy decode hides
under layer *l*'s compute.  This harness makes that claim a number: it runs
a traced compressed-resident serve (or analyzes a ``--trace FILE`` emitted
by ``repro.launch.serve --trace-out``) and reduces the trace to

  * **overlap fraction** — share of worker decode time that ran while the
    main thread was busy stepping (not blocked in ``consume_wait``), i.e.
    decode actually hidden under compute.  1.0 = perfectly pipelined.
  * **prefetch stall** — total wall-clock the step loop spent blocked in
    ``resident.consume_wait`` waiting for a layer's decode.

The in-process mode also serves once WITHOUT tracing first and asserts the
traced greedy tokens are bit-identical (observability is a pure observer)
and reports the tracing overhead on decode tok/s.

Usage:  PYTHONPATH=src python -m benchmarks.overlap_report [--quick]
        PYTHONPATH=src python -m benchmarks.overlap_report --trace t.json
        (or `python -m benchmarks.run overlap`)
"""
from __future__ import annotations

import argparse
import sys


def report_from_events(events, verbose: bool = True) -> dict:
    """Print + return the overlap metrics for one trace's events."""
    from repro.obs import analysis
    rep = analysis.overlap_report(events)
    if verbose:
        if rep["n_decode_spans"] == 0:
            print("  no resident.decode spans in trace — was the serve run "
                  "with --resident compressed and --trace-out?")
        frac = rep["overlap_fraction"]
        print(f"  worker decode {rep['decode_s']*1e3:8.1f}ms over "
              f"{rep['n_decode_spans']:.0f} spans; "
              f"step window {rep['step_s']*1e3:8.1f}ms")
        print(f"  overlap fraction {frac:6.1%}  "
              f"(hidden {rep['overlapped_decode_s']*1e3:.1f}ms)"
              if frac == frac else "  overlap fraction: n/a (no decode spans)")
        print(f"  prefetch stall   {rep['stall_s']*1e3:8.1f}ms over "
              f"{rep['n_wait_spans']:.0f} consume waits")
    return rep


def run(arch: str = "qwen3-1.7b", bits: int = 8, batch: int = 2,
        prompt_len: int = 16, gen: int = 16, segment_symbols: int = 1024,
        chunk_symbols: int = 64 * 1024, fused: bool = False,
        out: str | None = None, verbose: bool = True) -> dict:
    """Traced compressed-resident serve -> overlap metrics (+ optional
    trace file for Perfetto)."""
    import jax
    import numpy as np
    from repro.configs import registry
    from repro.core.quant import Granularity
    from repro.core.spec import spec_from_legacy
    from repro.core.store import CompressedModel
    from repro.models import api
    from repro.obs import trace as obs_trace
    from repro.serving import engine
    from repro.serving.resident import CompressedResidentWeights

    cfg = registry.reduced(registry.get(arch))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    host = {k: np.asarray(v, np.float32) for k, v in params.items()}
    cm = CompressedModel.compress(host, spec=spec_from_legacy(
        bits, Granularity.PER_CHANNEL, segment_symbols=segment_symbols))

    sc = engine.ServeConfig(max_len=prompt_len + gen)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab, (batch, prompt_len)).astype(np.int32)
    weights = CompressedResidentWeights(cm, cfg, fused=fused,
                                        chunk_symbols=chunk_symbols)
    eng = engine.Engine(cfg, weights, sc, resident="compressed")

    # 1) warm + untraced baseline: compiles amortized, reference tokens
    eng.generate(prompt, 2)
    out_off, m_off = eng.generate(prompt, gen, echo_metrics=True)

    # 2) traced serve — must not change a single token
    tracer = obs_trace.enable()
    out_on, m_on = eng.generate(prompt, gen, echo_metrics=True)
    obs_trace.disable()
    assert np.array_equal(np.asarray(out_off), np.asarray(out_on)), \
        "tracing changed greedy outputs — observability must be pure"

    overhead = 1.0 - m_on["decode_tok_per_s"] / \
        max(m_off["decode_tok_per_s"], 1e-9)
    if verbose:
        print(f"{cfg.name}: {bits}b, batch {batch}, gen {gen}, "
              f"fused={fused}; traced serve bit-identical to untraced")
        print(f"  decode tok/s untraced {m_off['decode_tok_per_s']:8.1f} | "
              f"traced {m_on['decode_tok_per_s']:8.1f} "
              f"(overhead {overhead:+.1%} — single-run, noisy on small "
              f"configs)")
    events = tracer.chrome_trace()["traceEvents"]
    rep = report_from_events(events, verbose=verbose)
    rep["trace_overhead"] = overhead
    if out:
        n = tracer.save(out)
        if verbose:
            print(f"  trace: {n} events -> {out} (open in ui.perfetto.dev)")
    return rep


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="analyze an existing trace_event JSON (e.g. from "
                         "repro.launch.serve --trace-out) instead of serving")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--segment-symbols", type=int, default=1024)
    ap.add_argument("--chunk-symbols", type=int, default=64 * 1024)
    ap.add_argument("--fused", action="store_true",
                    help="serve through the fused decode→dequant→matmul path")
    ap.add_argument("--out", default=None, metavar="FILE",
                    help="also write the trace_event JSON")
    ap.add_argument("--quick", action="store_true",
                    help="small shapes for CI smoke")
    args = ap.parse_args(argv)
    if args.trace:
        from repro.obs import analysis
        print(f"trace: {args.trace}")
        report_from_events(analysis.load_trace_events(args.trace))
        return 0
    if args.quick:
        args.prompt_len, args.gen, args.batch = 8, 6, 1
    run(args.arch, args.bits, args.batch, args.prompt_len, args.gen,
        args.segment_symbols, args.chunk_symbols, fused=args.fused,
        out=args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
