"""Render the §Roofline table from dry-run JSON results.

    PYTHONPATH=src python -m benchmarks.roofline_report results/dryrun_baseline.json
"""
from __future__ import annotations

import json
import sys


def fmt_row(d):
    if "skipped" in d:
        return (f"| {d['arch']} | {d['shape']} | {d.get('mesh','—')} | — | — "
                f"| — | — | — | skip: sub-quadratic only |")
    if "error" in d:
        return (f"| {d['arch']} | {d['shape']} | {d.get('mesh','?')} | — | — "
                f"| — | — | — | ERROR |")
    if d.get("compile_only"):
        ma = d["memory_analysis"]
        return (f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — | — | "
                f"compile+memory OK ({(ma['argument_size']+ma['temp_size'])/2**30:.1f} GiB) "
                f"| — | — |")
    frac = d["model_flops"] / max(d["chips"], 1) / 197e12 / max(d["step_s"], 1e-30)
    return ("| {arch} | {shape} | {mesh} | {c:.1f} | {m:.2f} | {w:.1f} | "
            "{dom} | {ratio:.2f} | {frac:.3f} |").format(
        arch=d["arch"], shape=d["shape"], mesh=d["mesh"],
        c=d["compute_s"] * 1e3, m=d["memory_s"] * 1e3,
        w=d["collective_s"] * 1e3, dom=d["dominant"],
        ratio=d["flops_ratio"], frac=min(frac, 1.0))


def run(path="results/dryrun_baseline.json", verbose=True):
    with open(path) as f:
        cells = json.load(f)
    lines = [
        "| arch | shape | mesh | compute ms | memory ms | collective ms | "
        "dominant | model/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for d in cells:
        lines.append(fmt_row(d))
    table = "\n".join(lines)
    if verbose:
        print(table)
    return table


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_baseline.json")
