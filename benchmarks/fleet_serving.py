"""Fleet serving: aggregate throughput scaling across DP engine replicas.

One compressed container is decoded ONCE (``FleetDriver.from_container``,
``weights="share"``) and served by fleets of 1..N ``ContinuousEngine``
replicas behind the request router, each replica pinned to its own forced
XLA host device and stepped by its own worker thread
(``replay_fleet(threaded=True)`` — docs/FLEET.md §"Drive modes").  The same
seeded Poisson trace replays against every fleet size plus a single-engine
reference, and every request's greedy tokens are asserted **bit-identical**
across all of them — scaling must change only *when* tokens appear, never
*what* they are.

Reported per fleet size: aggregate tok/s, per-replica token split, TTFT
p50/p99, end-to-end latency p50/p99, shed count.  The headline is the
scaling ratio (N-replica tok/s over 1-replica tok/s) and the efficiency
(ratio / N).  ``--check-scaling X`` gates the N-replica ratio (CI passes
1.7 for N=2 on multi-core runners; a single-core host serializes replica
compute, so the gate is opt-in, not default).

``--trace-out``/``--metrics-out`` export the observability artifacts;
``scripts/check_trace.py --expect fleet-continuous`` validates them against
the instrumentation-point catalog (the CI ``fleet-smoke`` job does).

Usage:  PYTHONPATH=src python -m benchmarks.fleet_serving [--quick]
        (or `python -m benchmarks.run fleet`)
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _force_host_devices(n: int) -> None:
    """Set the forced device count BEFORE jax initializes its backend —
    replica pinning needs >= n host devices to exist."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            (flags + f" --xla_force_host_platform_device_count={n}").strip()


def run(arch: str = "qwen3-1.7b", *, bits: int = 8, n_requests: int = 12,
        replica_counts=(1, 2), slots: int = 2, policy: str = "least-loaded",
        rate_per_s: float = 200.0, prompt_max: int = 16, gen_max: int = 10,
        prefill_chunk: int = 4, check_scaling=None, seed: int = 0,
        verbose: bool = True) -> dict:
    import jax
    import numpy as np
    from repro.configs import registry
    from repro.core.quant import Granularity
    from repro.core.spec import spec_from_legacy
    from repro.core.store import CompressedModel
    from repro.models import api
    from repro.obs.metrics import percentile
    from repro.serving import engine as serving_engine
    from repro.serving.batching import (ContinuousEngine, poisson_trace,
                                        replay_fleet)
    from repro.serving.fleet import FleetDriver

    cfg = registry.reduced(registry.get(arch))
    mod = api.build(cfg)
    params0 = mod.init(cfg, jax.random.PRNGKey(0))
    host = {k: np.asarray(v, np.float32) for k, v in params0.items()}
    cm = CompressedModel.compress(host, spec=spec_from_legacy(
        bits, Granularity.PER_CHANNEL, codec="rans"))

    sc = serving_engine.ServeConfig(max_len=prompt_max + gen_max
                                    + prefill_chunk)
    trace = poisson_trace(n_requests, rate_per_s=rate_per_s,
                          prompt_max=prompt_max, gen_max=gen_max,
                          vocab=cfg.vocab, seed=seed)
    n_max = max(replica_counts)
    devices = jax.devices()[:n_max]

    # single-engine reference: the bit-identity baseline AND the shape
    # warm-up (fleets share these jitted steps, so no fleet run compiles)
    ref = ContinuousEngine(cfg,
                           serving_engine.load_params_from_compressed(cm),
                           sc, n_slots=slots, max_queue=n_requests,
                           prefill_chunk=prefill_chunk)
    ref_reqs = [ref.submit(p, g) for _, p, g in trace]
    ref.run()
    refs = [r.output for r in ref_reqs]
    assert all(r.finish_reason == "length" for r in ref_reqs)

    if verbose:
        print(f"{cfg.name}: {n_requests} Poisson arrivals @ {rate_per_s}/s, "
              f"prompts ≤{prompt_max}, gen ≤{gen_max}, {slots} slots per "
              f"replica, router {policy}, {len(devices)} forced host "
              f"device(s)")
    tps: dict = {}
    results: dict = {}
    for n in replica_counts:
        fd = FleetDriver.from_container(
            cm, cfg, sc, n_replicas=n, weights="share", policy=policy,
            n_slots=slots, max_queue=n_requests, max_intake=n_requests,
            prefill_chunk=prefill_chunk, devices=devices[:n],
            steps=ref.steps)
        t0 = time.monotonic()
        reqs, shed, _ = replay_fleet(fd, trace, threaded=True)
        span = time.monotonic() - t0
        assert shed == 0 and all(r is not None for r in reqs)
        outs = [r.output for r in reqs]
        assert outs == refs, \
            (f"{n}-replica fleet changed greedy tokens vs the single "
             f"engine — the bit-identity contract is broken")
        toks = sum(len(o) for o in outs)
        ttft = [r.ttft_s for r in reqs]
        lat = [r.latency_s for r in reqs]
        tps[n] = toks / max(span, 1e-9)
        wb = fd.weight_bytes()
        per = "/".join(str(sum(len(r.output) for r in h.engine.finished))
                       for h in fd.replicas)
        results[n] = dict(tok_per_s=tps[n],
                          ttft_p99_s=percentile(ttft, 99),
                          latency_p99_s=percentile(lat, 99),
                          weight_copies=wb["copies"],
                          weight_bytes=wb["total_bytes"])
        if verbose:
            print(f"  {n} replica{'s' if n > 1 else ' '} "
                  f"[{wb['copies']} weight cop"
                  f"{'y' if wb['copies'] == 1 else 'ies'}, "
                  f"{wb['total_bytes']/2**20:.2f} MiB]: {toks} tok in "
                  f"{span:5.2f}s = {tps[n]:6.1f} tok/s ({per} per replica) "
                  f"| ttft p50 {percentile(ttft, 50)*1e3:5.0f}ms "
                  f"p99 {percentile(ttft, 99)*1e3:5.0f}ms | latency p99 "
                  f"{percentile(lat, 99)*1e3:5.0f}ms | bit-identical")
    base = min(replica_counts)
    top = max(replica_counts)
    scaling = tps[top] / max(tps[base], 1e-9)
    if verbose and top > base:
        print(f"  scaling: {scaling:.2f}x aggregate tok/s at {top} replicas "
              f"(efficiency {scaling/ (top/base):.0%} of linear)")
    if check_scaling is not None:
        assert scaling >= check_scaling, \
            (f"{top}-replica fleet scaled {scaling:.2f}x over {base} "
             f"replica(s); required {check_scaling}x")
    return dict(scaling=scaling, per_fleet=results)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="qwen3-1.7b")
    p.add_argument("--bits", type=int, default=8)
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--replicas", type=int, default=2,
                   help="largest fleet size (the benchmark runs fleet sizes "
                        "1 and N over the same trace)")
    p.add_argument("--slots", type=int, default=2)
    p.add_argument("--router", default="least-loaded",
                   choices=("round-robin", "least-loaded"))
    p.add_argument("--rate", type=float, default=200.0)
    p.add_argument("--prompt-max", type=int, default=16)
    p.add_argument("--gen-max", type=int, default=10)
    p.add_argument("--prefill-chunk", type=int, default=4)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--check-scaling", type=float, default=None, metavar="X",
                   help="fail unless the largest fleet reaches >= X times "
                        "the 1-replica aggregate tok/s (needs real cores; "
                        "CI's multi-core fleet-smoke job passes 1.7)")
    p.add_argument("--quick", action="store_true",
                   help="small CI configuration (fewer, shorter requests)")
    p.add_argument("--trace-out", default=None, metavar="FILE")
    p.add_argument("--metrics-out", default=None, metavar="FILE")
    args = p.parse_args(argv)

    _force_host_devices(max(args.replicas, 2))
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    if args.trace_out:
        obs_trace.enable()
    kw = dict(bits=args.bits, replica_counts=(1, args.replicas),
              slots=args.slots, policy=args.router,
              check_scaling=args.check_scaling, seed=args.seed)
    if args.quick:
        run(args.arch, n_requests=8, rate_per_s=500.0, prompt_max=10,
            gen_max=6, prefill_chunk=4, **kw)
    else:
        run(args.arch, n_requests=args.requests, rate_per_s=args.rate,
            prompt_max=args.prompt_max, gen_max=args.gen_max,
            prefill_chunk=args.prefill_chunk, **kw)
    if args.trace_out:
        tracer = obs_trace.disable()
        if tracer is not None:
            n = tracer.save(args.trace_out)
            print(f"trace: {n} events -> {args.trace_out}")
    if args.metrics_out:
        n = obs_metrics.default_registry().write_jsonl(args.metrics_out)
        print(f"metrics: {n} rows -> {args.metrics_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
