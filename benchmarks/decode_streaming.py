"""Monolithic vs streamed weight decode (the load-path half of Table II).

The paper's serving win assumes the one-time parallel decode is cheap AND
that the device can hold the working set; ``decode_all`` (monolithic) decodes
every segment of every tensor in one lock-step batch — peak host memory
~ total model size, first weight available only at the end.  The
:class:`~repro.core.scheduler.DecodeScheduler` streams fixed-budget chunks
through a named decoder backend with double-buffered prefetch instead.

For one 8-bit and one 4-bit container this harness reports, per strategy:

  ttfw_ms    — time to first weight (first tensor fully decoded)
  total_s    — wall time to decode every tensor
  Msym/s     — end-to-end decode throughput
  peak_MB    — peak Python-visible allocation during the decode
               (``tracemalloc``; numpy buffers are tracked), i.e. the
               decode working set *excluding* the shared container payload

and asserts the streamed outputs are bit-identical to the monolithic ones.

Usage:  PYTHONPATH=src python -m benchmarks.decode_streaming
        (or `python -m benchmarks.run streaming`)
"""
from __future__ import annotations

import time
import tracemalloc

import numpy as np

from repro.configs import registry
from repro.core.decode_backends import auto_pick, available_backends
from repro.core.quant import Granularity
from repro.core.store import CompressedModel
from .table1_storage import trained_like_params


def _run_strategy(cm: CompressedModel, strategy: str, backend: str):
    """Returns (decoded dict, row dict)."""
    tracemalloc.start()
    t0 = time.perf_counter()
    ttfw = None
    out = {}
    if strategy == "monolithic":
        out = cm.decode_all(backend=backend)
        ttfw = time.perf_counter() - t0          # nothing usable earlier
    else:
        for name, sym in cm.iter_decode(backend=backend):
            if ttfw is None:
                ttfw = time.perf_counter() - t0
            out[name] = sym
    total = time.perf_counter() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    n_sym = sum(t.n_symbols for t in cm.tensors.values())
    row = dict(strategy=strategy, backend=backend, ttfw_ms=ttfw * 1e3,
               total_s=total, msym_per_s=n_sym / total / 1e6,
               peak_mb=peak / 1e6)
    return out, row


def run(model: str = "qwen3-1.7b", backends=None, verbose: bool = True):
    cfg = registry.reduced(registry.get(model))
    params = trained_like_params(cfg)
    if backends is None:
        # numpy is iteration-bound (cost ~ segment symbol count per chunk, so
        # streaming multiplies it); the compiled backends are where streaming
        # wins wall-clock as well as memory — show both when possible.
        backends = [auto_pick().name]
        if "jax" in available_backends() and "jax" not in backends:
            backends.append("jax")
    rows = []
    for bits in (8, 4):
        cm = CompressedModel.compress(params, bits=bits,
                                      granularity=Granularity.PER_CHANNEL)
        for backend in backends:
            ref, r_mono = _run_strategy(cm, "monolithic", backend)
            got, r_str = _run_strategy(cm, "streamed", backend)
            assert set(ref) == set(got)
            for k in ref:
                assert (ref[k] == got[k]).all(), \
                    f"stream/mono mismatch: {k} ({bits}b, {backend})"
            for r in (r_mono, r_str):
                r.update(model=model, bits=bits)
                rows.append(r)
    if verbose:
        print(f"(available backends: {', '.join(available_backends())}; "
              f"streamed output verified bit-identical to monolithic)")
        print(f"{'bits':>4} {'backend':>16} {'strategy':>11} {'ttfw_ms':>9} "
              f"{'total_s':>8} {'Msym/s':>7} {'peak_MB':>8}")
        for r in rows:
            print(f"{r['bits']:>4} {r['backend']:>16} {r['strategy']:>11} "
                  f"{r['ttfw_ms']:>9.0f} {r['total_s']:>8.2f} "
                  f"{r['msym_per_s']:>7.2f} {r['peak_mb']:>8.1f}")
    return rows


if __name__ == "__main__":
    run()
