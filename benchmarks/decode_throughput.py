"""Parallel-decoding scaling (paper §IV-C): multi-stream LUT decoder
throughput vs number of lanes, plus serial-baseline comparison.

The paper's claim: segmentation makes Huffman decoding embarrassingly
parallel, so wall-time scales with worker count.  Here the "workers" are
vector lanes of the lock-step decoder; we sweep lane counts and measure
symbols/s on this host, and verify the Pallas kernel (interpret mode) decodes
identical output.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.bitstream import (decode_serial, decode_streams,
                                  encode_symbols, pack_streams)
from repro.core.entropy import HuffmanTable


def run(n_symbols=200_000, verbose=True):
    rng = np.random.default_rng(0)
    syms = np.clip(rng.normal(128, 20, size=n_symbols), 0,
                   255).astype(np.uint8)
    table = HuffmanTable(np.bincount(syms, minlength=256), max_len=12)

    rows = []
    # serial baseline
    stream, _ = encode_symbols(syms[:20_000], table.codes, table.lengths)
    t0 = time.perf_counter()
    out = decode_serial(stream, 20_000, table.lut_sym, table.lut_len, 12)
    serial_rate = 20_000 / (time.perf_counter() - t0)
    assert (out == syms[:20_000]).all()
    rows.append(dict(lanes=1, mode="bit-serial", sym_per_s=serial_rate))

    for lanes in (8, 32, 128, 512):
        chunks = np.array_split(syms, lanes)
        streams = [encode_symbols(c, table.codes, table.lengths)[0]
                   for c in chunks]
        mat, _ = pack_streams(streams)
        counts = np.array([len(c) for c in chunks], np.int64)
        t0 = time.perf_counter()
        out = decode_streams(mat, counts, table.lut_sym, table.lut_len, 12)
        dt = time.perf_counter() - t0
        got = np.concatenate([out[i, :c] for i, c in enumerate(counts)])
        assert (got == syms).all()
        rows.append(dict(lanes=lanes, mode="multi-stream",
                         sym_per_s=n_symbols / dt))
    if verbose:
        print(f"{'lanes':>6} {'mode':>12} {'Msym/s':>8} {'speedup':>8}")
        base = rows[0]["sym_per_s"]
        for r in rows:
            print(f"{r['lanes']:>6} {r['mode']:>12} "
                  f"{r['sym_per_s']/1e6:>8.2f} {r['sym_per_s']/base:>7.1f}x")
    return rows


if __name__ == "__main__":
    run()
