"""Fused decode→dequant→matmul vs the prefetch-overlap per-layer decode.

The compressed-resident engine (PR 5) keeps weights entropy-coded but still
materializes each layer's dense QT triples into a double-buffered slot
before its matmuls.  The fused kernel path
(``kernels/fused_decode_matmul.py``) removes that round trip: weight tiles
decode from the resident payload handles inside the matmul.  This harness
serves the SAME container both ways, per bit width (4/8) and codec
(huffman/rans):

  unfused — CompressedResidentWeights(fused=False): per-layer host decode,
            prefetch-overlapped against the previous layer's compute
  fused   — CompressedResidentWeights(fused=True): FusedQT payload handles,
            decode inside the jitted block (Pallas where it probes)

One row per (bits, codec, mode): decode-ms/token, end-to-end tok/s, and the
fused-vs-unfused decode speedup.  Asserted on every run: greedy tokens are
bit-identical between the two modes (and to the dense-QT engine), and the
fused path's decode-ms/token is no slower than the unfused path's
(tolerance ``--speed-slack``, because CPU wall-clock jitters; ``--quick``
keeps the assert but shrinks shapes for CI).

Usage:  PYTHONPATH=src python -m benchmarks.fused_decode_matmul [--quick]
        (or `python -m benchmarks.run fused`)
"""
from __future__ import annotations

import argparse
import sys


def run(arch: str = "qwen3-1.7b", batch: int = 2, prompt_len: int = 16,
        gen: int = 16, segment_symbols: int = 1024,
        chunk_symbols: int = 64 * 1024, speed_slack: float = 1.15,
        assert_speed: bool = True) -> dict:
    import jax
    import numpy as np
    from repro.configs import registry
    from repro.core.quant import Granularity
    from repro.core.spec import spec_from_legacy
    from repro.core.store import CompressedModel
    from repro.models import api
    from repro.serving import engine
    from repro.serving.resident import CompressedResidentWeights

    cfg = registry.reduced(registry.get(arch))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    host = {k: np.asarray(v, np.float32) for k, v in params.items()}
    sc = engine.ServeConfig(max_len=prompt_len + gen)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab, (batch, prompt_len)).astype(np.int32)

    results: dict = {}
    print(f"{cfg.name}: fused vs prefetch-overlap per-layer decode "
          f"(segment {segment_symbols} symbols)")
    print(f"{'config':>12s} {'mode':>8s} {'decode ms/tok':>14s} "
          f"{'e2e tok/s':>10s} {'fused impl':>18s}")
    for bits, codec in [(8, "huffman"), (4, "huffman"), (8, "rans"),
                        (4, "rans")]:
        cm = CompressedModel.compress(host, spec=spec_from_legacy(
            bits, Granularity.PER_CHANNEL, codec=codec,
            segment_symbols=segment_symbols))
        qparams = engine.load_params_from_compressed(cm, quantized=True)
        ref = np.asarray(
            engine.Engine(cfg, qparams, sc).generate(prompt, gen))
        row: dict = {}
        for mode, fused in (("unfused", False), ("fused", True)):
            weights = CompressedResidentWeights(
                cm, cfg, chunk_symbols=chunk_symbols, fused=fused)
            eng = engine.Engine(cfg, weights, sc, resident="compressed")
            out, metrics = eng.generate(prompt, gen, echo_metrics=True)
            assert np.array_equal(np.asarray(out), ref), \
                f"{bits}b {codec} {mode}: greedy tokens diverge from dense-QT"
            impls = sorted({fq.impl for slots in weights._fused_slots
                            for fq in slots.values()}) if fused else []
            ms = 1000.0 / metrics["decode_tok_per_s"]
            row[mode] = dict(decode_ms_per_tok=ms,
                             e2e_tok_per_s=metrics["e2e_tok_per_s"],
                             impls=impls)
            print(f"{codec + str(bits):>12s} {mode:>8s} {ms:>14.2f} "
                  f"{metrics['e2e_tok_per_s']:>10.1f} "
                  f"{','.join(impls) or '-':>18s}")
        speedup = (row["unfused"]["decode_ms_per_tok"]
                   / row["fused"]["decode_ms_per_tok"])
        print(f"{codec + str(bits):>12s} {'':>8s} decode speedup "
              f"{speedup:.2f}x, bit-identity OK")
        if assert_speed:
            assert row["fused"]["decode_ms_per_tok"] \
                <= speed_slack * row["unfused"]["decode_ms_per_tok"], (
                    f"{bits}b {codec}: fused decode "
                    f"{row['fused']['decode_ms_per_tok']:.2f} ms/tok slower "
                    f"than unfused "
                    f"{row['unfused']['decode_ms_per_tok']:.2f} ms/tok "
                    f"(slack {speed_slack}x)")
        results[f"{codec}{bits}"] = row
    print("all configs: fused greedy decode bit-identical to unfused and "
          "dense-QT")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--segment-symbols", type=int, default=1024)
    ap.add_argument("--chunk-symbols", type=int, default=64 * 1024)
    ap.add_argument("--speed-slack", type=float, default=1.15,
                    help="fused decode-ms/token may exceed unfused by this "
                         "factor before the speed assert fires (wall-clock "
                         "noise allowance)")
    ap.add_argument("--no-assert-speed", action="store_true",
                    help="report speeds without asserting the fused path is "
                         "no slower (bit-identity is always asserted)")
    ap.add_argument("--quick", action="store_true",
                    help="small shapes for CI smoke")
    args = ap.parse_args(argv)
    if args.quick:
        args.prompt_len, args.gen, args.batch = 8, 8, 1
    run(args.arch, args.batch, args.prompt_len, args.gen,
        args.segment_symbols, args.chunk_symbols, args.speed_slack,
        assert_speed=not args.no_assert_speed)
    return 0


if __name__ == "__main__":
    sys.exit(main())
