"""Inject the dry-run + roofline tables into EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.fill_experiments \
        results/dryrun_baseline.json EXPERIMENTS.md
"""
from __future__ import annotations

import json
import sys

HBM_BUDGET = 16 * 1024**3


def gib(x):
    return x / 2**30


def dryrun_table(cells):
    lines = [
        "| arch | shape | mesh | kind | args GiB | temp GiB | fits 16GiB | "
        "lower+compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for d in cells:
        if "skipped" in d:
            lines.append(f"| {d['arch']} | {d['shape']} | {d.get('mesh','-')} "
                         f"| — | — | — | n/a (skip) | — |")
            continue
        if "error" in d:
            lines.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — "
                         f"| — | ERROR | — |")
            continue
        ma = d["memory_analysis"]
        args, temp = ma["argument_size"], ma["temp_size"]
        fits = "yes" if args + temp <= HBM_BUDGET else "OVER*"
        t = d.get("lower_s", 0) + d.get("compile_s", 0)
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
            f"{d.get('kind','?')} | {gib(args):.2f} | {gib(temp):.2f} | "
            f"{fits} | {t:.0f} |")
    return "\n".join(lines)


def roofline_table(cells):
    lines = [
        "| arch | shape | compute ms | memory ms | collective ms | dominant |"
        " model/HLO flops | roofline frac | what would move the dominant term"
        " |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    notes = {
        ("train", "collective"): "less wire: bf16 cotangent ARs, fewer FSDP"
        " re-gathers (larger microbatch), SP reduce-scatter",
        ("train", "compute"): "remat policy (save attn outs), bf16 scores",
        ("train", "memory"): "larger microbatches / carry offload",
        ("decode", "collective"): "weight-stationary serving (move KiB"
        " activations, not GB weights) — hillclimb H1",
        ("decode", "memory"): "int4 weights (QT4) halve the weight stream —"
        " hillclimb H3; KV cache quantization next",
        ("decode", "compute"): "n/a at these sizes",
        ("prefill", "collective"): "bf16 collectives; sequence-parallel"
        " boundaries",
        ("prefill", "compute"): "q_block tuning; fused attention kernel",
        ("prefill", "memory"): "KV write combining",
    }
    for d in cells:
        if "skipped" in d or "error" in d or d.get("compile_only"):
            continue
        if d.get("mesh") != "16x16":
            continue
        frac = d["model_flops"] / max(d["chips"], 1) / 197e12 \
            / max(d["step_s"], 1e-30)
        note = notes.get((d["kind"], d["dominant"]), "")
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['compute_s']*1e3:.1f} | "
            f"{d['memory_s']*1e3:.2f} | {d['collective_s']*1e3:.1f} | "
            f"{d['dominant']} | {d['flops_ratio']:.2f} | "
            f"{min(frac,1.0):.3f} | {note} |")
    return "\n".join(lines)


def main(json_path, md_path):
    with open(json_path) as f:
        cells = json.load(f)
    with open(md_path) as f:
        md = f.read()
    md = md.replace("<!-- DRYRUN_TABLE -->",
                    dryrun_table(cells) +
                    "\n\n`*` OVER cells are analyzed in the per-cell notes — "
                    "the dominant component is XLA-CPU's f32 materialization "
                    "of bf16 dot operands (absent on TPU); see §Methodology.")
    md = md.replace("<!-- ROOFLINE_TABLE -->", roofline_table(cells))
    with open(md_path, "w") as f:
        f.write(md)
    print(f"updated {md_path} from {json_path} ({len(cells)} cells)")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_baseline.json",
         sys.argv[2] if len(sys.argv) > 2 else "EXPERIMENTS.md")
