"""Paper Table II analogue: latency breakdown with / without Huffman.

The paper measures (on a Jetson): pre-fill, per-token generation, one-time
parallel decode, first-token latency — for uint8 and uint4, with and without
Huffman.  This harness measures the same decomposition on THIS host for a
reduced model, and additionally derives the TPU-roofline projection of the
decode-phase speedup (the paper's 1.43x potential / 1.32x measured for
uint8), using the bytes-per-parameter ratio, which is hardware-independent.

Stages measured:
  parallel_decode_s — one-time Huffman decode of all weights (amortized)
  prefill_s         — prompt pass
  per_token_s       — steady-state decode step
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.quant import Granularity
from repro.core.store import CompressedModel
from repro.models import api
from repro.serving import engine
from .table1_storage import trained_like_params


def _measure(cfg, serve_params, B=2, prompt_len=32, gen=8):
    sc = engine.ServeConfig(max_len=prompt_len + gen)
    eng = engine.Engine(cfg, serve_params, sc)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, prompt_len)), jnp.int32)
    # warmup (compile)
    out, m0 = eng.generate(prompt, gen, echo_metrics=True)
    out, m = eng.generate(prompt, gen, echo_metrics=True)
    return {"prefill_s": m["prefill_s"],
            "per_token_s": m["decode_s"] / max(gen - 1, 1),
            "tok_per_s": m["tok_per_s"]}


def run(model="qwen3-1.7b", verbose=True):
    cfg = registry.reduced(registry.get(model))
    params = trained_like_params(cfg)
    rows = []
    for bits in (8, 4):
        cm = CompressedModel.compress(params, bits=bits,
                                      granularity=Granularity.PER_CHANNEL)
        st = cm.stats()

        t0 = time.perf_counter()
        qt_params = engine.load_params_from_compressed(cm, quantized=True)
        jax.block_until_ready(jax.tree.leaves(qt_params))
        decode_s = time.perf_counter() - t0

        with_h = _measure(cfg, qt_params)
        dense = engine.load_params_from_compressed(cm, quantized=False)
        without_h = _measure(cfg, dense)

        # TPU-roofline projection for the memory-bound decode phase:
        # bytes/param ratio fp16 -> int{8,4} residency
        bytes_ratio = {8: 1.0 / 2.0, 4: 0.5 / 2.0}[bits]
        rows.append(dict(
            model=model, bits=bits, effective_bits=st.effective_bits,
            parallel_decode_s=decode_s,
            prefill_wo=without_h["prefill_s"], prefill_w=with_h["prefill_s"],
            tok_wo=without_h["per_token_s"], tok_w=with_h["per_token_s"],
            first_token_wo=without_h["prefill_s"],
            first_token_w=with_h["prefill_s"] + decode_s,
            tpu_decode_speedup_bound=1.0 / bytes_ratio,
        ))
    if verbose:
        print(f"{'bits':>4} {'eff.bits':>8} {'decode(1x)':>10} "
              f"{'prefill w/o':>11} {'prefill w/':>10} {'tok w/o':>9} "
              f"{'tok w/':>9} {'TPU bound':>9}")
        for r in rows:
            print(f"{r['bits']:>4} {r['effective_bits']:>8.2f} "
                  f"{r['parallel_decode_s']:>10.2f} {r['prefill_wo']:>11.3f} "
                  f"{r['prefill_w']:>10.3f} {r['tok_wo']:>9.4f} "
                  f"{r['tok_w']:>9.4f} {r['tpu_decode_speedup_bound']:>8.1f}x")
    return rows


if __name__ == "__main__":
    run()
