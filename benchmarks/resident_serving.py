"""Compressed-resident vs dense-resident serving: resident bytes vs tok/s.

The paper's Table 2 argument is a bandwidth-vs-compute tradeoff: keeping
weights entropy-coded in memory moves fewer bytes per layer but spends
decode work per inference step.  This harness makes that tradeoff measurable
on a CPU host by serving the SAME container through three residency modes:

  bf16        — dense fp32/bf16 weights (the no-compression baseline;
                resident bytes only, no timing row of its own)
  dense-QT    — decode once at load, QT triples resident in HBM, dequant
                fused into the matmuls (the default engine)
  compressed  — the container stays entropy-coded; each layer's QT triples
                are decoded just before its matmuls, double-buffered against
                the previous layer's compute (docs/SERVING.md
                §"Compressed-resident serving")

One row per mode: peak resident weight bytes, decode tok/s, e2e tok/s.
Asserted on every run: greedy tokens are bit-identical across the modes,
and the compressed mode's peak resident bytes stay strictly below the
dense bf16 footprint.

The container is compressed with ``segment_symbols`` small enough that a
layer slice spans many segments — per-layer decode parallelism (lock-step
lanes) is ``chunk_symbols / segment_symbols``, so the paper-default 64k
segments would leave the tiny CPU config lane-starved.

Usage:  PYTHONPATH=src python -m benchmarks.resident_serving [--quick]
        (or `python -m benchmarks.run resident`)
"""
from __future__ import annotations

import argparse
import sys


def _fmt_bytes(n: float) -> str:
    return f"{n / 2**20:.2f} MiB"


def run(arch: str = "qwen3-1.7b", bits: int = 8, batch: int = 2,
        prompt_len: int = 16, gen: int = 16, segment_symbols: int = 1024,
        chunk_symbols: int = 64 * 1024) -> dict:
    import jax
    import numpy as np
    from repro.configs import registry
    from repro.core.quant import Granularity
    from repro.core.spec import spec_from_legacy
    from repro.core.store import CompressedModel
    from repro.models import api
    from repro.serving import engine
    from repro.serving.resident import CompressedResidentWeights

    cfg = registry.reduced(registry.get(arch))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    host = {k: np.asarray(v, np.float32) for k, v in params.items()}
    cm = CompressedModel.compress(host, spec=spec_from_legacy(
        bits, Granularity.PER_CHANNEL, segment_symbols=segment_symbols))

    sc = engine.ServeConfig(max_len=prompt_len + gen)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab, (batch, prompt_len)).astype(np.int32)
    # the KV cache is resident alongside the weights in every mode — the
    # true serving peak is weights + KV, and at production slot counts the
    # KV term dominates (the paged pool in docs/KV_CACHE.md attacks it)
    from repro.serving.kvcache import kv_cache_bytes
    kv_bytes = kv_cache_bytes(cfg, batch, sc.max_len)

    weights = CompressedResidentWeights(cm, cfg,
                                        chunk_symbols=chunk_symbols)
    bf16 = weights.dense_bf16_bytes()
    modes = {
        "dense-QT": dict(
            params=engine.load_params_from_compressed(cm, quantized=True),
            resident="dense", bytes=weights.dense_resident_bytes()),
        "compressed": dict(
            params=weights, resident="compressed",
            bytes=weights.peak_resident_bytes()),
    }

    from repro.obs.metrics import percentile

    print(f"{cfg.name}: {bits}b {cm.stats().effective_bits:.2f} effective "
          f"bits; dense bf16 footprint {_fmt_bytes(bf16)}; KV cache "
          f"{_fmt_bytes(kv_bytes)} ({batch} x {sc.max_len} rows, resident "
          f"in every mode)")
    print(f"{'mode':12s} {'resident weights':>18s} {'vs bf16':>8s} "
          f"{'decode tok/s':>13s} {'e2e tok/s':>10s} "
          f"{'step p50/p99 ms':>16s}")
    print(f"{'bf16':12s} {_fmt_bytes(bf16):>18s} {'1.00x':>8s} "
          f"{'-':>13s} {'-':>10s} {'-':>16s}")

    results: dict = {"bf16_bytes": bf16}
    outs = {}
    for mode, m in modes.items():
        eng = engine.Engine(cfg, m["params"], sc, resident=m["resident"])
        out, metrics = eng.generate(prompt, gen, echo_metrics=True)
        outs[mode] = np.asarray(out)
        # per-decode-step wall-time percentiles (exact, shared linear-
        # interpolation rule) — the tail exposes prefetch stalls the mean
        # decode tok/s smears out
        step_p50 = percentile(eng.last_step_times, 50) * 1e3
        step_p99 = percentile(eng.last_step_times, 99) * 1e3
        results[mode] = dict(
            resident_bytes=m["bytes"],
            decode_tok_per_s=metrics["decode_tok_per_s"],
            e2e_tok_per_s=metrics["e2e_tok_per_s"],
            step_p50_ms=step_p50, step_p99_ms=step_p99)
        print(f"{mode:12s} {_fmt_bytes(m['bytes']):>18s} "
              f"{m['bytes'] / bf16:>7.2f}x "
              f"{metrics['decode_tok_per_s']:>13.1f} "
              f"{metrics['e2e_tok_per_s']:>10.1f} "
              f"{step_p50:>7.1f}/{step_p99:>7.1f}")

    assert np.array_equal(outs["dense-QT"], outs["compressed"]), \
        "compressed-resident greedy decode must be bit-identical to dense"
    print(f"greedy bit-identity: OK ({outs['dense-QT'].shape[0]}x"
          f"{outs['dense-QT'].shape[1]} tokens)")
    peak = results["compressed"]["resident_bytes"]
    assert peak < bf16, (
        f"compressed-resident peak {peak} must stay below the dense bf16 "
        f"footprint {bf16}")
    rb = weights.resident_bytes()
    print(f"compressed peak breakdown: payload {_fmt_bytes(rb['payload'])} "
          f"+ tables/qmeta {_fmt_bytes(rb['tables'] + rb['qmeta'])} "
          f"+ globals {_fmt_bytes(rb['globals'] + rb['stacked'])} "
          f"+ 2x layer slot {_fmt_bytes(rb['layer_slot'])} "
          f"+ scratch {_fmt_bytes(rb['scratch'])}")
    results["kv_bytes"] = kv_bytes
    print(f"true serving peak (weights + KV): compressed "
          f"{_fmt_bytes(peak + kv_bytes)} vs dense bf16 "
          f"{_fmt_bytes(bf16 + kv_bytes)}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--segment-symbols", type=int, default=1024)
    ap.add_argument("--chunk-symbols", type=int, default=64 * 1024)
    ap.add_argument("--quick", action="store_true",
                    help="small shapes for CI smoke")
    args = ap.parse_args(argv)
    if args.quick:
        args.prompt_len, args.gen, args.batch = 8, 6, 1
    run(args.arch, args.bits, args.batch, args.prompt_len, args.gen,
        args.segment_symbols, args.chunk_symbols)
    return 0


if __name__ == "__main__":
    sys.exit(main())
