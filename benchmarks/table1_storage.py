"""Paper Table I analogue: effective bits + storage reduction per model.

The paper reports fp16 / uint8 / uint4 effective bits for three edge LLMs
whose TRAINED weights have peaky (low-entropy) distributions.  Random-init
Gaussian weights are nearly max-entropy on the quantized grid, so to
reproduce the paper's regime we synthesize trained-LLM-like weights
(Student-t heavy tails, layer-dependent scale — matching the paper's Fig. 4
histograms) for each REDUCED assigned architecture, then run the real
pipeline: mixed quantization -> global Huffman table -> encoded container.

Reported per (model x bits): entropy bound, effective bits, % below the
quantized size, % below fp16 — the same columns as Table I.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.configs import registry
from repro.core.quant import Granularity
from repro.core.store import CompressedModel
from repro.models import api


def trained_like_params(cfg, seed: int = 0) -> Dict[str, np.ndarray]:
    """Synthesize weights with trained-LLM statistics: heavy-tailed, mostly
    near zero (Fig. 4 of the paper), per-layer scale variation."""
    rng = np.random.default_rng(seed)
    sch = api.build(cfg).schema(cfg)
    out = {}
    for i, (name, spec) in enumerate(sorted(sch.items())):
        scale = 0.02 * (0.5 + rng.random())
        w = rng.standard_t(df=2.2, size=spec.shape) * scale
        out[name] = w.astype(np.float32)
    return out


def run(models=("qwen3-1.7b", "glm4-9b", "mamba2-370m"), verbose=True):
    rows = []
    for name in models:
        cfg = registry.reduced(registry.get(name))
        params = trained_like_params(cfg)
        n_params = sum(int(np.prod(v.shape)) for v in params.values())
        for bits in (8, 4):
            t0 = time.perf_counter()
            cm = CompressedModel.compress(params, bits=bits,
                                          granularity=Granularity.PER_CHANNEL)
            st = cm.stats()
            rows.append(dict(
                model=name, bits=bits, params=n_params,
                entropy=st.entropy_bits, effective_bits=st.effective_bits,
                vs_quant=st.reduction_vs_quant * 100,
                vs_fp16=st.reduction_vs_fp16 * 100,
                encode_s=time.perf_counter() - t0,
            ))
    if verbose:
        print(f"{'model':22s} {'bits':>4} {'entropy':>8} {'eff.bits':>9} "
              f"{'-vs-quant%':>10} {'-vs-fp16%':>9}")
        for r in rows:
            print(f"{r['model']:22s} {r['bits']:>4} {r['entropy']:>8.2f} "
                  f"{r['effective_bits']:>9.2f} {r['vs_quant']:>10.1f} "
                  f"{r['vs_fp16']:>9.1f}")
    return rows


if __name__ == "__main__":
    run()
