"""Paper Table I analogue: achieved bits vs. Shannon bound, per model x codec x bits.

The paper reports fp16 / uint8 / uint4 effective bits for three edge LLMs
whose TRAINED weights have peaky (low-entropy) distributions.  Random-init
Gaussian weights are nearly max-entropy on the quantized grid, so to
reproduce the paper's regime we synthesize trained-LLM-like weights
(Student-t heavy tails, layer-dependent scale — matching the paper's Fig. 4
histograms) for each REDUCED assigned architecture, then run the real
pipeline: mixed quantization -> per-group code table -> encoded container.

Beyond the paper, the sweep crosses the entropy-codec registry
(``--codec huffman,rans,raw``): ``raw`` is the quantized-only baseline,
``huffman`` the paper's coder, ``rans`` the fractional-bit tANS coder.  Each
row reports the Shannon bound (group histogram entropy), the ACHIEVED
bits/symbol (encoded payload / symbols, headers included), their ratio, and
the % storage reductions — the same columns as Table I plus the bound gap.

``--check-bound R`` turns the report into a gate: every huffman and rans row
must achieve <= R x the Shannon bound (CI runs R = 1.02 via the
compression-matrix job; ``raw`` is exempt — it codes at exactly ``bits``).
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, Sequence

import numpy as np

from repro.configs import registry
from repro.core.quant import Granularity
from repro.core.spec import spec_from_legacy
from repro.core.store import CompressedModel
from repro.models import api

DEFAULT_MODELS = ("qwen3-1.7b", "glm4-9b", "mamba2-370m")
QUICK_MODELS = ("qwen3-1.7b",)
GATED_CODECS = ("huffman", "rans")     # raw codes at exactly `bits` — exempt


def trained_like_params(cfg, seed: int = 0) -> Dict[str, np.ndarray]:
    """Synthesize weights with trained-LLM statistics: heavy-tailed, mostly
    near zero (Fig. 4 of the paper), per-layer scale variation."""
    rng = np.random.default_rng(seed)
    sch = api.build(cfg).schema(cfg)
    out = {}
    for i, (name, spec) in enumerate(sorted(sch.items())):
        scale = 0.02 * (0.5 + rng.random())
        w = rng.standard_t(df=2.2, size=spec.shape) * scale
        out[name] = w.astype(np.float32)
    return out


def run(models: Sequence[str] = DEFAULT_MODELS,
        codecs: Sequence[str] = ("huffman",),
        bits_sweep: Sequence[int] = (8, 4),
        verbose: bool = True):
    rows = []
    for name in models:
        cfg = registry.reduced(registry.get(name))
        params = trained_like_params(cfg)
        n_params = sum(int(np.prod(v.shape)) for v in params.values())
        for codec in codecs:
            for bits in bits_sweep:
                t0 = time.perf_counter()
                spec = spec_from_legacy(bits, Granularity.PER_CHANNEL,
                                        codec=codec)
                cm = CompressedModel.compress(params, spec=spec)
                st = cm.stats()
                rows.append(dict(
                    model=name, codec=codec, bits=bits, params=n_params,
                    entropy=st.entropy_bits, effective_bits=st.effective_bits,
                    bound_ratio=st.shannon_ratio,
                    vs_quant=st.reduction_vs_quant * 100,
                    vs_fp16=st.reduction_vs_fp16 * 100,
                    encode_s=time.perf_counter() - t0,
                ))
    if verbose:
        print(f"{'model':22s} {'codec':>8} {'bits':>4} {'shannon':>8} "
              f"{'achieved':>9} {'x-bound':>8} {'-vs-quant%':>10} "
              f"{'-vs-fp16%':>9}")
        for r in rows:
            print(f"{r['model']:22s} {r['codec']:>8} {r['bits']:>4} "
                  f"{r['entropy']:>8.3f} {r['effective_bits']:>9.3f} "
                  f"{r['bound_ratio']:>8.4f} {r['vs_quant']:>10.1f} "
                  f"{r['vs_fp16']:>9.1f}")
    return rows


def check_bound(rows, ratio: float, verbose: bool = True) -> bool:
    """Gate: every huffman/rans row achieves <= ratio x the Shannon bound."""
    bad = [r for r in rows
           if r["codec"] in GATED_CODECS and r["bound_ratio"] > ratio]
    if verbose:
        gated = [r for r in rows if r["codec"] in GATED_CODECS]
        print(f"bound gate: {len(gated) - len(bad)}/{len(gated)} gated rows "
              f"within {ratio}x Shannon bound")
        for r in bad:
            print(f"  FAIL {r['model']} {r['codec']} {r['bits']}b: "
                  f"{r['effective_bits']:.3f} achieved vs "
                  f"{r['entropy']:.3f} bound ({r['bound_ratio']:.4f}x)")
    return not bad


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--models", default=None,
                   help=f"comma list (default: {','.join(DEFAULT_MODELS)})")
    p.add_argument("--codec", default="huffman",
                   help="comma list of codecs to sweep (huffman,rans,raw)")
    p.add_argument("--bits", default="8,4",
                   help="comma list of bit-widths to sweep")
    p.add_argument("--quick", action="store_true",
                   help=f"single-model smoke sweep ({','.join(QUICK_MODELS)})")
    p.add_argument("--check-bound", type=float, default=None, metavar="R",
                   help="exit nonzero unless every huffman/rans row achieves "
                        "<= R x the Shannon bound (CI: 1.02)")
    args = p.parse_args(argv)

    from repro.core.codecs import codec_names
    codecs = [c.strip() for c in args.codec.split(",") if c.strip()]
    unknown = [c for c in codecs if c not in codec_names()]
    if unknown:
        p.error(f"unknown codec(s) {unknown}; registered: {codec_names()}")
    models = (QUICK_MODELS if args.quick else
              tuple(m.strip() for m in args.models.split(","))
              if args.models else DEFAULT_MODELS)
    bits_sweep = tuple(int(b) for b in args.bits.split(","))

    rows = run(models=models, codecs=codecs, bits_sweep=bits_sweep)
    if args.check_bound is not None:
        return 0 if check_bound(rows, args.check_bound) else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
