"""Benchmark entry point: one harness per paper table/figure.

  table1  — storage / effective bits (paper Table I)
  table2  — latency breakdown with/without Huffman (paper Table II)
  decode  — parallel-decoding scaling (paper §IV-C / Fig. 3)
  streaming — monolithic vs streamed weight decode (load-path of Table II)
  traffic — continuous batching vs lockstep under Poisson arrivals
  sharded — multi-device sharded residency vs single-device (bit-identity)
  fleet   — DP replica fleet vs single engine: aggregate tok/s scaling
            behind the request router (bit-identity asserted)
  resident — compressed-resident vs dense-resident serving (Table II's
             bandwidth-vs-compute tradeoff: resident bytes vs tok/s)
  fused    — fused decode→dequant→matmul vs the prefetch-overlap per-layer
             decode (decode-ms/token per bit width and codec, bit-identity
             asserted)
  overlap  — decode/compute overlap fraction + prefetch stall from a traced
             compressed-resident serve (tracing bit-identity asserted)
  roofline — render §Roofline from dry-run JSON (if present)

``python -m benchmarks.run [name ...]`` runs all by default.
"""
from __future__ import annotations

import os
import sys


def main(argv=None) -> int:
    which = (argv or sys.argv[1:]) or ["table1", "table2", "decode",
                                       "streaming", "traffic", "sharded",
                                       "fleet", "resident", "fused",
                                       "overlap", "roofline"]
    from . import (decode_streaming, decode_throughput, table1_storage,
                   table2_latency)

    if "table1" in which:
        print("== Table I analogue: storage & effective bits ==")
        table1_storage.run()
        print()
    if "table2" in which:
        print("== Table II analogue: latency breakdown w/ and w/o Huffman ==")
        table2_latency.run()
        print()
    if "decode" in which:
        print("== Parallel decode scaling (paper §IV-C) ==")
        decode_throughput.run()
        print()
    if "streaming" in which:
        print("== Monolithic vs streamed weight decode ==")
        decode_streaming.run()
        print()
    if "traffic" in which:
        print("== Continuous batching vs lockstep (Poisson traffic) ==")
        from . import serving_traffic
        serving_traffic.run()
        print()
    if "sharded" in which:
        print("== Multi-device sharded serving (weights sharded in HBM) ==")
        # earlier harnesses already initialized the jax backend, so the
        # forced-device-count flag sharded_serving sets for standalone runs
        # cannot take effect here — skip cleanly when the host is short
        from . import sharded_serving
        try:
            sharded_serving.run()
        except ValueError as e:
            print(f"(skip sharded: {e} — run it standalone: "
                  f"XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                  f"python -m benchmarks.sharded_serving)")
        print()
    if "fleet" in which:
        print("== DP replica fleet vs single engine (router, bit-identity) ==")
        # replicas wrap onto the available devices, so this runs even when
        # an earlier harness already initialized jax with one host device
        from . import fleet_serving
        fleet_serving.run(n_requests=8, rate_per_s=500.0, prompt_max=10,
                          gen_max=6)
        print()
    if "resident" in which:
        print("== Compressed-resident vs dense-resident serving ==")
        from . import resident_serving
        resident_serving.run()
        print()
    if "fused" in which:
        print("== Fused decode→dequant→matmul vs per-layer decode ==")
        from . import fused_decode_matmul
        fused_decode_matmul.run()
        print()
    if "overlap" in which:
        print("== Decode/compute overlap (traced compressed-resident) ==")
        from . import overlap_report
        overlap_report.run()
        print()
    if "roofline" in which:
        path = "results/dryrun_baseline.json"
        if os.path.exists(path):
            print("== Roofline (from dry-run) ==")
            from . import roofline_report
            roofline_report.run(path)
        else:
            print(f"(skip roofline: {path} not found — run "
                  f"`python -m repro.launch.dryrun --all --out {path}`)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
