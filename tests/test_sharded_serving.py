"""Multi-device tensor-parallel serving of compressed weights.

Runs on 8 forced host-platform CPU devices (tests/conftest.py sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for the whole
session).  Three contracts:

* **placement** — the streaming loader's ``make_param_placer`` lands every
  QT/QT4 leaf with *consistent* q/scale/zero shardings (scale follows q's
  output-channel axes wherever sizes line up, size-1 broadcast dims
  replicate) and actually distributes bytes across the mesh;
* **numerics** — greedy decode through the sharded engine is bit-identical
  (token-for-token) to the single-device engine, dense AND moe;
* **slot pool** — the continuous-batching engine's resident cache lands with
  the ``layout="slot"`` shardings and serves requests identically to its
  single-device twin.
"""
import os

# Standalone safety: when this file is run outside the repo conftest the flag
# must still be set before jax's backend initializes.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.quant import Granularity
from repro.core.store import CompressedModel
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_lib
from repro.models import api
from repro.models.layers import QT, QT4
from repro.serving import engine

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _compressed(arch: str, bits: int = 8):
    cfg = registry.reduced(registry.get(arch))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    host = {k: np.asarray(v, np.float32) for k, v in params.items()}
    return cfg, CompressedModel.compress(host, bits=bits,
                                         granularity=Granularity.PER_CHANNEL)


@pytest.fixture(scope="module")
def dense_cm():
    return _compressed("qwen3-1.7b")


@pytest.fixture(scope="module")
def moe_cm():
    return _compressed("qwen2-moe-a2.7b")


@pytest.fixture(scope="module")
def mesh():
    return mesh_lib.make_serve_mesh(2, 4)


def _spec_entries(sharding, ndim):
    e = list(sharding.spec)
    return e + [None] * (ndim - len(e))


@needs8
def test_qt_leaves_land_consistently_sharded(dense_cm, mesh):
    cfg, cm = dense_cm
    params = engine.load_params_from_compressed(
        cm, quantized=True, placer=engine.make_param_placer(cfg, mesh))
    qt_leaves = {n: v for n, v in params.items() if isinstance(v, (QT, QT4))}
    assert qt_leaves, "8-bit container must produce QT residency"
    model_sharded = 0
    for name, qt in qt_leaves.items():
        # committed on the serve mesh
        for part in qt:
            assert set(part.sharding.device_set) <= set(mesh.devices.flat), name
        qe = _spec_entries(qt.q.sharding, qt.q.ndim)
        for part in (qt.scale, qt.zero):
            pe = _spec_entries(part.sharding, part.ndim)
            for dim, (size, got, want) in enumerate(
                    zip(part.shape, pe, qe)):
                if size == 1:
                    assert got is None, (name, dim, got)
                else:
                    assert got == want, \
                        f"{name} dim {dim}: scale/zero sharded {got}, q {want}"
        if any("model" in ((e,) if isinstance(e, str) else (e or ()))
               for e in qe):
            model_sharded += 1
    assert model_sharded, "no QT leaf sharded over the model axis"
    # the placement actually spreads bytes: every device holds a strict
    # subset of the total
    pb = engine.per_device_bytes(params)
    assert len(pb) == 8
    assert max(pb.values()) < sum(pb.values())


@needs8
@pytest.mark.parametrize("fixture", ["dense_cm", "moe_cm"])
def test_sharded_greedy_decode_bit_identical(fixture, mesh, request):
    cfg, cm = request.getfixturevalue(fixture)
    sc = engine.ServeConfig(max_len=24, temperature=0.0)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0, cfg.vocab)

    ref_params = engine.load_params_from_compressed(cm, quantized=True)
    ref = engine.Engine(cfg, ref_params, sc).generate(prompt, 10)

    sh_params = engine.load_params_from_compressed(
        cm, quantized=True, placer=engine.make_param_placer(cfg, mesh))
    out = engine.Engine(cfg, sh_params, sc, mesh=mesh).generate(prompt, 10)

    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


@needs8
def test_continuous_engine_slot_cache_sharded_and_identical(dense_cm, mesh):
    from repro.serving.batching import ContinuousEngine
    cfg, cm = dense_cm
    sc = engine.ServeConfig(max_len=32, temperature=0.0)
    sh_params = engine.load_params_from_compressed(
        cm, quantized=True, placer=engine.make_param_placer(cfg, mesh))
    ce = ContinuousEngine(cfg, sh_params, sc, n_slots=4, prefill_chunk=8,
                          mesh=mesh)
    want = shd.cache_shardings(cfg, mesh, engine.serve_mesh_rules(cfg, mesh),
                               4, sc.max_len, layout="slot")
    for k, leaf in ce.slots.cache.items():
        assert leaf.sharding.is_equivalent_to(want[k], leaf.ndim), k
        # slot axis (dim 1) of the resident pool is data-sharded
        assert _spec_entries(leaf.sharding, leaf.ndim)[1] is not None, k

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (int(n),)).astype(np.int32)
               for n in (5, 8, 3)]
    reqs = [ce.submit(p, 6) for p in prompts]
    ce.run()

    # single-device lockstep reference, one request at a time
    ref_params = engine.load_params_from_compressed(cm, quantized=True)
    ref_eng = engine.Engine(cfg, ref_params, sc)
    for p, req in zip(prompts, reqs):
        ref = ref_eng.generate(jnp.asarray(p)[None, :], 6)
        assert req.output == list(np.asarray(ref)[0]), req.rid
