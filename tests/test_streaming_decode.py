"""Streaming decode subsystem: scheduler chunking, backend registry,
bit-identity with the monolithic path, and the serving load path."""
import os
import tempfile

import numpy as np
import pytest

from repro.core import decode_backends as db
from repro.core.quant import Granularity
from repro.core.scheduler import DecodeScheduler, layer_group_key
from repro.core.store import CompressedModel


def _params(seed=0):
    rng = np.random.default_rng(seed)
    # > 1 segment for the big tensors at segment_symbols=16k, plus small and
    # unquantized tensors to exercise every container path
    return {
        "embed": (rng.standard_t(3, size=(300, 128)) * 0.02).astype(np.float32),
        "layers/wq": (rng.standard_t(3, size=(3, 96, 128)) * 0.02).astype(np.float32),
        "layers/w_up": (rng.standard_t(3, size=(3, 128, 160)) * 0.02).astype(np.float32),
        "lm_head": (rng.standard_t(3, size=(128, 300)) * 0.02).astype(np.float32),
        "final_norm": rng.normal(size=(128,)).astype(np.float32),
    }


def _compress(bits, seed=0, segment_symbols=16 * 1024):
    return CompressedModel.compress(_params(seed), bits=bits,
                                    granularity=Granularity.PER_CHANNEL,
                                    segment_symbols=segment_symbols)


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("chunk_symbols", [10_000, 40_000, 10**9])
def test_streaming_bit_identical_to_monolithic(bits, chunk_symbols):
    cm = _compress(bits)
    mono = cm.decode_all()
    streamed = dict(cm.iter_decode(chunk_symbols=chunk_symbols))
    assert set(mono) == set(streamed)
    for k in mono:
        assert mono[k].dtype == streamed[k].dtype == np.uint8
        assert (mono[k] == streamed[k]).all(), k


@pytest.mark.parametrize("bits", [4, 8])
def test_streaming_save_load_roundtrip(bits):
    cm = _compress(bits)
    mono = cm.decode_all()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "model.npz")
        cm.save(path)
        cm2 = CompressedModel.load(path)
        streamed = dict(cm2.iter_decode(chunk_symbols=20_000))
    assert set(mono) == set(streamed)
    for k in mono:
        assert (mono[k] == streamed[k]).all(), k


@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas-interpret"])
def test_streaming_backends_agree(backend):
    cm = _compress(8, segment_symbols=4096)
    if backend not in db.available_backends():
        pytest.skip(f"{backend} unavailable here")
    mono = cm.decode_all()
    streamed = dict(cm.iter_decode(backend=backend, chunk_symbols=12_000))
    for k in mono:
        assert (mono[k] == streamed[k]).all(), (backend, k)


def test_scheduler_plan_respects_budget_and_groups():
    cm = _compress(8)
    budget = 20_000
    sched = DecodeScheduler(cm, backend="numpy", chunk_symbols=budget)
    plan = sched.plan()
    all_segs = [(s.tensor, s.index) for c in plan for s in c.segs]
    want = [(n, j) for n, t in cm.tensors.items()
            for j in range(len(t.seg_offsets))]
    assert all_segs == want                      # every segment exactly once
    for c in plan:
        groups = {layer_group_key(s.tensor) for s in c.segs}
        assert len(groups) == 1                  # per-layer affinity
        # budget is only exceeded when a single segment alone exceeds it
        if len(c.segs) > 1:
            assert c.symbols <= budget
    assert len(plan) > 1


def test_scheduler_first_prefix_reorders_schedule():
    cm = _compress(8)
    names = [n for n, _ in cm.iter_decode(chunk_symbols=20_000,
                                          first=("lm_head",))]
    assert names[0] == "lm_head"
    assert set(names) == set(cm.tensors)


def test_scheduler_monolithic_single_chunk():
    cm = _compress(8)
    plan = DecodeScheduler(cm, backend="numpy", chunk_symbols=None).plan()
    assert len(plan) == 1
    assert plan[0].symbols == sum(t.n_symbols for t in cm.tensors.values())


def test_prefetch_off_matches_prefetch_on():
    cm = _compress(4)
    on = dict(DecodeScheduler(cm, backend="numpy", chunk_symbols=15_000,
                              prefetch=True).iter_decode())
    off = dict(DecodeScheduler(cm, backend="numpy", chunk_symbols=15_000,
                               prefetch=False).iter_decode())
    for k in on:
        assert (on[k] == off[k]).all(), k


def test_backend_registry_auto_pick_never_interpret():
    assert db.auto_pick().name != "pallas-interpret"
    assert "numpy" in db.available_backends()
    with pytest.raises(KeyError):
        db.get_backend("no-such-backend")


def test_backend_registry_pallas_fallback_is_clean():
    """Compiled pallas is capability-probed; when the kernel cannot compile
    on this host, requesting it raises and auto-pick routes elsewhere."""
    b = db._REGISTRY["pallas"]
    if b.available():
        assert db.get_backend("pallas").name == "pallas"
    else:
        with pytest.raises(RuntimeError, match="not available"):
            db.get_backend("pallas")
        assert db.auto_pick().name in ("numpy", "jax")


def test_streaming_engine_load_matches_monolithic():
    from repro.serving import engine
    cm = _compress(8)
    metrics = {}
    streamed = engine.load_params_from_compressed(cm, quantized=True,
                                                  metrics=metrics)
    mono = engine.load_params_from_compressed(cm, quantized=True,
                                              stream=False)
    assert set(streamed) == set(mono)
    for k in mono:
        ms, mm = streamed[k], mono[k]
        if hasattr(ms, "q"):
            pairs = [(ms.q, mm.q), (ms.scale, mm.scale), (ms.zero, mm.zero)]
        else:
            pairs = [(ms, mm)]
        for a, b in pairs:
            assert (np.asarray(a) == np.asarray(b)).all(), k
    assert 0.0 <= metrics["time_to_first_weight_s"] <= metrics["decode_load_s"]
    assert metrics["decode_backend"] in db.backend_names()


def test_streaming_engine_load_int4_packed():
    from repro.serving import engine
    from repro.models.layers import QT4
    cm = _compress(4)
    streamed = engine.load_params_from_compressed(cm, quantized=True)
    mono = engine.load_params_from_compressed(cm, quantized=True, stream=False)
    assert any(isinstance(v, QT4) for v in streamed.values())
    for k in mono:
        ms, mm = streamed[k], mono[k]
        if hasattr(ms, "q"):
            assert (np.asarray(ms.q) == np.asarray(mm.q)).all(), k
        else:
            assert (np.asarray(ms) == np.asarray(mm)).all(), k
