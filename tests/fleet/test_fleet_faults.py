"""Fault-injection suite: the fleet's no-loss / no-duplicate contract.

Every scenario drives a seed-derived :class:`~fleet.faults.FaultPlan`
through a lockstep fleet and asserts the three invariants that make
failures invisible to callers:

* **no drop** — every submitted request finishes (or sheds for a *declared*
  reason with the matching ``fleet.shed{reason}`` count);
* **no duplicate** — each rid finishes exactly once, fleet-wide;
* **bit-identity** — outputs equal the single-engine reference even when
  the tokens were generated twice (kill mid-decode, redrive elsewhere).

``rng_seed`` fans the plans out under ``--rng-repeats N`` (CI runs 3), so
the kill step, delay pattern, and veto budget all vary per repeat while
each repeat stays individually deterministic.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core.spec import KVCompressionSpec
from repro.models import api
from repro.obs import metrics as obs_metrics
from repro.serving import engine as serving_engine
from repro.serving.batching import ContinuousEngine, QueueFullError
from repro.serving.fleet import FleetDriver

from .faults import FaultHarness, FaultPlan

MAX_LEN = 48


@pytest.fixture(scope="module")
def harness(rng_seed):
    cfg = registry.reduced(registry.get("qwen3-1.7b"))
    params = api.build(cfg).init(cfg, jax.random.PRNGKey(0))
    sc = serving_engine.ServeConfig(max_len=MAX_LEN)
    eng = serving_engine.Engine(cfg, params, sc)
    return cfg, params, sc, eng, rng_seed


def _jobs(cfg, seed, n=6, gen_min=6, gen_max=9):
    rng = np.random.default_rng([seed, 7])
    return [(rng.integers(0, cfg.vocab,
                          (int(rng.integers(5, 21)),)).astype(np.int32),
             int(rng.integers(gen_min, gen_max + 1)))
            for _ in range(n)]


def _refs(eng, jobs):
    return [np.asarray(eng.generate(np.asarray(p)[None], g))[0].tolist()
            for p, g in jobs]


def _driver(cfg, params, sc, eng, **kw):
    kw.setdefault("n_replicas", 3)
    kw.setdefault("n_slots", 2)
    kw.setdefault("prefill_chunk", 4)
    return FleetDriver(cfg, params, sc, steps=eng.steps, **kw)


# ------------------------------------------------------------------- kills

def test_kill_replica_mid_decode_no_loss_no_duplicate(harness):
    cfg, params, sc, eng, seed = harness
    jobs = _jobs(cfg, seed)
    refs = _refs(eng, jobs)
    plan = FaultPlan.from_seed(seed, n_replicas=3, kill=True, kill_after=5)
    fd = _driver(cfg, params, sc, eng, policy="round-robin")
    h = FaultHarness(fd, plan)
    redrives0 = obs_metrics.counter("fleet.redrives").total()
    rids = [fd.submit(p, g).rid for p, g in jobs]
    fin = {r.rid: r for r in h.run()}

    # gens >= 6 and a kill threshold <= 5 guarantee the victim replica was
    # still decoding when it died — the kill really fired mid-stream
    assert h.victims, f"plan {plan} never triggered"
    assert sorted(fin) == sorted(rids)               # no drop, no duplicate
    assert len(fd.finished) == len(jobs)
    assert fd.shed == []
    assert [fin[r].output for r in rids] == refs     # bit-identity across kill
    for v in h.victims:
        assert v.redrives == 1
        assert fin[v.rid] is v                       # same object, re-finished
    assert obs_metrics.counter("fleet.redrives").total() - redrives0 \
        == len(h.victims)
    # no victim re-finished on the dead replica (redrive went elsewhere)
    dead = next(iter(plan.kills))
    assert not ({v.rid for v in h.victims}
                & {r.rid for r in fd.replicas[dead].engine.finished})


# -------------------------------------------------------- admission rejects

def test_admission_rejects_requeue_without_loss(harness):
    cfg, params, sc, eng, seed = harness
    jobs = _jobs(cfg, seed, n=5)
    refs = _refs(eng, jobs)
    plan = FaultPlan.from_seed(seed, n_replicas=3, kill=False, max_rejects=5)
    assert plan.admission_rejects >= 1
    fd = _driver(cfg, params, sc, eng, policy="least-loaded")
    h = FaultHarness(fd, plan)
    rejects0 = obs_metrics.counter("fleet.admission_rejects").total()
    rids = [fd.submit(p, g).rid for p, g in jobs]
    fin = {r.rid: r for r in h.run()}

    assert h.n_rejected == plan.admission_rejects    # whole budget exercised
    assert obs_metrics.counter("fleet.admission_rejects").total() - rejects0 \
        == plan.admission_rejects
    assert sorted(fin) == sorted(rids)
    assert fd.shed == []                             # vetoes defer, never drop
    assert [fin[r].output for r in rids] == refs


# ----------------------------------------------------------- handoff delays

def test_delayed_handoff_delivers_bit_identical(harness):
    cfg, params, sc, eng, seed = harness
    kv_spec = KVCompressionSpec.parse("bits=16,block=8")
    jobs = _jobs(cfg, seed, n=4)
    ref_ce = ContinuousEngine(cfg, params, sc, n_slots=2, prefill_chunk=4,
                              steps=eng.steps, kv_spec=kv_spec)
    ref_rids = [ref_ce.submit(p, g).rid for p, g in jobs]
    ref_fin = {r.rid: r for r in ref_ce.run()}
    refs = [ref_fin[r].output for r in ref_rids]

    plan = FaultPlan.from_seed(seed, n_replicas=2, kill=False,
                               n_delayed=3, max_delay=4)
    fd = _driver(cfg, params, sc, eng, n_replicas=2, disaggregate=(1, 1),
                 kv_spec=kv_spec)
    h = FaultHarness(fd, plan)
    rids = [fd.submit(p, g).rid for p, g in jobs]
    fin = {r.rid: r for r in h.run()}

    assert h.n_handoffs == len(jobs)                 # transport saw each one
    assert fd.handoff.n_delivered == len(jobs)
    assert fd.handoff.pending == 0
    assert sorted(fin) == sorted(rids)
    assert [fin[r].output for r in rids] == refs
    # prefill replicas never decode; decode replica did all the tokens
    assert fd.replicas[0].engine.n_decode_steps == 0
    assert sum(len(r.output) for r in fd.replicas[1].engine.finished) \
        == sum(len(o) for o in refs)


# ------------------------------------------------------- shed{reason} counts

def test_shed_deadline_metric_exact(harness):
    cfg, params, sc, eng, seed = harness
    fd = _driver(cfg, params, sc, eng, n_replicas=1)
    before = obs_metrics.counter("fleet.shed").value(reason="deadline")
    req = fd.submit(np.ones(6, np.int32), 4, deadline_s=1e-6)
    time.sleep(0.01)
    fd.run()
    assert req.finish_reason == "deadline"
    assert req in fd.shed
    assert fd.finished == []
    assert obs_metrics.counter("fleet.shed").value(reason="deadline") \
        - before == 1


def test_shed_queue_full_metric_exact(harness):
    cfg, params, sc, eng, seed = harness
    fd = _driver(cfg, params, sc, eng, n_replicas=1, max_intake=2)
    before = obs_metrics.counter("fleet.shed").value(reason="queue_full")
    fd.submit(np.ones(6, np.int32), 3)
    fd.submit(np.ones(6, np.int32), 3)
    with pytest.raises(QueueFullError):
        fd.submit(np.ones(6, np.int32), 3)
    assert obs_metrics.counter("fleet.shed").value(reason="queue_full") \
        - before == 1
    assert len(fd.shed) == 1 and fd.shed[0].finish_reason == "queue_full"
    assert len(fd.run()) == 2                        # survivors still finish


def test_shed_no_replica_metric_exact(harness):
    cfg, params, sc, eng, seed = harness
    fd = _driver(cfg, params, sc, eng, n_replicas=2)
    fd.kill_replica(0)
    fd.kill_replica(1)
    before = obs_metrics.counter("fleet.shed").value(reason="no_replica")
    req = fd.submit(np.ones(6, np.int32), 4)
    fd.run()
    assert req.finish_reason == "no_replica"
    assert req in fd.shed
    assert obs_metrics.counter("fleet.shed").value(reason="no_replica") \
        - before == 1


# ---------------------------------------------------------------- draining

def test_draining_finishes_in_flight_but_accepts_nothing(harness):
    cfg, params, sc, eng, seed = harness
    jobs = _jobs(cfg, seed, n=6, gen_min=3, gen_max=5)
    fd = _driver(cfg, params, sc, eng, policy="round-robin")
    first = [fd.submit(p, g) for p, g in jobs[:3]]
    fd.pump()                                        # place on all 3 replicas
    drained = fd.drain_replica(0)
    # nothing has stepped yet, so replica 0's share is still in its queue
    in_flight_on_0 = {r.rid for r in fd.replicas[0].engine.queue._q}
    assert in_flight_on_0                            # round-robin gave it work
    late = [fd.submit(p, g) for p, g in jobs[3:]]
    fin = {r.rid: r for r in fd.run()}
    assert sorted(fin) == sorted(r.rid for r in first + late)  # nobody lost
    done_on_0 = {r.rid for r in fd.replicas[0].engine.finished}
    assert in_flight_on_0 <= done_on_0               # drained work finished
    assert not done_on_0 & {r.rid for r in late}     # nothing new accepted
    assert drained.accepting is False


# ------------------------------------------------------------ plan derivation

def test_fault_plan_seed_deterministic():
    mk = lambda s: FaultPlan.from_seed(s, n_replicas=3, n_delayed=2,
                                       max_rejects=5)
    assert mk(3) == mk(3)
    assert any(mk(a) != mk(b) for a, b in [(0, 1), (1, 2), (2, 3)])


def test_harness_raises_on_stuck_fleet(harness):
    cfg, params, sc, eng, seed = harness
    fd = _driver(cfg, params, sc, eng, n_replicas=1)
    # a gate that vetoes forever wedges dispatch; the harness must detect
    # the unchanged fingerprint and raise instead of spinning to max_steps
    fd.router.admission_gate = lambda h, r: False
    fd.submit(np.ones(6, np.int32), 3)
    with pytest.raises(TimeoutError, match="stuck"):
        FaultHarness(fd, FaultPlan()).run(max_steps=50)
