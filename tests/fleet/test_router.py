"""Router unit suite: placement determinism, backpressure, shedding.

Pure host-side — replicas are stubs exposing exactly the surface the
router ranks on (queue, slots occupancy, ``submit_request``), so every
policy decision is checked without building an engine.  Also home to the
direct :class:`RequestQueue` ``peek``/``requeue`` tests and the
``poisson_trace`` prefix-stability regression (the fleet benchmark scales
trace length with replica count and relies on content not shifting).
"""
import numpy as np
import pytest

from repro.obs import metrics as obs_metrics
from repro.serving.batching import (QueueFullError, Request, RequestQueue,
                                    RequestState, poisson_trace)
from repro.serving.fleet import POLICIES, ReplicaHandle, ReplicaState, Router


class _StubSlots:
    def __init__(self, n_slots, n_free):
        self.n_slots, self.n_free = n_slots, n_free


class _StubEngine:
    def __init__(self, n_slots=2, occupied=0, max_queue=4):
        self.queue = RequestQueue(max_queue)
        self.slots = _StubSlots(n_slots, n_slots - occupied)

    def submit_request(self, req):
        return self.queue.submit(req)


def _fleet(loads, **kw):
    """Handles with the given (queue_depth, occupied) pairs."""
    out = []
    for i, (depth, occ) in enumerate(loads):
        h = ReplicaHandle(i, _StubEngine(occupied=occ, **kw))
        for _ in range(depth):
            h.engine.queue.submit(_req())
        out.append(h)
    return out


def _req(**kw):
    return Request(prompt=np.ones(4, np.int32), max_new_tokens=2, **kw)


# ------------------------------------------------------------------ policies

def test_policy_names_are_the_public_contract():
    assert POLICIES == ("round-robin", "least-loaded")
    with pytest.raises(ValueError, match="unknown router policy"):
        Router(_fleet([(0, 0)]), policy="weighted")
    with pytest.raises(ValueError, match="at least one replica"):
        Router([], policy="round-robin")


def test_round_robin_rotates_across_up_replicas():
    r = Router(_fleet([(0, 0), (0, 0), (0, 0)]), policy="round-robin")
    picks = [r.dispatch(_req()).idx for _ in range(5)]
    assert picks == [0, 1, 2, 0, 1]


def test_round_robin_skips_full_replica():
    replicas = _fleet([(0, 0), (0, 0)], max_queue=1)
    r = Router(replicas, policy="round-robin")
    assert r.dispatch(_req()).idx == 0
    assert r.dispatch(_req()).idx == 1
    # both at bound now: drain replica 1 only; the rotation wants 0 next
    # but 0 is full, so the dispatch lands on 1 (skip, not shed)
    replicas[1].engine.queue.pop()
    assert r.dispatch(_req()).idx == 1


def test_least_loaded_ranks_by_queue_plus_slots():
    # loads: r0 = 2+0 = 2, r1 = 0+1 = 1, r2 = 1+2 = 3  -> r1 wins
    r = Router(_fleet([(2, 0), (0, 1), (1, 2)]), policy="least-loaded")
    assert r.dispatch(_req()).idx == 1


def test_least_loaded_tie_breaks_on_lowest_index_deterministically():
    for _ in range(3):   # no hidden state: same tie, same answer, every time
        r = Router(_fleet([(1, 1), (2, 0), (1, 1)]), policy="least-loaded")
        picks = [r.dispatch(_req()).idx for _ in range(2)]
        # all tied at load 2; r0 wins, then holds load 3 so r1/r2 tie at 2
        assert picks == [0, 1]


def test_dispatch_defers_when_every_candidate_is_full():
    r = Router(_fleet([(1, 0)], max_queue=1), policy="round-robin")
    req = _req()
    assert r.dispatch(req) is None
    assert not req.done                  # backpressure: intake retries later
    assert r.shed == [] and r.n_dispatched == 0


def test_deadline_shed_is_exact():
    r = Router(_fleet([(0, 0)]), policy="round-robin")
    before = obs_metrics.counter("fleet.shed").value(reason="deadline")
    req = _req(deadline_s=0.5)
    req.t_arrival = 100.0                # queued at t=100, deadline t=100.5
    assert r.dispatch(req, now=100.4) is not None      # not expired: placed
    req2 = _req(deadline_s=0.5)
    req2.t_arrival = 100.0
    assert r.dispatch(req2, now=100.6) is None         # past the deadline
    assert req2.done and req2.state is RequestState.EXPIRED
    assert req2.finish_reason == "deadline"
    assert req2.t_finished == 100.6
    assert r.shed == [req2]
    assert obs_metrics.counter("fleet.shed").value(reason="deadline") \
        - before == 1


def test_no_replica_shed_when_none_routable():
    replicas = _fleet([(0, 0), (0, 0)])
    replicas[0].state = ReplicaState.DRAINING
    replicas[1].state = ReplicaState.FAILED
    r = Router(replicas, policy="least-loaded")
    before = obs_metrics.counter("fleet.shed").value(reason="no_replica")
    req = _req()
    assert r.dispatch(req) is None
    assert req.done and req.state is RequestState.REJECTED
    assert req.finish_reason == "no_replica"
    assert obs_metrics.counter("fleet.shed").value(reason="no_replica") \
        - before == 1
    assert r.n_up == 0


def test_draining_replica_gets_no_new_work():
    replicas = _fleet([(0, 0), (0, 0)])
    r = Router(replicas, policy="round-robin")
    replicas[0].state = ReplicaState.DRAINING
    assert all(r.dispatch(_req()).idx == 1 for _ in range(3))
    assert not replicas[0].accepting
    assert replicas[1].accepting


def test_admission_gate_veto_skips_but_never_sheds():
    vetoed = []
    r = Router(_fleet([(0, 0), (0, 0)]), policy="round-robin",
               admission_gate=lambda h, req: not (
                   h.idx == 0 and not vetoed.append((h.idx, req.rid))))
    before = obs_metrics.counter("fleet.admission_rejects").total()
    assert r.dispatch(_req()).idx == 1   # r0 vetoed, fell through to r1
    assert len(vetoed) == 1
    assert obs_metrics.counter("fleet.admission_rejects").total() \
        - before == 1
    assert r.shed == []


# ------------------------------------------------- RequestQueue direct tests

def test_queue_peek_returns_head_without_removal():
    q = RequestQueue(max_queue=4)
    a, b = _req(), _req()
    q.submit(a, now=0.0)
    q.submit(b, now=0.0)
    assert q.peek(now=0.0) is a
    assert len(q) == 2                   # peek did not pop
    assert q.pop(now=0.0) is a           # peek-then-pop agree on the head
    assert q.peek(now=0.0) is b


def test_queue_peek_lazily_expires_overdue_heads():
    q = RequestQueue(max_queue=4)
    dead = _req(deadline_s=0.5)
    live = _req()
    q.submit(dead, now=0.0)
    q.submit(live, now=0.0)
    assert q.peek(now=1.0) is live       # dead expired in passing
    assert dead.state is RequestState.EXPIRED
    assert q.expired == [dead]
    assert len(q) == 1


def test_queue_peek_empty_returns_none():
    assert RequestQueue(max_queue=1).peek() is None


def test_queue_requeue_front_inserts_and_bypasses_bound():
    q = RequestQueue(max_queue=2)
    a, b, c = _req(), _req(), _req()
    q.submit(a, now=0.0)
    q.submit(b, now=0.0)
    c.state = RequestState.DECODING      # evacuated mid-flight
    q.requeue(c)
    assert len(q) == 3                   # over the bound, on purpose
    assert c.state is RequestState.QUEUED
    assert q.pop(now=0.0) is c           # front insert: redrives go first
    with pytest.raises(QueueFullError):  # submit backpressure still applies
        q.submit(_req(), now=0.0)


# ------------------------------------------- trace determinism (fleet scale)

def test_poisson_trace_is_prefix_stable():
    """trace(n)[:k] == trace(k): request content derives from (seed, i)
    only, so scaling trace length with replica count never changes what any
    request contains (the pre-fleet single-stream RNG broke this)."""
    kw = dict(rate_per_s=50.0, prompt_max=12, gen_max=5, vocab=97, seed=11)
    long = poisson_trace(9, **kw)
    for k in (1, 4, 9):
        short = poisson_trace(k, **kw)
        for (ta, pa, ga), (tb, pb, gb) in zip(long[:k], short):
            assert ta == tb and ga == gb
            np.testing.assert_array_equal(pa, pb)


def test_poisson_trace_prefix_pool_is_prefix_stable():
    kw = dict(rate_per_s=50.0, prompt_max=12, gen_max=5, vocab=97, seed=3,
              prefix_pool=2, prefix_len=4)
    long = poisson_trace(7, **kw)
    short = poisson_trace(3, **kw)
    for (ta, pa, ga), (tb, pb, gb) in zip(long[:3], short):
        assert ta == tb and ga == gb
        np.testing.assert_array_equal(pa, pb)
    # the shared prefixes really are shared: every prompt opens with one of
    # exactly two distinct 4-token prefixes
    heads = {tuple(p[:4]) for _, p, _ in long}
    assert len(heads) == 2
