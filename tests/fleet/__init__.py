"""Fleet serving suite: DP replicas, router, disaggregated handoff.

Package so the fault-injection harness (:mod:`.faults`) is shared by the
test modules via a relative import (pytest imports these as ``fleet.*``;
tests/ itself is not a package — same pattern as ``tests/differential``).
The harness is deliberately importable by downstream chaos tooling too:
``from fleet.faults import FaultPlan, FaultHarness``.
"""
