"""Fleet bit-identity: N replicas == 1 engine, token for token.

The contract (docs/FLEET.md): a request's greedy output is a function of
its prompt alone — never of replica count, router policy, which replica it
landed on, or whether its KV crossed the prefill→decode wire.  Holds by
construction (one shared ``ServeSteps`` ⇒ same jitted functions ⇒ same
numerics; per-slot ``kv_len`` masking ⇒ lane independence), pinned here by
property tests over (replica count, policy, trace seed) for both
attention-cache families, plus the disaggregated path with a byte-level
check of the handoff wire format.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core.spec import KVCompressionSpec
from repro.models import api
from repro.serving import engine as serving_engine
from repro.serving.batching import ContinuousEngine, poisson_trace
from repro.serving.fleet import POLICIES, FleetDriver
from repro.serving.kvcache.cold import (decode_block_leaves,
                                        encode_block_leaves)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # dev extra absent: the fixed grid below runs
    given = None

MAX_LEN = 48


def _cfg(family):
    if family == "dense":
        return registry.reduced(registry.get("qwen3-1.7b"))
    cfg = registry.reduced(registry.get("qwen2-moe-a2.7b"))
    # generous capacity keeps GShard token-dropping packing-independent
    # (same knob as tests/test_continuous_batching.py)
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))


@pytest.fixture(scope="module", params=["dense", "moe"])
def harness(request):
    cfg = _cfg(request.param)
    params = api.build(cfg).init(cfg, jax.random.PRNGKey(0))
    sc = serving_engine.ServeConfig(max_len=MAX_LEN)
    eng = serving_engine.Engine(cfg, params, sc)
    return cfg, params, sc, eng


def _trace_jobs(cfg, seed, n, prefix=False):
    """(prompt, gen) pairs off a Poisson trace (arrival times dropped —
    identity is about content, the fault suite covers pacing)."""
    kw = dict(prefix_pool=2, prefix_len=8) if prefix else {}
    trace = poisson_trace(n, rate_per_s=1e9, prompt_max=16, gen_max=6,
                          vocab=cfg.vocab, seed=seed, **kw)
    return [(p, g) for _, p, g in trace]


def _solo_refs(eng, jobs):
    return [np.asarray(eng.generate(np.asarray(p)[None], g))[0].tolist()
            for p, g in jobs]


def _run_fleet(cfg, params, sc, eng, jobs, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("prefill_chunk", 4)
    fd = FleetDriver(cfg, params, sc, steps=eng.steps, **kw)
    rids = [fd.submit(p, g).rid for p, g in jobs]
    fin = {r.rid: r for r in fd.run()}
    assert sorted(fin) == sorted(rids)
    return fd, [fin[r].output for r in rids]


# --------------------------------------------------------------- DP fleets

def _check_fleet_matches_single_engine(harness, n_replicas, policy, seed):
    cfg, params, sc, eng = harness
    jobs = _trace_jobs(cfg, seed, n=5)
    refs = _solo_refs(eng, jobs)
    _, outs = _run_fleet(cfg, params, sc, eng, jobs,
                         n_replicas=n_replicas, policy=policy)
    assert outs == refs


if given is not None:
    # property form: hypothesis explores (replica count, policy, trace seed)
    # under the deterministic profile (tests/conftest.py)
    @settings(max_examples=6)
    @given(n_replicas=st.integers(1, 3), policy=st.sampled_from(POLICIES),
           seed=st.integers(0, 3))
    def test_fleet_matches_single_engine(harness, n_replicas, policy, seed):
        _check_fleet_matches_single_engine(harness, n_replicas, policy, seed)
else:
    # no dev extra: same bounds as the strategies, fixed grid
    @pytest.mark.parametrize("n_replicas,policy,seed", [
        (1, "round-robin", 0), (1, "least-loaded", 1),
        (2, "round-robin", 1), (2, "least-loaded", 2),
        (3, "round-robin", 3), (3, "least-loaded", 0)])
    def test_fleet_matches_single_engine(harness, n_replicas, policy, seed):
        _check_fleet_matches_single_engine(harness, n_replicas, policy, seed)


def test_fleet_identity_survives_prefix_shared_paged_traffic(harness):
    """2-replica paged fleet with prefix sharing == 1 paged engine, on a
    trace of shared system prompts (the sharing fast path must not leak
    across replicas or requests)."""
    cfg, params, sc, eng = harness
    kv_spec = KVCompressionSpec.parse("bits=16,block=4,sharing")
    jobs = _trace_jobs(cfg, seed=1, n=5, prefix=True)
    ref = ContinuousEngine(cfg, params, sc, n_slots=2, prefill_chunk=4,
                           steps=eng.steps, kv_spec=kv_spec)
    ref_rids = [ref.submit(p, g).rid for p, g in jobs]
    ref_fin = {r.rid: r for r in ref.run()}
    refs = [ref_fin[r].output for r in ref_rids]
    _, outs = _run_fleet(cfg, params, sc, eng, jobs, n_replicas=2,
                         policy="least-loaded", kv_spec=kv_spec)
    assert outs == refs


# ------------------------------------------------------- disaggregated path

@pytest.mark.parametrize("split,spec", [
    ((1, 1), "bits=16,block=8"),
    ((1, 2), "bits=8,codec=rans,block=8"),
])
def test_disaggregated_fleet_matches_single_paged_engine(harness, split,
                                                         spec):
    cfg, params, sc, eng = harness
    kv_spec = KVCompressionSpec.parse(spec)
    jobs = _trace_jobs(cfg, seed=2, n=4)
    ref = ContinuousEngine(cfg, params, sc, n_slots=2, prefill_chunk=4,
                           steps=eng.steps, kv_spec=kv_spec)
    ref_rids = [ref.submit(p, g).rid for p, g in jobs]
    ref_fin = {r.rid: r for r in ref.run()}
    refs = [ref_fin[r].output for r in ref_rids]

    fd, outs = _run_fleet(cfg, params, sc, eng, jobs,
                          n_replicas=sum(split), disaggregate=split,
                          kv_spec=kv_spec)
    assert outs == refs
    assert fd.handoff.n_delivered == len(jobs)       # every KV crossed the wire
    assert fd.handoff.bytes_on_wire > 0


def test_handoff_wire_format_round_trips_byte_equal(harness):
    """decode(encode(blocks)) is byte-equal and dtype-preserving — the
    cold-tier codec round-trip really is lossless as a wire format."""
    cfg, params, sc, eng = harness
    kv_spec = KVCompressionSpec.parse("bits=8,codec=rans,block=8")
    captured = []
    fd = FleetDriver(cfg, params, sc, steps=eng.steps, n_replicas=2,
                     n_slots=2, prefill_chunk=4, disaggregate=(1, 1),
                     kv_spec=kv_spec,
                     handoff_transport=lambda p: captured.append(p) or 0)
    for p, g in _trace_jobs(cfg, seed=3, n=3):
        fd.submit(p, g)
    fd.run()
    assert captured
    for payload in captured:
        leaves = payload.decode_blocks()
        assert len(leaves) == -(-payload.kv_len // kv_spec.block_size)
        for block in leaves:
            entry, _, _ = encode_block_leaves(fd.handoff.codec, block)
            again = decode_block_leaves(entry)
            assert set(again) == set(block)
            for name in block:
                assert again[name].dtype == block[name].dtype
                np.testing.assert_array_equal(
                    np.asarray(again[name]).view(np.uint8),
                    np.asarray(block[name]).view(np.uint8))


# -------------------------------------------------------- weight accounting

def test_weight_bytes_accounts_share_vs_per_replica(harness):
    cfg, params, sc, eng = harness
    shared = FleetDriver(cfg, params, sc, steps=eng.steps, n_replicas=3,
                         n_slots=1)
    wb = shared.weight_bytes()
    assert wb["mode"] == "share" and wb["copies"] == 1
    assert wb["total_bytes"] == wb["bytes_per_copy"] > 0

    copies = [jax.tree.map(lambda x: x + 0, params) for _ in range(2)]
    per = FleetDriver(cfg, None, sc, steps=eng.steps, n_replicas=2,
                      n_slots=1, replica_params=copies)
    wb2 = per.weight_bytes()
    assert wb2["mode"] == "per-replica" and wb2["copies"] == 2
    assert wb2["total_bytes"] == 2 * wb["bytes_per_copy"]

    # per-replica trees still serve bit-identically (same values, same steps)
    jobs = _trace_jobs(cfg, seed=0, n=2)
    refs = _solo_refs(eng, jobs)
    rids = [per.submit(p, g).rid for p, g in jobs]
    fin = {r.rid: r for r in per.run()}
    assert [fin[r].output for r in rids] == refs
