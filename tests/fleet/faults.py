"""Deterministic fault injection for fleet serving (docs/FLEET.md).

A :class:`FaultPlan` is a *schedule*, not a dice roll at runtime: every
fault it describes is derived once from a seed (``FaultPlan.from_seed``)
and then replayed against a **lockstep** :class:`~repro.serving.fleet.
FleetDriver`, so a failing seed reproduces exactly — same kill step, same
handoff delays, same admission vetoes.  Three fault classes:

* ``kills[idx] = K`` — fail replica ``idx`` once *its engine* has taken
  ``K`` fused decode steps (``ContinuousEngine.n_decode_steps``), i.e. mid
  decode with real tokens already generated.  The driver evacuates and
  redrives the victims; the no-loss/no-duplicate contract is what
  ``test_fleet_faults.py`` pins.
* ``handoff_delays[j] = d`` — the ``j``-th prefill→decode payload sits on
  the wire for ``d`` extra pumps (installed as the coordinator's
  ``transport``).
* ``admission_rejects = M`` — the router's ``admission_gate`` vetoes the
  first ``M`` (replica, request) placement attempts, forcing the
  defer-requeue-retry path without any queue actually being full.

:class:`FaultHarness` installs a plan on a driver and drives it to drain
with a bounded-step, stuck-detection loop: if a step moves nothing AND the
whole observable fleet state (intake, gate budget, handoff backlog, decode
progress, finish/shed counts) is unchanged, it raises ``TimeoutError``
instead of spinning — a regression that wedges the fleet fails fast with
the state snapshot in the message.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Set

import numpy as np

from repro.serving.batching.request import Request
from repro.serving.fleet import FleetDriver


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seed-derived fault schedule (see module docstring for semantics)."""
    kills: Dict[int, int] = dataclasses.field(default_factory=dict)
    handoff_delays: Dict[int, int] = dataclasses.field(default_factory=dict)
    admission_rejects: int = 0

    @classmethod
    def from_seed(cls, seed: int, *, n_replicas: int,
                  kill: bool = True, kill_after: int = 5,
                  n_delayed: int = 0, max_delay: int = 3,
                  max_rejects: int = 0) -> "FaultPlan":
        """Derive a plan from ``seed`` (stable across runs and platforms).

        ``kill`` picks ONE victim replica (a plan never kills the whole
        fleet — total loss of capacity is the ``no_replica`` shed test's
        job, not a redrive scenario)."""
        rng = np.random.default_rng([int(seed), 0xFA])
        kills: Dict[int, int] = {}
        if kill and n_replicas > 1:
            kills[int(rng.integers(n_replicas))] = \
                int(rng.integers(1, kill_after + 1))
        delays = {j: int(rng.integers(1, max_delay + 1))
                  for j in range(n_delayed)}
        rejects = int(rng.integers(1, max_rejects + 1)) if max_rejects else 0
        return cls(kills=kills, handoff_delays=delays,
                   admission_rejects=rejects)


class FaultHarness:
    """Install a :class:`FaultPlan` on a lockstep driver and run it dry."""

    def __init__(self, driver: FleetDriver, plan: FaultPlan):
        self.driver = driver
        self.plan = plan
        self.rejects_left = plan.admission_rejects
        self.n_rejected = 0               # vetoes actually exercised
        self.n_handoffs = 0               # payloads seen by the transport
        self.victims: List[Request] = []  # evacuated by triggered kills
        self.n_steps = 0
        self._killed: Set[int] = set()
        if plan.admission_rejects:
            driver.router.admission_gate = self._gate
        if plan.handoff_delays:
            if driver.handoff is None:
                raise ValueError("plan delays handoffs but the driver is "
                                 "not disaggregated")
            driver.handoff.transport = self._transport

    # ------------------------------------------------------------ fault hooks
    def _gate(self, handle, req) -> bool:
        if self.rejects_left > 0:
            self.rejects_left -= 1
            self.n_rejected += 1
            return False
        return True

    def _transport(self, payload) -> int:
        d = self.plan.handoff_delays.get(self.n_handoffs, 0)
        self.n_handoffs += 1
        return d

    def _maybe_kill(self) -> None:
        for idx, after in self.plan.kills.items():
            if idx in self._killed:
                continue
            if self.driver.replicas[idx].engine.n_decode_steps >= after:
                self._killed.add(idx)
                self.victims.extend(self.driver.kill_replica(idx))

    # ------------------------------------------------------------------ drive
    def _fingerprint(self) -> tuple:
        d = self.driver
        return (len(d.intake), self.rejects_left,
                d.handoff.pending if d.handoff is not None else 0,
                tuple(h.engine.n_decode_steps for h in d.replicas),
                len(d.finished), len(d.shed), tuple(sorted(self._killed)))

    def run(self, max_steps: int = 5000) -> List[Request]:
        """Lockstep the fleet to drain, firing plan kills between steps.

        Raises ``TimeoutError`` on the step bound or on a no-progress step
        that also left the fleet state fingerprint unchanged (stuck, not
        merely quiet — e.g. an admission veto changes the gate budget, so
        a deferred-but-retrying request never trips this)."""
        prev = None
        while self.driver.has_work:
            moved = self.driver.step()
            self._maybe_kill()
            self.n_steps += 1
            if self.n_steps >= max_steps:
                raise TimeoutError(
                    f"fleet not drained after {max_steps} steps: "
                    f"{self._fingerprint()}")
            fp = self._fingerprint()
            if not moved and fp == prev and self.driver.has_work:
                raise TimeoutError(f"fleet stuck (no progress): {fp}")
            prev = fp
        return self.driver.finished
