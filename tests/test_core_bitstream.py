"""Round-trip tests for encode -> serial decode -> multi-stream decode (np + jax)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import bitstream, quant
from repro.core.decode_jax import decode_streams_jax
from repro.core.entropy import HuffmanTable, global_frequencies
from repro.core.segmentation import balanced_assignment, segment_and_encode
from repro.core.store import CompressedModel


def _table_for(symbols, bits):
    freqs = np.bincount(symbols.reshape(-1), minlength=1 << bits).astype(np.int64)
    return HuffmanTable(freqs, max_len=12)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_encode_serial_roundtrip(bits):
    rng = np.random.default_rng(bits)
    # skewed symbol distribution, like quantized Gaussian weights
    raw = rng.normal(0, 0.15, size=5000)
    symbols = np.clip(np.rint(raw * (1 << bits) + (1 << (bits - 1))), 0,
                      (1 << bits) - 1).astype(np.uint8)
    t = _table_for(symbols, bits)
    stream, nbits = bitstream.encode_symbols(symbols, t.codes, t.lengths)
    assert nbits == t.encoded_bits(symbols)
    dec = bitstream.decode_serial(stream, symbols.size, t.lut_sym, t.lut_len, t.max_len)
    np.testing.assert_array_equal(dec, symbols)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 8), st.integers(1, 2000))
def test_roundtrip_property(seed, bits, n):
    rng = np.random.default_rng(seed)
    symbols = rng.integers(0, 1 << bits, size=n).astype(np.uint8)
    t = _table_for(symbols, bits)
    stream, _ = bitstream.encode_symbols(symbols, t.codes, t.lengths)
    dec = bitstream.decode_serial(stream, n, t.lut_sym, t.lut_len, t.max_len)
    np.testing.assert_array_equal(dec, symbols)


def test_multistream_matches_serial():
    rng = np.random.default_rng(11)
    segs = [rng.integers(0, 256, size=rng.integers(1, 700)).astype(np.uint8)
            for _ in range(17)]
    t = _table_for(np.concatenate(segs), 8)
    streams, counts = [], []
    for s in segs:
        enc, _ = bitstream.encode_symbols(s, t.codes, t.lengths)
        streams.append(enc)
        counts.append(s.size)
    mat, _ = bitstream.pack_streams(streams)
    counts = np.array(counts)
    out = bitstream.decode_streams(mat, counts, t.lut_sym, t.lut_len, t.max_len)
    for i, s in enumerate(segs):
        np.testing.assert_array_equal(out[i, : s.size], s)


def test_jax_decoder_matches_numpy():
    rng = np.random.default_rng(12)
    segs = [rng.integers(0, 16, size=256).astype(np.uint8) for _ in range(8)]
    t = _table_for(np.concatenate(segs), 4)
    streams = [bitstream.encode_symbols(s, t.codes, t.lengths)[0] for s in segs]
    mat, _ = bitstream.pack_streams(streams)
    counts = np.full(8, 256, dtype=np.int32)
    ref = bitstream.decode_streams(mat, counts, t.lut_sym, t.lut_len, t.max_len)
    out = decode_streams_jax(mat, counts, t.lut_sym.astype(np.int32),
                             t.lut_len.astype(np.int32), max_len=t.max_len,
                             max_count=256)
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_segmentation_roundtrip_and_balance():
    rng = np.random.default_rng(13)
    q = rng.integers(0, 256, size=(300, 70)).astype(np.uint8)
    t = _table_for(q, 8)
    meta, streams = segment_and_encode("w", q, t, segment_symbols=1024)
    assert meta.seg_counts.sum() == q.size
    # balanced assignment: worker loads within 20% of each other
    buckets = balanced_assignment(meta.seg_bits, 3)
    loads = [meta.seg_bits[b].sum() for b in buckets]
    assert max(loads) <= 1.2 * max(min(loads), 1)
    # segments decode independently and reassemble exactly
    mat, _ = bitstream.pack_streams(streams)
    out = bitstream.decode_streams(mat, meta.seg_counts, t.lut_sym, t.lut_len, t.max_len)
    flat = np.concatenate([out[i, : int(c)] for i, c in enumerate(meta.seg_counts)])
    np.testing.assert_array_equal(flat.astype(np.uint8), q.reshape(-1))


@pytest.mark.parametrize("bits", [4, 8])
def test_compressed_model_end_to_end(bits, tmp_path):
    rng = np.random.default_rng(bits + 100)
    params = {
        "layer0/attn/wq": rng.normal(0, 0.02, size=(128, 128)).astype(np.float32),
        "layer0/mlp/w1": rng.normal(0, 0.02, size=(128, 256)).astype(np.float32),
        "layer0/mlp/w2": np.abs(rng.normal(0, 0.02, size=(256, 128))).astype(np.float32),
        "layer0/norm/scale": np.ones(128, dtype=np.float32),  # stays fp32
    }
    cm = CompressedModel.compress(params, bits=bits, segment_symbols=2048)
    assert "layer0/norm/scale" in cm.unquantized

    # lossless: decoded symbols equal direct quantization
    for name in ["layer0/attn/wq", "layer0/mlp/w1", "layer0/mlp/w2"]:
        direct = quant.quantize(params[name], bits)
        np.testing.assert_array_equal(cm.decode_tensor(name), direct.q)

    # dequantized weights approximate originals within half a step
    deq = cm.dequantize_all()
    for name in ["layer0/attn/wq", "layer0/mlp/w1"]:
        direct = quant.quantize(params[name], bits)
        np.testing.assert_allclose(deq[name], quant.dequantize(direct), rtol=0, atol=1e-6)

    # stats coherent: encoded <= quantized <= fp16
    st_ = cm.stats()
    assert st_.encoded_bytes <= st_.quant_bytes <= st_.raw_bytes
    assert st_.entropy_bits <= st_.effective_bits <= st_.entropy_bits + 1.0

    # persistence roundtrip
    p = str(tmp_path / "model.npz")
    cm.save(p)
    cm2 = CompressedModel.load(p)
    np.testing.assert_array_equal(cm2.decode_tensor("layer0/attn/wq"),
                                  cm.decode_tensor("layer0/attn/wq"))
    assert cm2.stats().effective_bits == pytest.approx(st_.effective_bits)
