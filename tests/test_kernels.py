"""Per-kernel allclose sweeps: Pallas (interpret=True) vs the ref.py oracle,
across shapes and scale/zero layouts (deliverable c)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.bitstream import decode_streams, encode_symbols, pack_streams
from repro.core.entropy import HuffmanTable
from repro.kernels import ops, ref


@pytest.mark.parametrize("M,K,N", [
    (8, 128, 64), (64, 384, 200), (128, 512, 128), (1, 1024, 96), (33, 257, 65),
])
@pytest.mark.parametrize("per_channel", [True, False])
def test_dequant_matmul_int8(M, K, N, per_channel):
    rng = np.random.default_rng(M * 1000 + K + N)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.bfloat16)
    wq = jnp.asarray(rng.integers(0, 256, size=(K, N)), jnp.uint8)
    if per_channel:
        scale = rng.uniform(1e-3, 1e-2, size=(N,)).astype(np.float32)
        zero = rng.uniform(-1, 0, size=(N,)).astype(np.float32)
    else:
        scale, zero = np.float32(0.005), np.float32(-0.6)
    out = ops.dequant_matmul(x, wq, scale, zero)
    want = ref.dequant_matmul_ref(x, wq, scale, zero)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=1e-2,
                               rtol=1e-2)


@pytest.mark.parametrize("M,K,N", [(16, 256, 128), (8, 130, 48)])
def test_dequant_matmul_int4(M, K, N):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.bfloat16)
    q4 = rng.integers(0, 16, size=(K, N)).astype(np.uint8)
    packed = jnp.asarray(ops.pack_nibbles(q4))
    scale = rng.uniform(0.01, 0.1, size=(N,)).astype(np.float32)
    zero = np.zeros(N, np.float32)
    out = ops.dequant_matmul(x, packed, scale, zero, int4=True)
    want = ref.dequant_matmul_ref(x, packed, scale, zero, int4=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=1e-2,
                               rtol=1e-2)


def test_pack_unpack_nibbles_roundtrip():
    rng = np.random.default_rng(1)
    q = rng.integers(0, 16, size=(64, 33)).astype(np.uint8)
    assert (ops.unpack_nibbles(ops.pack_nibbles(q)) == q).all()


def test_dequant_matmul_equals_float_matmul():
    """Quantize a real matrix, then kernel(x, q) ~= x @ w_dequant."""
    from repro.core import quant
    rng = np.random.default_rng(2)
    w = rng.normal(0, 0.05, size=(256, 128)).astype(np.float32)
    qt = quant.quantize(w, 8)
    x = jnp.asarray(rng.normal(size=(16, 256)), jnp.bfloat16)
    out = ops.dequant_matmul(x, jnp.asarray(qt.q),
                             qt.scale.reshape(-1), qt.zero.reshape(-1))
    want = np.asarray(x, np.float32) @ quant.dequantize(qt)
    np.testing.assert_allclose(np.asarray(out, np.float32), want,
                               atol=0.15, rtol=0.05)


@pytest.mark.parametrize("n_streams,max_len", [(1, 12), (7, 12), (130, 12),
                                               (16, 10)])
def test_huffman_decode_kernel_vs_host(n_streams, max_len):
    rng = np.random.default_rng(n_streams)
    freqs = rng.integers(1, 2000, size=256)
    table = HuffmanTable(freqs, max_len=max_len)
    streams, counts = [], []
    for _ in range(n_streams):
        n = int(rng.integers(10, 500))
        syms = rng.integers(0, 256, size=n).astype(np.uint8)
        s, _ = encode_symbols(syms, table.codes, table.lengths)
        streams.append(s)
        counts.append(n)
    mat, _ = pack_streams(streams)
    counts = np.array(counts, np.int64)
    host = decode_streams(mat, counts, table.lut_sym, table.lut_len, max_len)
    kern = ops.huffman_decode(
        jnp.asarray(mat), jnp.asarray(counts, jnp.int32),
        jnp.asarray(table.lut_sym), jnp.asarray(table.lut_len),
        max_len=max_len, max_count=int(counts.max()))
    assert (np.asarray(kern) == host).all()


def test_huffman_decode_kernel_roundtrip_identity():
    """encode -> pallas decode == original symbols, skewed histogram."""
    rng = np.random.default_rng(9)
    # peaky (trained-LLM-like) distribution
    syms = np.clip(rng.normal(128, 12, size=5000), 0, 255).astype(np.uint8)
    freqs = np.bincount(syms, minlength=256) + 0
    table = HuffmanTable(np.maximum(freqs, 0), max_len=12)
    chunks = np.array_split(syms, 5)
    streams = [encode_symbols(c, table.codes, table.lengths)[0]
               for c in chunks]
    mat, _ = pack_streams(streams)
    counts = np.array([len(c) for c in chunks], np.int64)
    out = ops.huffman_decode(
        jnp.asarray(mat), jnp.asarray(counts, jnp.int32),
        jnp.asarray(table.lut_sym), jnp.asarray(table.lut_len),
        max_len=12, max_count=int(counts.max()))
    got = np.concatenate([np.asarray(out)[i, :c] for i, c in enumerate(counts)])
    assert (got == syms).all()
