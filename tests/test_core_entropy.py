"""Tests for canonical length-limited Huffman coding + the paper's Table I bands."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import entropy, quant
from repro.core.entropy import (HuffmanTable, canonical_codes, code_lengths,
                                effective_bits, huffman_code_lengths,
                                package_merge_lengths, shannon_entropy,
                                validate_kraft)


def _rand_freqs(rng, n, zipf=False):
    if zipf:
        f = np.floor(1e6 / (np.arange(1, n + 1) ** 1.3)).astype(np.int64)
        rng.shuffle(f)
        return f
    return rng.integers(0, 10_000, size=n).astype(np.int64)


def test_huffman_matches_entropy_bound():
    rng = np.random.default_rng(0)
    for _ in range(10):
        freqs = _rand_freqs(rng, 256)
        lengths = huffman_code_lengths(freqs)
        h = shannon_entropy(freqs)
        eb = effective_bits(freqs, lengths)
        assert h <= eb + 1e-9
        assert eb < h + 1.0  # Huffman is within 1 bit of entropy
        assert abs(validate_kraft(lengths) - 1.0) < 1e-12


def test_package_merge_optimal_when_unconstrained():
    rng = np.random.default_rng(1)
    for _ in range(10):
        freqs = _rand_freqs(rng, 64, zipf=True)
        unlimited = huffman_code_lengths(freqs)
        limited = package_merge_lengths(freqs, max_len=32)
        # same total cost (code assignments may differ, cost must match exactly)
        assert (freqs * unlimited).sum() == (freqs * limited).sum()


def test_package_merge_respects_limit_and_kraft():
    rng = np.random.default_rng(2)
    # heavily skewed -> unlimited Huffman would exceed 12 bits
    freqs = np.array([2 ** i for i in range(20)], dtype=np.int64)
    assert huffman_code_lengths(freqs).max() > 12
    lengths = package_merge_lengths(freqs, max_len=12)
    assert lengths.max() <= 12
    assert np.all(lengths[freqs > 0] >= 1)
    assert validate_kraft(lengths) <= 1.0 + 1e-12
    # cost must not be worse than the naive "clamp all to ceil(log2 n)" code
    flat = np.where(freqs > 0, int(np.ceil(np.log2((freqs > 0).sum()))), 0)
    assert (freqs * lengths).sum() <= (freqs * flat).sum()


def test_canonical_codes_are_prefix_free():
    rng = np.random.default_rng(3)
    freqs = _rand_freqs(rng, 100, zipf=True)
    lengths = code_lengths(freqs, max_len=12)
    codes = canonical_codes(lengths)
    entries = [(int(codes[s]), int(l)) for s, l in enumerate(lengths) if l > 0]
    # pairwise prefix-freedom
    as_bits = {format(c, f"0{l}b") for c, l in entries}
    assert len(as_bits) == len(entries)
    for a in as_bits:
        for b in as_bits:
            if a is not b and len(a) < len(b):
                assert not b.startswith(a), (a, b)


def test_decode_lut_consistency():
    rng = np.random.default_rng(4)
    freqs = _rand_freqs(rng, 256, zipf=True)
    t = HuffmanTable(freqs, max_len=12)
    # every symbol's canonical code decodes back to itself through the LUT
    for s in np.nonzero(freqs)[0]:
        l = int(t.lengths[s])
        peek = int(t.codes[s]) << (t.max_len - l)
        assert t.lut_sym[peek] == s
        assert t.lut_len[peek] == l


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 256), st.integers(0, 2**31 - 1))
def test_table_property(nsym, seed):
    rng = np.random.default_rng(seed)
    freqs = np.zeros(256, dtype=np.int64)
    active = rng.choice(256, size=nsym, replace=False)
    freqs[active] = rng.integers(1, 100_000, size=nsym)
    t = HuffmanTable(freqs, max_len=12)
    assert t.lengths.max() <= 12
    assert t.entropy <= t.effective_bits + 1e-9
    assert validate_kraft(t.lengths) <= 1.0 + 1e-12
    # length-limited optimum is within 0.1 bits of entropy for these sizes... not
    # guaranteed in general; assert the Huffman <= entropy + 1 bound instead.
    assert t.effective_bits < t.entropy + 1.0


def test_paper_table1_effective_bits_band():
    """Reproduce the paper's Table I 'Effective Bits' finding on realistic weights.

    LLM weights are near-Gaussian with outliers; per-tensor min/max quantization then
    concentrates symbols around the center, so 8-bit quantized weights entropy-code to
    ~5.5-6 bits and 4-bit weights to ~1.3-1.7 bits (paper: 5.92/5.58/5.84 and
    1.57/1.39/1.62).  We synthesize weights as Gaussian + a small outlier tail, the
    standard model for trained LLM weight matrices.
    """
    rng = np.random.default_rng(7)
    tensors = []
    for _ in range(8):
        w = rng.normal(0.0, 0.02, size=(512, 512)).astype(np.float32)
        # outlier tail (~0.1% of entries, 10-25 sigma) as observed in trained LLMs
        n_out = int(w.size * 0.001)
        idx = rng.choice(w.size, n_out, replace=False)
        w.reshape(-1)[idx] *= rng.uniform(10, 25, size=n_out).astype(np.float32)
        tensors.append(w)

    for bits, lo, hi in [(8, 5.0, 6.5), (4, 1.0, 2.2)]:
        qs = [quant.quantize(w, bits).q for w in tensors]
        freqs = entropy.global_frequencies(qs, 1 << bits)
        t = HuffmanTable(freqs, max_len=12)
        assert lo < t.effective_bits < hi, (bits, t.effective_bits)
        # near-optimal coding: Gallager's redundancy bound is p_max + 0.086; small
        # alphabets (4-bit: 16 symbols) sit closer to that bound than large ones.
        p_max = t.freqs.max() / t.freqs.sum()
        assert t.effective_bits <= t.entropy + p_max + 0.086 + 1e-9
