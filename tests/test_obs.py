"""Observability subsystem: tracer, metrics registry, trace analysis.

The cardinal rule under test is PURE OBSERVATION: enabling the tracer and
recording metrics must not change a single computed token (greedy serve
traced vs untraced is asserted bit-identical).  The rest pins down the
contracts the tooling stands on: span nesting across threads, Perfetto
``trace_event`` schema validity, P² streaming-quantile accuracy vs numpy,
the label-cardinality guard, exact-percentile agreement with
``np.percentile``, interval arithmetic for the overlap report, and the
instrumentation-point catalog staying in sync with docs/OBSERVABILITY.md.
"""
import json
import os
import threading

import numpy as np
import pytest

from repro.obs import analysis, metrics, points, trace


@pytest.fixture(autouse=True)
def _isolate_obs():
    """Every test gets a fresh registry and no global tracer."""
    metrics.reset()
    trace.disable()
    yield
    metrics.reset()
    trace.disable()


# ---------------------------------------------------------------------------
# tracer

class TestTracer:
    def test_span_nesting_parents(self):
        tr = trace.enable()
        with trace.span("outer"):
            with trace.span("mid"):
                with trace.span("inner"):
                    pass
            with trace.span("sibling"):
                pass
        trace.disable()
        by_name = {e.name: e for e in tr.events}
        assert by_name["outer"].parent is None
        assert by_name["mid"].parent == by_name["outer"].id
        assert by_name["inner"].parent == by_name["mid"].id
        assert by_name["sibling"].parent == by_name["outer"].id
        # children are contained in their parent's [t0, t0+dur) window
        o, i = by_name["outer"], by_name["inner"]
        assert o.ts_us <= i.ts_us
        assert i.ts_us + i.dur_us <= o.ts_us + o.dur_us + 1e-3

    def test_thread_safety_and_per_thread_stacks(self):
        tr = trace.enable()
        n_threads, n_spans = 8, 200
        # hold every thread at a barrier so all 8 are alive concurrently —
        # CPython recycles thread idents of exited threads, so sequential
        # completion would legitimately collapse the tid mapping
        gate = threading.Barrier(n_threads)

        def work(k):
            gate.wait()
            for i in range(n_spans):
                with trace.span("w", idx=i, thread=k):
                    pass

        threads = [threading.Thread(target=work, args=(k,), name=f"w{k}")
                   for k in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        trace.disable()
        events = tr.events
        assert len(events) == n_threads * n_spans
        # ids unique; every span rooted (no cross-thread parent leakage
        # since each thread's stack is thread-local and spans don't nest)
        assert len({e.id for e in events}) == len(events)
        assert all(e.parent is None for e in events)
        assert len({e.tid for e in events}) == n_threads

    def test_disabled_span_is_noop_and_cheap(self):
        assert not trace.enabled()
        cm = trace.span("anything", layer=3)
        assert cm is trace.span("other")     # shared singleton
        with cm:
            pass
        trace.instant("nothing")             # no tracer: silently dropped

    def test_chrome_trace_schema(self, tmp_path):
        tr = trace.enable()
        with trace.span("a", cat="serve", layer=1):
            with trace.span("b", cat="decode"):
                pass
        trace.instant("mark", cat="resident", layer=2)
        trace.disable()
        path = os.fspath(tmp_path / "t.json")
        n = tr.save(path)
        assert n == 3                        # 2 spans + 1 instant
        with open(path) as f:
            doc = json.load(f)
        events = doc["traceEvents"]
        assert isinstance(events, list)
        phases = {e["ph"] for e in events}
        assert phases <= {"X", "M", "i"}
        for e in events:
            assert {"name", "ph", "pid", "tid"} <= set(e)
            if e["ph"] == "X":
                assert e["dur"] >= 0 and "ts" in e
        names = {e["args"]["name"] for e in events if e["ph"] == "M"
                 and e["name"] == "thread_name"}
        assert names                          # thread metadata present
        xs = [e for e in events if e["ph"] == "X"]
        assert {e["name"] for e in xs} == {"a", "b"}
        assert [e for e in events if e["ph"] == "i"][0]["args"]["layer"] == 2

    def test_event_cap_drops_not_grows(self, monkeypatch):
        monkeypatch.setattr(trace, "MAX_EVENTS", 10)
        tr = trace.enable()
        for i in range(50):
            with trace.span("s", i=i):
                pass
        trace.disable()
        assert len(tr.events) == 10
        assert tr.dropped == 40

    def test_span_tree_renders(self):
        tr = trace.enable()
        with trace.span("outer"):
            with trace.span("inner", layer=7):
                pass
        trace.disable()
        txt = tr.span_tree()
        assert "outer" in txt and "inner" in txt and "layer=7" in txt
        assert txt.index("outer") < txt.index("inner")

    def test_sync_enabled_contract(self):
        assert not trace.sync_enabled()
        trace.enable(sync=False)
        assert not trace.sync_enabled()
        trace.enable(sync=True)
        assert trace.sync_enabled()
        trace.disable()
        assert not trace.sync_enabled()


# ---------------------------------------------------------------------------
# metrics

class TestPercentile:
    def test_matches_numpy(self):
        rng = np.random.default_rng(0)
        for n in (1, 2, 3, 10, 101):
            xs = rng.normal(size=n).tolist()
            for p in (0, 25, 50, 90, 99, 100):
                assert metrics.percentile(xs, p) == pytest.approx(
                    float(np.percentile(xs, p)), abs=1e-9)

    def test_empty_and_bounds(self):
        assert np.isnan(metrics.percentile([], 50))
        with pytest.raises(ValueError):
            metrics.percentile([1.0], 101)
        with pytest.raises(ValueError):
            metrics.percentile([1.0], -1)

    def test_unbiased_vs_old_index_rule(self):
        # the bug this replaced: sorted[int(n*0.99)] clamps to max for small n
        xs = list(range(16))
        old = sorted(xs)[min(len(xs) - 1, int(len(xs) * 0.99))]
        assert old == 15                       # the max, not a p99
        assert metrics.percentile(xs, 99) == pytest.approx(14.85)


class TestP2Quantile:
    def test_accuracy_vs_numpy(self):
        rng = np.random.default_rng(7)
        xs = rng.normal(10.0, 2.0, size=20_000)
        for q in (0.5, 0.9, 0.99):
            est = metrics.P2Quantile(q)
            for x in xs:
                est.observe(float(x))
            exact = float(np.quantile(xs, q))
            spread = float(np.quantile(xs, 0.999) - np.quantile(xs, 0.001))
            assert abs(est.value - exact) / spread < 0.01, (q, est.value, exact)

    def test_exact_small_n(self):
        est = metrics.P2Quantile(0.5)
        for x in (3.0, 1.0, 2.0):
            est.observe(x)
        assert est.value == pytest.approx(2.0)
        assert np.isnan(metrics.P2Quantile(0.5).value)


class TestRegistry:
    def test_counter_gauge_histogram_roundtrip(self):
        r = metrics.Registry()
        r.counter("c").inc(2, mode="x")
        r.counter("c").inc(3, mode="x")
        r.gauge("g").set(1.5)
        for v in (0.1, 0.2, 0.3):
            r.histogram("h").observe(v)
        assert r.counter("c").value(mode="x") == 5
        assert r.gauge("g").value() == 1.5
        assert r.histogram("h").count() == 3
        rows = r.snapshot()
        by = {(row["name"], row["kind"]): row for row in rows}
        assert by[("c", "counter")]["value"] == 5
        assert by[("h", "histogram")]["count"] == 3
        assert "p99" in by[("h", "histogram")]

    def test_counter_rejects_negative_and_kind_drift(self):
        r = metrics.Registry()
        with pytest.raises(ValueError):
            r.counter("c").inc(-1)
        r.counter("dup")
        with pytest.raises(TypeError):
            r.gauge("dup")

    def test_cardinality_guard(self):
        r = metrics.Registry()
        c = r.counter("runaway")
        for i in range(metrics.MAX_LABEL_SETS):
            c.inc(rid=i)
        with pytest.raises(metrics.CardinalityError):
            c.inc(rid=metrics.MAX_LABEL_SETS)

    def test_jsonl_export_strict_json(self, tmp_path):
        r = metrics.Registry()
        r.gauge("g").set(float("nan"))       # must serialize as null
        r.counter("c").inc()
        lc = r.lifecycle(1, outcome="length")
        lc.event("queued", 1.0)
        lc.event("done", 2.0)
        path = os.fspath(tmp_path / "m.jsonl")
        n = r.write_jsonl(path)
        rows = [json.loads(line) for line in open(path)]
        assert len(rows) == n == 3
        kinds = {row["kind"] for row in rows}
        assert kinds == {"gauge", "counter", "lifecycle"}
        g = next(row for row in rows if row["kind"] == "gauge")
        assert g["value"] is None             # NaN -> null
        life = next(row for row in rows if row["kind"] == "lifecycle")
        assert life["events"] == [["queued", 1.0], ["done", 2.0]]

    def test_default_registry_reset_isolates(self):
        metrics.counter("x").inc()
        assert metrics.default_registry().counter("x").total() == 1
        metrics.reset()
        assert metrics.default_registry().counter("x").total() == 0

    def test_legacy_view_freezes_at_construction(self):
        r = metrics.Registry()
        r.gauge("serve.decode_tok_per_s").set(10.0)
        view = metrics.LegacyMetricsView(
            r, {"tok_per_s": "serve.decode_tok_per_s",
                "decode_tok_per_s": "serve.decode_tok_per_s"},
            extra={"decode_backend": "numpy"})
        r.gauge("serve.decode_tok_per_s").set(99.0)   # a later serve
        assert view["tok_per_s"] == view["decode_tok_per_s"] == 10.0
        assert view["decode_backend"] == "numpy"
        assert set(view) == {"tok_per_s", "decode_tok_per_s",
                             "decode_backend"}
        assert view.get("missing") is None


# ---------------------------------------------------------------------------
# analysis (interval arithmetic + overlap report)

class TestAnalysis:
    def test_interval_algebra(self):
        assert analysis.union([(0, 2), (1, 3), (5, 6)]) == [(0, 3), (5, 6)]
        assert analysis.subtract([(0, 10)], [(2, 4), (6, 8)]) == \
            [(0, 2), (4, 6), (8, 10)]
        assert analysis.total([(0, 2), (1, 3)]) == 3
        assert analysis.intersect_total([(0, 5)], [(3, 8)]) == 2

    def test_overlap_report_synthetic(self):
        def span(name, ts, dur, tid=0):
            return dict(name=name, ph="X", ts=ts, dur=dur, pid=1, tid=tid)
        # step [0, 100); wait [40, 60); decode [30, 80) on the worker:
        # busy = [0,40) + [60,100); hidden decode = [30,40)+[60,80) = 30
        events = [span("serve.decode_step", 0, 100),
                  span("resident.consume_wait", 40, 20),
                  span("resident.decode", 30, 50, tid=1)]
        rep = analysis.overlap_report(events)
        assert rep["decode_s"] == pytest.approx(50e-6)
        assert rep["stall_s"] == pytest.approx(20e-6)
        assert rep["overlap_fraction"] == pytest.approx(30 / 50)
        assert rep["n_decode_spans"] == 1

    def test_overlap_report_empty(self):
        rep = analysis.overlap_report([])
        assert np.isnan(rep["overlap_fraction"])
        assert rep["decode_s"] == 0

    def test_load_trace_events_roundtrip(self, tmp_path):
        tr = trace.enable()
        with trace.span("x"):
            pass
        trace.disable()
        p = os.fspath(tmp_path / "t.json")
        tr.save(p)
        events = analysis.load_trace_events(p)
        assert analysis.span_intervals(events, "x")


# ---------------------------------------------------------------------------
# instrumentation points catalog <-> docs

def test_points_catalog_documented():
    """Every span/metric the catalog requires must appear by name in
    docs/OBSERVABILITY.md — the doc IS the user-facing contract."""
    doc = os.path.join(os.path.dirname(__file__), "..", "docs",
                       "OBSERVABILITY.md")
    with open(doc) as f:
        text = f.read()
    missing = [name
               for mode in points.EXPECTED_POINTS.values()
               for group in ("spans", "metrics")
               for name in mode[group]
               if name not in text]
    assert not missing, f"undocumented instrumentation points: {missing}"


# ---------------------------------------------------------------------------
# pure observation: tracing must not change computed tokens

def test_bit_identity_trace_on_vs_off():
    import jax
    from repro.configs import registry
    from repro.models import api
    from repro.serving import engine as serving_engine

    cfg = registry.reduced(registry.get("qwen3-1.7b"))
    params = api.build(cfg).init(cfg, jax.random.PRNGKey(0))
    sc = serving_engine.ServeConfig(max_len=16)
    eng = serving_engine.Engine(cfg, params, sc)
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 8)).astype(np.int32)
    import jax.numpy as jnp
    prompt = jnp.asarray(prompt)

    out_off = np.asarray(eng.generate(prompt, 6))
    tr = trace.enable(sync=True)        # sync fencing must also be pure
    out_on = np.asarray(eng.generate(prompt, 6))
    trace.disable()
    assert np.array_equal(out_off, out_on)
    assert any(e.name == "serve.decode_step" for e in tr.events)
    # and the registry recorded the serve without being asked
    assert metrics.histogram("serve.decode_step_s").count() > 0
    assert metrics.counter("serve.tokens").total() > 0
