"""Compressed-resident serving: per-layer decode in execution order.

Load-bearing properties:

* **Bit-identity** — greedy tokens from the compressed-resident engine
  (weights stay entropy-coded; each layer's QT triples materialize just
  before its matmuls) must equal the dense-resident engine bit for bit, for
  both attention-cache families (dense, moe), through both front ends
  (lockstep ``Engine.generate`` and the continuous-batching scheduler), and
  for mixed 4/8-bit rans+huffman containers.
* **Bounded residency** — peak resident weight bytes (compressed payload +
  decode tables + globals/carve-outs + the double-buffered layer slot pair
  + the int32 decode scratch) stay strictly below the dense bf16 footprint.
* **Plan correctness** — the execution-order plan partitions every stacked
  tensor's symbols exactly into per-layer spans, and the per-layer decode
  reproduces the whole-model loader's stacked QT slices byte for byte.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core.quant import Granularity
from repro.core.scheduler import iter_seg_runs, plan_execution, tensor_segments
from repro.core.spec import CompressionSpec, spec_from_legacy
from repro.core.store import CompressedModel
from repro.models import api
from repro.models.layers import QT, QT4
from repro.serving import engine as serving_engine
from repro.serving.batching import ContinuousEngine
from repro.serving.resident import CompressedResidentWeights

MAX_LEN = 40
SEGMENT = 1024          # segments per layer slice >> 1 (per-layer lanes)
CHUNK = 64 * 1024


def _cfg(family: str):
    if family == "dense":
        return registry.reduced(registry.get("qwen3-1.7b"))
    cfg = registry.reduced(registry.get("qwen2-moe-a2.7b"))
    # small expert FFN keeps the per-layer numpy decode fast on CPU, and a
    # generous capacity_factor keeps GShard token-dropping out of the
    # picture (see moe.prefill_chunk)
    return dataclasses.replace(
        cfg, d_model=64, n_heads=2, n_kv_heads=2, d_ff=64,
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))


def _build(cfg, spec=None):
    params = api.build(cfg).init(cfg, jax.random.PRNGKey(0))
    host = {k: np.asarray(v, np.float32) for k, v in params.items()}
    if spec is None:
        spec = spec_from_legacy(8, Granularity.PER_CHANNEL,
                                segment_symbols=SEGMENT)
    return CompressedModel.compress(host, spec=spec)


@pytest.fixture(scope="module", params=["dense", "moe"])
def harness(request):
    cfg = _cfg(request.param)
    cm = _build(cfg)
    qparams = serving_engine.load_params_from_compressed(cm, quantized=True)
    weights = CompressedResidentWeights(cm, cfg, chunk_symbols=CHUNK)
    sc = serving_engine.ServeConfig(max_len=MAX_LEN)
    return cfg, cm, qparams, weights, sc


def _prompt(cfg, batch, length, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, (batch, length)).astype(np.int32)


# ------------------------------------------------------------- plan level

def test_execution_plan_partitions_symbols(harness):
    cfg, cm, _, weights, _ = harness
    plan = weights.plan
    assert len(plan) == cfg.n_layers
    per_tensor = {n: 0 for n in weights._hosted}
    for steps in plan:
        seen = set()
        for step in steps:
            for sp in step.spans:
                assert sp.tensor not in seen     # one span per tensor/layer
                seen.add(sp.tensor)
                assert sp.count == cm.tensors[sp.tensor].n_symbols \
                    // cfg.n_layers
                assert sp.trim >= 0
                assert sum(s.count for s in sp.segs) >= sp.trim + sp.count
                per_tensor[sp.tensor] += sp.count
        assert seen == set(weights._hosted)
    for n, total in per_tensor.items():
        assert total == cm.tensors[n].n_symbols


def test_iter_seg_runs_respects_budget(harness):
    _, cm, _, weights, _ = harness
    name = weights._hosted[0]
    segs = tensor_segments(cm, name)
    runs = list(iter_seg_runs(segs, 2 * SEGMENT))
    assert [s.index for r in runs for s in r] == [s.index for s in segs]
    for r in runs:
        assert len(r) == 1 or sum(s.count for s in r) <= 2 * SEGMENT
    assert list(iter_seg_runs(segs, None)) == [segs]


def test_layer_slots_match_stacked_loader(harness):
    """The per-layer decode must reproduce the whole-model loader's stacked
    QT slices byte for byte — symbols, scale, zero, and QT4 packing."""
    cfg, _, qparams, weights, _ = harness
    for l in (0, cfg.n_layers - 1):
        slot = weights.get(l)
        for name in weights._hosted:
            short = name.split("/", 1)[1]
            stacked, got = qparams[name], slot[short]
            assert type(got) is type(stacked)
            np.testing.assert_array_equal(np.asarray(got.q),
                                          np.asarray(stacked.q[l]))
            np.testing.assert_array_equal(np.asarray(got.scale),
                                          np.asarray(stacked.scale[l]))
            np.testing.assert_array_equal(np.asarray(got.zero),
                                          np.asarray(stacked.zero[l]))
        for name, w in weights.stacked.items():
            short = name.split("/", 1)[1]
            np.testing.assert_array_equal(np.asarray(slot[short]),
                                          np.asarray(qparams[name][l]))


# ----------------------------------------------------------- engine level

def test_lockstep_greedy_bit_identity(harness):
    cfg, _, qparams, weights, sc = harness
    dense_eng = serving_engine.Engine(cfg, qparams, sc)
    comp_eng = serving_engine.Engine(cfg, weights, sc, resident="compressed")
    prompt = _prompt(cfg, 2, 8)
    ref = np.asarray(dense_eng.generate(prompt, 6))
    out = np.asarray(comp_eng.generate(prompt, 6))
    np.testing.assert_array_equal(ref, out)


def test_continuous_batching_bit_identity(harness):
    cfg, _, qparams, weights, sc = harness
    comp = ContinuousEngine(cfg, weights, sc, n_slots=3, prefill_chunk=8,
                            resident="compressed")
    ref = ContinuousEngine(cfg, qparams, sc, n_slots=3, prefill_chunk=8)
    for eng in (comp, ref):
        for i in range(3):
            eng.submit(_prompt(cfg, 1, 5 + i, seed=i)[0], 5)
        eng.run()
    assert [r.output for r in comp.finished] \
        == [r.output for r in ref.finished]
    assert all(len(r.output) == 5 for r in comp.finished)


def test_peak_resident_bytes_below_dense_bf16(harness):
    """The acceptance invariant: everything the compressed mode keeps
    resident (payload + tables + qmeta + globals + carve-outs + the
    double-buffered slot pair + decode scratch) < the dense bf16 footprint,
    and the accounting is internally consistent."""
    _, _, _, weights, _ = harness
    b = weights.resident_bytes()
    peak = weights.peak_resident_bytes()
    assert peak == (b["payload"] + b["tables"] + b["qmeta"] + b["globals"]
                    + b["stacked"] + b["scratch"] + 2 * b["layer_slot"])
    assert peak < weights.dense_bf16_bytes()
    # and the payload really is the dominant resident term, not the slots
    assert 2 * b["layer_slot"] < weights.dense_resident_bytes()


# ------------------------------------------------------- mixed containers

def test_mixed_rans4_huffman8_bit_identity():
    """A v2 container mixing 4-bit rans (QT4-packed slots) and 8-bit
    huffman tensors serves bit-identically through per-layer decode."""
    cfg = _cfg("dense")
    spec = CompressionSpec.parse(
        f"defaults:segment_symbols={SEGMENT};"
        f"layers/*w_*:bits=4,codec=rans",
        default_granularity=Granularity.PER_CHANNEL)
    cm = _build(cfg, spec=spec)
    assert sorted(cm.tables) == ["huffman8", "rans4"]
    qparams = serving_engine.load_params_from_compressed(cm, quantized=True)
    weights = CompressedResidentWeights(cm, cfg, chunk_symbols=CHUNK)
    slot = weights.get(0)
    kinds = {type(slot[n.split("/", 1)[1]]) for n in weights._hosted}
    assert kinds == {QT, QT4}          # both families host per-layer slots
    sc = serving_engine.ServeConfig(max_len=MAX_LEN)
    dense_eng = serving_engine.Engine(cfg, qparams, sc)
    comp_eng = serving_engine.Engine(cfg, weights, sc, resident="compressed")
    prompt = _prompt(cfg, 1, 7)
    ref = np.asarray(dense_eng.generate(prompt, 5))
    out = np.asarray(comp_eng.generate(prompt, 5))
    np.testing.assert_array_equal(ref, out)
    assert weights.peak_resident_bytes() < weights.dense_bf16_bytes()


# ------------------------------------------------------------- guardrails

def test_resident_mode_guardrails():
    sc = serving_engine.ServeConfig(max_len=MAX_LEN)
    with pytest.raises(ValueError, match="resident"):
        serving_engine.ServeSteps(_cfg("dense"), sc, resident="bogus")
    ssm = registry.reduced(registry.get("mamba2-370m"))
    assert not api.supports_resident_serving(ssm)
    with pytest.raises(NotImplementedError, match="per-layer"):
        serving_engine.ServeSteps(ssm, sc, resident="compressed")


def test_decode_into_preallocated_buffer():
    """The decode-into-buffer entry point: same symbols, caller's buffer."""
    from repro.core.bitstream import decode_streams, pack_streams
    from repro.core.codecs import get_codec
    rng = np.random.default_rng(0)
    sym = rng.integers(0, 256, 4096).astype(np.uint8)
    freqs = np.bincount(sym, minlength=256).astype(np.int64)
    table = get_codec("huffman").build(freqs, 8, max_code_len=12)
    streams, counts = [], []
    for i in range(0, 4096, 1024):
        s, _ = table.encode(sym[i:i + 1024])
        streams.append(s)
        counts.append(1024)
    mat, _ = pack_streams(streams)
    counts = np.asarray(counts, np.int64)
    a = table.decode_arrays()
    ref = decode_streams(mat, counts, a["lut_sym"], a["lut_len"],
                         table.peek_bits)
    buf = np.full((8, 2048), -1, np.int32)      # oversize on purpose
    got = decode_streams(mat, counts, a["lut_sym"], a["lut_len"],
                         table.peek_bits, out=buf)
    np.testing.assert_array_equal(ref, got)
    np.testing.assert_array_equal(buf[:4, :1024], ref)
    assert got.base is buf                      # genuinely in place
    with pytest.raises(ValueError, match="too small"):
        decode_streams(mat, counts, a["lut_sym"], a["lut_len"],
                       table.peek_bits, out=np.zeros((2, 8), np.int32))
    # the device-returning (jax) backend honors the same contract: copies
    # into the caller's buffer, and rejects undersized ones identically
    from repro.core.decode_backends import get_backend
    jb = get_backend("jax")
    buf2 = np.full((8, 2048), -1, np.int32)
    got2 = jb.decode_table(table, mat, counts, out=buf2)
    np.testing.assert_array_equal(ref, got2)
    np.testing.assert_array_equal(buf2[:4, :1024], ref)
    with pytest.raises(ValueError, match="too small"):
        jb.decode_table(table, mat, counts, out=np.zeros((2, 8), np.int32))
