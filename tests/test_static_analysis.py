"""Tests for the repro-lint static-analysis pass (src/repro/analysis/).

Each checker gets a positive fixture (a violation it must flag) and a
negative fixture (compliant code it must stay silent on); the twin checker
additionally gets a *real* perturbation test — a resident twin with one
extra bf16 multiply must produce a divergence finding, which is the
acceptance mechanism for the whole pass (a checker that cannot fail proves
nothing).  The baseline file round-trips and the split logic implements
the empty-delta gate.
"""
import ast
import textwrap

import pytest

from repro.analysis import base
from repro.analysis import catalog as cat
from repro.analysis import dtypes
from repro.analysis import jit_boundary as jb
from repro.analysis import locks
from repro.analysis import twins


# ------------------------------------------------------------ base/baseline

def test_finding_render_and_fingerprint_stability():
    f = base.Finding(file="src/a.py", line=42, rule="dtype-discipline",
                     message="affine in bf16 at row 17", symbol="deq")
    assert f.render() == "src/a.py:42 dtype-discipline affine in bf16 at row 17"
    g = base.Finding(file="src/a.py", line=99, rule="dtype-discipline",
                     message="affine in bf16 at row 23", symbol="deq")
    # fingerprints ignore line numbers and collapse digits: moving code or
    # renumbering rows must not invalidate a reviewed suppression
    assert f.fingerprint() == g.fingerprint()
    h = base.Finding(file="src/b.py", line=42, rule="dtype-discipline",
                     message="affine in bf16 at row 17", symbol="deq")
    assert f.fingerprint() != h.fingerprint()


def test_baseline_roundtrip_and_split(tmp_path):
    f1 = base.Finding(file="a.py", line=1, rule="r", message="one")
    f2 = base.Finding(file="b.py", line=2, rule="r", message="two")
    b = base.Baseline()
    b.absorb([f1])
    path = tmp_path / "baseline.json"
    b.save(path)
    b2 = base.Baseline.load(path)
    assert b2.entries.keys() == b.entries.keys()
    new, accepted, stale = b2.split([f1, f2])
    assert [x.message for x in new] == ["two"]
    assert [x.message for x in accepted] == ["one"]
    assert stale == []
    # stale: baseline entry matching nothing current
    new, accepted, stale = b2.split([f2])
    assert stale == [f1.fingerprint()]


def test_baseline_missing_file_is_empty(tmp_path):
    b = base.Baseline.load(tmp_path / "nope.json")
    assert b.entries == {}


def test_checker_registry_resolves():
    for name in base.CHECKERS:
        assert callable(base.resolve(name))


# -------------------------------------------------------------------- dtype

def test_dtype_checker_flags_bf16_affine():
    src = textwrap.dedent("""
        def deq(q, scale, zero):
            qf = q.astype(jnp.bfloat16)
            return qf * scale + zero
    """)
    got = dtypes.check_source(src, "fix.py")
    assert len(got) == 1 and got[0].rule == "dtype-discipline"
    assert "bfloat16" in got[0].message


def test_dtype_checker_flags_dynamic_dtype_affine():
    src = textwrap.dedent("""
        def deq(q, scale, zero, x):
            dt = x.dtype
            qf = q.astype(dt)
            return qf * scale + zero
    """)
    got = dtypes.check_source(src, "fix.py")
    assert len(got) == 1 and "dynamic" in got[0].message


def test_dtype_checker_silent_on_f32_affine():
    src = textwrap.dedent("""
        def deq(q, scale, zero):
            qf = q.astype(jnp.float32)
            out = qf * scale.astype(jnp.float32) + zero.astype(jnp.float32)
            return out.astype(jnp.bfloat16)   # cast AFTER the affine is fine
    """)
    assert dtypes.check_source(src, "fix.py") == []


def test_dtype_checker_silent_on_unresolvable():
    # unknown factor dtypes are not guessed at — no finding
    src = "def f(a, b, c):\n    return a * b + c\n"
    assert dtypes.check_source(src, "fix.py") == []


# ------------------------------------------------------------- jit boundary

def test_jit_boundary_flags_obs_in_scan_body():
    src = textwrap.dedent("""
        def body(carry, xs):
            obs_metrics.counter("steps").inc()
            return carry, xs

        def run(xs):
            return jax.lax.scan(body, 0, xs)
    """)
    got = jb.check_source(src, "fix.py")
    assert len(got) == 1 and got[0].symbol == "body"
    assert "obs_metrics.counter" in got[0].message


def test_jit_boundary_follows_partial_alias_into_pallas():
    src = textwrap.dedent("""
        def _kern(x_ref, o_ref):
            print("traced!")

        def launch(x):
            kernel = functools.partial(_kern)
            return pl.pallas_call(kernel, out_shape=x)(x)
    """)
    got = jb.check_source(src, "fix.py")
    assert [f.symbol for f in got] == ["_kern"]


def test_jit_boundary_silent_outside_staging():
    src = textwrap.dedent("""
        def host_loop(xs):
            obs_metrics.counter("calls").inc()
            print("fine here")
            return [x + 1 for x in xs]
    """)
    assert jb.check_source(src, "fix.py") == []


def test_jit_boundary_exempts_jax_debug():
    src = textwrap.dedent("""
        @jax.jit
        def f(x):
            jax.debug.print("x={}", x)
            return x + 1
    """)
    assert jb.check_source(src, "fix.py") == []


# -------------------------------------------------------------------- locks

_LOCK_POLICY = locks.LockPolicy(
    lock="_lock", guarded=frozenset({"counter"}),
    single_writer={"solo": "single writer by contract"})


def _lock_findings(src):
    cls = next(n for n in ast.walk(ast.parse(textwrap.dedent(src)))
               if isinstance(n, ast.ClassDef))
    return locks.check_class(cls, _LOCK_POLICY, "fix.py")


def test_lock_checker_flags_unguarded_write():
    got = _lock_findings("""
        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.counter = 0
            def bump(self):
                self.counter += 1
    """)
    assert len(got) == 1 and "outside" in got[0].message
    assert got[0].symbol == "C.bump"


def test_lock_checker_accepts_locked_write_and_single_writer():
    got = _lock_findings("""
        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.counter = 0
                self.solo = []
            def bump(self):
                with self._lock:
                    self.counter += 1
                self.solo.append(1)
    """)
    assert got == []


def test_lock_checker_flags_undeclared_attribute():
    got = _lock_findings("""
        class C:
            def __init__(self):
                self._lock = threading.Lock()
            def sneak(self):
                self.rogue = 1
    """)
    assert len(got) == 1 and "undeclared" in got[0].message


def test_lock_checker_flags_missing_lock():
    got = _lock_findings("""
        class C:
            def __init__(self):
                self.counter = 0
    """)
    assert len(got) == 1 and "never assigned" in got[0].message


def test_lock_checker_mutating_call_counts_as_write():
    got = _lock_findings("""
        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.counter = {}
            def bump(self, k):
                self.counter.update({k: 1})
    """)
    assert len(got) == 1 and got[0].symbol == "C.bump"


def test_lock_policies_match_repo():
    assert locks.check(base.REPO_ROOT) == []


# ------------------------------------------------------------- catalog sync

def test_catalog_collect_emits_and_dynamic_name(tmp_path):
    pkg = tmp_path / "src" / "repro" / "serving"
    pkg.mkdir(parents=True)
    (pkg / "mod.py").write_text(textwrap.dedent("""
        def f(name):
            obs_trace.span("serve.step")
            obs_metrics.counter("queue.shed").inc()
            obs_metrics.counter(name).inc()
    """))
    sites, findings = cat.collect_emits(tmp_path)
    assert ("spans", "serve.step") in sites
    assert ("metrics", "queue.shed") in sites
    assert len(findings) == 1 and "non-literal" in findings[0].message


def test_catalog_sync_clean_on_repo():
    assert cat.check(base.REPO_ROOT) == []


# ---------------------------------------------------------------- twins

@pytest.fixture(scope="module")
def dense_setup():
    import jax
    import jax.numpy as jnp
    from repro.models import dense
    cfg = twins._tiny_cfg("dense")
    params = dense.init(cfg, jax.random.PRNGKey(0))
    lp0 = {k: v[0] for k, v in dense._layer_stack(params).items()}
    cache = dense.init_cache(cfg, 2, 8)
    posv = jnp.zeros((2,), jnp.int32)
    token1 = jnp.zeros((2, 1), jnp.int32)
    x1 = jnp.zeros((2, 1, cfg.d_model), params["embed"].dtype)
    return dense, cfg, params, lp0, cache, posv, token1, x1


def test_twin_pair_clean(dense_setup):
    import jax
    dense, cfg, params, lp0, cache, posv, token1, x1 = dense_setup
    ref = twins.canonical_ops(twins.scan_body(jax.make_jaxpr(
        lambda: dense.decode_step(cfg, params, token1, cache, posv))()))
    twin = twins.canonical_ops(jax.make_jaxpr(
        lambda: dense.resident_block(cfg, lp0, x1, cache, 0, posv))())
    assert ref, "canonicalization must keep float ops"
    assert twins.diff_ops(ref, twin) == ""


def test_twin_perturbation_detected(dense_setup):
    """The acceptance mechanism: a deliberately perturbed twin (one extra
    bf16 multiply on the block output) must yield a divergence finding."""
    import jax
    import jax.numpy as jnp
    dense, cfg, params, lp0, cache, posv, token1, x1 = dense_setup
    ref = twins.canonical_ops(twins.scan_body(jax.make_jaxpr(
        lambda: dense.decode_step(cfg, params, token1, cache, posv))()))

    def perturbed():
        y, c = dense.resident_block(cfg, lp0, x1, cache, 0, posv)
        return y * y.dtype.type(1.0001), c

    twin = twins.canonical_ops(jax.make_jaxpr(perturbed)())
    msg = twins.diff_ops(ref, twin)
    assert msg != ""
    assert "mul" in msg


def test_twin_dropped_op_detected(dense_setup):
    # a twin that *loses* an op diverges too (symmetry of the contract)
    import jax
    dense, cfg, params, lp0, cache, posv, token1, x1 = dense_setup
    ref = twins.canonical_ops(twins.scan_body(jax.make_jaxpr(
        lambda: dense.decode_step(cfg, params, token1, cache, posv))()))
    assert "additionally computes" in twins.diff_ops(ref, ref[:-1])


def test_scan_body_raises_without_scan():
    import jax
    with pytest.raises(ValueError, match="no scan"):
        twins.scan_body(jax.make_jaxpr(lambda x: x + 1.0)(1.0))
