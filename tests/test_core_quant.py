"""Unit + property tests for the EntroLLM mixed quantization scheme (paper Alg. 1)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import quant


def test_scheme_selection_rule():
    # all-positive / all-negative tensors -> symmetric unsigned, mixed-sign -> asymmetric
    assert quant.choose_scheme(np.array([0.1, 2.0])) is quant.Scheme.SYMMETRIC_UNSIGNED
    assert quant.choose_scheme(np.array([-3.0, -0.5])) is quant.Scheme.SYMMETRIC_UNSIGNED
    assert quant.choose_scheme(np.array([0.0, 1.0])) is quant.Scheme.SYMMETRIC_UNSIGNED
    assert quant.choose_scheme(np.array([-1.0, 1.0])) is quant.Scheme.ASYMMETRIC


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("gran", list(quant.Granularity))
def test_roundtrip_error_bound(bits, gran):
    rng = np.random.default_rng(0)
    w = rng.normal(size=(64, 256)).astype(np.float32)
    qt = quant.quantize(w, bits, gran, group=64)
    wd = quant.dequantize(qt)
    # reconstruction error bounded by half a quantization step everywhere
    step = np.abs(np.broadcast_to(qt.scale, (64, 256) if gran is not quant.Granularity.PER_GROUP
                                  else qt.scale.shape))
    err = np.abs(wd - w)
    if gran is quant.Granularity.PER_GROUP:
        errg = err.reshape(64, 256 // 64, 64)
        assert np.all(errg <= 0.5 * np.abs(qt.scale) + 1e-7)
    else:
        assert np.all(err <= 0.5 * step + 1e-7)
    assert qt.q.min() >= 0 and qt.q.max() <= (1 << bits) - 1


def test_symbols_are_unsigned_for_both_schemes():
    rng = np.random.default_rng(1)
    w_pos = np.abs(rng.normal(size=(32, 32))).astype(np.float32)
    w_mix = rng.normal(size=(32, 32)).astype(np.float32)
    for w, scheme in [(w_pos, quant.Scheme.SYMMETRIC_UNSIGNED),
                      (w_mix, quant.Scheme.ASYMMETRIC)]:
        qt = quant.quantize(w, 8)
        assert qt.scheme is scheme
        assert qt.q.dtype == np.uint8


def test_negative_tensor_signed_scale():
    w = -np.abs(np.random.default_rng(2).normal(size=(16, 16))).astype(np.float32)
    qt = quant.quantize(w, 8)
    assert qt.scheme is quant.Scheme.SYMMETRIC_UNSIGNED
    assert qt.scale.item() < 0  # sign carried by the scale
    assert np.allclose(quant.dequantize(qt), w, atol=abs(qt.scale.item()) / 2 + 1e-7)


@settings(max_examples=40, deadline=None)
@given(
    st.integers(2, 8),
    st.integers(0, 2**31 - 1),
    st.sampled_from(["normal", "uniform", "allpos", "allneg", "constant"]),
)
def test_roundtrip_property(bits, seed, kind):
    rng = np.random.default_rng(seed)
    shape = (rng.integers(1, 40), rng.integers(1, 40))
    if kind == "normal":
        w = rng.normal(size=shape)
    elif kind == "uniform":
        w = rng.uniform(-5, 5, size=shape)
    elif kind == "allpos":
        w = np.abs(rng.normal(size=shape)) + 0.1
    elif kind == "allneg":
        w = -np.abs(rng.normal(size=shape)) - 0.1
    else:
        w = np.full(shape, float(rng.normal()))
    w = w.astype(np.float32)
    qt = quant.quantize(w, bits)
    wd = quant.dequantize(qt)
    scale = abs(qt.scale.item())
    assert np.all(np.abs(wd - w) <= 0.5 * scale + 1e-6 + 1e-5 * np.abs(w))


def test_jnp_matches_numpy():
    import jax.numpy as jnp
    rng = np.random.default_rng(3)
    for kind in ["mixed", "pos"]:
        w = rng.normal(size=(48, 48)).astype(np.float32)
        if kind == "pos":
            w = np.abs(w)
        q_np = quant.quantize(w, 8)
        q_j, s_j, z_j = quant.quantize_jnp(jnp.asarray(w), 8)
        assert np.array_equal(np.asarray(q_j), q_np.q)
        assert np.allclose(float(s_j), q_np.scale.item(), rtol=1e-6)
        assert np.allclose(float(z_j), q_np.zero.item(), rtol=1e-6)
