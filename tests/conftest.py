"""Session-wide test environment.

Force 8 host-platform CPU devices BEFORE jax initializes its backend, so the
multi-device suite (``tests/test_sharded_serving.py``) runs under plain
``pytest`` with no special invocation.  The flag only takes effect at first
backend init; conftest imports before any test module, which is early enough.
An operator-provided device count (XLA_FLAGS already naming the option) wins.

Single-device tests are unaffected: jit without shardings still places
everything on device 0, exactly as on a one-device host.

Tier-1 policy knobs (see docs/TESTING.md):

* Hypothesis runs under a **deterministic profile** — ``derandomize=True``
  derives a fixed seed per test, ``deadline=None`` tolerates jit compile
  time, no example database — so property tests are tier-1 citizens: same
  examples every run, no flaky shrink-cache interactions.  Registration is
  guarded; without the dev extra the property tests ``importorskip`` as
  before.
* ``--require-dev-deps`` (CI tier-1) hard-imports hypothesis up front and
  fails the session if any test still skipped for a missing dev
  dependency — property tests can never silently drop out of CI.
  Capability skips (e.g. a decode backend that genuinely cannot run on the
  host) are unaffected.
* ``--rng-repeats N`` fans the ``rng_seed`` fixture out over N distinct
  PRNG seeds (default 1, seed 0 — the historical value).  The serving
  bit-identity suites consume it; CI's flake-audit job runs them 3x.
"""
import os

import pytest

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()


def pytest_addoption(parser):
    parser.addoption(
        "--require-dev-deps", action="store_true", default=False,
        help="fail the session if any test skips because a dev extra "
             "(hypothesis) is missing — tier-1 CI runs with this on")
    parser.addoption(
        "--rng-repeats", type=int, default=1, metavar="N",
        help="run rng_seed-consuming suites N times with distinct PRNG "
             "seeds (seeded-RNG flake audit)")


def pytest_configure(config):
    if config.getoption("--require-dev-deps"):
        try:
            import hypothesis  # noqa: F401
        except ImportError as e:
            raise pytest.UsageError(
                f"--require-dev-deps: {e} — install the dev extra "
                f"(pip install -e '.[dev]')") from e
    try:
        from hypothesis import settings
    except ImportError:
        return
    settings.register_profile(
        "repro-deterministic", derandomize=True, deadline=None,
        database=None, max_examples=25)
    settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "repro-deterministic"))


@pytest.fixture(scope="module")
def rng_seed(request):
    """PRNG seed for seeded-RNG suites; ``--rng-repeats N`` fans it out."""
    return getattr(request, "param", 0)


def pytest_generate_tests(metafunc):
    if "rng_seed" in metafunc.fixturenames:
        n = max(1, metafunc.config.getoption("--rng-repeats"))
        metafunc.parametrize("rng_seed", range(n), indirect=True,
                             scope="module")


_DEV_DEP_MARKERS = ("could not import", "dev extra")


def pytest_sessionfinish(session, exitstatus):
    """With ``--require-dev-deps``, turn dev-dependency skips into a
    session failure (the skip reason of ``importorskip`` names the missing
    import; capability skips use different wording and stay skips)."""
    if not session.config.getoption("--require-dev-deps"):
        return
    tr = session.config.pluginmanager.get_plugin("terminalreporter")
    bad = []
    for rep in (tr.stats.get("skipped", []) if tr else []):
        reason = str(getattr(rep, "longrepr", ""))
        if any(m in reason for m in _DEV_DEP_MARKERS):
            bad.append(f"{rep.nodeid}: {reason.splitlines()[-1]}")
    if bad and session.exitstatus == 0:
        for line in bad:
            tr.write_line(f"--require-dev-deps: {line}", red=True)
        tr.write_line(
            f"--require-dev-deps: {len(bad)} test(s) skipped for a missing "
            f"dev dependency — failing the session", red=True)
        session.exitstatus = 1
