"""Session-wide test environment.

Force 8 host-platform CPU devices BEFORE jax initializes its backend, so the
multi-device suite (``tests/test_sharded_serving.py``) runs under plain
``pytest`` with no special invocation.  The flag only takes effect at first
backend init; conftest imports before any test module, which is early enough.
An operator-provided device count (XLA_FLAGS already naming the option) wins.

Single-device tests are unaffected: jit without shardings still places
everything on device 0, exactly as on a one-device host.
"""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=8").strip()
