"""Paged KV cache: block pool, prefix sharing, cold tier, engine parity.

The load-bearing property mirrors the slot pool's batch invariance
(docs/KV_CACHE.md): with DENSE blocks the paged engine must be
bit-identical to the PR 2 slot pool — the block table is pure routing —
and with QUANTIZED blocks the drift against the dense reference must stay
bounded and deterministic.  The host-side ``BlockKVManager`` bookkeeping
(prefix chain, refcounts, LRU + cold tier) is exercised directly, including
the compaction edge cases the slot pool shares: release-all-then-reinsert,
ragged ``kv_len`` after a neighbor's release, and the double-release guard.

Bit-identity needs ``max_len % block_size == 0`` (identical attention
reduction shapes) and sharing needs ``prefill_chunk % block_size == 0`` —
both hold here by construction (BS=8 divides MAX_LEN=48 and CHUNK=8).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.spec import KVCompressionSpec
from repro.models import api
from repro.serving import engine as serving_engine
from repro.serving.batching import (ContinuousEngine, Request,
                                    SlotBatchManager)
from repro.serving.kvcache import (BlockKVManager, ColdBlockStore,
                                   kv_cache_bytes, kv_pool_bytes)

MAX_LEN = 48
BS = 8          # block size; divides MAX_LEN and CHUNK
CHUNK = 8


def _cfg():
    return registry.reduced(registry.get("qwen3-1.7b"))


@pytest.fixture(scope="module")
def harness():
    cfg = _cfg()
    params = api.build(cfg).init(cfg, jax.random.PRNGKey(0))
    sc = serving_engine.ServeConfig(max_len=MAX_LEN)
    eng = serving_engine.Engine(cfg, params, sc)
    return cfg, params, sc, eng


def _tok(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, (n,)).astype(np.int32)


def _req(prompt, gen=4):
    return Request(prompt=np.asarray(prompt, np.int32), max_new_tokens=gen)


def _block_leaves(pool, blk):
    """Host snapshot of one pool block across every leaf."""
    return {k: np.asarray(v[:, blk]) for k, v in pool.items()}


# -------------------------------------------------------------------- policy

def test_kv_spec_parse_roundtrip():
    spec = KVCompressionSpec.parse("bits=4,block=16,codec=rans,sharing")
    assert (spec.bits, spec.block_size, spec.codec, spec.sharing) == \
        (4, 16, "rans", True)
    assert KVCompressionSpec.parse(spec.describe()) == spec
    with pytest.raises(ValueError, match="bits"):
        KVCompressionSpec(bits=5).validate()
    with pytest.raises(ValueError, match="codec"):
        # entropy-coding bf16 blocks needs a sub-16-bit symbol alphabet
        KVCompressionSpec(bits=16, codec="rans").validate()


def test_supports_paged_kv_gates_families():
    assert api.supports_paged_kv(_cfg())
    assert api.supports_paged_kv(
        registry.reduced(registry.get("qwen2-moe-a2.7b")))
    assert not api.supports_paged_kv(
        registry.reduced(registry.get("mamba2-370m")))


def test_pool_sizing_helpers():
    cfg = _cfg()
    dense = kv_pool_bytes(cfg, 8, BS, 16)
    q4 = kv_pool_bytes(cfg, 8, BS, 4)
    assert dense > 0 and q4 > 0
    # int4 + bf16 scale/zero per (token, head) must beat bf16 blocks
    assert q4 < dense / 2
    # default-capacity dense pool == slot cache bytes + one trash block
    m = BlockKVManager(cfg, n_slots=2, max_len=MAX_LEN,
                       spec=KVCompressionSpec(block_size=BS))
    assert m.pool_bytes == (kv_cache_bytes(cfg, 2, MAX_LEN)
                            + kv_pool_bytes(cfg, 1, BS, 16))


def test_manager_rejects_bad_geometry():
    cfg = _cfg()
    with pytest.raises(ValueError, match="chunk"):
        BlockKVManager(cfg, 1, MAX_LEN, prefill_chunk=6,
                       spec=KVCompressionSpec(block_size=BS, sharing=True))
    with pytest.raises(ValueError, match="n_blocks"):
        BlockKVManager(cfg, 1, MAX_LEN, n_blocks=3,
                       spec=KVCompressionSpec(block_size=BS))


# ------------------------------------------------------------- block manager

def test_block_manager_lifecycle_and_trash_block():
    cfg = _cfg()
    m = BlockKVManager(cfg, n_slots=2, max_len=MAX_LEN,
                       spec=KVCompressionSpec(block_size=BS),
                       prefill_chunk=CHUNK)
    got = m.alloc(_req(_tok(cfg, 12, 0), gen=4))
    assert got is not None
    slot, skip = got
    assert slot == 0 and skip == 0           # sharing off: never skips
    row = m.table_rows([slot])[0]
    nb = -(-16 // BS)                        # ceil((12 + 4) / BS)
    assert all(b != 0 for b in row[:nb])     # block 0 is never allocated
    assert all(b == 0 for b in row[nb:])     # tail stays trash
    m.insert(slot, 12)
    assert m.kv_len[slot] == 12 and m.active == [slot]
    req = m.release(slot)
    assert req is not None and m.active == [] and m.n_free == 2
    assert m.n_free_blocks == m.n_blocks - 1      # everything but trash
    assert not m.table_rows([slot]).any()


def test_decode_tables_masks_nonlive_lanes():
    cfg = _cfg()
    m = BlockKVManager(cfg, n_slots=2, max_len=MAX_LEN,
                       spec=KVCompressionSpec(block_size=BS),
                       prefill_chunk=CHUNK)
    s0, _ = m.alloc(_req(_tok(cfg, 9, 1)))
    m.insert(s0, 9)
    s1, _ = m.alloc(_req(_tok(cfg, 9, 2)))   # allocated but NOT live yet
    dt = m.decode_tables()
    assert dt[s0].any()                      # live lane routes to its blocks
    assert not dt[s1].any()                  # prefilling lane is all-trash
    assert m.table_rows([s1]).any()          # ...but the prefill view isn't
    m.insert(s1, 9)
    assert m.decode_tables()[s1].any()


def test_prefix_sharing_hits_and_refcounts():
    cfg = _cfg()
    m = BlockKVManager(cfg, n_slots=3, max_len=MAX_LEN,
                       spec=KVCompressionSpec(block_size=BS, sharing=True),
                       prefill_chunk=CHUNK)
    prefix = _tok(cfg, 2 * BS, 3)
    a = _req(np.concatenate([prefix, _tok(cfg, 4, 4)]), gen=4)
    b = _req(np.concatenate([prefix, _tok(cfg, 6, 5)]), gen=4)
    s0, skip0 = m.alloc(a)
    assert skip0 == 0 and m.shared_hits == 0
    m.insert(s0, a.prompt_len)               # publishes the 2 full blocks
    s1, skip1 = m.alloc(b)
    # both full prefix blocks hit; skip = 2 blocks' worth of whole chunks
    assert m.shared_hits == 2 and skip1 == 2 * BS
    assert (m.table_rows([s0])[0][:2] == m.table_rows([s1])[0][:2]).all()
    m.insert(s1, b.prompt_len)
    # shared blocks survive the publisher's release while b still holds them
    m.release(s0)
    assert m.stats()["prefix_hit_rate"] > 0
    s2, skip2 = m.alloc(_req(np.concatenate([prefix, _tok(cfg, 4, 6)])))
    assert skip2 == 2 * BS                   # chain outlives the publisher
    m.release(s1)
    m.release(s2)


def test_eviction_never_reclaims_planned_hit():
    """A planned resident hit at refcount 0 sits on the LRU; the admission
    eviction loop must pin it first, not reclaim it (regression: evicting
    the hit crashed the refcount bump and corrupted the chain)."""
    cfg = _cfg()
    m = BlockKVManager(cfg, n_slots=2, max_len=MAX_LEN, n_blocks=7,
                       spec=KVCompressionSpec(block_size=BS, sharing=True),
                       prefill_chunk=CHUNK)
    pa = _tok(cfg, BS, 7)
    for prompt in [pa, _tok(cfg, BS, 8)]:
        s, _ = m.alloc(_req(prompt, gen=BS))
        m.insert(s, BS)
        m.release(s)
    # LRU is now [A0, B0] with A0 oldest; a 40-token request hitting A0
    # needs 5 fresh blocks with only 4 free -> one eviction must pick B0
    a_blk = int(m._chain[m._chain_keys(pa)[0]])
    before = _block_leaves(m.pool, a_blk)
    big = _req(np.concatenate([pa, _tok(cfg, 32, 9)]), gen=8)
    assert m.can_admit(big)
    s, skip = m.alloc(big)
    assert skip == BS and m.dropped_evictions == 1
    assert int(m.table_rows([s])[0][0]) == a_blk
    after = _block_leaves(m.pool, a_blk)
    for k in before:
        np.testing.assert_array_equal(before[k], after[k])
    m.insert(s, big.prompt_len)
    m.release(s)


def test_cold_tier_evict_restore_roundtrip():
    """Evicted shared blocks entropy-code to host bytes and restore
    bit-exactly on the next prefix hit (quantized leaves are uint8, so the
    codec roundtrip is lossless)."""
    cfg = _cfg()
    spec = KVCompressionSpec(bits=8, block_size=BS, codec="rans",
                             sharing=True)
    m = BlockKVManager(cfg, n_slots=2, max_len=MAX_LEN, n_blocks=7,
                       spec=spec, prefill_chunk=CHUNK)
    pa = _tok(cfg, 2 * BS, 10)
    sa, _ = m.alloc(_req(pa, gen=8))
    # fake a prefill: stamp recognizable data into A's two prompt blocks
    row = m.table_rows([sa])[0]
    pool = {k: np.array(v) for k, v in m.pool.items()}
    rng = np.random.default_rng(0)
    for j in range(2):
        for k in pool:
            leaf = pool[k]
            stamp = rng.integers(0, 255, leaf[:, row[j]].shape)
            leaf[:, row[j]] = stamp.astype(leaf.dtype)
    m.pool = {k: jnp.asarray(v) for k, v in pool.items()}
    originals = [_block_leaves(m.pool, int(row[j])) for j in range(2)]
    m.insert(sa, len(pa))
    m.release(sa)
    # a 40-token stranger needs 6 blocks with 4 free -> evicts A0+A1 to cold
    sb, _ = m.alloc(_req(_tok(cfg, 5 * BS, 11), gen=8))
    assert m.cold_evictions == 2 and len(m.cold) == 2 and m.cold_bytes > 0
    m.insert(sb, 5 * BS)
    m.release(sb)
    # readmitting A walks the chain into the cold tier and decodes back
    sa2, skip = m.alloc(_req(pa, gen=8))
    assert m.cold_restores == 2
    assert skip == BS                        # final chunk always re-runs
    row2 = m.table_rows([sa2])[0]
    for j in range(2):
        restored = _block_leaves(m.pool, int(row2[j]))
        for k in restored:
            np.testing.assert_array_equal(restored[k], originals[j][k])


def test_cold_store_entropy_codes_uint8_leaves():
    store = ColdBlockStore("rans")
    rng = np.random.default_rng(0)
    # skewed symbols compress; bf16-viewed scale leaves ride along raw
    leaves = {
        "k": rng.choice(8, size=(2, 16, 2, 4)).astype(np.uint8),
        "k_scale": rng.normal(size=(2, 16, 2, 1)).astype(np.float32),
    }
    store.put("key", leaves)
    assert "key" in store and store.effective_bits < 8.0
    got = store.pop("key")
    assert "key" not in store and len(store) == 0
    np.testing.assert_array_equal(got["k"], leaves["k"])
    np.testing.assert_array_equal(got["k_scale"], leaves["k_scale"])


# ------------------------------------------- compaction edge cases (both
# managers: the slot pool and its paged successor share the lifecycle)

def test_slot_manager_release_all_then_reinsert():
    cfg = _cfg()
    mod = api.build(cfg)
    m = SlotBatchManager(cfg, n_slots=2, max_len=16)
    slots = [m.alloc(_req(np.ones(4, np.int32))) for _ in range(2)]
    rc = jax.tree.map(lambda c: jnp.ones_like(c[:, :1]),
                      mod.init_cache(cfg, 2, 16))
    for s in slots:
        m.insert(s, rc, kv_len=4)
    for s in slots:
        m.release(s)
    assert m.n_free == 2 and not m.kv_len.any()
    # the pool is fully compacted and immediately reusable
    assert all(float(jnp.abs(leaf).sum()) == 0.0
               for leaf in jax.tree.leaves(m.cache))
    s = m.alloc(_req(np.ones(4, np.int32)))
    m.insert(s, rc, kv_len=7)
    assert m.kv_len[s] == 7 and m.active == [s]


def test_block_manager_release_all_then_reinsert():
    cfg = _cfg()
    m = BlockKVManager(cfg, n_slots=2, max_len=MAX_LEN,
                       spec=KVCompressionSpec(block_size=BS, sharing=True),
                       prefill_chunk=CHUNK)
    prompts = [_tok(cfg, 12, s) for s in (20, 21)]
    slots = [m.alloc(_req(p))[0] for p in prompts]
    for s, p in zip(slots, prompts):
        m.insert(s, len(p))
    for s in slots:
        m.release(s)
    assert m.n_free == 2 and not m.kv_len.any() and not m.tables.any()
    # published blocks linger on the LRU (refcount 0 != free) ...
    assert m.n_free_blocks < m.n_blocks - 1 and len(m._lru) == 2
    # ... and a full reinsert cycle still works on the drained pool
    s, skip = m.alloc(_req(prompts[0]))
    assert skip == BS                        # the chain survived release-all
    m.insert(s, 12)
    assert m.kv_len[s] == 12 and m.active == [s]


def test_slot_manager_ragged_kv_len_survives_neighbor_compaction():
    cfg = _cfg()
    mod = api.build(cfg)
    m = SlotBatchManager(cfg, n_slots=3, max_len=16)
    rc = jax.tree.map(lambda c: jnp.ones_like(c[:, :1]),
                      mod.init_cache(cfg, 3, 16))
    lens = [4, 9, 13]
    slots = [m.alloc(_req(np.ones(4, np.int32))) for _ in lens]
    for s, L in zip(slots, lens):
        m.insert(s, rc, kv_len=L)
    m.release(slots[1])                      # compact the middle lane
    assert m.kv_len.tolist() == [4, 0, 13]   # neighbors' lens untouched
    assert float(jnp.abs(m.cache["k"][:, slots[1]]).sum()) == 0.0
    assert float(jnp.abs(m.cache["k"][:, slots[0]]).sum()) > 0.0
    s = m.alloc(_req(np.ones(4, np.int32)))  # freed slot comes back ...
    assert s == slots[1] and m.kv_len[s] == 0    # ... with kv_len reset


def test_block_manager_ragged_kv_len_survives_neighbor_compaction():
    cfg = _cfg()
    m = BlockKVManager(cfg, n_slots=3, max_len=MAX_LEN,
                       spec=KVCompressionSpec(block_size=BS),
                       prefill_chunk=CHUNK)
    lens = [4, 9, 13]
    slots = [m.alloc(_req(_tok(cfg, L, 30 + L)))[0] for L in lens]
    for s, L in zip(slots, lens):
        m.insert(s, L)
    freed = set(m.table_rows([slots[1]])[0]) - {0}
    m.release(slots[1])
    assert m.kv_len.tolist() == [4, 0, 13]
    assert freed <= set(m._free_blocks)      # blocks compacted + reclaimed
    assert m.table_rows([slots[0]]).any() and m.table_rows([slots[2]]).any()
    s, _ = m.alloc(_req(_tok(cfg, 5, 40)))
    assert s == slots[1] and m.kv_len[s] == 0


def test_double_release_guard_both_managers():
    cfg = _cfg()
    sm = SlotBatchManager(cfg, n_slots=1, max_len=16)
    s = sm.alloc(_req(np.ones(2, np.int32)))
    sm.release(s)
    with pytest.raises(AssertionError, match="free slot"):
        sm.release(s)
    bm = BlockKVManager(cfg, n_slots=1, max_len=MAX_LEN,
                        spec=KVCompressionSpec(block_size=BS),
                        prefill_chunk=CHUNK)
    s, _ = bm.alloc(_req(_tok(cfg, 4, 50)))
    bm.insert(s, 4)
    with pytest.raises(AssertionError, match="double insert"):
        bm.insert(s, 4)
    bm.release(s)
    with pytest.raises(AssertionError, match="free slot"):
        bm.release(s)


# ------------------------------------------------------------- engine parity

def _jobs(cfg, seed=0):
    """Six requests over two shared 2-block system prompts + ragged tails."""
    rng = np.random.default_rng(seed)
    prefixes = [_tok(cfg, 2 * BS, 100 + i) for i in range(2)]
    jobs = []
    for i, tail in enumerate([5, 9, 2, 7, 11, 3]):
        p = np.concatenate([prefixes[i % 2], _tok(cfg, tail, 200 + i)])
        jobs.append((p, int(rng.integers(3, 7))))
    return jobs


def test_paged_dense_engine_bit_identical_to_slot_pool(harness):
    """Dense blocks + prefix sharing through the FULL scheduler must equal
    the slot-pool engine token for token — the block table is pure routing
    and a shared prefix's K/V rows are bit-identical to recomputing them."""
    cfg, params, sc, eng = harness
    jobs = _jobs(cfg)
    ref = ContinuousEngine(cfg, params, sc, n_slots=3, prefill_chunk=CHUNK,
                           steps=eng.steps)
    rids = [ref.submit(p, g).rid for p, g in jobs]
    want = {r.rid: r.output for r in ref.run()}
    spec = KVCompressionSpec(bits=16, block_size=BS, sharing=True)
    ce = ContinuousEngine(cfg, params, sc, n_slots=3, prefill_chunk=CHUNK,
                          steps=eng.steps, kv_spec=spec)
    prids = [ce.submit(p, g).rid for p, g in jobs]
    got = {r.rid: r.output for r in ce.run()}
    assert [got[r] for r in prids] == [want[r] for r in rids]
    st = ce.slots.stats()
    assert st["shared_hits"] > 0             # the sharing actually engaged
    assert st["blocks_free"] >= 0 and st["pool_bytes"] > 0


def test_paged_quantized_engine_bounded_deterministic_drift(harness):
    """Quantized blocks trade exactness for capacity: outputs keep their
    lengths, drift vs the dense reference stays bounded, and two identical
    runs are bit-identical (the drift is deterministic, not noise)."""
    cfg, params, sc, eng = harness
    jobs = _jobs(cfg)

    def run(spec):
        ce = ContinuousEngine(cfg, params, sc, n_slots=3,
                              prefill_chunk=CHUNK, steps=eng.steps,
                              kv_spec=spec)
        rids = [ce.submit(p, g).rid for p, g in jobs]
        fin = {r.rid: r for r in ce.run()}
        return [fin[r].output for r in rids]

    ref = run(KVCompressionSpec(bits=16, block_size=BS, sharing=True))
    spec = KVCompressionSpec(bits=4, block_size=BS, codec="rans",
                             sharing=True)
    q1, q2 = run(spec), run(spec)
    assert q1 == q2                          # deterministic
    assert [len(o) for o in q1] == [len(o) for o in ref]
    toks = sum(len(o) for o in ref)
    diverged = sum(t != r for o, ro in zip(q1, ref)
                   for t, r in zip(o, ro))
    assert diverged / toks <= 0.6, f"int4 KV drift {diverged}/{toks}"


def test_paged_moe_engine_matches_slot_pool():
    """The MoE family rides the same paged step plumbing (one small run)."""
    cfg = registry.reduced(registry.get("qwen2-moe-a2.7b"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = api.build(cfg).init(cfg, jax.random.PRNGKey(0))
    sc = serving_engine.ServeConfig(max_len=MAX_LEN)
    jobs = [(_tok(cfg, 11, 60), 4), (_tok(cfg, 7, 61), 3)]
    ref = ContinuousEngine(cfg, params, sc, n_slots=2, prefill_chunk=CHUNK)
    reqs = [ref.submit(p, g) for p, g in jobs]
    ref.run()
    want = [r.output for r in reqs]
    ce = ContinuousEngine(cfg, params, sc, n_slots=2, prefill_chunk=CHUNK,
                          kv_spec=KVCompressionSpec(block_size=BS))
    reqs = [ce.submit(p, g) for p, g in jobs]
    ce.run()
    assert [r.output for r in reqs] == want
