"""``resolve_granularity`` fallback paths: warn, name the tensor, and keep
quantizing within the same error envelope as the aligned case.

The paper's PER_GROUP extension groups along the last axis; real
checkpoints have ragged last dims (GQA head counts, odd vocab pads), so
the fallback from a non-dividing group to per-channel must be a quality
downgrade measured in scale granularity — never a crash, and never a
silent accuracy cliff.
"""
import numpy as np
import pytest

from repro.core import quant
from repro.core.quant import Granularity
from repro.core.spec import CompressionSpec
from repro.core.store import CompressedModel


def _roundtrip_err(w, qt):
    return np.abs(quant.dequantize(qt) - w)


def test_ragged_group_falls_back_to_per_channel_with_warning():
    rng = np.random.default_rng(0)
    w = rng.normal(0, 0.05, (8, 50)).astype(np.float32)
    with pytest.warns(UserWarning, match="does not divide"):
        qt = quant.quantize(w, 8, Granularity.PER_GROUP, group=16)
    assert qt.granularity is Granularity.PER_CHANNEL
    assert qt.scale.shape == (8, 1)
    # the fallback QT still round-trips within half a quantization step
    # elementwise — the dequantize contract, independent of granularity
    assert (_roundtrip_err(w, qt)
            <= np.abs(qt.scale) / 2 + 1e-7).all()


def test_fallback_tolerance_matches_aligned_case():
    """Same distribution, aligned vs ragged last dim: the ragged tensor's
    fallback (per-channel) error stays within 2x of the aligned per-group
    error — a bounded granularity downgrade, not an accuracy cliff."""
    rng = np.random.default_rng(1)
    aligned = rng.normal(0, 0.05, (8, 48)).astype(np.float32)
    ragged = rng.normal(0, 0.05, (8, 50)).astype(np.float32)
    qt_a = quant.quantize(aligned, 8, Granularity.PER_GROUP, group=16)
    assert qt_a.granularity is Granularity.PER_GROUP
    with pytest.warns(UserWarning):
        qt_r = quant.quantize(ragged, 8, Granularity.PER_GROUP, group=16)
    err_a = float(_roundtrip_err(aligned, qt_a).mean())
    err_r = float(_roundtrip_err(ragged, qt_r).mean())
    assert err_r <= 2.0 * err_a + 1e-7
    # and both satisfy the elementwise half-step bound of their own scales
    sr = np.abs(qt_r.scale)
    assert (_roundtrip_err(ragged, qt_r) <= sr / 2 + 1e-7).all()


def test_warning_names_the_tensor():
    w = np.ones((4, 10), np.float32)
    with pytest.warns(UserWarning, match=r"layers/w_up: PER_GROUP group=16"):
        quant.quantize(w, 8, Granularity.PER_GROUP, group=16,
                       name="layers/w_up")
    # and stays anonymous when no name is threaded
    with pytest.warns(UserWarning) as rec:
        quant.quantize(w, 8, Granularity.PER_GROUP, group=16)
    assert not str(rec[0].message).startswith("layers/")


def test_scalar_and_vector_fallbacks():
    with pytest.warns(UserWarning, match="0-D tensor has no axis"):
        g = quant.resolve_granularity(np.float32(3.0).reshape(()),
                                      Granularity.PER_GROUP, 16)
    assert g is Granularity.PER_TENSOR
    with pytest.warns(UserWarning, match="falling back to per_tensor"):
        g = quant.resolve_granularity(np.ones(10, np.float32),
                                      Granularity.PER_GROUP, 16)
    assert g is Granularity.PER_TENSOR
    with pytest.warns(UserWarning, match="per-element scales"):
        g = quant.resolve_granularity(np.ones(10, np.float32),
                                      Granularity.PER_CHANNEL, 16)
    assert g is Granularity.PER_TENSOR
    with pytest.raises(ValueError, match="group >= 1"):
        quant.resolve_granularity(np.ones((4, 8), np.float32),
                                  Granularity.PER_GROUP, 0)


def test_container_round_trip_through_fallback():
    """A container compressed under a ragged PER_GROUP spec stores the
    fallback QT; decompression equals quantize→dequantize directly."""
    rng = np.random.default_rng(2)
    host = {"layers/w_a": rng.normal(0, 0.05, (2, 64, 50))
            .astype(np.float32)}
    with pytest.warns(UserWarning, match=r"layers/w_a: PER_GROUP group=16"):
        cm = CompressedModel.compress(host, spec=CompressionSpec(
            default_bits=8, default_granularity=Granularity.PER_GROUP,
            default_group=16, segment_symbols=1024))
    with pytest.warns(UserWarning):
        qt = quant.quantize(host["layers/w_a"], 8, Granularity.PER_GROUP,
                            group=16)
    back = cm.dequantize_all()
    np.testing.assert_allclose(back["layers/w_a"], quant.dequantize(qt),
                               rtol=0, atol=0)
