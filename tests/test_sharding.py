"""Sharding-rule resolution logic (pure; no multi-device requirement)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


@pytest.fixture(scope="module")
def mesh():
    # single-device 1x1 mesh: resolve_spec only reads axis NAMES and SIZES,
    # so divisibility is exercised with a fake-shape wrapper below
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


class FakeMesh:
    """Duck-typed mesh exposing .shape only (resolve_spec needs nothing else)."""

    def __init__(self, **shape):
        self.shape = shape


def test_resolve_basic():
    m = FakeMesh(data=16, model=16)
    rules = shd.Rules({"embed": "data", "heads": "model"})
    spec = shd.resolve_spec(("embed", "heads"), (4096, 8192), rules, m)
    assert spec == P("data", "model")


def test_resolve_divisibility_drops():
    m = FakeMesh(data=16, model=16)
    rules = shd.Rules({"kv": "model"})
    # 2 KV heads cannot shard 16 ways -> replicated
    assert shd.resolve_spec(("kv",), (2,), rules, m) == P()
    assert shd.resolve_spec(("kv",), (32,), rules, m) == P("model")


def test_resolve_tuple_axes_shorten():
    m = FakeMesh(pod=2, data=16, model=16)
    rules = shd.Rules({"batch": ("pod", "data")})
    # 32 divides pod*data -> both; 16 only divides pod... (2) -> shortened
    assert shd.resolve_spec(("batch",), (32,), rules, m) == P(("pod", "data"))
    assert shd.resolve_spec(("batch",), (16,), rules, m) == P("pod")
    assert shd.resolve_spec(("batch",), (3,), rules, m) == P()


def test_resolve_no_duplicate_mesh_axes():
    m = FakeMesh(data=4, model=4)
    rules = shd.Rules({"a": "model", "b": "model"})
    spec = shd.resolve_spec(("a", "b"), (8, 8), rules, m)
    assert spec == P("model")       # second claim dropped, trailing None trimmed


def test_resolve_skips_missing_axes():
    m = FakeMesh(data=4)            # no "model" on this mesh
    rules = shd.Rules({"heads": "model", "embed": "data"})
    assert shd.resolve_spec(("heads", "embed"), (8, 8), rules, m) == \
        P(None, "data")


def test_train_rules_profile(mesh):
    rules = shd.train_rules(mesh)
    assert rules.lookup("vocab") == ("model",)
    assert rules.lookup("embed") == ("data",)
    assert rules.lookup(None) == ()


def test_param_shardings_cover_every_tensor(mesh):
    from repro.configs import registry
    cfg = registry.reduced(registry.get("glm4-9b"))
    rules = shd.train_rules(mesh)
    shards = shd.param_shardings(cfg, mesh, rules)
    from repro.models import api
    assert set(shards) == set(api.build(cfg).schema(cfg))


def test_cell_builders_construct_for_host_mesh(mesh):
    """build_cell on the 1x1 host mesh: structure + shardings line up (the
    production-mesh versions are exercised by the dry-run)."""
    from repro.configs import registry
    from repro.configs.base import SHAPES
    from repro.launch import specs
    cfg = registry.reduced(registry.get("qwen3-1.7b"))
    shape = SHAPES["train_4k"]
    try:
        cell = specs.build_train_cell(cfg, shape, mesh, microbatches=1)
        assert set(cell.args[0]) == set(cell.in_shardings[0])
    finally:
        specs.clear_contexts()


def test_quantized_param_structs_match_schema():
    from repro.configs import registry
    from repro.launch import specs
    m = jax.make_mesh((1, 1), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = registry.get("qwen3-1.7b")
    rules = shd.serve_rules(m)
    for fmt in ("bf16", "int8", "int4"):
        structs, shards = specs.param_structs(cfg, m, rules, fmt)
        assert set(structs) == set(shards)
        if fmt == "int4":
            from repro.models.layers import QT4
            big = [v for v in structs.values() if isinstance(v, QT4)]
            assert big, "int4 format must quantize the big matrices"
            for qt in big:
                assert qt.q.dtype.name == "uint8"
