"""Sharding-rule resolution logic (pure; no multi-device requirement)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_lib


@pytest.fixture(scope="module")
def mesh():
    # single-device 1x1 mesh: resolve_spec only reads axis NAMES and SIZES,
    # so divisibility is exercised with a fake-shape wrapper below.
    # mesh_lib.make_mesh is the jax-version compat shim (AxisType on new jax,
    # positional fallback on 0.4.x).
    return mesh_lib.make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Duck-typed mesh exposing .shape only (resolve_spec needs nothing else)."""

    def __init__(self, **shape):
        self.shape = shape


def test_resolve_basic():
    m = FakeMesh(data=16, model=16)
    rules = shd.Rules({"embed": "data", "heads": "model"})
    spec = shd.resolve_spec(("embed", "heads"), (4096, 8192), rules, m)
    assert spec == P("data", "model")


def test_resolve_divisibility_drops():
    m = FakeMesh(data=16, model=16)
    rules = shd.Rules({"kv": "model"})
    # 2 KV heads cannot shard 16 ways -> replicated
    assert shd.resolve_spec(("kv",), (2,), rules, m) == P()
    assert shd.resolve_spec(("kv",), (32,), rules, m) == P("model")


def test_resolve_tuple_axes_shorten():
    m = FakeMesh(pod=2, data=16, model=16)
    rules = shd.Rules({"batch": ("pod", "data")})
    # 32 divides pod*data -> both; 16 only divides pod... (2) -> shortened
    assert shd.resolve_spec(("batch",), (32,), rules, m) == P(("pod", "data"))
    assert shd.resolve_spec(("batch",), (16,), rules, m) == P("pod")
    assert shd.resolve_spec(("batch",), (3,), rules, m) == P()


def test_resolve_tuple_prefix_rechecked_against_used():
    """Regression: a (pod, data) batch rule colliding with an embed rule.

    The batch tuple is shortened from the right; whatever prefix survives
    must be re-checked against the axes other dims already claimed — in
    either dim order the resolved spec may never duplicate a mesh axis."""
    m = FakeMesh(pod=2, data=4, model=4)
    rules = shd.Rules({"batch": ("pod", "data"), "embed": "data"})
    # batch first: 2 % (pod*data)=8 fails -> prefix ("pod",); embed takes data
    assert shd.resolve_spec(("batch", "embed"), (2, 8), rules, m) == \
        P("pod", "data")
    # embed first claims data; the batch tuple must drop it and keep pod only
    assert shd.resolve_spec(("embed", "batch"), (8, 2), rules, m) == \
        P("data", "pod")
    # embed first, batch dim divisible by pod*data — data is claimed, so the
    # re-check must strip it from the surviving candidate, NOT emit it twice
    assert shd.resolve_spec(("embed", "batch"), (8, 8), rules, m) == \
        P("data", "pod")


def test_resolve_duplicate_axis_inside_rule_tuple():
    """A rule tuple that names one mesh axis twice dedups instead of emitting
    an illegal duplicate-axis PartitionSpec."""
    m = FakeMesh(data=4, model=4)
    rules = shd.Rules({"batch": ("data", "data")})
    # 16 % (4*4) == 0, so without within-tuple dedup the unshortened
    # candidate ("data", "data") survives verbatim -> illegal spec
    assert shd.resolve_spec(("batch",), (16,), rules, m) == P("data")


def test_resolve_no_duplicate_mesh_axes():
    m = FakeMesh(data=4, model=4)
    rules = shd.Rules({"a": "model", "b": "model"})
    spec = shd.resolve_spec(("a", "b"), (8, 8), rules, m)
    assert spec == P("model")       # second claim dropped, trailing None trimmed


def test_resolve_skips_missing_axes():
    m = FakeMesh(data=4)            # no "model" on this mesh
    rules = shd.Rules({"heads": "model", "embed": "data"})
    assert shd.resolve_spec(("heads", "embed"), (8, 8), rules, m) == \
        P(None, "data")


def test_train_rules_profile(mesh):
    rules = shd.train_rules(mesh)
    assert rules.lookup("vocab") == ("model",)
    assert rules.lookup("embed") == ("data",)
    assert rules.lookup(None) == ()


def test_param_shardings_cover_every_tensor(mesh):
    from repro.configs import registry
    cfg = registry.reduced(registry.get("glm4-9b"))
    rules = shd.train_rules(mesh)
    shards = shd.param_shardings(cfg, mesh, rules)
    from repro.models import api
    assert set(shards) == set(api.build(cfg).schema(cfg))


def test_cell_builders_construct_for_host_mesh(mesh):
    """build_cell on the 1x1 host mesh: structure + shardings line up (the
    production-mesh versions are exercised by the dry-run)."""
    from repro.configs import registry
    from repro.configs.base import SHAPES
    from repro.launch import specs
    cfg = registry.reduced(registry.get("qwen3-1.7b"))
    shape = SHAPES["train_4k"]
    try:
        cell = specs.build_train_cell(cfg, shape, mesh, microbatches=1)
        assert set(cell.args[0]) == set(cell.in_shardings[0])
    finally:
        specs.clear_contexts()


def test_qt_leaf_shardings_consistent():
    """QT triples resolve q along the output-channel axis and scale/zero
    FOLLOW it (same mesh axes where sizes line up, replicated on size-1
    broadcast dims)."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro.models.layers import QT, QT4
    m = mesh_lib.make_mesh((1, 1), ("data", "model"))

    class FM:        # fake 4x4 shape, real mesh for NamedSharding construction
        shape = {"data": 4, "model": 4}
    rules = shd.Rules({"vocab": "model", "embed": "data"})
    qt = QT(jnp.zeros((64, 32), jnp.uint8), jnp.zeros((64, 1), jnp.float32),
            jnp.zeros((64, 1), jnp.float32))
    spec = shd.resolve_spec(("vocab", "embed"), (64, 32), rules, FM)
    assert spec == P("model", "data")
    sspec = shd.follower_spec(spec, (64, 32), (64, 1), FM)
    assert sspec == P("model")          # channel rows follow q, bcast dim trimmed
    sh = shd.leaf_shardings(("vocab", "embed"), qt, rules, m)
    assert isinstance(sh, QT)
    assert all(isinstance(s, NamedSharding) for s in sh)
    assert sh.q.spec == shd.resolve_spec(("vocab", "embed"), (64, 32), rules, m)
    # packed QT4: last-dim divisibility is checked at the PACKED size
    qt4 = QT4(jnp.zeros((64, 16), jnp.uint8), jnp.zeros((64, 1), jnp.float32),
              jnp.zeros((64, 1), jnp.float32))
    sh4 = shd.leaf_shardings(("vocab", "embed"), qt4, rules, m)
    assert isinstance(sh4, QT4)


def test_qt_follower_per_group_divisibility():
    """Per-group scale (C, G, 1): group dim keeps q's axes only when every
    shard owns whole groups; otherwise that dim replicates."""

    class FM:
        shape = {"data": 4, "model": 4}
    qspec = P("model", "data")
    # q (64, 32) sharded 4-ways on dim1; 8 groups % 4 == 0 -> follow
    assert shd.follower_spec(qspec, (64, 32), (64, 8), FM) == P("model", "data")
    # 6 groups % 4 != 0 -> group dim replicates, channel dim still follows
    assert shd.follower_spec(qspec, (64, 32), (64, 6), FM) == P("model")


def test_quantized_param_structs_match_schema():
    from repro.configs import registry
    from repro.launch import specs
    m = mesh_lib.make_mesh((1, 1), ("data", "model"))
    cfg = registry.get("qwen3-1.7b")
    rules = shd.serve_rules(m)
    for fmt in ("bf16", "int8", "int4"):
        structs, shards = specs.param_structs(cfg, m, rules, fmt)
        assert set(structs) == set(shards)
        if fmt == "int4":
            from repro.models.layers import QT4
            big = [v for v in structs.values() if isinstance(v, QT4)]
            assert big, "int4 format must quantize the big matrices"
            for qt in big:
                assert qt.q.dtype.name == "uint8"
