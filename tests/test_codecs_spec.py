"""Codec registry + CompressionSpec: round-trips, rates, rule precedence."""
import numpy as np
import pytest

from repro.core import codecs, quant
from repro.core.bitstream import (decode_serial_tans, decode_streams_tans,
                                  pack_streams)
from repro.core.codecs.rans import RansCodeTable, normalize_freqs
from repro.core.entropy import shannon_entropy
from repro.core.spec import (CompressionRule, CompressionSpec,
                             default_quantize_predicate, spec_from_legacy)
from repro.core.store import CompressedModel


def _heavy_tailed(rng, shape, scale=0.02):
    return (rng.standard_t(2.5, size=shape) * scale).astype(np.float32)


# --------------------------------------------------------------------- registry
def test_codec_registry_names_and_errors():
    assert set(codecs.codec_names()) >= {"huffman", "rans", "raw"}
    with pytest.raises(KeyError, match="registered"):
        codecs.get_codec("no-such-codec")


@pytest.mark.parametrize("codec", ["huffman", "rans", "raw"])
@pytest.mark.parametrize("bits", [4, 8])
def test_codec_table_roundtrip_and_serialization(codec, bits):
    rng = np.random.default_rng(bits)
    syms = np.clip(np.abs(rng.standard_t(2.5, size=4000)) * (1 << bits) / 6,
                   0, (1 << bits) - 1).astype(np.uint8)
    freqs = np.bincount(syms, minlength=1 << bits)
    table = codecs.get_codec(codec).build(freqs, bits)
    stream, nbits = table.encode(syms)
    # decode through the numpy backend's table dispatch
    from repro.core.decode_backends import get_backend
    mat, _ = pack_streams([stream])
    out = get_backend("numpy").decode_table(
        table, mat, np.array([len(syms)], np.int64))
    assert (out[0, : len(syms)] == syms).all()
    # deterministic rebuild from (manifest, arrays)
    revived = codecs.table_from_container(table.to_manifest(),
                                          table.to_arrays())
    stream2, nbits2 = revived.encode(syms)
    assert nbits2 == nbits
    assert (stream2 == stream).all()


# ------------------------------------------------------------------------ rates
def test_rans_beats_huffman_on_both_bitwidths():
    """Acceptance: rans achieved-bits <= huffman achieved-bits on 4-bit AND
    8-bit histograms (fractional-bit coding closes the integer-bit gap)."""
    rng = np.random.default_rng(0)
    w = [_heavy_tailed(rng, (256, 256)) for _ in range(4)]
    for bits in (4, 8):
        qs = [quant.quantize(x, bits).q for x in w]
        freqs = sum(np.bincount(q.reshape(-1), minlength=1 << bits)
                    for q in qs)
        syms = np.concatenate([q.reshape(-1) for q in qs])
        achieved = {}
        for codec in ("huffman", "rans"):
            t = codecs.get_codec(codec).build(freqs, bits)
            _, nbits = t.encode(syms)
            achieved[codec] = nbits / syms.size
        h = shannon_entropy(freqs)
        assert h <= achieved["rans"] <= achieved["huffman"], (bits, achieved)
        assert achieved["rans"] <= 1.02 * h, (bits, achieved["rans"], h)


def test_rans_tiny_table_log_raises_clearly():
    # L=8 makes the spread stride even (shares factor 2 with L): must refuse
    # loudly instead of building a corrupt table
    with pytest.raises(ValueError, match="table_log"):
        RansCodeTable(np.array([3, 1], np.int64), bits=1, table_log=3)
    # ...and states beyond the 16-bit stream header would truncate silently
    with pytest.raises(ValueError, match="header"):
        RansCodeTable(np.array([3, 1], np.int64), bits=1, table_log=17)
    RansCodeTable(np.array([3, 1], np.int64), bits=1, table_log=16)  # fits


def test_rans_normalization_sums_to_table_and_keeps_symbols():
    rng = np.random.default_rng(1)
    freqs = np.zeros(256, np.int64)
    active = rng.choice(256, size=40, replace=False)
    freqs[active] = rng.integers(1, 1_000_000, size=40)
    norm = normalize_freqs(freqs, 12)
    assert norm.sum() == 1 << 12
    assert (norm[freqs > 0] >= 1).all()
    assert (norm[freqs == 0] == 0).all()


def test_tans_serial_matches_multistream():
    rng = np.random.default_rng(2)
    syms = rng.integers(0, 16, size=1000).astype(np.uint8)
    t = RansCodeTable(np.bincount(syms, minlength=16), bits=4)
    chunks = [c for c in np.array_split(syms, 5) if len(c)]
    streams = [t.encode(c)[0] for c in chunks]
    mat, _ = pack_streams(streams)
    counts = np.array([len(c) for c in chunks], np.int64)
    out = decode_streams_tans(mat, counts, t.tab_sym, t.tab_bits, t.tab_base,
                              t.table_log)
    for i, c in enumerate(chunks):
        serial = decode_serial_tans(streams[i], len(c), t.tab_sym, t.tab_bits,
                                    t.tab_base, t.table_log)
        assert (serial == c).all()
        assert (out[i, : len(c)] == c).all()


# ------------------------------------------------ container round-trip property
@pytest.mark.parametrize("codec", ["huffman", "rans", "raw"])
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("gran", [quant.Granularity.PER_TENSOR,
                                  quant.Granularity.PER_CHANNEL,
                                  quant.Granularity.PER_GROUP])
def test_container_roundtrip_codec_bits_granularity(codec, bits, gran):
    rng = np.random.default_rng(7)
    params = {"a": _heavy_tailed(rng, (80, 64)),
              "b": _heavy_tailed(rng, (2, 48, 64))}
    spec = CompressionSpec(default_bits=bits, default_codec=codec,
                           default_granularity=gran, default_group=32,
                           segment_symbols=2048)
    cm = CompressedModel.compress(params, spec=spec)
    dec = cm.decode_all()
    for name, w in params.items():
        direct = quant.quantize(w, bits, gran, group=32)
        assert (dec[name] == direct.q).all(), (name, codec, bits, gran)
        # lossless w.r.t. the quantized model: dequantized values match too
        got = cm._dequantize_one(name, dec[name])
        assert np.array_equal(got, quant.dequantize(direct)), name


# ------------------------------------------------------------------------- spec
def test_spec_rule_precedence_first_match_wins():
    spec = CompressionSpec.parse(
        "layers/*norm*:fp32;"
        "layers/*:bits=4,codec=rans;"
        "*:bits=8,codec=huffman")
    w = np.zeros((64, 64), np.float32)
    assert spec.resolve("layers/q_norm", w).quantize is False
    p4 = spec.resolve("layers/wq", w)
    assert (p4.quantize, p4.bits, p4.codec) == (True, 4, "rans")
    p8 = spec.resolve("embed", w)
    assert (p8.quantize, p8.bits, p8.codec) == (True, 8, "huffman")
    # order matters: flipping the rules hides the fp32 carve-out
    flipped = CompressionSpec(rules=(spec.rules[1], spec.rules[0]))
    assert flipped.resolve("layers/q_norm", w).bits == 4


def test_spec_default_path_keeps_paper_predicate():
    """Tensors no rule matches follow DESIGN.md §5 (norms/small stay fp32)."""
    spec = spec_from_legacy(8, quant.Granularity.PER_TENSOR)
    big = np.zeros((128, 64), np.float32)
    assert spec.resolve("wq", big).quantize is True
    assert spec.resolve("final_norm", np.zeros(64, np.float32)).quantize is False
    assert default_quantize_predicate("wq", big) is True


def test_spec_parse_validates_upfront():
    with pytest.raises(KeyError, match="registered"):
        CompressionSpec.parse("*:codec=lzma")
    with pytest.raises(ValueError, match="bits"):
        CompressionSpec.parse("*:bits=12")
    with pytest.raises(ValueError, match="clause"):
        CompressionSpec.parse("no-colon-here")
    with pytest.raises(ValueError, match="granularity"):
        CompressionSpec.parse("*:granularity=per_banana")
    with pytest.raises(ValueError, match="group"):
        CompressionSpec.parse("*:bits=4,granularity=group,group=0")


def test_spec_defaults_clause_sets_defaults_not_a_rule():
    spec = CompressionSpec.parse("defaults:bits=4,codec=rans,group=64")
    assert spec.rules == ()
    assert (spec.default_bits, spec.default_codec, spec.default_group) \
        == (4, "rans", 64)
    # defaults do NOT override the keep-fp32 predicate (unlike a '*' rule)
    assert spec.resolve("bias", np.zeros(64, np.float32)).quantize is False
    assert spec.resolve("wq", np.zeros((128, 64), np.float32)).bits == 4
    with pytest.raises(ValueError, match="defaults"):
        CompressionSpec.parse("defaults:fp32")


def test_describe_of_legacy_spec_roundtrips_with_same_semantics():
    """Provenance regression: describe() must not turn spec DEFAULTS into a
    '*' catch-all rule, which would override the keep-fp32 predicate when a
    loaded container's spec is reused for re-compression."""
    rng = np.random.default_rng(9)
    params = {"wq": _heavy_tailed(rng, (128, 64)),
              "bias": rng.normal(size=(64,)).astype(np.float32)}
    spec = spec_from_legacy(8, quant.Granularity.PER_CHANNEL)
    revived = CompressionSpec.parse(spec.describe())
    cm1 = CompressedModel.compress(params, spec=spec)
    cm2 = CompressedModel.compress(params, spec=revived)
    assert set(cm1.unquantized) == set(cm2.unquantized) == {"bias"}
    assert cm2.qmeta["wq"]["bits"] == 8


def test_spec_auto_bits_policy():
    rng = np.random.default_rng(3)
    spec = CompressionSpec.parse("*:bits=auto,codec=huffman")
    # tightly clustered weights quantize to 4 bits almost losslessly
    smooth = (rng.normal(0, 1, (64, 128)) * 0.01).astype(np.float32)
    smooth = np.tanh(smooth)  # bounded, no outliers
    # huge outliers blow up the 4-bit relative error -> 8 bits
    spiky = smooth.copy()
    spiky[0, 0] = 50.0
    p4 = spec.resolve("smooth", smooth)
    assert p4.bits == 4
    # the probe's 4-bit quantization rides along for compress() to reuse,
    # and it matches a direct quantize call exactly
    assert p4.qt is not None
    direct = quant.quantize(smooth, 4, p4.granularity, group=p4.group)
    assert (p4.qt.q == direct.q).all()
    p8 = spec.resolve("spiky", spiky)
    assert p8.bits == 8 and p8.qt is None
    # end-to-end: an auto container decodes to the direct 4-bit symbols
    cm = CompressedModel.compress({"smooth": smooth}, spec=spec)
    assert (cm.decode_all()["smooth"] == direct.q).all()


def test_legacy_should_quantize_predicate_still_overrides():
    rng = np.random.default_rng(8)
    params = {"keep_me": _heavy_tailed(rng, (64, 64)),
              "skip_me": _heavy_tailed(rng, (64, 64))}
    cm = CompressedModel.compress(
        params, bits=8, should_quantize=lambda n, w: n == "keep_me")
    assert set(cm.qmeta) == {"keep_me"}
    assert set(cm.unquantized) == {"skip_me"}
    # spec rules still take precedence over the predicate where they match
    spec = CompressionSpec.parse("skip_me:bits=4,codec=raw")
    cm2 = CompressedModel.compress(
        params, spec=spec, should_quantize=lambda n, w: n == "keep_me")
    assert cm2.qmeta["skip_me"]["bits"] == 4
    assert cm2.qmeta["keep_me"]["bits"] == 8


def test_spec_describe_roundtrips_through_parse():
    text = "layers/*:bits=4,codec=rans;*:bits=8"
    spec = CompressionSpec.parse(text)
    spec2 = CompressionSpec.parse(spec.describe())
    assert spec2.rules == spec.rules
    assert spec2.default_bits == spec.default_bits
    assert spec2.default_granularity is spec.default_granularity
    # out-of-band parse() defaults (serve.py passes per-channel) must be
    # recorded in describe() so provenance round-trips semantically
    spec3 = CompressionSpec.parse("layers/*:bits=4",
                                  default_granularity=quant.Granularity.PER_CHANNEL)
    revived = CompressionSpec.parse(spec3.describe())
    assert revived.default_granularity is quant.Granularity.PER_CHANNEL
    assert revived.rules == spec3.rules
    # encoder-wide params survive the round-trip too (non-defaults emitted)
    spec4 = CompressionSpec(rules=spec3.rules, max_code_len=10, auto_tol=0.1,
                            segment_symbols=4096)
    revived4 = CompressionSpec.parse(spec4.describe())
    assert (revived4.max_code_len, revived4.auto_tol,
            revived4.segment_symbols) == (10, 0.1, 4096)
    # ...and are rejected outside a defaults: clause
    with pytest.raises(ValueError, match="spec-wide"):
        CompressionSpec.parse("layers/*:bits=4,max_code_len=10")


# ---------------------------------------------------------- quant PER_GROUP fix
def test_per_group_ragged_tail_falls_back_per_channel():
    w = np.random.default_rng(4).normal(size=(8, 100)).astype(np.float32)
    with pytest.warns(UserWarning, match="does not divide"):
        qt = quant.quantize(w, 8, quant.Granularity.PER_GROUP, group=64)
    assert qt.granularity is quant.Granularity.PER_CHANNEL
    err = np.abs(quant.dequantize(qt) - w)
    assert np.all(err <= 0.5 * np.abs(qt.scale) + 1e-6)


def test_per_group_ragged_vector_falls_back_per_tensor():
    w = np.random.default_rng(5).normal(size=(100,)).astype(np.float32)
    with pytest.warns(UserWarning, match="does not divide"):
        qt = quant.quantize(w, 8, quant.Granularity.PER_GROUP, group=64)
    assert qt.granularity is quant.Granularity.PER_TENSOR


def test_per_channel_1d_falls_back_per_tensor():
    # one (scale, zero) pair per ELEMENT would be larger than fp32
    w = np.random.default_rng(12).normal(size=(200,)).astype(np.float32)
    with pytest.warns(UserWarning, match="per-element"):
        qt = quant.quantize(w, 8, quant.Granularity.PER_CHANNEL)
    assert qt.granularity is quant.Granularity.PER_TENSOR
    assert qt.scale.size == 1


def test_per_group_invalid_group_raises_clearly():
    w = np.zeros((8, 64), np.float32)
    with pytest.raises(ValueError, match="group >= 1"):
        quant.quantize(w, 8, quant.Granularity.PER_GROUP, group=0)


def test_per_group_divisible_unchanged():
    w = np.random.default_rng(6).normal(size=(8, 128)).astype(np.float32)
    qt = quant.quantize(w, 8, quant.Granularity.PER_GROUP, group=64)
    assert qt.granularity is quant.Granularity.PER_GROUP
    assert qt.scale.shape == (8, 2, 1)


# -------------------------------------------------------------- CLI validation
def test_serve_cli_rejects_unknown_codec_and_spec_upfront():
    from repro.launch.serve import main
    with pytest.raises(SystemExit) as e:
        main(["--arch", "qwen3-1.7b", "--codec", "lzma"])
    assert e.value.code == 2
    with pytest.raises(SystemExit):
        main(["--arch", "qwen3-1.7b", "--compress-spec", "*:codec=nope"])
    with pytest.raises(SystemExit):
        main(["--arch", "qwen3-1.7b", "--bits", "12"])
