"""Decode-backend ↔ serial-oracle parity, exhaustively.

Every available backend must reproduce the bit-serial reference decoders
(``bitstream.decode_serial`` / ``decode_serial_tans``) exactly, for every
codec family × every bit width 1..8 × both decode-into-buffer modes — plus
a zero-count lane mid-pack (must stay empty, not misalign its neighbours).
These serial loops are the harness's root of trust: the fused-kernel
differential suite (``tests/differential/``) compares against
``kernels.ref.fused_decode_matmul_ref``, which decodes through the numpy
backend, which this file pins to the serial oracles.

The backend list is computed at collection from the capability probes, so
hosts without a compiled Pallas toolchain test {numpy, jax,
pallas-interpret} with zero skips (tier-1 CI runs ``--require-dev-deps``
and rejects silent skip-outs).

Also here: the ``plan_execution`` boundary-segment trim paths — segments
straddling a layer cut are decoded on both sides and trimmed, and the
per-layer reassembly must equal the whole-model loader's slices.
"""
import numpy as np
import pytest

from repro.core import bitstream
from repro.core.codecs import get_codec
from repro.core.decode_backends import available_backends, get_backend
from repro.core.quant import Granularity
from repro.core.scheduler import decode_execution_step, plan_execution
from repro.core.spec import spec_from_legacy
from repro.core.store import CompressedModel

BACKENDS = available_backends()
N_STREAMS, COUNT = 4, 96


def _case(codec: str, bits: int, *, seed: int = 0):
    rng = np.random.default_rng(seed)
    hi = 1 << bits
    sym = rng.integers(0, hi, N_STREAMS * COUNT).astype(np.uint8)
    freqs = np.bincount(sym, minlength=hi).astype(np.int64)
    if np.count_nonzero(freqs) < 2:        # bits=1 can degenerate
        freqs[(int(sym[0]) + 1) % hi] += 1
    table = get_codec(codec).build(freqs, bits, max_code_len=12)
    streams = [table.encode(sym[i * COUNT:(i + 1) * COUNT])[0]
               for i in range(N_STREAMS)]
    counts = [COUNT] * N_STREAMS
    streams.insert(2, np.zeros(0, np.uint8))     # a zero-count lane mid-pack
    counts.insert(2, 0)
    mat, _ = bitstream.pack_streams(streams)
    return table, mat, np.asarray(counts, np.int64), sym.reshape(N_STREAMS,
                                                                 COUNT)


def _serial_rows(table, mat, counts):
    a = table.decode_arrays()
    rows = []
    for i, c in enumerate(np.asarray(counts)):
        if table.kernel == "prefix":
            rows.append(bitstream.decode_serial(
                mat[i], int(c), a["lut_sym"], a["lut_len"],
                table.peek_bits))
        else:
            rows.append(bitstream.decode_serial_tans(
                mat[i], int(c), a["tab_sym"], a["tab_bits"], a["tab_base"],
                table.table_log))
    return rows


@pytest.mark.parametrize("use_out", [False, True], ids=["ret", "out"])
@pytest.mark.parametrize("bits", range(1, 9))
@pytest.mark.parametrize("codec", ["huffman", "rans"])
@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_matches_serial_oracle(backend, codec, bits, use_out):
    table, mat, counts, sym = _case(codec, bits, seed=bits)
    b = get_backend(backend)
    out = (np.full((mat.shape[0] + 2, COUNT + 32), -1, np.int32)
           if use_out else None)
    dec = np.asarray(b.decode_table(table, mat, counts, out=out))
    serial = _serial_rows(table, mat, counts)
    k = 0
    for i, c in enumerate(counts):
        np.testing.assert_array_equal(dec[i, :c], serial[i])
        if c:
            np.testing.assert_array_equal(dec[i, :c].astype(np.uint8),
                                          sym[k])
            k += 1
    if use_out:
        assert dec.base is out or dec is out     # genuinely in place


def test_raw_codec_matches_symbols_on_every_backend():
    """The raw codec (identity LUT, fixed width) is prefix-family too and
    must satisfy the same decode contract — it is the 'quantized only'
    baseline every entropy codec is judged against."""
    rng = np.random.default_rng(7)
    sym = rng.integers(0, 256, (N_STREAMS, COUNT)).astype(np.uint8)
    raw = get_codec("raw").build(
        np.bincount(sym.reshape(-1), minlength=256).astype(np.int64), 8)
    streams = [raw.encode(row)[0] for row in sym]
    counts = np.full(len(streams), COUNT, np.int64)
    mat, _ = bitstream.pack_streams(streams)
    for backend in BACKENDS:
        dec = np.asarray(get_backend(backend).decode_table(raw, mat, counts))
        # device backends may pad the lane count to a pow2 bucket
        np.testing.assert_array_equal(
            dec[:len(streams), :COUNT].astype(np.uint8), sym)


def test_plan_execution_boundary_trims_round_trip():
    """Segments straddling layer cuts: 2048 symbols/layer over 1000-symbol
    segments means every layer boundary lands mid-segment, so spans carry
    non-zero trims and boundary segments decode twice.  Reassembly must
    equal the whole-model loader's stacked slices for every backend."""
    from repro.serving import engine as serving_engine
    rng = np.random.default_rng(0)
    host = {"layers/w_a": rng.normal(0, 0.05, (3, 64, 32)).astype(np.float32)}
    cm = CompressedModel.compress(host, spec=spec_from_legacy(
        8, Granularity.PER_TENSOR, segment_symbols=1000))
    meta = cm.tensors["layers/w_a"]
    assert meta.n_symbols % 1000                 # really has a ragged tail
    plan = plan_execution(cm, 3, ["layers/w_a"])
    trims = [sp.trim for steps in plan for st in steps for sp in st.spans]
    assert any(trims)                            # boundary-trim path taken
    qparams = serving_engine.load_params_from_compressed(cm, quantized=True)
    want = np.asarray(qparams["layers/w_a"].q)
    for backend in BACKENDS:
        b = get_backend(backend)
        for l, steps in enumerate(plan):
            got = {}
            for st in steps:
                got.update(decode_execution_step(cm, st, b))
            np.testing.assert_array_equal(
                got["layers/w_a"].reshape(64, 32), want[l])
