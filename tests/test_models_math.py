"""Numerical correctness of the model-math building blocks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import gqa_attention, rms_norm, rope, softmax_xent
from repro.models.mamba2 import _causal_conv, ssd_chunked, ssd_step


def _naive_attention(q, k, v, causal, kv_len=None):
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    kf = np.repeat(np.asarray(k, np.float32), G, axis=2)
    vf = np.repeat(np.asarray(v, np.float32), G, axis=2)
    qf = np.asarray(q, np.float32)
    out = np.zeros((B, S, H, hd), np.float32)
    for b in range(B):
        for h in range(H):
            s = qf[b, :, h] @ kf[b, :, h].T / np.sqrt(hd)
            mask = np.ones((S, T), bool)
            if causal:
                mask &= np.tril(np.ones((S, T), bool))
            if kv_len is not None:
                mask[:, kv_len:] = False
            s = np.where(mask, s, -1e9)
            p = np.exp(s - s.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            out[b, :, h] = p @ vf[b, :, h]
    return out


@pytest.mark.parametrize("S,H,KV,q_block", [(16, 4, 2, 0), (32, 4, 4, 8),
                                            (32, 8, 2, 16)])
def test_gqa_attention_matches_naive(S, H, KV, q_block):
    rng = np.random.default_rng(S + H)
    B, hd = 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    out = gqa_attention(q, k, v, causal=True, q_block=q_block)
    want = _naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32), want, atol=5e-2)


def test_gqa_attention_decode_with_cache_mask():
    rng = np.random.default_rng(0)
    B, T, H, KV, hd = 2, 16, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    out = gqa_attention(q, k, v, causal=False, kv_len=jnp.int32(10))
    want = _naive_attention(q, k, v, causal=False, kv_len=10)
    np.testing.assert_allclose(np.asarray(out, np.float32), want, atol=5e-2)


def test_rope_preserves_norm_and_relativity():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 16)), jnp.float32)
    pos = jnp.arange(8)
    y = rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # dot(q_i, k_j) depends only on i - j
    q = jnp.asarray(rng.normal(size=(1, 16, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 16, 1, 16)), jnp.float32)
    qr = np.asarray(rope(q, jnp.arange(16), 1e4))
    kr = np.asarray(rope(k, jnp.arange(16), 1e4))
    d1 = (qr[0, 5, 0] * kr[0, 3, 0]).sum()
    q2 = np.asarray(rope(q, jnp.arange(16) + 7, 1e4))
    k2 = np.asarray(rope(k, jnp.arange(16) + 7, 1e4))
    d2 = (q2[0, 5, 0] * k2[0, 3, 0]).sum()
    np.testing.assert_allclose(d1, d2, rtol=1e-4, atol=1e-5)


def test_rms_norm_unit_rms():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 10, size=(4, 32)), jnp.float32)
    y = rms_norm(x, jnp.ones(32))
    rms = np.sqrt((np.asarray(y) ** 2).mean(-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_softmax_xent_masks_out_of_vocab():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.asarray([[1, 2, 100, -1]])     # 2 valid, 2 masked
    loss = softmax_xent(logits, labels, vocab=8)
    np.testing.assert_allclose(float(loss), np.log(8), rtol=1e-5)


@pytest.mark.parametrize("S,chunk", [(64, 16), (96, 32), (50, 32)])
def test_ssd_chunked_matches_stepwise(S, chunk):
    rng = np.random.default_rng(S)
    B, H, P, N = 2, 3, 8, 16
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B, S, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 4.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(B, H, P, N)), jnp.float32)
    y, h = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk, h0=h0)
    hh = h0
    ys = []
    for t in range(S):
        yt, hh = ssd_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], hh)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.stack(ys, 1)),
                               atol=5e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hh), atol=5e-5)


def test_causal_conv_matches_numpy_and_streams():
    rng = np.random.default_rng(3)
    B, S, C, K = 2, 20, 6, 4
    x = jnp.asarray(rng.normal(size=(B, S, C)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, C)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(C,)), jnp.float32)
    y, state = _causal_conv(x, w, b)
    # numpy oracle
    xp = np.concatenate([np.zeros((B, K - 1, C)), np.asarray(x)], axis=1)
    want = np.zeros((B, S, C))
    for k in range(K):
        want += xp[:, k: k + S] * np.asarray(w)[k]
    want = want + np.asarray(b)
    want = want / (1 + np.exp(-want))           # silu
    np.testing.assert_allclose(np.asarray(y), want, atol=1e-5)
    # streaming: feed the tail one token at a time with carried state
    y2, st = _causal_conv(x[:, :10], w, b)
    outs = [y2]
    for t in range(10, S):
        yt, st = _causal_conv(x[:, t:t + 1], w, b, state=st)
        outs.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(y), atol=1e-5)


def test_moe_dispatch_matches_dense_ffn_when_experts_identical():
    """With identical experts + top-1 and ample capacity, MoE == dense MLP."""
    from repro.configs.base import MoEConfig
    from repro.models.moe import moe_mlp
    rng = np.random.default_rng(4)
    B, S, D, F, E = 2, 8, 16, 32, 4
    x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.bfloat16)
    w_gate1 = rng.normal(0, 0.2, size=(D, F)).astype(np.float32)
    w_up1 = rng.normal(0, 0.2, size=(D, F)).astype(np.float32)
    w_down1 = rng.normal(0, 0.2, size=(F, D)).astype(np.float32)
    wts = {
        "router": jnp.asarray(rng.normal(size=(D, E)), jnp.float32),
        "w_gate": jnp.asarray(np.tile(w_gate1, (E, 1, 1)), jnp.bfloat16),
        "w_up": jnp.asarray(np.tile(w_up1, (E, 1, 1)), jnp.bfloat16),
        "w_down": jnp.asarray(np.tile(w_down1, (E, 1, 1)), jnp.bfloat16),
    }
    mcfg = MoEConfig(num_experts=E, top_k=1, capacity_factor=8.0)
    y, aux = moe_mlp(x, wts, mcfg, E)
    xd = np.asarray(x, np.float32)
    h = xd @ w_gate1
    u = xd @ w_up1
    want = (h / (1 + np.exp(-h)) * u) @ w_down1
    np.testing.assert_allclose(np.asarray(y, np.float32), want, atol=0.1,
                               rtol=0.1)
    assert np.isfinite(float(aux))
