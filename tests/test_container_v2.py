"""Container format v2: v1 back-compat bit-identity, mixed-precision
round-trips across every decode backend, and the per-group stats contract."""
import os
import tempfile

import numpy as np
import pytest

from repro.core import decode_backends as db
from repro.core import quant
from repro.core.spec import CompressionSpec
from repro.core.store import CompressedModel

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")


def _params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "embed": (rng.standard_t(3, size=(300, 128)) * 0.02).astype(np.float32),
        "layers/wq": (rng.standard_t(3, size=(3, 96, 128)) * 0.02).astype(np.float32),
        "layers/w_up": (rng.standard_t(3, size=(3, 128, 160)) * 0.02).astype(np.float32),
        "lm_head": (rng.standard_t(3, size=(128, 300)) * 0.02).astype(np.float32),
        "final_norm": rng.normal(size=(128,)).astype(np.float32),
    }


MIXED_SPEC = CompressionSpec.parse(
    "layers/*:bits=4,codec=rans,granularity=channel;"
    "*:bits=8,codec=rans,granularity=channel")


# ------------------------------------------------------------- v1 back-compat
def test_v1_container_loads_and_decodes_bit_identically():
    """Acceptance: a container written BEFORE the codec-registry redesign
    (committed fixture) loads through the v2 reader and reproduces the
    symbols and dequantized values bit-for-bit."""
    cm = CompressedModel.load(os.path.join(FIXTURES, "container_v1_8bit.npz"))
    expected = np.load(os.path.join(FIXTURES,
                                    "container_v1_8bit_expected.npz"))
    dec = cm.decode_all()
    names = {k.split("::", 1)[1] for k in expected.files
             if k.startswith("sym::")}
    assert set(dec) == names
    for k in dec:
        assert dec[k].dtype == np.uint8
        assert (dec[k] == expected[f"sym::{k}"]).all(), k
    deq = cm.dequantize_all()
    for k in deq:
        assert np.array_equal(deq[k], expected[f"deq::{k}"]), k
    # revived as the single-huffman-table degenerate case of v2
    assert list(cm.tables) == ["huffman8"]
    assert cm.table.codec_name == "huffman"
    assert all(m["codec"] == "huffman" for m in cm.qmeta.values())


def test_v1_fixture_streams_through_scheduler():
    cm = CompressedModel.load(os.path.join(FIXTURES, "container_v1_8bit.npz"))
    mono = cm.decode_all()
    streamed = dict(cm.iter_decode(chunk_symbols=1024))
    assert set(mono) == set(streamed)
    for k in mono:
        assert (mono[k] == streamed[k]).all(), k


# ----------------------------------------------------------- v2 mixed rans/4+8
def test_mixed_container_groups_and_decode():
    cm = CompressedModel.compress(_params(), spec=MIXED_SPEC)
    assert set(cm.tables) == {"rans4", "rans8"}
    assert cm.qmeta["layers/wq"]["bits"] == 4
    assert cm.qmeta["embed"]["bits"] == 8
    with pytest.raises(AttributeError, match="tables"):
        cm.table                      # legacy accessor refuses mixed
    dec = cm.decode_all()
    for name in dec:
        bits = cm.qmeta[name]["bits"]
        direct = quant.quantize(_params()[name], bits,
                                quant.Granularity.PER_CHANNEL)
        assert (dec[name] == direct.q).all(), name


@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas",
                                     "pallas-interpret"])
def test_mixed_rans_container_roundtrips_every_backend(backend):
    """Acceptance: a v2 mixed 4/8-bit rans container round-trips bit-exactly
    through every decode backend available on this host."""
    if backend not in db.available_backends():
        pytest.skip(f"{backend} unavailable here")
    cm = CompressedModel.compress(_params(), spec=MIXED_SPEC)
    mono = cm.decode_all(backend="numpy")
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "m.npz")
        cm.save(path)
        cm2 = CompressedModel.load(path)
        streamed = dict(cm2.iter_decode(backend=backend,
                                        chunk_symbols=12_000))
        mono2 = cm2.decode_all(backend=backend)
    assert set(mono) == set(streamed) == set(mono2)
    for k in mono:
        assert (mono[k] == streamed[k]).all(), (backend, k)
        assert (mono[k] == mono2[k]).all(), (backend, k)


def test_mixed_codec_huffman_plus_rans_one_container():
    spec = CompressionSpec.parse(
        "layers/*:bits=8,codec=huffman,granularity=channel;"
        "*:bits=8,codec=rans,granularity=channel")
    cm = CompressedModel.compress(_params(), spec=spec)
    assert set(cm.tables) == {"huffman8", "rans8"}
    dec = cm.decode_all()
    for name in dec:
        direct = quant.quantize(_params()[name], 8,
                                quant.Granularity.PER_CHANNEL)
        assert (dec[name] == direct.q).all(), name


def test_scheduler_chunks_never_straddle_tables():
    cm = CompressedModel.compress(_params(), spec=MIXED_SPEC)
    for chunk in cm.scheduler(backend="numpy", chunk_symbols=10_000).plan():
        tables = {cm.table_id_for(s.tensor) for s in chunk.segs}
        assert len(tables) == 1
    # monolithic plan groups table-major: exactly ONE batched lock-step call
    # per table, no matter how tensor order alternates between tables
    mono = cm.scheduler(backend="numpy", chunk_symbols=None).plan()
    assert len(mono) == len(cm.tables)
    for chunk in mono:
        tables = {cm.table_id_for(s.tensor) for s in chunk.segs}
        assert len(tables) == 1


def test_mixed_container_serving_load_packs_qt4():
    from repro.models.layers import QT4
    from repro.serving import engine
    cm = CompressedModel.compress(_params(), spec=MIXED_SPEC)
    loaded = engine.load_params_from_compressed(cm, quantized=True)
    assert isinstance(loaded["layers/wq"], QT4)      # 4-bit -> nibble-packed
    assert not isinstance(loaded["embed"], QT4)      # 8-bit stays QT
    mono = engine.load_params_from_compressed(cm, quantized=True,
                                              stream=False)
    for k in mono:
        ms, mm = loaded[k], mono[k]
        if hasattr(ms, "q"):
            assert (np.asarray(ms.q) == np.asarray(mm.q)).all(), k
        else:
            assert (np.asarray(ms) == np.asarray(mm)).all(), k


def test_serving_load_dequantizes_per_group_tensors():
    """Per-group scales (…, D/group, 1) cannot broadcast in the fused
    dequant-matmul path: the serving loader must hand such tensors over
    dense instead of packing QT/QT4."""
    from repro.serving import engine
    spec = CompressionSpec.parse("*:bits=8,granularity=group,group=32")
    cm = CompressedModel.compress(_params(), spec=spec)
    per_group = [n for n, m in cm.qmeta.items()
                 if m["granularity"] == "per_group"]
    assert per_group                              # the guard is exercised
    loaded = engine.load_params_from_compressed(cm, quantized=True)
    for name in per_group:
        assert not hasattr(loaded[name], "q"), name
        want = cm._dequantize_one(name, cm.decode_tensor(name))
        assert np.array_equal(np.asarray(loaded[name]), want), name
    # ragged tensors fell back to per-channel, whose scales QT hosts fine
    ragged = set(cm.qmeta) - set(per_group)
    assert all(cm.qmeta[n]["granularity"] == "per_channel" for n in ragged)


def test_serving_load_dequantizes_rule_quantized_norms():
    """A spec rule may quantize norm/bias tensors into the container, but the
    serving loader must hand them to the model as plain arrays — layer code
    (rms_norm etc.) cannot host QT/QT4 structs."""
    from repro.serving import engine
    rng = np.random.default_rng(11)
    params = dict(_params(),
                  **{"layers/attn_norm":
                     rng.normal(size=(3, 128)).astype(np.float32)})
    spec = CompressionSpec.parse(
        "layers/*:bits=4,codec=rans,granularity=channel;"
        "*:bits=8,codec=rans,granularity=channel")
    cm = CompressedModel.compress(params, spec=spec)
    assert cm.qmeta["layers/attn_norm"]["bits"] == 4   # stored quantized...
    loaded = engine.load_params_from_compressed(cm, quantized=True)
    norm = loaded["layers/attn_norm"]
    assert not hasattr(norm, "q")                      # ...served dense
    got = np.asarray(norm)
    want = quant.dequantize(quant.quantize(
        params["layers/attn_norm"], 4, quant.Granularity.PER_CHANNEL))
    assert np.array_equal(got, want)


# ------------------------------------------------------------------ stats v2
def test_stats_per_group_breakdown_and_weighted_effective_bits():
    cm = CompressedModel.compress(_params(), spec=MIXED_SPEC)
    st = cm.stats()
    assert {g.table_id for g in st.groups} == {"rans4", "rans8"}
    by_id = {g.table_id: g for g in st.groups}
    n4, n8 = by_id["rans4"].param_count, by_id["rans8"].param_count
    assert n4 > 0 and n8 > 0
    # the weighted aggregate is exactly the symbol-weighted group mean
    want = (by_id["rans4"].effective_bits * n4
            + by_id["rans8"].effective_bits * n8) / (n4 + n8)
    assert st.effective_bits == pytest.approx(want)
    assert st.bits == pytest.approx((4 * n4 + 8 * n8) / (n4 + n8))
    # quant_bytes reflects per-group widths, not one uniform bits field
    n_u = st.unquantized_params
    assert st.quant_bytes == (n4 * 4) // 8 + (n8 * 8) // 8 + 2 * n_u
    # achieved >= the group Shannon bound, and close to it for rans
    for g in st.groups:
        assert g.entropy_bits <= g.effective_bits <= 1.02 * g.entropy_bits


def test_stats_uniform_container_matches_legacy_contract():
    cm = CompressedModel.compress(_params(), bits=8,
                                  granularity=quant.Granularity.PER_CHANNEL)
    st = cm.stats()
    assert len(st.groups) == 1
    assert st.bits == 8
    assert st.entropy_bits <= st.effective_bits <= st.entropy_bits + 1.0
    assert 0.0 < st.reduction_vs_fp16 < 1.0


def test_stats_survive_save_load():
    cm = CompressedModel.compress(_params(), spec=MIXED_SPEC)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "m.npz")
        cm.save(path)
        st2 = CompressedModel.load(path).stats()
    st = cm.stats()
    assert st2.effective_bits == pytest.approx(st.effective_bits)
    assert [g.table_id for g in st2.groups] == [g.table_id for g in st.groups]


def test_v2_manifest_records_spec_provenance():
    import json
    cm = CompressedModel.compress(_params(), spec=MIXED_SPEC)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "m.npz")
        cm.save(path)
        z = np.load(path)
        manifest = json.loads(bytes(z["__manifest__"]).decode())
        assert manifest["version"] == 2
        assert "rans4" in manifest["tables"] and "rans8" in manifest["tables"]
        assert manifest["spec"] == MIXED_SPEC.describe()
        # provenance survives a load -> save round-trip (e.g. repack) with
        # identical semantics (canonical text incl. the defaults clause)
        cm2 = CompressedModel.load(path)
        assert cm2.spec is not None
        assert cm2.spec.describe() == MIXED_SPEC.describe()
        assert cm2.spec.rules == MIXED_SPEC.rules
        path2 = os.path.join(d, "m2.npz")
        cm2.save(path2)
        manifest2 = json.loads(bytes(np.load(path2)["__manifest__"]).decode())
        assert manifest2["spec"] == MIXED_SPEC.describe()


def test_unknown_future_format_version_raises():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "future.npz")
        np.savez(path, __format_version__=np.array([99], np.int64),
                 __manifest__=np.frombuffer(b"{}", dtype=np.uint8))
        with pytest.raises(ValueError, match="unsupported container format"):
            CompressedModel.load(path)
