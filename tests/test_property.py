"""Hypothesis property tests on the system's invariants (deliverable c)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import quant
from repro.core.bitstream import decode_streams, encode_symbols, pack_streams
from repro.core.entropy import (HuffmanTable, canonical_codes, code_lengths,
                                effective_bits, huffman_code_lengths,
                                package_merge_lengths, shannon_entropy,
                                validate_kraft)

arrays_f32 = st.lists(
    st.floats(min_value=-100, max_value=100, allow_nan=False, width=32),
    min_size=4, max_size=300)


@given(arrays_f32, st.sampled_from([2, 4, 8]))
@settings(max_examples=60, deadline=None)
def test_quantize_error_bounded_by_half_step(vals, bits):
    """|w - dequant(quant(w))| <= scale/2 everywhere (round-to-nearest)."""
    w = np.array(vals, np.float32).reshape(1, -1)
    qt = quant.quantize(w, bits)
    err = np.abs(quant.dequantize(qt) - w)
    assert (err <= np.abs(qt.scale) * 0.5 + 1e-6).all()


@given(arrays_f32, st.sampled_from([4, 8]))
@settings(max_examples=60, deadline=None)
def test_quantize_symbols_in_range(vals, bits):
    w = np.array(vals, np.float32).reshape(1, -1)
    qt = quant.quantize(w, bits)
    assert qt.q.min() >= 0 and qt.q.max() < (1 << bits)


@given(arrays_f32)
@settings(max_examples=40, deadline=None)
def test_scheme_selection_rule(vals):
    """Paper Alg.1 line 5: symmetric iff single-signed."""
    w = np.array(vals, np.float32)
    scheme = quant.choose_scheme(w)
    single = float(w.max()) * float(w.min()) >= 0
    assert (scheme is quant.Scheme.SYMMETRIC_UNSIGNED) == single


@given(st.lists(st.integers(min_value=0, max_value=100_000), min_size=2,
                max_size=256))
@settings(max_examples=60, deadline=None)
def test_huffman_kraft_equality(freqs):
    f = np.array(freqs, np.int64)
    if (f > 0).sum() < 2:
        return
    lengths = huffman_code_lengths(f)
    assert abs(validate_kraft(lengths) - 1.0) < 1e-9


@given(st.lists(st.integers(min_value=1, max_value=100_000), min_size=2,
                max_size=200), st.integers(min_value=9, max_value=15))
@settings(max_examples=40, deadline=None)
def test_package_merge_respects_limit_and_kraft(freqs, max_len):
    f = np.array(freqs, np.int64)
    lengths = package_merge_lengths(f, max_len)
    nz = lengths[f > 0]
    assert (nz > 0).all() and (nz <= max_len).all()
    assert validate_kraft(lengths) <= 1.0 + 1e-9


@given(st.lists(st.integers(min_value=1, max_value=10_000), min_size=2,
                max_size=128))
@settings(max_examples=40, deadline=None)
def test_code_is_within_one_bit_of_entropy(freqs):
    """Huffman optimality: H <= avg_len < H + 1."""
    f = np.array(freqs, np.int64)
    lengths = code_lengths(f, max_len=16)
    h = shannon_entropy(f)
    avg = effective_bits(f, lengths)
    assert h - 1e-9 <= avg < h + 1.0


@given(st.lists(st.integers(min_value=0, max_value=255), min_size=1,
                max_size=2000),
       st.integers(min_value=1, max_value=4))
@settings(max_examples=40, deadline=None)
def test_encode_decode_roundtrip(symbols, n_segments):
    """Lossless: decode(encode(s)) == s for any symbols and segmentation."""
    syms = np.array(symbols, np.uint8)
    freqs = np.bincount(syms, minlength=256)
    table = HuffmanTable(freqs, max_len=12)
    chunks = np.array_split(syms, min(n_segments, len(syms)))
    chunks = [c for c in chunks if len(c)]
    streams = [encode_symbols(c, table.codes, table.lengths)[0]
               for c in chunks]
    mat, _ = pack_streams(streams)
    counts = np.array([len(c) for c in chunks], np.int64)
    out = decode_streams(mat, counts, table.lut_sym, table.lut_len, 12)
    got = np.concatenate([out[i, :c] for i, c in enumerate(counts)])
    assert (got == syms).all()


@given(st.lists(st.integers(min_value=0, max_value=255), min_size=2,
                max_size=500))
@settings(max_examples=30, deadline=None)
def test_canonical_codes_prefix_free(symbols):
    syms = np.array(symbols, np.uint8)
    freqs = np.bincount(syms, minlength=256)
    lengths = code_lengths(freqs, max_len=14)
    codes = canonical_codes(lengths)
    live = [(int(codes[s]), int(lengths[s]))
            for s in range(256) if lengths[s] > 0]
    # no code is a prefix of another
    for i, (c1, l1) in enumerate(live):
        for c2, l2 in live[i + 1:]:
            lo = min(l1, l2)
            assert (c1 >> (l1 - lo)) != (c2 >> (l2 - lo))


@given(st.lists(st.floats(min_value=-5, max_value=5, allow_nan=False,
                          width=32), min_size=256, max_size=1024))
@settings(max_examples=20, deadline=None)
def test_compressed_model_lossless_vs_quantized(vals):
    """The container reproduces the QUANTIZED weights bit-exactly (the paper's
    losslessness claim is w.r.t. the quantized model)."""
    from repro.core.store import CompressedModel
    arr = np.array(vals, np.float32)
    arr = arr[: len(arr) - len(arr) % 16]
    w = arr.reshape(16, -1)
    params = {"w": np.tile(w, (4, 1))}        # make it big enough to quantize
    cm = CompressedModel.compress(params, bits=8)
    if "w" not in cm.tensors:                 # too small -> kept raw
        return
    direct = quant.quantize(np.tile(w, (4, 1)), 8)
    got = cm.decode_tensor("w")
    assert (got == direct.q).all()


@given(st.integers(min_value=1, max_value=64), st.integers(min_value=1,
                                                           max_value=8))
@settings(max_examples=30, deadline=None)
def test_balanced_assignment_covers_all(n_segments, n_workers):
    from repro.core.segmentation import balanced_assignment
    rng = np.random.default_rng(n_segments * 10 + n_workers)
    bits = rng.integers(1, 10_000, size=n_segments)
    buckets = balanced_assignment(bits, n_workers)
    allidx = np.concatenate([b for b in buckets if len(b)]) \
        if any(len(b) for b in buckets) else np.array([])
    assert sorted(allidx.tolist()) == list(range(n_segments))
    if n_segments >= n_workers * 4:
        loads = np.array([bits[b].sum() for b in buckets])
        assert loads.max() <= 2.5 * max(loads.min(), 1)
