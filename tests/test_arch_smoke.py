"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
config of the same family, one forward/train step on CPU — shapes + no NaNs,
plus a prefill -> decode_step round trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.models import api

ARCHS = sorted(registry.ARCHS)


def _batch(cfg, B, S, key):
    out = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        out["src_embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                              jnp.bfloat16)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_finite(arch):
    cfg = registry.reduced(registry.get(arch))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 64, jax.random.PRNGKey(1))
    loss = jax.jit(lambda p, b: mod.loss_fn(cfg, p, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # random-init loss should be near ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.5


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_updates_and_finite(arch):
    from repro.training import optimizer as opt, train_loop
    cfg = registry.reduced(registry.get(arch))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    tc = train_loop.TrainConfig(opt=opt.AdamWConfig(
        schedule=opt.Schedule(base_lr=1e-3, warmup_steps=1, total_steps=10)))
    state = opt.init_state(tc.opt, params)
    step = jax.jit(train_loop.make_train_step(cfg, tc))
    batch = _batch(cfg, 2, 64, jax.random.PRNGKey(1))
    p2, s2, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(s2.step) == 1
    # at least one parameter must actually change
    changed = any(
        not np.array_equal(np.asarray(params[k], np.float32),
                           np.asarray(p2[k], np.float32)) for k in params)
    assert changed


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_roundtrip(arch):
    cfg = registry.reduced(registry.get(arch))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch(cfg, B, S, jax.random.PRNGKey(1))
    prompt = batch if cfg.family == "encdec" else batch["tokens"]
    logits, cache = jax.jit(
        lambda p, t: mod.prefill(cfg, p, t, max_len=S + 4))(params, prompt)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits2, cache2 = jax.jit(
        lambda p, t, c: mod.decode_step(cfg, p, t, c, S))(params, tok, cache)
    assert logits2.shape[0] == B
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_matches_prefill_extension(arch):
    """Teacher-forcing consistency: decode_step(token at pos S) must produce
    the same logits as prefill over S+1 tokens — the KV/SSM cache is exact."""
    cfg = registry.reduced(registry.get(arch))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 33
    batch = _batch(cfg, B, S, jax.random.PRNGKey(1))
    toks = batch["tokens"]

    if cfg.family == "encdec":
        full_prompt = {"tokens": toks, "src_embeds": batch["src_embeds"]}
        part_prompt = {"tokens": toks[:, :-1],
                       "src_embeds": batch["src_embeds"]}
    else:
        full_prompt, part_prompt = toks, toks[:, :-1]

    full_logits, _ = jax.jit(
        lambda p, t: mod.prefill(cfg, p, t, max_len=S))(params, full_prompt)
    _, cache = jax.jit(
        lambda p, t: mod.prefill(cfg, p, t, max_len=S))(params, part_prompt)
    step_logits, _ = jax.jit(
        lambda p, t, c: mod.decode_step(cfg, p, t, c, S - 1))(
        params, toks[:, -1:], cache)

    a = np.asarray(full_logits[:, -1], np.float32)
    b = np.asarray(step_logits[:, -1], np.float32)
    np.testing.assert_allclose(a, b, atol=0.15, rtol=0.05)


def test_all_param_shapes_match_config_table():
    """Full configs instantiate the exact published dimensions."""
    expect = {
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
    }
    for name, (L, D, H, KV, F, V) in expect.items():
        cfg = registry.get(name)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, D, H, KV, F, V), name
        shapes = cfg.param_shapes()      # must build without error
        assert len(shapes) > 3


def test_moe_configs():
    dbrx = registry.get("dbrx-132b")
    assert dbrx.moe.num_experts == 16 and dbrx.moe.top_k == 4
    q2 = registry.get("qwen2-moe-a2.7b")
    assert q2.moe.num_experts == 60 and q2.moe.top_k == 4
    assert q2.moe.shared_experts == 4
    jm = registry.get("jamba-1.5-large-398b")
    assert jm.moe.num_experts == 16 and jm.moe.top_k == 2
    assert jm.ssm.d_state == 128 and jm.attn_period == 8


def test_param_counts_match_published():
    """6·N·D roofline inputs: param counts within 10% of published sizes."""
    expect = {"chameleon-34b": 34e9, "stablelm-12b": 12e9,
              "command-r-plus-104b": 104e9, "glm4-9b": 9e9,
              "jamba-1.5-large-398b": 398e9, "dbrx-132b": 132e9,
              "mamba2-370m": 0.37e9}
    for name, n in expect.items():
        got = registry.get(name).param_count()
        assert abs(got - n) / n < 0.12, (name, got, n)
    # MoE active params
    assert abs(registry.get("dbrx-132b").active_param_count() - 36e9) < 4e9
    assert abs(registry.get("qwen2-moe-a2.7b").active_param_count() - 2.7e9) \
        < 0.5e9
