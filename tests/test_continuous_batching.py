"""Continuous-batching serving subsystem: slot cache, queue, batch invariance.

The load-bearing property is BATCH INVARIANCE: a request's greedy tokens must
be bit-identical whether it runs alone through ``Engine.generate`` or packed
into a slot batch with ragged neighbors (per-slot ``kv_len`` masking makes
each lane independent).  Checked here for both attention-cache families
(dense, moe) at every layer: raw per-slot cache ops, chunked prefill, and the
full ContinuousEngine scheduler loop.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import api
from repro.models.layers import gqa_attention, update_kv_cache
from repro.serving import engine as serving_engine
from repro.serving.batching import (ContinuousEngine, QueueFullError, Request,
                                    RequestQueue, RequestState, SamplingParams,
                                    SlotBatchManager)

MAX_LEN = 48


def _cfg(family: str):
    if family == "dense":
        return registry.reduced(registry.get("qwen3-1.7b"))
    cfg = registry.reduced(registry.get("qwen2-moe-a2.7b"))
    # a generous dispatch capacity keeps GShard token-dropping out of the
    # picture: capacity depends on the number of tokens in flight, so it is
    # the one MoE knob that could differ between packings (see
    # moe.prefill_chunk docstring)
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))


@pytest.fixture(scope="module", params=["dense", "moe"])
def harness(request):
    cfg = _cfg(request.param)
    params = api.build(cfg).init(cfg, jax.random.PRNGKey(0))
    sc = serving_engine.ServeConfig(max_len=MAX_LEN)
    eng = serving_engine.Engine(cfg, params, sc)
    return cfg, params, sc, eng


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (L,)).astype(np.int32) for L in lens]


def _solo_greedy(eng, prompt, steps):
    out = eng.generate(jnp.asarray(prompt[None]), steps)
    return np.asarray(out)[0].tolist()


# --------------------------------------------------------------- layer level

def test_update_kv_cache_per_slot_positions():
    rng = np.random.default_rng(0)
    B, T, KV, hd = 3, 8, 2, 4
    ck = jnp.zeros((B, T, KV, hd))
    cv = jnp.zeros((B, T, KV, hd))
    k = jnp.asarray(rng.normal(size=(B, 1, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, 1, KV, hd)), jnp.float32)
    pos = jnp.asarray([0, 3, 7], jnp.int32)
    nk, nv = update_kv_cache(ck, cv, k, v, pos)
    for b, p in enumerate([0, 3, 7]):
        np.testing.assert_array_equal(np.asarray(nk[b, p]),
                                      np.asarray(k[b, 0]))
        assert float(jnp.abs(nk[b, :p]).sum()) == 0.0
        np.testing.assert_array_equal(np.asarray(nv[b, p]),
                                      np.asarray(v[b, 0]))


def test_gqa_attention_per_slot_kv_len_matches_solo():
    """Ragged (B,) kv_len must equal running each row alone with its scalar."""
    rng = np.random.default_rng(1)
    B, T, H, KV, hd = 3, 16, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    lens = [4, 9, 16]
    packed = gqa_attention(q, k, v, causal=False,
                           kv_len=jnp.asarray(lens, jnp.int32))
    for b, L in enumerate(lens):
        solo = gqa_attention(q[b:b + 1], k[b:b + 1], v[b:b + 1], causal=False,
                             kv_len=jnp.int32(L))
        np.testing.assert_array_equal(np.asarray(packed[b:b + 1]),
                                      np.asarray(solo))


# --------------------------------------------------------------- model level

def test_prefill_chunk_matches_full_prefill(harness):
    cfg, params, sc, _ = harness
    mod = api.build(cfg)
    prompt = _prompts(cfg, [20])[0]
    logits_ref, cache_ref = mod.prefill(cfg, params, jnp.asarray(prompt[None]),
                                        max_len=MAX_LEN)
    chunk, P = 8, len(prompt)
    padded = np.zeros((1, 24), np.int32)
    padded[0, :P] = prompt
    cache = mod.init_cache(cfg, 1, MAX_LEN)
    last = None
    for c0 in range(0, 24, chunk):
        lg, cache = mod.prefill_chunk(cfg, params,
                                      jnp.asarray(padded[:, c0:c0 + chunk]),
                                      cache, jnp.full((1,), c0, jnp.int32))
        if c0 <= P - 1 < c0 + chunk:
            last = lg[:, P - 1 - c0][:, None]
    np.testing.assert_array_equal(np.asarray(last), np.asarray(logits_ref))
    np.testing.assert_array_equal(np.asarray(cache["k"][:, :, :P]),
                                  np.asarray(cache_ref["k"][:, :, :P]))
    np.testing.assert_array_equal(np.asarray(cache["v"][:, :, :P]),
                                  np.asarray(cache_ref["v"][:, :, :P]))


def test_slot_batch_decode_invariance(harness):
    """Greedy decode packed with ragged neighbors == each request alone."""
    cfg, params, sc, eng = harness
    mod = api.build(cfg)
    lens, steps = [20, 11, 7], 5
    prompts = _prompts(cfg, lens, seed=2)
    refs = [_solo_greedy(eng, p, steps) for p in prompts]

    B = len(prompts)
    cache = mod.init_cache(cfg, B, MAX_LEN)
    first = []
    for s, p in enumerate(prompts):
        lg, rc = mod.prefill(cfg, params, jnp.asarray(p[None]),
                             max_len=MAX_LEN)
        cache = jax.tree.map(
            lambda c, r: jax.lax.dynamic_update_slice_in_dim(
                c, r.astype(c.dtype), s, axis=1), cache, rc)
        first.append(int(jnp.argmax(lg[:, -1], -1)[0]))
    packed = [[f] for f in first]
    pos = jnp.asarray(lens, jnp.int32)
    tok = jnp.asarray(first, jnp.int32)[:, None]
    for i in range(steps - 1):
        lg, cache = mod.decode_step(cfg, params, tok, cache, pos + i)
        tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        for b in range(B):
            packed[b].append(int(tok[b, 0]))
    assert packed == refs


# -------------------------------------------------------------- engine level

def test_continuous_engine_matches_lockstep_engine(harness):
    """Full scheduler loop: more requests than slots, ragged everything."""
    cfg, params, sc, eng = harness
    jobs = list(zip(_prompts(cfg, [20, 11, 7, 25, 5, 16], seed=3),
                    [6, 9, 3, 5, 8, 4]))
    refs = [_solo_greedy(eng, p, g) for p, g in jobs]
    ce = ContinuousEngine(cfg, params, sc, n_slots=3, max_queue=16,
                          prefill_chunk=8, steps=eng.steps)
    rids = [ce.submit(p, g).rid for p, g in jobs]
    fin = {r.rid: r for r in ce.run()}
    assert [fin[r].output for r in rids] == refs
    assert all(fin[r].state is RequestState.FINISHED for r in rids)
    assert all(fin[r].finish_reason == "length" for r in rids)
    # completed requests detached without stalling: the batch never ran
    # max(gen) * ceil(n/slots) lockstep waves' worth of steps
    assert ce.n_decode_steps < sum(g for _, g in jobs)


def test_eos_detaches_early(harness):
    cfg, params, sc, eng = harness
    prompt = _prompts(cfg, [9], seed=4)[0]
    ref = _solo_greedy(eng, prompt, 8)
    eos = ref[2]                       # force a stop at the third token
    ce = ContinuousEngine(cfg, params, sc, n_slots=2, steps=eng.steps)
    req = ce.submit(prompt, 8, eos_id=eos)
    ce.run()
    assert req.output == ref[:3]
    assert req.finish_reason == "eos"


def test_sampled_requests_are_deterministic_per_seed(harness):
    cfg, params, sc, eng = harness
    prompt = _prompts(cfg, [10], seed=5)[0]

    def once(seed):
        ce = ContinuousEngine(cfg, params, sc, n_slots=2, steps=eng.steps)
        r = ce.submit(prompt, 6, sampling=SamplingParams(temperature=0.9,
                                                         seed=seed))
        ce.run()
        return r.output

    assert once(7) == once(7)
    assert once(7) != once(8)          # astronomically unlikely to collide


def test_poisson_trace_clamps_degenerate_bounds():
    from repro.serving.batching import poisson_trace
    trace = poisson_trace(5, rate_per_s=100.0, prompt_max=3, gen_max=1,
                          vocab=64, seed=0)
    assert len(trace) == 5
    assert all(len(p) == 3 and g == 1 for _, p, g in trace)
    assert trace[0][0] == 0.0                   # first arrival at t=0
    assert all(a <= b for (a, *_), (b, *_) in zip(trace, trace[1:]))


def test_moe_low_capacity_warns():
    import warnings
    cfg = registry.reduced(registry.get("qwen2-moe-a2.7b"))
    assert cfg.moe.capacity_factor * cfg.moe.top_k < cfg.moe.num_experts
    params = api.build(cfg).init(cfg, jax.random.PRNGKey(0))
    with pytest.warns(UserWarning, match="capacity_factor"):
        ContinuousEngine(cfg, params,
                         serving_engine.ServeConfig(max_len=MAX_LEN))


def test_unsupported_family_raises():
    cfg = registry.reduced(registry.get("mamba2-370m"))
    with pytest.raises(NotImplementedError, match="slot-batch"):
        ContinuousEngine(cfg, {}, serving_engine.ServeConfig(max_len=8))


def test_request_too_long_for_cache_rejected(harness):
    cfg, params, sc, eng = harness
    ce = ContinuousEngine(cfg, params, sc, n_slots=1, steps=eng.steps)
    with pytest.raises(ValueError, match="cache rows"):
        ce.submit(_prompts(cfg, [MAX_LEN])[0], 4)


# ---------------------------------------------------- queue + slot mechanics

def test_queue_bound_backpressure():
    q = RequestQueue(max_queue=2)
    mk = lambda: Request(prompt=np.ones(4, np.int32), max_new_tokens=2)
    q.submit(mk())
    q.submit(mk())
    with pytest.raises(QueueFullError):
        q.submit(mk())
    assert q.n_rejected == 1
    assert len(q) == 2


def test_queue_deadline_expiry():
    q = RequestQueue(max_queue=4)
    now = time.monotonic()
    dead = Request(prompt=np.ones(4, np.int32), max_new_tokens=2,
                   deadline_s=0.5)
    live = Request(prompt=np.ones(4, np.int32), max_new_tokens=2)
    q.submit(dead, now=now)
    q.submit(live, now=now)
    got = q.pop(now=now + 1.0)         # dead's deadline passed while queued
    assert got is live
    assert dead.state is RequestState.EXPIRED
    assert dead.finish_reason == "deadline"
    assert q.expired == [dead]
    assert q.pop(now=now + 1.0) is None


def test_slot_manager_alloc_release_compact():
    cfg = _cfg("dense")
    m = SlotBatchManager(cfg, n_slots=2, max_len=16)
    mod = api.build(cfg)
    req = Request(prompt=np.ones(4, np.int32), max_new_tokens=2)
    slot = m.alloc(req)
    assert slot == 0 and m.n_free == 1 and m.active == [0]
    rc = jax.tree.map(lambda c: jnp.ones_like(c[:, :1]),
                      mod.init_cache(cfg, 2, 16))
    m.insert(slot, rc, kv_len=4)
    assert m.kv_len[0] == 4
    assert float(jnp.abs(m.cache["k"][:, 0]).sum()) > 0
    got = m.release(slot)
    assert got is req and m.n_free == 2 and m.active == []
    # compaction zeroed the freed slot's rows
    assert float(jnp.abs(m.cache["k"][:, 0]).sum()) == 0.0
    assert m.kv_len[0] == 0


def test_slot_exhaustion_returns_none():
    cfg = _cfg("dense")
    m = SlotBatchManager(cfg, n_slots=1, max_len=8)
    mk = lambda: Request(prompt=np.ones(2, np.int32), max_new_tokens=1)
    assert m.alloc(mk()) == 0
    assert m.alloc(mk()) is None


# ------------------------------------------------------------ engine metrics

def test_generate_reports_both_throughputs(harness):
    cfg, params, sc, eng = harness
    prompt = jnp.asarray(_prompts(cfg, [8], seed=6)[0][None])
    out, m = eng.generate(prompt, 4, echo_metrics=True)
    assert out.shape == (1, 4)
    assert m["decode_tok_per_s"] > 0 and m["e2e_tok_per_s"] > 0
    assert m["tok_per_s"] == m["decode_tok_per_s"]   # legacy alias
    # e2e includes prefill + first token, so it can never beat pure decode
    assert m["e2e_tok_per_s"] <= m["decode_tok_per_s"] * 4 / 3 + 1e-6


def test_first_token_uses_fresh_subkey(harness):
    """Token 0 must be sampled from split(key)[1], not the parent key that
    the decode loop then re-splits (the pre-fix correlation bug)."""
    cfg, params, sc, eng = harness
    sampled_eng = serving_engine.Engine(
        cfg, params, dataclasses.replace(sc, temperature=1.0),
        steps=eng.steps)
    prompt = jnp.asarray(_prompts(cfg, [8], seed=7)[0][None])
    key = jax.random.PRNGKey(123)
    out = sampled_eng.generate(prompt, 1, key=key)
    logits, _ = eng.steps.prefill_fn(params, prompt)
    _, sub = jax.random.split(key)
    want = serving_engine.sample(logits, sub, 1.0)
    assert int(out[0, 0]) == int(want[0])
