"""Fused decode→dequant→matmul vs its oracles (kernel level).

Every case runs a three-way comparison (builders in ``qt_cases``):

* ``kernels.ref.fused_decode_matmul_ref`` — host serial decode through the
  numpy backend + the exact deq/dot ops (the oracle);
* the in-graph ``impl="jax"`` fused path — must match the oracle AND the
  eager unfused ``layers.matmul(x, QT)`` **bit for bit** (same ops, so any
  divergence is a decode bug, not float noise);
* ``impl="pallas-interpret"`` — the same kernel body the TPU compiles,
  interpreted on CPU; allclose only (MXU f32-accumulation order differs).

Fixed sweeps cover bits {2,3,4,8} × both codec families × the three
broadcastable granularities × skewed and constant histograms; the
quantizer-driven cases add PER_GROUP ragged-tail fallback QTs.  The
hypothesis fuzz layer rides the same builders in ``test_fused_fuzz.py``.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decode_backends import get_backend
from repro.core.quant import Granularity
from repro.core.scheduler import (fused_tile_reason, plan_fused_spans,
                                  tensor_segments)
from repro.core.spec import spec_from_legacy
from repro.core.store import CompressedModel
from repro.kernels.fused_decode_matmul import (build_fused_qt,
                                               fused_decode_matmul,
                                               lanes_per_tile)
from repro.kernels.ref import fused_decode_matmul_ref
from repro.models import layers

from . import qt_cases

CASES = [
    dict(bits=8, codec="huffman", K=8, N=16, seg=32),
    dict(bits=4, codec="huffman", K=8, N=16, seg=16,
         granularity="per_channel"),
    dict(bits=8, codec="rans", K=8, N=16, seg=32, granularity="per_row"),
    dict(bits=4, codec="rans", K=6, N=8, seg=24, skew=True),
    dict(bits=8, codec="huffman", K=4, N=8, seg=16, constant=3),
    dict(bits=2, codec="rans", K=8, N=16, seg=64),
    dict(bits=3, codec="huffman", K=9, N=8, seg=24, skew=True),
]

QCASES = [
    # ragged PER_GROUP tails fall back to per-channel inside quantize —
    # the fallback QT must flow through the fused kernel like any other
    dict(bits=8, codec="huffman", K=8, N=48, seg=48,
         granularity=Granularity.PER_GROUP, group=32),
    dict(bits=4, codec="rans", K=8, N=48, seg=96,
         granularity=Granularity.PER_GROUP, group=36),
    dict(bits=8, codec="rans", K=8, N=16, seg=32,
         granularity=Granularity.PER_TENSOR),
]

# the Pallas wrapper takes scalar or per-output-row scales (per-channel
# (K, 1) columns stay on the jax impl)
INTERPRET_CASES = [
    dict(bits=8, codec="huffman", K=8, N=16, seg=32),
    dict(bits=4, codec="rans", K=8, N=16, seg=32, granularity="per_row"),
]


def _oracle(c):
    return np.asarray(fused_decode_matmul_ref(
        c.x, c.mat, c.table, c.scale, c.zero,
        seg_symbols=c.seg, K=c.K, N=c.N))


def _fused(c, impl):
    fq = build_fused_qt(c.table, c.mat, c.scale, c.zero, seg_symbols=c.seg,
                        K=c.K, N=c.N, bits=c.bits, impl=impl)
    # through layers.matmul, so the dispatch hook is part of the test
    return np.asarray(layers.matmul(c.x, fq))


def _unfused(c):
    qt = layers.pack_qt(c.sym, c.scale, c.zero, bits=c.bits)
    qt = type(qt)(*(jnp.asarray(p) for p in qt))
    return np.asarray(layers.matmul(c.x, qt))


@pytest.mark.parametrize("kw", CASES, ids=qt_cases.case_id)
def test_jax_impl_matches_oracle_and_unfused_bitwise(kw):
    c = qt_cases.fused_case(**kw)
    oracle = _oracle(c)
    fused = _fused(c, "jax")
    unfused = _unfused(c)
    np.testing.assert_array_equal(fused, oracle)
    np.testing.assert_array_equal(fused, unfused)


@pytest.mark.parametrize("kw", QCASES, ids=qt_cases.case_id)
def test_quantized_tensor_cases_bitwise(kw):
    c = qt_cases.quantized_case(**kw)
    oracle = _oracle(c)
    fused = _fused(c, "jax")
    unfused = _unfused(c)
    np.testing.assert_array_equal(fused, oracle)
    np.testing.assert_array_equal(fused, unfused)


@pytest.mark.parametrize("kw", INTERPRET_CASES, ids=qt_cases.case_id)
def test_pallas_interpret_close_to_oracle(kw):
    c = qt_cases.fused_case(**kw)
    got = _fused(c, "pallas-interpret").astype(np.float32)
    oracle = _oracle(c).astype(np.float32)
    np.testing.assert_allclose(got, oracle, rtol=1e-2, atol=1e-2)


# -------------------------------------------------------- backend registry

def test_backend_fused_registry_parity():
    """The numpy backend's fused path (host decode + same ops) and the jax
    backend's in-graph path answer identically through the registry."""
    c = qt_cases.fused_case(bits=8, codec="rans", K=8, N=16, seg=32)
    outs = {}
    for name in ("numpy", "jax"):
        b = get_backend(name)
        assert b.fused_available()
        assert b.fused_families() == ["prefix", "tans"]
        outs[name] = np.asarray(b.fused_matmul(
            c.table, c.x, c.mat, c.scale, c.zero,
            seg_symbols=c.seg, K=c.K, N=c.N, bits=c.bits))
    np.testing.assert_array_equal(outs["numpy"], outs["jax"])


def test_backend_without_family_raises():
    class Bogus:
        kernel = "bogus"

    c = qt_cases.fused_case(bits=8, codec="huffman", K=4, N=8, seg=16)
    with pytest.raises(RuntimeError, match="no fused 'bogus'"):
        get_backend("numpy").fused_matmul(
            Bogus(), c.x, c.mat, c.scale, c.zero,
            seg_symbols=c.seg, K=c.K, N=c.N)


# -------------------------------------------------------- contract checks

def test_build_fused_qt_rejects_misaligned_geometry():
    c = qt_cases.fused_case(bits=8, codec="huffman", K=8, N=16, seg=32)
    with pytest.raises(ValueError, match="dense geometry"):
        build_fused_qt(c.table, c.mat, c.scale, c.zero, seg_symbols=c.seg,
                       K=c.K + 1, N=c.N, bits=c.bits)
    # same symbol total, but segments no longer tile rows of width N
    with pytest.raises(ValueError, match="tile rows"):
        build_fused_qt(c.table, c.mat, c.scale, c.zero, seg_symbols=c.seg,
                       K=2, N=64, bits=c.bits)


def test_lanes_per_tile_is_largest_divisor():
    assert lanes_per_tile(256) == 128
    assert lanes_per_tile(128) == 128
    assert lanes_per_tile(12) == 12
    assert lanes_per_tile(130) == 65
    assert lanes_per_tile(6, cap=4) == 3


def test_fused_tile_reason_and_spans():
    """The scheduler's eligibility classifier and whole-segment span
    planner, one tensor per failure mode."""
    rng = np.random.default_rng(0)
    host = {
        "layers/w_a": rng.normal(0, 0.05, (2, 64, 32)).astype(np.float32),
        "layers/w_b": rng.normal(0, 0.05, (2, 80, 32)).astype(np.float32),
        "layers/w_c": rng.normal(0, 0.05, (4, 64, 32)).astype(np.float32),
        "layers/w_d": rng.normal(0, 0.05, (2, 2, 32, 32)).astype(np.float32),
        "layers/w_e": rng.normal(0, 0.05, (2, 72, 32)).astype(np.float32),
    }
    cm = CompressedModel.compress(host, spec=spec_from_legacy(
        8, Granularity.PER_TENSOR, segment_symbols=1024))
    assert fused_tile_reason(cm, 2, "layers/w_a") is None
    assert "whole number" in fused_tile_reason(cm, 2, "layers/w_b")
    assert "n_layers" in fused_tile_reason(cm, 2, "layers/w_c")
    assert "stacked (L, K, N)" in fused_tile_reason(cm, 2, "layers/w_d")
    assert "ragged tail" in fused_tile_reason(cm, 2, "layers/w_e")

    spans = plan_fused_spans(cm, 2, ["layers/w_a"])["layers/w_a"]
    assert [sp.layer for sp in spans] == [0, 1]
    assert all(len(sp.segs) == 2 and sp.seg_symbols == 1024 for sp in spans)
    # spans partition the tensor's segments, in order, with no trims
    assert [s.index for sp in spans for s in sp.segs] \
        == [s.index for s in tensor_segments(cm, "layers/w_a")]
    with pytest.raises(ValueError, match="whole number"):
        plan_fused_spans(cm, 2, ["layers/w_b"])
