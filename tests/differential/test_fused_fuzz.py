"""Hypothesis fuzz over the fused-kernel differential builders.

Same three-way comparison as the fixed sweeps in ``test_fused_kernel.py``
— fused jax impl vs the serial-decode oracle vs the eager unfused QT path,
bit for bit — but with hypothesis drawing the geometry, bit width, codec,
granularity, and histogram shape (skewed → zero-width alphabet entries,
constant → single-support).  Runs under the deterministic profile
registered in conftest (derandomize, fixed per-test seeds), so tier-1 sees
the same examples every time.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given

from repro.kernels.ref import fused_decode_matmul_ref

from . import qt_cases
from .test_fused_kernel import _fused, _oracle, _unfused


@given(kw=qt_cases.fused_case_kwargs())
def test_fuzz_jax_matches_oracle_and_unfused(kw):
    c = qt_cases.fused_case(**kw)
    oracle = _oracle(c)
    fused = _fused(c, "jax")
    unfused = _unfused(c)
    np.testing.assert_array_equal(fused, oracle)
    np.testing.assert_array_equal(fused, unfused)


@given(kw=qt_cases.fused_case_kwargs())
def test_fuzz_decoded_symbols_round_trip(kw):
    """The lane matrix really holds the case's symbols: decode through the
    oracle path with an identity dequant (scale=1, zero=0) and an identity
    activation, recovering the (K, N) symbol block exactly."""
    import jax.numpy as jnp
    c = qt_cases.fused_case(**kw)
    eye = jnp.eye(c.K, dtype=jnp.float32)
    one = np.ones((1, 1), np.float32)
    out = np.asarray(fused_decode_matmul_ref(
        eye, c.mat, c.table, one, np.zeros((1, 1), np.float32),
        seg_symbols=c.seg, K=c.K, N=c.N))
    np.testing.assert_array_equal(out.astype(np.uint8), c.sym)
