"""Differential harness: fused decode→dequant→matmul vs its oracles.

Package so the test modules can share the ``qt_cases`` builders via a
relative import (tests/ itself is not a package — pytest imports these
modules as ``differential.*``).
"""
