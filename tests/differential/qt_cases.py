"""Case builders shared by the differential harness (tests/differential/).

Two layers:

* **Deterministic builders** — plain numpy, importable with no dev extras.
  :func:`fused_case` builds a symbol-level case (scale/zero chosen
  directly, per granularity); :func:`quantized_case` drives the real
  quantizer first, so the encoded symbols come from an actual
  :class:`~repro.core.quant.QuantizedTensor` — including the PER_GROUP
  ragged-tail fallback path.
* **Hypothesis strategies** — :func:`fused_case_kwargs` draws builder
  kwargs (bits, codec, granularity, geometry, skewed/constant histograms
  with zero-width alphabet entries).  Imported lazily: only the fuzz
  modules, which ``importorskip("hypothesis")``, ever call it.

Every case lays out its lane matrix exactly like
``serving.resident.CompressedResidentWeights._build_fused_slots``:
per-segment encode, then a guard-padded ``pack_streams`` at one pow2
width — so what the tests feed the kernel is what serving feeds it.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import numpy as np

CODECS = ("huffman", "rans")
# scale/zero shapes the fused contract admits on a 2-D (K, N) case:
# scalar, per-input-channel column, per-output-channel row
GRANULARITIES = ("per_tensor", "per_channel", "per_row")


@dataclasses.dataclass
class FusedCase:
    """Everything the differential tests compare against each other."""

    table: object            # codec CodeTable (prefix or tans family)
    mat: np.ndarray          # (S, B) packed lane matrix, guard-padded
    sym: np.ndarray          # (K, N) uint8 ground-truth symbols
    scale: np.ndarray
    zero: np.ndarray
    x: object                # (M, K) bf16 activation batch (jax array)
    seg: int
    K: int
    N: int
    bits: int


def symbols(bits: int, n: int, *, seed: int = 0, skew: bool = False,
            constant: Optional[int] = None) -> np.ndarray:
    """Uint8 symbol vector.  ``skew`` draws from a narrow normal so most
    alphabet entries have zero frequency (zero-width codes); ``constant``
    collapses the whole tensor to one value."""
    hi = (1 << bits) - 1
    if constant is not None:
        return np.full(n, int(constant) % (hi + 1), np.uint8)
    rng = np.random.default_rng(seed)
    if skew:
        vals = np.rint(rng.normal(hi / 2.0, max(hi / 8.0, 0.5), n))
        return np.clip(vals, 0, hi).astype(np.uint8)
    return rng.integers(0, hi + 1, n).astype(np.uint8)


def build_table(codec: str, sym: np.ndarray, bits: int):
    """Codec table from the case's own histogram.  Single-support
    histograms get one phantom count on a neighbouring symbol so both
    codecs can build a table; the phantom symbol never occurs in the
    streams (a zero-width-in-practice entry)."""
    from repro.core.codecs import get_codec
    freqs = np.bincount(sym, minlength=1 << bits).astype(np.int64)
    if np.count_nonzero(freqs) < 2:
        freqs[(int(sym.flat[0]) + 1) % (1 << bits)] += 1
    return get_codec(codec).build(freqs, bits, max_code_len=12)


def encode_lanes(table, sym: np.ndarray, seg: int) -> np.ndarray:
    """Per-segment encode + guard-padded pack at one pow2 width — the
    resident builder's exact layout for a layer slice."""
    from repro.core.bitstream import GUARD_BYTES, pack_streams, pow2_bucket
    streams = [table.encode(sym[i:i + seg])[0]
               for i in range(0, sym.size, seg)]
    width = pow2_bucket(max(GUARD_BYTES, max(s.size for s in streams)), 64)
    mat, _ = pack_streams(streams, min_width=width)
    return mat


def fused_case(*, bits: int, codec: str, K: int, N: int, seg: int,
               seed: int = 0, skew: bool = False,
               constant: Optional[int] = None,
               granularity: str = "per_tensor", m: int = 3) -> FusedCase:
    """Symbol-level case: symbols, table, lane matrix, scale/zero of the
    requested granularity, and a bf16 activation batch."""
    import jax.numpy as jnp
    assert seg % N == 0 and (K * N) % seg == 0, (K, N, seg)
    sym = symbols(bits, K * N, seed=seed, skew=skew, constant=constant)
    table = build_table(codec, sym, bits)
    mat = encode_lanes(table, sym, seg)
    rng = np.random.default_rng(seed + 1)
    shape = {"per_tensor": (1, 1), "per_channel": (K, 1),
             "per_row": (1, N)}[granularity]
    scale = (0.005 + rng.random(shape) * 0.02).astype(np.float32)
    zero = (rng.random(shape) * 0.2 - 0.1).astype(np.float32)
    x = jnp.asarray(rng.normal(0.0, 1.0, (m, K)), jnp.bfloat16)
    return FusedCase(table=table, mat=mat, sym=sym.reshape(K, N),
                     scale=scale, zero=zero, x=x, seg=seg, K=K, N=N,
                     bits=bits)


def quantized_case(*, bits: int, codec: str, K: int, N: int, seg: int,
                   granularity, group: int = 128, seed: int = 0,
                   m: int = 3) -> FusedCase:
    """Quantizer-driven case: a float matrix through ``quant.quantize``.
    PER_GROUP with a group that does not divide N warns and falls back to
    per-channel — that fallback QT is exactly what this builder encodes
    (aligned PER_GROUP scales are not broadcastable against (K, N) and
    never reach the fused path; callers pass ragged groups only)."""
    import jax.numpy as jnp
    from repro.core import quant
    rng = np.random.default_rng(seed)
    w = rng.normal(0.0, 0.05, (K, N)).astype(np.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")       # ragged tails warn by design
        qt = quant.quantize(w, bits, granularity, group=group)
    assert qt.granularity is not quant.Granularity.PER_GROUP, \
        "aligned PER_GROUP scales cannot broadcast against (K, N)"
    sym = qt.q.reshape(-1)
    table = build_table(codec, sym, bits)
    mat = encode_lanes(table, sym, seg)
    x = jnp.asarray(rng.normal(0.0, 1.0, (m, K)), jnp.bfloat16)
    return FusedCase(table=table, mat=mat, sym=qt.q.reshape(K, N),
                     scale=np.asarray(qt.scale), zero=np.asarray(qt.zero),
                     x=x, seg=seg, K=K, N=N, bits=bits)


def case_id(kw: dict) -> str:
    """Readable pytest id for a builder-kwargs dict."""
    parts = [f"{kw['codec']}{kw['bits']}",
             f"{kw['K']}x{kw['N']}s{kw['seg']}"]
    gran = kw.get("granularity", "per_tensor")
    gran = getattr(gran, "value", gran)
    if gran != "per_tensor":
        parts.append(str(gran))
    if kw.get("group"):
        parts.append(f"g{kw['group']}")
    if kw.get("skew"):
        parts.append("skew")
    if kw.get("constant") is not None:
        parts.append(f"const{kw['constant']}")
    return "-".join(parts)


def fused_case_kwargs():
    """Hypothesis strategy over :func:`fused_case` kwargs.  Lazy import:
    call only under ``pytest.importorskip("hypothesis")``."""
    from hypothesis import strategies as st

    def _assemble(geom, bits, codec, seed, skew, constant, gran):
        n, rows_per_seg, lanes = geom
        return dict(bits=bits, codec=codec, N=n, seg=n * rows_per_seg,
                    K=lanes * rows_per_seg, seed=seed, skew=skew,
                    constant=constant, granularity=gran)

    return st.builds(
        _assemble,
        st.tuples(st.sampled_from((8, 16)),      # N (row width)
                  st.integers(1, 3),             # rows per segment
                  st.integers(2, 4)),            # lanes (segments)
        st.sampled_from((2, 3, 4, 8)),
        st.sampled_from(CODECS),
        st.integers(0, 2 ** 16),
        st.booleans(),
        st.one_of(st.none(), st.integers(0, 3)),
        st.sampled_from(GRANULARITIES),
    )
