"""End-to-end identity: fused-resident serving vs unfused vs dense-QT.

The acceptance gate for the fused decode→dequant→matmul kernel: switching
``CompressedResidentWeights(fused=True)`` must not change a single greedy
token — for both attention-cache families (dense, moe), through both front
ends (lockstep ``Engine.generate`` and the continuous-batching scheduler),
and for mixed rans4+huffman8 containers.  Tensors the tile contract
rejects fall back **per-tensor** (never per-model) with a recorded reason:
moe's 4-D expert stacks are the standing example, and a misaligned
segment size exercises the same path on dense.

The module-scoped harness consumes the ``rng_seed`` fixture, so CI's
flake-audit job (``--rng-repeats 3``) re-derives the model weights from
distinct PRNG keys and re-runs every identity check.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core.quant import Granularity
from repro.core.spec import CompressionSpec, spec_from_legacy
from repro.core.store import CompressedModel
from repro.kernels.fused_decode_matmul import FusedQT
from repro.models import api
from repro.models.layers import QT, QT4
from repro.serving import engine as serving_engine
from repro.serving.batching import ContinuousEngine
from repro.serving.resident import CompressedResidentWeights

MAX_LEN = 32
SEGMENT = 1024
CHUNK = 64 * 1024


def _cfg(family: str):
    if family == "dense":
        return registry.reduced(registry.get("qwen3-1.7b"))
    cfg = registry.reduced(registry.get("qwen2-moe-a2.7b"))
    return dataclasses.replace(
        cfg, d_model=64, n_heads=2, n_kv_heads=2, d_ff=64,
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))


def _compress(cfg, seed, spec=None):
    params = api.build(cfg).init(cfg, jax.random.PRNGKey(seed))
    host = {k: np.asarray(v, np.float32) for k, v in params.items()}
    if spec is None:
        spec = spec_from_legacy(8, Granularity.PER_CHANNEL,
                                segment_symbols=SEGMENT)
    return CompressedModel.compress(host, spec=spec)


def _prompt(cfg, batch, length, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, (batch, length)).astype(np.int32)


def _short(name):
    return name.split("/", 1)[1]


@pytest.fixture(scope="module", params=["dense", "moe"])
def fused_harness(request, rng_seed):
    cfg = _cfg(request.param)
    cm = _compress(cfg, rng_seed)
    qparams = serving_engine.load_params_from_compressed(cm, quantized=True)
    unfused = CompressedResidentWeights(cm, cfg, chunk_symbols=CHUNK)
    fused = CompressedResidentWeights(cm, cfg, chunk_symbols=CHUNK,
                                      fused=True)
    sc = serving_engine.ServeConfig(max_len=MAX_LEN)
    return cfg, cm, qparams, unfused, fused, sc


# ------------------------------------------------------------ slot level

def test_every_tensor_fused_or_fallback_with_reason(fused_harness):
    cfg, cm, _, unfused, fused, _ = fused_harness
    assert fused._fused                       # something actually fused
    assert sorted(fused._fused + list(fused.fused_fallback)) \
        == sorted(unfused._hosted)
    slot = fused.get(0)
    for name in fused._fused:
        assert isinstance(slot[_short(name)], FusedQT)
    for name, reason in fused.fused_fallback.items():
        assert isinstance(slot[_short(name)], (QT, QT4))
        assert reason                          # every fallback says why
    if cfg.family == "moe":
        experts = [n for n in fused.fused_fallback
                   if len(cm.tensors[n].shape) == 4]
        assert experts                         # (L, E, D, F) stacks
        assert all("stacked (L, K, N)" in fused.fused_fallback[n]
                   for n in experts)
        # 2-D-per-layer attention weights still fuse alongside them
        assert any(n.endswith(("wq", "wk", "wv", "wo"))
                   for n in fused._fused)
    else:
        assert not fused.fused_fallback        # dense fuses everything


def test_fused_peak_accounting_consistent(fused_harness):
    _, _, _, unfused, fused, _ = fused_harness
    b = fused.resident_bytes()
    peak = fused.peak_resident_bytes()
    assert peak == (b["payload"] + b["tables"] + b["qmeta"] + b["globals"]
                    + b["stacked"] + b["scratch"] + 2 * b["layer_slot"])
    assert peak < fused.dense_bf16_bytes()
    # fused handles keep the payload resident on device; the *hosted*
    # (fallback) slot pair can only shrink relative to the unfused build
    assert b["layer_slot"] <= unfused.resident_bytes()["layer_slot"]


# ---------------------------------------------------------- engine level

def test_fused_lockstep_bit_identity(fused_harness):
    cfg, _, qparams, unfused, fused, sc = fused_harness
    prompt = _prompt(cfg, 2, 8)
    ref = np.asarray(
        serving_engine.Engine(cfg, qparams, sc).generate(prompt, 6))
    out_unfused = np.asarray(serving_engine.Engine(
        cfg, unfused, sc, resident="compressed").generate(prompt, 6))
    out_fused = np.asarray(serving_engine.Engine(
        cfg, fused, sc, resident="compressed").generate(prompt, 6))
    np.testing.assert_array_equal(ref, out_unfused)
    np.testing.assert_array_equal(ref, out_fused)


def test_fused_continuous_batching_bit_identity(fused_harness):
    cfg, _, qparams, _, fused, sc = fused_harness
    comp = ContinuousEngine(cfg, fused, sc, n_slots=2, prefill_chunk=8,
                            resident="compressed")
    ref = ContinuousEngine(cfg, qparams, sc, n_slots=2, prefill_chunk=8)
    for eng in (comp, ref):
        for i in range(2):
            eng.submit(_prompt(cfg, 1, 5 + i, seed=i)[0], 4)
        eng.run()
    assert [r.output for r in comp.finished] \
        == [r.output for r in ref.finished]
    assert all(len(r.output) == 4 for r in comp.finished)


# ------------------------------------------------------ mixed containers

def test_fused_mixed_rans4_huffman8_bit_identity(rng_seed):
    """One container, two codec families and two bit widths, all fused:
    4-bit rans (tans kernel) for the MLP weights, 8-bit huffman (prefix
    kernel) for attention — greedy-identical to the dense-QT engine."""
    cfg = _cfg("dense")
    spec = CompressionSpec.parse(
        f"defaults:segment_symbols={SEGMENT};"
        f"layers/*w_*:bits=4,codec=rans",
        default_granularity=Granularity.PER_CHANNEL)
    cm = _compress(cfg, rng_seed, spec=spec)
    assert sorted(cm.tables) == ["huffman8", "rans4"]
    fused = CompressedResidentWeights(cm, cfg, chunk_symbols=CHUNK,
                                      fused=True)
    assert not fused.fused_fallback
    handles = [fq for slots in fused._fused_slots for fq in slots.values()]
    assert {fq.family for fq in handles} == {"prefix", "tans"}
    assert {fq.bits for fq in handles} == {4, 8}
    qparams = serving_engine.load_params_from_compressed(cm, quantized=True)
    sc = serving_engine.ServeConfig(max_len=MAX_LEN)
    prompt = _prompt(cfg, 1, 7)
    ref = np.asarray(
        serving_engine.Engine(cfg, qparams, sc).generate(prompt, 5))
    out = np.asarray(serving_engine.Engine(
        cfg, fused, sc, resident="compressed").generate(prompt, 5))
    np.testing.assert_array_equal(ref, out)


# ----------------------------------------------------- fallback behavior

def test_misaligned_segments_fall_back_per_tensor(rng_seed):
    """A segment size that violates the tile contract (1000 symbols never
    tiles the reduced model's row widths) must not disable the mode: every
    tensor falls back to the per-layer QT path with a recorded reason, and
    the engine stays bit-identical."""
    cfg = _cfg("dense")
    cm = _compress(cfg, rng_seed, spec=spec_from_legacy(
        8, Granularity.PER_CHANNEL, segment_symbols=1000))
    fused = CompressedResidentWeights(cm, cfg, chunk_symbols=CHUNK,
                                      fused=True)
    assert not fused._fused
    assert sorted(fused.fused_fallback) == sorted(fused._hosted)
    slot = fused.get(0)
    assert all(isinstance(slot[_short(n)], (QT, QT4))
               for n in fused._hosted)
    qparams = serving_engine.load_params_from_compressed(cm, quantized=True)
    sc = serving_engine.ServeConfig(max_len=MAX_LEN)
    prompt = _prompt(cfg, 1, 6)
    ref = np.asarray(
        serving_engine.Engine(cfg, qparams, sc).generate(prompt, 4))
    out = np.asarray(serving_engine.Engine(
        cfg, fused, sc, resident="compressed").generate(prompt, 4))
    np.testing.assert_array_equal(ref, out)


def test_serve_cli_fused_requires_compressed_resident():
    from repro.launch import serve
    with pytest.raises(SystemExit):
        serve.main(["--arch", "qwen3-1.7b", "--fused"])
