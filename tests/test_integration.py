"""End-to-end integration: train-to-convergence, fault tolerance, the
compress -> parallel-decode -> serve path, gradient compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import api


def _train(arch="qwen3-1.7b", steps=15, q8=False, grad_compress=False, mb=1):
    from repro.data.pipeline import DataConfig, SyntheticSource
    from repro.training import optimizer as opt, train_loop
    cfg = registry.reduced(registry.get(arch))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    tc = train_loop.TrainConfig(
        opt=opt.AdamWConfig(
            schedule=opt.Schedule(base_lr=1e-3, warmup_steps=2,
                                  total_steps=steps),
            quantized_state=q8),
        microbatches=mb, grad_compress=grad_compress)
    state = opt.init_state(tc.opt, params)
    src = SyntheticSource(DataConfig(vocab=cfg.vocab, seq_len=64,
                                     global_batch=8, seed=0))
    return cfg, train_loop.train(cfg, tc, params, state, iter(src), steps)


def test_training_reduces_loss():
    _, (params, state, info) = _train()
    losses = [h["loss"] for h in info["history"]]
    assert losses[-1] < losses[0] - 0.05


def test_training_q8_matches_fp32_trajectory():
    """EntroLLM-quantized optimizer state trains as well as fp32 moments."""
    _, (_, _, info32) = _train(q8=False)
    _, (_, _, info8) = _train(q8=True)
    l32 = info32["history"][-1]["loss"]
    l8 = info8["history"][-1]["loss"]
    assert abs(l32 - l8) < 0.15


def test_training_with_grad_compression_converges():
    _, (_, _, info) = _train(grad_compress=True)
    losses = [h["loss"] for h in info["history"]]
    assert losses[-1] < losses[0] - 0.05


def test_microbatched_equals_single_batch_grads():
    """Grad accumulation is numerically consistent with the fused batch."""
    from repro.training import optimizer as opt, train_loop
    cfg = registry.reduced(registry.get("glm4-9b"))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 65), 0,
                                          cfg.vocab)}
    outs = {}
    for mb in (1, 2):
        tc = train_loop.TrainConfig(opt=opt.AdamWConfig(), microbatches=mb)
        state = opt.init_state(tc.opt, params)
        step = jax.jit(train_loop.make_train_step(cfg, tc))
        p2, _, m = step(params, state, batch)
        outs[mb] = (p2, float(m["loss"]))
    assert abs(outs[1][1] - outs[2][1]) < 0.02
    for k in params:
        np.testing.assert_allclose(
            np.asarray(outs[1][0][k], np.float32),
            np.asarray(outs[2][0][k], np.float32), atol=5e-3)


# ------------------------------------------------------------- fault tolerance

def test_checkpoint_restart_resumes_exactly():
    """Kill-and-restart: restored (params, opt, step) continue bit-identically
    (data stream is a pure function of step index)."""
    from repro.checkpoint.checkpointer import Checkpointer, CheckpointConfig
    from repro.data.pipeline import DataConfig, SyntheticSource
    from repro.training import optimizer as opt, train_loop
    cfg = registry.reduced(registry.get("qwen3-1.7b"))
    mod = api.build(cfg)
    tc = train_loop.TrainConfig(opt=opt.AdamWConfig(
        schedule=opt.Schedule(base_lr=1e-3, warmup_steps=2, total_steps=20)))
    src = SyntheticSource(DataConfig(vocab=cfg.vocab, seq_len=64,
                                     global_batch=8, seed=0))
    step = jax.jit(train_loop.make_train_step(cfg, tc))

    # uninterrupted 6-step run
    p = mod.init(cfg, jax.random.PRNGKey(0))
    s = opt.init_state(tc.opt, p)
    for i in range(6):
        p, s, _ = step(p, s, src.batch(i))
    ref = {k: np.asarray(v, np.float32) for k, v in p.items()}

    # run 3 steps, checkpoint, "crash", restore, run 3 more
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(CheckpointConfig(root=d))
        p2 = mod.init(cfg, jax.random.PRNGKey(0))
        s2 = opt.init_state(tc.opt, p2)
        for i in range(3):
            p2, s2, _ = step(p2, s2, src.batch(i))
        ck.save(3, (p2, s2))
        del p2, s2
        start, (p3, s3) = ck.restore(like=(mod.init(cfg, jax.random.PRNGKey(0)),
                                           opt.init_state(tc.opt, mod.init(
                                               cfg, jax.random.PRNGKey(0)))))
        assert start == 3
        for i in range(3, 6):
            p3, s3, _ = step(p3, s3, src.batch(i))
    for k in ref:
        np.testing.assert_allclose(ref[k], np.asarray(p3[k], np.float32),
                                   atol=1e-5)


def test_nan_watchdog_rolls_back():
    from repro.checkpoint.checkpointer import Checkpointer, CheckpointConfig
    from repro.distributed.fault_tolerance import NanWatchdog
    from repro.training import optimizer as opt
    cfg = registry.reduced(registry.get("qwen3-1.7b"))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    state = opt.init_state(opt.AdamWConfig(), params)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(CheckpointConfig(root=d))
        ck.save(1, (params, state))
        wd = NanWatchdog(ck, (params, state))
        out = wd(5, params, state, {"loss": float("nan"), "grad_norm": 1.0})
        assert out is not None          # rollback triggered
        assert wd.rollbacks == [5]
        out2 = wd(6, params, state, {"loss": 2.0, "grad_norm": 1.0})
        assert out2 is None


def test_straggler_watchdog_and_rebalance():
    from repro.distributed.fault_tolerance import (StepTimeWatchdog,
                                                   suggest_rebalance)
    wd = StepTimeWatchdog(threshold=2.0)
    for i in range(10):
        assert wd.observe(i, 0.1) is None
    assert wd.observe(10, 0.5) == 10          # 5x median -> flagged
    assign = suggest_rebalance({0: 1.0, 1: 5.0, 2: 1.2, 3: 0.9}, hosts=2)
    assert set(assign) == {0, 1, 2, 3}
    loads = [sum(t for s, t in {0: 1.0, 1: 5.0, 2: 1.2, 3: 0.9}.items()
                 if assign[s] == h) for h in range(2)]
    assert max(loads) <= 5.1                  # LPT keeps the big shard alone


def test_elastic_reshard_restore():
    """Restore a checkpoint onto a different device layout (1-dev host mesh)."""
    from repro.checkpoint.checkpointer import Checkpointer, CheckpointConfig
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_mesh((1, 1), ("data", "model"))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(CheckpointConfig(root=d))
        ck.save(7, tree)
        shard = {"w": NamedSharding(mesh, P("data", None))}
        step, out = ck.restore(like=tree, shardings=shard)
    assert step == 7
    assert out["w"].sharding.is_equivalent_to(shard["w"], 2)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


# ----------------------------------------------------- compress -> serve path

def test_compress_serve_equivalence():
    """QT-resident serving must produce the same logits as serving the densely
    dequantized weights (the quantized model IS the served model)."""
    from repro.core.quant import Granularity
    from repro.core.store import CompressedModel
    from repro.serving import engine
    cfg = registry.reduced(registry.get("glm4-9b"))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    host = {k: np.asarray(v, np.float32) for k, v in params.items()}
    cm = CompressedModel.compress(host, bits=8,
                                  granularity=Granularity.PER_CHANNEL)

    qt_params = engine.load_params_from_compressed(cm, quantized=True)
    dense_params = engine.load_params_from_compressed(cm, quantized=False)

    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)
    lq, _ = jax.jit(lambda p, t: mod.prefill(cfg, p, t, max_len=16))(
        qt_params, toks)
    ld, _ = jax.jit(lambda p, t: mod.prefill(cfg, p, t, max_len=16))(
        dense_params, toks)
    np.testing.assert_allclose(np.asarray(lq, np.float32),
                               np.asarray(ld, np.float32), atol=0.2, rtol=0.1)


def test_compression_stats_sane():
    from repro.core.store import CompressedModel
    rng = np.random.default_rng(0)
    # peaky trained-like weights -> entropy clearly below 8 bits
    params = {"w": (rng.standard_t(4, size=(64, 4096)) * 0.02).astype(np.float32)}
    cm = CompressedModel.compress(params, bits=8)
    st = cm.stats()
    assert st.effective_bits < 7.0
    assert st.entropy_bits <= st.effective_bits <= st.entropy_bits + 1.0
    assert st.reduction_vs_quant > 0.1


def test_entro_checkpoint_roundtrip_bounded_error():
    from repro.checkpoint.checkpointer import Checkpointer, CheckpointConfig
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(0, 0.02, (64, 512)), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(CheckpointConfig(root=d, compress="entro"))
        ck.save(1, tree)
        _, out = ck.restore(like=tree)
    err = np.abs(np.asarray(out["w"]) - np.asarray(tree["w"])).max()
    assert err < 0.02 * 256 / 255 / 2 + 1e-5   # half quantization step


def test_entro_checkpoint_spec_patterns_match_tree_paths():
    """entro_spec rules match the pytree key path (leaf names carry it), so a
    carve-out like '*/mu/*:fp32' actually protects the optimizer moments."""
    from repro.checkpoint.checkpointer import Checkpointer, CheckpointConfig
    from repro.core.store import CompressedModel
    rng = np.random.default_rng(1)
    tree = {"params": {"wq": jnp.asarray(rng.normal(0, 0.02, (64, 256)),
                                         jnp.float32)},
            "opt": {"mu": {"wq": jnp.asarray(rng.normal(0, 0.001, (64, 256)),
                                             jnp.float32)}}}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(CheckpointConfig(
            root=d, compress="entro",
            entro_spec="*/mu/*:fp32; */params/*:bits=8,codec=rans"))
        ck.save(1, tree)
        step_dir = os.path.join(d, "step_000000001")
        cm = CompressedModel.load(os.path.join(step_dir,
                                               "shard_00000_entro.npz"))
        # the fp32 carve-out fired for the moment leaf: exact round-trip
        assert any("opt/mu/wq" in n for n in cm.unquantized), cm.unquantized
        assert any("params/wq" in n for n in cm.qmeta), list(cm.qmeta)
        _, out = ck.restore(like=tree)
    assert np.array_equal(np.asarray(out["opt"]["mu"]["wq"]),
                          np.asarray(tree["opt"]["mu"]["wq"]))


def test_ef_gradient_compression_unbiased():
    from repro.distributed import grad_compress as gc
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(0, 0.01, (4096,)), jnp.float32)}
    res = None
    acc = jnp.zeros(4096)
    for _ in range(30):
        c, res = gc.ef_compress(g, res)
        acc = acc + c["w"]
    assert float(jnp.abs(acc / 30 - g["w"]).max()) < 1e-4
    ratio = gc.wire_bytes(g, compressed=True) / gc.wire_bytes(g, compressed=False)
    assert ratio < 0.3


def test_int8_kv_cache_matches_bf16():
    """H3 optimization: int8 KV cache decode matches bf16-cache decode."""
    from repro.models import dense
    cfg = registry.reduced(registry.get("qwen3-1.7b"))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    logits, c16 = jax.jit(lambda p, t: mod.prefill(cfg, p, t, max_len=S + 4))(
        params, toks)
    kq, ks = dense.quantize_kv(c16["k"])
    vq, vs = dense.quantize_kv(c16["v"])
    c8 = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    step = jax.jit(lambda p, t, c: mod.decode_step(cfg, p, t, c, S))
    l16, _ = step(params, tok, c16)
    l8, n8 = step(params, tok, c8)
    a, b = np.asarray(l16, np.float32), np.asarray(l8, np.float32)
    assert np.abs(a - b).max() < 0.5
    assert (a.argmax(-1) == b.argmax(-1)).all()
    assert n8["k"].dtype == jnp.int8          # cache stays quantized


def test_ste_compressed_gather_training_converges():
    """H2 machinery: QTG straight-through training at 8/4-bit weight gathers
    tracks the fp32 loss trajectory."""
    finals = {}
    for bits in (0, 8, 4):
        from repro.data.pipeline import DataConfig, SyntheticSource
        from repro.training import optimizer as opt, train_loop
        cfg = registry.reduced(registry.get("qwen3-1.7b"))
        mod = api.build(cfg)
        params = mod.init(cfg, jax.random.PRNGKey(0))
        tc = train_loop.TrainConfig(
            opt=opt.AdamWConfig(schedule=opt.Schedule(
                base_lr=1e-3, warmup_steps=2, total_steps=12)),
            q8_gather=bits)
        state = opt.init_state(tc.opt, params)
        src = SyntheticSource(DataConfig(vocab=cfg.vocab, seq_len=64,
                                         global_batch=8, seed=0))
        _, _, info = train_loop.train(cfg, tc, params, state, iter(src), 12)
        finals[bits] = info["history"][-1]["loss"]
        assert finals[bits] < info["history"][0]["loss"] - 0.05
    assert abs(finals[8] - finals[0]) < 0.1
    assert abs(finals[4] - finals[0]) < 0.2


def test_int4_packed_serving_matches_unpacked():
    """4-bit containers load as packed QT4 (0.5 B/param resident) and serve
    the same logits as the unpacked QT path."""
    from repro.core.quant import Granularity
    from repro.core.store import CompressedModel
    from repro.serving import engine
    from repro.models.layers import QT4
    cfg = registry.reduced(registry.get("glm4-9b"))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    host = {k: np.asarray(v, np.float32) for k, v in params.items()}
    cm = CompressedModel.compress(host, bits=4,
                                  granularity=Granularity.PER_CHANNEL)
    packed = engine.load_params_from_compressed(cm, quantized=True)
    unpacked = engine.load_params_from_compressed(cm, quantized=True,
                                                  pack_int4=False)
    assert any(isinstance(v, QT4) for v in packed.values())
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)
    lp, _ = jax.jit(lambda p, t: mod.prefill(cfg, p, t, max_len=16))(packed, toks)
    lu, _ = jax.jit(lambda p, t: mod.prefill(cfg, p, t, max_len=16))(unpacked, toks)
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(lu, np.float32), atol=1e-2)
