"""Deterministic sharded token pipeline.

Two sources share one iterator protocol (yield numpy batches ready for
``jax.device_put`` with the batch sharding):

* :class:`SyntheticSource` — structured pseudo-text: a fixed Markov chain over
  the vocab (Zipf-ish unigram + bigram dependence) so losses actually decrease
  during the e2e example, seeded deterministically by (seed, step, shard).
  Restart-safe: batch content is a pure function of the step index, so a
  restarted run re-reads the exact stream (fault-tolerance requirement).
* :class:`FileSource` — memmap over a flat uint32 token file, sharded by
  host: host h of H reads tokens [h::H] windows; deterministic per step.

For the enc-dec family the batch also carries ``src_embeds`` — the stubbed
modality frontend output (assignment: precomputed frame embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard: int = 0                 # this host's data shard index
    num_shards: int = 1
    src_embed_dim: int = 0         # > 0 => also emit src_embeds (encdec stub)
    src_len: Optional[int] = None


class SyntheticSource:
    """Markov-chain pseudo-text with a learnable structure (not iid noise)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.num_shards == 0
        self.local_batch = cfg.global_batch // cfg.num_shards
        base = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # Zipf unigram + a sparse deterministic "grammar": each token has a
        # small set of likely successors. Stored compactly: 8 successors/token.
        self.succ = base.integers(0, v, size=(v, 8), dtype=np.int64)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.shard))            # content := f(step, shard)
        B, S = self.local_batch, cfg.seq_len
        toks = np.empty((B, S), dtype=np.int32)
        cur = rng.choice(cfg.vocab, size=B, p=self.unigram)
        toks[:, 0] = cur
        follow = rng.random((B, S)) < 0.8           # 80% grammar, 20% resample
        picks = rng.integers(0, 8, size=(B, S))
        resample = rng.choice(cfg.vocab, size=(B, S), p=self.unigram)
        for t in range(1, S):
            nxt = np.where(follow[:, t], self.succ[cur, picks[:, t]],
                           resample[:, t])
            toks[:, t] = nxt
            cur = nxt
        out = {"tokens": toks}
        if cfg.src_embed_dim:
            L = cfg.src_len or S
            out["src_embeds"] = rng.standard_normal(
                (B, L, cfg.src_embed_dim)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        """Restart-safe iterator: resume mid-stream after checkpoint restore."""
        while True:
            yield self.batch(step)
            step += 1


class FileSource:
    """Memmap-backed token stream, deterministic, host-sharded."""

    def __init__(self, cfg: DataConfig, path: str):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=np.uint32, mode="r")
        self.local_batch = cfg.global_batch // cfg.num_shards
        self.windows = (len(self.tokens) - 1) // cfg.seq_len

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        B, S = self.local_batch, cfg.seq_len
        rng = np.random.default_rng((cfg.seed, step, cfg.shard))
        idx = rng.integers(0, self.windows, size=B)
        toks = np.stack([
            self.tokens[i * S: i * S + S].astype(np.int32) % cfg.vocab
            for i in idx
        ])
        return {"tokens": toks}

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch(step)
            step += 1

    def __iter__(self):
        return self.iter_from(0)
