from . import pipeline
