"""Compressed-resident serving weights: the container stays entropy-coded
in memory and each layer's QT triples are materialized just before that
layer's matmuls, then dropped.

This is the paper's headline serving scenario (§IV: weights stay
entropy-coded so each layer moves fewer bytes than its dense footprint;
Table 2's latency wins come from that bandwidth saving): instead of
decoding the whole container into dense/QT params at engine start
(:func:`repro.serving.engine.load_params_from_compressed`), only three
things are permanently resident:

* the **compressed payload** itself (per-table bitstreams + decode LUTs +
  per-tensor scale/zero metadata from container v2) — the "resident segment
  handles";
* the **globals** — non-layer tensors (embedding, final norm, lm head),
  decoded once with the exact packing rules of the whole-model loader;
* a small **dense-stacked carve-out** — layer tensors the fused-QT path
  cannot host (fp32 norms, per-group or rule-quantized sensitive params),
  decoded once and sliced per layer (views, no copies).

Everything else is decoded per layer through an execution-order plan
(:func:`repro.core.scheduler.plan_execution`), double-buffered: a worker
thread decodes layer *l+1* into a shared preallocated scratch buffer while
the jitted block of layer *l* computes (JAX dispatch is asynchronous, so
the overlap is real).  Peak weight memory is bounded by

    compressed payload + globals + carve-outs + 2 x (one layer's QT slot)

which is strictly below the dense bf16 footprint whenever the model
compresses at all — the invariant ``benchmarks/resident_serving.py`` and
``tests/test_resident_serving.py`` measure.  See docs/SERVING.md
§"Compressed-resident serving" for the execution model and the timing
diagram.

Bit-identity: the decoded symbols, the per-layer scale/zero slices, and the
QT/QT4 packing (:func:`repro.models.layers.pack_qt`) are byte-identical to
slicing the whole-model loader's stacked triples, and the per-layer step
functions mirror the scan bodies op for op — so greedy decode matches the
dense-resident engine bit for bit.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from repro.configs.base import ArchConfig
from repro.core.bitstream import GUARD_BYTES, pack_streams, pow2_bucket
from repro.core.decode_backends import DecoderBackend, get_backend
from repro.core.scheduler import (DEFAULT_CHUNK_SYMBOLS, ExecutionStep,
                                  decode_execution_step, fused_tile_reason,
                                  iter_seg_runs, plan_execution,
                                  plan_fused_spans)
from repro.core.spec import quantizable_shape
from repro.core.store import CompressedModel
from repro.models.layers import pack_qt

LAYER_PREFIX = "layers/"


def _device(tree: Any) -> Any:
    """Host triple/array -> device (preserving QT/QT4 NamedTuple types)."""
    if isinstance(tree, tuple) and hasattr(tree, "_fields"):
        return type(tree)(*(jnp.asarray(p) for p in tree))
    return jnp.asarray(tree)


class CompressedResidentWeights:
    """Device-resident entropy-coded weights + per-layer decode slots.

    Drop-in replacement for the ``params`` dict of the serving engines when
    the steps are built with ``ServeSteps(cfg, sc, resident="compressed")``:
    the per-layer drivers call :meth:`get` / :meth:`prefetch` instead of
    letting ``lax.scan`` slice a stacked tree.

    Args:
      model: the compressed container (format v1 or v2).
      cfg: architecture config; ``cfg.n_layers`` names the stacked axis.
      backend: decoder-registry name or instance (None/"auto" = capability
        pick), same contract as the whole-model loader.
      pack_int4: pack 4-bit layers into QT4 nibble pairs (default, matching
        the whole-model loader).
      chunk_symbols: per-decode-call symbol budget within a layer (the
        generalized scheduler budget): bounds the int32 scratch at O(chunk)
        instead of O(layer).  ``None`` -> one call per (layer, table).
      prefetch: decode layer l+1 on a worker thread while layer l computes
        (double buffering).  Disable for single-threaded debugging.
      fused: hand tile-aligned tensors to the fused decode→dequant→matmul
        kernel as :class:`~repro.kernels.fused_decode_matmul.FusedQT`
        payload handles (built once, device-resident) instead of decoding
        them into dense per-layer slots.  Tensors the fused contract cannot
        host (ragged tails, non-matrix shapes, non-scalar per-layer scales)
        stay on the unfused per-layer decode path; ``fused_fallback`` maps
        each to its reason.
      fused_impl: fused implementation override ("pallas" / "jax" /
        "pallas-interpret"); None = capability pick (compiled Pallas where
        it probes, the jit in-graph decode elsewhere).
    """

    def __init__(self, model: CompressedModel, cfg: ArchConfig, *,
                 backend=None, pack_int4: bool = True,
                 chunk_symbols: Optional[int] = DEFAULT_CHUNK_SYMBOLS,
                 prefetch: bool = True, fused: bool = False,
                 fused_impl: Optional[str] = None):
        t_load = time.perf_counter()
        self.model = model
        self.cfg = cfg
        self.n_layers = int(cfg.n_layers)
        self.backend: DecoderBackend = (
            backend if isinstance(backend, DecoderBackend)
            else get_backend(backend))
        self.pack_int4 = pack_int4

        self.globals: Dict[str, Any] = {}
        self.stacked: Dict[str, Any] = {}      # dense-resident carve-outs
        self._hosted: List[str] = []           # per-layer compressed tensors
        for name, w in model.unquantized.items():
            if self._is_layer_stacked(name, w.shape):
                self.stacked[name] = jnp.asarray(w)
            else:
                self.globals[name] = jnp.asarray(w)
        for name, meta in model.tensors.items():
            if self._is_layer_stacked(name, meta.shape) \
                    and self._qt_hostable(name):
                self._hosted.append(name)
            else:
                val = self._load_one(name)
                (self.stacked if self._is_layer_stacked(name, meta.shape)
                 else self.globals)[name] = val

        self.fused = bool(fused)
        self._fused: List[str] = []
        self.fused_fallback: Dict[str, str] = {}
        self._fused_slots: List[Dict[str, Any]] = [
            {} for _ in range(self.n_layers)]
        if fused:
            self._build_fused_slots(fused_impl)

        self.chunk_symbols = chunk_symbols
        self.plan: List[List[ExecutionStep]] = plan_execution(
            model, self.n_layers, self._hosted)
        rows = cols = 1
        for steps in self.plan:
            for step in steps:
                for run in iter_seg_runs(step.segs, chunk_symbols):
                    rows = max(rows, len(run))
                    cols = max(cols, max(s.count for s in run))
        # ONE scratch buffer shared by every per-layer decode call (the
        # decode-into-buffer contract); double buffering is safe because the
        # single worker thread serializes decodes and the returned QT slots
        # are trimmed copies, never views of the scratch
        self._buf = np.zeros((rows, cols), dtype=np.int32)
        self._exec: Optional[ThreadPoolExecutor] = (
            ThreadPoolExecutor(max_workers=1,
                               thread_name_prefix="resident-decode")
            if prefetch else None)
        self._pending: Dict[int, Future] = {}
        # guards _pending: prefetch() may be called from a driver thread
        # while get() consumes from the engine loop (lock-discipline policy
        # in repro.analysis.locks)
        self._lock = threading.Lock()
        # fused dispatch accounting: which tensors the fused kernel hosts vs
        # which fall back per-tensor, with the fallback REASON as the label
        # (docs/OBSERVABILITY.md "Fused dispatch")
        if self.fused:
            obs_metrics.counter("resident.fused_tensors").inc(
                len(self._fused))
            for reason in self.fused_fallback.values():
                obs_metrics.counter("resident.fused_fallback").inc(
                    reason=reason)
        obs_metrics.gauge("load.decode_load_s").set(
            time.perf_counter() - t_load)

    # ------------------------------------------------------------ classification
    def _is_layer_stacked(self, name: str, shape) -> bool:
        return (name.startswith(LAYER_PREFIX) and len(shape) >= 1
                and shape[0] == self.n_layers
                and int(np.prod(shape)) % self.n_layers == 0)

    def _qt_hostable(self, name: str) -> bool:
        """Can this stacked tensor live compressed with per-layer QT slots?
        Needs the fused dequant-matmul to host the slot (same rule as the
        whole-model loader) and a scale/zero that slices or broadcasts per
        layer (per-channel leading-axis pairs, or per-tensor scalars)."""
        m = self.model.qmeta[name]
        if not quantizable_shape(name, self.model.tensors[name].shape):
            return False
        if m["granularity"] == "per_group":
            return False
        s = np.asarray(m["scale"])
        return s.ndim == len(self.model.tensors[name].shape) \
            and s.shape[0] in (1, self.n_layers)

    def _fused_reason(self, name: str) -> Optional[str]:
        """Why a hosted tensor cannot take the fused kernel path (None =
        eligible): the scheduler's tile-alignment contract plus a per-layer
        scale/zero the kernel can broadcast against its (K, N) tiles."""
        reason = fused_tile_reason(self.model, self.n_layers, name)
        if reason:
            return reason
        m = self.model.qmeta[name]
        s = np.asarray(m["scale"])
        N = self.model.tensors[name].shape[-1]
        if s.ndim != 3 or s.shape[1] != 1 or s.shape[2] not in (1, N):
            return f"scale shape {s.shape} is not a per-layer scalar/row"
        return None

    def _build_fused_slots(self, fused_impl: Optional[str]) -> None:
        """Partition ``_hosted`` into fused handles + unfused fallback, and
        build every layer's :class:`FusedQT` ONCE (device-resident payload
        slices + decode tables; nothing is re-decoded per step — decode
        happens inside the matmul)."""
        from repro.kernels.fused_decode_matmul import build_fused_qt
        keep: List[str] = []
        for name in self._hosted:
            reason = self._fused_reason(name)
            if reason:
                keep.append(name)
                self.fused_fallback[name] = reason
            else:
                self._fused.append(name)
        self._hosted = keep
        spans = plan_fused_spans(self.model, self.n_layers, self._fused)
        for name, layer_spans in spans.items():
            table = self.model.table_for(name)
            m = self.model.qmeta[name]
            scale, zero = np.asarray(m["scale"]), np.asarray(m["zero"])
            _, K, N = self.model.tensors[name].shape
            # one pow2 width across ALL layers -> the per-layer lane
            # matrices share one shape (one jit/pallas trace per tensor)
            width = pow2_bucket(
                max(GUARD_BYTES,
                    max(s.nbytes for sp in layer_spans for s in sp.segs)), 64)
            short = name[len(LAYER_PREFIX):]
            for sp in layer_spans:
                streams = [self.model.payload[s.offset: s.offset + s.nbytes]
                           for s in sp.segs]
                mat, _ = pack_streams(streams, min_width=width)
                i = min(sp.layer, scale.shape[0] - 1)
                self._fused_slots[sp.layer][short] = build_fused_qt(
                    table, mat, scale[i], zero[i],
                    seg_symbols=sp.seg_symbols, K=K, N=N, bits=m["bits"],
                    impl=fused_impl)

    def _load_one(self, name: str) -> Any:
        """Decode one tensor with the whole-model loader's packing rules
        (globals and dense-stacked carve-outs are bit-identical to
        ``load_params_from_compressed``'s output for the same name)."""
        q = self.model.decode_tensor(name, backend=self.backend)
        m = self.model.qmeta[name]
        if not quantizable_shape(name, self.model.tensors[name].shape) \
                or m["granularity"] == "per_group":
            return jnp.asarray(self.model._dequantize_one(name, q))
        return _device(pack_qt(q, m["scale"], m["zero"], bits=m["bits"],
                               pack_int4=self.pack_int4))

    # ----------------------------------------------------------------- decoding
    def _decode_layer(self, l: int) -> Dict[str, Any]:
        """Materialize layer ``l``'s weight-slot dict: decode its execution
        steps into the scratch buffer, slice scale/zero, pack QT/QT4, and
        append the dense-stacked carve-out views."""
        with obs_trace.span("resident.decode", cat="resident", layer=l):
            slot = self._decode_layer_inner(l)
        obs_metrics.counter("resident.slot_tensors").inc(len(slot))
        return slot

    def _decode_layer_inner(self, l: int) -> Dict[str, Any]:
        slot: Dict[str, Any] = {}
        for step in self.plan[l]:
            for name, flat in decode_execution_step(
                    self.model, step, self.backend, out=self._buf,
                    chunk_symbols=self.chunk_symbols).items():
                m = self.model.qmeta[name]
                shape = self.model.tensors[name].shape[1:]
                scale, zero = np.asarray(m["scale"]), np.asarray(m["zero"])
                i = min(l, scale.shape[0] - 1)   # (L,1,..) slices; (1,1,..)
                qt = pack_qt(flat.reshape(shape), scale[i], zero[i],
                             bits=m["bits"], pack_int4=self.pack_int4)
                slot[name[len(LAYER_PREFIX):]] = _device(qt)
        for name, w in self.stacked.items():
            slot[name[len(LAYER_PREFIX):]] = w[l]
        # fused handles are prebuilt and device-resident: no per-get work
        slot.update(self._fused_slots[l])
        return slot

    def prefetch(self, l: int) -> None:
        """Start decoding layer ``l`` on the worker thread (no-op when
        already in flight or prefetch is disabled)."""
        if self._exec is None:
            return
        with self._lock:
            if l in self._pending:
                return
            self._pending[l] = self._exec.submit(self._decode_layer, l)
        obs_trace.instant("resident.prefetch_issue", cat="resident", layer=l)
        obs_metrics.counter("resident.prefetch_issued").inc()

    def get(self, l: int) -> Dict[str, Any]:
        """Layer ``l``'s weight-slot dict (waits on its prefetch if one is
        in flight; decodes inline otherwise).  The caller drops the dict
        after the layer's matmuls — nothing retains it here.

        The ``resident.consume_wait`` span is the overlap-stall probe: its
        duration is the time the serving loop actually blocked on weight
        decode (≈0 on a prefetch hit).  ``benchmarks/overlap_report.py``
        sums these against the worker's ``resident.decode`` spans."""
        with self._lock:
            fut = self._pending.pop(l, None)
        if fut is not None:
            hit = fut.done()
            # literal names per branch: catalog-sync audits emit sites
            if hit:
                obs_metrics.counter("resident.prefetch_hit").inc()
            else:
                obs_metrics.counter("resident.prefetch_wait").inc()
            with obs_trace.span("resident.consume_wait", cat="resident",
                                layer=l, hit=hit):
                return fut.result()
        # no prefetch in flight: the whole decode is a stall by definition
        obs_metrics.counter("resident.prefetch_wait").inc()
        with obs_trace.span("resident.consume_wait", cat="resident",
                            layer=l, hit=False):
            if self._exec is not None:
                # route through the worker so the shared scratch buffer is
                # only ever touched by one thread
                return self._exec.submit(self._decode_layer, l).result()
            return self._decode_layer(l)

    # ---------------------------------------------------------------- accounting
    def resident_bytes(self) -> Dict[str, int]:
        """Deterministic weight-memory breakdown (the serving analogue of
        the paper's Table 2 storage column; asserted against the dense
        footprint by the resident benchmark/tests)."""
        payload = sum(int(self.model.tensors[n].seg_nbytes.sum())
                      for n in self._hosted)
        # fused tensors keep their payload as device lane matrices (guard +
        # pow2-width padding included): count the actual resident bytes
        payload += sum(int(fq.mat.nbytes)
                       for slots in self._fused_slots
                       for fq in slots.values())
        compressed = self._hosted + self._fused
        tables = sum(
            sum(np.asarray(a).nbytes
                for a in self.model.tables[t].decode_arrays().values())
            for t in {self.model.table_id_for(n) for n in compressed})
        qmeta = sum(np.asarray(self.model.qmeta[n]["scale"]).nbytes
                    + np.asarray(self.model.qmeta[n]["zero"]).nbytes
                    for n in compressed)
        leaves = lambda tree: (
            tuple(tree) if isinstance(tree, tuple) else (tree,))
        globals_b = sum(p.nbytes for v in self.globals.values()
                        for p in leaves(v))
        stacked_b = sum(p.nbytes for v in self.stacked.values()
                        for p in leaves(v))
        slot = 0
        for n in self._hosted:
            m = self.model.qmeta[n]
            per_layer = self.model.tensors[n].n_symbols // self.n_layers
            last = self.model.tensors[n].shape[-1]
            packed = m["bits"] == 4 and self.pack_int4 and last % 2 == 0
            scale = np.asarray(m["scale"])
            slot += (per_layer // 2 if packed else per_layer) \
                + 2 * (scale.nbytes // scale.shape[0])
        return {
            "payload": payload, "tables": tables, "qmeta": qmeta,
            "globals": globals_b, "stacked": stacked_b,
            "layer_slot": slot, "scratch": self._buf.nbytes,
        }

    def peak_resident_bytes(self) -> int:
        """Peak weight-path bytes: everything permanently resident plus the
        double-buffered pair of per-layer slots and the decode scratch."""
        b = self.resident_bytes()
        return (b["payload"] + b["tables"] + b["qmeta"] + b["globals"]
                + b["stacked"] + b["scratch"] + 2 * b["layer_slot"])

    def dense_resident_bytes(self) -> int:
        """What the dense-resident QT mode holds for the same container
        (globals/carve-outs identical; hosted tensors fully decoded)."""
        b = self.resident_bytes()
        full = 0
        for n in self._hosted + self._fused:
            m = self.model.qmeta[n]
            t = self.model.tensors[n]
            packed = m["bits"] == 4 and self.pack_int4 \
                and t.shape[-1] % 2 == 0
            full += (t.n_symbols // 2 if packed else t.n_symbols) \
                + np.asarray(m["scale"]).nbytes \
                + np.asarray(m["zero"]).nbytes
        return b["globals"] + b["stacked"] + full

    def dense_bf16_bytes(self) -> int:
        """The uncompressed bf16 baseline (2 bytes/param, paper Table 2)."""
        n = sum(t.n_symbols for t in self.model.tensors.values()) \
            + sum(int(np.prod(w.shape))
                  for w in self.model.unquantized.values())
        return 2 * n
