"""Cold tier for evicted shared KV blocks: entropy-coded host bytes.

When the block pool runs out and the LRU victim is a *shared* prefix block
(refcount 0 — published but currently unreferenced), dropping it means the
next request with that prefix pays a full re-prefill.  With a codec
configured (``KVCompressionSpec.codec``) the block is instead entropy-coded
to host memory and revived on the next prefix hit for the price of one
serial decode — the same trade Huff-LLM makes for weights, applied to KV.

The symbol alphabet is the quantized pool's uint8 leaves (k/v codes; 256
symbols regardless of ``bits`` — 4-bit pools nibble-pack two codes per
byte, which the histogram simply sees as a 256-symbol source).  Each leaf
gets its own table built from its own histogram (mixed leaves cannot share
one histogram — the container-v2 rule).  The bf16 scale/zero leaves are
tiny and stored raw.  Decoding routes on the table's *kernel family*
exactly like the weight path: ``prefix`` → ``bitstream.decode_serial``,
``tans`` → ``bitstream.decode_serial_tans``.

Cold storage is host-side bookkeeping — nothing here touches jax.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, Optional, Tuple

import numpy as np

from repro.core import bitstream, entropy
from repro.core.codecs import CodeTable, get_codec

NUM_SYMBOLS = 256   # uint8 pool leaves; nibble-packed at bits=4


@dataclasses.dataclass
class _ColdLeaf:
    """One entropy-coded uint8 leaf of a cold block."""
    stream: np.ndarray          # guard-padded byte stream
    count: int                  # symbols encoded
    shape: Tuple[int, ...]
    table: CodeTable

    @property
    def nbytes(self) -> int:
        # stream + the histogram needed to rebuild the table (int32 freqs),
        # the same accounting a serialized container would pay
        return int(self.stream.nbytes) + NUM_SYMBOLS * 4

    def decode(self) -> np.ndarray:
        arrs = self.table.decode_arrays()
        if self.table.kernel == "prefix":
            sym = bitstream.decode_serial(self.stream, self.count,
                                          arrs["lut_sym"], arrs["lut_len"],
                                          max_len=self.table.peek_bits)
        else:
            sym = bitstream.decode_serial_tans(self.stream, self.count,
                                               arrs["tab_sym"],
                                               arrs["tab_bits"],
                                               arrs["tab_base"],
                                               self.table.table_log)
        return sym.astype(np.uint8).reshape(self.shape)


def encode_block_leaves(codec, leaves: Dict[str, np.ndarray]
                        ) -> Tuple[Dict[str, object], int, int]:
    """Entropy-code one block's leaves with ``codec``: uint8 code leaves get
    per-leaf tables (the container-v2 rule — mixed leaves cannot share one
    histogram), everything else is kept raw.  Returns ``(entry,
    encoded_symbols, payload_bits)``.

    This is the cold tier's storage format AND the fleet handoff's wire
    format (``serving/fleet/handoff.py``): one codec round-trip, two
    consumers, zero drift between what eviction persists and what a decode
    replica receives."""
    entry: Dict[str, object] = {}
    nsym = 0
    nbits = 0
    for name, arr in leaves.items():
        if arr.dtype == np.uint8:
            flat = arr.reshape(-1)
            freqs = entropy.symbol_frequencies(flat, NUM_SYMBOLS)
            table = codec.build(freqs, 8)
            stream, bits = table.encode(flat)
            entry[name] = _ColdLeaf(stream, flat.size, arr.shape, table)
            nsym += flat.size
            nbits += bits
        else:
            entry[name] = arr.copy()     # bf16 scale/zero: raw
    return entry, nsym, nbits


def decode_block_leaves(entry: Dict[str, object]) -> Dict[str, np.ndarray]:
    """Invert :func:`encode_block_leaves` back to numpy pool leaves."""
    return {name: leaf.decode() if isinstance(leaf, _ColdLeaf) else leaf
            for name, leaf in entry.items()}


def entry_nbytes(entry: Dict[str, object]) -> int:
    """Host bytes one encoded entry occupies (streams + tables + raw)."""
    return sum(int(leaf.nbytes) for leaf in entry.values())


class ColdBlockStore:
    """Host-side store of evicted shared blocks, keyed by prefix-chain key.

    ``put`` entropy-codes the uint8 code leaves (per-leaf tables) and keeps
    the bf16 scale/zero leaves raw; ``pop`` decodes everything back to the
    numpy leaves the block manager scatters into a fresh pool block.
    """

    def __init__(self, codec_name: str):
        self.codec = get_codec(codec_name)   # loud on unknown names
        self._entries: Dict[Hashable, Dict[str, object]] = {}
        self.encoded_symbols = 0
        self.payload_bits = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    @property
    def nbytes(self) -> int:
        return sum(entry_nbytes(entry) for entry in self._entries.values())

    def put(self, key: Hashable, leaves: Dict[str, np.ndarray]) -> None:
        """Store one block's per-layer leaves, e.g. k: (L, BS, KV, hs)."""
        entry, nsym, nbits = encode_block_leaves(self.codec, leaves)
        self.encoded_symbols += nsym
        self.payload_bits += nbits
        self._entries[key] = entry

    def pop(self, key: Hashable) -> Dict[str, np.ndarray]:
        return decode_block_leaves(self._entries.pop(key))

    def drop(self, key: Hashable) -> None:
        self._entries.pop(key, None)

    @property
    def effective_bits(self) -> Optional[float]:
        """Mean coded bits per pool byte, across everything ever encoded."""
        if not self.encoded_symbols:
            return None
        return self.payload_bits / self.encoded_symbols
