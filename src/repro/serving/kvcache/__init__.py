"""Paged, compressible KV cache for continuous batching (docs/KV_CACHE.md).

The subsystem splits along the device/host line:

* :mod:`repro.models` owns the device side — ``init_kv_pool`` block pools
  and the ``paged_prefill_chunk`` / ``paged_decode_step`` twins that
  scatter/gather K/V through a block table (``api.supports_paged_kv``
  gates families);
* :class:`BlockKVManager` (here) owns the host side — block tables, free
  lists, prefix-chain refcounts, LRU eviction;
* :class:`ColdBlockStore` entropy-codes evicted shared blocks to host
  bytes via the ``core.codecs`` registry.

Policy comes in as :class:`repro.core.spec.KVCompressionSpec` (the
``--kv-spec`` grammar).  ``kv_pool_bytes`` sizes a pool without allocating
it — the peak-HBM breakdowns in ``launch/serve.py`` and
``benchmarks/resident_serving.py`` use it.
"""
from __future__ import annotations

import math

import jax

from repro.configs.base import ArchConfig
from repro.core.spec import KVCompressionSpec
from repro.models import api
from .blocks import BlockKVManager
from .cold import ColdBlockStore


def kv_pool_bytes(cfg: ArchConfig, n_blocks: int, block_size: int,
                  bits: int = 16) -> int:
    """Bytes of a paged KV pool, via ``eval_shape`` (nothing allocated)."""
    shapes = jax.eval_shape(
        lambda: api.build(cfg).init_kv_pool(cfg, n_blocks, block_size, bits))
    return sum(math.prod(leaf.shape) * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(shapes))


def kv_cache_bytes(cfg: ArchConfig, n_slots: int, max_len: int) -> int:
    """Bytes of the PR 2 slotted cache — the dense reference budget."""
    shapes = jax.eval_shape(
        lambda: api.build(cfg).init_cache(cfg, n_slots, max_len))
    return sum(math.prod(leaf.shape) * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(shapes))


__all__ = ["BlockKVManager", "ColdBlockStore", "KVCompressionSpec",
           "kv_pool_bytes", "kv_cache_bytes"]
