"""Paged KV block pool + prefix sharing for continuous batching.

``BlockKVManager`` is the paged successor of
:class:`~repro.serving.batching.slots.SlotBatchManager` (docs/KV_CACHE.md):
instead of one contiguous ``max_len`` row per slot it owns a pool of
fixed-size blocks — ``init_kv_pool(cfg, n_blocks, block_size, bits)`` — and
a host-side ``(n_slots, max_blocks)`` int32 block table routing every slot's
logical positions to pool blocks.  The jitted step functions
(``paged_prefill_chunk`` / ``paged_decode_step``) scatter and gather through
that table; everything else — free lists, refcounts, the prefix-chain map,
LRU cold eviction — is plain host bookkeeping here.

Layout invariants the step functions rely on:

* **Block 0 is the trash block.**  It is never allocated; table rows handed
  to the fused decode step for non-live lanes (free, or still prefilling)
  are all-trash, so their per-step garbage write (position 0) lands in a
  block nobody gathers unmasked.  Stale rows a live lane *does* gather
  (trash entries past its allocation, a reused block's old tail) are killed
  by ``kv_len`` masking — masked scores get exactly ``NEG_INF`` and
  ``exp`` underflows to an exact 0.0 contribution.
* **Shared blocks are immutable after publish.**  Only *full* prompt blocks
  (``j < prompt_len // block_size``) are published to the prefix chain at
  ``insert``; decode writes start at ``prompt_len`` which always lands in a
  private block.  A prefix *hit* may still re-scatter the tail of the
  shared region when the skip is chunk-aligned short of the hit boundary —
  benign, because identical tokens after an identical prefix produce
  bit-identical K/V rows (the same argument that makes dense paged mode
  bit-identical to the slot pool).
* **Refcount 0 ≠ free.**  A published block whose requests all released
  stays resident on an LRU list; it is reclaimed only when admission needs
  blocks, and — with a codec configured — entropy-coded to the host cold
  tier (:mod:`repro.serving.kvcache.cold`) instead of dropped, so the next
  hit pays a serial decode rather than a re-prefill.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from functools import partial
from typing import TYPE_CHECKING, Any, Dict, Hashable, List, Optional, Tuple

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.spec import KVCompressionSpec
from repro.models import api
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from .cold import ColdBlockStore

if TYPE_CHECKING:                 # import cycle: batching.engine imports us
    from ..batching.request import Request


@partial(jax.jit, donate_argnums=(0,))
def _zero_block(pool, blk):
    def leaf(c):
        blank = jnp.zeros((c.shape[0], 1) + c.shape[2:], c.dtype)
        return jax.lax.dynamic_update_slice_in_dim(c, blank, blk, axis=1)
    return jax.tree.map(leaf, pool)


@jax.jit
def _read_block(pool, blk):
    def leaf(c):
        return jax.lax.dynamic_slice_in_dim(c, blk, 1, axis=1)[:, 0]
    return jax.tree.map(leaf, pool)


@partial(jax.jit, donate_argnums=(0,))
def _write_block(pool, blk, leaves):
    def leaf(c, r):
        return jax.lax.dynamic_update_slice_in_dim(c, r[:, None].astype(c.dtype),
                                                   blk, axis=1)
    return jax.tree.map(leaf, pool, leaves)


@dataclasses.dataclass
class _Plan:
    """Admission plan for one request (see ``BlockKVManager._plan``)."""
    nb: int                                    # blocks the request needs
    res_hits: List[Tuple[int, Hashable, int]]  # (j, chain key, block id)
    cold_hits: List[Tuple[int, Hashable]]      # (j, chain key)
    n_skip: int                                # prefill tokens skipped
    pending: List[Tuple[int, Hashable]]        # full blocks to publish later

    @property
    def n_new(self) -> int:                    # fresh blocks to claim
        return self.nb - len(self.res_hits)


class BlockKVManager:
    """Block-table-backed KV cache + per-slot request bookkeeping.

    Drop-in for ``SlotBatchManager`` on the paged engine path: same slot
    lifecycle (``alloc`` → ``insert`` → ``release``), but ``alloc`` returns
    ``(slot, n_skip)`` — the prefix-shared token count admission may skip —
    and ``insert`` takes only the kv length (prefill wrote the pool blocks
    in place through the table; there is no scratch cache to splice).
    """

    def __init__(self, cfg: ArchConfig, n_slots: int, max_len: int, *,
                 spec: Optional[KVCompressionSpec] = None,
                 n_blocks: Optional[int] = None, prefill_chunk: int = 1):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.cfg = cfg
        self.spec = spec = spec or KVCompressionSpec()
        spec.validate()
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        if spec.sharing and prefill_chunk % spec.block_size:
            raise ValueError(
                f"prefix sharing needs prefill_chunk % block_size == 0 "
                f"(got chunk={prefill_chunk}, block={spec.block_size}): the "
                f"skip boundary must be a chunk boundary")
        self.n_slots = n_slots
        self.max_len = max_len
        self.block_size = spec.block_size
        self.chunk = prefill_chunk
        self.max_blocks = -(-max_len // spec.block_size)
        # default capacity = trash + the slot pool's worth of blocks, so the
        # dense default matches SlotBatchManager byte for byte modulo trash
        self.n_blocks = (1 + n_slots * self.max_blocks
                         if n_blocks is None else n_blocks)
        if self.n_blocks < 1 + self.max_blocks:
            raise ValueError(
                f"n_blocks={self.n_blocks} cannot hold trash + one "
                f"max-length request ({1 + self.max_blocks})")
        self.pool = api.build(cfg).init_kv_pool(cfg, self.n_blocks,
                                                spec.block_size, spec.bits)
        self.tables = np.zeros((n_slots, self.max_blocks), np.int32)
        self.kv_len = np.zeros((n_slots,), np.int32)
        self.requests: List[Optional[Request]] = [None] * n_slots
        self._live = [False] * n_slots
        self._free_slots: List[int] = list(range(n_slots - 1, -1, -1))
        self._free_blocks: List[int] = list(range(self.n_blocks - 1, 0, -1))
        self._slot_shared: List[List[Tuple[int, Hashable]]] = \
            [[] for _ in range(n_slots)]
        self._slot_private: List[List[int]] = [[] for _ in range(n_slots)]
        self._pending: List[List[Tuple[int, Hashable]]] = \
            [[] for _ in range(n_slots)]
        self._chain: Dict[Hashable, int] = {}    # resident prefix key -> block
        self._refs: Dict[int, int] = {}          # shared block -> refcount
        self._block_key: Dict[int, Hashable] = {}
        self._lru: "OrderedDict[int, Hashable]" = OrderedDict()
        self.cold = ColdBlockStore(spec.codec) if spec.codec else None
        # stats counters are read by stats()/monitoring threads while the
        # engine loop mutates them (lock-discipline policy in
        # repro.analysis.locks); everything else is engine-thread-only
        self._stats_lock = threading.Lock()
        self.shared_hits = 0
        self.shared_misses = 0
        self.cold_evictions = 0
        self.cold_restores = 0
        self.dropped_evictions = 0
        self._update_gauges()

    # ------------------------------------------------------------- occupancy
    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def active(self) -> List[int]:
        return [s for s, r in enumerate(self.requests) if r is not None]

    @property
    def n_free_blocks(self) -> int:
        return len(self._free_blocks)

    @property
    def pool_bytes(self) -> int:
        return sum(int(leaf.nbytes) for leaf in jax.tree.leaves(self.pool))

    @property
    def cold_bytes(self) -> int:
        return self.cold.nbytes if self.cold is not None else 0

    def stats(self) -> Dict[str, Any]:
        with self._stats_lock:
            hits, misses = self.shared_hits, self.shared_misses
            evic, rest, drop = (self.cold_evictions, self.cold_restores,
                                self.dropped_evictions)
        lookups = hits + misses
        return {
            "pool_bytes": self.pool_bytes,
            "cold_bytes": self.cold_bytes,
            "blocks_free": len(self._free_blocks),
            "blocks_total": self.n_blocks,
            "shared_hits": hits,
            "shared_misses": misses,
            "prefix_hit_rate": hits / lookups if lookups else 0.0,
            "cold_evictions": evic,
            "cold_restores": rest,
            "dropped_evictions": drop,
        }

    def _update_gauges(self) -> None:
        obs_metrics.gauge("kv.resident_bytes").set(self.pool_bytes)
        obs_metrics.gauge("kv.blocks_free").set(len(self._free_blocks))
        obs_metrics.gauge("slots.occupied").set(
            self.n_slots - len(self._free_slots))

    # ------------------------------------------------------------ block table
    def table_rows(self, slots: List[int]) -> np.ndarray:
        """Raw table rows for ``slots`` — the prefill view (writes allowed)."""
        return self.tables[np.asarray(slots, np.int32)]

    def decode_tables(self) -> np.ndarray:
        """The fused-decode view: non-live lanes' rows are all-trash so their
        per-step garbage write cannot touch an allocated (or shared) block."""
        out = self.tables.copy()
        for s in range(self.n_slots):
            if not self._live[s]:
                out[s] = 0
        return out

    # ---------------------------------------------------------------- sharing
    def _chain_keys(self, prompt: np.ndarray) -> List[Hashable]:
        """Content-hash chain over the prompt's *full* blocks: each key folds
        in its parent, so equal keys imply equal whole prefixes."""
        BS = self.block_size
        keys: List[Hashable] = []
        parent: Hashable = None
        for j in range(len(prompt) // BS):
            parent = (parent, prompt[j * BS:(j + 1) * BS].tobytes())
            keys.append(parent)
        return keys

    def _plan(self, req: Request, count: bool = True) -> Optional[_Plan]:
        P = req.prompt_len
        padded = -(-P // self.chunk) * self.chunk
        need = max(P + req.max_new_tokens, padded)
        if need > self.max_len:
            return None
        nb = -(-need // self.block_size)
        res_hits: List[Tuple[int, Hashable, int]] = []
        cold_hits: List[Tuple[int, Hashable]] = []
        keys = self._chain_keys(req.prompt) if self.spec.sharing else []
        n_hit = 0
        for j, key in enumerate(keys):
            if key in self._chain:
                res_hits.append((j, key, self._chain[key]))
            elif self.cold is not None and key in self.cold:
                cold_hits.append((j, key))
            else:
                break
            n_hit = j + 1
        # skip whole chunks covered by hits, but always leave the final
        # chunk (the one holding position P-1) to run — its logits seed the
        # first generated token
        n_skip = min(n_hit * self.block_size // self.chunk * self.chunk,
                     (P - 1) // self.chunk * self.chunk)
        pending = [(j, key) for j, key in enumerate(keys) if j >= n_hit]
        if count:
            with self._stats_lock:
                self.shared_hits += n_hit
                self.shared_misses += len(keys) - n_hit
            if n_hit:
                obs_metrics.counter("kv.shared_hits").inc(n_hit)
            if len(keys) - n_hit:
                obs_metrics.counter("kv.shared_misses").inc(len(keys) - n_hit)
        return _Plan(nb=nb, res_hits=res_hits, cold_hits=cold_hits,
                     n_skip=n_skip, pending=pending)

    # ------------------------------------------------------------- lifecycle
    def can_admit(self, req: Request) -> bool:
        """Admission probe — free slot + enough claimable blocks.  Does not
        touch the hit/miss stats (``alloc`` re-plans and counts); before the
        ``count=`` flag this rolled the attrs back by hand but still emitted
        the obs counters, so probes double-counted kv.shared_* metrics."""
        if not self._free_slots:
            return False
        plan = self._plan(req, count=False)
        if plan is None:
            return False
        # planned hits sitting at refcount 0 are on the LRU but must not be
        # counted as evictable — alloc pins them before evicting
        pinned = sum(1 for _, _, blk in plan.res_hits if blk in self._lru)
        return plan.n_new <= (len(self._free_blocks)
                              + len(self._lru) - pinned)

    def alloc(self, req: Request) -> Optional[Tuple[int, int]]:
        """Claim a slot + blocks for ``req``; returns ``(slot, n_skip)`` —
        admission may skip the first ``n_skip`` prompt tokens (prefix hits).
        None when the batch or the pool is full."""
        if not self._free_slots:
            return None
        with obs_trace.span("kv.admit", rid=req.rid, prompt=req.prompt_len):
            plan = self._plan(req)
            if plan is None:
                return None
            # pin resident hits FIRST (refcount up, off the LRU) — a hit at
            # refcount 0 is an eviction candidate, and the eviction loop
            # below must never reclaim a block this plan is about to reuse
            for _, _, blk in plan.res_hits:
                self._refs[blk] += 1
                self._lru.pop(blk, None)
            if plan.n_new > len(self._free_blocks) + len(self._lru):
                for _, _, blk in plan.res_hits:      # unwind the pins
                    self._refs[blk] -= 1
                    if self._refs[blk] == 0:
                        self._lru[blk] = self._block_key[blk]
                return None
            while len(self._free_blocks) < plan.n_new:
                self._evict_one()
            slot = self._free_slots.pop()
            row = self.tables[slot]
            row[:] = 0
            shared = self._slot_shared[slot]
            private = self._slot_private[slot]
            for j, key, blk in plan.res_hits:
                row[j] = blk
                shared.append((j, key))
            for j, key in plan.cold_hits:
                blk = self._free_blocks.pop()
                leaves = {name: jnp.asarray(arr) for name, arr
                          in self.cold.pop(key).items()}
                with obs_trace.span("kv.cold_decode", block=blk):
                    self.pool = _write_block(self.pool, jnp.int32(blk), leaves)
                self._chain[key] = blk
                self._refs[blk] = 1
                self._block_key[blk] = key
                row[j] = blk
                shared.append((j, key))
                with self._stats_lock:
                    self.cold_restores += 1
                obs_metrics.counter("kv.cold_restores").inc()
            n_hit = len(plan.res_hits) + len(plan.cold_hits)
            for j in range(n_hit, plan.nb):
                blk = self._free_blocks.pop()
                row[j] = blk
                private.append(blk)
            self._pending[slot] = plan.pending
            self.requests[slot] = req
            self.kv_len[slot] = 0
            self._live[slot] = False
            self._update_gauges()
            return slot, plan.n_skip

    def insert(self, slot: int, kv_len: int) -> None:
        """Activate a prefilled slot at length ``kv_len`` and publish its
        full prompt blocks to the prefix chain (sharing only)."""
        assert self.requests[slot] is not None, f"insert into free slot {slot}"
        assert not self._live[slot], f"double insert into slot {slot}"
        assert kv_len <= self.max_len, (kv_len, self.max_len)
        self.kv_len[slot] = kv_len
        self._live[slot] = True
        if self.spec.sharing:
            private = self._slot_private[slot]
            for j, key in self._pending[slot]:
                if key in self._chain:       # racing identical prefix won;
                    continue                 # keep ours private
                blk = int(self.tables[slot, j])
                private.remove(blk)
                self._chain[key] = blk
                self._refs[blk] = 1
                self._block_key[blk] = key
                self._slot_shared[slot].append((j, key))
                if self.cold is not None:    # resident copy supersedes cold
                    self.cold.drop(key)
        self._pending[slot] = []
        obs_metrics.counter("slots.inserts").inc()
        self._update_gauges()

    def release(self, slot: int, *, compact: bool = True) -> Request:
        """Detach the slot's request.  Shared blocks drop a refcount (to the
        LRU at zero); private blocks return to the free list, compacted
        (zeroed) by default like the slot pool."""
        req = self.requests[slot]
        assert req is not None, f"release of free slot {slot}"
        self.requests[slot] = None
        self.kv_len[slot] = 0
        self._live[slot] = False
        for j, key in self._slot_shared[slot]:
            blk = self._chain[key]
            self._refs[blk] -= 1
            if self._refs[blk] == 0:
                self._lru[blk] = key
        self._slot_shared[slot] = []
        for blk in self._slot_private[slot]:
            if compact:
                self.pool = _zero_block(self.pool, jnp.int32(blk))
            self._free_blocks.append(blk)
        if compact and self._slot_private[slot]:
            obs_metrics.counter("slots.compactions").inc()
        self._slot_private[slot] = []
        self._pending[slot] = []
        self.tables[slot] = 0
        self._free_slots.append(slot)
        obs_metrics.counter("slots.releases").inc()
        self._update_gauges()
        return req

    # ---------------------------------------------------------- export/import
    def export_blocks(self, slot: int) -> List[Dict[str, np.ndarray]]:
        """Raw pool leaves of the slot's first ``ceil(kv_len / block_size)``
        blocks, in logical order — the KV export API behind the fleet's
        disaggregated prefill→decode handoff (``serving/fleet/handoff.py``
        entropy-codes each block with the cold tier's wire format).  Rows
        past ``kv_len`` inside the last block are pool garbage; the decode
        side's ``kv_len`` masking makes them unreachable, same invariant as
        block reuse."""
        req = self.requests[slot]
        assert req is not None, f"export of free slot {slot}"
        n = -(-int(self.kv_len[slot]) // self.block_size)
        out: List[Dict[str, np.ndarray]] = []
        for j in range(n):
            blk = int(self.tables[slot, j])
            leaves = jax.tree.map(np.asarray,
                                  _read_block(self.pool, jnp.int32(blk)))
            out.append(dict(leaves))
        return out

    def can_import(self, req: Request, kv_len: int, n_blocks: int) -> bool:
        """Probe for ``import_blocks`` — free slot + claimable blocks for
        the imported prefix AND the request's remaining generation."""
        if not self._free_slots:
            return False
        need = kv_len + req.max_new_tokens
        if need > self.max_len:
            return False
        nb = max(-(-need // self.block_size), n_blocks)
        return nb <= len(self._free_blocks) + len(self._lru)

    def import_blocks(self, req: Request,
                      kv_len: int,
                      blocks: List[Dict[str, np.ndarray]]) -> Optional[int]:
        """Claim a slot + private blocks and install externally produced
        block leaves (the decode half of the disaggregated handoff).

        The imported blocks stay *private* — publishing another replica's
        prefix blocks to this pool's chain would need the chain keys, and
        prefix reuse across replicas is the router's job, not the pool's.
        Returns the slot, or None when the batch or the pool cannot take the
        request right now (the caller retries)."""
        if not self.can_import(req, kv_len, len(blocks)):
            return None
        need = kv_len + req.max_new_tokens
        nb = max(-(-need // self.block_size), len(blocks))
        while len(self._free_blocks) < nb:
            self._evict_one()
        slot = self._free_slots.pop()
        row = self.tables[slot]
        row[:] = 0
        private = self._slot_private[slot]
        for j in range(nb):
            blk = self._free_blocks.pop()
            row[j] = blk
            private.append(blk)
            if j < len(blocks):
                leaves = {name: jnp.asarray(arr)
                          for name, arr in blocks[j].items()}
                self.pool = _write_block(self.pool, jnp.int32(blk), leaves)
        self.requests[slot] = req
        self._pending[slot] = []
        self.kv_len[slot] = 0
        self._live[slot] = False
        self.insert(slot, kv_len)
        return slot

    # --------------------------------------------------------------- eviction
    def _evict_one(self) -> None:
        """Reclaim the LRU-oldest refcount-0 shared block: entropy-code it to
        the cold tier when a codec is configured, else drop it."""
        if not self._lru:
            raise RuntimeError("no evictable blocks (all referenced)")
        blk, key = self._lru.popitem(last=False)
        del self._chain[key]
        del self._refs[blk]
        del self._block_key[blk]
        if self.cold is not None:
            leaves = jax.tree.map(np.asarray,
                                  _read_block(self.pool, jnp.int32(blk)))
            with obs_trace.span("kv.cold_encode", block=blk):
                self.cold.put(key, leaves)
            with self._stats_lock:
                self.cold_evictions += 1
            obs_metrics.counter("kv.cold_evictions").inc()
        else:
            with self._stats_lock:
                self.dropped_evictions += 1
            obs_metrics.counter("kv.dropped_evictions").inc()
        self.pool = _zero_block(self.pool, jnp.int32(blk))
        self._free_blocks.append(blk)
