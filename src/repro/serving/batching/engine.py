"""Continuous-batching engine: slot-batched decode with mid-flight admission.

The scheduler loop (one ``step()``):

  1. **Admit** — if the admission pipeline is idle and a slot is free, the
     next queued request reserves the slot and starts chunk-prefilling
     through the shared ``ServeSteps.prefill_chunk_fn`` (fixed ``(1, chunk)``
     shape — ONE compile serves every prompt length) into a scratch cache.
     Under load the prefill advances at most ``admit_chunks_per_step``
     chunks per scheduler step (default 4), fused decode steps running in
     between — so in-flight requests pay a bounded slice of prefill latency
     per generated token, never a whole queued prompt; when nothing is
     decoding there is no lane to stall and the prefill drains to completion
     immediately.  On the last chunk the scratch
     rows are spliced into the reserved slot and the request's first token is
     sampled from the logit at its true last prompt position.  Because the
     compressed-weight load streams (PR 1), admission can start as soon as
     the embedding + early layers are resident — prefill of the first
     requests overlaps the tail of the weight decode.
  2. **Decode** — ONE fused ``decode_fn`` call advances every slot: ``pos``
     is the ``(B,)`` per-slot ``kv_len`` vector, so a request 3 tokens deep
     and one 300 tokens deep share the same jitted step (ragged attention via
     per-slot ``kv_len`` masking in ``models/layers.py``).
  3. **Detach** — slots whose request hit EOS or ``max_new_tokens`` are
     released (and their cache rows compacted) without stalling the batch;
     the freed slot is eligible for admission on the next step.

Inactive slots still ride through the fused step (their lane computes a
garbage token that is never read, and their row-0 cache write lands in freed
memory that the next ``insert`` overwrites) — wasted lanes are the price of a
single compiled shape, and they convert into admitted requests on the very
next step.

Numerics: the engine drives the SAME jitted step functions as the lockstep
:class:`~repro.serving.engine.Engine`, and per-slot masking makes each lane
independent of its neighbors, so a request's greedy tokens are bit-identical
whether it runs alone through ``Engine.generate`` or packed in a slot batch
(asserted by ``tests/test_continuous_batching.py`` and the traffic
benchmark).  One carve-out: MoE dispatch capacity is shared across the
batch, so bit-identity additionally needs ``capacity_factor >= num_experts /
top_k`` (no token ever drops) — ``__init__`` warns when a config falls
short.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.spec import KVCompressionSpec
from repro.models import api
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from ..engine import ServeConfig, ServeSteps, _fence, sample
from ..kvcache import BlockKVManager
from .queue import RequestQueue
from .request import Request, RequestState, SamplingParams
from .slots import SlotBatchManager


@jax.jit
def _sample_slots(logits, keys, temps):
    """Per-slot sampling with per-request PRNG streams.

    logits: (B, 1, V); keys: (B, 2) uint32 (one stream per slot, split fresh
    every step); temps: (B,) f32 — greedy lanes (temp <= 0) ignore their key.
    Returns (tokens (B,), advanced keys (B, 2)).
    """
    last = logits[:, -1]
    greedy = jnp.argmax(last, axis=-1).astype(jnp.int32)
    ks = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
    cat = jax.vmap(
        lambda k, l, t: jax.random.categorical(k, l / jnp.maximum(t, 1e-6))
    )(ks[:, 1], last, temps).astype(jnp.int32)
    return jnp.where(temps > 0, cat, greedy), ks[:, 0]


class ContinuousEngine:
    """Serve concurrent, independently-arriving requests over one slot batch.

    Families must implement the slot-batch cache contract
    (``api.supports_continuous_batching``): dense and moe today; recurrent
    caches (ssm/hybrid/encdec) need family-specific slot state and raise.

    ``resident="compressed"`` serves the slot batch straight from the
    entropy-coded container — ``params`` must then be a
    :class:`repro.serving.resident.CompressedResidentWeights`, and the
    per-layer drivers replace the jitted whole-tree steps with identical
    numerics (docs/SERVING.md §"Compressed-resident serving").
    """

    def __init__(self, cfg: ArchConfig, params: Dict[str, Any],
                 sc: ServeConfig, *, n_slots: int = 8, max_queue: int = 64,
                 prefill_chunk: int = 32, admit_chunks_per_step: int = 4,
                 mesh=None, rules=None,
                 steps: Optional[ServeSteps] = None,
                 resident: str = "dense",
                 kv_spec: Optional[KVCompressionSpec] = None,
                 kv_blocks: Optional[int] = None,
                 handoff_sink=None):
        if not api.supports_continuous_batching(cfg):
            raise NotImplementedError(
                f"family {cfg.family!r} does not implement the slot-batch "
                f"cache contract (prefill_chunk + per-slot decode positions);"
                f" supported today: dense, moe")
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        if admit_chunks_per_step < 1:
            raise ValueError(f"admit_chunks_per_step must be >= 1, "
                             f"got {admit_chunks_per_step}")
        if cfg.moe is not None and \
                cfg.moe.capacity_factor * cfg.moe.top_k < cfg.moe.num_experts:
            # GShard capacity is shared across the batch, so a token that
            # routes fine solo can be DROPPED when packed with busy neighbors
            # — packing-dependent outputs.  cf >= E/top_k admits the worst
            # case (every token on one expert) and restores bit-identity.
            import warnings
            warnings.warn(
                f"{cfg.name}: moe capacity_factor={cfg.moe.capacity_factor} "
                f"< num_experts/top_k = "
                f"{cfg.moe.num_experts / cfg.moe.top_k:.2f}; expert overflow "
                f"under slot batching can drop tokens a solo run would keep, "
                f"so outputs may depend on batch packing (raise "
                f"capacity_factor to >= num_experts/top_k for bit-identical "
                f"serving)", stacklevel=2)
        if handoff_sink is not None and kv_spec is None:
            raise ValueError(
                "handoff_sink needs the paged KV cache (kv_spec): the "
                "disaggregated handoff ships block payloads, and only "
                "BlockKVManager implements export_blocks (docs/FLEET.md)")
        self.cfg = cfg
        self.params = params
        self.sc = sc
        # disaggregated prefill replicas: called as sink(engine, slot, req)
        # right after a request's prefill completes and its first token is
        # sampled; the sink must export_request() the slot (docs/FLEET.md)
        self.handoff_sink = handoff_sink
        self.steps = steps if steps is not None else \
            ServeSteps(cfg, sc, mesh=mesh, rules=rules, resident=resident)
        self.paged = kv_spec is not None
        if self.paged:
            # paged KV rides the block-pool step functions (docs/KV_CACHE.md)
            if not api.supports_paged_kv(cfg):
                raise NotImplementedError(
                    f"family {cfg.family!r} does not implement the paged "
                    f"block-pool cache contract (init_kv_pool + "
                    f"paged_decode_step); supported today: dense, moe")
            if self.steps.paged_decode_fn is None:
                raise NotImplementedError(
                    "paged KV needs the dense-residency whole-tree steps; "
                    "serve with resident='dense' (docs/KV_CACHE.md)")
            if self.steps.mesh is not None:
                raise NotImplementedError(
                    "paged KV is single-device today: the block table is a "
                    "host-side gather index with no sharding rule yet")
            self.slots: Any = BlockKVManager(
                cfg, n_slots, sc.max_len, spec=kv_spec, n_blocks=kv_blocks,
                prefill_chunk=prefill_chunk)
        else:
            self.slots = SlotBatchManager(cfg, n_slots, sc.max_len)
        if not self.paged and self.steps.mesh is not None:
            # the resident slot pool lives sharded on the serve mesh ("slot"
            # resolves like lockstep batch rows — serve_rules); the donating
            # _splice/_zero_slot helpers then keep that placement step over
            # step, and scratch prefill caches (batch 1, unshardable) splice
            # in through GSPMD without ever re-laying-out the pool
            self.slots.cache = jax.device_put(
                self.slots.cache,
                self.steps.cache_shardings(n_slots, layout="slot"))
        self.queue = RequestQueue(max_queue)
        self.prefill_chunk = prefill_chunk
        self.admit_chunks_per_step = admit_chunks_per_step
        self.finished: List[Request] = []
        self.n_decode_steps = 0
        self._prefilling: Optional[dict] = None   # in-flight admission state
        # per-slot device-step state (host mirrors; tiny, synced every step)
        self._tokens = np.zeros((n_slots,), np.int32)
        self._keys = np.zeros((n_slots, 2), np.uint32)
        self._temps = np.zeros((n_slots,), np.float32)

    # --------------------------------------------------------------- submit
    def submit(self, prompt, max_new_tokens: int, *,
               sampling: SamplingParams = SamplingParams(),
               eos_id: Optional[int] = None,
               deadline_s: Optional[float] = None) -> Request:
        """Queue one request (raises ``QueueFullError`` under backpressure)."""
        req = Request(prompt=np.asarray(prompt), max_new_tokens=max_new_tokens,
                      sampling=sampling, eos_id=eos_id, deadline_s=deadline_s)
        return self.submit_request(req)

    def submit_request(self, req: Request) -> Request:
        """External-admission hook: queue a pre-built :class:`Request`.

        The fleet router (``serving/fleet/router.py``) builds one Request at
        the fleet boundary and dispatches it to a replica through this seam,
        so rid / timestamps / sampling state stay with the same object across
        redrives.  Raises ``QueueFullError`` under backpressure and
        ``ValueError`` when the request cannot fit ``max_len`` on any step."""
        P = req.prompt_len
        chunks = -(-P // self.prefill_chunk) * self.prefill_chunk
        need = max(P + req.max_new_tokens, chunks)
        if need > self.sc.max_len:
            raise ValueError(
                f"request {req.rid} needs {need} cache rows (prompt {P} + "
                f"{req.max_new_tokens} new, prefill padded to {chunks}) but "
                f"max_len is {self.sc.max_len}")
        return self.queue.submit(req)

    # ------------------------------------------------------------ admission
    def _start_prefill(self, req: Request) -> None:
        """Reserve a slot and set up the chunked-prefill pipeline state."""
        req.state = RequestState.PREFILLING
        req.t_admitted = time.monotonic()
        obs_metrics.histogram("queue.wait_s").observe(
            req.queue_wait_s or 0.0, outcome="admitted")
        P, chunk = req.prompt_len, self.prefill_chunk
        padded = -(-P // chunk) * chunk
        toks = np.zeros((1, padded), np.int32)
        toks[0, :P] = req.prompt
        if self.paged:
            # block admission: claim table entries (consuming shared prefix
            # blocks) and start prefill AFTER the shared region — the block
            # manager guarantees the final chunk (position P-1) always runs
            got = self.slots.alloc(req)
            assert got is not None, "admission past can_admit"
            slot, skip = got
            self._prefilling = dict(req=req, slot=slot, toks=toks, c0=skip,
                                    last=None, scratch=None)
            return
        slot = self.slots.alloc(req)
        assert slot is not None, "admission with no free slot"
        self._prefilling = dict(
            req=req, slot=slot, toks=toks, c0=0, last=None,
            scratch=self.steps.mod.init_cache(self.cfg, 1, self.sc.max_len))

    def _advance_prefill(self) -> None:
        """Run ONE prefill chunk; on the last chunk, splice the scratch rows
        into the reserved slot and sample the request's first token."""
        st = self._prefilling
        req, chunk = st["req"], self.prefill_chunk
        P, c0 = req.prompt_len, st["c0"]
        with obs_trace.span("serve.admit_chunk", rid=req.rid, c0=c0):
            if self.paged:
                bt = jnp.asarray(self.slots.table_rows([st["slot"]]))
                logits, self.slots.pool = self.steps.paged_prefill_chunk_fn(
                    self.params, jnp.asarray(st["toks"][:, c0:c0 + chunk]),
                    self.slots.pool, bt, jnp.full((1,), c0, jnp.int32))
            else:
                logits, st["scratch"] = self.steps.prefill_chunk_fn(
                    self.params, jnp.asarray(st["toks"][:, c0:c0 + chunk]),
                    st["scratch"], jnp.full((1,), c0, jnp.int32))
            _fence(logits)
        if c0 <= P - 1 < c0 + chunk:
            st["last"] = logits[:, P - 1 - c0][:, None]     # (1, 1, V)
        st["c0"] = c0 + chunk
        if st["c0"] < st["toks"].shape[1]:
            return
        self._prefilling = None
        slot = st["slot"]
        if self.paged:
            self.slots.insert(slot, P)      # prefill wrote the pool in place
        else:
            self.slots.insert(slot, st["scratch"], P)
        key, sub = jax.random.split(jax.random.PRNGKey(req.sampling.seed))
        tok = int(sample(st["last"], sub, req.sampling.temperature)[0])
        req.t_first_token = time.monotonic()
        obs_metrics.histogram("request.ttft_s").observe(req.ttft_s or 0.0)
        req.state = RequestState.DECODING
        req.output.append(tok)
        self._tokens[slot] = tok
        kd = key if key.dtype == jnp.uint32 else jax.random.key_data(key)
        self._keys[slot] = np.asarray(kd, np.uint32)
        self._temps[slot] = req.sampling.temperature
        if self._hit_stop(req, tok):
            self._detach(slot, req, tok)
        elif self.handoff_sink is not None:
            # disaggregated prefill replica: the request never decodes here —
            # the sink exports the slot's KV blocks + sampling lane and the
            # decode side continues from the exact same state
            self.handoff_sink(self, slot, req)

    def _decoding(self) -> List[int]:
        return [s for s, r in enumerate(self.slots.requests)
                if r is not None and r.state is RequestState.DECODING]

    # ------------------------------------------------------------- stepping
    def step(self) -> bool:
        """One scheduler iteration: advance admission by at most
        ``admit_chunks_per_step`` prefill chunks (to completion while nothing
        is decoding), then one fused decode step over every slot.  Returns
        False when idle (nothing queued, nothing prefilling, nothing
        decoding)."""
        with obs_trace.span("serve.step", step=self.n_decode_steps):
            return self._step_inner()

    def _step_inner(self) -> bool:
        progressed = False
        chunks = 0
        while True:
            if self._prefilling is None and self.slots.n_free:
                if self.paged:
                    # peek-then-plan: commit the pop only once the block
                    # manager can cover the head's whole allocation
                    head = self.queue.peek()
                    if head is not None and self.slots.can_admit(head):
                        self._start_prefill(self.queue.pop())
                else:
                    req = self.queue.pop()
                    if req is not None:
                        self._start_prefill(req)
            if self._prefilling is None:
                break
            self._advance_prefill()
            chunks += 1
            progressed = True
            if self._decoding() and chunks >= self.admit_chunks_per_step:
                break       # a batch is running: bounded stall, move on

        active = self._decoding()
        if not active:
            return progressed

        with obs_trace.span("serve.decode_batch", active=len(active)):
            pos = jnp.asarray(self.slots.kv_len)
            tok = jnp.asarray(self._tokens[:, None])
            if self.paged:
                bt = jnp.asarray(self.slots.decode_tables())
                logits, self.slots.pool = self.steps.paged_decode_fn(
                    self.params, tok, self.slots.pool, bt, pos)
            else:
                logits, self.slots.cache = self.steps.decode_fn(
                    self.params, tok, self.slots.cache, pos)
            new_tok, new_keys = _sample_slots(logits, jnp.asarray(self._keys),
                                              jnp.asarray(self._temps))
            new_tok = np.asarray(new_tok)
        self._keys = np.array(new_keys)     # copy: host mirror stays writable
        self.n_decode_steps += 1
        obs_metrics.counter("serve.tokens").inc(len(active))
        for s in active:
            self.slots.kv_len[s] += 1
            req = self.slots.requests[s]
            t = int(new_tok[s])
            req.output.append(t)
            self._tokens[s] = t
            if self._hit_stop(req, t):
                self._detach(s, req, t)
        return True

    def run(self) -> List[Request]:
        """Drain queue + slots to completion; returns finished requests."""
        n0 = len(self.finished)
        while self.step():
            pass
        return self.finished[n0:]

    @property
    def has_work(self) -> bool:
        return bool(len(self.queue)) or self._prefilling is not None \
            or bool(self.slots.active)

    # ------------------------------------------------- fleet seams (export)
    def export_request(self, slot: int):
        """Detach ``slot``'s request mid-flight for a KV handoff.

        Returns ``(req, kv_len, blocks, lane)``: the request object, its
        committed KV length, the raw per-block pool leaves
        (``BlockKVManager.export_blocks``), and the sampling lane state
        ``(token, key, temp)`` a peer engine needs to continue decode from
        the exact device state this engine would have used.  The slot is
        released.  Paged engines only."""
        assert self.paged, "export_request needs the paged KV cache"
        req = self.slots.requests[slot]
        assert req is not None, f"export of free slot {slot}"
        kv_len = int(self.slots.kv_len[slot])
        blocks = self.slots.export_blocks(slot)
        lane = (int(self._tokens[slot]),
                np.array(self._keys[slot]),
                float(self._temps[slot]))
        self.slots.release(slot)
        self._tokens[slot] = 0
        self._keys[slot] = 0
        self._temps[slot] = 0.0
        return req, kv_len, blocks, lane

    def can_adopt(self, req: Request, kv_len: int, n_blocks: int) -> bool:
        """Probe for ``adopt_request`` (peek-then-adopt, like can_admit)."""
        assert self.paged, "can_adopt needs the paged KV cache"
        return self.slots.can_import(req, kv_len, n_blocks)

    def adopt_request(self, req: Request, kv_len: int, blocks, lane) -> bool:
        """Admit an externally prefilled request: install its KV blocks and
        sampling lane, then decode it like any local request.  Returns False
        (nothing changed) when no slot or not enough pool blocks are free —
        the handoff coordinator retries on a later pump.  Paged engines
        only."""
        assert self.paged, "adopt_request needs the paged KV cache"
        slot = self.slots.import_blocks(req, kv_len, blocks)
        if slot is None:
            return False
        tok, key, temp = lane
        req.state = RequestState.DECODING
        self._tokens[slot] = tok
        self._keys[slot] = np.asarray(key, np.uint32)
        self._temps[slot] = temp
        return True

    def evacuate(self) -> List[Request]:
        """Strip every unfinished request off the engine, oldest first.

        The failed-replica redrive path: the fleet driver marks a replica
        FAILED, evacuates it, resets each request (``Request.requeue``) and
        re-enqueues them at the fleet intake — nothing is lost, nothing is
        duplicated.  Queued, mid-prefill, and decoding requests are all
        harvested; the engine is left empty but serviceable."""
        out: List[Request] = []
        while True:
            r = self.queue.pop()    # lazy-expires overdue heads in passing
            if r is None:
                break
            out.append(r)
        # a mid-prefill request already occupies its reserved slot
        # (alloc registered it in slots.requests), so the slot sweep below
        # harvests it; only the pipeline state needs clearing here
        self._prefilling = None
        for s, r in enumerate(list(self.slots.requests)):
            if r is not None:
                self.slots.release(s)
                self._tokens[s] = 0
                self._keys[s] = 0
                self._temps[s] = 0.0
                out.append(r)
        out.sort(key=lambda r: (r.t_arrival if r.t_arrival is not None
                                else float("inf"), r.rid))
        return out

    # -------------------------------------------------------------- private
    @staticmethod
    def _hit_stop(req: Request, tok: int) -> bool:
        return (req.eos_id is not None and tok == req.eos_id) \
            or len(req.output) >= req.max_new_tokens

    def _detach(self, slot: int, req: Request, tok: int) -> None:
        req.finish_reason = "eos" \
            if (req.eos_id is not None and tok == req.eos_id) else "length"
        req.state = RequestState.FINISHED
        req.t_finished = time.monotonic()
        self.slots.release(slot)
        self._tokens[slot] = 0
        self._keys[slot] = 0
        self._temps[slot] = 0.0
        self.finished.append(req)
        obs_metrics.histogram("request.latency_s").observe(req.latency_s or 0.0)
        obs_metrics.counter("requests.finished").inc(reason=req.finish_reason)
        # lifecycle record built from the Request's own monotonic stamps
        # (same clock Lifecycle uses), so the chain is exact, not re-measured
        lc = obs_metrics.lifecycle(req.rid, outcome=req.finish_reason,
                                   tokens=len(req.output))
        for name, t in (("queued", req.t_arrival),
                        ("admitted", req.t_admitted),
                        ("first_token", req.t_first_token),
                        ("done", req.t_finished)):
            if t is not None:
                lc.event(name, t)
