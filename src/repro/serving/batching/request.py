"""Request lifecycle for continuous-batching serving.

A request is born QUEUED (admission control in :class:`~.queue.RequestQueue`),
becomes PREFILLING while its prompt is chunk-prefilled into a scratch cache,
DECODING once it occupies a slot of the batched KV cache, and detaches as
FINISHED (EOS or ``max_new_tokens``) without stalling the rest of the batch.
EXPIRED marks requests whose admission deadline passed while still queued;
REJECTED marks requests bounced by the queue bound.

Timestamps are monotonic-clock seconds stamped by the queue/engine; the
traffic benchmark derives queue wait, TTFT, and end-to-end latency from them.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import time
from typing import List, Optional

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"
    EXPIRED = "expired"      # admission deadline passed while queued
    REJECTED = "rejected"    # queue bound hit at submit


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling: greedy at ``temperature <= 0``; otherwise
    temperature-categorical with a request-private PRNG stream seeded by
    ``seed`` (one fresh split per generated token)."""
    temperature: float = 0.0
    seed: int = 0


_ids = itertools.count()


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                       # (P,) int32 token ids
    max_new_tokens: int
    sampling: SamplingParams = SamplingParams()
    eos_id: Optional[int] = None             # None = run to max_new_tokens
    deadline_s: Optional[float] = None       # max seconds queued before expiry
    rid: int = dataclasses.field(default_factory=lambda: next(_ids))

    state: RequestState = RequestState.QUEUED
    output: List[int] = dataclasses.field(default_factory=list)
    finish_reason: Optional[str] = None      # "eos" | "length" | "deadline" | "queue_full" | "no_replica"
    redrives: int = 0                        # times re-enqueued after a replica failure

    t_arrival: Optional[float] = None
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_finished: Optional[float] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {self.max_new_tokens}")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return self.state in (RequestState.FINISHED, RequestState.EXPIRED,
                              RequestState.REJECTED)

    # ---- metric views (None until the corresponding event happened) --------
    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.t_admitted is None or self.t_arrival is None:
            return None
        return self.t_admitted - self.t_arrival

    @property
    def ttft_s(self) -> Optional[float]:
        if self.t_first_token is None or self.t_arrival is None:
            return None
        return self.t_first_token - self.t_arrival

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_finished is None or self.t_arrival is None:
            return None
        return self.t_finished - self.t_arrival

    def requeue(self) -> None:
        """Reset for a redrive after a replica failure (fleet router).

        Generated tokens are discarded and the request decodes again from
        its prompt — greedy decode is deterministic and sampling re-derives
        the same per-request PRNG stream from ``sampling.seed``, so the
        rerun reproduces the lost tokens bit-identically.  ``t_arrival`` is
        kept: the deadline covers total time in the system, redrives
        included."""
        self.state = RequestState.QUEUED
        self.output = []
        self.finish_reason = None
        self.t_admitted = None
        self.t_first_token = None
        self.redrives += 1

    def expired(self, now: Optional[float] = None) -> bool:
        if self.deadline_s is None or self.t_arrival is None:
            return False
        return (time.monotonic() if now is None else now) \
            > self.t_arrival + self.deadline_s
