"""Slot-based KV cache pool for continuous batching.

The manager owns ONE fixed-capacity batched cache pytree (the model family's
``init_cache(cfg, n_slots, max_len)`` layout — axis 1 is the slot axis, see
``cache_specs(cfg, layout="slot")``) plus the per-slot bookkeeping the jitted
step cannot hold: ``kv_len`` per slot, the free list, and the slot → request
map.  All device-side mutation goes through two jitted, donating helpers:

* ``insert``  — splice a freshly prefilled request's cache rows into a slot
  (one ``dynamic_update_slice`` per leaf; overwrites the whole slot, so
  whatever a previous occupant or an idle decode step left there is gone);
* ``release`` — free the slot and *compact* it (zero the slot's rows), so a
  dead request's keys don't linger in cache memory until reuse.

Slot alloc/free is O(1); there is no cross-slot copying — "compaction" here
means reclaim-and-zero, not defragmentation, because slots are fixed-size
rows of one preallocated pool and can never fragment.  Compaction is hygiene,
not a correctness requirement: correctness rests on ``insert`` overwriting
every row of the slot and on ``kv_len`` masking, and a freed slot does not
stay pristine — idle lanes riding the engine's fused decode step deposit one
garbage k/v row (at position 0) per step until the slot is reused.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api
from repro.obs import metrics as obs_metrics
from .request import Request


@partial(jax.jit, donate_argnums=(0,))
def _splice(cache, req_cache, slot):
    def leaf(c, r):
        return jax.lax.dynamic_update_slice_in_dim(c, r.astype(c.dtype),
                                                   slot, axis=1)
    return jax.tree.map(leaf, cache, req_cache)


@partial(jax.jit, donate_argnums=(0,))
def _zero_slot(cache, slot):
    def leaf(c):
        blank = jnp.zeros(c.shape[:1] + (1,) + c.shape[2:], c.dtype)
        return jax.lax.dynamic_update_slice_in_dim(c, blank, slot, axis=1)
    return jax.tree.map(leaf, cache)


class SlotBatchManager:
    """Fixed-capacity slotted KV cache + per-slot request bookkeeping."""

    def __init__(self, cfg: ArchConfig, n_slots: int, max_len: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = api.build(cfg).init_cache(cfg, n_slots, max_len)
        self.kv_len = np.zeros((n_slots,), np.int32)
        self.requests: List[Optional[Request]] = [None] * n_slots
        self._free: List[int] = list(range(n_slots - 1, -1, -1))  # pop() -> 0 first

    # ------------------------------------------------------------- occupancy
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def active(self) -> List[int]:
        return [s for s, r in enumerate(self.requests) if r is not None]

    # ------------------------------------------------------------- lifecycle
    def alloc(self, req: Request) -> Optional[int]:
        """Claim a free slot for ``req``; None when the batch is full."""
        if not self._free:
            return None
        slot = self._free.pop()
        self.requests[slot] = req
        self.kv_len[slot] = 0
        obs_metrics.gauge("slots.occupied").set(self.n_slots - len(self._free))
        return slot

    def insert(self, slot: int, req_cache: Dict[str, Any], kv_len: int) -> None:
        """Splice a prefilled single-request cache (batch dim 1) into ``slot``."""
        assert self.requests[slot] is not None, f"insert into free slot {slot}"
        assert kv_len <= self.max_len, (kv_len, self.max_len)
        self.cache = _splice(self.cache, req_cache, jnp.int32(slot))
        self.kv_len[slot] = kv_len
        obs_metrics.counter("slots.inserts").inc()

    def release(self, slot: int, *, compact: bool = True) -> Request:
        """Detach the slot's request; by default compact (zero) its rows."""
        req = self.requests[slot]
        assert req is not None, f"release of free slot {slot}"
        self.requests[slot] = None
        self.kv_len[slot] = 0
        self._free.append(slot)
        obs_metrics.counter("slots.releases").inc()
        obs_metrics.gauge("slots.occupied").set(self.n_slots - len(self._free))
        if compact:
            self.cache = _zero_slot(self.cache, jnp.int32(slot))
            obs_metrics.counter("slots.compactions").inc()
        return req
