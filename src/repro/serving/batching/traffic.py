"""Synthetic traffic generation + replay for continuous-batching serving.

Shared by ``benchmarks/serving_traffic.py`` and ``repro.launch.serve
--traffic`` so arrival pacing, ragged-request sampling, and the
submit-when-due driver loop live in exactly one place.
"""
from __future__ import annotations

import time
from typing import List, Optional, Tuple

import numpy as np

from .engine import ContinuousEngine
from .queue import QueueFullError
from .request import Request

Trace = List[Tuple[float, np.ndarray, int]]     # (arrival_s, prompt, max_new)


def poisson_trace(n_requests: int, *, rate_per_s: float, prompt_max: int,
                  gen_max: int, vocab: int, seed: int = 0,
                  prompt_min: int = 4, gen_min: int = 2,
                  prefix_pool: int = 0, prefix_len: int = 0) -> Trace:
    """Seeded Poisson arrival trace with ragged prompt/gen lengths.

    The ragged lower bounds clamp to the caller's maxima, so degenerate
    settings (``prompt_max < prompt_min``) produce fixed-size requests
    instead of crashing.

    ``prefix_pool > 0`` models shared system prompts: ``prefix_pool``
    distinct prefixes of ``prefix_len`` tokens are drawn once, and every
    request opens with one of them (uniformly chosen) followed by a ragged
    unique suffix of at least one token — the workload prefix sharing in the
    paged KV cache (docs/KV_CACHE.md) is built to exploit.

    **Determinism contract (fleet serving).**  Request *content* is drawn
    from a per-request derived stream: entry ``i``'s (prompt, gen) depends
    only on ``(seed, i)``, the length bounds, and the prefix pool — never on
    ``n_requests``, ``rate_per_s``, or anything drawn for other entries.
    Arrival pacing and the prefix pool each have their own derived stream.
    A trace is therefore *prefix-stable*: ``poisson_trace(n, ...)[:k] ==
    poisson_trace(k, ...)`` (same kwargs) for every ``k <= n``, so the fleet
    benchmark can scale trace length with replica count without any
    request's content changing.  The pre-fleet version drew everything from
    ONE stream, where the block of ``n`` arrival gaps shifted every
    subsequent draw — two traces differing only in length disagreed on
    every prompt (regression: ``tests/fleet/test_router.py``).
    """
    arrivals_rng = np.random.default_rng([seed, 0])
    gaps = arrivals_rng.exponential(1.0 / rate_per_s, n_requests)
    arrivals = np.cumsum(gaps) - (gaps[0] if n_requests else 0.0)
    pmin = min(prompt_min, prompt_max)
    gmin = min(gen_min, gen_max)
    prefixes = []
    if prefix_pool > 0:
        if prefix_len < 1:
            raise ValueError(f"prefix_pool={prefix_pool} needs "
                             f"prefix_len >= 1, got {prefix_len}")
        prefix_rng = np.random.default_rng([seed, 1])
        prefixes = [prefix_rng.integers(0, vocab,
                                        (prefix_len,)).astype(np.int32)
                    for _ in range(prefix_pool)]
    trace: Trace = []
    for i in range(n_requests):
        rng = np.random.default_rng([seed, 2, i])   # request-private stream
        G = int(rng.integers(gmin, gen_max + 1))
        if prefixes:
            smax = max(prompt_max - prefix_len, 1)  # suffix keeps >= 1 token
            S = int(rng.integers(1, smax + 1))
            prompt = np.concatenate([
                prefixes[int(rng.integers(len(prefixes)))],
                rng.integers(0, vocab, (S,)).astype(np.int32)])
        else:
            P = int(rng.integers(pmin, prompt_max + 1))
            prompt = rng.integers(0, vocab, (P,)).astype(np.int32)
        trace.append((float(arrivals[i]), prompt, G))
    return trace


def replay(ce: ContinuousEngine, trace: Trace, *, shed_on_full: bool = False
           ) -> Tuple[List[Optional[Request]], int, float]:
    """Feed ``trace`` through the engine as arrival timestamps come due.

    Returns ``(requests, shed, makespan_s)`` — ``requests`` in trace order
    (None where an arrival was shed), ``shed`` the number of arrivals
    bounced by queue backpressure (only possible with ``shed_on_full=True``;
    otherwise ``QueueFullError`` propagates), and the wall-clock makespan.
    """
    t0 = time.monotonic()
    pending = list(trace)
    requests: List[Optional[Request]] = []
    shed = 0
    while pending or ce.has_work:
        now = time.monotonic() - t0
        while pending and pending[0][0] <= now:
            _, prompt, max_new = pending[0]
            try:
                requests.append(ce.submit(prompt, max_new))
            except QueueFullError:
                if not shed_on_full:
                    raise
                shed += 1
                requests.append(None)
            pending.pop(0)
        if not ce.step() and pending:
            time.sleep(max(0.0, min(pending[0][0] - (time.monotonic() - t0),
                                    1e-3)))
    return requests, shed, time.monotonic() - t0


def replay_fleet(driver, trace: Trace, *, shed_on_full: bool = False,
                 threaded: bool = False
                 ) -> Tuple[List[Optional[Request]], int, float]:
    """Feed ``trace`` through a :class:`~repro.serving.fleet.FleetDriver`.

    Same submit-when-due pacing and return shape as :func:`replay`, but
    arrivals land at the fleet intake and the router spreads them over the
    replicas.  ``threaded=True`` runs one worker thread per replica
    (``driver.start_workers``) with the submit loop pumping dispatch from
    this thread; the default steps the whole fleet in deterministic lockstep
    (``driver.step``) — the mode every fleet test uses (docs/FLEET.md
    §"Drive modes").
    """
    t0 = time.monotonic()
    pending = list(trace)
    requests: List[Optional[Request]] = []
    shed = 0
    if threaded:
        driver.start_workers()
    try:
        while pending or driver.has_work:
            now = time.monotonic() - t0
            while pending and pending[0][0] <= now:
                _, prompt, max_new = pending[0]
                try:
                    requests.append(driver.submit(prompt, max_new))
                except QueueFullError:
                    if not shed_on_full:
                        raise
                    shed += 1
                    requests.append(None)
                pending.pop(0)
            if threaded:
                driver.pump()
                time.sleep(2e-4)
            elif not driver.step() and pending:
                time.sleep(max(0.0,
                               min(pending[0][0] - (time.monotonic() - t0),
                                   1e-3)))
    finally:
        if threaded:
            driver.stop_workers()
    return requests, shed, time.monotonic() - t0
