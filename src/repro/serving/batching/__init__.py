"""Continuous-batching serving subsystem.

Request lifecycle (:mod:`.request`), bounded admission queue (:mod:`.queue`),
slot-based KV cache pool (:mod:`.slots`), and the scheduler that fuses them
over the shared jitted step functions (:mod:`.engine`).  See
docs/ARCHITECTURE.md §"Serving".
"""
from .engine import ContinuousEngine
from .queue import QueueFullError, RequestQueue
from .request import Request, RequestState, SamplingParams
from .slots import SlotBatchManager
from .traffic import poisson_trace, replay, replay_fleet

__all__ = [
    "ContinuousEngine", "QueueFullError", "Request", "RequestQueue",
    "RequestState", "SamplingParams", "SlotBatchManager", "poisson_trace",
    "replay", "replay_fleet",
]
