"""Bounded admission queue for continuous-batching serving.

FIFO with two control points:

* **Backpressure** — ``submit`` raises :class:`QueueFullError` once
  ``max_queue`` requests are waiting (the caller sheds load instead of the
  engine hoarding unbounded host memory).
* **Deadlines** — a request may carry ``deadline_s`` (max seconds it is
  willing to wait for admission); ``pop`` lazily expires overdue requests
  instead of handing dead work to the batch.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Deque, List, Optional

from repro.obs import metrics as obs_metrics
from .request import Request, RequestState


class QueueFullError(RuntimeError):
    """Raised by submit when the queue is at its bound."""


class RequestQueue:
    def __init__(self, max_queue: int = 64):
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_queue = max_queue
        self._q: Deque[Request] = deque()
        self.expired: List[Request] = []     # deadline casualties, for metrics
        self.n_rejected = 0

    def __len__(self) -> int:
        return len(self._q)

    def submit(self, req: Request, now: Optional[float] = None) -> Request:
        """Admit ``req`` to the waiting line (stamps ``t_arrival``)."""
        if len(self._q) >= self.max_queue:
            self.n_rejected += 1
            req.state = RequestState.REJECTED
            req.finish_reason = "queue_full"
            obs_metrics.counter("queue.shed").inc(reason="queue_full")
            raise QueueFullError(
                f"queue at bound ({self.max_queue} waiting); request "
                f"{req.rid} rejected")
        req.t_arrival = time.monotonic() if now is None else now
        req.state = RequestState.QUEUED
        self._q.append(req)
        obs_metrics.counter("queue.submitted").inc()
        obs_metrics.gauge("queue.depth").set(len(self._q))
        return req

    def requeue(self, req: Request) -> Request:
        """Return ``req`` to the *front* of the line (fleet redrive path).

        Bypasses the ``max_queue`` bound on purpose: a redriven request was
        already admitted once, and dropping it here would violate the
        router's no-loss contract — transient over-bound depth is the cost
        of a replica failure, and ``submit`` backpressure shrinks it again.
        ``t_arrival`` is NOT restamped (deadlines keep counting)."""
        req.state = RequestState.QUEUED
        self._q.appendleft(req)
        obs_metrics.gauge("queue.depth").set(len(self._q))
        return req

    def peek(self, now: Optional[float] = None) -> Optional[Request]:
        """The request ``pop`` would return, without removing it.  Overdue
        heads are expired in passing (same lazy semantics as ``pop``), so a
        peek-then-pop pair always agrees on the head — the paged engine
        plans block admission against the peeked request before committing."""
        now = time.monotonic() if now is None else now
        while self._q:
            req = self._q[0]
            if not req.expired(now):
                return req
            self._q.popleft()
            obs_metrics.gauge("queue.depth").set(len(self._q))
            req.state = RequestState.EXPIRED
            req.finish_reason = "deadline"
            req.t_finished = now
            self.expired.append(req)
            obs_metrics.counter("queue.shed").inc(reason="deadline")
            if req.t_arrival is not None:
                obs_metrics.histogram("queue.wait_s").observe(
                    now - req.t_arrival, outcome="shed")
        return None

    def pop(self, now: Optional[float] = None) -> Optional[Request]:
        """Next admissible request, or None.  Overdue requests are expired in
        passing (state EXPIRED, ``finish_reason="deadline"``)."""
        now = time.monotonic() if now is None else now
        while self._q:
            req = self._q.popleft()
            obs_metrics.gauge("queue.depth").set(len(self._q))
            if req.expired(now):
                req.state = RequestState.EXPIRED
                req.finish_reason = "deadline"
                req.t_finished = now
                self.expired.append(req)
                obs_metrics.counter("queue.shed").inc(reason="deadline")
                if req.t_arrival is not None:
                    obs_metrics.histogram("queue.wait_s").observe(
                        now - req.t_arrival, outcome="shed")
                continue
            return req
        return None
