"""Batched serving engine with the EntroLLM weight path.

Pipeline (paper Alg. 1 EDGE DEVICE OPERATIONS, pod-scale):

  1. **Load**: the engine takes a :class:`core.store.CompressedModel`
     (entropy-coded container).  Weights are parallel-decoded ONCE per engine
     start — the analogue of the paper's once-per-sequence decode, amortized
     over every request the engine ever serves.  The default load path
     *streams*: the :class:`~repro.core.scheduler.DecodeScheduler` feeds
     fixed-budget chunks (embedding first) through a pluggable decoder
     backend with double-buffered prefetch, so host memory stays bounded and
     the first weights are resident long before the last chunk decodes
     (``time_to_first_weight_s`` in the load metrics).
  2. **Residency**: decoded weights stay *quantized* (uint8 symbols + scale +
     zero as :class:`models.layers.QT` triples) in HBM; dequantization fuses
     into each consuming matmul.  HBM traffic per decode step is 1 byte/param
     (uint8) or 0.5 (packed uint4) instead of 2 (bf16) — the bandwidth saving
     the paper measures on Jetson, realized on the TPU memory roofline.
  3. **Serve**: `prefill` then repeated `decode_step`, both jitted with the
     serve shardings; sampling is greedy or temperature-categorical.

``serve_step`` (single decode step) is the function the dry-run lowers for
decode-shape roofline cells.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.spec import quantizable_shape as _quantizable_shape
from repro.core.store import _DEFAULT_CHUNK, CompressedModel
from repro.models import api
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

# historical ad-hoc metric-dict keys -> canonical registry gauge names (the
# deprecated read-through surface Engine.generate keeps alive; the catalog
# lives in docs/OBSERVABILITY.md)
_LEGACY_GENERATE_KEYS = {
    "prefill_s": "serve.prefill_s",
    "decode_s": "serve.decode_s",
    "ttft_s": "serve.ttft_s",
    "decode_tok_per_s": "serve.decode_tok_per_s",
    "e2e_tok_per_s": "serve.e2e_tok_per_s",
    "tok_per_s": "serve.decode_tok_per_s",     # legacy alias of the alias
}


def _fence(x: Any) -> None:
    """Block on ``x`` when the active tracer asked for fenced spans
    (``--trace-sync``): JAX dispatch is asynchronous, so without a fence a
    span around a jitted call measures dispatch, not compute.  No-op (and
    no device sync) in every other configuration — tracing stays a pure
    observer of the async pipeline by default."""
    if obs_trace.sync_enabled():
        jax.block_until_ready(x)


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 2048
    temperature: float = 0.0           # 0 => greedy
    unroll: int = 1
    q_block: int = 0
    quantized_weights: bool = True     # keep QT triples in HBM (EntroLLM mode)


def serve_mesh_rules(cfg: ArchConfig, mesh) -> "Any":
    """The default rule profile for the multi-device serving path: serve
    rules (weights TP over model + FSDP over data, cache batch/slot over
    data) with the KV-head divisibility adjustment."""
    from repro.distributed import sharding as shd
    return shd.arch_rules(cfg, mesh, shd.serve_rules(mesh))


def make_param_placer(cfg: ArchConfig, mesh, rules=None) -> Callable:
    """``(name, host_value) -> placed device value`` for the streaming load.

    Each decoded tensor is ``jax.device_put`` onto the serve mesh the moment
    it leaves the decoder — placement overlaps the prefetch-decode of the
    next chunk exactly like the single-device transfer did, so sharded
    serving keeps the bounded-host-memory property of the streaming loader.
    QT/QT4 triples get consistent q/scale/zero shardings
    (:func:`repro.distributed.sharding.leaf_shardings`); names the schema
    does not know replicate.

    Default layout (``rules=None``): per-tensor output-channel TP
    (:func:`repro.distributed.sharding.serve_tp_table`) for the families the
    exact-TP serving constraints cover (dense, moe) — the bit-identical
    profile the multi-device suite asserts; other families keep weights
    replicated (batch/cache still shard over data).  Pass an explicit
    ``rules`` profile to override both.
    """
    from repro.distributed import sharding as shd
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = api.param_specs(cfg)
    exact_tp = rules is None and cfg.family in ("dense", "moe")
    replicate_all = shd.Rules({})
    rep = NamedSharding(mesh, P())

    def place(name: str, val: Any) -> Any:
        if name in axes:
            r = (shd.serve_tp_table(cfg, mesh, axes[name]) if exact_tp
                 else (rules if rules is not None else replicate_all))
            sh = shd.leaf_shardings(axes[name], val, r, mesh)
        else:
            sh = jax.tree.map(lambda _: rep, val)
        return jax.device_put(val, sh)

    return place


def per_device_bytes(tree) -> Dict[str, int]:
    """Resident bytes per device for a placed pytree (the sharded-serving
    analogue of the paper's weight-footprint accounting)."""
    out: Dict[str, int] = {}
    for leaf in jax.tree.leaves(tree):
        for sh in getattr(leaf, "addressable_shards", ()):
            key = str(sh.device)
            out[key] = out.get(key, 0) + sh.data.nbytes
    return out


def load_params_from_compressed(model: CompressedModel, *,
                                quantized: bool = True,
                                pack_int4: bool = True,
                                backend: Optional[str] = None,
                                chunk_symbols: Optional[int] = _DEFAULT_CHUNK,
                                stream: bool = True,
                                placer: Optional[Callable] = None,
                                metrics: Optional[dict] = None) -> Dict[str, Any]:
    """Decode the container into serving weights, streaming by default.

    quantized=True  -> {name: QT(q, scale, zero)} + fp32 leftovers (EntroLLM
                       path); 4-bit containers pack nibble pairs into QT4
                       (0.5 bytes/param resident) unless ``pack_int4=False``
    quantized=False -> dense fp32 weights (baseline path)

    ``stream=True`` consumes :meth:`CompressedModel.iter_decode` chunk by
    chunk: host memory stays bounded by the scheduler's chunk budget
    (``chunk_symbols``; ``None`` = one monolithic chunk, same convention as
    the scheduler), the embedding is scheduled first, and each tensor's
    device transfer overlaps the prefetch-decode of the next chunk.
    ``stream=False`` recovers the monolithic ``decode_all`` batch.
    ``backend`` is a decoder-registry name (``numpy`` / ``jax`` / ``pallas``
    / ``pallas-interpret``; None = auto) and is honored on both paths.

    ``placer`` overrides how a decoded host tensor becomes a device tensor:
    ``(name, host_value) -> device value`` — :func:`make_param_placer` builds
    the multi-device one (``jax.device_put`` with the serve-rule shardings at
    load-stream time); the default is a plain single-device transfer.

    When a ``metrics`` dict is passed it is filled with
    ``time_to_first_weight_s`` (start -> first decoded tensor resident),
    ``decode_load_s`` (total), and the resolved ``decode_backend`` name.
    """
    from repro.core.decode_backends import get_backend
    from repro.models.layers import pack_qt
    t0 = time.perf_counter()
    ttfw: Optional[float] = None
    resolved = get_backend(backend)
    place = placer if placer is not None else \
        (lambda _name, v: jax.tree.map(jnp.asarray, v))

    if stream:
        kw = dict(backend=resolved, first=("embed",),
                  chunk_symbols=chunk_symbols)
        pairs = (model.iter_dequantize(**kw) if not quantized
                 else model.iter_quantized_weights(**kw))
    elif quantized:
        pairs = iter(model.quantized_weights(backend=resolved).items())
    else:
        pairs = iter(model.dequantize_all(backend=resolved).items())

    out: Dict[str, Any] = {}
    with obs_trace.span("load.stream", cat="load", backend=resolved.name,
                        stream=stream, quantized=quantized):
        if quantized:
            for k, v in model.unquantized.items():
                out[k] = place(k, v)
        for name, val in pairs:
            if quantized and name in model.qmeta:
                q, scale, zero = val
                bits = model.qmeta[name]["bits"]
                if (not _quantizable_shape(name, model.tensors[name].shape)
                        or model.qmeta[name]["granularity"] == "per_group"):
                    # Two cases the fused dequant-matmul path cannot host, so
                    # dequantize at load instead of packing a QT struct:
                    # * norm scales / biases / sensitive params (quantized
                    #   via an explicit spec rule) — model layers consume
                    #   plain arrays;
                    # * per-group quantization — the (…, D/group, 1) scale
                    #   does not broadcast against the (…, D) weight in the
                    #   kernels.
                    out[name] = place(name, model._dequantize_one(name, q))
                else:
                    out[name] = place(name, pack_qt(q, scale, zero, bits=bits,
                                                    pack_int4=pack_int4))
            else:
                out[name] = place(name, val)
            if ttfw is None:
                jax.block_until_ready(jax.tree.leaves(out[name]))
                ttfw = time.perf_counter() - t0
        jax.block_until_ready(jax.tree.leaves(out))
    load_s = time.perf_counter() - t0
    # registry is canonical (stable names); the caller's dict keeps the
    # historical keys as a deprecated alias surface
    obs_metrics.gauge("load.decode_load_s").set(load_s)
    obs_metrics.gauge("load.time_to_first_weight_s").set(
        ttfw if ttfw is not None else 0.0)
    obs_metrics.counter("load.decodes").inc(backend=resolved.name)
    if metrics is not None:
        metrics["time_to_first_weight_s"] = ttfw if ttfw is not None else 0.0
        metrics["decode_load_s"] = load_s
        metrics["decode_backend"] = resolved.name
    return out


def sample(logits: jax.Array, key: jax.Array, temperature: float) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits[:, -1] / temperature).astype(jnp.int32)


class ServeSteps:
    """Jitted per-architecture step functions — the ONE set of compiled
    closures every serving front end drives.

    :class:`Engine` (lockstep single batch) and
    :class:`repro.serving.batching.ContinuousEngine` (slot batch) are both
    thin clients of this object: prefill, decode, and (for attention-cache
    families) chunked prefill are jitted here once, so the two engines can
    never drift numerically and a model warm in one is warm in the other.
    ``decode_fn`` accepts ``pos`` as a scalar (lockstep) or a ``(B,)`` array
    (per-slot ragged positions) — same callable, two traced shapes.

    Multi-device: pass ``mesh`` (and optionally ``rules``) and the steps
    carry the serve sharding profile — engines call :meth:`cache_shardings`
    to pin their KV cache (lockstep batch layout or the continuous-batching
    slot pool) onto the mesh; params arrive already placed by the streaming
    loader (:func:`make_param_placer`), and GSPMD propagates the
    tensor-parallel layout through the jitted steps from the operand
    shardings alone.

    Residency: ``resident="dense"`` (default) jits the whole-tree step
    functions — ``params`` is the decoded pytree and every layer's weights
    are in HBM for the scan to slice.  ``resident="compressed"`` swaps the
    step callables for per-layer *drivers*: ``params`` must then be a
    :class:`repro.serving.resident.CompressedResidentWeights`, and each step
    loops the layers in execution order, materializing layer ``l``'s QT
    triples just before its block (the next layer's entropy decode runs on a
    worker thread underneath the asynchronously dispatched compute).  The
    drivers keep the step-function signatures, so :class:`Engine` and
    :class:`~repro.serving.batching.ContinuousEngine` drive either mode
    unchanged — and greedy decode is bit-identical between the two (the
    per-layer blocks mirror the scan bodies op for op; see docs/SERVING.md
    §"Compressed-resident serving").  Compressed residency is single-device
    today (``mesh`` must stay None): per-layer decode targets the
    bandwidth-bound single-accelerator regime the paper measures, while
    multi-device hosts shard *decoded* weights (ARCHITECTURE.md §6).
    """

    def __init__(self, cfg: ArchConfig, sc: ServeConfig,
                 *, shardings: Optional[dict] = None,
                 mesh=None, rules=None, resident: str = "dense"):
        if resident not in ("dense", "compressed"):
            raise ValueError(f"resident must be 'dense' or 'compressed', "
                             f"got {resident!r}")
        self.cfg = cfg
        self.sc = sc
        self.mod = api.build(cfg)
        self.resident = resident
        self.mesh = mesh
        self.rules = None
        self._cache_shardings_memo: dict = {}
        if resident == "compressed":
            if mesh is not None:
                raise NotImplementedError(
                    "compressed-resident serving is single-device (see "
                    "docs/SERVING.md §\"Which mode when\"); drop mesh= or "
                    "use resident='dense'")
            if not api.supports_resident_serving(cfg):
                raise NotImplementedError(
                    f"family {cfg.family!r} does not implement the per-layer "
                    f"weight-slot contract (embed_step / resident_block); "
                    f"supported today: dense, moe")
            self._build_resident_steps()
            return
        if mesh is not None:
            self.rules = rules if rules is not None \
                else serve_mesh_rules(cfg, mesh)

        kw = {}
        if shardings:
            kw["in_shardings"] = shardings.get("in")
            kw["out_shardings"] = shardings.get("out")

        scoped = self._scoped_tracer()

        def _prefill(params, prompt):
            return self.mod.prefill(cfg, params, prompt, max_len=sc.max_len,
                                    unroll=sc.unroll, q_block=sc.q_block)

        def _decode(params, token, cache, pos):
            return self.mod.decode_step(cfg, params, token, cache, pos,
                                        unroll=sc.unroll)

        self.prefill_fn = jax.jit(scoped(_prefill), **kw)
        self.decode_fn = jax.jit(scoped(_decode), donate_argnums=(2,))
        self.prefill_chunk_fn = None
        if hasattr(self.mod, "prefill_chunk"):
            def _chunk(params, tokens, cache, pos):
                return self.mod.prefill_chunk(cfg, params, tokens, cache, pos,
                                              unroll=sc.unroll)

            self.prefill_chunk_fn = jax.jit(scoped(_chunk), donate_argnums=(2,))

        # paged twins: the block-pool cache layout (docs/KV_CACHE.md) —
        # same donation discipline, block table rides as an extra operand
        self.paged_decode_fn = self.paged_prefill_chunk_fn = None
        if hasattr(self.mod, "paged_decode_step"):
            def _pdec(params, token, pool, bt, pos):
                return self.mod.paged_decode_step(cfg, params, token, pool,
                                                  bt, pos, unroll=sc.unroll)

            def _pchunk(params, tokens, pool, bt, pos):
                return self.mod.paged_prefill_chunk(cfg, params, tokens, pool,
                                                    bt, pos, unroll=sc.unroll)

            self.paged_decode_fn = jax.jit(scoped(_pdec), donate_argnums=(2,))
            self.paged_prefill_chunk_fn = jax.jit(scoped(_pchunk),
                                                  donate_argnums=(2,))

    # ------------------------------------------------- compressed residency
    def _build_resident_steps(self) -> None:
        """Per-layer jitted pieces + Python drivers (compressed residency).

        Five small jitted closures replace the three whole-tree steps: embed,
        head (and the prefill last-position variant), the cacheless prefill
        block, the cached block shared by decode and chunked prefill, and
        the prefill cache write.  One trace of the cached block serves every
        layer (``l`` is a traced scalar) and every front end (S comes from
        the token shape).  The drivers below stitch them together around the
        weight store's prefetch/get double buffer.
        """
        cfg, sc, mod = self.cfg, self.sc, self.mod

        def _embed(g, tokens):
            return mod.embed_step(cfg, g, tokens)

        def _head(g, x):
            return mod.head_step(cfg, g, x)

        def _head_last(g, x):
            return mod.head_step(cfg, g, x, last_only=True)

        def _pblock(lp, x, positions):
            return mod.resident_prefill_block(
                cfg, lp, x, positions=positions, q_block=sc.q_block,
                unroll=sc.unroll)

        def _rblock(lp, x, cache, l, pos):
            return mod.resident_block(cfg, lp, x, cache, l, pos)

        def _write(cache, k, v, l):
            out = dict(cache)
            for key, val in (("k", k), ("v", v)):
                out[key] = jax.lax.dynamic_update_slice(
                    cache[key], val[None].astype(cache[key].dtype),
                    (l,) + (0,) * (cache[key].ndim - 1))
            return out

        self._embed_fn = jax.jit(_embed)
        self._head_fn = jax.jit(_head)
        self._head_last_fn = jax.jit(_head_last)
        self._pblock_fn = jax.jit(_pblock)
        self._rblock_fn = jax.jit(_rblock, donate_argnums=(2,))
        self._write_fn = jax.jit(_write, donate_argnums=(0,))
        self.prefill_fn = self._resident_prefill
        self.decode_fn = self._resident_step
        self.prefill_chunk_fn = self._resident_step
        # paged KV is a dense-residency feature (docs/KV_CACHE.md)
        self.paged_decode_fn = self.paged_prefill_chunk_fn = None

    def _resident_prefill(self, weights, prompt):
        """Driver twin of the jitted whole-tree ``prefill``: full causal
        attention per layer, each layer's (k, v) written into the
        zero-padded cache row as it is produced."""
        B, S = prompt.shape
        x = self._embed_fn(weights.globals, prompt)
        positions = jnp.arange(S)
        cache = self.mod.init_cache(self.cfg, B, self.sc.max_len)
        weights.prefetch(0)
        for l in range(weights.n_layers):
            with obs_trace.span("serve.layer", layer=l, phase="prefill"):
                lp = weights.get(l)
                weights.prefetch((l + 1) % weights.n_layers)
                x, (k, v) = self._pblock_fn(lp, x, positions)
                cache = self._write_fn(cache, k, v, jnp.int32(l))
                _fence(x)
        return self._head_last_fn(weights.globals, x), cache

    def _resident_step(self, weights, tokens, cache, pos):
        """Driver twin of ``decode_step`` AND ``prefill_chunk`` (the cached
        block reads S from the token shape, exactly like the scan bodies).

        The overlap: ``get(l)`` returns layer l's slot (usually already
        decoded by the worker), ``prefetch(l+1)`` kicks off the next
        layer's entropy decode, and the jitted block dispatches
        asynchronously — so layer l+1 decodes on the worker thread while
        layer l's matmuls run.  The wrap-around prefetch primes layer 0 for
        the next step.
        """
        x = self._embed_fn(weights.globals, tokens)
        weights.prefetch(0)
        for l in range(weights.n_layers):
            with obs_trace.span("serve.layer", layer=l, phase="step"):
                lp = weights.get(l)
                weights.prefetch((l + 1) % weights.n_layers)
                x, cache = self._rblock_fn(lp, x, cache, jnp.int32(l), pos)
                _fence(x)
        return self._head_fn(weights.globals, x), cache

    def _scoped_tracer(self) -> Callable:
        """Identity on one device.  With a mesh: wrap each step body so its
        TRACE runs under the ambient mesh + exact-TP sharding hints — the
        model's ``constrain_replicated``/``constrain_heads`` hooks fire only
        inside these closures, and the process-global hints are restored
        afterwards so co-resident training/lowering traces never see them."""
        if self.mesh is None:
            return lambda fn: fn
        from repro.distributed.ctx import ShardingHints, get_hints, set_hints
        # exact profile: weights gathered at use (layers.gather_weight), NO
        # activation constraints — every compute op keeps reference shapes,
        # which is what makes sharded greedy decode bit-identical
        hints = ShardingHints(
            mesh=self.mesh, batch_axes=(), model_axis=None,
            kv_seq_axes=(), seq_sp=False, exact_tp=True)

        def scoped(fn):
            def run(*args):
                prev = get_hints()
                set_hints(hints)
                try:
                    # no ambient-mesh context needed: every constraint the
                    # hints drive builds an explicit NamedSharding from
                    # hints.mesh (works on 0.4.x and new jax alike)
                    return fn(*args)
                finally:
                    set_hints(prev)
            return run

        return scoped

    def cache_shardings(self, batch: int, *, layout: str = "batch",
                        **cache_kw) -> Optional[dict]:
        """NamedShardings for this config's cache pytree on the serve mesh
        (None when the steps are single-device).  Memoized — resolution runs
        an eval_shape trace of the cache, and ``Engine.generate`` asks once
        per call on the serving hot path."""
        if self.mesh is None:
            return None
        key = (batch, layout, tuple(sorted(cache_kw.items())))
        if key not in self._cache_shardings_memo:
            from repro.distributed import sharding as shd
            self._cache_shardings_memo[key] = shd.cache_shardings(
                self.cfg, self.mesh, self.rules, batch, self.sc.max_len,
                layout=layout, **cache_kw)
        return self._cache_shardings_memo[key]


class Engine:
    """Lockstep serving: one fixed-shape batch per ``generate`` call.

    A thin single-request-batch client of :class:`ServeSteps` — for
    concurrent, independently-arriving requests use
    :class:`repro.serving.batching.ContinuousEngine`, which drives the same
    step functions with a slot batch.

    ``resident="compressed"`` serves straight from the entropy-coded
    container: pass a :class:`repro.serving.resident.
    CompressedResidentWeights` as ``params`` (docs/SERVING.md
    §"Compressed-resident serving").
    """

    def __init__(self, cfg: ArchConfig, params: Dict[str, Any], sc: ServeConfig,
                 *, shardings: Optional[dict] = None,
                 mesh=None, rules=None,
                 steps: Optional[ServeSteps] = None,
                 resident: str = "dense"):
        self.cfg = cfg
        self.params = params
        self.sc = sc
        self.steps = steps if steps is not None else \
            ServeSteps(cfg, sc, shardings=shardings, mesh=mesh, rules=rules,
                       resident=resident)
        self.mod = self.steps.mod
        self.prefill_fn = self.steps.prefill_fn      # backwards-compat aliases
        self.decode_fn = self.steps.decode_fn

    def generate(self, prompt, steps: int, *, key: Optional[jax.Array] = None,
                 echo_metrics: bool = False):
        """prompt: (B, S) int32 tokens — or the batch dict for encdec."""
        key = key if key is not None else jax.random.PRNGKey(0)
        t0 = time.perf_counter()
        with obs_trace.span("serve.prefill"):
            logits, cache = self.prefill_fn(self.params, prompt)
            logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        if isinstance(prompt, dict):
            S = prompt["tokens"].shape[1]
            B = prompt["tokens"].shape[0]
        else:
            B, S = prompt.shape
        if self.steps.mesh is not None:
            # pin the cache layout once per generate: propagation out of
            # prefill is free to pick any layout, the decode loop then runs
            # against the deterministic serve-rule shardings
            cache = jax.device_put(cache, self.steps.cache_shardings(B))
        toks = []
        # one fresh split per sampled token, including token 0 — sampling the
        # first token from the parent key and then re-splitting that same key
        # in the loop would correlate token 0 with token 1
        key, sub = jax.random.split(key)
        tok = sample(logits, sub, self.sc.temperature)[:, None]
        tok.block_until_ready()
        t_first_token = time.perf_counter() - t0
        toks.append(tok)
        t1 = time.perf_counter()
        step_hist = obs_metrics.histogram("serve.decode_step_s")
        self.last_step_times: list = []
        for i in range(steps - 1):
            ts = time.perf_counter()
            with obs_trace.span("serve.decode_step", step=i):
                key, sub = jax.random.split(key)
                logits, cache = self.decode_fn(self.params, tok, cache,
                                               jnp.int32(S + i))
                tok = sample(logits, sub, self.sc.temperature)[:, None]
                toks.append(tok)
                _fence(tok)
            # without --trace-sync each step time is host dispatch (plus any
            # resident decode waits), not device compute — the loop-level
            # t_decode below is fenced and authoritative either way
            dt = time.perf_counter() - ts
            self.last_step_times.append(dt)
            step_hist.observe(dt)
        out = jnp.concatenate(toks, axis=1)
        out.block_until_ready()
        t_decode = time.perf_counter() - t1
        # t_decode covers the steps-1 loop tokens only (token 0 rides on
        # the prefill timing), so the two rates are reported separately
        # instead of pretending one number covers both
        decode_tps = B * max(steps - 1, 1) / max(t_decode, 1e-9)
        e2e_tps = B * steps / max(time.perf_counter() - t0, 1e-9)
        obs_metrics.gauge("serve.prefill_s").set(t_prefill)
        obs_metrics.gauge("serve.decode_s").set(t_decode)
        obs_metrics.gauge("serve.ttft_s").set(t_first_token)
        obs_metrics.gauge("serve.decode_tok_per_s").set(decode_tps)
        obs_metrics.gauge("serve.e2e_tok_per_s").set(e2e_tps)
        obs_metrics.counter("serve.tokens").inc(B * steps)
        if echo_metrics:
            return out, obs_metrics.LegacyMetricsView(
                obs_metrics.default_registry(), _LEGACY_GENERATE_KEYS)
        return out


def make_serve_step(cfg: ArchConfig, sc: ServeConfig) -> Callable:
    """The decode-shape dry-run target: one token against a full KV cache."""
    mod = api.build(cfg)

    def serve_step(params, token, cache, pos):
        return mod.decode_step(cfg, params, token, cache, pos, unroll=sc.unroll)

    return serve_step


def make_prefill_step(cfg: ArchConfig, sc: ServeConfig) -> Callable:
    mod = api.build(cfg)

    def prefill_step(params, prompt):
        return mod.prefill(cfg, params, prompt, max_len=sc.max_len,
                           unroll=sc.unroll, q_block=sc.q_block)

    return prefill_step
