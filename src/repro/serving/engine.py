"""Batched serving engine with the EntroLLM weight path.

Pipeline (paper Alg. 1 EDGE DEVICE OPERATIONS, pod-scale):

  1. **Load**: the engine takes a :class:`core.store.CompressedModel`
     (entropy-coded container).  Weights are parallel-decoded ONCE per engine
     start — the analogue of the paper's once-per-sequence decode, amortized
     over every request the engine ever serves.  The default load path
     *streams*: the :class:`~repro.core.scheduler.DecodeScheduler` feeds
     fixed-budget chunks (embedding first) through a pluggable decoder
     backend with double-buffered prefetch, so host memory stays bounded and
     the first weights are resident long before the last chunk decodes
     (``time_to_first_weight_s`` in the load metrics).
  2. **Residency**: decoded weights stay *quantized* (uint8 symbols + scale +
     zero as :class:`models.layers.QT` triples) in HBM; dequantization fuses
     into each consuming matmul.  HBM traffic per decode step is 1 byte/param
     (uint8) or 0.5 (packed uint4) instead of 2 (bf16) — the bandwidth saving
     the paper measures on Jetson, realized on the TPU memory roofline.
  3. **Serve**: `prefill` then repeated `decode_step`, both jitted with the
     serve shardings; sampling is greedy or temperature-categorical.

``serve_step`` (single decode step) is the function the dry-run lowers for
decode-shape roofline cells.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.spec import quantizable_shape as _quantizable_shape
from repro.core.store import _DEFAULT_CHUNK, CompressedModel
from repro.models import api
from repro.models.layers import QT


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 2048
    temperature: float = 0.0           # 0 => greedy
    unroll: int = 1
    q_block: int = 0
    quantized_weights: bool = True     # keep QT triples in HBM (EntroLLM mode)


def load_params_from_compressed(model: CompressedModel, *,
                                quantized: bool = True,
                                pack_int4: bool = True,
                                backend: Optional[str] = None,
                                chunk_symbols: Optional[int] = _DEFAULT_CHUNK,
                                stream: bool = True,
                                metrics: Optional[dict] = None) -> Dict[str, Any]:
    """Decode the container into serving weights, streaming by default.

    quantized=True  -> {name: QT(q, scale, zero)} + fp32 leftovers (EntroLLM
                       path); 4-bit containers pack nibble pairs into QT4
                       (0.5 bytes/param resident) unless ``pack_int4=False``
    quantized=False -> dense fp32 weights (baseline path)

    ``stream=True`` consumes :meth:`CompressedModel.iter_decode` chunk by
    chunk: host memory stays bounded by the scheduler's chunk budget
    (``chunk_symbols``; ``None`` = one monolithic chunk, same convention as
    the scheduler), the embedding is scheduled first, and each tensor's
    device transfer overlaps the prefetch-decode of the next chunk.
    ``stream=False`` recovers the monolithic ``decode_all`` batch.
    ``backend`` is a decoder-registry name (``numpy`` / ``jax`` / ``pallas``
    / ``pallas-interpret``; None = auto) and is honored on both paths.

    When a ``metrics`` dict is passed it is filled with
    ``time_to_first_weight_s`` (start -> first decoded tensor resident),
    ``decode_load_s`` (total), and the resolved ``decode_backend`` name.
    """
    from repro.core.decode_backends import get_backend
    from repro.models.layers import QT4
    t0 = time.perf_counter()
    ttfw: Optional[float] = None
    resolved = get_backend(backend)

    if stream:
        kw = dict(backend=resolved, first=("embed",),
                  chunk_symbols=chunk_symbols)
        pairs = (model.iter_dequantize(**kw) if not quantized
                 else model.iter_quantized_weights(**kw))
    elif quantized:
        pairs = iter(model.quantized_weights(backend=resolved).items())
    else:
        pairs = iter(model.dequantize_all(backend=resolved).items())

    out: Dict[str, Any] = {}
    if quantized:
        for k, v in model.unquantized.items():
            out[k] = jnp.asarray(v)
    for name, val in pairs:
        if quantized and name in model.qmeta:
            q, scale, zero = val
            bits = model.qmeta[name]["bits"]
            if (not _quantizable_shape(name, model.tensors[name].shape)
                    or model.qmeta[name]["granularity"] == "per_group"):
                # Two cases the fused dequant-matmul path cannot host, so
                # dequantize at load instead of packing a QT struct:
                # * norm scales / biases / sensitive params (quantized via an
                #   explicit spec rule) — model layers consume plain arrays;
                # * per-group quantization — the (…, D/group, 1) scale does
                #   not broadcast against the (…, D) weight in the kernels.
                out[name] = jnp.asarray(model._dequantize_one(name, q))
            elif bits == 4 and pack_int4 and q.shape[-1] % 2 == 0:
                packed = (q[..., 0::2] | (q[..., 1::2] << 4)).astype(np.uint8)
                out[name] = QT4(jnp.asarray(packed), jnp.asarray(scale),
                                jnp.asarray(zero))
            else:
                out[name] = QT(jnp.asarray(q), jnp.asarray(scale),
                               jnp.asarray(zero))
        else:
            out[name] = jnp.asarray(val)
        if ttfw is None:
            jax.block_until_ready(jax.tree.leaves(out[name]))
            ttfw = time.perf_counter() - t0
    jax.block_until_ready(jax.tree.leaves(out))
    if metrics is not None:
        metrics["time_to_first_weight_s"] = ttfw if ttfw is not None else 0.0
        metrics["decode_load_s"] = time.perf_counter() - t0
        metrics["decode_backend"] = resolved.name
    return out


def sample(logits: jax.Array, key: jax.Array, temperature: float) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits[:, -1] / temperature).astype(jnp.int32)


class ServeSteps:
    """Jitted per-architecture step functions — the ONE set of compiled
    closures every serving front end drives.

    :class:`Engine` (lockstep single batch) and
    :class:`repro.serving.batching.ContinuousEngine` (slot batch) are both
    thin clients of this object: prefill, decode, and (for attention-cache
    families) chunked prefill are jitted here once, so the two engines can
    never drift numerically and a model warm in one is warm in the other.
    ``decode_fn`` accepts ``pos`` as a scalar (lockstep) or a ``(B,)`` array
    (per-slot ragged positions) — same callable, two traced shapes.
    """

    def __init__(self, cfg: ArchConfig, sc: ServeConfig,
                 *, shardings: Optional[dict] = None):
        self.cfg = cfg
        self.sc = sc
        self.mod = api.build(cfg)

        kw = {}
        if shardings:
            kw["in_shardings"] = shardings.get("in")
            kw["out_shardings"] = shardings.get("out")

        def _prefill(params, prompt):
            return self.mod.prefill(cfg, params, prompt, max_len=sc.max_len,
                                    unroll=sc.unroll, q_block=sc.q_block)

        def _decode(params, token, cache, pos):
            return self.mod.decode_step(cfg, params, token, cache, pos,
                                        unroll=sc.unroll)

        self.prefill_fn = jax.jit(_prefill, **kw)
        self.decode_fn = jax.jit(_decode, donate_argnums=(2,))
        self.prefill_chunk_fn = None
        if hasattr(self.mod, "prefill_chunk"):
            def _chunk(params, tokens, cache, pos):
                return self.mod.prefill_chunk(cfg, params, tokens, cache, pos,
                                              unroll=sc.unroll)

            self.prefill_chunk_fn = jax.jit(_chunk, donate_argnums=(2,))


class Engine:
    """Lockstep serving: one fixed-shape batch per ``generate`` call.

    A thin single-request-batch client of :class:`ServeSteps` — for
    concurrent, independently-arriving requests use
    :class:`repro.serving.batching.ContinuousEngine`, which drives the same
    step functions with a slot batch.
    """

    def __init__(self, cfg: ArchConfig, params: Dict[str, Any], sc: ServeConfig,
                 *, shardings: Optional[dict] = None,
                 steps: Optional[ServeSteps] = None):
        self.cfg = cfg
        self.params = params
        self.sc = sc
        self.steps = steps if steps is not None else \
            ServeSteps(cfg, sc, shardings=shardings)
        self.mod = self.steps.mod
        self.prefill_fn = self.steps.prefill_fn      # backwards-compat aliases
        self.decode_fn = self.steps.decode_fn

    def generate(self, prompt, steps: int, *, key: Optional[jax.Array] = None,
                 echo_metrics: bool = False):
        """prompt: (B, S) int32 tokens — or the batch dict for encdec."""
        key = key if key is not None else jax.random.PRNGKey(0)
        t0 = time.perf_counter()
        logits, cache = self.prefill_fn(self.params, prompt)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        if isinstance(prompt, dict):
            S = prompt["tokens"].shape[1]
            B = prompt["tokens"].shape[0]
        else:
            B, S = prompt.shape
        toks = []
        # one fresh split per sampled token, including token 0 — sampling the
        # first token from the parent key and then re-splitting that same key
        # in the loop would correlate token 0 with token 1
        key, sub = jax.random.split(key)
        tok = sample(logits, sub, self.sc.temperature)[:, None]
        tok.block_until_ready()
        t_first_token = time.perf_counter() - t0
        toks.append(tok)
        t1 = time.perf_counter()
        for i in range(steps - 1):
            key, sub = jax.random.split(key)
            logits, cache = self.decode_fn(self.params, tok, cache,
                                           jnp.int32(S + i))
            tok = sample(logits, sub, self.sc.temperature)[:, None]
            toks.append(tok)
        out = jnp.concatenate(toks, axis=1)
        out.block_until_ready()
        t_decode = time.perf_counter() - t1
        if echo_metrics:
            # t_decode covers the steps-1 loop tokens only (token 0 rides on
            # the prefill timing), so the two rates are reported separately
            # instead of pretending one number covers both
            decode_tps = B * max(steps - 1, 1) / max(t_decode, 1e-9)
            e2e_tps = B * steps / max(time.perf_counter() - t0, 1e-9)
            return out, {"prefill_s": t_prefill, "decode_s": t_decode,
                         "ttft_s": t_first_token,
                         "decode_tok_per_s": decode_tps,
                         "e2e_tok_per_s": e2e_tps,
                         "tok_per_s": decode_tps}   # legacy alias
        return out


def make_serve_step(cfg: ArchConfig, sc: ServeConfig) -> Callable:
    """The decode-shape dry-run target: one token against a full KV cache."""
    mod = api.build(cfg)

    def serve_step(params, token, cache, pos):
        return mod.decode_step(cfg, params, token, cache, pos, unroll=sc.unroll)

    return serve_step


def make_prefill_step(cfg: ArchConfig, sc: ServeConfig) -> Callable:
    mod = api.build(cfg)

    def prefill_step(params, prompt):
        return mod.prefill(cfg, params, prompt, max_len=sc.max_len,
                           unroll=sc.unroll, q_block=sc.q_block)

    return prefill_step
