"""Data-parallel fleet of ContinuousEngine replicas (docs/FLEET.md).

Topology: one bounded **intake** queue at the fleet boundary, a
:class:`~repro.serving.fleet.router.Router` spreading intake over N replica
queues, and optionally a disaggregated split where the first P replicas run
prefill-only (their ``handoff_sink`` exports finished prompt KV) and the
remaining D replicas decode-only, bridged by a
:class:`~repro.serving.fleet.handoff.HandoffCoordinator`.

Weights: all replicas serve from ONE compressed container.
``from_container`` decodes it once and shares the tree
(``weights="share"``) or decodes one copy per replica
(``weights="per-replica"`` — the multi-host stand-in); ``weight_bytes()``
accounts both honestly, counting device broadcast copies when replicas are
pinned to distinct (forced host) devices.  Every replica shares one
:class:`~repro.serving.engine.ServeSteps`, so all replicas run the SAME
jitted step functions — the compile cache is paid once and numerical
identity across replicas is by construction, which is what makes the fleet
bit-identity contract (any request's greedy tokens == a single engine's,
regardless of replica count, policy, or failures) hold.

Drive modes:

* ``step()`` / ``run()`` — deterministic lockstep: pump dispatch, step every
  live replica once, pump the handoff.  Single-threaded; what the fault
  and identity tests (and ``launch/serve.py --replicas``) use.
* ``start_workers()`` / ``stop_workers()`` — one thread per replica stepping
  its own engine, with dispatch pumped from the submitting thread
  (``traffic.replay_fleet(threaded=True)``).  Real wall-clock parallelism
  when replicas sit on distinct forced host devices (the fleet benchmark);
  plain DP only — disaggregation is lockstep-only because adopting into a
  stepping engine would race its block pool.

Failure semantics: ``kill_replica`` marks the handle FAILED (its worker, if
any, exits and is joined), evacuates every queued / mid-prefill / decoding
request off the engine, resets each (``Request.requeue`` — generated tokens
discarded; determinism regenerates them bit-identically) and re-enqueues
them at the *front* of the intake in arrival order.  Nothing is lost,
nothing runs twice to completion.  ``drain_replica`` stops new placements
but lets in-flight work finish.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.configs.base import ArchConfig
from repro.core.spec import KVCompressionSpec
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from ..engine import ServeConfig, ServeSteps
from ..batching.engine import ContinuousEngine
from ..batching.queue import QueueFullError, RequestQueue
from ..batching.request import Request, SamplingParams
from .handoff import HandoffCoordinator
from .router import ReplicaHandle, ReplicaState, Router


def _tree_bytes(tree) -> int:
    return sum(int(leaf.nbytes) for leaf in jax.tree.leaves(tree))


class FleetDriver:
    """N data-parallel engine replicas behind one router (docs/FLEET.md)."""

    def __init__(self, cfg: ArchConfig, params: Any, sc: ServeConfig, *,
                 n_replicas: int = 2,
                 policy: str = "round-robin",
                 n_slots: int = 4,
                 max_queue: int = 16,
                 prefill_chunk: int = 8,
                 admit_chunks_per_step: int = 4,
                 kv_spec: Optional[KVCompressionSpec] = None,
                 kv_blocks: Optional[int] = None,
                 max_intake: int = 256,
                 disaggregate: Optional[Tuple[int, int]] = None,
                 handoff_codec: str = "rans",
                 handoff_transport=None,
                 devices: Optional[List[Any]] = None,
                 steps: Optional[ServeSteps] = None,
                 admission_gate=None,
                 replica_params: Optional[List[Any]] = None):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        if disaggregate is not None:
            P, D = disaggregate
            if P < 1 or D < 1:
                raise ValueError(f"disaggregate needs >= 1 prefill and >= 1 "
                                 f"decode replica, got {disaggregate}")
            if P + D != n_replicas:
                raise ValueError(f"disaggregate {P}:{D} must sum to "
                                 f"n_replicas={n_replicas}")
            if kv_spec is None:
                raise ValueError(
                    "disaggregated mode needs the paged KV cache (kv_spec): "
                    "the handoff ships entropy-coded block payloads")
        self.cfg = cfg
        self.sc = sc
        self.prefill_chunk = prefill_chunk
        self.disaggregate = disaggregate
        # one ServeSteps for the whole fleet: one compile cache, and
        # replica-count-independent numerics by construction
        self.steps = steps if steps is not None else ServeSteps(cfg, sc)

        # ---- weight placement: share one tree or hold one per replica ----
        if replica_params is not None:
            if len(replica_params) != n_replicas:
                raise ValueError(f"replica_params has {len(replica_params)} "
                                 f"trees for {n_replicas} replicas")
            self.weight_mode = "per-replica"
            trees = list(replica_params)
        else:
            self.weight_mode = "share"
            trees = [params] * n_replicas
        if devices is not None:
            if not devices:
                raise ValueError("devices list is empty")
            placed: Dict[tuple, Any] = {}
            pinned = []
            for i, tree in enumerate(trees):
                dev = devices[i % len(devices)]
                key = (id(tree), getattr(dev, "id", repr(dev)))
                if key not in placed:
                    # sharing across distinct devices = one broadcast copy
                    # per device; weight_bytes() counts each copy
                    placed[key] = jax.device_put(tree, dev)
                pinned.append(placed[key])
            trees = pinned
        self._replica_trees = trees

        # ---- replicas -----------------------------------------------------
        n_prefill = disaggregate[0] if disaggregate else n_replicas
        self.replicas: List[ReplicaHandle] = []
        for i in range(n_replicas):
            is_prefill = i < n_prefill
            eng = ContinuousEngine(
                cfg, trees[i], sc, n_slots=n_slots, max_queue=max_queue,
                prefill_chunk=prefill_chunk,
                admit_chunks_per_step=admit_chunks_per_step,
                steps=self.steps, kv_spec=kv_spec, kv_blocks=kv_blocks,
                # the sink is wired after the coordinator exists (below);
                # construction order: decode handles -> coordinator -> sinks
                handoff_sink=None)
            dev = devices[i % len(devices)] if devices else None
            if dev is not None:
                if eng.paged:
                    eng.slots.pool = jax.device_put(eng.slots.pool, dev)
                else:
                    eng.slots.cache = jax.device_put(eng.slots.cache, dev)
            self.replicas.append(ReplicaHandle(i, eng, device=dev))
        self.prefill_replicas = self.replicas[:n_prefill]
        self.decode_replicas = self.replicas[n_prefill:]

        self.handoff: Optional[HandoffCoordinator] = None
        if disaggregate is not None:
            self.handoff = HandoffCoordinator(
                self.decode_replicas, codec=handoff_codec,
                transport=handoff_transport)
            for h in self.prefill_replicas:
                h.engine.handoff_sink = self.handoff.sink

        # router targets: replicas that ADMIT new requests (prefill side
        # under disaggregation; everyone otherwise)
        self.router = Router(self.prefill_replicas, policy=policy,
                             admission_gate=admission_gate)
        self.intake = RequestQueue(max_intake)
        self.n_steps = 0
        self.n_submitted = 0
        self._threads: Dict[int, threading.Thread] = {}
        self._stop_flag = False
        self._lock = threading.Lock()
        self._update_gauges()

    # ------------------------------------------------------------ factories
    @classmethod
    def from_container(cls, cm, cfg: ArchConfig, sc: ServeConfig, *,
                       n_replicas: int = 2, weights: str = "share",
                       backend: Optional[str] = None, **kw) -> "FleetDriver":
        """Build a fleet from one compressed container.

        ``weights="share"`` decodes the container ONCE and every replica
        serves the same resident tree (decode-once-then-share — the
        single-host fleet).  ``weights="per-replica"`` decodes one copy per
        replica (the multi-host stand-in: each host pays its own decode and
        holds its own bytes).  Both are accounted by ``weight_bytes()``.
        """
        from ..engine import load_params_from_compressed
        if weights not in ("share", "per-replica"):
            raise ValueError(f"weights must be 'share' or 'per-replica', "
                             f"got {weights!r}")
        if weights == "share":
            params = load_params_from_compressed(cm, backend=backend)
            return cls(cfg, params, sc, n_replicas=n_replicas, **kw)
        replica_params = [load_params_from_compressed(cm, backend=backend)
                          for _ in range(n_replicas)]
        return cls(cfg, None, sc, n_replicas=n_replicas,
                   replica_params=replica_params, **kw)

    # ------------------------------------------------------------ accounting
    def weight_bytes(self) -> Dict[str, Any]:
        """Resident weight bytes across the fleet, honestly counted: one
        entry per distinct in-memory tree (sharing collapses to one copy;
        per-replica or per-device placement counts each copy)."""
        unique: Dict[int, Any] = {}
        for tree in self._replica_trees:
            unique[id(tree)] = tree
        per_copy = [_tree_bytes(t) for t in unique.values()]
        return {"mode": self.weight_mode, "copies": len(per_copy),
                "bytes_per_copy": per_copy[0] if per_copy else 0,
                "total_bytes": sum(per_copy)}

    # ---------------------------------------------------------------- submit
    def submit(self, prompt, max_new_tokens: int, *,
               sampling: SamplingParams = SamplingParams(),
               eos_id: Optional[int] = None,
               deadline_s: Optional[float] = None) -> Request:
        """Queue one request at the fleet intake (raises ``QueueFullError``
        under intake backpressure, with the shed recorded)."""
        req = Request(prompt=prompt, max_new_tokens=max_new_tokens,
                      sampling=sampling, eos_id=eos_id, deadline_s=deadline_s)
        P = req.prompt_len
        chunks = -(-P // self.prefill_chunk) * self.prefill_chunk
        need = max(P + max_new_tokens, chunks)
        if need > self.sc.max_len:
            raise ValueError(
                f"request {req.rid} needs {need} cache rows but max_len is "
                f"{self.sc.max_len}")
        try:
            self.intake.submit(req)
        except QueueFullError:
            self.router.shed_request(req, "queue_full")
            raise
        self.n_submitted += 1
        obs_metrics.counter("fleet.submitted").inc()
        return req

    # ------------------------------------------------------------- dispatch
    def pump(self) -> int:
        """Move intake requests onto replica queues through the router.

        Pops in FIFO order; a request the router defers (pure backpressure)
        goes back to the *front* of the intake and the pump stops — FIFO
        order is part of the determinism contract.  Intake requests whose
        deadline lapsed expire in passing (``RequestQueue`` lazy expiry)
        and are mirrored to ``fleet.shed{deadline}``.
        """
        if not len(self.intake):
            self._update_gauges()
            return 0
        with obs_trace.span("fleet.pump", depth=len(self.intake)):
            n_exp0 = len(self.intake.expired)
            dispatched = 0
            while True:
                req = self.intake.pop()
                if req is None:
                    break
                h = self.router.dispatch(req)
                if h is not None:
                    dispatched += 1
                    continue
                if req.done:
                    continue          # shed terminally by the router
                self.intake.requeue(req)
                break                 # backpressure: retry next pump
            for _ in range(len(self.intake.expired) - n_exp0):
                obs_metrics.counter("fleet.shed").inc(reason="deadline")
            self._update_gauges()
            return dispatched

    # -------------------------------------------------------------- stepping
    def step(self) -> bool:
        """One lockstep fleet iteration: dispatch, step every live replica,
        pump the handoff.  Returns False when nothing moved — with no
        external intervention (fault plans), a False step means the fleet is
        drained or permanently stuck, so ``run()`` stops."""
        self.n_steps += 1
        moved = self.pump() > 0
        for h in self.replicas:
            if h.state is ReplicaState.FAILED:
                continue
            if h.engine.has_work:
                moved |= h.engine.step()
        if self.handoff is not None:
            delivered, ticked = self.handoff.pump(
                shed=self.router.shed_request)
            moved |= delivered > 0 or ticked > 0
        self._update_gauges()
        return moved

    def run(self, max_steps: Optional[int] = None) -> List[Request]:
        """Lockstep to completion (or ``max_steps``); returns finished."""
        steps = 0
        while self.has_work:
            if not self.step():
                break                 # drained or stuck — state inspectable
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return self.finished

    @property
    def has_work(self) -> bool:
        if len(self.intake):
            return True
        if self.handoff is not None and self.handoff.pending:
            return True
        return any(h.state is not ReplicaState.FAILED and h.engine.has_work
                   for h in self.replicas)

    # ------------------------------------------------------------ harvesting
    @property
    def finished(self) -> List[Request]:
        """Finished requests across all replicas, by rid (deterministic)."""
        out: List[Request] = []
        for h in self.replicas:
            out.extend(h.engine.finished)
        return sorted(out, key=lambda r: r.rid)

    @property
    def shed(self) -> List[Request]:
        """Every terminally shed request: fleet-boundary sheds (router),
        intake deadline expiries, and replica-queue deadline expiries."""
        out = list(self.router.shed) + list(self.intake.expired)
        for h in self.replicas:
            out.extend(h.engine.queue.expired)
        return out

    # ---------------------------------------------------------------- health
    def kill_replica(self, idx: int) -> List[Request]:
        """Fail replica ``idx`` and redrive its requests through the intake.

        Returns the evacuated requests (already reset and re-enqueued,
        oldest first).  Idempotent: a second kill returns []."""
        h = self.replicas[idx]
        if h.state is ReplicaState.FAILED:
            return []
        h.state = ReplicaState.FAILED
        t = self._threads.get(h.idx)
        if t is not None:
            t.join(timeout=60.0)      # worker sees FAILED and exits
            if t.is_alive():
                raise RuntimeError(f"replica {idx} worker failed to stop")
        victims = h.engine.evacuate()
        for r in victims:
            r.requeue()
        if victims:
            obs_metrics.counter("fleet.redrives").inc(len(victims))
        for r in reversed(victims):   # front-insert keeps arrival order
            self.intake.requeue(r)
        self._update_gauges()
        return victims

    def drain_replica(self, idx: int) -> ReplicaHandle:
        """Stop routing new work to replica ``idx``; in-flight work (queued
        included) finishes normally."""
        h = self.replicas[idx]
        if h.state is ReplicaState.UP:
            h.state = ReplicaState.DRAINING
        return h

    # --------------------------------------------------------------- threads
    def start_workers(self) -> None:
        """One stepping thread per live replica (plain-DP fleets only).

        Dispatch stays on the submitting thread (``pump()``), which is the
        single writer of the intake; replica queues cross threads only
        through ``RequestQueue``'s append/popleft pairs."""
        if self._threads:
            raise RuntimeError("fleet workers already running")
        if self.handoff is not None:
            raise NotImplementedError(
                "threaded fleets are plain DP today: adopting a handoff "
                "into a stepping engine would race its block pool "
                "(docs/FLEET.md)")
        with self._lock:
            self._stop_flag = False
        for h in self.replicas:
            if h.state is ReplicaState.FAILED:
                continue
            t = threading.Thread(target=self._worker, args=(h,),
                                 name=f"fleet-replica-{h.idx}", daemon=True)
            with self._lock:
                self._threads[h.idx] = t
            t.start()

    def _worker(self, h: ReplicaHandle) -> None:
        while True:
            with self._lock:
                stop = self._stop_flag
            if stop or h.state is ReplicaState.FAILED:
                return
            if h.engine.has_work:
                h.engine.step()
            else:
                time.sleep(5e-4)

    def stop_workers(self) -> None:
        with self._lock:
            self._stop_flag = True
        for t in list(self._threads.values()):
            t.join(timeout=60.0)
            if t.is_alive():
                raise RuntimeError("fleet worker failed to stop")
        with self._lock:
            self._threads.clear()
            self._stop_flag = False

    # ----------------------------------------------------------------- gauges
    def _update_gauges(self) -> None:
        obs_metrics.gauge("fleet.replicas_up").set(
            sum(1 for h in self.replicas if h.state is ReplicaState.UP))
        obs_metrics.gauge("fleet.queue_depth").set(len(self.intake))
