"""Fleet serving: data-parallel engine replicas behind a request router,
with an optional disaggregated prefill/decode split (docs/FLEET.md).

The mesh layer (``launch/mesh.py``) scales one engine *across devices*;
this package scales *engines* — N :class:`~repro.serving.batching.engine.
ContinuousEngine` replicas from one compressed container, a
:class:`Router` with pluggable placement policies, health states, and
deadline-aware shedding, and a :class:`HandoffCoordinator` shipping
prefilled KV between replicas as entropy-coded block payloads (the cold
tier's codec round-trip as wire format).  The whole fleet stays
per-request greedy bit-identical to a single engine
(``tests/fleet/test_fleet_identity.py``).
"""
from .driver import FleetDriver
from .handoff import HandoffCoordinator, HandoffPayload
from .router import POLICIES, ReplicaHandle, ReplicaState, Router

__all__ = [
    "FleetDriver", "HandoffCoordinator", "HandoffPayload", "POLICIES",
    "ReplicaHandle", "ReplicaState", "Router",
]
