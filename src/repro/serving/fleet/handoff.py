"""Disaggregated prefill→decode KV handoff (docs/FLEET.md).

Prefill replicas run admission + chunked prefill only; the moment a
request's prompt KV is committed and its first token sampled, the engine's
``handoff_sink`` hands the slot to a :class:`HandoffCoordinator`, which

1. **exports** the slot's KV blocks (``ContinuousEngine.export_request`` →
   ``BlockKVManager.export_blocks``) plus the sampling lane state
   ``(token, key, temp)``,
2. **entropy-codes** each block with the cold tier's codec round-trip
   (``kvcache.cold.encode_block_leaves`` — the SAME wire format eviction
   persists, so the transfer is lossless by the same argument: uint8 code
   leaves entropy-coded per-leaf, bf16 scale/zero raw), and
3. **delivers** the payload to the least-loaded UP decode replica
   (``adopt_request`` → ``import_blocks``), which continues decode from the
   exact device state the prefill replica would have used.

Bit-identity across the wire: the codec round-trip is byte-lossless
(``tests/fleet/test_fleet_identity.py`` asserts decode(encode(blocks)) is
byte-equal), rows past ``kv_len`` in the last block are unreachable under
``kv_len`` masking, and the first token plus PRNG key travel with the
payload — so the decode replica's token stream is bit-identical to a single
engine running the whole request (the fleet contract).

The coordinator is **single-threaded by contract**: the lockstep
:class:`~repro.serving.fleet.driver.FleetDriver` pumps it between replica
steps.  Threaded fleets run plain DP (no disaggregation) today — adopting
into an engine while its worker thread steps would race the block pool.

``transport`` is the fault-injection seam: a callable ``payload -> int``
returning how many pumps to delay delivery (the fault harness's
delay-KV-handoff plans); None delivers on the next pump.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.codecs import get_codec
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from ..batching.engine import ContinuousEngine
from ..batching.request import Request
from ..kvcache.cold import (decode_block_leaves, encode_block_leaves,
                            entry_nbytes)
from .router import ReplicaHandle, ReplicaState


@dataclasses.dataclass
class HandoffPayload:
    """One prefilled request on the wire: entropy-coded KV + sampling lane."""
    req: Request
    kv_len: int
    blocks: List[Dict[str, object]]   # encoded entries (cold-tier format)
    token: int                        # first sampled token (already in output)
    key: np.ndarray                   # (2,) uint32 PRNG lane state
    temp: float
    delay: int = 0                    # transport pumps left before delivery

    @property
    def payload_bytes(self) -> int:
        return sum(entry_nbytes(entry) for entry in self.blocks)

    def decode_blocks(self) -> List[Dict[str, np.ndarray]]:
        return [decode_block_leaves(entry) for entry in self.blocks]

    @property
    def lane(self) -> Tuple[int, np.ndarray, float]:
        return (self.token, self.key, self.temp)


class HandoffCoordinator:
    """Prefill→decode bridge over entropy-coded block payloads."""

    def __init__(self, decode_replicas: List[ReplicaHandle], *,
                 codec: str = "rans",
                 transport: Optional[Callable[[HandoffPayload], int]] = None):
        if not decode_replicas:
            raise ValueError("disaggregated mode needs >= 1 decode replica")
        self.codec = get_codec(codec)    # loud on unknown names
        self.decode_replicas = decode_replicas
        self.transport = transport
        self.n_handoffs = 0
        self.n_delivered = 0
        self.bytes_on_wire = 0
        self._pending: Deque[HandoffPayload] = deque()

    @property
    def pending(self) -> int:
        return len(self._pending)

    # ----------------------------------------------------------- prefill side
    def sink(self, engine: ContinuousEngine, slot: int, req: Request) -> None:
        """``ContinuousEngine.handoff_sink`` hook: export + encode + enqueue."""
        with obs_trace.span("fleet.handoff_encode", rid=req.rid):
            req2, kv_len, blocks, (tok, key, temp) = engine.export_request(slot)
            assert req2 is req
            encoded = [encode_block_leaves(self.codec, leaves)[0]
                       for leaves in blocks]
        payload = HandoffPayload(req=req, kv_len=kv_len, blocks=encoded,
                                 token=tok, key=key, temp=temp)
        if self.transport is not None:
            payload.delay = max(0, int(self.transport(payload)))
        self.n_handoffs += 1
        self.bytes_on_wire += payload.payload_bytes
        obs_metrics.counter("fleet.handoffs").inc()
        obs_metrics.counter("fleet.handoff_bytes").inc(payload.payload_bytes)
        self._pending.append(payload)

    # ------------------------------------------------------------ decode side
    def _pick(self) -> Optional[ReplicaHandle]:
        up = [h for h in self.decode_replicas
              if h.state is ReplicaState.UP]
        if not up:
            return None
        return min(up, key=lambda h: (h.occupied_slots, h.idx))

    def pump(self, shed: Optional[Callable[[Request, str], None]] = None
             ) -> Tuple[int, int]:
        """Deliver ready payloads; count down transport delays.

        Returns ``(delivered, ticked)`` — ``ticked`` counts payloads whose
        delay advanced, so the lockstep driver can tell "progress is
        happening" from "stuck".  A payload no UP decode replica exists for
        is handed to ``shed(req, "no_replica")`` (terminal) rather than
        pending forever; a payload the decode side merely cannot fit *right
        now* stays pending for the next pump.
        """
        delivered = 0
        ticked = 0
        keep: Deque[HandoffPayload] = deque()
        while self._pending:
            p = self._pending.popleft()
            if p.delay > 0:
                p.delay -= 1
                ticked += 1
                keep.append(p)
                continue
            h = self._pick()
            if h is None:
                if shed is not None:
                    shed(p.req, "no_replica")
                    continue
                keep.append(p)
                continue
            with obs_trace.span("fleet.handoff_adopt", rid=p.req.rid,
                                replica=h.idx, blocks=len(p.blocks)):
                ok = h.engine.adopt_request(p.req, p.kv_len,
                                            p.decode_blocks(), p.lane)
            if ok:
                delivered += 1
                self.n_delivered += 1
            else:
                keep.append(p)       # decode side full: retry next pump
        self._pending = keep
        return delivered, ticked

    def evacuate_pending(self) -> List[Request]:
        """Drop every in-flight payload and return its request (failed
        decode-fleet redrive: the requests re-prefill elsewhere)."""
        out = [p.req for p in self._pending]
        self._pending.clear()
        return out
