"""Request router over data-parallel engine replicas (docs/FLEET.md).

One :class:`Router` fronts N replica queues.  It owns three decisions:

* **Placement** — which UP replica takes the next request.  Policies are
  pluggable by name (``POLICIES``): ``round-robin`` rotates; ``least-loaded``
  ranks replicas by ``queue depth + occupied slots`` with the replica index
  as the deterministic tie-break (equal load never routes differently on two
  runs — ``tests/fleet/test_router.py`` pins this).
* **Backpressure** — a replica whose queue is at its bound is skipped this
  round; when every candidate is full, ``dispatch`` returns None and the
  request stays at the fleet intake for the next pump (never dropped).
* **Shedding** — requests whose admission deadline passed shed with reason
  ``deadline`` (the same lazy-expiry semantics as ``RequestQueue.peek``);
  requests with no UP replica to run on shed with reason ``no_replica``.
  Every shed increments ``fleet.shed{reason}`` and lands in ``self.shed``.

Replica health is a three-state machine on :class:`ReplicaHandle`:
UP (routable) → DRAINING (finishes in-flight work, accepts nothing new) →
FAILED (dead; the driver evacuates and redrives its requests).  DRAINING and
FAILED are both non-routable; only FAILED triggers redrive.

Thread-crossing contract: ``dispatch`` and ``_shed`` mutate router state
under ``self._lock`` (lock-discipline policy in ``repro.analysis.locks``).
The load snapshot a policy ranks on is racy-but-benign: a replica worker
popping its queue mid-ranking only makes the chosen replica *less* loaded
than estimated, and the post-choice queue-bound check keeps backpressure
exact for the single dispatching thread.
"""
from __future__ import annotations

import enum
import threading
import time
from typing import Callable, List, Optional

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from ..batching.engine import ContinuousEngine
from ..batching.request import Request, RequestState

POLICIES = ("round-robin", "least-loaded")


class ReplicaState(enum.Enum):
    UP = "up"
    DRAINING = "draining"    # finishes in-flight work, accepts no new work
    FAILED = "failed"        # dead; requests evacuated and redriven


class ReplicaHandle:
    """One engine replica as the router sees it: identity, health, load."""

    def __init__(self, idx: int, engine: ContinuousEngine, device=None):
        self.idx = idx
        self.engine = engine
        self.device = device          # forced host device (threaded fleets)
        self.state = ReplicaState.UP

    @property
    def queue_depth(self) -> int:
        return len(self.engine.queue)

    @property
    def occupied_slots(self) -> int:
        # alloc registers mid-prefill requests too, so this counts every
        # request physically on the replica
        return self.engine.slots.n_slots - self.engine.slots.n_free

    @property
    def load(self) -> int:
        """The least-loaded ranking key: waiting + running requests."""
        return self.queue_depth + self.occupied_slots

    @property
    def accepting(self) -> bool:
        return self.state is ReplicaState.UP

    def __repr__(self) -> str:
        return (f"ReplicaHandle(idx={self.idx}, state={self.state.value}, "
                f"load={self.load})")


class Router:
    """Dispatch one request stream across replica queues (docs/FLEET.md)."""

    def __init__(self, replicas: List[ReplicaHandle], *,
                 policy: str = "round-robin",
                 admission_gate: Optional[
                     Callable[[ReplicaHandle, Request], bool]] = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; "
                             f"choose from {', '.join(POLICIES)}")
        if not replicas:
            raise ValueError("router needs at least one replica")
        self.replicas = replicas
        self.policy = policy
        # test/chaos seam: called (replica, request) before a submit; False
        # vetoes this replica for this round (fault harness rejection plans)
        self.admission_gate = admission_gate
        self.shed: List[Request] = []
        self.n_dispatched = 0
        self._rr = 0                  # round-robin cursor
        self._lock = threading.Lock()

    # ------------------------------------------------------------- ranking
    def _candidates(self) -> List[ReplicaHandle]:
        """Routable replicas in policy preference order (deterministic)."""
        up = [h for h in self.replicas if h.accepting]
        if not up:
            return []
        if self.policy == "round-robin":
            k = self._rr % len(up)
            return up[k:] + up[:k]
        # least-loaded; idx breaks ties so equal load is reproducible
        return sorted(up, key=lambda h: (h.load, h.idx))

    # ------------------------------------------------------------ dispatch
    def dispatch(self, req: Request,
                 now: Optional[float] = None) -> Optional[ReplicaHandle]:
        """Place ``req`` on a replica queue, or shed it, or defer it.

        Returns the chosen handle on success.  Returns None in two distinct
        situations the caller tells apart via ``req.done``:

        * ``req.done`` — the request was *shed* terminally (deadline passed,
          or no UP replica exists); it is in ``self.shed`` with
          ``finish_reason`` set and the ``fleet.shed{reason}`` count bumped.
        * not done — pure backpressure (every UP replica full or vetoed);
          the request belongs back at the intake for a later pump.
        """
        with self._lock:
            now = time.monotonic() if now is None else now
            if req.expired(now):
                self._shed_locked(req, "deadline", now)
                return None
            cands = self._candidates()
            if not cands:
                self._shed_locked(req, "no_replica", now)
                return None
            with obs_trace.span("fleet.dispatch", rid=req.rid,
                                policy=self.policy):
                for h in cands:
                    if len(h.engine.queue) >= h.engine.queue.max_queue:
                        continue      # per-replica backpressure: skip, not shed
                    if self.admission_gate is not None \
                            and not self.admission_gate(h, req):
                        obs_metrics.counter("fleet.admission_rejects").inc()
                        continue
                    h.engine.submit_request(req)
                    self._rr += 1
                    self.n_dispatched += 1
                    obs_metrics.counter("fleet.dispatched").inc(
                        replica=h.idx)
                    return h
            return None

    def _shed_locked(self, req: Request, reason: str,
                     now: Optional[float] = None) -> None:
        """Terminal shed (caller holds the lock): mirror the queue's expiry
        bookkeeping at the fleet boundary."""
        req.state = RequestState.EXPIRED if reason == "deadline" \
            else RequestState.REJECTED
        req.finish_reason = reason
        req.t_finished = time.monotonic() if now is None else now
        self.shed.append(req)
        obs_metrics.counter("fleet.shed").inc(reason=reason)

    def shed_request(self, req: Request, reason: str,
                     now: Optional[float] = None) -> None:
        """Public terminal-shed entry for the driver (intake overflow,
        undeliverable handoffs)."""
        with self._lock:
            self._shed_locked(req, reason, now)

    # -------------------------------------------------------------- health
    @property
    def n_up(self) -> int:
        return sum(1 for h in self.replicas if h.state is ReplicaState.UP)
