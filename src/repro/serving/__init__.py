from . import engine
