from . import batching, engine
