from . import batching, engine, resident
