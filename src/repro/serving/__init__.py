from . import batching, engine, fleet, resident
