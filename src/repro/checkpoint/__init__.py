from . import checkpointer
