"""Sharded, atomic, async checkpointing — plain or EntroLLM-compressed.

Layout on disk (one directory per step)::

    <root>/step_000123/
        manifest.json           # tree structure, shapes, dtypes, step, mesh
        shard_00000.npz         # this host's leaves (host-sharded)
        ...                     # (single-host here; the format is per-host)
    <root>/step_000123.COMMIT   # written LAST -> restart-safe atomicity

Properties required at 1000-node scale, all implemented here:

* **atomic**: a checkpoint without its ``.COMMIT`` marker is ignored and
  garbage-collected — a mid-save crash can never corrupt the restore path.
* **async**: ``save_async`` snapshots leaves to host memory then writes on a
  background thread; training continues immediately (the snapshot is the only
  synchronous cost, matching the async checkpointers used by MaxText et al.).
* **sharded**: every host writes only the leaves (or leaf-shards) it owns;
  ``restore`` reassembles and re-shards onto the *current* mesh, which may
  have a different shape than the mesh at save time (elastic rescale).
* **EntroLLM-compressed** (beyond-paper, themed): with ``compress="entro"``
  parameter leaves are stored as quantized symbols + entropy-coded streams
  via :class:`repro.core.store.CompressedModel` — cutting checkpoint bytes by
  the paper's Table-I ratios and hence restore-broadcast traffic at rescale
  events.  ``entro_bits`` sets one uniform bit-width; ``entro_spec`` accepts
  a :class:`repro.core.spec.CompressionSpec` (or its rule string) for
  per-leaf bits / codec policy (DESIGN.md §7).  Optimizer moments stay exact
  (fp32/uint8 as configured).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return {f"leaf_{i:05d}": np.asarray(l) for i, l in enumerate(leaves)}, treedef


def _path_str(path) -> str:
    """A pytree key path as a '/'-joined glob-matchable string
    (``opt/mu/layers/wq``)."""
    parts = []
    for p in path:
        for attr in ("key", "name", "idx"):
            if hasattr(p, attr):
                parts.append(str(getattr(p, attr)))
                break
        else:
            parts.append(str(p).strip("[].'\""))
    return "/".join(parts)


@dataclasses.dataclass
class CheckpointConfig:
    root: str
    keep: int = 3                      # retained committed checkpoints
    compress: Optional[str] = None     # None | "entro"
    entro_bits: int = 8                # quantization bits for "entro"
    # optional CompressionSpec (instance or rule string) driving the "entro"
    # path; overrides entro_bits.  Leaf names are "leaf_%05d/<pytree path>"
    # (e.g. "leaf_00042/opt/mu/layers/wq"), so patterns match the tree path:
    # "*/mu/*:bits=8;*/params/*:bits=auto,codec=rans".  The container is
    # self-describing, so restore needs no spec.
    entro_spec: Optional[object] = None


class Checkpointer:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        os.makedirs(cfg.root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: PyTree, *, blocking: bool = True) -> None:
        """Snapshot (sync) + write (optionally async)."""
        self.wait()                                    # one in-flight save max
        paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
        # synchronous part: device -> host copy (the only training stall)
        host_leaves = [np.asarray(l) for _, l in paths_and_leaves]
        leaf_paths = [_path_str(p) for p, _ in paths_and_leaves]

        def write():
            try:
                self._write(step, host_leaves, treedef, leaf_paths)
            except BaseException as e:               # surfaced on next wait()
                self._last_error = e

        if blocking:
            write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def save_async(self, step: int, tree: PyTree) -> None:
        self.save(step, tree, blocking=False)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self) -> None:
        if self._last_error is not None:
            e, self._last_error = self._last_error, None
            raise RuntimeError("async checkpoint write failed") from e

    def _write(self, step: int, host_leaves, treedef, leaf_paths) -> None:
        name = f"step_{step:09d}"
        tmp = os.path.join(self.cfg.root, name + ".tmp")
        final = os.path.join(self.cfg.root, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(host_leaves),
            "compress": self.cfg.compress,
            "dtypes": [str(l.dtype) for l in host_leaves],
            "shapes": [list(l.shape) for l in host_leaves],
            "time": time.time(),
        }
        if self.cfg.compress == "entro":
            from repro.core.spec import CompressionSpec
            from repro.core.store import CompressedModel
            # leaf names carry the pytree key path ("leaf_00042/opt/mu/…") so
            # entro_spec name-pattern rules can actually match; restore keys
            # on the leaf_%05d prefix, so old positional-only names still load
            named = {f"leaf_{i:05d}/{leaf_paths[i]}" if leaf_paths[i]
                     else f"leaf_{i:05d}":
                     l.astype(np.float32)
                     if str(l.dtype) == "bfloat16" else l
                     for i, l in enumerate(host_leaves)}
            # compress float leaves; ints/bools stored raw
            floaty = {k: v for k, v in named.items()
                      if v.dtype in (np.float32, np.float64)}
            raw = {k: v for k, v in named.items() if k not in floaty}
            spec = self.cfg.entro_spec
            if isinstance(spec, str):
                spec = CompressionSpec.parse(spec)
            if spec is not None:
                manifest["entro_spec"] = spec.describe()
                cm = CompressedModel.compress(floaty, spec=spec)
            else:
                # default path keeps its historical coverage: shape/size only.
                # (Leaf names now embed the pytree path, which the default
                # predicate's sensitive-name keys would newly match — an
                # entro_spec opts into name-based policy; the bare config
                # must not change which leaves get quantized.)
                cm = CompressedModel.compress(
                    floaty, bits=self.cfg.entro_bits,
                    should_quantize=lambda n, w: w.ndim >= 2
                    and w.size >= 4096)
            cm.save(os.path.join(tmp, "shard_00000_entro"))
            np.savez(os.path.join(tmp, "shard_00000_raw.npz"), **raw)
        else:
            # npz cannot round-trip bf16 -> store such leaves as uint16 views
            arrays = {f"leaf_{i:05d}": (l.view(np.uint16)
                                        if str(l.dtype) == "bfloat16" else l)
                      for i, l in enumerate(host_leaves)}
            np.savez(os.path.join(tmp, "shard_00000.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, final)                        # atomic rename ...
        with open(final + ".COMMIT", "w") as f:       # ... then commit marker
            f.write(str(step))
        self._gc()

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = []
        for n in os.listdir(self.cfg.root):
            if n.endswith(".COMMIT"):
                steps.append(int(n[len("step_"):-len(".COMMIT")]))
        return max(steps) if steps else None

    def restore(self, step: Optional[int] = None, *,
                like: Optional[PyTree] = None,
                shardings: Optional[PyTree] = None) -> Tuple[int, PyTree]:
        """Restore a committed checkpoint; re-shard onto the current mesh.

        ``like`` supplies the treedef (a template pytree, e.g. freshly-inited
        state); leaves are matched positionally.  With ``shardings`` the
        leaves are device_put with the (possibly different / elastic) current
        sharding.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {self.cfg.root}")
        final = os.path.join(self.cfg.root, f"step_{step:09d}")
        if not os.path.exists(final + ".COMMIT"):
            raise FileNotFoundError(f"checkpoint {final} lacks COMMIT marker")
        with open(os.path.join(final, "manifest.json")) as f:
            manifest = json.load(f)

        if manifest.get("compress") == "entro":
            from repro.core.store import CompressedModel
            cm = CompressedModel.load(os.path.join(final, "shard_00000_entro.npz"))
            named = dict(cm.dequantize_all())
            raw = np.load(os.path.join(final, "shard_00000_raw.npz"))
            named.update({k: raw[k] for k in raw.files})
        else:
            z = np.load(os.path.join(final, "shard_00000.npz"))
            named = {k: z[k] for k in z.files}

        import ml_dtypes
        # leaves are matched by the leaf_%05d prefix: new checkpoints carry
        # 'leaf_00042/<pytree path>' names, old ones the bare prefix
        by_idx = {k.split("/", 1)[0]: k for k in named}
        leaves = []
        for i in range(manifest["n_leaves"]):
            arr = named[by_idx[f"leaf_{i:05d}"]]
            dt = manifest["dtypes"][i]
            if dt == "bfloat16":
                arr = (arr.view(ml_dtypes.bfloat16) if arr.dtype == np.uint16
                       else arr.astype(ml_dtypes.bfloat16))
            else:
                arr = arr.astype(dt)
            leaves.append(arr.reshape(manifest["shapes"][i]))

        assert like is not None, "restore() needs a template pytree (like=)"
        treedef = jax.tree.structure(like)
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return step, tree

    # -------------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = sorted(s for s in (self.latest_steps()))
        # remove uncommitted debris
        for n in os.listdir(self.cfg.root):
            p = os.path.join(self.cfg.root, n)
            if n.endswith(".tmp"):
                shutil.rmtree(p, ignore_errors=True)
            elif n.startswith("step_") and not n.endswith(".COMMIT") \
                    and not os.path.exists(p + ".COMMIT"):
                shutil.rmtree(p, ignore_errors=True)
        for s in steps[: -self.cfg.keep]:
            name = os.path.join(self.cfg.root, f"step_{s:09d}")
            shutil.rmtree(name, ignore_errors=True)
            try:
                os.remove(name + ".COMMIT")
            except FileNotFoundError:
                pass

    def latest_steps(self):
        for n in os.listdir(self.cfg.root):
            if n.endswith(".COMMIT"):
                yield int(n[len("step_"):-len(".COMMIT")])
