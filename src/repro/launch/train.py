"""Training launcher.

Two modes:

* default — run REAL steps on this host's devices with a reduced config
  (CPU-friendly): full data pipeline, AdamW, checkpoints, watchdogs.
* ``--production`` — build the production-mesh program for the full config
  and ``.lower().compile()`` it (on real hardware the same code path runs;
  on this container it is the dry-run proof).

Examples::

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch dbrx-132b --production
"""
import argparse
import os
import sys


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", default="train_4k")
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--checkpoint-dir", default=None)
    p.add_argument("--checkpoint-every", type=int, default=50)
    p.add_argument("--grad-compress", action="store_true")
    p.add_argument("--production", action="store_true",
                   help="lower+compile the full config on the production mesh")
    p.add_argument("--multi-pod", action="store_true")
    args = p.parse_args(argv)

    if args.production:
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

    import jax
    from repro.configs import registry
    from repro.configs.base import SHAPES

    if args.production:
        from repro.launch import dryrun
        d = dryrun.run_cell(args.arch, args.shape, multi_pod=args.multi_pod)
        return 0 if "error" not in d else 1

    from repro.data.pipeline import DataConfig, SyntheticSource
    from repro.models import api
    from repro.training import optimizer as opt, train_loop
    from repro.distributed.fault_tolerance import (CheckpointHook, NanWatchdog,
                                                   StepTimeWatchdog)
    from repro.checkpoint.checkpointer import Checkpointer, CheckpointConfig

    cfg = registry.reduced(registry.get(args.arch))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    tc = train_loop.TrainConfig(
        opt=opt.AdamWConfig(schedule=opt.Schedule(
            base_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
            total_steps=args.steps)),
        microbatches=args.microbatches,
        grad_compress=args.grad_compress)
    state = opt.init_state(tc.opt, params)
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                    global_batch=args.batch,
                    src_embed_dim=cfg.d_model if cfg.family == "encdec" else 0)
    src = SyntheticSource(dc)

    hooks = []
    watchdog = StepTimeWatchdog()
    hooks.append(lambda i, p, s, m: watchdog.tick(i) and None)
    if args.checkpoint_dir:
        ck = Checkpointer(CheckpointConfig(root=args.checkpoint_dir))
        hooks.append(CheckpointHook(ck, args.checkpoint_every))
        hooks.append(NanWatchdog(ck, (params, state)))

    params, state, info = train_loop.train(
        cfg, tc, params, state, iter(src), args.steps, hooks=tuple(hooks))
    h = info["history"]
    print(f"arch={cfg.name} steps={args.steps} "
          f"loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f} "
          f"({info['steps_per_s']:.2f} steps/s)")
    if args.checkpoint_dir:
        ck.save(args.steps, (params, state))
        print(f"final checkpoint -> {args.checkpoint_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
