"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — device count is locked at first jax init, and only
``dryrun.py`` sets the 512-device XLA flag.

Mesh shapes (assignment):
  * single-pod: (16, 16)     axes ("data", "model")   = 256 chips
  * multi-pod:  (2, 16, 16)  axes ("pod", "data", "model") = 512 chips

Axis roles (DESIGN.md §6): "model" = TP + EP; "data" = FSDP + batch DP;
"pod" = hierarchical DP (gradient all-reduce over DCI; weights replicated
per pod so only grads cross pods).
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(*, model: int = 1):
    """Whatever this host actually has (CPU smoke tests, examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=_auto(2))


# TPU v5e hardware constants used by every roofline computation.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW_PER_LINK = 50e9            # bytes/s per link
ICI_LINKS = 4                     # 2D torus: 4 links/chip (x+/x-/y+/y-)
DCI_BW = 25e9                     # inter-pod per-host effective (conservative)
HBM_PER_CHIP = 16 * 1024**3
