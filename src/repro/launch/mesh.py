"""Mesh construction + the jax-version compat shim.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — device count is locked at first jax init, and only
``dryrun.py`` sets the 512-device XLA flag.

Mesh shapes (assignment):
  * single-pod: (16, 16)     axes ("data", "model")   = 256 chips
  * multi-pod:  (2, 16, 16)  axes ("pod", "data", "model") = 512 chips

Axis roles (DESIGN.md §6): "model" = TP + EP; "data" = FSDP + batch DP;
"pod" = hierarchical DP (gradient all-reduce over DCI; weights replicated
per pod so only grads cross pods).

Compat: ``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
``jax.make_mesh``) only exists on newer jax.  On jax 0.4.x the attribute
lookup raises, which used to kill every mesh construction in the repo.
:func:`make_mesh` is the one place that knows the difference — every mesh in
src/ and tests/ goes through it: Auto axis types where the API has them,
positional fallback (plain ``jax.make_mesh``) where it doesn't.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax

# None on jax without the explicit-sharding API (e.g. 0.4.37); the enum on
# newer jax.  Resolved once at import — the API surface cannot change mid-run.
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def auto_axis_types(n: int) -> Optional[tuple]:
    """``(AxisType.Auto,) * n`` on new jax; None where the enum is absent."""
    if _AXIS_TYPE is None:
        return None
    return (_AXIS_TYPE.Auto,) * n


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None) -> jax.sharding.Mesh:
    """Version-portable ``jax.make_mesh``.

    On jax with ``jax.sharding.AxisType`` the mesh is built with explicit
    Auto axis types (the repo's GSPMD-propagation contract stated, not
    inferred); on 0.4.x the kwarg does not exist and the positional call is
    used — 0.4.x meshes are implicitly Auto, so behavior is identical.
    """
    shape, names = tuple(axis_shapes), tuple(axis_names)
    kw = {} if devices is None else {"devices": devices}
    at = auto_axis_types(len(names))
    if at is not None:
        try:
            return jax.make_mesh(shape, names, axis_types=at, **kw)
        except TypeError:
            # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(shape, names, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1):
    """Whatever this host actually has (CPU smoke tests, examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return make_mesh((n // model, model), ("data", "model"))


def parse_mesh_spec(spec: str) -> Tuple[int, int]:
    """``"DxM"`` (also ``D×M``) -> ``(data, model)`` ints, with a clear error
    on malformed input — shared by the serve CLI and the benchmarks."""
    d, sep, m = spec.lower().replace("×", "x").partition("x")
    try:
        if not sep:
            raise ValueError
        return int(d), int(m)
    except ValueError:
        raise ValueError(
            f"bad mesh spec {spec!r}: expected DATAxMODEL, e.g. 2x4") from None


def make_serve_mesh(data: int, model: int) -> jax.sharding.Mesh:
    """(data, model) serving mesh over the first ``data*model`` local devices
    (the ``--mesh DxM`` serve flag; forced host-platform CPU devices in tests
    via ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got {data}x{model}")
    need = data * model
    devs = jax.devices()
    if len(devs) < need:
        raise ValueError(
            f"--mesh {data}x{model} needs {need} devices but this host has "
            f"{len(devs)}; force CPU devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    return make_mesh((data, model), ("data", "model"), devices=devs[:need])


# TPU v5e hardware constants used by every roofline computation.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW_PER_LINK = 50e9            # bytes/s per link
ICI_LINKS = 4                     # 2D torus: 4 links/chip (x+/x-/y+/y-)
DCI_BW = 25e9                     # inter-pod per-host effective (conservative)
HBM_PER_CHIP = 16 * 1024**3
