"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch, shape, mesh), all in seconds/step per chip:

  compute    = HLO_FLOPs_per_chip / 197e12          (bf16 MXU peak, v5e)
  memory     = HBM_bytes_per_chip / 819e9
  collective = wire_bytes_per_chip / (4 x 50e9)     (2D-torus ICI)

FLOPs come from ``compiled.cost_analysis()['flops']`` (post-SPMD,
per-device).  Collective bytes are parsed from the optimized HLO text with
ring-cost formulas (AG/RS: (n-1)/n, AR: 2(n-1)/n, A2A: (n-1)/n, permute: 1x).

HBM bytes: ``cost_analysis()['bytes accessed']`` is reported, but the CPU
backend materializes f32 copies of bf16 dot operands and counts every fusion
boundary, so we ALSO compute a dtype-aware analytic estimate (weights + KV +
activation carries + optimizer traffic per step kind — formulas below) and
use it as the roofline's memory term; both numbers are recorded.  This is the
approach DESIGN.md §4 documents.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

from repro.configs.base import ArchConfig, ShapeConfig
from . import mesh as hw

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9,\[\]{}]+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of 'bf16[2,3]' or a '(bf16[..], f32[..])' tuple string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def collective_wire_bytes(hlo_text: str, total_devices: int) -> Dict[str, float]:
    """Per-chip wire bytes by collective kind, ring-cost weighted."""
    out: Dict[str, float] = {"all-gather": 0.0, "all-reduce": 0.0,
                             "reduce-scatter": 0.0, "all-to-all": 0.0,
                             "collective-permute": 0.0}
    counts: Dict[str, int] = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2).lower()
        nbytes = _shape_bytes(shape_str)     # op OUTPUT shape
        n = max(_group_size(line, total_devices), 1)
        if n == 1:
            continue
        if kind == "all-gather":
            wire = nbytes * (n - 1) / n          # output is the gathered size
        elif kind == "all-reduce":
            wire = nbytes * 2 * (n - 1) / n
        elif kind == "reduce-scatter":
            wire = nbytes * (n - 1)              # output is 1/n of the input
        elif kind == "all-to-all":
            wire = nbytes * (n - 1) / n
        else:                                    # collective-permute
            wire = nbytes
        out[kind] += wire
        counts[kind] += 1
    out["counts"] = counts  # type: ignore
    return out


# ------------------------------------------------------------- analytic bytes

def _param_bytes(cfg: ArchConfig, weights: str) -> float:
    per = {"bf16": 2.0, "int8": 1.0, "int4": 0.5}[weights]
    return cfg.param_count() * per


def _active_param_bytes(cfg: ArchConfig, weights: str) -> float:
    per = {"bf16": 2.0, "int8": 1.0, "int4": 0.5}[weights]
    return cfg.active_param_count() * per


def _cache_bytes(cfg: ArchConfig, B: int, S: int, kv_bytes: float = 2.0
                 ) -> float:
    """KV/state cache bytes (whole fleet)."""
    if cfg.family == "ssm":
        ssm = cfg.ssm
        H = ssm.n_heads(cfg.d_model)
        conv = cfg.n_layers * B * (ssm.d_conv - 1) \
            * (ssm.d_inner(cfg.d_model) + 2 * ssm.d_state) * 2
        state = cfg.n_layers * B * H * ssm.head_dim * ssm.d_state * 4
        return conv + state
    kv = 2 * cfg.n_kv_heads * cfg.hd * B * S * kv_bytes
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_period
        ssm = cfg.ssm
        H = ssm.n_heads(cfg.d_model)
        state = (cfg.n_layers - n_attn) * B * H * ssm.head_dim * ssm.d_state * 4
        return kv * n_attn + state
    if cfg.family == "encdec":
        return kv * cfg.n_layers * 2          # self + cross caches
    return kv * cfg.n_layers


def analytic_hbm_bytes(cfg: ArchConfig, shape: ShapeConfig, meta: Dict,
                       chips: int) -> float:
    """Per-chip HBM bytes per step (documented formulas, DESIGN.md §4)."""
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    L = cfg.n_layers
    weights = meta.get("weights", "bf16")
    if meta["kind"] == "train":
        mb = meta.get("microbatches", 1)
        # fwd + bwd weight reads per microbatch (gathered shard traffic lands
        # as HBM writes+reads on the receiving chip), grads rw, opt state rw
        p = cfg.param_count()
        wbytes = 2 * p * 2 * mb              # fwd+bwd reads, bf16
        gbytes = 2 * p * 4                   # grad accumulate rw (f32)
        q8 = meta.get("q8_opt", False)
        obytes = p * (2 * 2 if q8 else 2 * 8) + p * 2      # m+v rw + param write
        act = mb * L * (B // mb) * S * D * 2 * 2           # carry save + load
        logits = (B * S * cfg.padded_vocab() * 2) * 2      # lm head out + grad
        return (wbytes + gbytes + obytes + act + logits) / chips
    if meta["kind"] == "prefill":
        p = _param_bytes(cfg, weights)
        act = L * B * S * D * 2 * 2
        cache_w = _cache_bytes(cfg, B, S)
        return (p + act + cache_w) / chips
    # decode: weights once (active params only for MoE), cache read once
    p = _active_param_bytes(cfg, weights)
    kvb = 2.0 if meta.get("kv_bits", 16) == 16 else 1.0 + 2.0 / cfg.hd
    cache_r = _cache_bytes(cfg, B, S, kv_bytes=kvb)
    return (p + cache_r) / chips


def model_flops(cfg: ArchConfig, shape: ShapeConfig, kind: str) -> float:
    """6·N_active·tokens for train; 2·N_active·tokens for inference.

    N_active excludes the input embedding table: a gather does no matmul
    FLOPs (the LM head does and stays counted).
    """
    n = cfg.active_param_count() - cfg.padded_vocab() * cfg.d_model
    if kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch          # decode: 1 token/request


# ------------------------------------------------------------------- assembly

@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    kind: str
    chips: int
    flops_per_chip: float
    raw_bytes_per_chip: float
    analytic_bytes_per_chip: float
    wire_bytes_per_chip: float
    collective_detail: Dict[str, float]
    memory_analysis: Dict[str, float]
    meta: Dict[str, Any]

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / hw.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.analytic_bytes_per_chip / hw.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_chip / (hw.ICI_LINKS * hw.ICI_BW_PER_LINK)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Perfect-overlap step-time estimate: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful_model_time / estimated_step_time.

        For compute-bound cells this is MFU; for memory/collective-bound cells
        it is the fraction of the step the bounding resource spends on model-
        essential traffic.
        """
        from repro.configs.base import SHAPES
        mf = model_flops_cached(self)
        useful_compute = mf / self.chips / hw.PEAK_FLOPS_BF16
        return min(1.0, useful_compute / max(self.step_s, 1e-30))

    def to_dict(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "kind": self.kind, "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "raw_bytes_per_chip": self.raw_bytes_per_chip,
            "analytic_bytes_per_chip": self.analytic_bytes_per_chip,
            "wire_bytes_per_chip": self.wire_bytes_per_chip,
            "collective_detail": self.collective_detail,
            "memory_analysis": self.memory_analysis,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "step_s": self.step_s,
            "model_flops": model_flops_cached(self),
            "flops_ratio": model_flops_cached(self)
            / max(self.flops_per_chip * self.chips, 1e-30),
            "meta": {k: v for k, v in self.meta.items()
                     if isinstance(v, (str, int, float, bool))},
        }


def model_flops_cached(r: Roofline) -> float:
    from repro.configs import registry
    from repro.configs.base import SHAPES
    return model_flops(registry.get(r.arch), SHAPES[r.shape], r.kind)


def analyze_extrapolated(cell, compiled_mem, c1, c2, *, n_stack: int, u2: int,
                         gather_scale: int = 1) -> Roofline:
    """Roofline from the three-compile protocol (see dryrun.run_cell).

    ``compiled_mem`` supplies memory_analysis; ``c1``/``c2`` (unroll=1/u2,
    single microbatch) supply the linear FLOP/wire extrapolation.
    """
    chips = cell.mesh.devices.size
    f1 = float(c1.cost_analysis().get("flops", 0.0))
    f2 = float(c2.cost_analysis().get("flops", 0.0))
    flops = f1 + (n_stack - 1) * (f2 - f1) / max(u2 - 1, 1)
    b1 = float(c1.cost_analysis().get("bytes accessed", 0.0))
    b2 = float(c2.cost_analysis().get("bytes accessed", 0.0))
    raw_bytes = b1 + (n_stack - 1) * (b2 - b1) / max(u2 - 1, 1)

    w1 = collective_wire_bytes(c1.as_text(), chips)
    w2 = collective_wire_bytes(c2.as_text(), chips)
    counts1, counts2 = w1.pop("counts"), w2.pop("counts")

    def _ext(a, b):
        # if the u2 compile shows LESS of a kind (CSE merged copies), treat the
        # kind as loop-invariant rather than extrapolating negative.
        if b < a:
            return max(a, b)
        return a + (n_stack - 1) * (b - a) / max(u2 - 1, 1)

    wire = {k: _ext(w1[k], w2[k]) for k in w1}
    wire["all-gather"] *= gather_scale
    counts = {k: int(_ext(counts1[k], counts2[k])) for k in counts1}
    wire_total = sum(max(v, 0.0) for v in wire.values())

    ma = compiled_mem.memory_analysis()
    mem = {
        "argument_size": ma.argument_size_in_bytes,
        "output_size": ma.output_size_in_bytes,
        "temp_size": ma.temp_size_in_bytes,
        "alias_size": ma.alias_size_in_bytes,
        "generated_code_size": ma.generated_code_size_in_bytes,
    }
    analytic = analytic_hbm_bytes(cell.cfg, cell.shape, cell.meta, chips)
    return Roofline(
        arch=cell.cfg.name, shape=cell.shape.name,
        mesh="x".join(str(s) for s in cell.mesh.devices.shape),
        kind=cell.meta["kind"], chips=chips,
        flops_per_chip=flops, raw_bytes_per_chip=raw_bytes,
        analytic_bytes_per_chip=analytic, wire_bytes_per_chip=wire_total,
        collective_detail={**wire, "counts": counts},
        memory_analysis=mem, meta=cell.meta,
    )


def analyze(cell, lowered, compiled) -> Roofline:
    chips = cell.mesh.devices.size
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    wire = collective_wire_bytes(compiled.as_text(), chips)
    counts = wire.pop("counts")
    wire_total = sum(wire.values())
    ma = compiled.memory_analysis()
    mem = {
        "argument_size": ma.argument_size_in_bytes,
        "output_size": ma.output_size_in_bytes,
        "temp_size": ma.temp_size_in_bytes,
        "alias_size": ma.alias_size_in_bytes,
        "generated_code_size": ma.generated_code_size_in_bytes,
    }
    analytic = analytic_hbm_bytes(cell.cfg, cell.shape, cell.meta, chips)
    return Roofline(
        arch=cell.cfg.name, shape=cell.shape.name,
        mesh="x".join(str(s) for s in cell.mesh.devices.shape),
        kind=cell.meta["kind"], chips=chips,
        flops_per_chip=flops, raw_bytes_per_chip=raw_bytes,
        analytic_bytes_per_chip=analytic, wire_bytes_per_chip=wire_total,
        collective_detail={**wire, "counts": counts},
        memory_analysis=mem, meta=cell.meta,
    )
