"""Serving launcher: EntroLLM end-to-end on this host.

Pipeline: init weights -> mixed-quantize + Huffman-encode into the
compressed container -> *streaming* parallel decode (chunked, double-buffered
prefetch through a named decoder backend) -> serve batched requests with
quantized (QT) weights resident, dequant fused into matmuls.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --bits 8 --batch 4 --prompt-len 32 --gen 16

``--production`` lowers the full-config serve_step on the production mesh
instead (same path as the dry-run decode cells).
"""
import argparse
import os
import sys
import time


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--bits", type=int, default=8, choices=[4, 8])
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--no-quantized-serving", action="store_true",
                   help="dequantize to dense fp32 at load (baseline mode)")
    p.add_argument("--decode-backend", default=None,
                   help="decoder backend name (numpy / jax / pallas / "
                        "pallas-interpret); default: capability auto-pick")
    p.add_argument("--chunk-symbols", type=int, default=None,
                   help="streaming decode chunk budget in symbols "
                        "(default: scheduler per-layer budget)")
    p.add_argument("--no-stream", action="store_true",
                   help="monolithic decode_all load (pre-streaming path)")
    p.add_argument("--production", action="store_true")
    p.add_argument("--shape", default="decode_32k")
    p.add_argument("--multi-pod", action="store_true")
    args = p.parse_args(argv)

    if args.production:
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch import dryrun
        d = dryrun.run_cell(args.arch, args.shape, multi_pod=args.multi_pod)
        return 0 if "error" not in d else 1

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import registry
    from repro.core.store import CompressedModel
    from repro.models import api
    from repro.serving import engine

    cfg = registry.reduced(registry.get(args.arch))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    host = {k: np.asarray(v, np.float32) for k, v in params.items()}

    t0 = time.perf_counter()
    # PER_CHANNEL = one (s, z) per leading index — for layer-stacked tensors
    # that is exactly the paper's per-LAYER mixed scheme (Alg. 1 line 5), and
    # scanned layers need the leading scale dim to match the stack.
    from repro.core.quant import Granularity
    cm = CompressedModel.compress(host, bits=args.bits,
                                  granularity=Granularity.PER_CHANNEL)
    t_comp = time.perf_counter() - t0
    st = cm.stats()
    print(f"compressed {st.param_count/1e6:.1f}M params: "
          f"{st.bits}b quant -> {st.effective_bits:.2f} effective bits "
          f"(entropy {st.entropy_bits:.2f}); "
          f"{st.reduction_vs_quant*100:.1f}% below quantized, "
          f"{st.reduction_vs_fp16*100:.1f}% below fp16  [{t_comp:.1f}s]")

    load_metrics = {}
    load_kw = {}
    if args.chunk_symbols is not None:      # absent flag -> scheduler default
        load_kw["chunk_symbols"] = args.chunk_symbols
    serve_params = engine.load_params_from_compressed(
        cm, quantized=not args.no_quantized_serving,
        backend=args.decode_backend, stream=not args.no_stream,
        metrics=load_metrics, **load_kw)
    print(f"{'streamed' if not args.no_stream else 'monolithic'} decode + "
          f"load [{load_metrics['decode_backend']}]: "
          f"{load_metrics['decode_load_s']:.2f}s "
          f"(first weight resident after "
          f"{load_metrics['time_to_first_weight_s']*1e3:.0f}ms; "
          f"quantized residency: {not args.no_quantized_serving})")

    sc = engine.ServeConfig(max_len=args.prompt_len + args.gen)
    eng = engine.Engine(cfg, serve_params, sc)
    rng = np.random.default_rng(0)
    if cfg.family == "encdec":
        prompt = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab,
                                               (args.batch, args.prompt_len)),
                                  jnp.int32),
            "src_embeds": jnp.asarray(rng.normal(
                0, 1, (args.batch, args.prompt_len, cfg.d_model)),
                jnp.bfloat16),
        }
    else:
        prompt = jnp.asarray(rng.integers(0, cfg.vocab,
                                          (args.batch, args.prompt_len)),
                             jnp.int32)
    out, metrics = eng.generate(prompt, args.gen, echo_metrics=True)
    ttft = load_metrics["decode_load_s"] + metrics["ttft_s"]
    print(f"generated {out.shape} tokens: prefill {metrics['prefill_s']:.2f}s, "
          f"decode {metrics['decode_s']:.2f}s "
          f"({metrics['tok_per_s']:.1f} tok/s); "
          f"time-to-first-token incl. weight load: {ttft:.2f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
