"""Serving launcher: EntroLLM end-to-end on this host.

Pipeline: init weights -> mixed-quantize + entropy-encode into the
compressed container (``--codec`` picks the coder; ``--compress-spec`` sets
per-tensor bits / codec / fp32 rules — see :mod:`repro.core.spec`) ->
*streaming* parallel decode (chunked, double-buffered prefetch through a
named decoder backend) -> serve with quantized (QT) weights resident,
dequant fused into matmuls.

``--resident compressed`` skips the load-time decode entirely: the
entropy-coded container stays resident and each layer is decoded just
before its matmuls, double-buffered against the previous layer's compute
(the paper's §IV serving scenario; docs/SERVING.md §"Compressed-resident
serving").  Greedy outputs are bit-identical to the default
``--resident dense`` engine; the launcher reports peak resident weight
bytes for both so the bandwidth-vs-compute tradeoff is visible.

Two serving modes:

* lockstep (default) — one fixed-shape batch through ``Engine.generate``:

      PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
          --bits 8 --batch 4 --prompt-len 32 --gen 16

* continuous batching (``--batch-slots N``) — a slot-batched
  ``ContinuousEngine`` serves ``--traffic R`` independently-arriving
  synthetic requests (Poisson replay; ragged prompts and gen lengths),
  reporting queue wait / TTFT / latency percentiles:

      PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
          --bits 8 --batch-slots 8 --traffic 16 --gen 16

``--production`` lowers the full-config serve_step on the production mesh
instead (same path as the dry-run decode cells).
"""
import argparse
import os
import sys
import time


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--bits", type=int, default=8,
                   help="uniform quantization bit-width, 1..8 (subsumed by "
                        "--compress-spec)")
    p.add_argument("--codec", default="huffman",
                   help="entropy codec for the whole model (huffman / rans / "
                        "raw); subsumed by --compress-spec")
    p.add_argument("--compress-spec", default=None, metavar="SPEC",
                   help="per-tensor compression rules, e.g. "
                        "'*norm*:fp32;layers/*:bits=4,codec=rans;*:bits=8' "
                        "(see repro.core.spec); overrides --bits/--codec")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--no-quantized-serving", action="store_true",
                   help="dequantize to dense fp32 at load (baseline mode)")
    p.add_argument("--resident", choices=("dense", "compressed"),
                   default="dense",
                   help="weight residency: 'dense' decodes the container "
                        "into HBM-resident QT params at load; 'compressed' "
                        "keeps the entropy-coded payload resident and "
                        "decodes each layer just before its matmuls "
                        "(bit-identical greedy outputs; see docs/SERVING.md)")
    p.add_argument("--fused", action="store_true",
                   help="with --resident compressed: hand tile-aligned "
                        "tensors to the fused decode→dequant→matmul kernel "
                        "as payload handles (weights never materialize "
                        "densely in HBM); incompatible tensors fall back "
                        "per-tensor to the per-layer decode path")
    p.add_argument("--fused-impl", default=None,
                   choices=("pallas", "jax", "pallas-interpret"),
                   help="fused kernel implementation override (default: "
                        "capability pick — compiled Pallas where it probes, "
                        "the jit in-graph decode elsewhere)")
    p.add_argument("--decode-backend", default=None,
                   help="decoder backend name (numpy / jax / pallas / "
                        "pallas-interpret); default: capability auto-pick")
    p.add_argument("--chunk-symbols", type=int, default=None,
                   help="streaming decode chunk budget in symbols "
                        "(default: scheduler per-layer budget)")
    p.add_argument("--no-stream", action="store_true",
                   help="monolithic decode_all load (pre-streaming path)")
    p.add_argument("--batch-slots", type=int, default=0, metavar="N",
                   help="serve with an N-slot continuous-batching engine "
                        "instead of one lockstep batch (0 = lockstep)")
    p.add_argument("--max-queue", type=int, default=64,
                   help="admission-queue bound for --batch-slots")
    p.add_argument("--prefill-chunk", type=int, default=16,
                   help="chunked-prefill step for --batch-slots")
    p.add_argument("--traffic", type=int, default=0, metavar="R",
                   help="with --batch-slots: replay R synthetic Poisson "
                        "arrivals (ragged prompts/gen) instead of one "
                        "uniform request wave")
    p.add_argument("--kv-spec", default=None, metavar="SPEC",
                   help="with --batch-slots: serve through the paged KV "
                        "block pool under this compression policy, e.g. "
                        "'bits=4,block=16,codec=rans,sharing' (see "
                        "repro.core.spec.KVCompressionSpec; bits=16 keeps "
                        "dense bf16 blocks, bit-identical to the slot pool; "
                        "docs/KV_CACHE.md)")
    p.add_argument("--kv-block", type=int, default=0, metavar="B",
                   help="override the paged KV block size (tokens per "
                        "block); implies --kv-spec when given alone")
    p.add_argument("--prefix-sharing", action="store_true",
                   help="share identical prompt-prefix KV blocks across "
                        "requests (copy-on-write publish of full prompt "
                        "blocks; implies --kv-spec when given alone, and "
                        "makes --traffic replay shared system prompts)")
    p.add_argument("--replicas", type=int, default=0, metavar="N",
                   help="with --batch-slots: serve through a fleet of N "
                        "data-parallel continuous-batching replicas behind "
                        "a request router (one shared weight tree, lockstep "
                        "drive; docs/FLEET.md)")
    p.add_argument("--router", default="round-robin",
                   choices=("round-robin", "least-loaded"),
                   help="fleet placement policy for --replicas "
                        "(docs/FLEET.md)")
    p.add_argument("--disaggregate", default=None, metavar="P:D",
                   help="with --replicas: split the fleet into P prefill "
                        "replicas + D decode replicas (P+D = N); finished "
                        "prompt KV ships prefill->decode as entropy-coded "
                        "block payloads, so requires --kv-spec")
    p.add_argument("--mesh", default=None, metavar="DxM",
                   help="serve on a (data, model) device mesh, e.g. 2x4: "
                        "weights tensor-parallel over model (QT q/scale/zero "
                        "sharded consistently along output channels), KV "
                        "cache batch/slot-sharded over data, placement at "
                        "load-stream time; needs data*model local devices "
                        "(CPU hosts: XLA_FLAGS=--xla_force_host_platform_"
                        "device_count=N)")
    p.add_argument("--trace-out", default=None, metavar="FILE",
                   help="write a Chrome/Perfetto trace_event JSON of the "
                        "serve (load + prefill + decode spans; open in "
                        "ui.perfetto.dev or chrome://tracing, analyze with "
                        "benchmarks/overlap_report.py; docs/OBSERVABILITY.md)")
    p.add_argument("--metrics-out", default=None, metavar="FILE",
                   help="write the metrics-registry snapshot as JSON lines "
                        "(counters/gauges/histograms + per-request "
                        "lifecycles; docs/OBSERVABILITY.md has the catalog)")
    p.add_argument("--trace-sync", action="store_true",
                   help="fence (block_until_ready) inside spans so durations "
                        "measure device compute, not jax async dispatch — "
                        "perturbs pipelining, so timings are faithful but "
                        "throughput is not; outputs stay bit-identical")
    p.add_argument("--production", action="store_true")
    p.add_argument("--shape", default="decode_32k")
    p.add_argument("--multi-pod", action="store_true")
    args = p.parse_args(argv)

    mesh_dims = None
    if args.mesh:
        from repro.launch.mesh import parse_mesh_spec
        try:
            mesh_dims = parse_mesh_spec(args.mesh)
        except ValueError as e:
            p.error(f"--mesh: {e}")

    if args.resident == "compressed":
        # same upfront-validation contract as the other flags: incompatible
        # mode combinations fail here with the documented alternative
        if args.mesh:
            p.error("--resident compressed is single-device (per-layer "
                    "decode targets the bandwidth-bound single-accelerator "
                    "regime); drop --mesh or use --resident dense")
        if args.no_quantized_serving:
            p.error("--resident compressed always serves QT weights "
                    "(the fused-dequant path hosts the per-layer slots); "
                    "drop --no-quantized-serving")
        if args.no_stream:
            p.error("--no-stream only applies to the load-time decode of "
                    "--resident dense")
    elif args.fused or args.fused_impl:
        p.error("--fused/--fused-impl require --resident compressed (the "
                "fused kernel consumes the entropy-coded payload handles "
                "that mode keeps resident)")

    # paged KV: parse + validate the policy upfront (same contract as
    # --compress-spec); the paged pool rides dense residency, single device
    kv_spec = None
    if args.kv_spec is not None or args.kv_block or args.prefix_sharing:
        if args.batch_slots <= 0:
            p.error("--kv-spec/--kv-block/--prefix-sharing require "
                    "--batch-slots (the paged KV cache is a "
                    "continuous-batching feature; docs/KV_CACHE.md)")
        if args.resident != "dense":
            p.error("paged KV (--kv-spec) needs --resident dense: the "
                    "compressed-resident per-layer drivers have no paged "
                    "step twins yet")
        if args.mesh:
            p.error("paged KV (--kv-spec) is single-device today; drop "
                    "--mesh")
        from repro.core.spec import KVCompressionSpec
        overrides = {}
        if args.kv_block:
            overrides["block_size"] = args.kv_block
        if args.prefix_sharing:
            overrides["sharing"] = True
        try:
            kv_spec = KVCompressionSpec.parse(args.kv_spec or "", **overrides)
        except (ValueError, KeyError) as e:
            p.error(f"bad --kv-spec: {e}")
        if kv_spec.sharing and args.prefill_chunk % kv_spec.block_size:
            p.error(f"--prefix-sharing needs --prefill-chunk divisible by "
                    f"the KV block size (chunk {args.prefill_chunk}, block "
                    f"{kv_spec.block_size}): the prefix-skip boundary must "
                    f"be a chunk boundary")

    # fleet flags: same upfront-validation contract (docs/FLEET.md); the
    # parsed P:D split rides on args so _serve_fleet sees a tuple, not text
    args.disaggregate_split = None
    if args.disaggregate and args.replicas <= 0:
        p.error("--disaggregate requires --replicas")
    if args.replicas:
        if args.replicas < 1:
            p.error(f"--replicas must be >= 1, got {args.replicas}")
        if args.batch_slots <= 0:
            p.error("--replicas requires --batch-slots (fleet replicas are "
                    "continuous-batching engines; docs/FLEET.md)")
        if args.mesh:
            p.error("--replicas is data parallelism over single-device "
                    "engines; the mesh layer shards ONE engine — drop "
                    "--mesh or serve a single replica")
        if args.resident != "dense":
            p.error("--replicas needs --resident dense: the per-layer "
                    "compressed-resident drivers are single-engine today")
        if args.disaggregate:
            try:
                n_pre, n_dec = (int(x) for x in args.disaggregate.split(":"))
            except ValueError:
                p.error(f"bad --disaggregate {args.disaggregate!r}: "
                        f"want P:D, e.g. 1:1")
            if n_pre < 1 or n_dec < 1:
                p.error("--disaggregate needs at least one prefill and one "
                        "decode replica")
            if n_pre + n_dec != args.replicas:
                p.error(f"--disaggregate {args.disaggregate} must sum to "
                        f"--replicas ({args.replicas})")
            if kv_spec is None:
                p.error("--disaggregate requires --kv-spec: the prefill->"
                        "decode handoff ships paged KV blocks entropy-coded "
                        "on the wire (docs/FLEET.md)")
            args.disaggregate_split = (n_pre, n_dec)

    # validate the backend against the registry BEFORE any expensive work, so
    # a typo fails with the list of choices, not a deep KeyError mid-load
    if args.decode_backend is not None and args.decode_backend != "auto":
        from repro.core.decode_backends import (available_backends,
                                                backend_names)
        if args.decode_backend not in backend_names():
            p.error(f"unknown decoder backend {args.decode_backend!r}; "
                    f"registered: {backend_names()}, "
                    f"available on this host: {available_backends()}")
        if args.decode_backend not in available_backends():
            p.error(f"decoder backend {args.decode_backend!r} is not "
                    f"available on this host; available: "
                    f"{available_backends()}")

    # same contract for the encode side: spec / codec names fail upfront
    # against the codec registry, not deep inside compress()
    from repro.core.codecs import codec_names
    from repro.core.quant import Granularity
    from repro.core.spec import CompressionSpec, spec_from_legacy
    if args.compress_spec is not None:
        try:
            # same PER_CHANNEL default as the --bits path: serving scale
            # shapes assume per-leading-index (s, z) on layer-stacked tensors
            compress_spec = CompressionSpec.parse(
                args.compress_spec,
                default_granularity=Granularity.PER_CHANNEL)
        except (ValueError, KeyError) as e:
            p.error(f"bad --compress-spec: {e}")
    else:
        if args.codec not in codec_names():
            p.error(f"unknown codec {args.codec!r}; "
                    f"registered: {codec_names()}")
        if not 1 <= args.bits <= 8:
            p.error(f"--bits must be in [1, 8], got {args.bits}")
        # PER_CHANNEL = one (s, z) per leading index — for layer-stacked
        # tensors that is exactly the paper's per-LAYER mixed scheme (Alg. 1
        # line 5), and scanned layers need the leading scale dim to match
        legacy_kw = {}
        if args.resident == "compressed":
            # per-layer decode parallelism is chunk/segment lanes, so the
            # storage-default 64k segments would lane-starve small layers;
            # finer segments keep every layer many-laned (SERVING.md
            # §"Tuning: segments, chunks, lanes").  An explicit
            # --compress-spec (defaults:segment_symbols=...) overrides.
            legacy_kw["segment_symbols"] = 4096
        compress_spec = spec_from_legacy(args.bits, Granularity.PER_CHANNEL,
                                         codec=args.codec, **legacy_kw)

    if args.production:
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch import dryrun
        d = dryrun.run_cell(args.arch, args.shape, multi_pod=args.multi_pod)
        return 0 if "error" not in d else 1

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import registry
    from repro.core.store import CompressedModel
    from repro.models import api
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    from repro.serving import engine

    if args.trace_out or args.trace_sync:
        obs_trace.enable(sync=args.trace_sync)

    cfg = registry.reduced(registry.get(args.arch))
    mod = api.build(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    host = {k: np.asarray(v, np.float32) for k, v in params.items()}

    t0 = time.perf_counter()
    cm = CompressedModel.compress(host, spec=compress_spec)
    t_comp = time.perf_counter() - t0
    st = cm.stats()
    print(f"compressed {st.param_count/1e6:.1f}M params: "
          f"{st.bits:.3g}b quant -> {st.effective_bits:.2f} effective bits "
          f"(entropy {st.entropy_bits:.2f}); "
          f"{st.reduction_vs_quant*100:.1f}% below quantized, "
          f"{st.reduction_vs_fp16*100:.1f}% below fp16  [{t_comp:.1f}s]")
    for g in st.groups:
        print(f"  [{g.table_id}] {g.param_count/1e6:.2f}M params: "
              f"{g.bits}b {g.codec} -> {g.effective_bits:.2f} achieved bits "
              f"(bound {g.entropy_bits:.2f}, {g.shannon_ratio:.3f}x)")

    mesh = rules = None
    if mesh_dims is not None:
        from repro.launch import mesh as mesh_lib
        try:
            mesh = mesh_lib.make_serve_mesh(*mesh_dims)
        except ValueError as e:
            p.error(str(e))
        rules = engine.serve_mesh_rules(cfg, mesh)

    load_metrics = {}
    load_kw = {}
    if args.chunk_symbols is not None:      # absent flag -> scheduler default
        load_kw["chunk_symbols"] = args.chunk_symbols
    if args.resident == "compressed":
        from repro.serving.resident import CompressedResidentWeights
        # absent --chunk-symbols: a tighter budget than the storage-default
        # 512k — the int32 scratch is part of the resident peak, and on the
        # reduced configs this launcher serves, the storage default alone
        # would push peak past the dense bf16 footprint (SERVING.md
        # §"Tuning: segments, chunks, lanes"; explicit flag overrides)
        load_kw.setdefault("chunk_symbols", 64 * 1024)
        t0 = time.perf_counter()
        serve_params = CompressedResidentWeights(
            cm, cfg, backend=args.decode_backend, fused=args.fused,
            fused_impl=args.fused_impl, **load_kw)
        load_metrics["decode_load_s"] = time.perf_counter() - t0
        load_metrics["decode_backend"] = serve_params.backend.name
        if args.fused:
            impls = sorted({fq.impl for slots in serve_params._fused_slots
                            for fq in slots.values()})
            print(f"  fused decode→dequant→matmul: "
                  f"{len(serve_params._fused)} tensors "
                  f"{sorted(serve_params._fused)} via {impls or ['-']}; "
                  f"{len(serve_params.fused_fallback)} fall back "
                  f"{serve_params.fused_fallback or ''}")
        rb = serve_params.resident_bytes()
        peak = serve_params.peak_resident_bytes()
        print(f"compressed-resident load [{load_metrics['decode_backend']}]: "
              f"{load_metrics['decode_load_s']:.2f}s (globals + carve-outs "
              f"decoded; {len(serve_params.plan)} layers stay entropy-coded)")
        print(f"  peak resident weights {peak/2**20:.2f} MiB "
              f"(payload {rb['payload']/2**20:.2f} + tables/qmeta "
              f"{(rb['tables']+rb['qmeta'])/2**20:.2f} + globals "
              f"{(rb['globals']+rb['stacked'])/2**20:.2f} + 2x layer slot "
              f"{rb['layer_slot']/2**20:.2f} + scratch "
              f"{rb['scratch']/2**20:.2f}) vs dense-resident QT "
              f"{serve_params.dense_resident_bytes()/2**20:.2f} MiB, "
              f"dense bf16 {serve_params.dense_bf16_bytes()/2**20:.2f} MiB")
    else:
        if mesh is not None:
            # default placer profile: per-tensor output-channel TP (exact
            # numerics); `rules` only steers cache/batch placement in engines
            load_kw["placer"] = engine.make_param_placer(cfg, mesh)
        serve_params = engine.load_params_from_compressed(
            cm, quantized=not args.no_quantized_serving,
            backend=args.decode_backend, stream=not args.no_stream,
            metrics=load_metrics, **load_kw)
        print(f"{'streamed' if not args.no_stream else 'monolithic'} decode + "
              f"load [{load_metrics['decode_backend']}]: "
              f"{load_metrics['decode_load_s']:.2f}s "
              f"(first weight resident after "
              f"{load_metrics['time_to_first_weight_s']*1e3:.0f}ms; "
              f"quantized residency: {not args.no_quantized_serving})")
    if mesh is not None:
        pb = engine.per_device_bytes(serve_params)
        lo, hi = min(pb.values()), max(pb.values())
        print(f"mesh {mesh_dims[0]}x{mesh_dims[1]} (data x model): weights "
              f"placed over {len(pb)} devices, "
              f"{lo/2**20:.1f}-{hi/2**20:.1f} MiB/device "
              f"({sum(pb.values())/2**20:.1f} MiB total)")

    # slot mode pads prompts to a prefill-chunk multiple, so its cache needs
    # that much headroom; the lockstep path keeps the exact footprint.
    # Prefix-shared traffic prepends a block-aligned system prompt, so the
    # prompt budget grows to cover prefix + at least one unique token.
    kv_prefix_len = 0
    if kv_spec is not None and kv_spec.sharing and args.traffic > 0:
        b = kv_spec.block_size
        kv_prefix_len = max(b, args.prompt_len // (2 * b) * b)
    prompt_budget = max(args.prompt_len, kv_prefix_len + 1)
    headroom = max(args.prefill_chunk, 0) if args.batch_slots > 0 else 0
    sc = engine.ServeConfig(max_len=prompt_budget + args.gen + headroom)
    rng = np.random.default_rng(0)

    # true serving peak is weights + KV — surface the KV term the weight
    # breakdowns above leave out (paged pool bytes print with the manager's
    # own numbers inside _serve_continuous)
    if kv_spec is None and hasattr(mod, "init_cache"):
        from repro.serving.kvcache import kv_cache_bytes
        kv_rows = args.batch_slots if args.batch_slots > 0 else args.batch
        kvb = kv_cache_bytes(cfg, kv_rows, sc.max_len)
        print(f"  KV cache {kvb/2**20:.2f} MiB resident "
              f"({kv_rows} x {sc.max_len} bf16 rows) — true serving peak = "
              f"weights + KV")

    if args.batch_slots > 0:
        rc = _serve_continuous(cfg, serve_params, sc, args, rng,
                               load_metrics, mesh=mesh, rules=rules,
                               kv_spec=kv_spec, kv_prefix_len=kv_prefix_len)
        _write_obs(args)
        return rc

    eng = engine.Engine(cfg, serve_params, sc, mesh=mesh, rules=rules,
                        resident=args.resident)
    if cfg.family == "encdec":
        prompt = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab,
                                               (args.batch, args.prompt_len)),
                                  jnp.int32),
            "src_embeds": jnp.asarray(rng.normal(
                0, 1, (args.batch, args.prompt_len, cfg.d_model)),
                jnp.bfloat16),
        }
    else:
        prompt = jnp.asarray(rng.integers(0, cfg.vocab,
                                          (args.batch, args.prompt_len)),
                             jnp.int32)
    out, metrics = eng.generate(prompt, args.gen, echo_metrics=True)
    ttft = load_metrics["decode_load_s"] + metrics["ttft_s"]
    print(f"generated {out.shape} tokens: prefill {metrics['prefill_s']:.2f}s, "
          f"decode {metrics['decode_s']:.2f}s "
          f"({metrics['decode_tok_per_s']:.1f} decode tok/s, "
          f"{metrics['e2e_tok_per_s']:.1f} e2e tok/s); "
          f"time-to-first-token incl. weight load: {ttft:.2f}s")
    _write_obs(args)
    return 0


def _write_obs(args):
    """Export the trace / metrics-registry snapshot the serve recorded."""
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    if args.trace_out or args.trace_sync:
        tracer = obs_trace.disable()
        if args.trace_out and tracer is not None:
            n = tracer.save(args.trace_out)
            print(f"trace: {n} events -> {args.trace_out} "
                  f"(open in ui.perfetto.dev; "
                  f"benchmarks/overlap_report.py analyzes it)")
    if args.metrics_out:
        n = obs_metrics.default_registry().write_jsonl(args.metrics_out)
        print(f"metrics: {n} rows -> {args.metrics_out}")


def _serve_continuous(cfg, serve_params, sc, args, rng, load_metrics,
                      mesh=None, rules=None, kv_spec=None, kv_prefix_len=0):
    """--batch-slots path: slot-batched serving of independent requests."""
    import numpy as np
    from repro.obs.metrics import percentile
    from repro.serving.batching import (ContinuousEngine, QueueFullError,
                                        poisson_trace, replay)

    if args.replicas > 0:
        return _serve_fleet(cfg, serve_params, sc, args, load_metrics,
                            kv_spec=kv_spec, kv_prefix_len=kv_prefix_len)

    ce = ContinuousEngine(cfg, serve_params, sc, n_slots=args.batch_slots,
                          max_queue=args.max_queue,
                          prefill_chunk=args.prefill_chunk,
                          mesh=mesh, rules=rules, resident=args.resident,
                          kv_spec=kv_spec)
    if kv_spec is not None:
        print(f"  paged KV [{kv_spec.describe()}]: pool "
              f"{ce.slots.pool_bytes/2**20:.2f} MiB resident "
              f"({ce.slots.n_blocks} x {kv_spec.block_size}-token blocks) — "
              f"true serving peak = weights + KV")
    n = args.traffic if args.traffic > 0 else args.batch
    shed = 0
    t0 = time.monotonic()
    if args.traffic > 0:        # Poisson replay: ragged prompts + gen lengths
        prefix_kw = {}
        if kv_spec is not None and kv_spec.sharing:
            # shared system prompts exercise prefix sharing: 2 distinct
            # block-aligned prefixes, ragged unique suffixes
            prefix_kw = dict(prefix_pool=2, prefix_len=kv_prefix_len)
        trace = poisson_trace(n, rate_per_s=100.0, prompt_max=args.prompt_len,
                              gen_max=args.gen, vocab=cfg.vocab, seed=0,
                              **prefix_kw)
        _, shed, _ = replay(ce, trace, shed_on_full=True)
    else:                       # one wave of uniform requests
        for _ in range(n):
            prompt = rng.integers(0, cfg.vocab, (args.prompt_len,)
                                  ).astype(np.int32)
            while True:
                try:
                    ce.submit(prompt, args.gen)
                    break
                except QueueFullError:   # drain some work, then re-offer
                    ce.step()
        ce.run()
    span = time.monotonic() - t0
    fin = ce.finished
    if not fin:
        print(f"continuous batching: no requests completed "
              f"({shed} shed by backpressure)")
        return 1
    toks = sum(len(r.output) for r in fin)
    lat = [r.latency_s for r in fin]
    ttft = [r.ttft_s for r in fin]
    wait = [r.queue_wait_s for r in fin]
    print(f"continuous batching [{args.batch_slots} slots, queue bound "
          f"{args.max_queue}]: {len(fin)}/{n} requests"
          + (f" ({shed} shed by backpressure)" if shed else "")
          + f", {toks} tok in "
          f"{span:.2f}s = {toks/max(span, 1e-9):.1f} tok/s aggregate")
    print(f"  ttft p50 {percentile(ttft, 50)*1e3:.0f}ms "
          f"(+{load_metrics['decode_load_s']:.2f}s "
          f"weight load) | latency p50 {percentile(lat, 50)*1e3:.0f}ms "
          f"p99 {percentile(lat, 99)*1e3:.0f}ms | "
          f"{ce.n_decode_steps} fused decode steps")
    print(f"  queue wait [admitted] p50 {percentile(wait, 50)*1e3:.0f}ms "
          f"p99 {percentile(wait, 99)*1e3:.0f}ms over {len(fin)} requests"
          + (f"; {shed} shed before admission" if shed else ""))
    if kv_spec is not None:
        st = ce.slots.stats()
        print(f"  paged KV: prefix hit rate {st['prefix_hit_rate']*100:.0f}% "
              f"({st['shared_hits']} hits / {st['shared_misses']} misses), "
              f"{st['blocks_free']}/{st['blocks_total']} blocks free, cold "
              f"tier {st['cold_bytes']/2**10:.1f} KiB "
              f"({st['cold_evictions']} evictions, {st['cold_restores']} "
              f"restores, {st['dropped_evictions']} dropped)")
    return 0


def _serve_fleet(cfg, serve_params, sc, args, load_metrics,
                 kv_spec=None, kv_prefix_len=0):
    """--replicas path: DP fleet of continuous engines behind the router.

    Lockstep drive (docs/FLEET.md §"Drive modes") — deterministic and
    per-request bit-identical to a single engine; the threaded mode is the
    fleet benchmark's job, not the launcher's.
    """
    from repro.obs.metrics import percentile
    from repro.serving.batching import poisson_trace, replay_fleet
    from repro.serving.fleet import FleetDriver

    split = args.disaggregate_split
    fd = FleetDriver(cfg, serve_params, sc, n_replicas=args.replicas,
                     policy=args.router, n_slots=args.batch_slots,
                     max_queue=args.max_queue,
                     prefill_chunk=args.prefill_chunk,
                     kv_spec=kv_spec, disaggregate=split)
    wb = fd.weight_bytes()
    topo = (f"{split[0]} prefill + {split[1]} decode, disaggregated"
            if split else f"{args.replicas}x data-parallel")
    print(f"fleet [{topo}; router {args.router}]: "
          f"{wb['copies']} weight cop{'y' if wb['copies'] == 1 else 'ies'} "
          f"resident ({wb['total_bytes']/2**20:.2f} MiB, "
          f"mode {wb['mode']})")
    n = args.traffic if args.traffic > 0 else args.batch
    prefix_kw = {}
    if kv_spec is not None and kv_spec.sharing:
        prefix_kw = dict(prefix_pool=2, prefix_len=kv_prefix_len)
    trace = poisson_trace(n, rate_per_s=100.0, prompt_max=args.prompt_len,
                          gen_max=args.gen, vocab=cfg.vocab, seed=0,
                          **prefix_kw)
    t0 = time.monotonic()
    _, shed, _ = replay_fleet(fd, trace, shed_on_full=True)
    span = time.monotonic() - t0
    fin = fd.finished
    n_shed = len(fd.shed)
    if not fin:
        print(f"fleet: no requests completed ({n_shed} shed)")
        return 1
    toks = sum(len(r.output) for r in fin)
    ttft = [r.ttft_s for r in fin]
    lat = [r.latency_s for r in fin]
    per_replica = ", ".join(
        f"r{h.idx}[{h.state.name.lower()}] "
        f"{sum(len(r.output) for r in h.engine.finished)} tok"
        for h in fd.replicas)
    print(f"fleet serve: {len(fin)}/{n} requests"
          + (f" ({n_shed} shed)" if n_shed else "")
          + f", {toks} tok in {span:.2f}s = "
          f"{toks/max(span, 1e-9):.1f} tok/s aggregate")
    print(f"  per replica: {per_replica}")
    print(f"  ttft p50 {percentile(ttft, 50)*1e3:.0f}ms "
          f"p99 {percentile(ttft, 99)*1e3:.0f}ms "
          f"(+{load_metrics['decode_load_s']:.2f}s weight load) | "
          f"latency p50 {percentile(lat, 50)*1e3:.0f}ms "
          f"p99 {percentile(lat, 99)*1e3:.0f}ms | {fd.n_steps} fleet steps")
    if fd.handoff is not None:
        print(f"  handoff: {fd.handoff.n_handoffs} prefill->decode "
              f"payloads, {fd.handoff.bytes_on_wire/2**10:.1f} KiB "
              f"entropy-coded on the wire")
    return 0


if __name__ == "__main__":
    sys.exit(main())
