import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract the roofline terms.

The two lines ABOVE this docstring must stay first: jax locks the device
count at first init, and the dry-run needs 512 placeholder CPU devices for
the 2x16x16 multi-pod mesh (smoke tests and benches keep the default 1).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --list

Per cell it prints ``compiled.memory_analysis()`` (proof the program fits
HBM) and ``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline), plus the
parsed per-collective wire bytes; ``--out`` appends machine-readable JSON
consumed by benchmarks/ and EXPERIMENTS.md.
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.launch import mesh as mesh_lib, roofline, specs


def _smallest_divisor(n: int) -> int:
    for d in (2, 3, 5, 7):
        if n % d == 0:
            return d
    return n            # prime stack depth: full unroll (none assigned)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             weights: str = "int8", verbose: bool = True,
             compile_only: bool = False, **kw):
    """Multi-compile protocol (methodology in DESIGN.md §4):

    1. *memory* compile — rolled scan (unroll=1), production microbatching:
       realistic buffer reuse; ``memory_analysis`` proves the cell fits HBM.
    2./3. *counting* compiles — unroll=1 and unroll=u2 with microbatches=1:
       XLA-CPU cost_analysis counts every while body exactly ONCE (verified
       by the linear f(u) series and its intercept == LM-head FLOPs), so the
       full-program FLOPs/collective-bytes follow by linear extrapolation
       ``full = f1 + (n_stack-1)·(f2-f1)/(u2-1)`` — exact for homogeneous
       layer stacks, which all ten architectures are by construction.
       For train cells the all-gather term is then scaled by the real
       microbatch count (FSDP re-gathers parameters every microbatch).
       For non-train cells the memory compile doubles as the unroll=1
       counting compile (identical program).

    ``compile_only`` (the multi-pod pass): only step 1 — proves lowering +
    compilation + memory on the 2x16x16 mesh; the roofline table itself is
    single-pod per the assignment.
    """
    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    if not shape.applicable(cfg):
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "skipped": "requires sub-quadratic attention (DESIGN.md §5)"}
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_stack = cfg.n_layers // cfg.attn_period if cfg.family == "hybrid" \
        else cfg.n_layers
    u2 = _smallest_divisor(n_stack)
    is_train = shape.kind == "train"

    t0 = time.perf_counter()
    try:
        # -- memory compile (real microbatching, rolled) --
        cell = specs.build_cell(cfg, shape, mesh, weights=weights, unroll=1,
                                **kw)
        compiled_mem = cell.lower().compile()
        t_mem = time.perf_counter() - t0

        if compile_only:
            ma = compiled_mem.memory_analysis()
            d = {
                "arch": arch, "shape": shape_name,
                "mesh": "x".join(str(s) for s in mesh.devices.shape),
                "kind": cell.meta["kind"], "compile_only": True,
                "memory_analysis": {
                    "argument_size": ma.argument_size_in_bytes,
                    "output_size": ma.output_size_in_bytes,
                    "temp_size": ma.temp_size_in_bytes,
                    "alias_size": ma.alias_size_in_bytes,
                },
                "lower_s": t_mem, "compile_s": 0.0,
                "meta": {k: v for k, v in cell.meta.items()
                         if isinstance(v, (str, int, float, bool))},
            }
            if verbose:
                print(f"== {arch} x {shape_name} on {d['mesh']} "
                      f"(compile-only) == args="
                      f"{ma.argument_size_in_bytes/2**30:.2f}GiB "
                      f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
                      f"[{t_mem:.0f}s]")
            return d

        # -- counting compiles --
        ckw = dict(kw)
        if is_train:
            ckw["microbatches"] = 1
        t1 = time.perf_counter()
        if is_train:
            cell1 = specs.build_cell(cfg, shape, mesh, weights=weights,
                                     unroll=1, **ckw)
            c1 = cell1.lower().compile()
        else:
            c1 = compiled_mem        # identical program: reuse
        cell2 = specs.build_cell(cfg, shape, mesh, weights=weights, unroll=u2,
                                 **ckw)
        c2 = cell2.lower().compile()
        t_count = time.perf_counter() - t1
    finally:
        specs.clear_contexts()

    r = roofline.analyze_extrapolated(
        cell, compiled_mem, c1, c2, n_stack=n_stack, u2=u2,
        gather_scale=(cell.meta.get("microbatches", 1) if is_train else 1))
    d = r.to_dict()
    d["lower_s"] = t_mem
    d["compile_s"] = t_count
    t_lower, t_compile = t_mem, t_count
    if verbose:
        ma = compiled_mem.memory_analysis()
        print(f"== {arch} x {shape_name} on {d['mesh']} "
              f"({d['kind']}, weights={cell.meta.get('weights')}) ==")
        print(f"  memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"out={ma.output_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
              f"alias={ma.alias_size_in_bytes/2**30:.2f}GiB")
        print(f"  cost_analysis: flops/chip={d['flops_per_chip']:.3e} "
              f"raw_bytes/chip={d['raw_bytes_per_chip']:.3e}")
        print(f"  analytic_bytes/chip={d['analytic_bytes_per_chip']:.3e} "
              f"wire_bytes/chip={d['wire_bytes_per_chip']:.3e}")
        cd = d["collective_detail"]
        print("  collectives:", {k: f"{v:.2e}" for k, v in cd.items()
                                 if k != "counts" and v},
              "counts:", {k: v for k, v in cd["counts"].items() if v})
        print(f"  terms: compute={d['compute_s']*1e3:.2f}ms "
              f"memory={d['memory_s']*1e3:.2f}ms "
              f"collective={d['collective_s']*1e3:.2f}ms "
              f"-> dominant={d['dominant']}")
        print(f"  model_flops_ratio={d['flops_ratio']:.3f} "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
    return d


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None, choices=list(SHAPES))
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--weights", default="int8",
                   choices=["bf16", "int8", "int4"])
    p.add_argument("--out", default=None)
    p.add_argument("--list", action="store_true")
    args = p.parse_args(argv)

    if args.list:
        for a, s, ok in specs.all_cells(registry.ARCHS):
            print(f"{a:24s} {s:12s} {'ok' if ok else 'SKIP (full attention @500k)'}")
        return 0

    cells = []
    if args.all:
        for a, s, ok in specs.all_cells(registry.ARCHS):
            cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    existing = []
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            existing = json.load(f)
    done = {(d.get("arch"), d.get("shape"), d.get("mesh"))
            for d in existing if "error" not in d}

    results = list(existing)
    failures = 0
    n_run = 0
    for mp in meshes:
        mesh_name = "2x16x16" if mp else "16x16"
        for a, s in cells:
            if (a, s, mesh_name) in done:
                continue
            n_run += 1
            try:
                # multi-pod pass proves lower+compile; roofline table is
                # single-pod (assignment), so skip the counting compiles
                results.append(run_cell(a, s, multi_pod=mp,
                                        weights=args.weights,
                                        compile_only=mp))
            except Exception as e:
                failures += 1
                traceback.print_exc()
                results.append({"arch": a, "shape": s, "mesh": mesh_name,
                                "error": str(e)})
            if args.out:          # checkpoint the sweep after every cell
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {len(results)} cells ({n_run} new) -> {args.out}")
    print(f"{n_run - failures}/{n_run} newly-run cells OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
