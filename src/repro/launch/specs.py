"""Cell builders: (architecture x input-shape x mesh) -> lowerable program.

``build_cell`` returns everything ``dryrun.py`` needs to
``jit(...).lower(...).compile()`` one roofline cell:

* the step function (train_step / prefill_step / serve_step per shape kind),
* ShapeDtypeStruct stand-ins for every argument (no allocation),
* in/out shardings derived from the logical-axis rules,
* donation indices and napkin metadata (microbatches, weight format).

Weight formats for serving cells: ``bf16`` (baseline), ``int8`` (EntroLLM
QT triples — uint8 symbols resident in HBM, dequant fused into matmuls),
``int4`` (QT4, nibbles packed along the last axis).  Training always uses the
schema dtypes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES
from repro.distributed import sharding as shd
from repro.distributed.ctx import ShardingHints, set_hints
from repro.models import api
from repro.models.layers import QT, QT4
from repro.models.moe import EPContext, set_ep_context
from repro.serving import engine
from repro.training import optimizer as opt, train_loop

SDS = jax.ShapeDtypeStruct

# Activation budget for choosing grad-accum microbatching (bytes per chip of
# saved scan carries; remat recomputes everything else).
ACT_BUDGET = 2 << 30
# Optimizer-moment format switches to EntroLLM-uint8 above this param count
# (AdamW fp32 moments for a 398B model cannot fit 256 x 16 GB HBM).
Q8_OPT_THRESHOLD = 100e9


@dataclasses.dataclass
class Cell:
    cfg: ArchConfig
    shape: ShapeConfig
    mesh: Mesh
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Any
    out_shardings: Any
    donate: Tuple[int, ...]
    meta: Dict[str, Any]

    def lower(self):
        jfn = jax.jit(self.fn, in_shardings=self.in_shardings,
                      out_shardings=self.out_shardings,
                      donate_argnums=self.donate)
        with jax.set_mesh(self.mesh):
            return jfn.lower(*self.args)


# ------------------------------------------------------------------ utilities

def _batch_ways(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n


def _quantize_pred(name: str, shape: Tuple[int, ...]) -> bool:
    """The shared shape-level precision policy (core.spec): struct planning
    here and serving residency must agree on which tensors are quantized."""
    from repro.core.spec import quantizable_shape
    return quantizable_shape(name, shape)


def param_structs(cfg: ArchConfig, mesh: Mesh, rules: shd.Rules,
                  weights: str = "bf16") -> Tuple[Dict, Dict]:
    """(ShapeDtypeStructs, NamedShardings) for the parameter pytree."""
    sch = api.build(cfg).schema(cfg)
    rep = NamedSharding(mesh, P())
    structs: Dict[str, Any] = {}
    shards: Dict[str, Any] = {}
    for name, spec in sch.items():
        ns = NamedSharding(mesh, shd.resolve_spec(spec.axes, spec.shape, rules,
                                                  mesh))
        if weights == "bf16" or not _quantize_pred(name, spec.shape):
            structs[name] = SDS(spec.shape, spec.dtype)
            shards[name] = ns
            continue
        # per-layer (axis-0 channel) scales: broadcastable against q
        sshape = (spec.shape[0],) + (1,) * (len(spec.shape) - 1)
        if weights == "int8":
            structs[name] = QT(SDS(spec.shape, jnp.uint8),
                               SDS(sshape, jnp.float32),
                               SDS(sshape, jnp.float32))
            shards[name] = QT(ns, rep, rep)
        elif weights == "int4":
            pshape = spec.shape[:-1] + (spec.shape[-1] // 2,)
            pns = NamedSharding(mesh, shd.resolve_spec(spec.axes, pshape,
                                                       rules, mesh))
            structs[name] = QT4(SDS(pshape, jnp.uint8),
                                SDS(sshape, jnp.float32),
                                SDS(sshape, jnp.float32))
            shards[name] = QT4(pns, rep, rep)
        else:
            raise ValueError(weights)
    return structs, shards


def _install_contexts(cfg: ArchConfig, mesh: Mesh, *, batch_sharded: bool,
                      kv_seq_axes: Tuple[str, ...] = (),
                      feature_axes: Tuple[str, ...] = ()) -> None:
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if cfg.moe:
        set_ep_context(EPContext(mesh=mesh, model_axis="model",
                                 data_axes=data_axes,
                                 batch_sharded=batch_sharded))
    else:
        set_ep_context(None)
    set_hints(ShardingHints(
        mesh=mesh,
        batch_axes=data_axes if batch_sharded else (),
        model_axis="model",
        kv_seq_axes=kv_seq_axes,
        feature_axes=feature_axes,
        # SP carries help dense stacks (saved-carry bytes / |model|) but
        # measurably inflate the hybrid family's backward transients on the
        # CPU analysis backend (EXPERIMENTS.md §Perf) — gate per family.
        seq_sp=cfg.family != "hybrid"))


# KV-head-aware rule adjustment now lives with the other rule machinery in
# distributed/sharding.py (the serving engines need it without importing the
# launch layer); these aliases keep the cell builders reading as before.
_kv_divisible = shd.kv_divisible
_arch_rules = shd.arch_rules


def clear_contexts() -> None:
    set_ep_context(None)
    set_hints(None)


def _serve_rules(cfg: ArchConfig, mesh: Mesh, *, long_context: bool
                 ) -> Tuple[shd.Rules, Tuple[str, ...]]:
    """Arch-aware serving rules: shard KV-cache heads over model when they
    divide it, otherwise shard the cache sequence axis over model (the
    flash-decoding layout; GSPMD emits the partial-softmax psum).

    Returns (rules, kv_seq_axes hint for activation constraints).
    """
    table = dict(shd.serve_rules(mesh, long_context=long_context).table)
    if long_context:
        kv_seq = tuple(a for a in ("pod", "data") if a in mesh.shape)
        table["kv"] = "model" if _kv_divisible(cfg, mesh) else None
        table["kv_seq"] = kv_seq
        return shd.Rules(table), kv_seq
    if _kv_divisible(cfg, mesh):
        table["kv"] = "model"
        table["kv_seq"] = ()
        return shd.Rules(table), ()
    table["kv"] = None
    table["kv_seq"] = "model"
    return shd.Rules(table), ("model",)


def _batch_struct(cfg: ArchConfig, B: int, S: int, *, train: bool) -> Dict:
    toks = SDS((B, S + 1 if train else S), jnp.int32)
    if cfg.family == "encdec":
        return {"tokens": toks, "src_embeds": SDS((B, S, cfg.d_model),
                                                  jnp.bfloat16)}
    return {"tokens": toks}


def _batch_shardings(batch: Dict, mesh: Mesh, rules: shd.Rules) -> Dict:
    return {k: shd.batch_sharding(mesh, rules, v.shape)
            for k, v in batch.items()}


def pick_microbatches(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> int:
    """Smallest grad-accum split whose saved scan carries fit ACT_BUDGET."""
    ways = _batch_ways(mesh)
    B_loc = max(1, shape.global_batch // ways)
    D = cfg.d_model
    L = cfg.n_layers
    carry = L * shape.seq_len * D * 2            # bytes per local batch row
    target = max(1, int(ACT_BUDGET // max(carry, 1)))
    mb = 1
    while B_loc // mb > target and mb < B_loc:
        mb *= 2
    # shard_map needs every microbatch to cover the batch mesh axes
    return min(mb, max(shape.global_batch // ways, 1))


# ---------------------------------------------------------------- cell builds

def build_train_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, *,
                     unroll: Optional[int] = None,
                     microbatches: Optional[int] = None,
                     grad_compress: bool = False,
                     q8_gather: int = 0) -> Cell:
    rules = _arch_rules(cfg, mesh, shd.train_rules(mesh))
    orules = _arch_rules(cfg, mesh, shd.opt_state_rules(mesh))
    _install_contexts(cfg, mesh, batch_sharded=True)

    q8 = cfg.param_count() >= Q8_OPT_THRESHOLD
    mb = microbatches or pick_microbatches(cfg, shape, mesh)
    n_stack = cfg.n_layers // cfg.attn_period if cfg.family == "hybrid" \
        else cfg.n_layers
    tc = train_loop.TrainConfig(
        opt=opt.AdamWConfig(quantized_state=q8),
        grad_accum_dtype="bf16" if q8 else "f32",
        q8_gather=q8_gather,
        microbatches=mb, remat=True,
        unroll=(n_stack if unroll is None else unroll),
        q_block=1024 if shape.seq_len > 8192 else 0,
        grad_compress=grad_compress)

    params, pshard = param_structs(cfg, mesh, rules, "bf16")
    ostate = jax.eval_shape(partial(opt.init_state, tc.opt), params)
    oshard_params = shd.param_shardings(cfg, mesh, orules)
    oshard = opt.state_shardings(
        tc.opt, {n: s.shape for n, s in params.items()}, oshard_params)
    batch = _batch_struct(cfg, shape.global_batch, shape.seq_len, train=True)
    bshard = _batch_shardings(batch, mesh, rules)

    rep = NamedSharding(mesh, P())
    fn = train_loop.make_train_step(cfg, tc)
    return Cell(
        cfg=cfg, shape=shape, mesh=mesh, fn=fn,
        args=(params, ostate, batch),
        in_shardings=(pshard, oshard, bshard),
        out_shardings=(pshard, oshard,
                       {"loss": rep, "grad_norm": rep, "lr": rep}),
        donate=(0, 1),
        meta={"kind": "train", "microbatches": mb, "q8_opt": q8,
              "weights": "bf16"},
    )


def build_prefill_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, *,
                       weights: str = "int8",
                       unroll: Optional[int] = None) -> Cell:
    rules, _ = _serve_rules(cfg, mesh, long_context=False)
    rules = _arch_rules(cfg, mesh, rules)
    _install_contexts(cfg, mesh, batch_sharded=True)
    B, S = shape.global_batch, shape.seq_len
    n_stack = cfg.n_layers // cfg.attn_period if cfg.family == "hybrid" \
        else cfg.n_layers

    sc = engine.ServeConfig(max_len=S, unroll=n_stack if unroll is None else unroll,
                            q_block=1024 if S > 8192 else 0)
    params, pshard = param_structs(cfg, mesh, rules, weights)

    mod = api.build(cfg)
    if cfg.family == "encdec":
        prompt = _batch_struct(cfg, B, S, train=False)
    else:
        prompt = SDS((B, S), jnp.int32)

    def prefill_step(p, prompt):
        return mod.prefill(cfg, p, prompt, max_len=S, unroll=sc.unroll,
                           q_block=sc.q_block)

    cache_shapes = jax.eval_shape(lambda: mod.init_cache(cfg, B, S))
    cshard = shd.tree_shardings(
        mod.cache_specs(cfg), {k: v.shape for k, v in cache_shapes.items()},
        rules, mesh)
    logits_shard = NamedSharding(
        mesh, shd.resolve_spec(("batch", None, "vocab"),
                               (B, 1, cfg.padded_vocab()), rules, mesh))
    pr_shard = (_batch_shardings(prompt, mesh, rules)
                if isinstance(prompt, dict)
                else shd.batch_sharding(mesh, rules, prompt.shape))
    return Cell(
        cfg=cfg, shape=shape, mesh=mesh, fn=prefill_step,
        args=(params, prompt),
        in_shardings=(pshard, pr_shard),
        out_shardings=(logits_shard, cshard),
        donate=(),
        meta={"kind": "prefill", "weights": weights},
    )


def build_decode_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, *,
                      weights: str = "int8", serve_mode: str = "fsdp",
                      kv_bits: int = 16,
                      unroll: Optional[int] = None) -> Cell:
    """Decode-step cell.

    ``serve_mode``:
      * ``fsdp`` — baseline: activations batch-sharded over data; weights
        (embed x model)-sharded are all-gathered per layer.  Faithful to the
        training layout but moves WEIGHT bytes for a single token's compute.
      * ``stationary`` — beyond-paper hillclimb: weights never move.  The
        token activations replicate over the data axis (they are KiB-scale at
        decode), projections contract against the 2-D-sharded weights with a
        small psum, and only the KV cache stays batch-/sequence-sharded.
        Moves ACTIVATION bytes instead of weight bytes — the classic
        inference inversion of FSDP.
    """
    long_context = shape.name == "long_500k"
    rules, kv_seq_axes = _serve_rules(cfg, mesh, long_context=long_context)
    rules = _arch_rules(cfg, mesh, rules)
    B, S = shape.global_batch, shape.seq_len
    stationary = serve_mode == "stationary"
    batch_sharded = (not stationary) and B % _batch_ways(mesh) == 0
    if stationary:
        # weight-stationary: expert FFN hidden dim carries the data axes (x
        # is replicated there); dense weights keep embed -> data for the
        # feature-sharded partial-dot path
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        rules = shd.Rules({**rules.table, "expert_embed": None,
                           "expert_mlp": data_axes})
    # io rules: token/logits/x-path sharding (batch dropped when stationary)
    io_rules = rules if not stationary else shd.Rules(
        {**rules.table, "batch": ()})
    _install_contexts(
        cfg, mesh, batch_sharded=batch_sharded, kv_seq_axes=kv_seq_axes,
        feature_axes=(tuple(a for a in ("pod", "data") if a in mesh.shape)
                      if stationary else ()))
    n_stack = cfg.n_layers // cfg.attn_period if cfg.family == "hybrid" \
        else cfg.n_layers

    sc = engine.ServeConfig(max_len=S,
                            unroll=n_stack if unroll is None else unroll)
    params, pshard = param_structs(cfg, mesh, rules, weights)
    mod = api.build(cfg)

    ckw = {"kv_bits": kv_bits} if (kv_bits != 16
                                    and cfg.family == "dense") else {}
    cache_shapes = jax.eval_shape(lambda: mod.init_cache(cfg, B, S, **ckw))
    cache = jax.tree.map(lambda s: SDS(s.shape, s.dtype), cache_shapes)
    cshard = shd.tree_shardings(
        mod.cache_specs(cfg, **ckw),
        {k: v.shape for k, v in cache_shapes.items()}, rules, mesh)

    token = SDS((B, 1), jnp.int32)
    pos = SDS((), jnp.int32)
    rep = NamedSharding(mesh, P())
    tok_shard = shd.batch_sharding(mesh, io_rules, (B, 1)) \
        if batch_sharded else rep
    logits_shard = NamedSharding(
        mesh, shd.resolve_spec(("batch", None, "vocab"),
                               (B, 1, cfg.padded_vocab()), io_rules, mesh))

    fn = engine.make_serve_step(cfg, sc)
    return Cell(
        cfg=cfg, shape=shape, mesh=mesh, fn=fn,
        args=(params, token, cache, pos),
        in_shardings=(pshard, tok_shard, cshard, rep),
        out_shardings=(logits_shard, cshard),
        donate=(2,),
        meta={"kind": "decode", "weights": weights, "kv_bits": kv_bits,
              "serve_mode": serve_mode, "long_context": long_context},
    )


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, *,
               weights: str = "int8", **kw) -> Cell:
    if shape.kind == "train":
        kw.pop("weights", None)
        return build_train_cell(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_cell(cfg, shape, mesh, weights=weights, **kw)
    return build_decode_cell(cfg, shape, mesh, weights=weights, **kw)


def all_cells(archs: Dict[str, ArchConfig]) -> list:
    """The 40 assigned cells as (arch_name, shape_name, applicable)."""
    out = []
    for a, cfg in archs.items():
        for s, sc in SHAPES.items():
            out.append((a, s, sc.applicable(cfg)))
    return out
