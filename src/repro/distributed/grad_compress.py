"""Gradient compression for the inter-pod (DCI-limited) all-reduce.

EntroLLM-themed: the same uint8 mixed symmetric/asymmetric grid the paper
applies to weights, applied to the gradient wire format, with **error
feedback** (the local quantization residual is added back into the next
step's gradient) so compression error does not accumulate as bias — the
standard EF-SGD construction.

Under pjit, the quantize->dequantize pair lowers around the all-reduce: XLA
performs the sum at uint8-dequantized f32 values, but the *wire* bytes of the
inter-pod collective are bounded by the uint8 payload when the collective is
split per the hierarchical schedule in DESIGN.md §6 (reduce-scatter intra-pod
in f32 over ICI, all-reduce of the scattered shards inter-pod at uint8 over
DCI, all-gather intra-pod).  On this CPU container we implement + test the
numerics (EF convergence, bounded error); the wire-byte claim is recorded in
the roofline as collective_bytes x (1/4) for the pod axis when enabled.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

_BLOCK = 256


def _q8_blockwise(g: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-block symmetric/asymmetric uint8 quantization of one gradient."""
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % _BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    xb = flat.reshape(-1, _BLOCK)
    lo = xb.min(axis=1, keepdims=True)
    hi = xb.max(axis=1, keepdims=True)
    single = lo * hi >= 0.0
    absmax = jnp.where(jnp.abs(hi) >= jnp.abs(lo), hi, lo)
    scale = jnp.where(single,
                      jnp.where(absmax == 0.0, 1.0, absmax / 255.0),
                      jnp.where(hi == lo, 1.0, (hi - lo) / 255.0))
    zero = jnp.where(single, 0.0, lo)
    q = jnp.clip(jnp.round((xb - zero) / scale), 0.0, 255.0).astype(jnp.uint8)
    return q, scale, zero


def _dq8_blockwise(q: jax.Array, scale: jax.Array, zero: jax.Array,
                   shape) -> jax.Array:
    x = q.astype(jnp.float32) * scale + zero
    n = 1
    for d in shape:
        n *= int(d)
    return x.reshape(-1)[:n].reshape(shape)


def compress_decompress(grads: PyTree) -> PyTree:
    """Quantize-dequantize every gradient leaf (wire-format simulation)."""
    def qdq(g):
        if g.size < _BLOCK:            # tiny leaves ride along uncompressed
            return g
        q, s, z = _q8_blockwise(g)
        return _dq8_blockwise(q, s, z, g.shape).astype(g.dtype)
    return jax.tree.map(qdq, grads)


def ef_compress(grads: PyTree, residual: Optional[PyTree]) -> Tuple[PyTree, PyTree]:
    """Error-feedback compression: returns (compressed grads, new residual).

    new_residual = (g + residual) - Q(g + residual)
    """
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, r):
        if g.size < _BLOCK:
            return g, jnp.zeros_like(r)
        corrected = g.astype(jnp.float32) + r
        q, s, z = _q8_blockwise(corrected)
        dq = _dq8_blockwise(q, s, z, g.shape)
        return dq.astype(g.dtype), corrected - dq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_res = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return comp, new_res


def wire_bytes(grads: PyTree, *, compressed: bool) -> int:
    """Bytes a gradient all-reduce moves per hop (for the roofline table)."""
    total = 0
    for g in jax.tree.leaves(grads):
        n = int(g.size)
        if compressed and n >= _BLOCK:
            nb = -(-n // _BLOCK)
            total += n + nb * 8          # uint8 payload + scale/zero per block
        else:
            total += n * 4
    return total
