from . import sharding
