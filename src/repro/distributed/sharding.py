"""Logical-axis sharding rules → concrete NamedShardings.

Models annotate every parameter/cache/batch dimension with a *logical* axis
name ("embed", "heads", "expert", "batch", ...).  This module maps logical
axes onto mesh axes per a :class:`Rules` profile, with two safety valves:

* **divisibility** — a logical axis only binds to a mesh-axis tuple whose size
  divides the dimension; otherwise the tuple is shortened (prefix) until it
  divides, possibly to unsharded.  E.g. glm4's 2 KV heads silently stay
  replicated on a 16-way model axis instead of erroring.
* **no-duplicate mesh axes** — a mesh axis may appear once per spec; later
  logical axes skip mesh axes already claimed by earlier dims.

Profiles (DESIGN.md §6):

* ``train_rules``  — FSDP over data (+ ZeRO over pod×data for optimizer
  state), TP over model for heads/mlp/vocab, EP over model for experts.
  Parameters are replicated across pods (hierarchical DP: only the gradient
  all-reduce crosses the pod axis, matching ICI-rich/DCI-poor topology).
* ``serve_rules`` — weights TP over model + FSDP over data (weights are
  all-gathered per layer; for decode they stream from HBM), KV cache batch
  over data, or sequence over data for the single-request long-context cell
  (flash-decoding combine).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxesSpec = Union[None, str, Tuple[str, ...]]


def _as_tuple(a: AxesSpec) -> Tuple[str, ...]:
    if a is None:
        return ()
    if isinstance(a, str):
        return (a,)
    return tuple(a)


@dataclasses.dataclass(frozen=True)
class Rules:
    """Mapping logical axis name -> preferred mesh axes (in priority order)."""

    table: Dict[str, AxesSpec]

    def lookup(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        return _as_tuple(self.table.get(logical))


def resolve_spec(axes: Sequence[Optional[str]], shape: Sequence[int],
                 rules: Rules, mesh: Mesh) -> P:
    """Build a PartitionSpec for one tensor, honoring divisibility + uniqueness."""
    assert len(axes) == len(shape), (axes, shape)
    used: set = set()
    entries = []
    for logical, dim in zip(axes, shape):
        cand = [a for a in rules.lookup(logical)
                if a not in used and a in mesh.shape]
        # shorten from the right until the product divides the dim
        while cand and (dim % int(np.prod([mesh.shape[a] for a in cand])) != 0):
            cand.pop()
        if cand:
            used.update(cand)
            entries.append(tuple(cand) if len(cand) > 1 else cand[0])
        else:
            entries.append(None)
    # trim trailing Nones (canonical form)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def tree_shardings(schema_axes: Dict[str, Sequence[Optional[str]]],
                   schema_shapes: Dict[str, Sequence[int]],
                   rules: Rules, mesh: Mesh) -> Dict[str, NamedSharding]:
    return {
        name: NamedSharding(mesh, resolve_spec(schema_axes[name],
                                               schema_shapes[name], rules, mesh))
        for name in schema_axes
    }


# ------------------------------------------------------------------- profiles

def _axes(mesh: Mesh, *names: str) -> Tuple[str, ...]:
    return tuple(n for n in names if n in mesh.shape)


def train_rules(mesh: Mesh) -> Rules:
    """FSDP(data) x TP(model) x EP(model); batch over (pod, data)."""
    return Rules({
        "vocab": "model",
        "embed": "data",                      # FSDP: gathered per layer under scan
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "expert": "model",
        "expert_embed": "data",               # FSDP over the expert D rows
        "expert_mlp": None,
        "layers": None,
        "batch": _axes(mesh, "pod", "data"),
        "seq": None,
    })


def opt_state_rules(mesh: Mesh) -> Rules:
    """ZeRO: optimizer moments shard over pod x data on top of the TP axes."""
    r = dict(train_rules(mesh).table)
    r["embed"] = _axes(mesh, "pod", "data")
    r["expert_embed"] = _axes(mesh, "pod", "data")
    return Rules(r)


def serve_rules(mesh: Mesh, *, long_context: bool = False) -> Rules:
    """Weights like training; KV cache batch-sharded, or sequence-sharded for
    the single-request long-context cell (flash-decoding combine over data)."""
    return Rules({
        "vocab": "model",
        "embed": "data",
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "expert": "model",
        "expert_embed": "data",
        "expert_mlp": None,
        "layers": None,
        "batch": () if long_context else _axes(mesh, "pod", "data"),
        # resolution rule for the slotted cache layout (cache_specs(cfg,
        # layout="slot")): slots resolve like lockstep batch rows.  The
        # single-host ContinuousEngine does not install shardings yet — this
        # rule exists so the slotted layout resolves when serving goes
        # multi-device.
        "slot": () if long_context else _axes(mesh, "pod", "data"),
        "kv_seq": _axes(mesh, "pod", "data") if long_context else (),
        "seq": None,
    })


# ------------------------------------------------------------- tensor helpers

def param_shardings(cfg, mesh: Mesh, rules: Rules) -> Dict[str, NamedSharding]:
    from repro.models import api
    sch = api.build(cfg).schema(cfg)
    return tree_shardings({n: s.axes for n, s in sch.items()},
                          {n: s.shape for n, s in sch.items()}, rules, mesh)


def cache_shardings(cfg, mesh: Mesh, rules: Rules, batch: int, max_len: int
                    ) -> Dict[str, NamedSharding]:
    from repro.models import api
    mod = api.build(cfg)
    specs = mod.cache_specs(cfg)
    shapes = jax.eval_shape(lambda: mod.init_cache(cfg, batch, max_len))
    return tree_shardings(specs, {k: shapes[k].shape for k in specs}, rules, mesh)


def batch_sharding(mesh: Mesh, rules: Rules, shape: Sequence[int]) -> NamedSharding:
    """Shard dim 0 (batch) of a (B, ...) input, honoring divisibility of B."""
    axes = ["batch"] + [None] * (len(shape) - 1)
    return NamedSharding(mesh, resolve_spec(axes, shape, rules, mesh))
