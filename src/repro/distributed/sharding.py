"""Logical-axis sharding rules → concrete NamedShardings.

Models annotate every parameter/cache/batch dimension with a *logical* axis
name ("embed", "heads", "expert", "batch", ...).  This module maps logical
axes onto mesh axes per a :class:`Rules` profile, with two safety valves:

* **divisibility** — a logical axis only binds to a mesh-axis tuple whose size
  divides the dimension; otherwise the tuple is shortened (prefix) until it
  divides, possibly to unsharded.  E.g. glm4's 2 KV heads silently stay
  replicated on a 16-way model axis instead of erroring.
* **no-duplicate mesh axes** — a mesh axis may appear once per spec; later
  logical axes skip mesh axes already claimed by earlier dims.

Profiles (DESIGN.md §6):

* ``train_rules``  — FSDP over data (+ ZeRO over pod×data for optimizer
  state), TP over model for heads/mlp/vocab, EP over model for experts.
  Parameters are replicated across pods (hierarchical DP: only the gradient
  all-reduce crosses the pod axis, matching ICI-rich/DCI-poor topology).
* ``serve_rules`` — weights TP over model + FSDP over data (weights are
  all-gathered per layer; for decode they stream from HBM), KV cache batch
  over data, or sequence over data for the single-request long-context cell
  (flash-decoding combine).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxesSpec = Union[None, str, Tuple[str, ...]]


def _as_tuple(a: AxesSpec) -> Tuple[str, ...]:
    if a is None:
        return ()
    if isinstance(a, str):
        return (a,)
    return tuple(a)


@dataclasses.dataclass(frozen=True)
class Rules:
    """Mapping logical axis name -> preferred mesh axes (in priority order)."""

    table: Dict[str, AxesSpec]

    def lookup(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        return _as_tuple(self.table.get(logical))


def _mesh_prod(mesh: Mesh, axes: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def resolve_spec(axes: Sequence[Optional[str]], shape: Sequence[int],
                 rules: Rules, mesh: Mesh) -> P:
    """Build a PartitionSpec for one tensor, honoring divisibility + uniqueness.

    Tuple candidates keep prefix semantics: shortening drops mesh axes from
    the RIGHT until the surviving product divides the dim.  The dedup filter
    against ``used`` is re-applied after every shortening step (not just once
    upfront): a rule table may name the same mesh axis twice — within one
    tuple, or in tuples claimed by two dims of the same tensor (e.g. a
    ``("pod", "data")`` batch rule colliding with a ``"data"`` embed rule) —
    and a surviving prefix must never resurrect an axis an earlier dim
    already claimed, which would emit an illegal duplicate-axis
    PartitionSpec.
    """
    assert len(axes) == len(shape), (axes, shape)
    used: set = set()
    entries = []
    for logical, dim in zip(axes, shape):
        # dedup WITHIN the candidate tuple (first occurrence wins) and drop
        # axes this mesh does not have
        cand, seen = [], set()
        for a in rules.lookup(logical):
            if a in seen or a not in mesh.shape:
                continue
            seen.add(a)
            cand.append(a)
        # interleave the `used` filter with prefix shortening: re-check the
        # surviving prefix after every pop so cross-dim claims stay disjoint
        while True:
            cand = [a for a in cand if a not in used]
            if not cand or dim % _mesh_prod(mesh, cand) == 0:
                break
            cand.pop()
        if cand:
            used.update(cand)
            entries.append(tuple(cand) if len(cand) > 1 else cand[0])
        else:
            entries.append(None)
    flat = [a for e in entries if e is not None
            for a in (e if isinstance(e, tuple) else (e,))]
    assert len(flat) == len(set(flat)), \
        f"duplicate mesh axes in resolved spec {entries} for {axes}/{shape}"
    # trim trailing Nones (canonical form)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def tree_shardings(schema_axes: Dict[str, Sequence[Optional[str]]],
                   schema_shapes: Dict[str, Sequence[int]],
                   rules: Rules, mesh: Mesh) -> Dict[str, NamedSharding]:
    return {
        name: NamedSharding(mesh, resolve_spec(schema_axes[name],
                                               schema_shapes[name], rules, mesh))
        for name in schema_axes
    }


# -------------------------------------------------------- quantized (QT) leaves

def follower_spec(qspec: P, q_shape: Sequence[int],
                  follower_shape: Sequence[int], mesh: Mesh) -> P:
    """Sharding for a QT ``scale``/``zero`` that FOLLOWS the resolved ``q``
    spec: a follower dim inherits q's mesh axes on that dim iff the sizes
    line up (size-1 broadcast dims replicate; a per-group dim whose group
    count the axis product does not divide replicates — the per-group
    granularity divisibility check).

    Consistency invariant: wherever the follower is sharded, it is sharded by
    exactly the mesh axes sharding the same dim of ``q`` — each device holds
    the (s, z) rows of precisely its own output-channel slice, so the fused
    dequant never reads remote quantization metadata.
    """
    entries = list(qspec) + [None] * (len(q_shape) - len(qspec))
    out = []
    for dim, qdim, e in zip(follower_shape, q_shape, entries):
        if e is None or dim == 1:
            out.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        n = _mesh_prod(mesh, axes)
        # dim == qdim: per-channel metadata, always divisible when q is;
        # dim != qdim: per-group metadata — keep the axes only if the group
        # count still divides (each shard must own whole groups)
        out.append(e if dim % n == 0 else None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def leaf_shardings(axes: Sequence[Optional[str]], value: Any, rules: Rules,
                   mesh: Mesh):
    """NamedSharding (pytree) for one parameter leaf.

    Plain arrays resolve through :func:`resolve_spec`.  Quantized triples
    (:class:`~repro.models.layers.QT` / ``QT4``) resolve the ``q`` symbols
    against the schema axes (QT4's nibble-packed last dim is checked for
    divisibility at its packed size) and derive ``scale``/``zero`` shardings
    with :func:`follower_spec`, so the whole triple lands consistently
    sharded along the output-channel axis.
    """
    from repro.models.layers import QT, QT4, QTG
    if isinstance(value, (QT, QT4, QTG)):
        q_shape = tuple(value.q.shape)
        qspec = resolve_spec(axes, q_shape, rules, mesh)
        qns = NamedSharding(mesh, qspec)
        sns = NamedSharding(mesh, follower_spec(qspec, q_shape,
                                                tuple(value.scale.shape), mesh))
        zns = NamedSharding(mesh, follower_spec(qspec, q_shape,
                                                tuple(value.zero.shape), mesh))
        if isinstance(value, QTG):
            mns = NamedSharding(mesh, resolve_spec(
                axes, tuple(value.master.shape), rules, mesh))
            return QTG(qns, sns, zns, mns)
        return type(value)(qns, sns, zns)
    return NamedSharding(mesh, resolve_spec(axes, tuple(value.shape),
                                            rules, mesh))




# ------------------------------------------------------------------- profiles

def _axes(mesh: Mesh, *names: str) -> Tuple[str, ...]:
    return tuple(n for n in names if n in mesh.shape)


def train_rules(mesh: Mesh) -> Rules:
    """FSDP(data) x TP(model) x EP(model); batch over (pod, data)."""
    return Rules({
        "vocab": "model",
        "embed": "data",                      # FSDP: gathered per layer under scan
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "expert": "model",
        "expert_embed": "data",               # FSDP over the expert D rows
        "expert_mlp": None,
        "layers": None,
        "batch": _axes(mesh, "pod", "data"),
        "seq": None,
    })


def opt_state_rules(mesh: Mesh) -> Rules:
    """ZeRO: optimizer moments shard over pod x data on top of the TP axes."""
    r = dict(train_rules(mesh).table)
    r["embed"] = _axes(mesh, "pod", "data")
    r["expert_embed"] = _axes(mesh, "pod", "data")
    return Rules(r)


def serve_rules(mesh: Mesh, *, long_context: bool = False) -> Rules:
    """Weights like training; KV cache batch-sharded, or sequence-sharded for
    the single-request long-context cell (flash-decoding combine over data)."""
    return Rules({
        "vocab": "model",
        "embed": "data",
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "expert": "model",
        "expert_embed": "data",
        "expert_mlp": None,
        "layers": None,
        "batch": () if long_context else _axes(mesh, "pod", "data"),
        # resolution rule for the slotted cache layout (cache_specs(cfg,
        # layout="slot")): slots resolve like lockstep batch rows.  The
        # single-host ContinuousEngine does not install shardings yet — this
        # rule exists so the slotted layout resolves when serving goes
        # multi-device.
        "slot": () if long_context else _axes(mesh, "pod", "data"),
        "kv_seq": _axes(mesh, "pod", "data") if long_context else (),
        "seq": None,
    })


def serve_tp_table(cfg, mesh: Mesh, axes: Sequence[Optional[str]]) -> Rules:
    """Exact serving TP: the rule table for ONE weight tensor that shards
    only its output-channel axis (the last dim) over model.

    Contraction dims stay whole everywhere, and the model layers constrain
    their reduction inputs feature-replicated under ``exact_tp`` hints
    (:func:`repro.distributed.ctx.constrain_replicated`), so the sharded
    compute never psums a floating-point reduction — greedy decode is
    bit-identical to the single-device engine.  Specifics:

    * the embedding table / lm_head shard over ``vocab`` (output channels of
      the logits matmul; token gathers over sharded rows are exact);
    * ``heads`` / ``kv`` output columns shard only when whole heads divide
      the model axis — a split inside a head resurfaces as a sharded
      head_dim contraction after the (B, S, H*hd) -> (B, S, H, hd) reshape;
    * everything else (norms, 1-D params, contraction-dim axes) replicates.
    """
    table: Dict[str, AxesSpec] = {a: None for a in axes if a}
    if "vocab" in axes:
        table["vocab"] = "model"
        return Rules(table)
    out = axes[-1] if len(axes) >= 2 else None
    if out is not None:
        m = mesh.shape.get("model", 1)
        ok = {"heads": bool(cfg.n_heads) and cfg.n_heads % m == 0,
              "kv": kv_divisible(cfg, mesh)}.get(out, True)
        if ok:
            table[out] = "model"
    return Rules(table)


def kv_divisible(cfg, mesh: Mesh) -> bool:
    m = mesh.shape.get("model", 1)
    return bool(cfg.n_kv_heads) and cfg.n_kv_heads % m == 0


def arch_rules(cfg, mesh: Mesh, base: Rules) -> Rules:
    """KV weight columns shard over model only when whole KV heads divide the
    axis; otherwise wk/wv stay replicated over model (Megatron GQA practice —
    splitting inside a head produces degenerate reshape shardings)."""
    table = dict(base.table)
    table["kv"] = "model" if kv_divisible(cfg, mesh) else None
    return Rules(table)


# ------------------------------------------------------------- tensor helpers

def param_shardings(cfg, mesh: Mesh, rules: Rules) -> Dict[str, NamedSharding]:
    from repro.models import api
    sch = api.build(cfg).schema(cfg)
    return tree_shardings({n: s.axes for n, s in sch.items()},
                          {n: s.shape for n, s in sch.items()}, rules, mesh)


def cache_shardings(cfg, mesh: Mesh, rules: Rules, batch: int, max_len: int,
                    **cache_kw) -> Dict[str, NamedSharding]:
    """Shardings for the KV-cache pytree.  ``cache_kw`` forwards family
    cache options (``layout="slot"`` for the continuous-batching pool,
    ``kv_bits=8`` for the int8 cache) through :func:`api.cache_specs`, which
    drops kwargs a family does not understand."""
    import inspect
    from repro.models import api
    mod = api.build(cfg)
    specs = api.cache_specs(cfg, **cache_kw)
    accepted = inspect.signature(mod.init_cache).parameters
    init_kw = {k: v for k, v in cache_kw.items() if k in accepted}
    shapes = jax.eval_shape(lambda: mod.init_cache(cfg, batch, max_len,
                                                   **init_kw))
    return tree_shardings(specs, {k: shapes[k].shape for k in specs}, rules, mesh)


def batch_sharding(mesh: Mesh, rules: Rules, shape: Sequence[int]) -> NamedSharding:
    """Shard dim 0 (batch) of a (B, ...) input, honoring divisibility of B."""
    axes = ["batch"] + [None] * (len(shape) - 1)
    return NamedSharding(mesh, resolve_spec(axes, shape, rules, mesh))
