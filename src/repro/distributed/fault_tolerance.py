"""Fault tolerance for 1000+-node training runs.

Mechanisms (each exercised by tests/test_fault_tolerance.py):

* **NaN/inf watchdog with rollback** — :class:`NanWatchdog` is a train-loop
  hook; on a non-finite loss/grad-norm it restores the last committed
  checkpoint and skips ``cooldown`` batches (the data stream is a pure
  function of the step index, so replay is deterministic and the bad batch is
  jumped over — the standard large-run recipe for loss spikes).
* **Elastic restart-with-resharding** — :func:`reshard_restore` restores a
  checkpoint saved on mesh A onto the *current* mesh B (any shape): leaves are
  materialized host-side and re-``device_put`` with the new shardings.  At
  real pod scale the same logic runs per-host over the leaf shards it owns.
* **Straggler mitigation** — :class:`StepTimeWatchdog` tracks a robust moving
  estimate of step time; a step slower than ``threshold×`` the median flags
  the slowest data shard for re-balancing (``suggest_rebalance`` emits a new
  shard->host map; the data pipeline is keyed by shard index, so re-mapping
  is a metadata operation, no data movement).
* **Preemption-safe save cadence** — :class:`CheckpointHook` saves every
  ``every`` steps asynchronously and a final blocking save on exit; combined
  with atomic commits, any kill point loses at most ``every`` steps of work.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer


# ---------------------------------------------------------------- NaN watchdog

class NanWatchdog:
    """Train hook: rollback to last checkpoint on non-finite metrics."""

    def __init__(self, ckpt: Checkpointer, template: Tuple[Any, Any],
                 shardings: Optional[Tuple[Any, Any]] = None,
                 cooldown: int = 1):
        self.ckpt = ckpt
        self.template = template
        self.shardings = shardings
        self.cooldown = cooldown
        self.rollbacks: List[int] = []

    def __call__(self, step: int, params, opt_state, metrics):
        vals = [float(metrics.get("loss", 0.0)),
                float(metrics.get("grad_norm", 0.0))]
        if all(math.isfinite(v) for v in vals):
            return None
        self.rollbacks.append(step)
        like = (self.template[0], self.template[1])
        _, tree = self.ckpt.restore(like=like, shardings=self.shardings)
        return tree  # train loop swaps (params, opt_state)


# ------------------------------------------------------------- checkpoint hook

class CheckpointHook:
    def __init__(self, ckpt: Checkpointer, every: int, *, async_save: bool = True):
        self.ckpt = ckpt
        self.every = every
        self.async_save = async_save

    def __call__(self, step: int, params, opt_state, metrics):
        if (step + 1) % self.every == 0:
            self.ckpt.save(step + 1, (params, opt_state),
                           blocking=not self.async_save)
        return None


# ------------------------------------------------------------ elastic reshard

def reshard_restore(ckpt: Checkpointer, like, new_shardings, step=None):
    """Restore onto a (possibly different-shaped) current mesh."""
    return ckpt.restore(step, like=like, shardings=new_shardings)


# -------------------------------------------------------- straggler mitigation

@dataclasses.dataclass
class StepTimeWatchdog:
    """Detect slow steps / slow shards and propose data-shard re-balancing."""

    threshold: float = 2.0         # x median => straggler
    window: int = 32

    def __post_init__(self):
        self.times: List[float] = []
        self.flagged: List[int] = []
        self._t_last: Optional[float] = None

    def tick(self, step: int) -> Optional[int]:
        now = time.perf_counter()
        if self._t_last is None:
            self._t_last = now
            return None
        dt = now - self._t_last
        self._t_last = now
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        med = float(np.median(self.times))
        if len(self.times) >= 8 and dt > self.threshold * med:
            self.flagged.append(step)
            return step
        return None

    def observe(self, step: int, dt: float) -> Optional[int]:
        """Test/simulation entry: feed a measured duration directly."""
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        med = float(np.median(self.times))
        if len(self.times) >= 8 and dt > self.threshold * med:
            self.flagged.append(step)
            return step
        return None


def suggest_rebalance(shard_times: Dict[int, float], hosts: int
                      ) -> Dict[int, int]:
    """Greedy longest-processing-time re-assignment of data shards to hosts.

    Same LPT primitive the paper's §III-C shuffling uses for decode segments,
    applied to data shards: shard->host map minimizing the makespan estimate.
    """
    order = sorted(shard_times, key=lambda s: -shard_times[s])
    loads = [0.0] * hosts
    assign: Dict[int, int] = {}
    for s in order:
        h = int(np.argmin(loads))
        assign[s] = h
        loads[h] += shard_times[s]
    return assign
