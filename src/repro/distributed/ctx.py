"""Process-wide sharding-hints context.

Models are mesh-agnostic; the launch layer installs hints so memory-critical
*activation* tensors (attention q/k/v and scores at 32k+) receive explicit
``with_sharding_constraint``s instead of relying on GSPMD propagation alone.
Attention uses ONE merged head axis (see ``layers.gqa_attention``), so every
constraint here is expressible as a plain PartitionSpec:

* q/k/v (B, T, H, hd): batch axes on B, model axis on H; for decode the
  KV-time dim T instead carries the cache's sequence sharding
  (``kv_seq_axes`` — "model" when the arch's KV head count cannot cover the
  model axis, the data axes for single-request long-context).
* scores (B, H, Sq, T): batch on B, model on H when free, cache sharding on T
  (GSPMD emits the partial-softmax psum — flash-decoding's combine).

Install with :func:`set_hints` before tracing; smoke tests leave it unset and
models run constraint-free on one device.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingHints:
    mesh: object
    batch_axes: Tuple[str, ...] = ("data",)   # () when batch is unsharded
    model_axis: Optional[str] = "model"
    kv_seq_axes: Tuple[str, ...] = ()         # cache T-dim sharding (decode)
    seq_sp: bool = True                       # sequence-parallel layer carries
    feature_axes: Tuple[str, ...] = ()        # weight-stationary decode: the
    #   FSDP axes ride the activation FEATURE dim, forcing partial-dot + tiny
    #   psum instead of weight all-gathers (EXPERIMENTS.md §Perf H1)
    exact_tp: bool = False                    # bit-identical sharded serving:
    #   weights stay sharded at REST (per-device HBM divided along output
    #   channels) and are constrained replicated at their USE site — an
    #   all-gather, pure data movement — so every compute op runs with
    #   reference shapes and rounds exactly like the single-device engine.
    #   Activation constraints are skipped entirely: partitioning activation
    #   rows changes XLA's emitted reduction loops (fusion/row-count
    #   dependent accumulation order, measured at ~1 ulp per rms_norm), which
    #   is what breaks greedy-token identity under classic sharded-compute TP.


_HINTS: list = [None]


def set_hints(h: Optional[ShardingHints]) -> None:
    _HINTS[0] = h


def get_hints() -> Optional[ShardingHints]:
    return _HINTS[0]


def _fits(dim: int, mesh, axes: Tuple[str, ...]) -> bool:
    if not axes:
        return False
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return dim % n == 0


def constrain_replicated(x: jax.Array) -> jax.Array:
    """Exact sharded serving: gather a HBM-sharded WEIGHT to every device at
    its use site.  The all-gather is pure data movement — the consuming op
    then reads a full-shape buffer exactly like the single-device program
    reads the parameter buffer, so its emitted kernel (and therefore its
    rounding) is identical.  No-op unless ``exact_tp`` hints are installed.
    """
    h = get_hints()
    if h is None or not h.exact_tp:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(h.mesh, P(*([None] * x.ndim))))


def constrain_heads(x: jax.Array, *, is_cache_side: bool = False) -> jax.Array:
    """Constrain (B, T, H, hd): batch/B, model/H, cache sharding on T."""
    h = get_hints()
    if h is None or h.exact_tp:     # exact serving: no activation constraints
        return x
    B, T, H, _ = x.shape
    batch = h.batch_axes if _fits(B, h.mesh, h.batch_axes) else None
    seq = h.kv_seq_axes if (is_cache_side
                            and _fits(T, h.mesh, h.kv_seq_axes)) else None
    heads = None
    m = h.model_axis
    if m and _fits(H, h.mesh, (m,)) and (seq is None or m not in seq):
        heads = m
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(h.mesh, P(batch, seq, heads, None)))


def constrain_scores(s: jax.Array) -> jax.Array:
    """Constrain (B, H, Sq, T) attention scores."""
    h = get_hints()
    if h is None or h.exact_tp:     # exact serving: no activation constraints
        return s
    B, H, Sq, T = s.shape
    batch = h.batch_axes if _fits(B, h.mesh, h.batch_axes) else None
    seq = h.kv_seq_axes if _fits(T, h.mesh, h.kv_seq_axes) else None
    heads = None
    m = h.model_axis
    if m and _fits(H, h.mesh, (m,)) and (seq is None or m not in seq):
        heads = m
    return jax.lax.with_sharding_constraint(
        s, NamedSharding(h.mesh, P(batch, heads, None, seq)))


def constrain_activation(x: jax.Array) -> jax.Array:
    """Constrain a (B, S, D) activation at a layer boundary.

    Batch shards over the batch axes; the SEQUENCE dim additionally shards
    over the model axis (sequence parallelism, Korthikanti et al.): the saved
    scan carry — the dominant remat-memory term — shrinks by |model|, and the
    TP all-reduce after each row-parallel matmul becomes an equal-byte
    reduce-scatter + all-gather pair.  Skipped automatically when S doesn't
    divide (decode steps).
    """
    h = get_hints()
    if h is None or h.exact_tp or x.ndim < 3:
        return x
    if h.feature_axes:
        if not _fits(x.shape[-1], h.mesh, h.feature_axes):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(h.mesh, P(*([None] * (x.ndim - 1)),
                                       h.feature_axes)))
    batch = h.batch_axes if _fits(x.shape[0], h.mesh, h.batch_axes) else None
    m = h.model_axis
    seq = m if (h.seq_sp and m and _fits(x.shape[1], h.mesh, (m,))) else None
    if batch is None and seq is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(h.mesh, P(batch, seq, *([None] * (x.ndim - 2)))))
