"""Streaming weight-decode scheduler (paper Alg. 1 EDGE DEVICE OPERATIONS,
restructured as a pipeline instead of a monolithic pass).

``CompressedModel.decode_all`` historically materialized *every* segment of
*every* tensor in one lock-step batch: peak host memory ~ total model size,
and the serving engine could not touch a single weight until the last symbol
of the last tensor had decoded.  :class:`DecodeScheduler` replaces that with:

1. **Plan** — walk the container's segments in order and group them into
   :class:`DecodeChunk`\\ s holding at most ``chunk_symbols`` symbols.  Chunk
   boundaries also respect a *group key* (per-layer by default: the tensor
   name's ``/``-prefix), so one chunk never straddles two layer groups unless
   a single tensor is itself larger than the budget (it then spans several
   chunks and is reassembled on completion).
2. **Decode** — each chunk is packed and decoded through a pluggable
   :class:`repro.core.decode_backends.DecoderBackend` (``numpy`` / ``jax`` /
   ``pallas`` by name, or capability-based auto-pick).
3. **Stream** — :meth:`iter_decode` yields ``(name, symbols)`` as soon as a
   tensor's last segment lands, with **double-buffered prefetch**: a worker
   thread decodes chunk *k+1* while the consumer (dequantize, device transfer,
   engine load) processes chunk *k*.

Peak host memory is bounded by ~2 in-flight chunks (packed bytes + int32
symbols) plus one partially assembled tensor — independent of model size.
The monolithic behaviour is recovered exactly by ``chunk_symbols=None``
(one chunk holding everything), which is what ``decode_all`` uses.
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor
from typing import (TYPE_CHECKING, Callable, Dict, Iterator, List, Optional,
                    Sequence, Tuple)

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from .bitstream import GUARD_BYTES, pack_streams, pow2_bucket
from .decode_backends import DecoderBackend, get_backend
from .segmentation import DEFAULT_SEGMENT_SYMBOLS

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (store -> scheduler)
    from .store import CompressedModel

# 8 segments x 64k symbols ~ 0.5 MB of encoded uint8 payload and 2 MB of
# decoded int32 per chunk at the default segment size — small enough for
# edge-class hosts, large enough to keep every decode lane busy.
DEFAULT_CHUNK_SYMBOLS = 8 * DEFAULT_SEGMENT_SYMBOLS


def layer_group_key(name: str) -> str:
    """Default chunk-affinity key: the tensor name's leading path component
    (``"layers/wq" -> "layers"``, ``"embed" -> "embed"``).  With the repo's
    layer-stacked parameter layout this keeps each logical weight group's
    segments contiguous in the plan."""
    return name.split("/", 1)[0]


@dataclasses.dataclass
class _Seg:
    """One encoded segment's coordinates inside the container."""

    tensor: str
    index: int        # segment index within the tensor
    is_last: bool     # final segment of its tensor
    offset: int       # byte offset into the payload
    nbytes: int
    count: int        # symbols in this segment


@dataclasses.dataclass
class DecodeChunk:
    """A fixed-budget unit of decode work (a run of consecutive segments)."""

    segs: List[_Seg]

    @property
    def symbols(self) -> int:
        return sum(s.count for s in self.segs)

    @property
    def tensors(self) -> List[str]:
        out: List[str] = []
        for s in self.segs:
            if not out or out[-1] != s.tensor:
                out.append(s.tensor)
        return out


class DecodeScheduler:
    """Plans and runs chunked, prefetched decoding of one compressed model.

    Args:
      model: the :class:`~repro.core.store.CompressedModel` container.
      backend: registry name (``"numpy"`` / ``"jax"`` / ``"pallas"`` /
        ``"pallas-interpret"``), ``"auto"``/None for capability pick, or a
        :class:`DecoderBackend` instance.
      chunk_symbols: symbol budget per chunk; ``None`` -> single monolithic
        chunk (the historical ``decode_all`` behaviour).
      group_key: ``name -> str`` chunk-affinity key (default per-layer); pass
        ``lambda n: ""`` to disable group boundaries and chunk purely by
        budget.
      first: optional name prefixes to schedule ahead of container order
        (e.g. ``("embed",)`` so the serving engine's embedding is resident
        before the bulk of the blocks decode).
      prefetch: decode chunk *k+1* on a worker thread while chunk *k* is
        consumed (double buffering).  Disable for single-threaded debugging.
    """

    def __init__(self, model: "CompressedModel", *,
                 backend=None,
                 chunk_symbols: Optional[int] = DEFAULT_CHUNK_SYMBOLS,
                 group_key: Optional[Callable[[str], str]] = None,
                 first: Sequence[str] = (),
                 prefetch: bool = True):
        self.model = model
        self.backend: DecoderBackend = (
            backend if isinstance(backend, DecoderBackend)
            else get_backend(backend))
        self.chunk_symbols = chunk_symbols
        self.group_key = group_key or layer_group_key
        self.first = tuple(first)
        self.prefetch = prefetch

    # ------------------------------------------------------------------ plan
    def _ordered_names(self) -> List[str]:
        """Container order, with ``first=`` prefixes pulled ahead and names
        grouped by code table.  Table-major order matters for mixed v2
        containers: chunks cannot straddle tables, so an order that
        alternates tables tensor-by-tensor would fragment into tiny
        lane-starved kernel calls (measured ~6x slower — see decode_all);
        grouping yields one contiguous run (and, unbudgeted, one lock-step
        call) per table."""
        names = list(self.model.tensors)
        rank = {n: i for i, n in enumerate(names)}
        early = lambda n: not any(n.startswith(p) for p in self.first)
        table_rank = {t: i for i, t in enumerate(sorted(self.model.tables))}
        return sorted(names, key=lambda n: (
            early(n), table_rank[self.model.table_id_for(n)], rank[n]))

    def plan(self) -> List[DecodeChunk]:
        """Group the container's segments into budgeted chunks.

        A chunk decodes through ONE code table (one lock-step kernel call),
        so chunk boundaries fall on code-table changes as well as on the
        symbol budget and the group key — a mixed 4/8-bit or mixed-codec
        container (format v2) never packs two tables' segments together.
        """
        budget = self.chunk_symbols
        chunks: List[DecodeChunk] = []
        cur: List[_Seg] = []
        cur_symbols = 0
        cur_group: Optional[str] = None
        cur_table: Optional[str] = None
        for name in self._ordered_names():
            group = self.group_key(name)
            table_id = self.model.table_id_for(name)
            for seg in tensor_segments(self.model, name):
                boundary = cur and (
                    table_id != cur_table
                    or (budget is not None and (
                        cur_symbols + seg.count > budget
                        or group != cur_group)))
                if boundary:
                    chunks.append(DecodeChunk(cur))
                    cur, cur_symbols = [], 0
                cur.append(seg)
                cur_symbols += seg.count
                cur_group = group
                cur_table = table_id
        if cur:
            chunks.append(DecodeChunk(cur))
        return chunks

    # ---------------------------------------------------------------- decode
    def _decode_chunk(self, chunk: DecodeChunk) -> List[np.ndarray]:
        """Decode one chunk; returns per-segment symbol arrays (trimmed)."""
        # plan() guarantees one code table per chunk; its kernel family
        # (prefix / tans) picks the backend's matching lock-step loop
        table_id = self.model.table_id_for(chunk.segs[0].tensor)
        table = self.model.table_for(chunk.segs[0].tensor)
        with obs_trace.span("decode.chunk", cat="decode",
                            table=table_id, backend=self.backend.name,
                            segments=len(chunk.segs), symbols=chunk.symbols):
            mat, counts = pack_segments(self.model.payload, chunk.segs)
            dec = self.backend.decode_table(table, mat, counts)
        obs_metrics.counter("decode.symbols").inc(chunk.symbols,
                                                  table=table_id)
        obs_metrics.counter("decode.calls").inc(backend=self.backend.name)
        return [dec[i, : s.count] for i, s in enumerate(chunk.segs)]

    def iter_decode(self) -> Iterator[Tuple[str, np.ndarray]]:
        """Yield ``(name, uint8 symbols in tensor shape)`` incrementally.

        Tensors are emitted the moment their final segment decodes; with
        prefetch enabled the next chunk decodes concurrently on a worker
        thread while the caller consumes the current one.
        """
        chunks = self.plan()
        if not chunks:
            return
        if not self.prefetch or len(chunks) == 1:
            gen = (self._decode_chunk(c) for c in chunks)
            yield from self._assemble(chunks, gen)
            return
        with ThreadPoolExecutor(max_workers=1) as ex:
            def prefetched():
                fut = ex.submit(self._decode_chunk, chunks[0])
                for i in range(len(chunks)):
                    got = fut.result()
                    if i + 1 < len(chunks):
                        fut = ex.submit(self._decode_chunk, chunks[i + 1])
                    yield got
            yield from self._assemble(chunks, prefetched())

    def _assemble(self, chunks: List[DecodeChunk],
                  decoded) -> Iterator[Tuple[str, np.ndarray]]:
        pieces: Dict[str, List[np.ndarray]] = {}
        for chunk, segs in zip(chunks, decoded):
            for seg, arr in zip(chunk.segs, segs):
                pieces.setdefault(seg.tensor, []).append(arr)
                if not seg.is_last:
                    continue
                meta = self.model.tensors[seg.tensor]
                parts = pieces.pop(seg.tensor)
                flat = np.concatenate(parts) if len(parts) > 1 else parts[0]
                yield seg.tensor, flat.astype(np.uint8).reshape(meta.shape)
        assert not pieces, f"incomplete tensors at end of plan: {list(pieces)}"


# ---------------------------------------------------------------------------
# Execution-order plans (compressed-resident serving, paper §IV "parallel
# decoding strategy"): instead of decoding the container in STORAGE order
# once at load, plan the decode in LAYER EXECUTION order so a serving step
# can materialize exactly layer l's weights just before layer l's matmuls —
# and decode layer l+1 on a worker thread while layer l computes (the
# decode/compute overlap documented in docs/SERVING.md §"Compressed-resident
# serving").


def tensor_segments(model: "CompressedModel", name: str) -> List[_Seg]:
    """The container's segment coordinates for one tensor, in symbol order
    (the one place segment-table columns become :class:`_Seg` records —
    both the storage-order and the execution-order planner consume it)."""
    meta = model.tensors[name]
    n_seg = len(meta.seg_offsets)
    return [
        _Seg(tensor=name, index=j, is_last=(j == n_seg - 1),
             offset=int(o), nbytes=int(nb), count=int(c))
        for j, (o, nb, c) in enumerate(zip(meta.seg_offsets, meta.seg_nbytes,
                                           meta.seg_counts))
    ]


def pack_segments(payload: np.ndarray,
                  segs: Sequence[_Seg]) -> Tuple[np.ndarray, np.ndarray]:
    """Pack a segment run's payload slices into one decode-call matrix.

    The one pack rule every lock-step decode call shares: rows are the
    segments' byte streams, counts their symbol counts, and the width
    buckets to a power of two so shape-specialized (jit / Pallas) backends
    reuse one compile per bucket instead of one per call geometry.
    """
    streams = [payload[s.offset: s.offset + s.nbytes] for s in segs]
    counts = np.array([s.count for s in segs], dtype=np.int64)
    width = max(GUARD_BYTES, max(s.nbytes for s in segs))
    mat, _ = pack_streams(streams, min_width=pow2_bucket(width, 64))
    return mat, counts


@dataclasses.dataclass
class ExecutionSpan:
    """One stacked tensor's layer-l slice, as container segments.

    Segments hold fixed symbol counts and know nothing about layer
    boundaries, so a layer's symbol range ``[l*P, (l+1)*P)`` may start and
    end mid-segment: ``segs`` are the overlapping segments in order, ``trim``
    is the slice start within their concatenated decode, ``count`` the
    symbols belonging to the layer (``P = n_symbols / n_layers``).  Boundary
    segments are decoded by both adjacent layers and trimmed — the price of
    planning over an unmodified container.
    """

    tensor: str
    segs: List[_Seg]
    trim: int
    count: int


@dataclasses.dataclass
class ExecutionStep:
    """All spans one layer decodes through ONE code table (one lock-step
    kernel call, same no-straddling rule as :meth:`DecodeScheduler.plan`)."""

    layer: int
    table_id: str
    spans: List[ExecutionSpan]

    @property
    def segs(self) -> List[_Seg]:
        return [s for sp in self.spans for s in sp.segs]


def plan_execution(model: "CompressedModel", n_layers: int,
                   names: Sequence[str]) -> List[List[ExecutionStep]]:
    """Plan per-layer decode of layer-stacked tensors in execution order.

    ``names`` are container tensors whose leading axis is the layer axis
    (``shape[0] == n_layers``); returns one list of :class:`ExecutionStep`
    per layer (usually a single step; mixed-codec containers get one step
    per code table).  The plan holds only coordinates into the resident
    payload — the bitstream itself is never copied or reordered.
    """
    spans: List[List[ExecutionSpan]] = [[] for _ in range(n_layers)]
    for name in names:
        meta = model.tensors[name]
        if len(meta.shape) == 0 or meta.shape[0] != n_layers:
            raise ValueError(
                f"{name}: shape {meta.shape} is not stacked over "
                f"{n_layers} layers")
        per_layer, rem = divmod(meta.n_symbols, n_layers)
        assert rem == 0, (name, meta.n_symbols, n_layers)
        segs = tensor_segments(model, name)
        starts = np.concatenate([[0], np.cumsum(meta.seg_counts)])
        for l in range(n_layers):
            a, b = l * per_layer, (l + 1) * per_layer
            idx = np.nonzero((starts[:-1] < b) & (starts[1:] > a))[0]
            spans[l].append(ExecutionSpan(
                tensor=name, segs=[segs[i] for i in idx],
                trim=a - int(starts[idx[0]]), count=per_layer))
    plan: List[List[ExecutionStep]] = []
    for l, layer_spans in enumerate(spans):
        by_table: Dict[str, List[ExecutionSpan]] = {}
        for sp in layer_spans:
            by_table.setdefault(model.table_id_for(sp.tensor), []).append(sp)
        plan.append([ExecutionStep(layer=l, table_id=t, spans=s)
                     for t, s in sorted(by_table.items())])
    return plan


@dataclasses.dataclass
class FusedTileSpan:
    """One stacked tensor's layer-l slice as *whole* segments whose lane
    boundaries coincide with matmul K-tiles (the fused-kernel contract:
    no trims, uniform counts — contrast :class:`ExecutionSpan`, which
    tolerates boundary segments by decoding them twice)."""

    tensor: str
    layer: int
    segs: List[_Seg]
    seg_symbols: int


def fused_tile_reason(model: "CompressedModel", n_layers: int,
                      name: str) -> Optional[str]:
    """Why ``name`` cannot feed the fused decode→dequant→matmul kernel —
    ``None`` when its segments tile-align with per-layer (K, N) blocks.

    The geometric contract (see kernels/fused_decode_matmul.py): a stacked
    (L, K, N) tensor whose segments all hold the same ``seg`` symbols, with
    ``seg`` a multiple of the row width N and the per-layer symbol count a
    multiple of ``seg`` — so each layer is a whole number of lanes and each
    decoded lane reshapes row-major into whole (seg/N, N) K-tile rows.
    """
    meta = model.tensors[name]
    if len(meta.shape) != 3:
        return f"shape {meta.shape} is not a stacked (L, K, N) matrix"
    if meta.shape[0] != n_layers:
        return f"leading dim {meta.shape[0]} != n_layers {n_layers}"
    counts = np.asarray(meta.seg_counts)
    seg = int(counts[0])
    if not (counts == seg).all():
        return "ragged tail segment (non-uniform symbol counts)"
    _, K, N = meta.shape
    if seg % N:
        return f"segment of {seg} symbols does not tile rows of width {N}"
    if (K * N) % seg:
        return f"layer slice of {K * N} symbols is not a whole number " \
               f"of {seg}-symbol segments"
    return None


def plan_fused_spans(model: "CompressedModel", n_layers: int,
                     names: Sequence[str]) -> Dict[str, List[FusedTileSpan]]:
    """Per-layer whole-segment spans for fused-eligible tensors.

    Raises on any name failing :func:`fused_tile_reason` — callers classify
    first and fall back to :func:`plan_execution` for the rest.  Returns
    ``{name: [span for layer 0, span for layer 1, ...]}``.
    """
    out: Dict[str, List[FusedTileSpan]] = {}
    for name in names:
        reason = fused_tile_reason(model, n_layers, name)
        if reason:
            raise ValueError(f"{name}: {reason}")
        meta = model.tensors[name]
        seg = int(meta.seg_counts[0])
        segs = tensor_segments(model, name)
        lanes_per_layer = (meta.n_symbols // n_layers) // seg
        out[name] = [
            FusedTileSpan(tensor=name, layer=l,
                          segs=segs[l * lanes_per_layer:
                                    (l + 1) * lanes_per_layer],
                          seg_symbols=seg)
            for l in range(n_layers)
        ]
    return out


def iter_seg_runs(segs: Sequence[_Seg],
                  chunk_symbols: Optional[int]) -> Iterator[List[_Seg]]:
    """Split a segment sequence into consecutive runs of at most
    ``chunk_symbols`` symbols (at least one segment per run; ``None`` ->
    one run).  The per-layer decode uses this exactly like
    :meth:`DecodeScheduler.plan` uses its budget: it bounds the int32
    decode scratch to O(chunk) instead of O(layer)."""
    if chunk_symbols is None:
        yield list(segs)
        return
    run: List[_Seg] = []
    n = 0
    for s in segs:
        if run and n + s.count > chunk_symbols:
            yield run
            run, n = [], 0
        run.append(s)
        n += s.count
    if run:
        yield run


def decode_execution_step(model: "CompressedModel", step: ExecutionStep,
                          backend: DecoderBackend, *,
                          out: Optional[np.ndarray] = None,
                          chunk_symbols: Optional[int] = None
                          ) -> Dict[str, np.ndarray]:
    """Decode one layer-step; returns ``{tensor: flat uint8 layer slice}``.

    Lock-step multi-stream calls through the step's code table, one per
    budgeted segment run (``chunk_symbols=None`` -> a single call); ``out``
    is the optional preallocated (streams, max_count) int32 scratch shared
    across layers (:meth:`DecoderBackend.decode_table`'s decode-into-buffer
    contract).  Decoded symbols are narrowed to uint8 per segment as they
    land, so the live int32 footprint never exceeds one run.
    """
    table = model.tables[step.table_id]
    pieces: Dict[str, List[np.ndarray]] = {}
    n_symbols = sum(s.count for s in step.segs)
    with obs_trace.span("decode.exec_step", cat="decode", layer=step.layer,
                        table=step.table_id, backend=backend.name,
                        segments=len(step.segs), symbols=n_symbols):
        for run in iter_seg_runs(step.segs, chunk_symbols):
            mat, counts = pack_segments(model.payload, run)
            dec = backend.decode_table(table, mat, counts, out=out)
            for j, s in enumerate(run):
                pieces.setdefault(s.tensor, []).append(
                    dec[j, : s.count].astype(np.uint8))
    obs_metrics.counter("decode.symbols").inc(n_symbols, table=step.table_id)
    obs_metrics.counter("decode.calls").inc(backend=backend.name)
    result: Dict[str, np.ndarray] = {}
    for sp in step.spans:
        parts = pieces[sp.tensor]
        flat = np.concatenate(parts) if len(parts) > 1 else parts[0]
        if sp.trim == 0 and sp.count == flat.size:
            result[sp.tensor] = flat
        else:
            # copy so the layer slot never pins a boundary segment's
            # over-decode (the slice would otherwise keep the whole
            # segment's buffer alive for the slot's lifetime)
            result[sp.tensor] = flat[sp.trim: sp.trim + sp.count].copy()
    return result
