"""EntroLLM mixed quantization scheme (paper Alg. 1, lines 4-10).

Per tensor (or per channel / per group as a beyond-paper extension) we choose between

* symmetric **unsigned** quantization, used when ``max(W) * min(W) >= 0`` — the whole
  tensor shares one sign, so ``W / s`` with a signed scale lands in ``[0, 2^b - 1]``;
* asymmetric quantization ``round((W - z) / s)`` with ``z = min(W)`` otherwise.

Both branches emit *unsigned* symbols in ``[0, 2^b)`` — this is what makes the
model-global symbol histogram a single low-entropy Gaussian-shaped distribution, the
property the paper's Huffman stage exploits.

Host-side (numpy) and device-side (jnp) implementations share the same math; the numpy
path is used by the compression pipeline / checkpointer, the jnp path by fused
dequantization inside compute steps.
"""
from __future__ import annotations

import dataclasses
import enum
import warnings
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np


class Scheme(enum.Enum):
    """Quantization grid selection (paper Fig. 2)."""

    SYMMETRIC_UNSIGNED = "symmetric_unsigned"
    ASYMMETRIC = "asymmetric"


class Granularity(enum.Enum):
    PER_TENSOR = "per_tensor"    # the paper's setting
    PER_CHANNEL = "per_channel"  # beyond-paper: one (s, z) per output channel (axis 0)
    PER_GROUP = "per_group"      # beyond-paper: one (s, z) per contiguous group on axis -1


@dataclasses.dataclass
class QuantizedTensor:
    """A quantized weight tensor plus everything needed to dequantize it.

    ``q`` always stores unsigned symbols as uint8 (uint4 values occupy the low nibble;
    bit-packing happens at the bitstream/storage layer, not here).
    """

    q: np.ndarray                  # uint8 symbols in [0, 2^bits)
    scale: np.ndarray              # f32, broadcastable against q
    zero: np.ndarray               # f32, broadcastable against q (0.0 for symmetric)
    bits: int
    scheme: Scheme
    granularity: Granularity
    shape: Tuple[int, ...]

    @property
    def num_symbols(self) -> int:
        return 1 << self.bits

    def __post_init__(self) -> None:
        assert self.q.dtype == np.uint8, self.q.dtype
        assert 1 <= self.bits <= 8


def _minmax(w: np.ndarray, granularity: Granularity, group: int) -> Tuple[np.ndarray, np.ndarray]:
    if granularity is Granularity.PER_TENSOR:
        return w.min(keepdims=True), w.max(keepdims=True)
    if granularity is Granularity.PER_CHANNEL:
        red = tuple(range(1, w.ndim))
        return w.min(axis=red, keepdims=True), w.max(axis=red, keepdims=True)
    if granularity is Granularity.PER_GROUP:
        if w.shape[-1] % group != 0:
            raise ValueError(
                f"PER_GROUP quantization needs group ({group}) to divide the "
                f"last dim of shape {w.shape}; resolve_granularity() picks "
                f"the per-channel fallback for ragged tails")
        wg = w.reshape(w.shape[:-1] + (w.shape[-1] // group, group))
        return wg.min(axis=-1, keepdims=True), wg.max(axis=-1, keepdims=True)
    raise ValueError(granularity)


def resolve_granularity(w: np.ndarray, granularity: Granularity,
                        group: int, *, name: Optional[str] = None,
                        stacklevel: int = 2) -> Granularity:
    """Validate a (granularity, group) request against a tensor's shape.

    PER_GROUP with a group that does not divide the last dim used to crash in
    an opaque reshape deep inside ``_minmax``; instead, warn and fall back to
    the nearest coarser granularity (per-channel for matrices, per-tensor for
    scalars/vectors) so ragged tails still quantize.  A non-positive
    ``group`` is a plain misconfiguration and raises.  PER_CHANNEL on a
    scalar or 1-D tensor would degenerate to one (scale, zero) pair per
    ELEMENT (8 metadata bytes per parameter — larger than fp32): warn and
    fall back to per-tensor.

    ``name`` (the container tensor name, threaded from
    ``store.CompressedModel.compress``) prefixes the warning so a fallback
    in a 300-tensor model is attributable; ``stacklevel`` points the
    warning at this function's direct caller by default — callers that wrap
    it (``quantize``) bump it so the warning lands on *their* caller.
    """
    tag = f"{name}: " if name else ""
    if granularity is Granularity.PER_CHANNEL and w.ndim < 2:
        warnings.warn(
            f"{tag}PER_CHANNEL on a {w.ndim}-D tensor of shape "
            f"{tuple(w.shape)} would store per-element scales; falling back "
            f"to per_tensor", stacklevel=stacklevel)
        return Granularity.PER_TENSOR
    if granularity is not Granularity.PER_GROUP:
        return granularity
    if group <= 0:
        raise ValueError(
            f"{tag}PER_GROUP quantization needs group >= 1, got {group}")
    if w.ndim == 0:
        # a scalar has no last dim to group; the generic "does not divide"
        # wording would be nonsense, so say what actually happened
        warnings.warn(
            f"{tag}PER_GROUP on a 0-D tensor has no axis to group; "
            f"falling back to per_tensor", stacklevel=stacklevel)
        return Granularity.PER_TENSOR
    if w.shape[-1] % group == 0:
        return granularity
    fallback = (Granularity.PER_CHANNEL if w.ndim >= 2
                else Granularity.PER_TENSOR)
    warnings.warn(
        f"{tag}PER_GROUP group={group} does not divide the last dim of "
        f"shape {tuple(w.shape)}; falling back to {fallback.value} for "
        f"this tensor", stacklevel=stacklevel)
    return fallback


def choose_scheme(w: np.ndarray) -> Scheme:
    """Paper Alg. 1 line 5: symmetric-unsigned iff the tensor is single-signed."""
    return (
        Scheme.SYMMETRIC_UNSIGNED
        if float(w.max()) * float(w.min()) >= 0.0
        else Scheme.ASYMMETRIC
    )


def quantize(
    w: np.ndarray,
    bits: int,
    granularity: Granularity = Granularity.PER_TENSOR,
    group: int = 128,
    scheme: Optional[Scheme] = None,
    name: Optional[str] = None,
) -> QuantizedTensor:
    """Quantize ``w`` with the EntroLLM mixed scheme.

    ``scheme=None`` (default) applies the paper's per-tensor rule; pass a scheme to
    force one branch (used by tests and by the policy layer).  ``name`` only
    labels granularity-fallback warnings (see :func:`resolve_granularity`).
    """
    w = np.asarray(w, dtype=np.float32)
    if scheme is None:
        scheme = choose_scheme(w)
    granularity = resolve_granularity(w, granularity, group, name=name,
                                      stacklevel=3)
    qmax = float((1 << bits) - 1)
    lo, hi = _minmax(w, granularity, group)

    if scheme is Scheme.SYMMETRIC_UNSIGNED:
        # Single-signed tensor: signed scale keeps symbols unsigned.  absmax with sign.
        absmax = np.where(np.abs(hi) >= np.abs(lo), hi, lo)
        scale = np.where(absmax == 0.0, 1.0, absmax / qmax).astype(np.float32)
        zero = np.zeros_like(scale)
    else:
        scale = ((hi - lo) / qmax).astype(np.float32)
        scale = np.where(scale == 0.0, 1.0, scale)
        zero = lo.astype(np.float32)

    if granularity is Granularity.PER_GROUP:
        wq = w.reshape(w.shape[:-1] + (w.shape[-1] // group, group))
        q = np.rint((wq - zero) / scale)
        q = np.clip(q, 0.0, qmax).astype(np.uint8).reshape(w.shape)
    else:
        q = np.rint((w - zero) / scale)
        q = np.clip(q, 0.0, qmax).astype(np.uint8)

    return QuantizedTensor(
        q=q, scale=scale, zero=zero, bits=bits, scheme=scheme,
        granularity=granularity, shape=tuple(w.shape),
    )


def dequantize(qt: QuantizedTensor) -> np.ndarray:
    q = qt.q.astype(np.float32)
    if qt.granularity is Granularity.PER_GROUP:
        group = qt.shape[-1] // qt.scale.shape[-2]
        qg = q.reshape(qt.shape[:-1] + (qt.shape[-1] // group, group))
        return (qg * qt.scale + qt.zero).reshape(qt.shape).astype(np.float32)
    return (q * qt.scale + qt.zero).astype(np.float32)


# --- jnp twins (used inside jitted compute; weights stay integer in HBM) ------------

def dequantize_jnp(q: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray,
                   dtype=jnp.bfloat16) -> jnp.ndarray:
    """Fusable dequant: XLA folds the convert+scale into the consuming dot."""
    return (q.astype(dtype) * scale.astype(dtype) + zero.astype(dtype))


def quantize_jnp(w: jnp.ndarray, bits: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-tensor mixed-scheme quantization under jit (used by gradient compression
    and by the on-device checkpoint path). Returns (q_uint8, scale, zero)."""
    qmax = float((1 << bits) - 1)
    lo, hi = w.min(), w.max()
    single_signed = lo * hi >= 0.0
    absmax = jnp.where(jnp.abs(hi) >= jnp.abs(lo), hi, lo)
    s_sym = jnp.where(absmax == 0.0, 1.0, absmax / qmax)
    s_asym = jnp.where(hi == lo, 1.0, (hi - lo) / qmax)
    scale = jnp.where(single_signed, s_sym, s_asym)
    zero = jnp.where(single_signed, 0.0, lo)
    q = jnp.clip(jnp.round((w - zero) / scale), 0.0, qmax).astype(jnp.uint8)
    return q, scale.astype(jnp.float32), zero.astype(jnp.float32)
