"""Parameter-space segmentation for parallel decoding (paper §III-C).

The paper preserves the weight tensors' packing structure so every encoded chunk's
start/end is known in advance, making chunks independently decodable.  We keep that
exactly, with one pod-scale refinement: segment boundaries are chosen to *nest inside
shard boundaries*, so a device that owns rows ``[a, b)`` of a TP/FSDP-sharded tensor can
decode its shard from a contiguous run of segments without touching any other device's
bytes — the paper's "independent segments across threads" lifted to SPMD across chips.

Every segment holds exactly ``segment_symbols`` symbols (except tensor-final tails),
so the lock-step LUT decoder is load-balanced by construction; the byte-size imbalance
the paper counteracts with shuffling only affects *storage* locality, for which
:func:`balanced_assignment` provides the paper's longest-first shuffle.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

DEFAULT_SEGMENT_SYMBOLS = 64 * 1024


@dataclasses.dataclass
class SegmentedTensor:
    """One tensor's encoded segments (byte offsets into the container buffer)."""

    name: str
    shape: Tuple[int, ...]
    n_symbols: int
    seg_offsets: np.ndarray   # (n_seg,) int64 byte offset of each segment stream
    seg_nbytes: np.ndarray    # (n_seg,) int64 byte length (incl. guard)
    seg_counts: np.ndarray    # (n_seg,) int64 symbols per segment
    seg_bits: np.ndarray      # (n_seg,) int64 encoded payload bits


def segment_and_encode(
    name: str,
    q: np.ndarray,
    table,
    segment_symbols: int = DEFAULT_SEGMENT_SYMBOLS,
) -> Tuple[SegmentedTensor, List[np.ndarray]]:
    """Encode one quantized tensor into independent byte-aligned segment streams.

    ``table`` is anything with the shared ``encode(flat_symbols) ->
    (guard-padded stream, payload bits)`` contract — a
    :class:`repro.core.codecs.base.CodeTable` or a bare
    :class:`repro.core.entropy.HuffmanTable`.
    """
    flat = q.reshape(-1)
    n = flat.size
    streams: List[np.ndarray] = []
    counts, bits = [], []
    for start in range(0, max(n, 1), segment_symbols):
        chunk = flat[start: start + segment_symbols]
        stream, nbits = table.encode(chunk)
        streams.append(stream)
        counts.append(len(chunk))
        bits.append(nbits)
    meta = SegmentedTensor(
        name=name,
        shape=tuple(q.shape),
        n_symbols=n,
        seg_offsets=np.zeros(len(streams), dtype=np.int64),  # filled by the container
        seg_nbytes=np.array([len(s) for s in streams], dtype=np.int64),
        seg_counts=np.array(counts, dtype=np.int64),
        seg_bits=np.array(bits, dtype=np.int64),
    )
    return meta, streams


def balanced_assignment(seg_bits: np.ndarray, n_workers: int) -> List[np.ndarray]:
    """Paper §III-C shuffling: longest-processing-time-first greedy assignment of
    segments to workers so each worker's total encoded bits are near-equal."""
    order = np.argsort(-seg_bits)
    loads = np.zeros(n_workers, dtype=np.int64)
    buckets: List[List[int]] = [[] for _ in range(n_workers)]
    for s in order:
        w = int(np.argmin(loads))
        buckets[w].append(int(s))
        loads[w] += int(seg_bits[s])
    return [np.array(sorted(b), dtype=np.int64) for b in buckets]


def shard_segment_slices(seg_counts: np.ndarray, shard_bounds: Sequence[Tuple[int, int]]
                         ) -> List[np.ndarray]:
    """Map flat-symbol shard ranges [a, b) to the segment indices that cover them.

    Used by the sharded loader: with ``segment_symbols`` dividing the per-shard symbol
    count (the framework picks segment sizes that do), each shard maps to a whole number
    of segments and decodes with zero overlap.
    """
    starts = np.concatenate([[0], np.cumsum(seg_counts)])[:-1]
    ends = starts + seg_counts
    out = []
    for a, b in shard_bounds:
        out.append(np.nonzero((starts < b) & (ends > a))[0].astype(np.int64))
    return out
