"""Declarative per-tensor compression policy (DESIGN.md §7).

The paper's Alg. 1 fixes ONE model-wide bit-width and ONE global Huffman
table.  :class:`CompressionSpec` generalizes that to an ordered rule list —
first matching rule wins, like firewall rules — so one container can mix
4- and 8-bit tensors, alternative entropy coders, per-channel/per-group
quantization, and explicit keep-fp32 carve-outs:

    spec = CompressionSpec.parse(
        "*norm*:fp32; layers/*mlp*:bits=4,codec=rans; *:bits=8,codec=huffman")
    cm = CompressedModel.compress(params, spec=spec)

Rules resolve to a :class:`TensorPolicy` per tensor.  Tensors no rule
matches fall back to the paper's policy: :func:`default_quantize_predicate`
(DESIGN.md §5) decides *whether* to quantize, and the spec's defaults decide
*how*.  A matching rule OVERRIDES that predicate — a bare ``*`` catch-all
quantizes everything it reaches, biases and sensitive SSM params included,
so keep explicit ``fp32`` carve-outs ahead of it (or omit the catch-all).  ``bits="auto"`` picks 4 vs. 8 per tensor from two signals
(:func:`auto_choose_bits`): the relative quantization error at 4 bits must
stay under ``auto_tol``, and the 4-bit symbol histogram must actually be
compressible (entropy under ``auto_entropy_cap`` — a near-uniform 4-bit
histogram means entropy coding would win nothing over the error risk).

The grammar for ``CompressionSpec.parse`` (the ``--compress-spec`` CLI
surface)::

    spec    := clause (';' clause)*
    clause  := pattern ':' opt (',' opt)*
    opt     := 'fp32' | 'auto' | INT            # bare int = bits
             | key '=' value                    # bits/codec/granularity/
                                                # group/scheme
    pattern := fnmatch glob over tensor names ('*', '?', '[..]')
             | 'defaults'                       # reserved: sets the spec
                                                # DEFAULTS, not a rule

A ``defaults:`` clause configures what unmatched tensors get (they still
pass through :func:`default_quantize_predicate` first) — unlike a ``*``
catch-all rule, which overrides the predicate.  It also accepts the
encoder-wide parameters ``auto_tol`` / ``auto_entropy_cap`` /
``segment_symbols`` / ``max_code_len``.  ``describe()`` emits this form
(non-default encoder params included), so provenance strings round-trip
with identical semantics.

``validate()`` checks every referenced codec against the codec registry and
every bit-width against the uint8-symbol range — called upfront by
``launch/serve.py`` so a typo fails with the registered list, not a deep
KeyError mid-compress (the same contract as ``--decode-backend``).
"""
from __future__ import annotations

import dataclasses
import fnmatch
from typing import Optional, Tuple, Union

import numpy as np

from . import quant
from .segmentation import DEFAULT_SEGMENT_SYMBOLS

AUTO = "auto"

# encoder-wide parameters: legal only in a 'defaults:' clause, carried by
# describe() when they differ from the dataclass defaults
_SPEC_WIDE_KEYS = frozenset(
    ("auto_tol", "auto_entropy_cap", "segment_symbols", "max_code_len"))

_GRANULARITY_ALIASES = {
    "per_tensor": quant.Granularity.PER_TENSOR,
    "tensor": quant.Granularity.PER_TENSOR,
    "per_channel": quant.Granularity.PER_CHANNEL,
    "channel": quant.Granularity.PER_CHANNEL,
    "per_group": quant.Granularity.PER_GROUP,
    "group": quant.Granularity.PER_GROUP,
}


SENSITIVE_NAME_KEYS = ("norm", "scale", "bias", "a_log", "dt_", "conv_")


def quantizable_shape(name: str, shape: Tuple[int, ...]) -> bool:
    """Shape/name-only twin of :func:`default_quantize_predicate`, for
    callers that hold container metadata rather than the tensor itself
    (e.g. the serving loader deciding quantized residency)."""
    if len(shape) < 2:
        return False
    lname = name.lower()
    if any(k in lname for k in SENSITIVE_NAME_KEYS):
        return False
    return int(np.prod(shape)) >= 4096


def default_quantize_predicate(name: str, w: np.ndarray) -> bool:
    """Quantize matrix-shaped weights; keep norms / biases / tiny or sensitive params
    (e.g. SSM ``A_log``/``dt``) in full precision, per DESIGN.md §5."""
    return quantizable_shape(name, np.shape(w))


@dataclasses.dataclass(frozen=True)
class CompressionRule:
    """One ordered rule: name pattern -> how (or whether) to compress.

    ``None`` fields inherit the spec's defaults; ``bits`` may be an int,
    ``"auto"``, or None (= spec default).  ``keep_fp32`` short-circuits
    everything else for matching tensors.
    """

    pattern: str
    bits: Union[int, str, None] = None
    codec: Optional[str] = None
    granularity: Optional[quant.Granularity] = None
    group: Optional[int] = None
    scheme: Optional[quant.Scheme] = None
    keep_fp32: bool = False

    def matches(self, name: str) -> bool:
        return fnmatch.fnmatchcase(name, self.pattern)


@dataclasses.dataclass(frozen=True, eq=False)
class TensorPolicy:
    """The fully resolved decision for one tensor."""

    quantize: bool
    bits: int = 8
    codec: str = "huffman"
    granularity: quant.Granularity = quant.Granularity.PER_TENSOR
    group: int = 128
    scheme: Optional[quant.Scheme] = None
    rule: Optional[CompressionRule] = None     # provenance (None = default path)
    # bits="auto" probes by actually quantizing at 4 bits; when 4 wins, the
    # probe's QuantizedTensor rides along so compress() need not redo it
    qt: Optional[quant.QuantizedTensor] = None


def auto_choose_bits(w: np.ndarray, *, granularity: quant.Granularity,
                     group: int, tol: float, entropy_cap: float
                     ) -> Tuple[int, Optional[quant.QuantizedTensor]]:
    """Pick 4 vs. 8 bits for one tensor (the spec's ``bits="auto"`` policy).

    Returns ``(bits, qt4)`` where ``qt4`` is the probe's 4-bit
    :class:`~repro.core.quant.QuantizedTensor` when 4 wins (reusable by the
    caller — the probe already paid for the quantization) and None otherwise.

    4 bits wins iff BOTH hold:
      * **bulk** relative quantization error <= ``tol`` — error and signal
        energy are measured over the sub-99.9th-percentile ``|w|`` mass.
        Outliers must be excluded from the *denominator*: a single huge entry
        dominates ``E[w^2]`` and makes the collapsed-to-one-bin bulk look
        accurate, which is exactly the failure mode that forces 8 bits;
      * 4-bit symbol entropy ``<= entropy_cap`` — a histogram near the
        uniform 4.0 bits would entropy-code to ~4 bits anyway, so the halved
        symbol width buys little storage for the added error.
    """
    from .entropy import shannon_entropy, symbol_frequencies
    qt4 = quant.quantize(w, 4, granularity, group=group)
    deq = quant.dequantize(qt4)
    bulk = np.abs(w) <= np.quantile(np.abs(w), 0.999)
    denom = float(np.mean(np.square(w[bulk]))) + 1e-20
    rel_err = float(np.mean(np.square((w - deq)[bulk]))) / denom
    h4 = shannon_entropy(symbol_frequencies(qt4.q, 16))
    if rel_err <= tol and h4 <= entropy_cap:
        return 4, qt4
    return 8, None


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Ordered per-tensor rules + defaults for everything they leave open."""

    rules: Tuple[CompressionRule, ...] = ()
    default_bits: Union[int, str] = 8
    default_codec: str = "huffman"
    default_granularity: quant.Granularity = quant.Granularity.PER_TENSOR
    default_group: int = 128
    auto_tol: float = 3e-2          # bits="auto": max relative 4-bit MSE
    #   (a clean Gaussian tensor quantizes to 4 bits at ~2% relative MSE;
    #    outlier-heavy tensors blow well past 3% and stay at 8 bits)
    auto_entropy_cap: float = 3.9   # bits="auto": max useful 4-bit entropy
    segment_symbols: int = DEFAULT_SEGMENT_SYMBOLS
    max_code_len: int = 12          # huffman length limit (codec-specific kw)
    source: Optional[str] = None    # the parsed text, for provenance

    # ---------------------------------------------------------------- resolve
    def resolve(self, name: str, w: np.ndarray) -> TensorPolicy:
        """First matching rule wins; unmatched tensors take the paper's
        default predicate + the spec defaults."""
        w = np.asarray(w)
        for rule in self.rules:
            if not rule.matches(name):
                continue
            if rule.keep_fp32:
                return TensorPolicy(quantize=False, rule=rule)
            return self._policy(w, rule=rule,
                                bits=(rule.bits if rule.bits is not None
                                      else self.default_bits),
                                codec=rule.codec or self.default_codec,
                                granularity=(rule.granularity
                                             or self.default_granularity),
                                group=(rule.group if rule.group is not None
                                       else self.default_group),
                                scheme=rule.scheme)
        if not default_quantize_predicate(name, w):
            return TensorPolicy(quantize=False)
        return self._policy(w, rule=None, bits=self.default_bits,
                            codec=self.default_codec,
                            granularity=self.default_granularity,
                            group=self.default_group, scheme=None)

    def _policy(self, w, *, rule, bits, codec, granularity, group,
                scheme) -> TensorPolicy:
        qt = None
        if bits == AUTO:
            bits, qt = auto_choose_bits(w, granularity=granularity,
                                        group=group, tol=self.auto_tol,
                                        entropy_cap=self.auto_entropy_cap)
            if scheme is not None:
                qt = None    # probe used choose_scheme; a forced scheme differs
        return TensorPolicy(quantize=True, bits=int(bits), codec=codec,
                            granularity=granularity, group=group,
                            scheme=scheme, rule=rule, qt=qt)

    # --------------------------------------------------------------- validate
    def codecs_used(self) -> Tuple[str, ...]:
        names = {r.codec for r in self.rules if r.codec}
        names.add(self.default_codec)
        return tuple(sorted(names))

    def validate(self) -> "CompressionSpec":
        """Fail fast on unknown codecs / unrepresentable bit-widths."""
        from . import codecs
        for name in self.codecs_used():
            codecs.get_codec(name)       # raises with the registered list
        for b in [self.default_bits] + [r.bits for r in self.rules
                                        if r.bits is not None]:
            if b == AUTO:
                continue
            if not (isinstance(b, int) and 1 <= b <= 8):
                raise ValueError(f"bits must be in [1, 8] or 'auto', got {b!r}"
                                 + (f" (spec: {self.source})"
                                    if self.source else ""))
        for g in [self.default_group] + [r.group for r in self.rules
                                         if r.group is not None]:
            if not (isinstance(g, int) and g >= 1):
                raise ValueError(f"group must be >= 1, got {g!r}"
                                 + (f" (spec: {self.source})"
                                    if self.source else ""))
        return self

    # ------------------------------------------------------------------ parse
    @classmethod
    def parse(cls, text: str, **defaults) -> "CompressionSpec":
        """Parse the rule mini-language (see module docstring)."""
        rules = []
        for clause in filter(None, (c.strip() for c in text.split(";"))):
            if ":" not in clause:
                raise ValueError(f"bad spec clause {clause!r}: expected "
                                 f"'pattern:opt[,opt...]'")
            pattern, _, body = clause.partition(":")
            is_defaults = pattern.strip().lower() == "defaults"
            kw: dict = {}
            for opt in filter(None, (o.strip() for o in body.split(","))):
                key, eq, value = opt.partition("=")
                key = key.strip().lower()
                value = value.strip()
                if not eq:
                    if key == "fp32":
                        kw["keep_fp32"] = True
                    elif key == AUTO:
                        kw["bits"] = AUTO
                    elif key.isdigit():
                        kw["bits"] = int(key)
                    else:
                        raise ValueError(
                            f"bad option {opt!r} in clause {clause!r}: "
                            f"expected fp32 / auto / <bits> / key=value")
                elif key == "bits":
                    kw["bits"] = AUTO if value == AUTO else int(value)
                elif key == "codec":
                    kw["codec"] = value
                elif key in ("granularity", "gran"):
                    try:
                        kw["granularity"] = _GRANULARITY_ALIASES[value.lower()]
                    except KeyError:
                        raise ValueError(
                            f"unknown granularity {value!r}; one of "
                            f"{sorted(_GRANULARITY_ALIASES)}") from None
                elif key == "group":
                    kw["group"] = int(value)
                elif key == "scheme":
                    kw["scheme"] = quant.Scheme(value)
                elif key in ("auto_tol", "auto_entropy_cap"):
                    kw[key] = float(value)
                elif key in ("segment_symbols", "max_code_len"):
                    kw[key] = int(value)
                else:
                    raise ValueError(f"unknown spec key {key!r} in "
                                     f"clause {clause!r}")
            if is_defaults:
                # reserved clause: sets the spec DEFAULTS (unmatched tensors
                # still pass the keep-fp32 predicate), not a catch-all rule
                if kw.get("keep_fp32") or "scheme" in kw:
                    raise ValueError(f"clause {clause!r}: 'defaults' takes "
                                     f"bits/codec/granularity/group and "
                                     f"encoder params only")
                defaults.update({
                    (k if k in _SPEC_WIDE_KEYS else f"default_{k}"): v
                    for k, v in kw.items()})
            elif set(kw) & _SPEC_WIDE_KEYS:
                raise ValueError(
                    f"clause {clause!r}: {sorted(set(kw) & _SPEC_WIDE_KEYS)} "
                    f"are spec-wide; put them in a 'defaults:' clause")
            else:
                rules.append(CompressionRule(pattern=pattern.strip(), **kw))
        return cls(rules=tuple(rules), source=text, **defaults).validate()

    def describe(self) -> str:
        """Canonical spec text: rules + a ``defaults:`` clause.  Built from
        the resolved fields — NOT the raw ``source`` — so defaults passed to
        ``parse()`` as keyword arguments (e.g. serve.py's per-channel) are
        recorded and ``parse(describe())`` round-trips with identical
        semantics."""
        parts = []
        for r in self.rules:
            opts = ("fp32" if r.keep_fp32 else ",".join(
                f"{k}={v}" for k, v in [
                    ("bits", r.bits), ("codec", r.codec),
                    ("granularity", r.granularity.value if r.granularity
                     else None),
                    ("group", r.group),
                    ("scheme", r.scheme.value if r.scheme else None),
                ] if v is not None))
            parts.append(f"{r.pattern}:{opts}")
        # 'defaults', NOT a '*' rule: a catch-all rule would override the
        # keep-fp32 predicate the original spec's defaults preserved
        field_defaults = {f.name: f.default for f in dataclasses.fields(self)}
        extras = "".join(
            f",{k}={getattr(self, k)}" for k in sorted(_SPEC_WIDE_KEYS)
            if getattr(self, k) != field_defaults[k])
        parts.append(f"defaults:bits={self.default_bits}"
                     f",codec={self.default_codec}"
                     f",granularity={self.default_granularity.value}"
                     f",group={self.default_group}" + extras)
        return "; ".join(parts)


@dataclasses.dataclass(frozen=True)
class KVCompressionSpec:
    """Paged KV-cache compression policy (the ``--kv-spec`` CLI surface).

    The weight-side :class:`CompressionSpec` is an ordered per-tensor rule
    list; the KV cache needs far less machinery — one uniform policy covers
    every block, because blocks are interchangeable units of one pool:

    * ``bits`` — in-pool precision: 16 keeps dense bf16 blocks (paged layout
      only, bit-identical to the slot pool), 8/4 quantize each block's K/V
      per (token, head) with an asymmetric grid
      (:func:`repro.models.layers.kv_quantize` — the jnp twin of
      :func:`repro.core.quant.quantize`'s ASYMMETRIC scheme);
    * ``block_size`` — tokens per block (the paging granularity);
    * ``codec`` — optional cold-tier entropy codec (``huffman`` / ``rans`` /
      ``raw`` from the codec registry): evicted shared blocks are
      entropy-coded to host bytes instead of dropped, so a prefix hit on a
      cold block costs one serial decode instead of a re-prefill.  Quantized
      pools only — there is no sub-bf16 symbol alphabet to code at bits=16;
    * ``sharing`` — content-hash prefix sharing of full, immutable prompt
      blocks across requests (docs/KV_CACHE.md has the COW rules).

    Grammar (comma-separated, mirroring one ``CompressionSpec`` clause)::

        opt  := 'sharing' | INT                  # bare int = bits
              | ('bits'|'block'|'codec'|'sharing') '=' value

    e.g. ``"bits=4,block=16,codec=rans,sharing"``.  ``validate()`` checks
    the codec against the registry upfront (same contract as
    ``CompressionSpec.validate``); ``describe()`` round-trips.
    """

    bits: int = 16
    block_size: int = 16
    codec: Optional[str] = None
    sharing: bool = False
    source: Optional[str] = None    # the parsed text, for provenance

    @classmethod
    def parse(cls, text: str, **overrides) -> "KVCompressionSpec":
        kw: dict = {}
        for opt in filter(None, (o.strip() for o in text.split(","))):
            key, eq, value = opt.partition("=")
            key = key.strip().lower()
            value = value.strip()
            if not eq:
                if key == "sharing":
                    kw["sharing"] = True
                elif key.isdigit():
                    kw["bits"] = int(key)
                else:
                    raise ValueError(
                        f"bad kv-spec option {opt!r}: expected sharing / "
                        f"<bits> / bits=/block=/codec=/sharing=")
            elif key == "bits":
                kw["bits"] = int(value)
            elif key in ("block", "block_size"):
                kw["block_size"] = int(value)
            elif key == "codec":
                kw["codec"] = None if value.lower() in ("", "none") else value
            elif key == "sharing":
                kw["sharing"] = value.lower() in ("1", "true", "yes", "on")
            else:
                raise ValueError(f"unknown kv-spec key {key!r} in {text!r}")
        kw.update(overrides)
        return cls(source=text, **kw).validate()

    def validate(self) -> "KVCompressionSpec":
        if self.bits not in (16, 8, 4):
            raise ValueError(f"kv bits must be 16 (dense), 8, or 4; got "
                             f"{self.bits!r}"
                             + (f" (kv-spec: {self.source})"
                                if self.source else ""))
        if not (isinstance(self.block_size, int) and self.block_size >= 1):
            raise ValueError(f"kv block_size must be >= 1, got "
                             f"{self.block_size!r}")
        if self.codec is not None:
            from . import codecs
            codecs.get_codec(self.codec)     # raises with the registered list
            if self.bits == 16:
                raise ValueError(
                    "kv codec (cold-block entropy coding) needs a quantized "
                    "pool: entropy coding targets the uint8 symbol stream, "
                    "so set bits=8 or bits=4 alongside codec="
                    + (f" (kv-spec: {self.source})" if self.source else ""))
        return self

    def describe(self) -> str:
        """Canonical spec text; ``parse(describe())`` round-trips."""
        s = f"bits={self.bits},block={self.block_size}"
        if self.codec:
            s += f",codec={self.codec}"
        if self.sharing:
            s += ",sharing"
        return s


def spec_from_legacy(bits: int = 8,
                     granularity: quant.Granularity = quant.Granularity.PER_TENSOR,
                     *, codec: str = "huffman",
                     segment_symbols: int = DEFAULT_SEGMENT_SYMBOLS,
                     max_code_len: int = 12) -> CompressionSpec:
    """The pre-spec ``compress(bits=, granularity=)`` call, as a spec."""
    return CompressionSpec(default_bits=bits, default_codec=codec,
                           default_granularity=granularity,
                           segment_symbols=segment_symbols,
                           max_code_len=max_code_len)
