"""``huffman`` codec: the paper's canonical length-limited Huffman path.

A thin :class:`~repro.core.codecs.base.CodeTable` adapter over
:class:`repro.core.entropy.HuffmanTable` — the code construction
(package-merge length limiting, canonical codes, peek-LUT) is unchanged from
the paper reproduction; this module only gives it the pluggable-codec shape
(DESIGN.md §7) so it can sit beside ``rans`` and ``raw`` in a v2 container.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from ..entropy import HuffmanTable
from .base import CodeTable

DEFAULT_MAX_CODE_LEN = 12


class HuffmanCodeTable(CodeTable):
    codec_name = "huffman"
    kernel = "prefix"

    def __init__(self, freqs: np.ndarray, bits: int,
                 max_len: int = DEFAULT_MAX_CODE_LEN):
        self.bits = int(bits)
        self.table = HuffmanTable(np.asarray(freqs, dtype=np.int64),
                                  max_len=max_len)
        self.freqs = self.table.freqs

    # legacy peek width: the prefix kernels window this many bits per symbol
    @property
    def peek_bits(self) -> int:
        return self.table.max_len

    def encode(self, symbols: np.ndarray):
        return self.table.encode(symbols)

    def decode_arrays(self) -> Dict[str, np.ndarray]:
        return {"lut_sym": self.table.lut_sym, "lut_len": self.table.lut_len}

    @property
    def effective_bits(self) -> float:
        return self.table.effective_bits

    def to_manifest(self) -> dict:
        return {"codec": self.codec_name, "bits": self.bits,
                "max_len": self.table.max_len}

    def to_arrays(self) -> Dict[str, np.ndarray]:
        return {"freqs": self.freqs}

    @classmethod
    def from_container(cls, manifest: dict,
                       arrays: Dict[str, np.ndarray]) -> "HuffmanCodeTable":
        return cls(arrays["freqs"], bits=int(manifest["bits"]),
                   max_len=int(manifest["max_len"]))


def build(freqs: np.ndarray, bits: int, *,
          max_code_len: int = DEFAULT_MAX_CODE_LEN) -> HuffmanCodeTable:
    return HuffmanCodeTable(freqs, bits, max_len=max_code_len)
