"""Pluggable entropy-codec registry (DESIGN.md §7).

Mirrors :mod:`repro.core.decode_backends`: the *coder* choice becomes a
named, first-class decision instead of a hard-wired Huffman import.  A codec
is a (name, table builder) pair; building yields a
:class:`~repro.core.codecs.base.CodeTable` that owns encode, the decode
lookup arrays, and its serialization — one table per ``(codec, bits)`` group
in a v2 container (mixed 4/8-bit symbols cannot share one histogram).

Registered codecs:

* ``huffman`` — the paper's canonical length-limited Huffman code (prefix
  kernel family; today's default).
* ``rans`` — tANS/FSE fractional-bit coder (tans kernel family); closes the
  integer-bit gap to the Shannon bound on peaky histograms.
* ``raw`` — fixed-width bit packing (prefix family, identity LUT); the
  "quantized only" baseline row of Table I.

``get_codec(name)`` raises with the registered list on unknown names so CLI
misconfiguration is loud (``launch/serve.py`` validates ``--codec`` /
``--compress-spec`` upfront, like ``--decode-backend``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from .base import CodeTable
from . import huffman as _huffman
from . import rans as _rans
from . import raw as _raw
from .huffman import HuffmanCodeTable
from .rans import RansCodeTable
from .raw import RawCodeTable


@dataclasses.dataclass(frozen=True)
class EntropyCodec:
    """A named entropy coder: builds tables and revives them from containers.

    ``build(freqs, bits, **kw) -> CodeTable``; ``kw`` is codec-specific
    (``max_code_len`` for huffman, ``table_log`` for rans) and unknown keys
    are ignored by each builder.
    """

    name: str
    build: Callable[..., CodeTable]
    table_cls: type

    def from_container(self, manifest: dict,
                       arrays: Dict[str, np.ndarray]) -> CodeTable:
        return self.table_cls.from_container(manifest, arrays)


_REGISTRY: Dict[str, EntropyCodec] = {}


def register_codec(codec: EntropyCodec) -> EntropyCodec:
    _REGISTRY[codec.name] = codec
    return codec


def codec_names() -> List[str]:
    return sorted(_REGISTRY)


def get_codec(name: str) -> EntropyCodec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown entropy codec {name!r}; "
                       f"registered: {codec_names()}") from None


def table_from_container(manifest: dict,
                         arrays: Dict[str, np.ndarray]) -> CodeTable:
    """Revive a serialized table: manifest['codec'] routes to its codec."""
    return get_codec(manifest["codec"]).from_container(manifest, arrays)


register_codec(EntropyCodec(name="huffman", build=_huffman.build,
                            table_cls=HuffmanCodeTable))
register_codec(EntropyCodec(name="rans", build=_rans.build,
                            table_cls=RansCodeTable))
register_codec(EntropyCodec(name="raw", build=_raw.build,
                            table_cls=RawCodeTable))

__all__ = [
    "CodeTable", "EntropyCodec", "HuffmanCodeTable", "RansCodeTable",
    "RawCodeTable", "register_codec", "codec_names", "get_codec",
    "table_from_container",
]
