"""``raw`` codec: fixed-width bit packing, the no-entropy-coding baseline.

Every symbol is stored in exactly ``bits`` bits.  Implemented as a degenerate
*prefix* code — all code lengths equal ``bits`` and the canonical code values
are the symbols themselves — so raw containers decode through the very same
LUT kernels as Huffman on every backend, with a ``2**bits``-entry identity
LUT.  This is the "quantized only" row of the paper's Table I: achieved bits
== ``bits`` by construction, making the entropy-coded savings of ``huffman``
and ``rans`` directly measurable against it (DESIGN.md §7).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from ..bitstream import encode_symbols
from ..entropy import build_decode_lut
from .base import CodeTable


class RawCodeTable(CodeTable):
    codec_name = "raw"
    kernel = "prefix"

    def __init__(self, freqs: np.ndarray, bits: int):
        self.bits = int(bits)
        self.freqs = np.asarray(freqs, dtype=np.int64)
        n = 1 << self.bits
        assert self.freqs.size == n, (self.freqs.size, n)
        self.lengths = np.full(n, self.bits, dtype=np.int32)
        self.codes = np.arange(n, dtype=np.uint32)   # canonical == identity
        self.lut_sym, self.lut_len = build_decode_lut(
            self.lengths, self.codes, max_len=self.bits)

    @property
    def peek_bits(self) -> int:
        return self.bits

    def encode(self, symbols: np.ndarray):
        return encode_symbols(symbols, self.codes, self.lengths)

    def decode_arrays(self) -> Dict[str, np.ndarray]:
        return {"lut_sym": self.lut_sym, "lut_len": self.lut_len}

    @property
    def effective_bits(self) -> float:
        return float(self.bits)

    def to_manifest(self) -> dict:
        return {"codec": self.codec_name, "bits": self.bits}

    def to_arrays(self) -> Dict[str, np.ndarray]:
        return {"freqs": self.freqs}

    @classmethod
    def from_container(cls, manifest: dict,
                       arrays: Dict[str, np.ndarray]) -> "RawCodeTable":
        return cls(arrays["freqs"], bits=int(manifest["bits"]))


def build(freqs: np.ndarray, bits: int, **_kw) -> RawCodeTable:
    return RawCodeTable(freqs, bits)
