"""``rans`` codec: table-based asymmetric numeral system (tANS / FSE-style).

Why a second entropy coder (DESIGN.md §7): Huffman assigns an *integer*
number of bits per symbol, so its redundancy vs. the Shannon bound grows as
the histogram gets peaky — exactly the regime EntroLLM quantization produces
(and the regime Huff-LLM / Shannon-bound followup work targets).  tANS codes
at *fractional* bits per symbol: its redundancy is the KL divergence between
the true histogram and the table-normalized one, ~``O(1/L)`` for an
``L``-state table, plus a 16-bit per-segment state header.

Construction (the classic FSE recipe, built deterministically from the raw
histogram so the container only ships frequencies, like Huffman):

1. **Normalize** the histogram to sum exactly ``L = 2**table_log`` with every
   present symbol >= 1 slot, greedily minimizing KL cost per slot moved.
2. **Spread** each symbol's slots over the state table with the odd-stride
   walk ``pos += (L>>1) + (L>>3) + 3  (mod L)``.
3. **Decode tables** — for state ``x`` (index in ``[0, L)``), the slot's
   symbol, its occurrence rank gives ``x_sub ∈ [n_s, 2·n_s)``, and
   ``nbits = table_log - floor(log2(x_sub))`` renormalizes:
   ``state' = (x_sub << nbits) - L + read_bits(nbits)``.
4. **Encode table** — the inverse map, walked symbol-by-symbol in *reverse*
   order (ANS is LIFO); emitted bit chunks are flushed in forward order so
   the decoder streams MSB-first like every other codec here.

Decoding is one more lock-step loop family (``kernel = "tans"``): per lane,
gather (symbol, nbits, base) by carried state, read ``nbits`` fresh bits,
fold into the next state — structurally the Huffman peek-LUT loop with one
extra carried register, which is why all three backends (numpy / jit /
Pallas) host it next to their prefix loops.  Encoding is state-serial per
segment (inherent to ANS) and runs on the host at container-build time only.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from ..bitstream import GUARD_BYTES, TANS_STATE_HEADER_BITS, pack_bit_chunks
from .base import CodeTable

# 4096 states for 8-bit symbols (the Huffman LUT's footprint class), 1024 for
# 4-bit — normalization error is already far below Huffman's integer-bit loss
DEFAULT_TABLE_LOG_CAP = 12


def default_table_log(bits: int) -> int:
    return min(DEFAULT_TABLE_LOG_CAP, bits + 6)


def normalize_freqs(freqs: np.ndarray, table_log: int) -> np.ndarray:
    """Scale a histogram to sum exactly ``2**table_log``.

    Every symbol with nonzero frequency keeps >= 1 slot (losslessness), and
    the residual slots are moved one at a time to the symbol where the move
    costs/gains the least KL — the per-slot greedy is optimal for this
    separable convex objective.
    """
    L = 1 << table_log
    f = np.asarray(freqs, dtype=np.int64)
    nz = np.nonzero(f)[0]
    if len(nz) == 0:
        raise ValueError("cannot build a tANS table from an empty histogram")
    if len(nz) > L:
        raise ValueError(f"{len(nz)} symbols cannot fit {L} tANS states")
    w = f[nz].astype(np.float64)
    n = np.maximum(1, np.rint(w * L / w.sum())).astype(np.int64)
    diff = L - int(n.sum())
    while diff != 0:
        if diff > 0:
            gain = w * np.log2((n + 1) / n)
            i = int(np.argmax(gain))
            n[i] += 1
            diff -= 1
        else:
            cost = np.where(n > 1, w * np.log2(n / np.maximum(n - 1, 1)), np.inf)
            i = int(np.argmin(cost))
            n[i] -= 1
            diff += 1
    norm = np.zeros_like(f)
    norm[nz] = n
    return norm


def build_tans_tables(norm: np.ndarray, table_log: int) -> Dict[str, np.ndarray]:
    """Spread + decode/encode tables from a normalized histogram."""
    L = 1 << table_log
    assert int(norm.sum()) == L, (int(norm.sum()), L)
    step = (L >> 1) + (L >> 3) + 3          # odd => coprime with L
    if step % 2 == 0:
        # L=2 and L=8 make the stride even (shares factor 2 with L): the
        # walk would revisit states and leave others uninitialized
        raise ValueError(f"table_log={table_log} too small for the spread "
                         f"stride; use table_log >= 4")
    spread = np.empty(L, dtype=np.int32)
    pos = 0
    for s in np.nonzero(norm)[0]:
        for _ in range(int(norm[s])):
            spread[pos] = s
            pos = (pos + step) & (L - 1)
    assert pos == 0                          # full cycle covers every state

    cumul = np.zeros(len(norm) + 1, dtype=np.int64)
    cumul[1:] = np.cumsum(norm)
    tab_bits = np.empty(L, dtype=np.int32)
    tab_base = np.empty(L, dtype=np.int32)
    enc_state = np.empty(L, dtype=np.int64)
    occ = np.zeros(len(norm), dtype=np.int64)
    for i in range(L):
        s = int(spread[i])
        x_sub = int(norm[s] + occ[s])        # in [norm_s, 2*norm_s)
        occ[s] += 1
        nb = table_log - x_sub.bit_length() + 1
        tab_bits[i] = nb
        tab_base[i] = (x_sub << nb) - L
        enc_state[cumul[s] + x_sub - norm[s]] = i
    return {"tab_sym": spread, "tab_bits": tab_bits, "tab_base": tab_base,
            "enc_state": enc_state, "cumul": cumul}


class RansCodeTable(CodeTable):
    codec_name = "rans"
    kernel = "tans"

    def __init__(self, freqs: np.ndarray, bits: int, table_log: int = None):
        self.bits = int(bits)
        self.freqs = np.asarray(freqs, dtype=np.int64)
        self.table_log = int(table_log if table_log is not None
                             else default_table_log(self.bits))
        if self.table_log > TANS_STATE_HEADER_BITS:
            # the initial decoder state ships in a fixed 16-bit stream
            # header; a larger state space would truncate silently
            raise ValueError(
                f"table_log={self.table_log} exceeds the "
                f"{TANS_STATE_HEADER_BITS}-bit stream state header")
        self.norm = normalize_freqs(self.freqs, self.table_log)
        t = build_tans_tables(self.norm, self.table_log)
        self.tab_sym = t["tab_sym"]
        self.tab_bits = t["tab_bits"]
        self.tab_base = t["tab_base"]
        self._enc_state = t["enc_state"]
        self._cumul = t["cumul"]
        # per-symbol encode constants: nbits = maxbits - (x < min_state_plus)
        safe = np.maximum(self.norm, 1)
        self._maxbits = np.array(
            [self.table_log - (int(v).bit_length() - 1) for v in safe],
            dtype=np.int64)
        self._min_state_plus = safe << self._maxbits

    # ----------------------------------------------------------------- encode
    def encode(self, symbols: np.ndarray) -> Tuple[np.ndarray, int]:
        """State-serial reverse-order tANS encode of one segment.

        Stream layout: 16-bit initial decoder state, then per-symbol
        renormalization chunks in decode order, MSB-first, guard-padded.
        """
        symbols = np.asarray(symbols, dtype=np.uint8).reshape(-1)
        if symbols.size == 0:
            return np.zeros(GUARD_BYTES, dtype=np.uint8), 0
        L = 1 << self.table_log
        # plain-int lists: the state feedback loop is scalar, and Python ints
        # beat numpy scalar ops ~5x here
        enc_state = self._enc_state.tolist()
        cumul = self._cumul.tolist()
        norm = self.norm.tolist()
        maxbits = self._maxbits.tolist()
        msp = self._min_state_plus.tolist()
        x = L
        vals = np.empty(symbols.size + 1, dtype=np.uint64)
        nbs = np.empty(symbols.size + 1, dtype=np.int64)
        j = symbols.size
        for s in symbols[::-1].tolist():
            nb = maxbits[s] - (1 if x < msp[s] else 0)
            vals[j] = x & ((1 << nb) - 1)
            nbs[j] = nb
            x_sub = x >> nb
            x = L + enc_state[cumul[s] + x_sub - norm[s]]
            j -= 1
        vals[0] = x - L                       # initial decoder state
        nbs[0] = TANS_STATE_HEADER_BITS
        stream, total = pack_bit_chunks(vals, nbs)
        return stream, total

    # ----------------------------------------------------------------- decode
    def decode_arrays(self) -> Dict[str, np.ndarray]:
        return {"tab_sym": self.tab_sym, "tab_bits": self.tab_bits,
                "tab_base": self.tab_base}

    @property
    def effective_bits(self) -> float:
        """Cross-entropy of the true histogram against the normalized table —
        the asymptotic tANS rate (headers excluded; stats report achieved)."""
        mask = self.freqs > 0
        p = self.freqs[mask].astype(np.float64)
        p /= p.sum()
        q = self.norm[mask].astype(np.float64) / (1 << self.table_log)
        return float(-(p * np.log2(q)).sum())

    # -------------------------------------------------------------- serialize
    def to_manifest(self) -> dict:
        return {"codec": self.codec_name, "bits": self.bits,
                "table_log": self.table_log}

    def to_arrays(self) -> Dict[str, np.ndarray]:
        return {"freqs": self.freqs}

    @classmethod
    def from_container(cls, manifest: dict,
                       arrays: Dict[str, np.ndarray]) -> "RansCodeTable":
        return cls(arrays["freqs"], bits=int(manifest["bits"]),
                   table_log=int(manifest["table_log"]))


def build(freqs: np.ndarray, bits: int, *, table_log: int = None,
          **_kw) -> RansCodeTable:
    return RansCodeTable(freqs, bits, table_log=table_log)
