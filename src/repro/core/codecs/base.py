"""CodeTable: the contract every entropy codec's table satisfies.

A *codec* (``huffman`` / ``rans`` / ``raw``; see the registry in
``repro.core.codecs``) builds a :class:`CodeTable` from a symbol histogram.
The table owns both directions of the transform for one group of tensors
(one ``(codec, bits)`` group in a container — DESIGN.md §7):

* ``encode(symbols)`` — one flat uint8 symbol array to one guard-padded byte
  stream (the per-segment unit of ``core.segmentation``).
* ``decode_arrays()`` + ``kernel`` — the lookup tables and the *kernel
  family* name a :class:`repro.core.decode_backends.DecoderBackend` needs to
  run the matching lock-step multi-stream decode loop.  Two families exist:
  ``"prefix"`` (peek ``peek_bits``, gather (symbol, length) — Huffman and the
  raw bit-packed baseline) and ``"tans"`` (carried per-lane state, gather
  (symbol, nbits, base) — the tANS coder).

Tables serialize as (JSON scalars, numpy arrays) pairs and must rebuild
*deterministically* from them — the container stores histograms, never code
words, exactly like the paper ships only its frequency table.
"""
from __future__ import annotations

import abc
from typing import Dict, Tuple

import numpy as np


class CodeTable(abc.ABC):
    """One codec's built code table for one symbol alphabet.

    Attributes (set by subclasses):
      codec_name: registry name of the codec that built this table.
      kernel: decode-kernel family, ``"prefix"`` or ``"tans"``.
      bits: symbol bit-width this table covers (alphabet = ``2**bits``).
      freqs: (2**bits,) int64 histogram the table was built from.
    """

    codec_name: str
    kernel: str
    bits: int
    freqs: np.ndarray

    @property
    def num_symbols(self) -> int:
        return 1 << self.bits

    # ----------------------------------------------------------------- encode
    @abc.abstractmethod
    def encode(self, symbols: np.ndarray) -> Tuple[np.ndarray, int]:
        """Encode flat uint8 symbols -> (guard-padded uint8 stream, payload bits)."""

    # ----------------------------------------------------------------- decode
    @abc.abstractmethod
    def decode_arrays(self) -> Dict[str, np.ndarray]:
        """The lookup arrays the ``kernel`` family's decode loop gathers from."""

    # ------------------------------------------------------------------ rates
    @property
    def entropy(self) -> float:
        from ..entropy import shannon_entropy
        return shannon_entropy(self.freqs)

    @property
    @abc.abstractmethod
    def effective_bits(self) -> float:
        """Expected bits/symbol under this table (the paper's 'Effective Bits');
        container stats report the *achieved* payload bits separately."""

    # -------------------------------------------------------------- serialize
    @abc.abstractmethod
    def to_manifest(self) -> dict:
        """JSON-scalar parameters (codec name included) for the container manifest."""

    @abc.abstractmethod
    def to_arrays(self) -> Dict[str, np.ndarray]:
        """Numpy arrays to store alongside the manifest entry."""
