"""Bit-level encode / decode of entropy-coded symbol streams.

Layout: MSB-first bit order inside a byte stream (matches ``np.packbits``), each
segment's stream byte-aligned and padded with >= 4 guard bytes so a decoder can always
load a 32-bit window.

Decoding is **multi-stream**: N independent segments advance in lock-step, one symbol
per iteration, via a single gather into the code tables.  This is the TPU-native
re-interpretation of the paper's thread-parallel decoding (§III-C): the paper gives each
CPU thread one segment; we give each *vector lane* one segment (numpy / jnp / Pallas all
share this structure).  Because segments hold a fixed number of SYMBOLS (not bits), every
lane finishes in exactly the same number of iterations — the LUT decoder is perfectly
load-balanced by construction, which subsumes the paper's shuffling heuristic (that
heuristic targets bit-serial decoders whose per-segment time varies with encoded bits).

Two lock-step loop families live here (DESIGN.md §7):

* ``decode_streams`` — the **prefix** family (canonical Huffman and the raw
  bit-packed baseline): peek ``max_len`` bits, gather (symbol, length),
  advance by the length.
* ``decode_streams_tans`` — the **tans** family (tANS / rANS): a carried
  per-lane state indexes (symbol, nbits, base) tables; ``nbits`` fresh bits
  are read per symbol and folded into the next state.  The 16-bit stream
  header holds the initial state.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

GUARD_BYTES = 4


def pow2_bucket(n: int, floor: int) -> int:
    """Round ``n`` up to a power of two >= floor.  Chunked decode callers
    bucket matrix shapes with this so shape-specialized (jit / Pallas)
    decoders compile once per bucket instead of once per chunk geometry."""
    b = floor
    while b < n:
        b <<= 1
    return b


def pack_bit_chunks(vals: np.ndarray, nbits: np.ndarray) -> Tuple[np.ndarray, int]:
    """Concatenate variable-width bit chunks MSB-first into a guard-padded stream.

    ``vals[i]`` contributes its low ``nbits[i]`` bits (written MSB-first).
    Returns (packed uint8 stream with guard padding, total bits).  This is the
    one bit-packer every encoder shares: Huffman/raw code words and tANS
    renormalization chunks differ only in how (vals, nbits) are produced.
    """
    vals = np.asarray(vals, dtype=np.uint64).reshape(-1)
    nbits = np.asarray(nbits, dtype=np.int64).reshape(-1)
    if vals.size == 0 or int(nbits.sum()) == 0:
        return np.zeros(GUARD_BYTES, dtype=np.uint8), 0
    offs = np.concatenate([[0], np.cumsum(nbits)])
    total = int(offs[-1])
    # bit i belongs to chunk reps[i], at position bitpos[i] within it (MSB first)
    reps = np.repeat(np.arange(vals.size), nbits)
    bitpos = np.arange(total, dtype=np.int64) - offs[reps]
    bits = (vals[reps] >> (nbits[reps] - 1 - bitpos).astype(np.uint64)) & 1
    packed = np.packbits(bits.astype(np.uint8))
    packed = np.concatenate([packed, np.zeros(GUARD_BYTES, dtype=np.uint8)])
    return packed, total


def encode_symbols(symbols: np.ndarray, codes: np.ndarray, lengths: np.ndarray
                   ) -> Tuple[np.ndarray, int]:
    """Vectorized prefix-code (Huffman / raw) encode of a flat uint8 symbol array.

    Returns (packed uint8 stream with guard padding, total bits).
    """
    symbols = symbols.reshape(-1)
    if symbols.size == 0:
        return np.zeros(GUARD_BYTES, dtype=np.uint8), 0
    return pack_bit_chunks(codes[symbols], lengths[symbols])


def decode_serial(stream: np.ndarray, count: int, lut_sym: np.ndarray, lut_len: np.ndarray,
                  max_len: int) -> np.ndarray:
    """Bit-serial reference decoder (oracle for the vectorized paths)."""
    out = np.zeros(count, dtype=np.int32)
    bitpos = 0
    mask = (1 << max_len) - 1
    s = stream.astype(np.uint32)
    for k in range(count):
        byte = bitpos >> 3
        window = (int(s[byte]) << 24) | (int(s[byte + 1]) << 16) \
            | (int(s[byte + 2]) << 8) | int(s[byte + 3])
        peek = (window >> (32 - max_len - (bitpos & 7))) & mask
        out[k] = lut_sym[peek]
        bitpos += int(lut_len[peek])
    return out


def pack_streams(streams: Sequence[np.ndarray], *, min_width: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Stack variable-length byte streams into a (S, max_bytes) matrix + byte lengths.

    ``min_width`` lets chunked callers pad every chunk's matrix to a common
    (e.g. power-of-two) width so shape-specialized decoders reuse one compile.
    """
    lens = np.array([len(s) for s in streams], dtype=np.int64)
    width = max(int(lens.max(initial=GUARD_BYTES)), int(min_width))
    mat = np.zeros((len(streams), width), dtype=np.uint8)
    for i, s in enumerate(streams):
        mat[i, : len(s)] = s
    return mat, lens


def _decode_out(S: int, max_n: int, out: "np.ndarray | None") -> np.ndarray:
    """Resolve the output buffer of a lock-step decode: allocate when ``out``
    is None, else validate and zero a ``(S, max_n)`` view of the caller's
    preallocated buffer (the decode-into-buffer serving path — the
    compressed-resident per-layer decode reuses ONE scratch buffer instead of
    allocating per layer)."""
    if out is None:
        return np.zeros((S, max_n), dtype=np.int32)
    if out.dtype != np.int32 or out.shape[0] < S or out.shape[1] < max_n:
        raise ValueError(
            f"decode out buffer {out.dtype}{out.shape} too small for "
            f"({S}, {max_n}) int32")
    view = out[:S, :max_n]
    view[:] = 0
    return view


def decode_streams(mat: np.ndarray, counts: np.ndarray, lut_sym: np.ndarray,
                   lut_len: np.ndarray, max_len: int, *,
                   out: "np.ndarray | None" = None) -> np.ndarray:
    """Lock-step multi-stream LUT decode (numpy host path).

    mat: (S, B) uint8, each row an independent segment stream (guard-padded).
    counts: (S,) symbols per segment.  Returns (S, max(counts)) int32, rows
    zero-padded past their count.  ``out`` (optional) is a preallocated
    int32 buffer at least that big: symbols are written in place and the
    trimmed view is returned (no per-call allocation).
    """
    S = mat.shape[0]
    d = np.concatenate([mat, np.zeros((S, GUARD_BYTES), np.uint8)], axis=1).astype(np.uint32)
    max_n = int(counts.max(initial=0))
    out = _decode_out(S, max_n, out)
    bitpos = np.zeros(S, dtype=np.int64)
    rows = np.arange(S)
    mask = (1 << max_len) - 1
    for k in range(max_n):
        active = k < counts
        byte = bitpos >> 3
        window = (
            (d[rows, byte] << 24)
            | (d[rows, byte + 1] << 16)
            | (d[rows, byte + 2] << 8)
            | d[rows, byte + 3]
        )
        shift = (32 - max_len - (bitpos & 7)).astype(np.uint32)
        peek = (window >> shift) & mask
        sym = lut_sym[peek]
        out[active, k] = sym[active]
        bitpos = np.where(active, bitpos + lut_len[peek], bitpos)
    return out


TANS_STATE_HEADER_BITS = 16   # stream-leading initial decoder state (MSB-first)


def decode_serial_tans(stream: np.ndarray, count: int, tab_sym: np.ndarray,
                       tab_bits: np.ndarray, tab_base: np.ndarray,
                       table_log: int) -> np.ndarray:
    """Bit-serial tANS reference decoder (oracle for the vectorized paths).

    ``tab_*`` are the (2^table_log,) state-indexed decode tables built by
    :mod:`repro.core.codecs.rans`; the stream's first 16 bits hold the
    initial state index.
    """
    out = np.zeros(count, dtype=np.int32)
    s = stream.astype(np.uint32)
    st = (int(s[0]) << 8) | int(s[1])          # 16-bit header
    bitpos = TANS_STATE_HEADER_BITS
    for k in range(count):
        out[k] = tab_sym[st]
        nb = int(tab_bits[st])
        byte = bitpos >> 3
        window = (int(s[byte]) << 24) | (int(s[byte + 1]) << 16) \
            | (int(s[byte + 2]) << 8) | int(s[byte + 3])
        peek = (window >> (32 - table_log - (bitpos & 7))) & ((1 << table_log) - 1)
        st = int(tab_base[st]) + (peek >> (table_log - nb))
        bitpos += nb
    return out


def decode_streams_tans(mat: np.ndarray, counts: np.ndarray, tab_sym: np.ndarray,
                        tab_bits: np.ndarray, tab_base: np.ndarray,
                        table_log: int, *,
                        out: "np.ndarray | None" = None) -> np.ndarray:
    """Lock-step multi-stream tANS decode (numpy host path).

    Same shape contract as :func:`decode_streams` — mat: (S, B) uint8
    guard-padded streams, counts: (S,) symbols per segment — but the gather
    target is the state-indexed (symbol, nbits, base) tables and each lane
    carries its ANS state: ``sym = tab_sym[state]``, read ``tab_bits[state]``
    fresh bits ``b``, ``state' = tab_base[state] + b``.  Lanes with zero
    counts (bucket padding) idle on state 0 harmlessly.  ``out`` is the
    same optional preallocated-buffer contract as :func:`decode_streams`.
    """
    S = mat.shape[0]
    d = np.concatenate([mat, np.zeros((S, GUARD_BYTES), np.uint8)], axis=1).astype(np.uint32)
    max_n = int(counts.max(initial=0))
    out = _decode_out(S, max_n, out)
    rows = np.arange(S)
    st = ((d[:, 0].astype(np.int64) << 8) | d[:, 1]).astype(np.int64)
    bitpos = np.full(S, TANS_STATE_HEADER_BITS, dtype=np.int64)
    mask = (1 << table_log) - 1
    for k in range(max_n):
        active = k < counts
        sym = tab_sym[st]
        nb = tab_bits[st]
        byte = bitpos >> 3
        window = (
            (d[rows, byte] << 24)
            | (d[rows, byte + 1] << 16)
            | (d[rows, byte + 2] << 8)
            | d[rows, byte + 3]
        )
        shift = (32 - table_log - (bitpos & 7)).astype(np.uint32)
        peek = (window >> shift) & mask
        fresh = peek >> (table_log - nb).astype(np.uint32)
        out[active, k] = sym[active]
        st = np.where(active, tab_base[st] + fresh, st)
        bitpos = np.where(active, bitpos + nb, bitpos)
    return out
