"""Bit-level encode / decode of Huffman symbol streams.

Layout: MSB-first bit order inside a byte stream (matches ``np.packbits``), each
segment's stream byte-aligned and padded with >= 4 guard bytes so a decoder can always
load a 32-bit window.

Decoding is **multi-stream**: N independent segments advance in lock-step, one symbol
per iteration, via a single gather into the canonical-code LUT.  This is the TPU-native
re-interpretation of the paper's thread-parallel decoding (§III-C): the paper gives each
CPU thread one segment; we give each *vector lane* one segment (numpy / jnp / Pallas all
share this structure).  Because segments hold a fixed number of SYMBOLS (not bits), every
lane finishes in exactly the same number of iterations — the LUT decoder is perfectly
load-balanced by construction, which subsumes the paper's shuffling heuristic (that
heuristic targets bit-serial decoders whose per-segment time varies with encoded bits).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

GUARD_BYTES = 4


def pow2_bucket(n: int, floor: int) -> int:
    """Round ``n`` up to a power of two >= floor.  Chunked decode callers
    bucket matrix shapes with this so shape-specialized (jit / Pallas)
    decoders compile once per bucket instead of once per chunk geometry."""
    b = floor
    while b < n:
        b <<= 1
    return b


def encode_symbols(symbols: np.ndarray, codes: np.ndarray, lengths: np.ndarray
                   ) -> Tuple[np.ndarray, int]:
    """Vectorized Huffman encode of a flat uint8 symbol array.

    Returns (packed uint8 stream with guard padding, total bits).
    """
    symbols = symbols.reshape(-1)
    if symbols.size == 0:
        return np.zeros(GUARD_BYTES, dtype=np.uint8), 0
    lens = lengths[symbols].astype(np.int64)
    offs = np.concatenate([[0], np.cumsum(lens)])
    total = int(offs[-1])
    # bit i belongs to symbol reps[i], at position bitpos[i] within its code (MSB first)
    reps = np.repeat(np.arange(symbols.size), lens)
    bitpos = np.arange(total, dtype=np.int64) - offs[reps]
    syms_r = symbols[reps]
    bits = (codes[syms_r].astype(np.uint32) >> (lens[reps] - 1 - bitpos).astype(np.uint32)) & 1
    packed = np.packbits(bits.astype(np.uint8))
    packed = np.concatenate([packed, np.zeros(GUARD_BYTES, dtype=np.uint8)])
    return packed, total


def decode_serial(stream: np.ndarray, count: int, lut_sym: np.ndarray, lut_len: np.ndarray,
                  max_len: int) -> np.ndarray:
    """Bit-serial reference decoder (oracle for the vectorized paths)."""
    out = np.zeros(count, dtype=np.int32)
    bitpos = 0
    mask = (1 << max_len) - 1
    s = stream.astype(np.uint32)
    for k in range(count):
        byte = bitpos >> 3
        window = (int(s[byte]) << 24) | (int(s[byte + 1]) << 16) \
            | (int(s[byte + 2]) << 8) | int(s[byte + 3])
        peek = (window >> (32 - max_len - (bitpos & 7))) & mask
        out[k] = lut_sym[peek]
        bitpos += int(lut_len[peek])
    return out


def pack_streams(streams: Sequence[np.ndarray], *, min_width: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Stack variable-length byte streams into a (S, max_bytes) matrix + byte lengths.

    ``min_width`` lets chunked callers pad every chunk's matrix to a common
    (e.g. power-of-two) width so shape-specialized decoders reuse one compile.
    """
    lens = np.array([len(s) for s in streams], dtype=np.int64)
    width = max(int(lens.max(initial=GUARD_BYTES)), int(min_width))
    mat = np.zeros((len(streams), width), dtype=np.uint8)
    for i, s in enumerate(streams):
        mat[i, : len(s)] = s
    return mat, lens


def decode_streams(mat: np.ndarray, counts: np.ndarray, lut_sym: np.ndarray,
                   lut_len: np.ndarray, max_len: int) -> np.ndarray:
    """Lock-step multi-stream LUT decode (numpy host path).

    mat: (S, B) uint8, each row an independent segment stream (guard-padded).
    counts: (S,) symbols per segment.  Returns (S, max(counts)) int32, rows
    zero-padded past their count.
    """
    S = mat.shape[0]
    d = np.concatenate([mat, np.zeros((S, GUARD_BYTES), np.uint8)], axis=1).astype(np.uint32)
    max_n = int(counts.max(initial=0))
    out = np.zeros((S, max_n), dtype=np.int32)
    bitpos = np.zeros(S, dtype=np.int64)
    rows = np.arange(S)
    mask = (1 << max_len) - 1
    for k in range(max_n):
        active = k < counts
        byte = bitpos >> 3
        window = (
            (d[rows, byte] << 24)
            | (d[rows, byte + 1] << 16)
            | (d[rows, byte + 2] << 8)
            | d[rows, byte + 3]
        )
        shift = (32 - max_len - (bitpos & 7)).astype(np.uint32)
        peek = (window >> shift) & mask
        sym = lut_sym[peek]
        out[active, k] = sym[active]
        bitpos = np.where(active, bitpos + lut_len[peek], bitpos)
    return out
