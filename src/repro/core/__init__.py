"""EntroLLM core: mixed quantization + global Huffman coding + parallel decoding."""
from . import (bitstream, decode_backends, decode_jax, entropy, quant,
               scheduler, segmentation, store)
from .decode_backends import (DecoderBackend, available_backends,
                              backend_names, get_backend, register_backend)
from .entropy import HuffmanTable
from .quant import Granularity, QuantizedTensor, Scheme, dequantize, quantize
from .scheduler import DEFAULT_CHUNK_SYMBOLS, DecodeScheduler
from .store import CompressedModel, CompressionStats

__all__ = [
    "bitstream", "decode_backends", "decode_jax", "entropy", "quant",
    "scheduler", "segmentation", "store",
    "HuffmanTable", "Granularity", "QuantizedTensor", "Scheme",
    "dequantize", "quantize", "CompressedModel", "CompressionStats",
    "DecoderBackend", "DecodeScheduler", "DEFAULT_CHUNK_SYMBOLS",
    "available_backends", "backend_names", "get_backend", "register_backend",
]
