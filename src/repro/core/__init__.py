"""EntroLLM core: mixed quantization + pluggable entropy coding + parallel decoding."""
from . import (bitstream, codecs, decode_backends, decode_jax, entropy, quant,
               scheduler, segmentation, spec, store)
from .codecs import CodeTable, EntropyCodec, codec_names, get_codec, register_codec
from .decode_backends import (DecoderBackend, available_backends,
                              backend_names, get_backend, register_backend)
from .entropy import HuffmanTable
from .quant import Granularity, QuantizedTensor, Scheme, dequantize, quantize
from .scheduler import DEFAULT_CHUNK_SYMBOLS, DecodeScheduler
from .spec import CompressionRule, CompressionSpec, TensorPolicy
from .store import CodecGroupStats, CompressedModel, CompressionStats

__all__ = [
    "bitstream", "codecs", "decode_backends", "decode_jax", "entropy",
    "quant", "scheduler", "segmentation", "spec", "store",
    "HuffmanTable", "Granularity", "QuantizedTensor", "Scheme",
    "dequantize", "quantize", "CompressedModel", "CompressionStats",
    "CodecGroupStats", "CompressionRule", "CompressionSpec", "TensorPolicy",
    "CodeTable", "EntropyCodec", "codec_names", "get_codec", "register_codec",
    "DecoderBackend", "DecodeScheduler", "DEFAULT_CHUNK_SYMBOLS",
    "available_backends", "backend_names", "get_backend", "register_backend",
]
