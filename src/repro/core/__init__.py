"""EntroLLM core: mixed quantization + global Huffman coding + parallel decoding."""
from . import bitstream, decode_jax, entropy, quant, segmentation, store
from .entropy import HuffmanTable
from .quant import Granularity, QuantizedTensor, Scheme, dequantize, quantize
from .store import CompressedModel, CompressionStats

__all__ = [
    "bitstream", "decode_jax", "entropy", "quant", "segmentation", "store",
    "HuffmanTable", "Granularity", "QuantizedTensor", "Scheme",
    "dequantize", "quantize", "CompressedModel", "CompressionStats",
]
