"""Canonical, length-limited Huffman coding (paper §III-B) with LUT-based decoding.

Design notes (TPU adaptation):

* The paper builds one Huffman tree from the *model-global* symbol frequency table
  (Alg. 1 line 11-12) so a single code describes every layer.  We do the same:
  :func:`global_frequencies` accumulates histograms across all quantized tensors.
* A tree-walk decoder is hostile to vector hardware, so we emit **canonical** codes and
  decode with a ``2^L_max`` lookup table: peek ``L_max`` bits, one gather yields
  (symbol, code length).  ``L_max`` defaults to 12 — small enough that the LUT
  (2 x 4096 int32 = 32 KiB) lives comfortably in VMEM for the Pallas decoder, large
  enough that the length limit costs < 0.01 effective bits on any histogram we see.
* Length limiting uses the package-merge algorithm, which is *optimal* among
  length-limited prefix codes — keeping us as close to the Shannon bound as the paper's
  unlimited Huffman tree in practice.
"""
from __future__ import annotations

import heapq
from typing import Iterable, List, Sequence, Tuple

import numpy as np


def symbol_frequencies(q: np.ndarray, num_symbols: int) -> np.ndarray:
    """Histogram of one tensor's symbols (uint8 values < num_symbols)."""
    return np.bincount(q.reshape(-1), minlength=num_symbols).astype(np.int64)


def global_frequencies(tensors: Iterable[np.ndarray], num_symbols: int) -> np.ndarray:
    """Paper Alg. 1 line 11: one frequency table across the whole model."""
    freqs = np.zeros(num_symbols, dtype=np.int64)
    for q in tensors:
        freqs += symbol_frequencies(q, num_symbols)
    return freqs


def shannon_entropy(freqs: np.ndarray) -> float:
    """Bits/symbol lower bound for any prefix code over this histogram."""
    f = freqs[freqs > 0].astype(np.float64)
    p = f / f.sum()
    return float(-(p * np.log2(p)).sum())


def huffman_code_lengths(freqs: np.ndarray) -> np.ndarray:
    """Unlimited Huffman code lengths via the classic two-queue/heap construction."""
    sym = np.nonzero(freqs)[0]
    if len(sym) == 0:
        return np.zeros_like(freqs, dtype=np.int32)
    if len(sym) == 1:
        lengths = np.zeros(len(freqs), dtype=np.int32)
        lengths[sym[0]] = 1
        return lengths
    # heap of (freq, tiebreak, node); node = int symbol or list of symbols
    heap: List[Tuple[int, int, List[int]]] = []
    for i, s in enumerate(sym):
        heapq.heappush(heap, (int(freqs[s]), i, [int(s)]))
    tie = len(sym)
    lengths = np.zeros(len(freqs), dtype=np.int32)
    while len(heap) > 1:
        fa, _, na = heapq.heappop(heap)
        fb, _, nb = heapq.heappop(heap)
        for s in na:
            lengths[s] += 1
        for s in nb:
            lengths[s] += 1
        heapq.heappush(heap, (fa + fb, tie, na + nb))
        tie += 1
    return lengths


def package_merge_lengths(freqs: np.ndarray, max_len: int) -> np.ndarray:
    """Optimal length-limited code lengths (package-merge / coin-collector).

    Returns lengths (int32) with ``0 < lengths[s] <= max_len`` for every symbol with
    nonzero frequency, satisfying Kraft equality, minimizing sum(freq * length).
    """
    sym = np.nonzero(freqs)[0]
    n = len(sym)
    if n == 0:
        return np.zeros_like(freqs, dtype=np.int32)
    if n == 1:
        lengths = np.zeros(len(freqs), dtype=np.int32)
        lengths[sym[0]] = 1
        return lengths
    if n > (1 << max_len):
        raise ValueError(f"{n} symbols cannot fit in {max_len}-bit codes")

    # Each "coin" is (weight, set-of-symbol-indices). Level l in [1, max_len] holds coins
    # of denomination 2^-l. We must buy n-1 units of value 1 using cheapest packages.
    weights = freqs[sym].astype(np.int64)
    # items at each level: the n symbol coins
    coins = [(int(weights[i]), [i]) for i in range(n)]
    coins.sort(key=lambda c: c[0])
    packages: List[Tuple[int, List[int]]] = []
    for _level in range(max_len):
        merged = sorted(coins + packages, key=lambda c: c[0])
        # pair adjacent to form next-level packages
        packages = [
            (merged[i][0] + merged[i + 1][0], merged[i][1] + merged[i + 1][1])
            for i in range(0, len(merged) - 1, 2)
        ]
    # after max_len rounds, `packages` holds denominative value 1 coins; take n-1 cheapest
    counts = np.zeros(n, dtype=np.int64)
    for _, members in packages[: n - 1]:
        for i in members:
            counts[i] += 1
    lengths = np.zeros(len(freqs), dtype=np.int32)
    lengths[sym] = counts
    return lengths


def code_lengths(freqs: np.ndarray, max_len: int = 12) -> np.ndarray:
    """Huffman lengths, falling back to package-merge only when the limit binds."""
    lengths = huffman_code_lengths(freqs)
    if lengths.max(initial=0) <= max_len:
        return lengths
    return package_merge_lengths(freqs, max_len)


def canonical_codes(lengths: np.ndarray) -> np.ndarray:
    """Canonical (MSB-first) code values for the given lengths.

    Symbols sorted by (length, symbol); codes assigned sequentially.  Canonical form is
    what makes the LUT construction and the Pallas decoder's bit arithmetic trivial.
    """
    lengths = np.asarray(lengths, dtype=np.int32)
    codes = np.zeros(len(lengths), dtype=np.uint32)
    order = sorted((int(l), s) for s, l in enumerate(lengths) if l > 0)
    code = 0
    prev_len = 0
    for l, s in order:
        code <<= l - prev_len
        codes[s] = code
        code += 1
        prev_len = l
    return codes


def validate_kraft(lengths: np.ndarray) -> float:
    """Kraft sum; must be <= 1 (== 1 for a complete code)."""
    l = lengths[lengths > 0]
    return float(np.sum(2.0 ** (-l.astype(np.float64))))


def effective_bits(freqs: np.ndarray, lengths: np.ndarray) -> float:
    """Average code length weighted by the histogram — the paper's 'Effective Bits'."""
    mask = freqs > 0
    total = freqs[mask].sum()
    if total == 0:
        return 0.0
    return float((freqs[mask] * lengths[mask]).sum() / total)


def build_decode_lut(lengths: np.ndarray, codes: np.ndarray, max_len: int = 12
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Build the peek-``max_len``-bits decode tables.

    ``lut_sym[peek]`` / ``lut_len[peek]`` give the decoded symbol and its true code
    length for every possible ``max_len``-bit window whose prefix is a valid code.
    """
    size = 1 << max_len
    lut_sym = np.zeros(size, dtype=np.int32)
    lut_len = np.zeros(size, dtype=np.int32)
    for s, l in enumerate(lengths):
        l = int(l)
        if l == 0:
            continue
        assert l <= max_len, (s, l, max_len)
        prefix = int(codes[s]) << (max_len - l)
        span = 1 << (max_len - l)
        lut_sym[prefix: prefix + span] = s
        lut_len[prefix: prefix + span] = l
    return lut_sym, lut_len


class HuffmanTable:
    """The model-global code: lengths + canonical codes + decode LUT (paper's H, P)."""

    def __init__(self, freqs: np.ndarray, max_len: int = 12):
        self.freqs = np.asarray(freqs, dtype=np.int64)
        self.max_len = int(max_len)
        self.lengths = code_lengths(self.freqs, max_len=self.max_len)
        self.codes = canonical_codes(self.lengths)
        self.lut_sym, self.lut_len = build_decode_lut(self.lengths, self.codes, self.max_len)

    @property
    def entropy(self) -> float:
        return shannon_entropy(self.freqs)

    @property
    def effective_bits(self) -> float:
        return effective_bits(self.freqs, self.lengths)

    def encoded_bits(self, q: np.ndarray) -> int:
        return int(self.lengths[q.reshape(-1)].sum())

    def encode(self, symbols: np.ndarray):
        """Encode flat symbols -> (guard-padded stream, payload bits) — the
        shared per-segment encode contract of :mod:`repro.core.codecs`."""
        from .bitstream import encode_symbols
        return encode_symbols(symbols, self.codes, self.lengths)

    # serialization --------------------------------------------------------------
    def to_arrays(self) -> dict:
        return {"freqs": self.freqs, "max_len": np.int64(self.max_len)}

    @classmethod
    def from_arrays(cls, d: dict) -> "HuffmanTable":
        return cls(np.asarray(d["freqs"]), max_len=int(d["max_len"]))
