"""EntroLLM compressed model container (paper Alg. 1 lines 11-16 + §III-C layout).

On-disk layout (a single ``.npz``) — **format v2** (DESIGN.md §7,
docs/ARCHITECTURE.md "Container format"):

  * one or more serialized code tables (one per ``(codec, bits)`` group —
    mixed 4/8-bit symbols cannot share one 256-symbol histogram), each
    rebuilt deterministically from its stored histogram,
  * per-tensor metadata: shape, bits, scheme, granularity, codec/table id,
    scale/zero arrays, segment offsets / byte sizes / symbol counts,
  * one contiguous uint8 payload holding every segment stream (byte aligned).

**Format v1** (single global Huffman table, uniform bits) is read
bit-identically by :meth:`CompressedModel.load`; new containers are always
written as v2.

The encode side is driven by a declarative :class:`repro.core.spec.
CompressionSpec` (ordered per-tensor rules: pattern -> bits / codec /
granularity / keep-fp32, with an ``auto`` 4-vs-8-bit policy); the legacy
``compress(bits=, granularity=, should_quantize=)`` arguments remain as the
single-rule shorthand.

Decode path mirrors Alg. 1's EDGE DEVICE OPERATIONS: load tables + streams,
then multi-stream parallel decode through a named backend (``numpy`` /
``jax`` / ``pallas`` — see :mod:`repro.core.decode_backends`), then either
dequantize to the compute dtype or hand the still-quantized weights to the
fused dequant-matmul serving path.  All decode entry points are thin
consumers of :class:`repro.core.scheduler.DecodeScheduler`; the ``iter_*``
variants stream tensors incrementally with bounded host memory
(docs/ARCHITECTURE.md, "Streaming decode").
"""
from __future__ import annotations

import dataclasses
import json
from typing import (Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

import numpy as np

from . import codecs, quant
from .codecs.base import CodeTable
from .segmentation import (DEFAULT_SEGMENT_SYMBOLS, SegmentedTensor,
                           segment_and_encode)
from .spec import (CompressionSpec, TensorPolicy, default_quantize_predicate,
                   spec_from_legacy)

# "use the scheduler's default budget" sentinel, so ``chunk_symbols=None``
# can mean "one monolithic chunk" consistently across every decode entry point
_DEFAULT_CHUNK: object = object()

CONTAINER_FORMAT_VERSION = 2


@dataclasses.dataclass
class CodecGroupStats:
    """Per-(codec, bits) group numbers — one row of the stats breakdown."""

    table_id: str
    codec: str
    bits: int
    param_count: int           # symbols in this group
    entropy_bits: float        # Shannon bound for the group histogram
    effective_bits: float      # ACHIEVED bits/symbol (payload bits / symbols)
    quant_bytes: int           # bits/8 per param
    encoded_bytes: int         # this group's share of the payload

    @property
    def shannon_ratio(self) -> float:
        """achieved / bound — 1.0 is the Shannon wall."""
        return self.effective_bits / max(self.entropy_bits, 1e-12)


@dataclasses.dataclass
class CompressionStats:
    """The numbers reported in the paper's Table I, per model.

    Mixed-precision containers report one :class:`CodecGroupStats` per
    ``(codec, bits)`` group; the scalar ``bits`` / ``entropy_bits`` /
    ``effective_bits`` properties are the symbol-weighted aggregates, so
    Table I stays correct when 4- and 8-bit tensors share a container.
    """

    param_count: int
    raw_bytes: int             # fp16 baseline (2 bytes/param)
    quant_bytes: int           # sum of bits/8 per param (+ fp32 leftovers)
    encoded_bytes: int         # entropy-coded payload (+ fp32 leftovers)
    metadata_bytes: int
    unquantized_params: int
    groups: List[CodecGroupStats] = dataclasses.field(default_factory=list)

    @property
    def quantized_params(self) -> int:
        return sum(g.param_count for g in self.groups)

    def _weighted(self, attr: str) -> float:
        n = self.quantized_params
        if n == 0:
            return 0.0
        return sum(getattr(g, attr) * g.param_count for g in self.groups) / n

    @property
    def bits(self) -> float:
        """Symbol-weighted stored bit-width (int-valued for uniform models)."""
        return self._weighted("bits")

    @property
    def entropy_bits(self) -> float:
        return self._weighted("entropy_bits")

    @property
    def effective_bits(self) -> float:
        return self._weighted("effective_bits")

    @property
    def shannon_ratio(self) -> float:
        """achieved / bound, symbol-weighted — 1.0 is the Shannon wall."""
        return self.effective_bits / max(self.entropy_bits, 1e-12)

    @property
    def reduction_vs_quant(self) -> float:
        return 1.0 - self.encoded_bytes / max(self.quant_bytes, 1)

    @property
    def reduction_vs_fp16(self) -> float:
        return 1.0 - self.encoded_bytes / max(self.raw_bytes, 1)


class CompressedModel:
    """In-memory compressed representation of a pytree of weights."""

    def __init__(self, tables: Dict[str, CodeTable],
                 tensors: Dict[str, SegmentedTensor],
                 qmeta: Dict[str, dict], payload: np.ndarray,
                 unquantized: Dict[str, np.ndarray],
                 spec: Optional[CompressionSpec] = None):
        self.tables = tables        # table id -> CodeTable
        self.tensors = tensors
        self.qmeta = qmeta          # name -> {bits, scheme, granularity,
        #                                      scale, zero, codec, table}
        self.payload = payload
        self.unquantized = unquantized  # small / sensitive tensors kept in fp32
        self.spec = spec

    @property
    def table(self) -> CodeTable:
        """Legacy single-table accessor (v1 containers / uniform specs)."""
        if len(self.tables) == 1:
            return next(iter(self.tables.values()))
        raise AttributeError(
            f"container holds {len(self.tables)} code tables "
            f"({sorted(self.tables)}); use .tables / .table_for(name)")

    def table_for(self, name: str) -> CodeTable:
        return self.tables[self.qmeta[name]["table"]]

    def table_id_for(self, name: str) -> str:
        return self.qmeta[name]["table"]

    # ---------------------------------------------------------------- compression
    @classmethod
    def compress(
        cls,
        params: Dict[str, np.ndarray],
        spec: Optional[CompressionSpec] = None,
        *,
        bits: int = 8,
        granularity: quant.Granularity = quant.Granularity.PER_TENSOR,
        should_quantize: Optional[Callable[[str, np.ndarray], bool]] = None,
        segment_symbols: int = DEFAULT_SEGMENT_SYMBOLS,
        max_code_len: int = 12,
    ) -> "CompressedModel":
        """Quantize + entropy-encode a named parameter dict.

        ``spec`` is the primary interface: ordered per-tensor rules resolve
        each tensor to (bits, codec, granularity, ...) or keep-fp32.  The
        keyword arguments are the pre-spec shorthand (one model-wide rule +
        optional predicate) and are ignored when ``spec`` is given — except
        ``should_quantize``, which still overrides the whether-to-quantize
        default for tensors no spec rule matches.
        """
        if spec is None:
            spec = spec_from_legacy(bits, granularity,
                                    segment_symbols=segment_symbols,
                                    max_code_len=max_code_len)
        spec.validate()

        qts: Dict[str, quant.QuantizedTensor] = {}
        policies: Dict[str, TensorPolicy] = {}
        unquantized: Dict[str, np.ndarray] = {}
        for name, w in params.items():
            w = np.asarray(w, dtype=np.float32)
            if should_quantize is not None and \
                    not any(r.matches(name) for r in spec.rules):
                # legacy predicate replaces the default whether-to-quantize
                # (spec defaults still decide HOW when it says yes)
                if should_quantize(name, w):
                    pol = spec._policy(
                        w, rule=None, bits=spec.default_bits,
                        codec=spec.default_codec,
                        granularity=spec.default_granularity,
                        group=spec.default_group, scheme=None)
                else:
                    pol = TensorPolicy(quantize=False)
            else:
                pol = spec.resolve(name, w)
            if not pol.quantize:
                unquantized[name] = w
                continue
            policies[name] = pol
            # bits="auto" already quantized at 4 bits inside the probe
            qts[name] = pol.qt if pol.qt is not None else quant.quantize(
                w, pol.bits, pol.granularity, group=pol.group,
                scheme=pol.scheme, name=name)

        # Alg.1 line 11, per group: one frequency table across each
        # (codec, bits) group of the model (v1 == the single-group case).
        from .entropy import global_frequencies
        group_names: Dict[str, List[str]] = {}
        for name, qt in qts.items():
            tid = f"{policies[name].codec}{qt.bits}"
            group_names.setdefault(tid, []).append(name)
        tables: Dict[str, CodeTable] = {}
        for tid, names in group_names.items():
            pol = policies[names[0]]
            gbits = qts[names[0]].bits
            freqs = global_frequencies((qts[n].q for n in names), 1 << gbits)
            tables[tid] = codecs.get_codec(pol.codec).build(
                freqs, gbits, max_code_len=spec.max_code_len)

        tensors: Dict[str, SegmentedTensor] = {}
        qmeta: Dict[str, dict] = {}
        chunks: List[np.ndarray] = []
        offset = 0
        for name, qt in qts.items():
            tid = f"{policies[name].codec}{qt.bits}"
            meta, streams = segment_and_encode(name, qt.q, tables[tid],
                                               spec.segment_symbols)
            offs = []
            for s in streams:
                offs.append(offset)
                chunks.append(s)
                offset += len(s)
            meta.seg_offsets = np.array(offs, dtype=np.int64)
            tensors[name] = meta
            qmeta[name] = dict(
                bits=qt.bits, scheme=qt.scheme.value,
                granularity=qt.granularity.value,
                scale=qt.scale, zero=qt.zero,
                codec=policies[name].codec, table=tid,
            )
        payload = (np.concatenate(chunks) if chunks else np.zeros(0, np.uint8))
        return cls(tables, tensors, qmeta, payload, unquantized, spec=spec)

    # --------------------------------------------------------------- decompression
    def scheduler(self, *, backend=None, chunk_symbols=_DEFAULT_CHUNK,
                  first: Sequence[str] = (), prefetch: bool = True):
        """Build a :class:`~repro.core.scheduler.DecodeScheduler` over this
        container.  ``chunk_symbols=None`` -> one monolithic chunk (the
        lock-step all-segments batch); a positive budget (default: the
        scheduler's per-layer budget) -> bounded-memory streaming with
        double-buffered prefetch."""
        from .scheduler import DEFAULT_CHUNK_SYMBOLS, DecodeScheduler
        if chunk_symbols is _DEFAULT_CHUNK:
            chunk_symbols = DEFAULT_CHUNK_SYMBOLS
        return DecodeScheduler(self, backend=backend,
                               chunk_symbols=chunk_symbols, first=first,
                               prefetch=prefetch)

    def decode_tensor(self, name: str, *, backend=None) -> np.ndarray:
        """Parallel-decode one tensor back to its uint8 symbols."""
        from .bitstream import pack_streams
        from .decode_backends import DecoderBackend, get_backend
        meta = self.tensors[name]
        b = backend if isinstance(backend, DecoderBackend) \
            else get_backend(backend or "numpy")
        streams = [
            self.payload[o: o + n]
            for o, n in zip(meta.seg_offsets, meta.seg_nbytes)
        ]
        mat, _ = pack_streams(streams)
        out = b.decode_table(self.table_for(name), mat, meta.seg_counts)
        flat = np.concatenate([out[i, : int(c)] for i, c in enumerate(meta.seg_counts)]) \
            if len(streams) > 1 else out[0, : int(meta.seg_counts[0])]
        return flat.astype(np.uint8).reshape(meta.shape)

    def iter_decode(self, *, backend=None,
                    chunk_symbols: Optional[int] = _DEFAULT_CHUNK,
                    first: Sequence[str] = (),
                    prefetch: bool = True) -> Iterator[Tuple[str, np.ndarray]]:
        """Stream ``(name, uint8 symbols)`` tensors as they finish decoding.

        ``chunk_symbols`` defaults to the scheduler's budget (per-layer
        groups, ~512k symbols/chunk) so host memory stays bounded by the
        chunk size; ``None`` means one monolithic chunk — the same convention
        as :class:`~repro.core.scheduler.DecodeScheduler` everywhere.
        """
        if chunk_symbols is _DEFAULT_CHUNK:
            from .scheduler import DEFAULT_CHUNK_SYMBOLS
            chunk_symbols = DEFAULT_CHUNK_SYMBOLS
        sched = self.scheduler(backend=backend, chunk_symbols=chunk_symbols,
                               first=first, prefetch=prefetch)
        return sched.iter_decode()

    def decode_all(self, workers: int = 1, *, backend=None) -> Dict[str, np.ndarray]:
        """Alg. 1 EDGE DEVICE OPERATIONS: decode every tensor.

        ALL segments of ALL tensors are batched into per-table lock-step
        multi-stream decodes — the paper's "assign segments across threads"
        with lanes playing the threads; batching keeps every lane busy
        regardless of per-tensor segment counts (per-tensor decoding is
        lane-starved for small tensors — measured ~6x slower in
        benchmarks/table2).  Peak host memory ~ total model size; use
        :meth:`iter_decode` / :meth:`iter_quantized_weights` for the
        bounded-memory streaming path.
        """
        sched = self.scheduler(backend=backend, chunk_symbols=None,
                               prefetch=False)
        return dict(sched.iter_decode())

    def iter_dequantize(self, **kw) -> Iterator[Tuple[str, np.ndarray]]:
        """Stream fully dequantized fp32 tensors (unquantized ones first)."""
        for name, w in self.unquantized.items():
            yield name, w
        for name, q in self.iter_decode(**kw):
            yield name, self._dequantize_one(name, q)

    def _dequantize_one(self, name: str, q: np.ndarray) -> np.ndarray:
        m = self.qmeta[name]
        qt = quant.QuantizedTensor(
            q=q, scale=m["scale"], zero=m["zero"], bits=m["bits"],
            scheme=quant.Scheme(m["scheme"]),
            granularity=quant.Granularity(m["granularity"]),
            shape=self.tensors[name].shape,
        )
        return quant.dequantize(qt)

    def dequantize_all(self, *, backend=None) -> Dict[str, np.ndarray]:
        out: Dict[str, np.ndarray] = dict(self.unquantized)
        for name, q in self.decode_all(backend=backend).items():
            out[name] = self._dequantize_one(name, q)
        return out

    def iter_quantized_weights(self, **kw) -> Iterator[
            Tuple[str, Tuple[np.ndarray, np.ndarray, np.ndarray]]]:
        """Stream ``name -> (q, scale, zero)`` triples for the fused dequant
        serving path — weights stay integer in HBM, dequant fuses into the
        matmul; tensors arrive incrementally with bounded host memory."""
        for name, q in self.iter_decode(**kw):
            m = self.qmeta[name]
            yield name, (q, m["scale"], m["zero"])

    def quantized_weights(self, *, backend=None) -> Dict[str, Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Decode to (q, scale, zero) triples for the fused dequant serving path —
        weights stay integer in HBM, dequant fuses into the matmul."""
        symbols = self.decode_all(backend=backend)
        return {
            name: (q, self.qmeta[name]["scale"], self.qmeta[name]["zero"])
            for name, q in symbols.items()
        }

    # ------------------------------------------------------------------- statistics
    def stats(self) -> CompressionStats:
        groups: List[CodecGroupStats] = []
        for tid, table in sorted(self.tables.items()):
            names = [n for n, m in self.qmeta.items() if m["table"] == tid]
            n_sym = sum(self.tensors[n].n_symbols for n in names)
            payload_bits = sum(int(self.tensors[n].seg_bits.sum())
                               for n in names)
            groups.append(CodecGroupStats(
                table_id=tid, codec=table.codec_name, bits=table.bits,
                param_count=n_sym, entropy_bits=table.entropy,
                effective_bits=payload_bits / max(n_sym, 1),
                quant_bytes=(n_sym * table.bits) // 8,
                encoded_bytes=(payload_bits + 7) // 8,
            ))
        n_q = sum(g.param_count for g in groups)
        n_u = sum(int(np.prod(w.shape)) for w in self.unquantized.values())
        meta_bytes = sum(
            m["scale"].size * 4 + m["zero"].size * 4 for m in self.qmeta.values()
        ) + sum(sum(a.size * a.itemsize for a in t.to_arrays().values())
                for t in self.tables.values())
        return CompressionStats(
            param_count=n_q + n_u,
            raw_bytes=2 * (n_q + n_u),
            quant_bytes=sum(g.quant_bytes for g in groups) + n_u * 2,
            encoded_bytes=sum(g.encoded_bytes for g in groups) + n_u * 2,
            metadata_bytes=int(meta_bytes),
            unquantized_params=n_u,
            groups=groups,
        )

    # ------------------------------------------------------------------ persistence
    def save(self, path: str) -> None:
        """Write a v2 container (v1 remains readable via :meth:`load`)."""
        arrays: Dict[str, np.ndarray] = {
            "__payload__": self.payload,
            "__format_version__": np.array([CONTAINER_FORMAT_VERSION],
                                           dtype=np.int64),
        }
        manifest: Dict[str, dict] = {
            "version": CONTAINER_FORMAT_VERSION,
            "tables": {}, "tensors": {}, "qmeta": {}, "unquantized": [],
            "spec": self.spec.describe() if self.spec is not None else None,
        }
        for tid, table in self.tables.items():
            manifest["tables"][tid] = table.to_manifest()
            for k, arr in table.to_arrays().items():
                arrays[f"tbl::{tid}::{k}"] = arr
        for name, t in self.tensors.items():
            key = f"t::{name}"
            manifest["tensors"][name] = dict(shape=list(t.shape), n_symbols=t.n_symbols)
            arrays[key + "::seg_offsets"] = t.seg_offsets
            arrays[key + "::seg_nbytes"] = t.seg_nbytes
            arrays[key + "::seg_counts"] = t.seg_counts
            arrays[key + "::seg_bits"] = t.seg_bits
        for name, m in self.qmeta.items():
            manifest["qmeta"][name] = dict(
                bits=m["bits"], scheme=m["scheme"],
                granularity=m["granularity"],
                codec=m["codec"], table=m["table"])
            arrays[f"q::{name}::scale"] = m["scale"]
            arrays[f"q::{name}::zero"] = m["zero"]
        for name, w in self.unquantized.items():
            manifest["unquantized"].append(name)
            arrays[f"u::{name}"] = w
        arrays["__manifest__"] = np.frombuffer(
            json.dumps(manifest).encode(), dtype=np.uint8)
        np.savez(path, **arrays)

    @classmethod
    def load(cls, path: str) -> "CompressedModel":
        z = np.load(path if path.endswith(".npz") else path + ".npz")
        if "__format_version__" in z.files:
            version = int(z["__format_version__"][0])
            if version != CONTAINER_FORMAT_VERSION:
                raise ValueError(
                    f"unsupported container format v{version} "
                    f"(this build reads v1 and v{CONTAINER_FORMAT_VERSION})")
            return cls._load_v2(z)
        return cls._load_v1(z)

    @staticmethod
    def _load_tensors(z, manifest) -> Dict[str, SegmentedTensor]:
        """Per-tensor segment tables — layout shared by formats v1 and v2."""
        tensors: Dict[str, SegmentedTensor] = {}
        for name, tm in manifest["tensors"].items():
            key = f"t::{name}"
            tensors[name] = SegmentedTensor(
                name=name, shape=tuple(tm["shape"]),
                n_symbols=int(tm["n_symbols"]),
                seg_offsets=z[key + "::seg_offsets"],
                seg_nbytes=z[key + "::seg_nbytes"],
                seg_counts=z[key + "::seg_counts"],
                seg_bits=z[key + "::seg_bits"],
            )
        return tensors

    @classmethod
    def _load_v2(cls, z) -> "CompressedModel":
        manifest = json.loads(bytes(z["__manifest__"]).decode())
        tables: Dict[str, CodeTable] = {}
        for tid, tman in manifest["tables"].items():
            prefix = f"tbl::{tid}::"
            arrs = {k[len(prefix):]: z[k] for k in z.files
                    if k.startswith(prefix)}
            tables[tid] = codecs.table_from_container(tman, arrs)
        tensors = cls._load_tensors(z, manifest)
        qmeta, unquantized = {}, {}
        for name, qm in manifest["qmeta"].items():
            qmeta[name] = dict(
                bits=int(qm["bits"]), scheme=qm["scheme"],
                granularity=qm["granularity"],
                codec=qm["codec"], table=qm["table"],
                scale=z[f"q::{name}::scale"], zero=z[f"q::{name}::zero"],
            )
        for name in manifest["unquantized"]:
            unquantized[name] = z[f"u::{name}"]
        # revive the recorded spec so provenance survives load -> save
        # (describe() emits canonical text, so this parse round-trips; an
        # unknown-codec container already failed above at table revival)
        spec = None
        spec_text = manifest.get("spec")
        if spec_text:
            try:
                spec = CompressionSpec.parse(spec_text)
            except Exception:
                spec = None
        return cls(tables, tensors, qmeta, z["__payload__"], unquantized,
                   spec=spec)

    @classmethod
    def _load_v1(cls, z) -> "CompressedModel":
        """Pre-registry containers: ONE global Huffman table, uniform bits.

        Reads the exact layout the v1 writer produced; the revived
        ``HuffmanCodeTable`` rebuilds the identical canonical code + LUT from
        the stored histogram, so decode is bit-identical to the v1 reader
        (pinned by tests/test_container_v2.py against a committed fixture).
        """
        manifest = json.loads(bytes(z["__manifest__"]).decode())
        tensors = cls._load_tensors(z, manifest)
        qmeta, unquantized = {}, {}
        bits = 8
        for name, qm in manifest["qmeta"].items():
            bits = int(qm["bits"])
        tid = f"huffman{bits}"
        for name, qm in manifest["qmeta"].items():
            qmeta[name] = dict(
                bits=int(qm["bits"]), scheme=qm["scheme"], granularity=qm["granularity"],
                codec="huffman", table=tid,
                scale=z[f"q::{name}::scale"], zero=z[f"q::{name}::zero"],
            )
        for name in manifest["unquantized"]:
            unquantized[name] = z[f"u::{name}"]
        table = codecs.HuffmanCodeTable(z["__freqs__"], bits=bits,
                                        max_len=int(z["__max_len__"][0]))
        return cls({tid: table}, tensors, qmeta, z["__payload__"], unquantized)


# re-exported for back-compat; the policy itself lives in repro.core.spec
__all__ = ["CompressedModel", "CompressionStats", "CodecGroupStats",
           "default_quantize_predicate", "CompressionSpec"]
