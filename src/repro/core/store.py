"""EntroLLM compressed model container (paper Alg. 1 lines 11-16 + §III-C layout).

On-disk layout (a single ``.npz``):
  * the global frequency table (reconstructs the Huffman table deterministically),
  * per-tensor metadata: shape, bits, scheme, granularity, scale/zero arrays,
    segment offsets / byte sizes / symbol counts,
  * one contiguous uint8 payload holding every segment stream (byte aligned).

Decode path mirrors Alg. 1's EDGE DEVICE OPERATIONS: load table + streams, then
multi-stream parallel decode through a named backend (``numpy`` / ``jax`` /
``pallas`` — see :mod:`repro.core.decode_backends`), then either dequantize to
the compute dtype or hand the still-quantized weights to the fused
dequant-matmul serving path.  All decode entry points are thin consumers of
:class:`repro.core.scheduler.DecodeScheduler`; the ``iter_*`` variants stream
tensors incrementally with bounded host memory (docs/ARCHITECTURE.md,
"Streaming decode").
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from . import quant
from .bitstream import GUARD_BYTES, decode_streams, pack_streams
from .entropy import HuffmanTable
from .segmentation import (DEFAULT_SEGMENT_SYMBOLS, SegmentedTensor,
                           balanced_assignment, segment_and_encode)


# "use the scheduler's default budget" sentinel, so ``chunk_symbols=None``
# can mean "one monolithic chunk" consistently across every decode entry point
_DEFAULT_CHUNK: object = object()


@dataclasses.dataclass
class CompressionStats:
    """The numbers reported in the paper's Table I, per model."""

    param_count: int
    bits: int
    entropy_bits: float        # Shannon bound for the global histogram
    effective_bits: float      # achieved average code length
    raw_bytes: int             # fp16 baseline (2 bytes/param)
    quant_bytes: int           # bits/8 per param
    encoded_bytes: int         # Huffman payload (+ metadata excluded, reported separately)
    metadata_bytes: int

    @property
    def reduction_vs_quant(self) -> float:
        return 1.0 - self.encoded_bytes / max(self.quant_bytes, 1)

    @property
    def reduction_vs_fp16(self) -> float:
        return 1.0 - self.encoded_bytes / max(self.raw_bytes, 1)


class CompressedModel:
    """In-memory compressed representation of a pytree of weights."""

    def __init__(self, table: HuffmanTable, tensors: Dict[str, SegmentedTensor],
                 qmeta: Dict[str, dict], payload: np.ndarray,
                 unquantized: Dict[str, np.ndarray]):
        self.table = table
        self.tensors = tensors
        self.qmeta = qmeta          # name -> {bits, scheme, granularity, scale, zero}
        self.payload = payload
        self.unquantized = unquantized  # small / sensitive tensors kept in fp32

    # ---------------------------------------------------------------- compression
    @classmethod
    def compress(
        cls,
        params: Dict[str, np.ndarray],
        bits: int = 8,
        granularity: quant.Granularity = quant.Granularity.PER_TENSOR,
        should_quantize: Optional[Callable[[str, np.ndarray], bool]] = None,
        segment_symbols: int = DEFAULT_SEGMENT_SYMBOLS,
        max_code_len: int = 12,
    ) -> "CompressedModel":
        should_quantize = should_quantize or default_quantize_predicate
        qts: Dict[str, quant.QuantizedTensor] = {}
        unquantized: Dict[str, np.ndarray] = {}
        for name, w in params.items():
            if should_quantize(name, w):
                qts[name] = quant.quantize(np.asarray(w), bits, granularity)
            else:
                unquantized[name] = np.asarray(w, dtype=np.float32)

        # Alg.1 line 11: ONE frequency table across the model.
        from .entropy import global_frequencies
        freqs = global_frequencies((qt.q for qt in qts.values()), 1 << bits)
        table = HuffmanTable(freqs, max_len=max_code_len)

        tensors: Dict[str, SegmentedTensor] = {}
        qmeta: Dict[str, dict] = {}
        chunks: List[np.ndarray] = []
        offset = 0
        for name, qt in qts.items():
            meta, streams = segment_and_encode(name, qt.q, table, segment_symbols)
            offs = []
            for s in streams:
                offs.append(offset)
                chunks.append(s)
                offset += len(s)
            meta.seg_offsets = np.array(offs, dtype=np.int64)
            tensors[name] = meta
            qmeta[name] = dict(
                bits=qt.bits, scheme=qt.scheme.value, granularity=qt.granularity.value,
                scale=qt.scale, zero=qt.zero,
            )
        payload = (np.concatenate(chunks) if chunks else np.zeros(0, np.uint8))
        return cls(table, tensors, qmeta, payload, unquantized)

    # --------------------------------------------------------------- decompression
    def scheduler(self, *, backend=None, chunk_symbols=_DEFAULT_CHUNK,
                  first: Sequence[str] = (), prefetch: bool = True):
        """Build a :class:`~repro.core.scheduler.DecodeScheduler` over this
        container.  ``chunk_symbols=None`` -> one monolithic chunk (the
        lock-step all-segments batch); a positive budget (default: the
        scheduler's per-layer budget) -> bounded-memory streaming with
        double-buffered prefetch."""
        from .scheduler import DEFAULT_CHUNK_SYMBOLS, DecodeScheduler
        if chunk_symbols is _DEFAULT_CHUNK:
            chunk_symbols = DEFAULT_CHUNK_SYMBOLS
        return DecodeScheduler(self, backend=backend,
                               chunk_symbols=chunk_symbols, first=first,
                               prefetch=prefetch)

    def decode_tensor(self, name: str) -> np.ndarray:
        """Parallel-decode one tensor back to its uint8 symbols."""
        meta = self.tensors[name]
        streams = [
            self.payload[o: o + n]
            for o, n in zip(meta.seg_offsets, meta.seg_nbytes)
        ]
        mat, _ = pack_streams(streams)
        out = decode_streams(mat, meta.seg_counts, self.table.lut_sym,
                             self.table.lut_len, self.table.max_len)
        flat = np.concatenate([out[i, : int(c)] for i, c in enumerate(meta.seg_counts)]) \
            if len(streams) > 1 else out[0, : int(meta.seg_counts[0])]
        return flat.astype(np.uint8).reshape(meta.shape)

    def iter_decode(self, *, backend=None,
                    chunk_symbols: Optional[int] = _DEFAULT_CHUNK,
                    first: Sequence[str] = (),
                    prefetch: bool = True) -> Iterator[Tuple[str, np.ndarray]]:
        """Stream ``(name, uint8 symbols)`` tensors as they finish decoding.

        ``chunk_symbols`` defaults to the scheduler's budget (per-layer
        groups, ~512k symbols/chunk) so host memory stays bounded by the
        chunk size; ``None`` means one monolithic chunk — the same convention
        as :class:`~repro.core.scheduler.DecodeScheduler` everywhere.
        """
        if chunk_symbols is _DEFAULT_CHUNK:
            from .scheduler import DEFAULT_CHUNK_SYMBOLS
            chunk_symbols = DEFAULT_CHUNK_SYMBOLS
        sched = self.scheduler(backend=backend, chunk_symbols=chunk_symbols,
                               first=first, prefetch=prefetch)
        return sched.iter_decode()

    def decode_all(self, workers: int = 1, *, backend=None) -> Dict[str, np.ndarray]:
        """Alg. 1 EDGE DEVICE OPERATIONS: decode every tensor.

        ALL segments of ALL tensors are batched into ONE lock-step
        multi-stream decode — the paper's "assign segments across threads"
        with lanes playing the threads; batching keeps every lane busy
        regardless of per-tensor segment counts (per-tensor decoding is
        lane-starved for small tensors — measured ~6x slower in
        benchmarks/table2).  Peak host memory ~ total model size; use
        :meth:`iter_decode` / :meth:`iter_quantized_weights` for the
        bounded-memory streaming path.
        """
        sched = self.scheduler(backend=backend, chunk_symbols=None,
                               prefetch=False)
        return dict(sched.iter_decode())

    def iter_dequantize(self, **kw) -> Iterator[Tuple[str, np.ndarray]]:
        """Stream fully dequantized fp32 tensors (unquantized ones first)."""
        for name, w in self.unquantized.items():
            yield name, w
        for name, q in self.iter_decode(**kw):
            yield name, self._dequantize_one(name, q)

    def _dequantize_one(self, name: str, q: np.ndarray) -> np.ndarray:
        m = self.qmeta[name]
        qt = quant.QuantizedTensor(
            q=q, scale=m["scale"], zero=m["zero"], bits=m["bits"],
            scheme=quant.Scheme(m["scheme"]),
            granularity=quant.Granularity(m["granularity"]),
            shape=self.tensors[name].shape,
        )
        return quant.dequantize(qt)

    def dequantize_all(self, *, backend=None) -> Dict[str, np.ndarray]:
        out: Dict[str, np.ndarray] = dict(self.unquantized)
        for name, q in self.decode_all(backend=backend).items():
            out[name] = self._dequantize_one(name, q)
        return out

    def iter_quantized_weights(self, **kw) -> Iterator[
            Tuple[str, Tuple[np.ndarray, np.ndarray, np.ndarray]]]:
        """Stream ``name -> (q, scale, zero)`` triples for the fused dequant
        serving path — weights stay integer in HBM, dequant fuses into the
        matmul; tensors arrive incrementally with bounded host memory."""
        for name, q in self.iter_decode(**kw):
            m = self.qmeta[name]
            yield name, (q, m["scale"], m["zero"])

    def quantized_weights(self, *, backend=None) -> Dict[str, Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Decode to (q, scale, zero) triples for the fused dequant serving path —
        weights stay integer in HBM, dequant fuses into the matmul."""
        symbols = self.decode_all(backend=backend)
        return {
            name: (q, self.qmeta[name]["scale"], self.qmeta[name]["zero"])
            for name, q in symbols.items()
        }

    # ------------------------------------------------------------------- statistics
    def stats(self) -> CompressionStats:
        n_q = sum(t.n_symbols for t in self.tensors.values())
        n_u = sum(int(np.prod(w.shape)) for w in self.unquantized.values())
        bits = next(iter(self.qmeta.values()))["bits"] if self.qmeta else 8
        payload_bits = int(sum(int(t.seg_bits.sum()) for t in self.tensors.values()))
        meta_bytes = sum(
            m["scale"].size * 4 + m["zero"].size * 4 for m in self.qmeta.values()
        ) + self.table.freqs.size * 8
        return CompressionStats(
            param_count=n_q + n_u,
            bits=bits,
            entropy_bits=self.table.entropy,
            effective_bits=self.table.effective_bits,
            raw_bytes=2 * (n_q + n_u),
            quant_bytes=(n_q * bits) // 8 + n_u * 2,
            encoded_bytes=(payload_bits + 7) // 8 + n_u * 2,
            metadata_bytes=int(meta_bytes),
        )

    # ------------------------------------------------------------------ persistence
    def save(self, path: str) -> None:
        arrays: Dict[str, np.ndarray] = {
            "__payload__": self.payload,
            "__freqs__": self.table.freqs,
            "__max_len__": np.array([self.table.max_len], dtype=np.int64),
        }
        manifest: Dict[str, dict] = {"tensors": {}, "qmeta": {}, "unquantized": []}
        for name, t in self.tensors.items():
            key = f"t::{name}"
            manifest["tensors"][name] = dict(shape=list(t.shape), n_symbols=t.n_symbols)
            arrays[key + "::seg_offsets"] = t.seg_offsets
            arrays[key + "::seg_nbytes"] = t.seg_nbytes
            arrays[key + "::seg_counts"] = t.seg_counts
            arrays[key + "::seg_bits"] = t.seg_bits
        for name, m in self.qmeta.items():
            manifest["qmeta"][name] = dict(
                bits=m["bits"], scheme=m["scheme"], granularity=m["granularity"])
            arrays[f"q::{name}::scale"] = m["scale"]
            arrays[f"q::{name}::zero"] = m["zero"]
        for name, w in self.unquantized.items():
            manifest["unquantized"].append(name)
            arrays[f"u::{name}"] = w
        arrays["__manifest__"] = np.frombuffer(
            json.dumps(manifest).encode(), dtype=np.uint8)
        np.savez(path, **arrays)

    @classmethod
    def load(cls, path: str) -> "CompressedModel":
        z = np.load(path if path.endswith(".npz") else path + ".npz")
        manifest = json.loads(bytes(z["__manifest__"]).decode())
        table = HuffmanTable(z["__freqs__"], max_len=int(z["__max_len__"][0]))
        tensors, qmeta, unquantized = {}, {}, {}
        for name, tm in manifest["tensors"].items():
            key = f"t::{name}"
            tensors[name] = SegmentedTensor(
                name=name, shape=tuple(tm["shape"]), n_symbols=int(tm["n_symbols"]),
                seg_offsets=z[key + "::seg_offsets"], seg_nbytes=z[key + "::seg_nbytes"],
                seg_counts=z[key + "::seg_counts"], seg_bits=z[key + "::seg_bits"],
            )
        for name, qm in manifest["qmeta"].items():
            qmeta[name] = dict(
                bits=int(qm["bits"]), scheme=qm["scheme"], granularity=qm["granularity"],
                scale=z[f"q::{name}::scale"], zero=z[f"q::{name}::zero"],
            )
        for name in manifest["unquantized"]:
            unquantized[name] = z[f"u::{name}"]
        return cls(table, tensors, qmeta, z["__payload__"], unquantized)


def default_quantize_predicate(name: str, w: np.ndarray) -> bool:
    """Quantize matrix-shaped weights; keep norms / biases / tiny or sensitive params
    (e.g. SSM ``A_log``/``dt``) in full precision, per DESIGN.md §5."""
    if w.ndim < 2:
        return False
    lname = name.lower()
    if any(k in lname for k in ("norm", "scale", "bias", "a_log", "dt_", "conv_")):
        return False
    return int(np.prod(w.shape)) >= 4096
