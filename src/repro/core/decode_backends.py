"""Pluggable decoder backends for the multi-stream Huffman decode.

One decode *call* takes a packed stream matrix (S segments x B bytes, guard
padded), per-segment symbol counts, and the canonical-code LUT, and returns
the (S, max_count) int32 symbol matrix — the contract shared by
``core.bitstream.decode_streams`` (numpy), ``core.decode_jax.decode_streams_jax``
(jit), and ``kernels.huffman_decode.decode_streams_pallas`` (TPU kernel).

This module makes that choice a first-class, *named* decision instead of an
ad-hoc per-call-site import:

* ``register_backend`` / ``get_backend`` — a string-keyed registry
  (``"numpy"``, ``"jax"``, ``"pallas"``, ``"pallas-interpret"``).
* Capability probing — each backend reports :meth:`DecoderBackend.available`;
  the ``pallas`` backend probes whether the kernel actually *compiles* on this
  host (``interpret=False``).  Interpret mode is never auto-picked: it exists
  only as the explicitly named ``"pallas-interpret"`` fallback.
* ``auto_pick`` — capability-based default: compiled Pallas on TPU, the jit
  decoder when an accelerator is attached, the numpy host path otherwise.

The :class:`repro.core.scheduler.DecodeScheduler` drives whichever backend it
is handed; see docs/ARCHITECTURE.md §"Streaming decode" for the data flow.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from .bitstream import decode_streams


@dataclasses.dataclass(frozen=True)
class DecoderBackend:
    """A named decode implementation + its capability probes.

    ``fn(mat, counts, lut_sym, lut_len, max_len, max_count) -> (S, max_count)
    int32 ndarray``.  ``probe`` answers "can this backend run here at all?"
    (gates by-name requests); ``auto_probe`` answers "should auto-pick use it
    here?" — e.g. the jit decoder runs fine on CPU but is only *preferred*
    when an accelerator is attached, and the interpret fallback is runnable
    everywhere yet never auto-picked.  ``priority`` orders auto-pick
    (higher wins).
    """

    name: str
    fn: Callable[..., np.ndarray]
    probe: Callable[[], bool]
    priority: int = 0
    auto_probe: Optional[Callable[[], bool]] = None

    def available(self) -> bool:
        try:
            return bool(self.probe())
        except Exception:
            return False

    def auto_eligible(self) -> bool:
        try:
            return bool((self.auto_probe or self.probe)())
        except Exception:
            return False

    def decode(self, mat: np.ndarray, counts: np.ndarray, lut_sym: np.ndarray,
               lut_len: np.ndarray, *, max_len: int,
               max_count: Optional[int] = None) -> np.ndarray:
        counts = np.asarray(counts, dtype=np.int64)
        mc = int(counts.max(initial=0)) if max_count is None else int(max_count)
        out = self.fn(mat, counts, lut_sym, lut_len, max_len, mc)
        return np.asarray(out)[:, :mc] if mc else np.asarray(out)


_REGISTRY: Dict[str, DecoderBackend] = {}


def register_backend(backend: DecoderBackend) -> DecoderBackend:
    _REGISTRY[backend.name] = backend
    return backend


def backend_names() -> List[str]:
    return sorted(_REGISTRY)


def available_backends() -> List[str]:
    return [n for n in backend_names() if _REGISTRY[n].available()]


def auto_pick() -> DecoderBackend:
    """Highest-priority backend whose auto-pick probe passes on this host."""
    ranked = sorted(_REGISTRY.values(), key=lambda b: -b.priority)
    for b in ranked:
        if b.auto_eligible():
            return b
    return _REGISTRY["numpy"]    # always available by construction


def get_backend(name: Optional[str] = None) -> DecoderBackend:
    """Resolve a backend by name; ``None`` / ``"auto"`` -> capability pick.

    Asking for an unavailable backend raises so misconfiguration is loud;
    use ``auto`` when a silent fallback is wanted.
    """
    if name is None or name == "auto":
        return auto_pick()
    try:
        b = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown decoder backend {name!r}; "
                       f"registered: {backend_names()}") from None
    if not b.available():
        raise RuntimeError(f"decoder backend {name!r} is not available on "
                           f"this host (available: {available_backends()})")
    return b


# ------------------------------------------------------------------ numpy
def _numpy_decode(mat, counts, lut_sym, lut_len, max_len, max_count):
    return decode_streams(mat, counts, lut_sym, lut_len, max_len)


register_backend(DecoderBackend(
    name="numpy", fn=_numpy_decode, probe=lambda: True, priority=0))


# -------------------------------------------------------------------- jax
def _jax_ok() -> bool:
    import jax  # noqa: F401  (baked into the image; probe stays cheap)
    return True


def _jax_accelerated() -> bool:
    import jax
    return jax.default_backend() != "cpu"


def _jax_decode(mat, counts, lut_sym, lut_len, max_len, max_count):
    import jax.numpy as jnp
    from .decode_jax import bucket_streams, decode_streams_jax
    mat, counts, mc = bucket_streams(mat, counts, max_count)
    out = decode_streams_jax(jnp.asarray(mat), jnp.asarray(counts, jnp.int32),
                             jnp.asarray(lut_sym), jnp.asarray(lut_len),
                             max_len=max_len, max_count=mc)
    return np.asarray(out)


register_backend(DecoderBackend(
    name="jax", fn=_jax_decode, probe=_jax_ok, priority=10,
    auto_probe=_jax_accelerated))


# ----------------------------------------------------------------- pallas
def _pallas_supported() -> bool:
    from repro.kernels.huffman_decode import pallas_decode_supported
    return pallas_decode_supported()


def _pallas_decode(interpret: bool):
    def fn(mat, counts, lut_sym, lut_len, max_len, max_count):
        import jax.numpy as jnp
        from repro.kernels.huffman_decode import decode_streams_pallas
        from .decode_jax import bucket_streams
        mat, counts, mc = bucket_streams(mat, counts, max_count)
        out = decode_streams_pallas(
            jnp.asarray(mat), jnp.asarray(counts, jnp.int32),
            jnp.asarray(lut_sym), jnp.asarray(lut_len),
            max_len=max_len, max_count=mc, interpret=interpret)
        return np.asarray(out)
    return fn


register_backend(DecoderBackend(
    name="pallas", fn=_pallas_decode(interpret=False),
    probe=_pallas_supported, priority=20))

# Interpret mode re-runs the kernel's Python trace per symbol step — orders of
# magnitude slower than the numpy path.  Explicit opt-in only (never auto).
register_backend(DecoderBackend(
    name="pallas-interpret", fn=_pallas_decode(interpret=True),
    probe=_jax_ok, priority=-10, auto_probe=lambda: False))
