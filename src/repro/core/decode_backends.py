"""Pluggable decoder backends for the multi-stream entropy decode.

One decode *call* takes a packed stream matrix (S segments x B bytes, guard
padded), per-segment symbol counts, and a codec's decode tables, and returns
the (S, max_count) int32 symbol matrix.  Two **kernel families** cover every
registered codec (see :mod:`repro.core.codecs`, DESIGN.md §7):

* ``"prefix"`` — canonical-code LUT loop (``huffman`` and the ``raw``
  bit-packed baseline): ``core.bitstream.decode_streams`` (numpy),
  ``core.decode_jax.decode_streams_jax`` (jit),
  ``kernels.huffman_decode.decode_streams_pallas`` (TPU kernel).
* ``"tans"`` — carried-state tANS loop (``rans``):
  ``core.bitstream.decode_streams_tans``,
  ``core.decode_jax.decode_streams_tans_jax``,
  ``kernels.ans_decode.decode_streams_tans_pallas``.

This module makes the implementation choice a first-class, *named* decision
instead of an ad-hoc per-call-site import:

* ``register_backend`` / ``get_backend`` — a string-keyed registry
  (``"numpy"``, ``"jax"``, ``"pallas"``, ``"pallas-interpret"``).
* Capability probing — each backend reports :meth:`DecoderBackend.available`;
  the ``pallas`` backend probes whether the kernels actually *compile* on
  this host (``interpret=False``).  Interpret mode is never auto-picked: it
  exists only as the explicitly named ``"pallas-interpret"`` fallback.
* ``auto_pick`` — capability-based default: compiled Pallas on TPU, the jit
  decoder when an accelerator is attached, the numpy host path otherwise.
* :meth:`DecoderBackend.decode_table` — codec-aware entry point: a
  :class:`repro.core.codecs.base.CodeTable` names its kernel family and
  supplies the gather arrays; the backend routes to the right loop.

The :class:`repro.core.scheduler.DecodeScheduler` drives whichever backend it
is handed; see docs/ARCHITECTURE.md §"Streaming decode" for the data flow.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional

import numpy as np

from .bitstream import decode_streams, decode_streams_tans


@dataclasses.dataclass(frozen=True)
class DecoderBackend:
    """A named decode implementation + its capability probes.

    ``fns`` maps kernel family -> callable:
      ``fns["prefix"](mat, counts, lut_sym, lut_len, max_len, max_count,
      out=None)``
      ``fns["tans"](mat, counts, tab_sym, tab_bits, tab_base, table_log,
      max_count, out=None)`` — both return an (S, >=max_count) int32 ndarray.
    ``out`` is an optional preallocated int32 host buffer (the
    decode-into-buffer serving contract): the numpy family decodes straight
    into it, device-returning families (jax / pallas) copy their result into
    it — either way the caller's buffer holds the symbols on return, so a
    per-layer decode loop reuses one scratch allocation.
    ``probe`` answers "can this backend run here at all?" (gates by-name
    requests); ``auto_probe`` answers "should auto-pick use it here?" — e.g.
    the jit decoder runs fine on CPU but is only *preferred* when an
    accelerator is attached, and the interpret fallback is runnable
    everywhere yet never auto-picked.  ``priority`` orders auto-pick
    (higher wins).
    """

    name: str
    fns: Mapping[str, Callable[..., np.ndarray]]
    probe: Callable[[], bool]
    priority: int = 0
    auto_probe: Optional[Callable[[], bool]] = None
    # the *fused* capability (decode→dequant→matmul in one pass, see
    # kernels/fused_decode_matmul.py): family -> callable
    #   fused_fns[fam](table, x, mat, scale, zero, *, seg_symbols, K, N,
    #                  bits) -> (..., N) activations
    # probed like compile capability: ``fused_probe`` answers "does the
    # fused kernel actually run here?" (falls back to ``probe``)
    fused_fns: Optional[Mapping[str, Callable]] = None
    fused_probe: Optional[Callable[[], bool]] = None

    @property
    def fn(self) -> Callable[..., np.ndarray]:
        """Legacy alias: the prefix-family decode callable."""
        return self.fns["prefix"]

    def available(self) -> bool:
        try:
            return bool(self.probe())
        except Exception:
            return False

    def auto_eligible(self) -> bool:
        try:
            return bool((self.auto_probe or self.probe)())
        except Exception:
            return False

    def kernel_families(self) -> List[str]:
        return sorted(self.fns)

    def fused_available(self) -> bool:
        """Can this backend run the fused decode→dequant→matmul here?"""
        if not self.fused_fns:
            return False
        try:
            return bool((self.fused_probe or self.probe)())
        except Exception:
            return False

    def fused_families(self) -> List[str]:
        return sorted(self.fused_fns or ())

    def fused_matmul(self, table, x, mat, scale, zero, *, seg_symbols: int,
                     K: int, N: int, bits: int = 8):
        """Fused ``x @ dequant(decode(mat))`` through this backend's kernel
        (same family routing as :meth:`decode_table`)."""
        fn = (self.fused_fns or {}).get(table.kernel)
        if fn is None:
            raise RuntimeError(
                f"decoder backend {self.name!r} has no fused {table.kernel!r} "
                f"kernel (fused families: {self.fused_families()})")
        return fn(table, x, mat, scale, zero, seg_symbols=seg_symbols,
                  K=K, N=N, bits=bits)

    def decode(self, mat: np.ndarray, counts: np.ndarray, lut_sym: np.ndarray,
               lut_len: np.ndarray, *, max_len: int,
               max_count: Optional[int] = None,
               out: Optional[np.ndarray] = None) -> np.ndarray:
        """Prefix-family decode (the pre-codec-registry contract, kept for
        direct callers); codec-aware callers use :meth:`decode_table`."""
        counts = np.asarray(counts, dtype=np.int64)
        mc = int(counts.max(initial=0)) if max_count is None else int(max_count)
        res = self.fns["prefix"](mat, counts, lut_sym, lut_len, max_len, mc,
                                 out=out)
        return np.asarray(res)[:, :mc] if mc else np.asarray(res)

    def decode_table(self, table, mat: np.ndarray, counts: np.ndarray, *,
                     max_count: Optional[int] = None,
                     out: Optional[np.ndarray] = None) -> np.ndarray:
        """Decode streams encoded under ``table`` (a codecs.CodeTable): the
        table names its kernel family and supplies the gather arrays.
        ``out`` is the optional decode-into-preallocated-buffer contract
        shared by both kernel families (see the class docstring)."""
        try:
            fn = self.fns[table.kernel]
        except KeyError:
            raise RuntimeError(
                f"decoder backend {self.name!r} has no {table.kernel!r} "
                f"kernel (families: {self.kernel_families()})") from None
        counts = np.asarray(counts, dtype=np.int64)
        mc = int(counts.max(initial=0)) if max_count is None else int(max_count)
        a = table.decode_arrays()
        if table.kernel == "prefix":
            res = fn(mat, counts, a["lut_sym"], a["lut_len"],
                     table.peek_bits, mc, out=out)
        elif table.kernel == "tans":
            res = fn(mat, counts, a["tab_sym"], a["tab_bits"], a["tab_base"],
                     table.table_log, mc, out=out)
        else:
            raise RuntimeError(f"unknown kernel family {table.kernel!r}")
        return np.asarray(res)[:, :mc] if mc else np.asarray(res)


_REGISTRY: Dict[str, DecoderBackend] = {}


def register_backend(backend: DecoderBackend) -> DecoderBackend:
    _REGISTRY[backend.name] = backend
    return backend


def backend_names() -> List[str]:
    return sorted(_REGISTRY)


def available_backends() -> List[str]:
    return [n for n in backend_names() if _REGISTRY[n].available()]


def auto_pick() -> DecoderBackend:
    """Highest-priority backend whose auto-pick probe passes on this host."""
    ranked = sorted(_REGISTRY.values(), key=lambda b: -b.priority)
    for b in ranked:
        if b.auto_eligible():
            return b
    return _REGISTRY["numpy"]    # always available by construction


def get_backend(name: Optional[str] = None) -> DecoderBackend:
    """Resolve a backend by name; ``None`` / ``"auto"`` -> capability pick.

    Asking for an unavailable backend raises so misconfiguration is loud;
    use ``auto`` when a silent fallback is wanted.
    """
    if name is None or name == "auto":
        return auto_pick()
    try:
        b = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown decoder backend {name!r}; "
                       f"registered: {backend_names()}") from None
    if not b.available():
        raise RuntimeError(f"decoder backend {name!r} is not available on "
                           f"this host (available: {available_backends()})")
    return b


def _fill_out(out, res, rows, max_count):
    """Decode-into-buffer fallback for kernels that return fresh (possibly
    bucket-padded) arrays: copy the ``rows`` real streams' symbols into the
    caller's buffer and return the written view.  Same contract — including
    the undersized-buffer ValueError — as the numpy family's in-place path
    (``bitstream._decode_out``); ``rows`` is the pre-bucketing stream count,
    so bucket-padding rows are never copied and never required to fit."""
    if out is None:
        return res
    if out.dtype != np.int32 or out.shape[0] < rows \
            or out.shape[1] < max_count:
        raise ValueError(
            f"decode out buffer {out.dtype}{out.shape} too small for "
            f"({rows}, {max_count}) int32")
    res = np.asarray(res)
    out[:rows, :max_count] = res[:rows, :max_count]
    return out[:rows, :max_count]


# ---------------------------------------------------------- fused capability
def _fused_ref(table, x, mat, scale, zero, *, seg_symbols, K, N, bits=8):
    """Host-decode fused oracle (the numpy backend's 'fused' path — decode
    on host, dequant+dot through the exact serving ops)."""
    import jax.numpy as jnp
    from repro.kernels.ref import fused_decode_matmul_ref
    return fused_decode_matmul_ref(jnp.asarray(x), mat, table, scale, zero,
                                   seg_symbols=seg_symbols, K=K, N=N)


def _fused_impl(impl: str):
    def fn(table, x, mat, scale, zero, *, seg_symbols, K, N, bits=8):
        import jax.numpy as jnp
        from repro.kernels.fused_decode_matmul import (build_fused_qt,
                                                       fused_decode_matmul)
        fq = build_fused_qt(table, mat, scale, zero, seg_symbols=seg_symbols,
                            K=K, N=N, bits=bits, impl=impl)
        return fused_decode_matmul(jnp.asarray(x), fq)
    return fn


def _fused_pallas_supported() -> bool:
    # keyed on the prefix kernel, mirroring _pallas_supported; the tans
    # kernel carries its own probe inside fused_supported("tans")
    from repro.kernels.fused_decode_matmul import fused_supported
    return fused_supported("prefix")


# ------------------------------------------------------------------ numpy
def _numpy_decode(mat, counts, lut_sym, lut_len, max_len, max_count,
                  out=None):
    return decode_streams(mat, counts, lut_sym, lut_len, max_len, out=out)


def _numpy_decode_tans(mat, counts, tab_sym, tab_bits, tab_base, table_log,
                       max_count, out=None):
    return decode_streams_tans(mat, counts, tab_sym, tab_bits, tab_base,
                               table_log, out=out)


register_backend(DecoderBackend(
    name="numpy",
    fns={"prefix": _numpy_decode, "tans": _numpy_decode_tans},
    probe=lambda: True, priority=0,
    fused_fns={"prefix": _fused_ref, "tans": _fused_ref}))


# -------------------------------------------------------------------- jax
def _jax_ok() -> bool:
    import jax  # noqa: F401  (baked into the image; probe stays cheap)
    return True


def _jax_accelerated() -> bool:
    import jax
    return jax.default_backend() != "cpu"


def _jax_decode(mat, counts, lut_sym, lut_len, max_len, max_count, out=None):
    import jax.numpy as jnp
    from .decode_jax import bucket_streams, decode_streams_jax
    rows = mat.shape[0]
    mat, counts, mc = bucket_streams(mat, counts, max_count)
    res = decode_streams_jax(jnp.asarray(mat), jnp.asarray(counts, jnp.int32),
                             jnp.asarray(lut_sym), jnp.asarray(lut_len),
                             max_len=max_len, max_count=mc)
    return _fill_out(out, res, rows, max_count)


def _jax_decode_tans(mat, counts, tab_sym, tab_bits, tab_base, table_log,
                     max_count, out=None):
    import jax.numpy as jnp
    from .decode_jax import bucket_streams, decode_streams_tans_jax
    rows = mat.shape[0]
    mat, counts, mc = bucket_streams(mat, counts, max_count)
    res = decode_streams_tans_jax(
        jnp.asarray(mat), jnp.asarray(counts, jnp.int32),
        jnp.asarray(tab_sym), jnp.asarray(tab_bits), jnp.asarray(tab_base),
        table_log=table_log, max_count=mc)
    return _fill_out(out, res, rows, max_count)


register_backend(DecoderBackend(
    name="jax",
    fns={"prefix": _jax_decode, "tans": _jax_decode_tans},
    probe=_jax_ok, priority=10, auto_probe=_jax_accelerated,
    fused_fns={"prefix": _fused_impl("jax"), "tans": _fused_impl("jax")}))


# ----------------------------------------------------------------- pallas
def _pallas_supported() -> bool:
    # availability keyed on the prefix kernel alone (the pre-registry
    # contract): a host that compiles huffman but not the newer tANS kernel
    # keeps its working 'pallas' prefix decode; the tans fn below probes its
    # own kernel and fails loudly with a named fallback if it cannot compile
    from repro.kernels.huffman_decode import pallas_decode_supported
    return pallas_decode_supported()


def _pallas_decode(interpret: bool):
    def fn(mat, counts, lut_sym, lut_len, max_len, max_count, out=None):
        import jax.numpy as jnp
        from repro.kernels.huffman_decode import decode_streams_pallas
        from .decode_jax import bucket_streams
        rows = mat.shape[0]
        mat, counts, mc = bucket_streams(mat, counts, max_count)
        res = decode_streams_pallas(
            jnp.asarray(mat), jnp.asarray(counts, jnp.int32),
            jnp.asarray(lut_sym), jnp.asarray(lut_len),
            max_len=max_len, max_count=mc, interpret=interpret)
        return _fill_out(out, res, rows, max_count)
    return fn


def _pallas_decode_tans(interpret: bool):
    def fn(mat, counts, tab_sym, tab_bits, tab_base, table_log, max_count,
           out=None):
        import warnings

        import jax.numpy as jnp
        from repro.kernels.ans_decode import (decode_streams_tans_pallas,
                                              tans_decode_supported)
        from .decode_jax import bucket_streams
        if not interpret and not tans_decode_supported():
            # availability is keyed on the prefix kernel, so auto may route a
            # rans container here on a host where only the tANS kernel fails
            # to compile: honor auto's silent-fallback contract (and spare a
            # by-name user a crash) by delegating to the jit tans loop
            warnings.warn(
                "the pallas backend's tANS kernel does not compile on this "
                "host; falling back to the jit tans decoder for this call",
                stacklevel=2)
            return _jax_decode_tans(mat, counts, tab_sym, tab_bits, tab_base,
                                    table_log, max_count, out=out)
        rows = mat.shape[0]
        mat, counts, mc = bucket_streams(mat, counts, max_count)
        res = decode_streams_tans_pallas(
            jnp.asarray(mat), jnp.asarray(counts, jnp.int32),
            jnp.asarray(tab_sym), jnp.asarray(tab_bits),
            jnp.asarray(tab_base),
            table_log=table_log, max_count=mc, interpret=interpret)
        return _fill_out(out, res, rows, max_count)
    return fn


register_backend(DecoderBackend(
    name="pallas",
    fns={"prefix": _pallas_decode(interpret=False),
         "tans": _pallas_decode_tans(interpret=False)},
    probe=_pallas_supported, priority=20,
    fused_fns={"prefix": _fused_impl("pallas"),
               "tans": _fused_impl("pallas")},
    fused_probe=_fused_pallas_supported))

# Interpret mode re-runs the kernel's Python trace per symbol step — orders of
# magnitude slower than the numpy path.  Explicit opt-in only (never auto).
register_backend(DecoderBackend(
    name="pallas-interpret",
    fns={"prefix": _pallas_decode(interpret=True),
         "tans": _pallas_decode_tans(interpret=True)},
    probe=_jax_ok, priority=-10, auto_probe=lambda: False,
    fused_fns={"prefix": _fused_impl("pallas-interpret"),
               "tans": _fused_impl("pallas-interpret")},
    fused_probe=_jax_ok))
