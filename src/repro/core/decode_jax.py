"""Jittable multi-stream decoders (device path): prefix + tANS loop families.

Identical structure to :func:`repro.core.bitstream.decode_streams` /
:func:`repro.core.bitstream.decode_streams_tans` but expressed with
``lax.fori_loop`` + vectorized gathers so they can run under ``jit`` / inside
``shard_map`` (each device decodes only its local segments — the pod-scale version of
the paper's thread-parallel decode).  The Pallas kernels in
``repro.kernels.huffman_decode`` / ``repro.kernels.ans_decode`` implement the
same loops with the tables pinned in VMEM.

:func:`bucket_streams` is the host-side companion for *chunked* callers (the
streaming :class:`~repro.core.scheduler.DecodeScheduler`): ``decode_streams_jax``
specializes on (S, B, max_count), so decoding many variably-shaped chunks
would recompile per chunk — bucketing shapes to powers of two keeps the
compile cache to a handful of entries.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .bitstream import pow2_bucket


def bucket_streams(mat: np.ndarray, counts: np.ndarray, max_count: int,
                   ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Zero-pad (S, B) streams + counts so every dimension lands on a
    power-of-two bucket (padded lanes decode nothing: count 0).  Callers that
    pack with ``pack_streams(..., min_width=pow2_bucket(...))`` arrive with B
    already bucketed and skip the copy here."""
    S, B = mat.shape
    Sp = pow2_bucket(S, 8)
    Bp = pow2_bucket(B, 64)
    mc = pow2_bucket(max_count, 256)
    if (Sp, Bp) != (S, B):
        m = np.zeros((Sp, Bp), dtype=np.uint8)
        m[:S, :B] = mat
        mat = m
        counts = np.concatenate(
            [np.asarray(counts, np.int64), np.zeros(Sp - S, np.int64)])
    return mat, np.asarray(counts, np.int64), mc


@partial(jax.jit, static_argnames=("max_len", "max_count"))
def decode_streams_jax(mat: jnp.ndarray, counts: jnp.ndarray, lut_sym: jnp.ndarray,
                       lut_len: jnp.ndarray, *, max_len: int, max_count: int) -> jnp.ndarray:
    """mat: (S, B) uint8 guard-padded streams; counts: (S,) int32.

    Returns (S, max_count) int32 decoded symbols (zero past counts).
    ``max_count`` must be a static upper bound on counts (segments are built with a
    fixed symbol budget, so this is exact in practice).
    """
    S = mat.shape[0]
    d = mat.astype(jnp.uint32)
    rows = jnp.arange(S)
    mask = jnp.uint32((1 << max_len) - 1)

    def step(k, carry):
        bitpos, out = carry
        byte = (bitpos >> 3).astype(jnp.int32)
        w = (
            (d[rows, byte] << 24)
            | (d[rows, byte + 1] << 16)
            | (d[rows, byte + 2] << 8)
            | d[rows, byte + 3]
        )
        shift = (32 - max_len - (bitpos & 7)).astype(jnp.uint32)
        peek = (w >> shift) & mask
        sym = lut_sym[peek]
        ln = lut_len[peek]
        active = k < counts
        out = out.at[:, k].set(jnp.where(active, sym, 0))
        bitpos = jnp.where(active, bitpos + ln, bitpos)
        return bitpos, out

    bitpos0 = jnp.zeros((S,), jnp.int32)
    out0 = jnp.zeros((S, max_count), jnp.int32)
    _, out = jax.lax.fori_loop(0, max_count, step, (bitpos0, out0))
    return out


@partial(jax.jit, static_argnames=("table_log", "max_count"))
def decode_streams_tans_jax(mat: jnp.ndarray, counts: jnp.ndarray,
                            tab_sym: jnp.ndarray, tab_bits: jnp.ndarray,
                            tab_base: jnp.ndarray, *, table_log: int,
                            max_count: int) -> jnp.ndarray:
    """Lock-step tANS decode under jit — the carried-state twin of
    :func:`decode_streams_jax`.  mat rows start with the 16-bit initial
    state header (see ``bitstream.TANS_STATE_HEADER_BITS``)."""
    from repro.core.bitstream import TANS_STATE_HEADER_BITS
    S = mat.shape[0]
    d = mat.astype(jnp.uint32)
    rows = jnp.arange(S)
    mask = jnp.uint32((1 << table_log) - 1)

    def step(k, carry):
        st, bitpos, out = carry
        sym = tab_sym[st]
        nb = tab_bits[st]
        byte = (bitpos >> 3).astype(jnp.int32)
        w = (
            (d[rows, byte] << 24)
            | (d[rows, byte + 1] << 16)
            | (d[rows, byte + 2] << 8)
            | d[rows, byte + 3]
        )
        shift = (32 - table_log - (bitpos & 7)).astype(jnp.uint32)
        peek = (w >> shift) & mask
        fresh = (peek >> (table_log - nb).astype(jnp.uint32)).astype(jnp.int32)
        active = k < counts
        out = out.at[:, k].set(jnp.where(active, sym, 0))
        st = jnp.where(active, tab_base[st] + fresh, st)
        bitpos = jnp.where(active, bitpos + nb, bitpos)
        return st, bitpos, out

    st0 = ((d[:, 0] << 8) | d[:, 1]).astype(jnp.int32)
    bitpos0 = jnp.full((S,), TANS_STATE_HEADER_BITS, jnp.int32)
    out0 = jnp.zeros((S, max_count), jnp.int32)
    _, _, out = jax.lax.fori_loop(0, max_count, step, (st0, bitpos0, out0))
    return out
