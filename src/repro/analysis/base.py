"""Shared static-analysis surface: findings, baselines, reporters, registry.

This module is the one reporting API all three repo gates speak
(``scripts/check_static.py``, ``scripts/check_docs.py``,
``scripts/check_trace.py``): a checker produces :class:`Finding` rows, a
reviewed :class:`Baseline` absorbs the accepted ones, and the reporters
print the rest as uniform ``file:line rule message`` text or ``--json``.

Deliberately stdlib-only — the ``docs-check`` CI job runs ``check_docs.py``
on a bare interpreter (no jax/numpy install), so importing this module must
never pull the scientific stack.  Checkers that need jax (twin-consistency)
import it lazily inside their ``check()`` function; the registry maps
checker names to ``"module:function"`` strings resolved only when run.
"""
from __future__ import annotations

import dataclasses
import importlib
import json
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Tuple

REPO_ROOT = Path(__file__).resolve().parents[3]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One structured analysis result.

    ``file`` is repo-relative; ``symbol`` names the enclosing function or
    class so the baseline fingerprint survives unrelated line drift.
    """

    file: str
    line: int
    rule: str
    message: str
    symbol: str = ""

    def fingerprint(self) -> str:
        # line numbers are deliberately excluded: a baselined finding must
        # stay baselined when code above it moves.  Messages are normalized
        # (digit runs collapsed) so counters/shapes embedded in them do not
        # churn the fingerprint either.
        msg = re.sub(r"\d+", "N", self.message)
        return f"{self.rule}::{self.file}::{self.symbol}::{msg}"

    def render(self) -> str:
        return f"{self.file}:{self.line} {self.rule} {self.message}"

    def to_json(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


class Baseline:
    """Reviewed suppression file: fingerprint -> one-line justification.

    Every entry is an *accepted* finding — intentional code the checkers
    would otherwise flag — and carries a human justification string that
    code review owns.  ``split()`` partitions a fresh run into (new,
    accepted, stale); the CI gate fails on new, reports stale so dead
    entries get pruned.
    """

    def __init__(self, entries: Optional[Dict[str, str]] = None):
        self.entries: Dict[str, str] = dict(entries or {})

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        if not path.exists():
            return cls()
        data = json.loads(path.read_text())
        entries = {e["fingerprint"]: e.get("justification", "")
                   for e in data.get("entries", [])}
        return cls(entries)

    def save(self, path: Path) -> None:
        data = {
            "comment": "Reviewed static-analysis suppressions "
                       "(docs/STATIC_ANALYSIS.md). Every entry needs a "
                       "justification a reviewer signed off on.",
            "entries": [
                {"fingerprint": fp, "justification": j}
                for fp, j in sorted(self.entries.items())
            ],
        }
        path.write_text(json.dumps(data, indent=2) + "\n")

    def split(self, findings: Iterable[Finding]
              ) -> Tuple[List[Finding], List[Finding], List[str]]:
        """Partition into (new, accepted) findings + stale fingerprints."""
        new: List[Finding] = []
        accepted: List[Finding] = []
        seen: set = set()
        for f in findings:
            fp = f.fingerprint()
            if fp in self.entries:
                accepted.append(f)
                seen.add(fp)
            else:
                new.append(f)
        stale = sorted(set(self.entries) - seen)
        return new, accepted, stale

    def absorb(self, findings: Iterable[Finding],
               justification: str = "TODO: justify") -> int:
        added = 0
        for f in findings:
            fp = f.fingerprint()
            if fp not in self.entries:
                self.entries[fp] = justification
                added += 1
        return added


# --------------------------------------------------------------- reporters

def render_text(findings: Iterable[Finding]) -> str:
    return "\n".join(f.render() for f in findings)


def render_json(findings: Iterable[Finding], *,
                extra: Optional[Dict[str, object]] = None) -> str:
    payload: Dict[str, object] = {"findings": [f.to_json() for f in findings]}
    if extra:
        payload.update(extra)
    return json.dumps(payload, indent=2)


# ---------------------------------------------------------------- registry

# name -> "module:function"; the callable takes the repo root Path and
# returns List[Finding].  Strings (not callables) keep this module free of
# checker imports — resolve() is the only place a checker module loads.
CHECKERS: Dict[str, str] = {
    "twin-consistency": "repro.analysis.twins:check",
    "dtype-discipline": "repro.analysis.dtypes:check",
    "jit-host-boundary": "repro.analysis.jit_boundary:check",
    "lock-discipline": "repro.analysis.locks:check",
    "catalog-sync": "repro.analysis.catalog:check",
}


def resolve(name: str) -> Callable[[Path], List[Finding]]:
    spec = CHECKERS[name]
    mod_name, _, fn_name = spec.partition(":")
    return getattr(importlib.import_module(mod_name), fn_name)


def run_checkers(names: Iterable[str], root: Path = REPO_ROOT
                 ) -> List[Finding]:
    findings: List[Finding] = []
    for name in names:
        findings.extend(resolve(name)(root))
    return findings


# ------------------------------------------------------------ AST helpers
# (shared by the pure-AST checkers; stdlib `ast` only)

def iter_py_files(root: Path, rel_globs: Iterable[str]) -> List[Path]:
    files: List[Path] = []
    for g in rel_globs:
        files.extend(sorted(root.glob(g)))
    return [f for f in files if f.is_file()]


def rel(path: Path, root: Path) -> str:
    try:
        return str(path.relative_to(root))
    except ValueError:
        return str(path)
