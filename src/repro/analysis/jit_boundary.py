"""jit-host-boundary: no Python side effects inside staged computations.

Obs spans and metrics are host-side: a ``obs_trace.span`` inside a jitted
closure fires once at trace time and then never again (or worse, at every
retrace), silently recording garbage — the reason ``obs/points.py``
documents the fully-fused carve-out (no per-layer decode points when the
layer loop lives inside jit).  The same goes for ``print``, ``time.*``,
``.item()``/``.tolist()`` host syncs, file I/O, and threading calls.

The pass finds *jit roots* in each module:

* functions decorated ``@jax.jit`` / ``@(functools.)partial(jax.jit, …)``
* local defs passed to ``jax.jit(fn)`` / assigned ``x = jax.jit(fn)``
* kernel functions handed to ``pl.pallas_call`` (directly or via partial)
* bodies handed to ``lax.scan`` / ``while_loop`` / ``fori_loop`` /
  ``cond`` / ``jax.checkpoint`` / ``jax.remat`` / ``jax.vmap`` /
  ``jax.grad`` / ``jax.value_and_grad``

then walks the module-local call graph from those roots (a worklist over
same-module function names) and flags host-side calls anywhere in the
traced set.  ``jax.debug.print`` / ``jax.debug.callback`` are exempt —
they are the sanctioned staged escape hatches.

numpy calls are only flagged for a small mutating/extracting subset
(``np.save``, ``np.asarray`` on traced values is legitimate constant
folding and stays allowed — flagging all of ``np.*`` would drown real
findings in trace-time constant math).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Set

from .base import Finding, iter_py_files, rel

TARGET_GLOBS = ["src/repro/**/*.py"]

# staging entry points whose first function-valued argument becomes traced
STAGERS = {"scan", "while_loop", "fori_loop", "cond", "checkpoint", "remat",
           "vmap", "grad", "value_and_grad", "pallas_call", "jit"}

HOST_CALL_NAMES = {"print", "open", "input", "breakpoint"}
HOST_ATTR_CALLS = {"item", "tolist", "block_until_ready"}
HOST_MODULES = {"time", "threading", "os", "sys", "logging"}
OBS_MODULES = {"obs_trace", "obs_metrics"}
NP_HOST_FNS = {"save", "load", "savez", "fromfile", "tofile"}


def _func_name(node: ast.AST) -> str:
    """Dotted name of a call target ('jax.lax.scan', 'obs_trace.span')."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _is_jit_expr(node: ast.AST) -> bool:
    """jax.jit(...) / partial(jax.jit, ...) / functools.partial(jax.jit,…)."""
    if not isinstance(node, ast.Call):
        return False
    name = _func_name(node.func)
    if name.endswith("jit"):
        return True
    if name.split(".")[-1] == "partial" and node.args:
        return _is_jit_expr(ast.Call(func=node.args[0], args=[],
                                     keywords=[])) or \
            _func_name(node.args[0]).endswith("jit")
    return False


def _fn_args_of(call: ast.Call) -> List[str]:
    """Names of function-valued args passed into a staging call."""
    out: List[str] = []
    for a in call.args:
        if isinstance(a, ast.Name):
            out.append(a.id)
        elif isinstance(a, ast.Call) and \
                _func_name(a.func).split(".")[-1] == "partial" and a.args \
                and isinstance(a.args[0], ast.Name):
            out.append(a.args[0].id)
    return out


class _ModuleScan:
    """Collect defs, jit roots, and per-def host calls for one module."""

    def __init__(self, tree: ast.Module, file: str):
        self.file = file
        self.defs: Dict[str, ast.FunctionDef] = {}
        self.roots: Set[str] = set()
        self.aliases: Dict[str, Set[str]] = {}
        self._collect(tree)

    def _collect(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            # alias tracking: `kernel = functools.partial(_kern, ...)` /
            # `step = body` — so `pallas_call(kernel)` resolves to _kern
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                tgt = node.targets[0].id
                v = node.value
                if isinstance(v, ast.Name):
                    self.aliases.setdefault(tgt, set()).add(v.id)
                elif isinstance(v, ast.Call) and \
                        _func_name(v.func).split(".")[-1] == "partial" \
                        and v.args and isinstance(v.args[0], ast.Name):
                    self.aliases.setdefault(tgt, set()).add(v.args[0].id)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # last definition wins; nested defs recorded too (the call
                # graph is name-based within the module)
                self.defs[node.name] = node
                for dec in node.decorator_list:
                    if _is_jit_expr(dec) or _func_name(dec).endswith("jit"):
                        self.roots.add(node.name)
            if isinstance(node, ast.Call):
                name = _func_name(node.func)
                tail = name.split(".")[-1]
                if tail in STAGERS:
                    self.roots.update(_fn_args_of(node))
                if tail == "jit" or (tail == "partial" and node.args and
                                     _func_name(node.args[0]).endswith("jit")):
                    self.roots.update(_fn_args_of(node))

    def traced_set(self) -> Set[str]:
        """Worklist closure of jit roots over module-local calls."""
        seen: Set[str] = set()
        resolved: Set[str] = set()
        for r in self.roots:
            if r in self.defs:
                resolved.add(r)
            else:
                resolved.update(self.aliases.get(r, set()))
        work = [r for r in resolved if r in self.defs]
        while work:
            name = work.pop()
            if name in seen:
                continue
            seen.add(name)
            for node in ast.walk(self.defs[name]):
                if isinstance(node, ast.Call):
                    callee = _func_name(node.func)
                    if callee in self.defs and callee not in seen:
                        work.append(callee)
        return seen

    def host_calls(self, fn: ast.FunctionDef) -> List[ast.Call]:
        bad: List[ast.Call] = []
        # nested defs inside fn that are themselves traced are visited on
        # their own worklist turn; host calls inside them still lexically
        # sit inside fn, so visiting the whole subtree is conservative but
        # correct (a host call is a finding wherever it sits in the set)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = _func_name(node.func)
            head, _, _tail = name.partition(".")
            last = name.split(".")[-1]
            if name.startswith("jax.debug") or head == "debug":
                continue
            if head in OBS_MODULES:
                bad.append(node)
            elif name in HOST_CALL_NAMES:
                bad.append(node)
            elif last in HOST_ATTR_CALLS and "." in name:
                bad.append(node)
            elif head in HOST_MODULES and "." in name:
                bad.append(node)
            elif head == "np" and last in NP_HOST_FNS:
                bad.append(node)
        return bad


def check_source(src: str, file: str) -> List[Finding]:
    tree = ast.parse(src)
    scan = _ModuleScan(tree, file)
    findings: List[Finding] = []
    for name in sorted(scan.traced_set()):
        fn = scan.defs[name]
        for call in scan.host_calls(fn):
            findings.append(Finding(
                file=file, line=call.lineno, rule="jit-host-boundary",
                message=f"host-side call {_func_name(call.func)!r} "
                        f"reachable inside staged function {name!r} — "
                        f"runs at trace time, not per step",
                symbol=name))
    return findings


def check(root: Path) -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_py_files(root, TARGET_GLOBS):
        if "analysis" in path.parts:
            continue
        findings.extend(check_source(path.read_text(), rel(path, root)))
    return findings
