"""twin-consistency: prove the hand-maintained serving twins op-for-op.

ROADMAP calls the per-layer ``resident_*`` and paged ``paged_*`` twins in
``models/dense.py`` / ``models/moe.py`` a *bit-identity hazard*: each one
must mirror one ``lax.scan`` iteration of its whole-tree step function, and
today nothing but end-to-end greedy-identity tests notices drift.  This
checker catches it at trace time: both sides are staged with
``jax.make_jaxpr`` on a microscopic :class:`ArchConfig`, the scan body is
extracted from the step function's jaxpr, and the two op sequences are
compared after canonicalization.

Canonicalization (the *documented* differences between a twin and its scan
body, see docs/STATIC_ANALYSIS.md):

* routing primitives are dropped — gather/scatter/dynamic-slice/reshape
  and friends.  The paged twins route K/V through block tables
  (``gather_blocks``/``scatter_blocks``) where the slot path uses
  ``update_kv_cache``; ``resident_block`` slices its layer's cache rows
  with ``dynamic_index_in_dim`` where the scan feeds them as xs.  Routing
  moves bytes; it cannot change values, so it is exempt by construction.
* non-float and scalar outputs are dropped — the twins compute positions
  and masks locally (integer ops) and the MoE scan carries a scalar aux
  accumulator the twins do not.
* wrapper primitives (pjit / custom_jvp / remat / nested scans) are
  flattened into their inner equations.

Everything that remains — matmuls, norms, rope, softmax, quantize grids,
casts — must match in primitive, shape, and dtype, in order.  A twin that
adds, drops, or re-types one float op fails with the first divergence.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Any, Callable, List, Sequence, Tuple

from .base import Finding

# routing/bookkeeping primitives: move or reshape bytes, never change them.
ROUTING_PRIMS = frozenset({
    "gather", "scatter", "scatter-add", "dynamic_slice",
    "dynamic_update_slice", "broadcast_in_dim", "reshape", "concatenate",
    "squeeze", "slice", "pad", "iota", "transpose", "rev", "copy",
    "select_n",
})

Op = Tuple[str, Tuple[Tuple[Tuple[int, ...], str], ...]]


def _subjaxprs(eqn) -> List[Any]:
    """Every jaxpr nested in an equation's params (pjit, scan, custom_*…)."""
    subs: List[Any] = []
    for v in eqn.params.values():
        for cand in (v if isinstance(v, (tuple, list)) else (v,)):
            if hasattr(cand, "eqns"):            # Jaxpr
                subs.append(cand)
            elif hasattr(cand, "jaxpr") and hasattr(cand.jaxpr, "eqns"):
                subs.append(cand.jaxpr)          # ClosedJaxpr
    return subs


def canonical_ops(jaxpr) -> List[Op]:
    """Flatten a (Closed)Jaxpr into the comparable float-op sequence."""
    import jax.numpy as jnp
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    ops: List[Op] = []

    def rec(jx) -> None:
        for eqn in jx.eqns:
            subs = _subjaxprs(eqn)
            if subs:
                for s in subs:
                    rec(s)
                continue
            if eqn.primitive.name in ROUTING_PRIMS:
                continue
            outs = []
            for var in eqn.outvars:
                aval = var.aval
                if not hasattr(aval, "dtype") or not hasattr(aval, "shape"):
                    continue
                if jnp.issubdtype(aval.dtype, jnp.floating) and aval.ndim:
                    outs.append((tuple(aval.shape), str(aval.dtype)))
            if outs:
                ops.append((eqn.primitive.name, tuple(outs)))

    rec(jaxpr)
    return ops


def scan_body(closed) -> Any:
    """The inner jaxpr of the (first) ``scan`` equation — the layer body."""

    def find(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name == "scan":
                return eqn.params["jaxpr"]
            for s in _subjaxprs(eqn):
                got = find(s)
                if got is not None:
                    return got
        return None

    got = find(closed.jaxpr if hasattr(closed, "jaxpr") else closed)
    if got is None:
        raise ValueError("no scan equation found — step function changed "
                         "shape; update repro.analysis.twins")
    return got


def diff_ops(ref: Sequence[Op], twin: Sequence[Op]) -> str:
    """Empty string when identical, else a first-divergence description."""
    for i, (a, b) in enumerate(zip(ref, twin)):
        if a != b:
            return (f"op {i}: scan body has {a[0]}{list(a[1])} but twin "
                    f"has {b[0]}{list(b[1])}")
    if len(ref) != len(twin):
        longer, who = (ref, "scan body") if len(ref) > len(twin) \
            else (twin, "twin")
        extra = longer[min(len(ref), len(twin))]
        return (f"length {len(ref)} vs {len(twin)}: {who} additionally "
                f"computes {extra[0]}{list(extra[1])}")
    return ""


# ----------------------------------------------------------- pair builders

@dataclasses.dataclass(frozen=True)
class TwinPair:
    """One contract: ``twin`` must mirror ``ref``'s scan body op-for-op."""

    name: str
    ref_ops: Callable[[], List[Op]]
    twin_ops: Callable[[], List[Op]]
    twin_obj: Any                        # for file:line of the finding


def _tiny_cfg(family: str):
    from repro.configs.base import ArchConfig, MoEConfig
    moe = MoEConfig(num_experts=4, top_k=2) if family == "moe" else None
    return ArchConfig(name=f"lint-{family}", family=family, n_layers=2,
                      d_model=16, n_heads=2, n_kv_heads=1, d_ff=32,
                      vocab=64, head_dim=8, moe=moe,
                      source="twin-consistency lint config")


def twin_pairs(family: str) -> List[TwinPair]:
    """The five contracts for one model family ('dense' | 'moe')."""
    import jax
    import jax.numpy as jnp
    from repro.models import dense
    mod = dense if family == "dense" else __import__(
        "repro.models.moe", fromlist=["moe"])
    cfg = _tiny_cfg(family)

    B, S_CHUNK, MAX_LEN, BS = 2, 4, 8, 4      # MAX_LEN == MB * BS (MB=2)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    stack = dense._layer_stack(params)
    lp0 = {k: v[0] for k, v in stack.items()}
    xdt = params["embed"].dtype

    def x_at(s):
        return jnp.zeros((B, s, cfg.d_model), xdt)

    tokens = jnp.zeros((B, S_CHUNK), jnp.int32)
    token1 = jnp.zeros((B, 1), jnp.int32)
    cache = dense.init_cache(cfg, B, MAX_LEN)
    pool = dense.init_kv_pool(cfg, n_blocks=B * 2 + 1, block_size=BS)
    bt = jnp.arange(1, B * 2 + 1, dtype=jnp.int32).reshape(B, 2)
    posv = jnp.zeros((B,), jnp.int32)         # per-slot positions

    def body_ops(fn, *args, **kw):
        return lambda: canonical_ops(
            scan_body(jax.make_jaxpr(lambda: fn(*args, **kw))()))

    def whole_ops(fn, *args, **kw):
        return lambda: canonical_ops(
            jax.make_jaxpr(lambda: fn(*args, **kw))())

    pairs = [
        TwinPair(
            f"{family}:forward-collect vs resident_prefill_block",
            body_ops(mod.forward, cfg, params, tokens, collect_cache=True),
            whole_ops(mod.resident_prefill_block, cfg, lp0, x_at(S_CHUNK),
                      positions=jnp.arange(S_CHUNK)),
            mod.resident_prefill_block),
        TwinPair(
            f"{family}:decode_step vs resident_block (S=1)",
            body_ops(mod.decode_step, cfg, params, token1, cache, posv),
            whole_ops(mod.resident_block, cfg, lp0, x_at(1), cache, 0, posv),
            mod.resident_block),
        TwinPair(
            f"{family}:prefill_chunk vs resident_block (S={S_CHUNK})",
            body_ops(mod.prefill_chunk, cfg, params, tokens, cache, posv),
            whole_ops(mod.resident_block, cfg, lp0, x_at(S_CHUNK), cache, 0,
                      posv),
            mod.resident_block),
        TwinPair(
            f"{family}:decode_step vs paged_decode_step (kv16)",
            body_ops(mod.decode_step, cfg, params, token1, cache, posv),
            body_ops(mod.paged_decode_step, cfg, params, token1, pool, bt,
                     posv),
            mod.paged_decode_step),
        TwinPair(
            f"{family}:prefill_chunk vs paged_prefill_chunk (kv16)",
            body_ops(mod.prefill_chunk, cfg, params, tokens, cache, posv),
            body_ops(mod.paged_prefill_chunk, cfg, params, tokens, pool, bt,
                     posv),
            mod.paged_prefill_chunk),
    ]
    return pairs


def compare_pair(pair: TwinPair) -> str:
    """Empty string when the contract holds, else the divergence message."""
    return diff_ops(pair.ref_ops(), pair.twin_ops())


def _location(obj) -> Tuple[str, int]:
    import inspect
    try:
        file = Path(inspect.getsourcefile(obj)).resolve()
        line = inspect.getsourcelines(obj)[1]
        from .base import REPO_ROOT
        return str(file.relative_to(REPO_ROOT)), line
    except (TypeError, OSError, ValueError):
        return "<unknown>", 0


def check(root: Path) -> List[Finding]:
    findings: List[Finding] = []
    for family in ("dense", "moe"):
        try:
            pairs = twin_pairs(family)
        except Exception as e:                    # noqa: BLE001 — surface,
            findings.append(Finding(               # never silently skip
                file=f"src/repro/models/{family}.py", line=1,
                rule="twin-consistency",
                message=f"checker could not stage {family} pairs: {e!r}",
                symbol=family))
            continue
        for pair in pairs:
            try:
                msg = compare_pair(pair)
            except Exception as e:                # noqa: BLE001
                file, line = _location(pair.twin_obj)
                findings.append(Finding(
                    file=file, line=line, rule="twin-consistency",
                    message=f"[{pair.name}] trace failed: {e!r}",
                    symbol=pair.name))
                continue
            if msg:
                file, line = _location(pair.twin_obj)
                findings.append(Finding(
                    file=file, line=line, rule="twin-consistency",
                    message=f"[{pair.name}] {msg}", symbol=pair.name))
    return findings
