"""dtype-discipline: dequant affine arithmetic is f32; bf16 only at the dot.

The PR-4 rule (EXPERIMENTS.md §Perf H1, kernels/dequant_matmul.py): the
dequantization affine ``q * scale + zero`` must be computed in float32 —
bf16's 8-bit mantissa rounds the reconstruction grid — and bfloat16 may
appear only as the *operand dtype of the MXU dot* (cast after the affine).

This is an AST pass over ``kernels/`` and ``models/layers.py``.  It finds
affine-dequant expressions (an ``Add`` whose left operand is a ``Mult``)
and resolves the compute dtype of each factor through a per-function
symbol table:

* ``x.astype(jnp.float32)``            -> f32 (compliant)
* ``x.astype(jnp.bfloat16)``           -> bf16 (violation)
* ``x.astype(dt)`` with ``dt = y.dtype`` or a ``dtype=jnp.bfloat16``
  parameter default                    -> dynamic/bf16 (violation: the
  affine inherits whatever the activation carries)

Factors whose dtype cannot be resolved are *not* flagged (no guessing);
the violations this checker does report are therefore high-confidence.
Intentional bf16 affines — ``layers.deq`` and friends define the bf16
quantization *grid* that the bit-identity contract pins — live in the
baseline with per-entry justifications.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional

from .base import Finding, iter_py_files, rel

TARGET_GLOBS = ["src/repro/kernels/*.py", "src/repro/models/layers.py"]

F32, BF16, DYN = "float32", "bfloat16", "dynamic"
_DTYPE_ATTRS = {"float32": F32, "bfloat16": BF16, "float16": BF16}


def _dtype_of_node(node: ast.AST, env: Dict[str, str]) -> Optional[str]:
    """Resolve a dtype-valued expression: jnp.float32, a Name, x.dtype."""
    if isinstance(node, ast.Attribute):
        if node.attr in _DTYPE_ATTRS:
            return _DTYPE_ATTRS[node.attr]
        if node.attr == "dtype":
            return DYN
    if isinstance(node, ast.Name):
        return env.get(node.id)
    return None


def _value_dtype(node: ast.AST, env: Dict[str, str]) -> Optional[str]:
    """Compute dtype of a value expression, best effort (None = unknown)."""
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
            and node.func.attr == "astype" and node.args:
        return _dtype_of_node(node.args[0], env)
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.BinOp):
        # dtype of an arithmetic expr: any bf16/dyn factor taints it
        for side in (node.left, node.right):
            d = _value_dtype(side, env)
            if d in (BF16, DYN):
                return d
        l, r = _value_dtype(node.left, env), _value_dtype(node.right, env)
        if F32 in (l, r):
            return F32
    return None


class _FnChecker(ast.NodeVisitor):
    def __init__(self, file: str, fn: ast.FunctionDef):
        self.file = file
        self.fn = fn
        self.env: Dict[str, str] = {}
        self.findings: List[Finding] = []
        # parameter defaults: def f(..., dtype=jnp.bfloat16) taints `dtype`
        args = fn.args
        defaults = list(args.defaults) + list(args.kw_defaults or [])
        names = [a.arg for a in args.args][len(args.args)
                                           - len(args.defaults):] \
            + [a.arg for a in args.kwonlyargs]
        for name, d in zip(names, defaults):
            if d is None:
                continue
            dt = _dtype_of_node(d, {})
            if dt:
                self.env[name] = dt

    def visit_Assign(self, node: ast.Assign) -> None:
        if len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            dt = _value_dtype(node.value, self.env)
            if dt is None and isinstance(node.value, ast.Attribute) \
                    and node.value.attr == "dtype":
                dt = DYN
            if dt:
                self.env[node.targets[0].id] = dt
        self.generic_visit(node)

    def visit_BinOp(self, node: ast.BinOp) -> None:
        # affine dequant shape: (a * b) + c
        if isinstance(node.op, ast.Add) and \
                isinstance(node.left, ast.BinOp) and \
                isinstance(node.left.op, ast.Mult):
            factors = [node.left.left, node.left.right, node.right]
            bad = []
            for f in factors:
                d = _value_dtype(f, self.env)
                if d in (BF16, DYN):
                    bad.append(d)
            if bad:
                kind = BF16 if BF16 in bad else DYN
                self.findings.append(Finding(
                    file=self.file, line=node.lineno,
                    rule="dtype-discipline",
                    message=f"dequant affine computed in {kind} dtype; "
                            f"PR-4 rule: affine in f32, bf16 only as the "
                            f"dot operand", symbol=self.fn.name))
        self.generic_visit(node)


def check_source(src: str, file: str) -> List[Finding]:
    tree = ast.parse(src)
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fc = _FnChecker(file, node)
            for stmt in node.body:
                fc.visit(stmt)
            findings.extend(fc.findings)
    return findings


def check(root: Path) -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_py_files(root, TARGET_GLOBS):
        findings.extend(check_source(path.read_text(), rel(path, root)))
    return findings
