"""repro-lint: custom static analysis for the repo's unwritten invariants.

The checkers (docs/STATIC_ANALYSIS.md) turn conventions that were previously
enforced only by runtime tests — twin bit-identity, f32 dequant discipline,
no host work inside jit, lock discipline, obs-catalog sync — into
machine-checked rules gating CI before any test runs.

Import surface is deliberately tiny and stdlib-only; checker modules load
lazily via :func:`repro.analysis.base.resolve` so the docs-check job (bare
interpreter, no jax) can share the reporting API.
"""
from .base import (Baseline, CHECKERS, Finding, render_json, render_text,
                   resolve, run_checkers)

__all__ = ["Baseline", "CHECKERS", "Finding", "render_json", "render_text",
           "resolve", "run_checkers"]
