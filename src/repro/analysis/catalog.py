"""catalog-sync: obs point catalog <-> emit sites, and registry closure.

Two drift directions, both previously invisible to tests:

* **dead catalog entry** — a span/metric listed in ``obs/points.py`` with
  no remaining emit site anywhere in ``src/repro`` (a rename or refactor
  dropped the call; ``check_trace.py --expect`` would fail only for the
  modes that exercise it, and only when that mode's smoke runs).
* **uncataloged emit** — an ``obs_trace.span``/``instant`` or
  ``obs_metrics.counter``/``gauge``/``histogram`` call whose literal name
  appears in neither ``EXPECTED_POINTS`` nor ``INFORMATIONAL_POINTS``.
  Every point must be classified: contract (some mode requires it) or
  informational (documented as best-effort).  The two sets must be
  disjoint.

Only literal first arguments are collected; a non-literal name (dynamic
span naming) is itself a finding — the catalog cannot audit what it
cannot read.

The registry half checks closure of the two extension registries:

* every decoder backend provides both decode families (``prefix``,
  ``tans``) and any fused families are a subset of those;
* every entropy codec's table class implements the container round-trip
  surface (``from_container``) that ``table_from_container`` dispatches on.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Set, Tuple

from .base import Finding, iter_py_files, rel

TARGET_GLOBS = ["src/repro/**/*.py"]

SPAN_CALLS = {"span", "instant"}          # obs_trace.<call>("name", ...)
METRIC_CALLS = {"counter", "gauge", "histogram"}   # obs_metrics.<call>("name")
REQUIRED_FAMILIES = frozenset({"prefix", "tans"})

EmitSites = Dict[Tuple[str, str], List[Tuple[str, int]]]


def collect_emits(root: Path) -> Tuple[EmitSites, List[Finding]]:
    """Map (kind, name) -> [(file, line)] for every literal obs emit."""
    sites: EmitSites = {}
    findings: List[Finding] = []
    for path in iter_py_files(root, TARGET_GLOBS):
        if "analysis" in path.parts:
            continue
        file = rel(path, root)
        for node in ast.walk(ast.parse(path.read_text())):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and isinstance(node.func.value, ast.Name)):
                continue
            mod, call = node.func.value.id, node.func.attr
            if mod == "obs_trace" and call in SPAN_CALLS:
                kind = "spans"
            elif mod == "obs_metrics" and call in METRIC_CALLS:
                kind = "metrics"
            else:
                continue
            if not node.args:
                continue
            arg = node.args[0]
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                sites.setdefault((kind, arg.value), []).append(
                    (file, node.lineno))
            else:
                findings.append(Finding(
                    file=file, line=node.lineno, rule="catalog-sync",
                    message=f"non-literal name in {mod}.{call}(...) — "
                            f"dynamic point names cannot be audited against "
                            f"the catalog"))
    return sites, findings


def check_points(root: Path) -> List[Finding]:
    from repro.obs.points import EXPECTED_POINTS, INFORMATIONAL_POINTS
    sites, findings = collect_emits(root)
    points_file = "src/repro/obs/points.py"

    expected: Dict[str, Set[str]] = {"spans": set(), "metrics": set()}
    for mode in EXPECTED_POINTS.values():
        for kind in expected:
            expected[kind].update(mode.get(kind, []))
    informational = {kind: set(INFORMATIONAL_POINTS.get(kind, []))
                     for kind in expected}

    for kind in expected:
        for name in sorted(expected[kind] & informational[kind]):
            findings.append(Finding(
                file=points_file, line=1, rule="catalog-sync",
                message=f"{kind[:-1]} {name!r} is both EXPECTED and "
                        f"INFORMATIONAL — pick one", symbol=name))
        for name in sorted(expected[kind] | informational[kind]):
            if (kind, name) not in sites:
                findings.append(Finding(
                    file=points_file, line=1, rule="catalog-sync",
                    message=f"dead catalog entry: {kind[:-1]} {name!r} has "
                            f"no emit site under src/repro", symbol=name))
    for (kind, name), locs in sorted(sites.items()):
        if name not in expected[kind] and name not in informational[kind]:
            file, line = locs[0]
            findings.append(Finding(
                file=file, line=line, rule="catalog-sync",
                message=f"uncataloged {kind[:-1]} {name!r} — add it to "
                        f"EXPECTED_POINTS (contract) or "
                        f"INFORMATIONAL_POINTS (best-effort) in obs/points",
                symbol=name))
    return findings


def check_registries(root: Path) -> List[Finding]:
    findings: List[Finding] = []
    from repro.core import codecs
    from repro.core import decode_backends as db

    reg_file = "src/repro/core/decode_backends.py"
    for name in db.backend_names():
        # registry is audited structurally, availability-independent: the
        # pallas backend must still declare both families on a CPU host
        be = db._REGISTRY[name]
        missing = REQUIRED_FAMILIES - set(be.fns)
        if missing:
            findings.append(Finding(
                file=reg_file, line=1, rule="catalog-sync",
                message=f"decoder backend {name!r} missing decode "
                        f"families {sorted(missing)}", symbol=name))
        extra_fused = set(be.fused_fns or {}) - set(be.fns)
        if extra_fused:
            findings.append(Finding(
                file=reg_file, line=1, rule="catalog-sync",
                message=f"decoder backend {name!r} fuses families "
                        f"{sorted(extra_fused)} it cannot decode unfused",
                symbol=name))

    codec_file = "src/repro/core/codecs/__init__.py"
    for name in codecs.codec_names():
        codec = codecs.get_codec(name)
        if codec.table_cls is not None and \
                not hasattr(codec.table_cls, "from_container"):
            findings.append(Finding(
                file=codec_file, line=1, rule="catalog-sync",
                message=f"codec {name!r} table class "
                        f"{codec.table_cls.__name__} lacks from_container — "
                        f"containers with this codec cannot be reloaded",
                symbol=name))
    return findings


def check(root: Path) -> List[Finding]:
    return check_points(root) + check_registries(root)
