"""lock-discipline: shared mutable state is written under its Lock.

The serving stack has three kinds of objects that outlive a single thread:
the resident prefetcher (worker decode thread + driver thread), the paged
block manager (engine loop + stats readers), and the obs tracer/metrics
(every thread).  Each one declares a policy here:

* ``lock``          — the attribute holding its ``threading.Lock``
* ``guarded``       — attributes that must only be *written* inside
  ``with self.<lock>:`` (outside ``__init__``)
* ``single_writer`` — attributes exempted with a reason: a documented
  single-writer contract makes the lock unnecessary (e.g. host bookkeeping
  only the engine loop touches, or a buffer serialized by a one-thread
  executor)
* ``locked_methods``— helpers *called with the lock already held* (their
  writes count as locked)
* ``init_methods``  — constructors/one-time builders that run before any
  thread can observe the object

Any write to an attribute in none of those sets is itself a finding
("undeclared mutable attribute") — new shared state must be classified
when it is introduced, not after the first race.  Reads are out of scope
(snapshot reads of counters are racy-but-benign by policy; the findings
this checker raises are the lost-update class).
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, FrozenSet, List, Optional

from .base import Finding, rel

MUTATORS = frozenset({"append", "pop", "popitem", "update", "clear",
                      "setdefault", "remove", "discard", "extend", "add",
                      "insert"})


@dataclasses.dataclass(frozen=True)
class LockPolicy:
    lock: str
    guarded: FrozenSet[str]
    single_writer: Dict[str, str] = dataclasses.field(default_factory=dict)
    locked_methods: FrozenSet[str] = frozenset()
    init_methods: FrozenSet[str] = frozenset({"__init__"})
    lock_inherited: bool = False    # lock assigned by a base class __init__


# (repo-relative file, class name) -> policy.  Adding a thread-crossing
# class to the serving/obs layer means adding its policy here — the
# checker's "undeclared mutable attribute" rule makes forgetting loud.
POLICIES: Dict[tuple, LockPolicy] = {
    ("src/repro/obs/trace.py", "Tracer"): LockPolicy(
        lock="_lock",
        guarded=frozenset({"_events", "_instants", "_ids", "_tids",
                           "_tnames", "dropped"}),
        single_writer={
            "_local": "threading.local — per-thread state by construction",
        },
        locked_methods=frozenset({"_tid_locked"}),
    ),
    ("src/repro/obs/metrics.py", "_Metric"): LockPolicy(
        lock="_lock",
        guarded=frozenset({"_children"}),
        locked_methods=frozenset({"_child"}),
    ),
    ("src/repro/obs/metrics.py", "Counter"): LockPolicy(
        lock="_lock", guarded=frozenset({"_children"}), lock_inherited=True,
    ),
    ("src/repro/obs/metrics.py", "Gauge"): LockPolicy(
        lock="_lock", guarded=frozenset({"_children"}), lock_inherited=True,
    ),
    ("src/repro/obs/metrics.py", "Histogram"): LockPolicy(
        lock="_lock", guarded=frozenset({"_children"}), lock_inherited=True,
    ),
    ("src/repro/obs/metrics.py", "Registry"): LockPolicy(
        lock="_lock",
        guarded=frozenset({"_metrics", "_lifecycles",
                           "dropped_lifecycles"}),
    ),
    ("src/repro/serving/resident.py", "CompressedResidentWeights"): LockPolicy(
        lock="_lock",
        guarded=frozenset({"_pending"}),
        single_writer={
            "_buf": "single-worker executor serializes every decode call "
                    "onto one thread (the decode-into-buffer contract)",
        },
        init_methods=frozenset({"__init__", "_build_fused_slots"}),
    ),
    ("src/repro/serving/fleet/router.py", "Router"): LockPolicy(
        lock="_lock",
        guarded=frozenset({"shed", "n_dispatched", "_rr"}),
        locked_methods=frozenset({"_shed_locked"}),
    ),
    ("src/repro/serving/fleet/driver.py", "FleetDriver"): LockPolicy(
        lock="_lock",
        guarded=frozenset({"_threads", "_stop_flag"}),
        single_writer={
            "n_steps": "lockstep driver thread only (step/run are never "
                       "called while workers are running)",
            "n_submitted": "submitting thread only (one submit entry point "
                           "by contract — replay_fleet / the launcher)",
            "handoff": "assigned in __init__ only after the decode handles "
                       "exist; never reassigned",
        },
    ),
    ("src/repro/serving/kvcache/blocks.py", "BlockKVManager"): LockPolicy(
        lock="_stats_lock",
        guarded=frozenset({"shared_hits", "shared_misses", "cold_evictions",
                           "cold_restores", "dropped_evictions"}),
        single_writer={a: "engine-loop thread only (admission/step/release "
                          "are driver-serialized); only the stats counters "
                          "cross threads"
                       for a in ("pool", "tables", "kv_len", "requests",
                                 "_live", "_free_slots", "_free_blocks",
                                 "_slot_shared", "_slot_private", "_pending",
                                 "_chain", "_refs", "_block_key", "_lru")},
    ),
}


def _self_attr(node: ast.AST) -> Optional[str]:
    """The first attribute off ``self`` in a chain (self.a.b[c] -> 'a')."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        base = node.value
        if isinstance(node, ast.Attribute) and isinstance(base, ast.Name) \
                and base.id == "self":
            return node.attr
        node = base
    return None


def _written_attrs(stmt: ast.AST) -> List[str]:
    """self-attributes written by one statement (assign/augassign/mutator)."""
    out: List[str] = []
    if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
        targets = stmt.targets if isinstance(stmt, ast.Assign) \
            else [stmt.target]
        for t in targets:
            for el in (t.elts if isinstance(t, ast.Tuple) else [t]):
                a = _self_attr(el)
                if a:
                    out.append(a)
    if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
        f = stmt.value.func
        if isinstance(f, ast.Attribute) and f.attr in MUTATORS:
            a = _self_attr(f.value)
            if a:
                out.append(a)
    return out


class _MethodWalk:
    """Track writes and whether they sit inside ``with self.<lock>:``."""

    def __init__(self, lock: str):
        self.lock = lock
        self.writes: List[tuple] = []    # (attr, line, locked)

    def walk(self, node: ast.AST, locked: bool) -> None:
        for stmt in ast.iter_child_nodes(node):
            now = locked
            if isinstance(stmt, ast.With):
                for item in stmt.items:
                    a = _self_attr(item.context_expr)
                    if a == self.lock:
                        now = True
            for attr in _written_attrs(stmt):
                self.writes.append((attr, stmt.lineno, locked))
            self.walk(stmt, now)


def check_class(cls: ast.ClassDef, policy: LockPolicy, file: str
                ) -> List[Finding]:
    findings: List[Finding] = []
    methods = [n for n in cls.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    init_writes = set()
    for m in methods:
        if m.name in policy.init_methods:
            for node in ast.walk(m):
                for a in _written_attrs(node):
                    init_writes.add(a)
    if not policy.lock_inherited and policy.lock not in init_writes \
            and not any(
            policy.lock in _written_attrs(n) for m in methods
            for n in ast.walk(m)):
        findings.append(Finding(
            file=file, line=cls.lineno, rule="lock-discipline",
            message=f"{cls.name}: declared lock attribute "
                    f"{policy.lock!r} is never assigned",
            symbol=cls.name))
        return findings
    for m in methods:
        if m.name in policy.init_methods or m.name in policy.locked_methods:
            continue
        w = _MethodWalk(policy.lock)
        w.walk(m, False)
        for attr, line, locked in w.writes:
            sym = f"{cls.name}.{m.name}"
            if attr == policy.lock:
                continue
            if attr in policy.guarded:
                if not locked:
                    findings.append(Finding(
                        file=file, line=line, rule="lock-discipline",
                        message=f"write to guarded attribute "
                                f"self.{attr} outside `with "
                                f"self.{policy.lock}:`", symbol=sym))
            elif attr not in policy.single_writer:
                findings.append(Finding(
                    file=file, line=line, rule="lock-discipline",
                    message=f"write to undeclared mutable attribute "
                            f"self.{attr} — classify it as guarded or "
                            f"single-writer in repro.analysis.locks",
                    symbol=sym))
    return findings


def check(root: Path) -> List[Finding]:
    findings: List[Finding] = []
    by_file: Dict[str, Dict[str, LockPolicy]] = {}
    for (file, cls), pol in POLICIES.items():
        by_file.setdefault(file, {})[cls] = pol
    for file, pols in sorted(by_file.items()):
        path = root / file
        if not path.exists():
            findings.append(Finding(
                file=file, line=0, rule="lock-discipline",
                message="policy target file missing — update "
                        "repro.analysis.locks.POLICIES"))
            continue
        tree = ast.parse(path.read_text())
        seen = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef) and node.name in pols:
                seen.add(node.name)
                findings.extend(check_class(node, pols[node.name],
                                            rel(path, root)))
        for missing in sorted(set(pols) - seen):
            findings.append(Finding(
                file=file, line=0, rule="lock-discipline",
                message=f"policy class {missing!r} not found — update "
                        f"repro.analysis.locks.POLICIES", symbol=missing))
    return findings
