"""Shared model-building blocks (pure JAX, functional, schema-driven).

Parameters live in a flat ``{name: array}`` dict; a parallel schema maps each name to
``(shape, logical_axes, init)``.  Logical axes (e.g. ``"vocab"``, "heads", "mlp",
"expert") are resolved to mesh axes by ``repro.distributed.sharding`` — models know
nothing about meshes.

Weight tensors may be plain arrays OR :class:`QT` triples (quantized weight + scale +
zero) — ``matmul``/``take`` dequantize on the fly, which XLA fuses into the consuming
dot, keeping integer bytes on the HBM path (the EntroLLM serving mode).  When the
``repro.kernels`` Pallas path is enabled, ``matmul`` routes to the fused dequant-matmul
kernel instead.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fused_decode_matmul import FusedQT, fused_decode_matmul

# --------------------------------------------------------------------------- schema

Axes = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Axes                      # logical axis names, len == len(shape)
    init: Any = 0.02                # float std | "zeros" | "ones" | "a_log" | "dt_bias"
    dtype: Any = jnp.bfloat16       # norms/ssm-sensitive params use f32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Schema = Dict[str, Spec]


def init_param(key: jax.Array, spec: Spec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "a_log":        # mamba2: A in [-16, -1] via log
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(spec.dtype)
    if spec.init == "dt_bias":      # softplus^-1 of dt in [1e-3, 1e-1]
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(spec.dtype)
    std = float(spec.init)
    return (std * jax.random.normal(key, spec.shape, jnp.float32)).astype(spec.dtype)


def init_params(schema: Schema, key: jax.Array) -> Dict[str, jax.Array]:
    names = sorted(schema)
    keys = jax.random.split(key, len(names))
    return {n: init_param(k, schema[n]) for n, k in zip(names, keys)}


# ----------------------------------------------------------------- quantized weights

class QT(NamedTuple):
    """Quantized weight triple; leaves integer bytes on the HBM path."""
    q: jax.Array        # uint8 symbols
    scale: jax.Array    # f32 broadcastable
    zero: jax.Array     # f32 broadcastable


class QT4(NamedTuple):
    """int4 weights packed two-per-byte along the LAST axis (see
    kernels.ops.pack_nibbles): q[..., j] holds symbol 2j in the low nibble and
    symbol 2j+1 in the high nibble.  Unpacking is shifts + interleave — cheap,
    fusable, and halves the HBM bytes of the uint8 path again."""
    q: jax.Array        # uint8, last dim = N/2
    scale: jax.Array
    zero: jax.Array


def _unpack4(q: jax.Array) -> jax.Array:
    lo = q & jnp.uint8(0x0F)
    hi = q >> jnp.uint8(4)
    return jnp.stack([lo, hi], axis=-1).reshape(*q.shape[:-1], q.shape[-1] * 2)


def pack_qt(q: np.ndarray, scale: np.ndarray, zero: np.ndarray, *,
            bits: int, pack_int4: bool = True) -> "QT | QT4":
    """Host ``(q, scale, zero)`` symbols -> the serving-resident triple.

    The ONE packing rule both weight loaders share (whole-model
    ``load_params_from_compressed`` and the per-layer compressed-resident
    decode): 4-bit symbols with an even last dim pack nibble pairs into
    :class:`QT4` (0.5 bytes/param resident), everything else stays a
    :class:`QT` of uint8 symbols.  Packing a full stacked tensor and then
    slicing a layer is byte-identical to packing the layer's slice, which is
    what keeps the two residency modes interchangeable.
    """
    q = np.asarray(q)
    if bits == 4 and pack_int4 and q.shape[-1] % 2 == 0:
        packed = (q[..., 0::2] | (q[..., 1::2] << 4)).astype(np.uint8)
        return QT4(packed, np.asarray(scale), np.asarray(zero))
    return QT(q, np.asarray(scale), np.asarray(zero))


class QTG(NamedTuple):
    """Quantized weight with a gradient path to the bf16 master (training's
    compressed-FSDP-gather mode): forward computes from the uint8 symbols
    (the master is dead code, so only integer bytes cross the FSDP
    all-gather); backward is a straight-through estimator into the master."""
    q: jax.Array        # uint8 symbols (packed nibbles when bits == 4)
    scale: jax.Array
    zero: jax.Array
    master: jax.Array   # bf16 FSDP-sharded master weight (grad target)
    # static marker for 4-bit packing rides in scale's trailing dim (see deq)


@jax.custom_vjp
def _ste_deq(master, q, scale, zero):
    # packed-nibble detection is static: packed q has half the master's
    # trailing dim
    sym = _unpack4(q) if q.shape[-1] != master.shape[-1] else q
    return (sym.astype(jnp.bfloat16) * scale.astype(jnp.bfloat16)
            + zero.astype(jnp.bfloat16))


def _ste_deq_fwd(master, q, scale, zero):
    return _ste_deq(master, q, scale, zero), None


def _ste_deq_bwd(_, g):
    # straight-through: full gradient to the master weight
    return g.astype(jnp.bfloat16), None, None, None


_ste_deq.defvjp(_ste_deq_fwd, _ste_deq_bwd)


def gather_weight(w: Any) -> Any:
    """Exact sharded serving: all-gather a HBM-sharded weight (or each part
    of a QT/QT4/QTG triple) at its use site.  Identity unless ``exact_tp``
    serving hints are installed (training / single-device paths unchanged)."""
    from repro.distributed.ctx import constrain_replicated, get_hints
    h = get_hints()
    if h is None or not h.exact_tp:
        return w
    if isinstance(w, (QT, QT4, QTG)):
        return type(w)(*(constrain_replicated(p) for p in w))
    return constrain_replicated(w)


def deq(w: Any, dtype=jnp.bfloat16) -> jax.Array:
    w = gather_weight(w)
    if isinstance(w, QT):
        return w.q.astype(dtype) * w.scale.astype(dtype) + w.zero.astype(dtype)
    if isinstance(w, QT4):
        return (_unpack4(w.q).astype(dtype) * w.scale.astype(dtype)
                + w.zero.astype(dtype))
    if isinstance(w, QTG):
        return _ste_deq(w.master, w.q, w.scale, w.zero).astype(dtype)
    return w.astype(dtype) if w.dtype != dtype else w


def matmul(x: jax.Array, w: Any, dim_nums: Optional[str] = None) -> jax.Array:
    """x @ w with on-the-fly dequantization (fused by XLA into the dot).

    Under exact-TP serving hints ``deq`` all-gathers the HBM-sharded weight
    first, so the dot reads a full-shape buffer and rounds exactly like the
    single-device program (sharded residency, replicated compute).

    A :class:`~repro.kernels.fused_decode_matmul.FusedQT` weight routes to
    the fused entropy-decode→dequant→matmul kernel — the weight never
    exists densely; the handle's jit path runs the exact ``deq`` ops after
    an in-graph decode, so it stays bit-identical to a QT slot.
    """
    if isinstance(w, FusedQT):
        assert dim_nums is None, "FusedQT weights support plain x @ w only"
        return fused_decode_matmul(x, w)
    wd = deq(w, x.dtype)
    if dim_nums is None:
        return x @ wd
    return jnp.einsum(dim_nums, x, wd)


def take_rows(w: Any, idx: jax.Array) -> jax.Array:
    """Embedding lookup honoring quantized tables (dequantize only gathered rows)."""
    w = gather_weight(w)
    if isinstance(w, QTG):
        rows = jnp.take(w.q, idx, axis=0)
        master_rows = jnp.take(w.master, idx, axis=0)
        scale = w.scale if w.scale.shape[0] == 1 \
            else jnp.take(w.scale, idx, axis=0)
        zero = w.zero if w.zero.shape[0] == 1 \
            else jnp.take(w.zero, idx, axis=0)
        return _ste_deq(master_rows, rows, scale, zero)
    if isinstance(w, QT4):
        rows = _unpack4(jnp.take(w.q, idx, axis=0))
        scale = w.scale if w.scale.ndim == 0 or w.scale.shape[0] == 1 \
            else jnp.take(w.scale, idx, axis=0)
        zero = w.zero if w.zero.ndim == 0 or w.zero.shape[0] == 1 \
            else jnp.take(w.zero, idx, axis=0)
        return rows.astype(jnp.bfloat16) * scale.astype(jnp.bfloat16) \
            + zero.astype(jnp.bfloat16)
    if isinstance(w, QT):
        rows = jnp.take(w.q, idx, axis=0)
        scale = w.scale if w.scale.ndim == 0 or w.scale.shape[0] == 1 \
            else jnp.take(w.scale, idx, axis=0)
        zero = w.zero if w.zero.ndim == 0 or w.zero.shape[0] == 1 \
            else jnp.take(w.zero, idx, axis=0)
        return rows.astype(jnp.bfloat16) * scale.astype(jnp.bfloat16) \
            + zero.astype(jnp.bfloat16)
    return jnp.take(w, idx, axis=0)


# ------------------------------------------------------------------------ primitives

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., S, n, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs          # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                                 # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([
        x1 * cos.astype(x.dtype) - x2 * sin.astype(x.dtype),
        x2 * cos.astype(x.dtype) + x1 * sin.astype(x.dtype),
    ], axis=-1)
    return out


def swiglu(x: jax.Array, w_gate: Any, w_up: Any, w_down: Any) -> jax.Array:
    g = matmul(x, w_gate)
    u = matmul(x, w_up)
    return matmul(jax.nn.silu(g) * u, w_down)


# -------------------------------------------------------------------------- attention

NEG_INF = -1e9


def gqa_attention(
    q: jax.Array,              # (B, S, H, hd)
    k: jax.Array,              # (B, T, KV, hd)
    v: jax.Array,              # (B, T, KV, hd)
    *,
    causal: bool,
    q_offset: Any = 0,         # global position of q[0]: scalar, or (B,) per-slot
    kv_len: Optional[jax.Array] = None,   # valid cache length: scalar, or (B,) per-slot
    q_block: int = 0,          # 0 = single block; else scan over q blocks
    unroll: int = 1,
) -> jax.Array:
    """Grouped-query attention with optional q-block chunking.

    ``q_offset`` and ``kv_len`` accept either scalars (lockstep batch: every
    row at the same position) or ``(B,)`` arrays (slot batch: each row is an
    independent request with its own cache length — the continuous-batching
    serving mode).  Per-slot offsets disable q-block chunking (the block scan
    would need ragged bases); callers pass ``q_block=0`` on that path.

    SPMD formulation: KV heads are broadcast up to the full head count BEFORE
    the score einsum (MaxText-style "KV replication"), so every attention
    tensor carries one merged head axis H that shards cleanly over the model
    axis — the (KV, G) split axes that GSPMD must otherwise co-shard are never
    materialized.  The broadcast is sharded by the head constraint, so each
    chip only materializes its own H/|model| head slice.

    Chunking over the query axis bounds the live (Qb x T) score tensor — the
    memory-realistic lowering used by the dry-run for long-sequence prefill
    (the softmax over T is exact per block; no online accumulation needed).
    """
    from repro.distributed.ctx import constrain_heads, constrain_scores
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    q = constrain_heads(q)
    if G > 1:
        k = jnp.broadcast_to(k[:, :, :, None, :], (B, T, KV, G, hd)
                             ).reshape(B, T, H, hd)
        v = jnp.broadcast_to(v[:, :, :, None, :], (B, T, KV, G, hd)
                             ).reshape(B, T, H, hd)
    k = constrain_heads(k, is_cache_side=True)
    v = constrain_heads(v, is_cache_side=True)

    def block(qb: jax.Array, qpos: jax.Array) -> jax.Array:
        # qb: (B, Sb, H, hd); qpos: (Sb,) or (B, Sb) global positions
        # bf16 operands + f32 accumulation (MXU-style): keeps the KV-cache
        # read at 2 bytes/element — an f32 cast before the dot doubles the
        # cache wire/HBM traffic (EXPERIMENTS.md §Perf H1 iteration 2)
        s = jnp.einsum("bsnh,btnh->bnst", (qb * scale).astype(jnp.bfloat16),
                       k.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
        s = constrain_scores(s)                       # (B, H, Sq, T)
        tpos = jnp.arange(T)
        mask = jnp.ones(qpos.shape + (T,), bool)      # (Sb, T) or (B, Sb, T)
        if causal:
            mask &= tpos <= qpos[..., None]
        if kv_len is not None:
            kl = jnp.asarray(kv_len)
            # scalar broadcasts; (B,) reshapes to (B, 1, 1) against (B, Sb, T)
            if kl.ndim == 1:
                mask = mask & (tpos < kl[:, None, None])
            else:
                mask &= tpos < kl
        while mask.ndim < 3:                          # -> (B|1, Sb, T)
            mask = mask[None]
        s = jnp.where(mask[:, None], s, NEG_INF)      # (B|1, 1, Sq, T)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bnst,btnh->bsnh", p.astype(v.dtype), v)

    qpos0 = jnp.asarray(q_offset)[..., None] + jnp.arange(S)  # (S,) or (B, S)
    if q_block <= 0 or q_block >= S or qpos0.ndim > 1:
        return block(q, qpos0)

    assert S % q_block == 0, (S, q_block)
    nb = S // q_block
    qb = q.reshape(B, nb, q_block, H, hd)

    def body(_, qi):
        qblk, base = qi
        return None, block(qblk, base + jnp.arange(q_block))

    bases = q_offset + jnp.arange(nb) * q_block
    _, outs = jax.lax.scan(body, None, (jnp.moveaxis(qb, 1, 0), bases), unroll=unroll)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)


def update_kv_cache(cache_k: jax.Array, cache_v: jax.Array, k: jax.Array, v: jax.Array,
                    pos: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Write step-k/v (B, S, KV, hd) into preallocated (B, T, KV, hd) caches.

    ``pos`` is the write offset along T: a scalar writes every batch row at
    the same position (lockstep decode), a ``(B,)`` array writes each row at
    its own position (slot batch — every slot tracks an independent
    ``kv_len``, so a freshly admitted request and a request 100 tokens deep
    share one fused cache update).
    """
    if jnp.ndim(pos) == 1:
        def row(c, x, p):
            return jax.lax.dynamic_update_slice(c, x.astype(c.dtype), (p, 0, 0))
        return jax.vmap(row)(cache_k, k, pos), jax.vmap(row)(cache_v, v, pos)
    ck = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    return ck, cv


# ------------------------------------------------------------- paged KV cache
#
# Block-pool primitives for the paged KV cache (docs/KV_CACHE.md): the pool
# leaf layout is (n_blocks, block_size, KV, hd_store) per layer, and a
# request's logical sequence is the concatenation of the blocks its
# (max_blocks,)-row of the block table names.  Block id 0 is the TRASH block:
# inactive lanes of the fused decode step point every table entry at it, so
# their garbage writes land in memory no request ever gathers as live rows.


def gather_blocks(pool_leaf: jax.Array, bt: jax.Array) -> jax.Array:
    """Gather per-request sequences out of a block pool.

    pool_leaf: (NB, BS, ...) one layer's pool; bt: (B, MB) int32 block table.
    Returns (B, MB * BS, ...) — each request's blocks concatenated in table
    order, ready to stand in for the slot cache's (B, T, ...) axis (positions
    >= kv_len are masked by attention exactly like slot-pool padding).
    """
    g = jnp.take(pool_leaf, bt, axis=0)                  # (B, MB, BS, ...)
    return g.reshape((bt.shape[0], -1) + pool_leaf.shape[2:])


def scatter_blocks(pool_leaf: jax.Array, bt: jax.Array, positions: jax.Array,
                   val: jax.Array) -> jax.Array:
    """Write per-request rows into the pool through the block table.

    pool_leaf: (NB, BS, ...); bt: (B, MB); positions: (B, S) global token
    positions; val: (B, S, ...).  Row (b, s) lands at block
    ``bt[b, positions[b, s] // BS]``, offset ``positions[b, s] % BS``.
    Duplicate targets only arise from trash-block writes (several idle lanes
    aiming at block 0), where any winner is equally garbage.
    """
    NB, BS = pool_leaf.shape[0], pool_leaf.shape[1]
    blk = jnp.take_along_axis(bt, positions // BS, axis=1)      # (B, S)
    idx = (blk * BS + positions % BS).reshape(-1)               # (B*S,)
    flat = pool_leaf.reshape((NB * BS,) + pool_leaf.shape[2:])
    flat = flat.at[idx].set(
        val.reshape((-1,) + val.shape[2:]).astype(pool_leaf.dtype))
    return flat.reshape(pool_leaf.shape)


def kv_quantize(x: jax.Array, bits: int):
    """Asymmetric per-(token, head) KV quantization — the jnp twin of
    :func:`repro.core.quant.quantize` with ``Scheme.ASYMMETRIC`` at
    per-channel granularity over head_dim, applied in-graph so paged blocks
    quantize as they are written.

    x: (..., hd) -> (q uint8 (..., hd) [or (..., hd/2) nibble-packed at
    bits=4], scale bf16 (..., 1), zero bf16 (..., 1)).  The grid spans
    [min, max] per (token, head) vector: KV activations are not
    zero-centered (unlike weights), so the asymmetric grid halves the error
    of a symmetric one at the same width.
    """
    assert bits in (8, 4), bits
    xf = x.astype(jnp.float32)
    lo = jnp.min(xf, axis=-1, keepdims=True)
    hi = jnp.max(xf, axis=-1, keepdims=True)
    qmax = (1 << bits) - 1
    scale = (hi - lo) / qmax
    scale = jnp.where(scale == 0.0, 1.0, scale)      # constant vector guard
    q = jnp.clip(jnp.round((xf - lo) / scale), 0, qmax).astype(jnp.uint8)
    if bits == 4:
        q = q[..., 0::2] | (q[..., 1::2] << 4)       # nibble-pack along hd
    return q, scale.astype(jnp.bfloat16), lo.astype(jnp.bfloat16)


def kv_dequantize(q: jax.Array, scale: jax.Array, zero: jax.Array,
                  bits: int) -> jax.Array:
    """Inverse of :func:`kv_quantize`: uint8 symbols -> bf16 K/V rows."""
    assert bits in (8, 4), bits
    if bits == 4:
        q = jnp.stack([q & 0xF, q >> 4], axis=-1
                      ).reshape(q.shape[:-1] + (q.shape[-1] * 2,))
    return q.astype(jnp.bfloat16) * scale.astype(jnp.bfloat16) \
        + zero.astype(jnp.bfloat16)


# ---------------------------------------------------------------------- loss helpers

def softmax_xent(logits: jax.Array, labels: jax.Array, vocab: int) -> jax.Array:
    """Mean token cross-entropy; labels >= vocab (padding) are masked out."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None].clip(0, logits.shape[-1] - 1),
        axis=-1)[..., 0]
    mask = (labels >= 0) & (labels < vocab)
    return jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1)
