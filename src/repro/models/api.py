"""Uniform model API: ``build(cfg)`` returns the family module; every family exposes

    schema(cfg) -> {name: Spec}
    init(cfg, key) -> params
    loss_fn(cfg, params, batch, *, unroll, ...) -> scalar
    prefill(cfg, params, tokens_or_batch, *, max_len, ...) -> (logits, cache)
    decode_step(cfg, params, token, cache, pos, *, ...) -> (logits, cache)
    init_cache(cfg, batch, max_len) -> cache pytree
    cache_specs(cfg) -> logical axes for cache leaves

Attention-cache families (dense, moe) additionally expose the slot-batch
contract used by continuous-batching serving (``serving/batching``):

    prefill_chunk(cfg, params, tokens, cache, pos, *, ...) -> (logits, cache)
        — chunked prefill at per-slot (B,) write offsets
    decode_step(..., pos=(B,) array)
        — one fused step over a slot batch with ragged per-slot kv_len

``supports_continuous_batching(cfg)`` reports whether a family implements it
(recurrent caches — ssm/hybrid conv+state, encdec cross-attention — need a
family-specific slot layout and are not wired up yet).
"""
from __future__ import annotations

import types
from typing import Dict, Tuple

import jax

from repro.configs.base import ArchConfig


def build(cfg: ArchConfig) -> types.ModuleType:
    from . import dense, encdec, hybrid, mamba2, moe
    return {
        "dense": dense,
        "moe": moe,
        "ssm": mamba2,
        "hybrid": hybrid,
        "encdec": encdec,
    }[cfg.family]


def supports_continuous_batching(cfg: ArchConfig) -> bool:
    """True when the family implements the slot-batch cache contract
    (``prefill_chunk`` + per-slot ``decode_step`` positions)."""
    return hasattr(build(cfg), "prefill_chunk")


def supports_paged_kv(cfg: ArchConfig) -> bool:
    """True when the family implements the paged block-pool cache contract
    (``init_kv_pool`` + ``paged_prefill_chunk`` / ``paged_decode_step``
    routing K/V through a block table — see docs/KV_CACHE.md)."""
    return hasattr(build(cfg), "paged_decode_step")


def supports_resident_serving(cfg: ArchConfig) -> bool:
    """True when the family implements the per-layer weight-slot contract
    of compressed-resident serving (``embed_step`` / ``head_step`` /
    ``resident_prefill_block`` / ``resident_block`` — see
    docs/SERVING.md §"Compressed-resident serving"); dense and moe today."""
    return hasattr(build(cfg), "resident_block")


def supports_fused_resident(cfg: ArchConfig) -> bool:
    """True when the family's per-layer drivers can consume fused payload
    handles (:class:`repro.kernels.fused_decode_matmul.FusedQT`) in their
    weight-slot dicts.  Any family meeting the resident contract qualifies:
    the drivers route every weight through ``layers.matmul``, which
    dispatches FusedQT slots to the fused decode→dequant→matmul kernel
    (tensors the fused tile contract rejects simply stay QT slots)."""
    return supports_resident_serving(cfg)


def cache_specs(cfg: ArchConfig, **kw) -> Dict[str, Tuple]:
    """Family ``cache_specs`` with kwarg filtering: callers pass the full
    option set (``layout="slot"``, ``kv_bits=8``, ...) and families that do
    not take an option simply don't see it — the sharding layer can resolve
    any family's cache without per-family dispatch."""
    import inspect
    fn = build(cfg).cache_specs
    accepted = inspect.signature(fn).parameters
    return fn(cfg, **{k: v for k, v in kw.items() if k in accepted})


def param_shapes(cfg: ArchConfig) -> Dict[str, Tuple[int, ...]]:
    return {n: s.shape for n, s in build(cfg).schema(cfg).items()}


def param_specs(cfg: ArchConfig) -> Dict[str, Tuple]:
    return {n: s.axes for n, s in build(cfg).schema(cfg).items()}


def param_shape_structs(cfg: ArchConfig, dtype=None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every parameter (dry-run: no allocation)."""
    import jax.numpy as jnp
    sch = build(cfg).schema(cfg)
    return {n: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype) for n, s in sch.items()}
