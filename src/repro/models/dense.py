"""Dense decoder-only transformer (chameleon / stablelm / command-r+ / glm4 / qwen3).

Layers are stacked on a leading axis and iterated with ``lax.scan`` whose ``unroll``
degree is a lowering knob: smoke tests keep it rolled (fast compile), the dry-run
unrolls fully so ``cost_analysis`` counts every layer.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import (QT, Schema, Spec, gather_blocks, gqa_attention,
                     init_params, kv_dequantize, kv_quantize, matmul, rms_norm,
                     rope, scatter_blocks, softmax_xent, swiglu, take_rows,
                     update_kv_cache)


def schema(cfg: ArchConfig) -> Schema:
    L, D, H, KV, hd, F = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.hd, cfg.d_ff)
    Vp = cfg.padded_vocab()
    resid = 0.02 / (2 * L) ** 0.5    # residual-branch init scaling
    s: Schema = {
        "embed": Spec((Vp, D), ("vocab", "embed"), 0.02),
        "final_norm": Spec((D,), (None,), "ones", jnp.float32),
        "layers/attn_norm": Spec((L, D), ("layers", None), "ones", jnp.float32),
        "layers/wq": Spec((L, D, H * hd), ("layers", "embed", "heads")),
        "layers/wk": Spec((L, D, KV * hd), ("layers", "embed", "kv")),
        "layers/wv": Spec((L, D, KV * hd), ("layers", "embed", "kv")),
        "layers/wo": Spec((L, H * hd, D), ("layers", "heads", "embed"), resid),
        "layers/mlp_norm": Spec((L, D), ("layers", None), "ones", jnp.float32),
        "layers/w_gate": Spec((L, D, F), ("layers", "embed", "mlp")),
        "layers/w_up": Spec((L, D, F), ("layers", "embed", "mlp")),
        "layers/w_down": Spec((L, F, D), ("layers", "mlp", "embed"), resid),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = Spec((D, Vp), ("embed", "vocab"), 0.02)
    if cfg.qk_norm:
        s["layers/q_norm"] = Spec((L, hd), ("layers", None), "ones", jnp.float32)
        s["layers/k_norm"] = Spec((L, hd), ("layers", None), "ones", jnp.float32)
    return s


def init(cfg: ArchConfig, key: jax.Array) -> Dict[str, jax.Array]:
    return init_params(schema(cfg), key)


def _layer_stack(params: Dict[str, Any]) -> Dict[str, Any]:
    return {k.split("/", 1)[1]: v for k, v in params.items() if k.startswith("layers/")}


def quantize_kv(k: jax.Array):
    """EntroLLM-grid int8 KV quantization: per (token, head) symmetric scale
    over head_dim — the cache read is the decode-phase HBM bound at serving
    batch sizes, so halving its bytes is the paper's bandwidth insight
    applied to the cache (beyond-paper, EXPERIMENTS.md §Perf H3)."""
    s = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-8)
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / s), -127, 127
                 ).astype(jnp.int8)
    return q, s.astype(jnp.bfloat16)


def dequantize_kv(q: jax.Array, s: jax.Array) -> jax.Array:
    return q.astype(jnp.bfloat16) * s.astype(jnp.bfloat16)


def _attn(cfg: ArchConfig, lp: Dict[str, Any], x: jax.Array, *, positions,
          cache: Optional[Tuple] = None, pos=None, q_block: int = 0, unroll: int = 1):
    """Attention sub-block; returns (out, new_cache).

    ``cache`` is (k, v) bf16 or (k, v, k_scale, v_scale) for the int8 cache.
    ``pos`` is the cache write offset — scalar (lockstep batch) or ``(B,)``
    (slot batch, one independent position per row).  With a cache present,
    ``S`` may exceed 1: the chunk is written at ``[pos, pos + S)`` and
    attended causally against the whole cache (chunked prefill).
    """
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, lp["attn_norm"])
    q = matmul(h, lp["wq"]).reshape(B, S, H, hd)
    k = matmul(h, lp["wk"]).reshape(B, S, KV, hd)
    v = matmul(h, lp["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"])
        k = rms_norm(k, lp["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if cache is None:
        attn = gqa_attention(q, k, v, causal=True, q_block=q_block, unroll=unroll)
        new_cache = (k, v)
    elif len(cache) == 4:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        ck, cv = update_kv_cache(cache[0], cache[1], kq, vq, pos)
        cks, cvs = update_kv_cache(cache[2], cache[3], ks, vs, pos)
        attn = gqa_attention(q, dequantize_kv(ck, cks), dequantize_kv(cv, cvs),
                             causal=S > 1, q_offset=pos, kv_len=pos + S)
        new_cache = (ck, cv, cks, cvs)
    else:
        ck, cv = update_kv_cache(cache[0], cache[1], k, v, pos)
        attn = gqa_attention(q, ck, cv, causal=S > 1, q_offset=pos,
                             kv_len=pos + S)
        new_cache = (ck, cv)
    out = matmul(attn.reshape(B, S, H * hd), lp["wo"])
    return out, new_cache


def _block(cfg: ArchConfig, lp, x, *, positions, cache=None, pos=None,
           q_block=0, unroll=1):
    attn_out, new_cache = _attn(cfg, lp, x, positions=positions, cache=cache, pos=pos,
                                q_block=q_block, unroll=unroll)
    x = x + attn_out
    h = rms_norm(x, lp["mlp_norm"])
    x = x + swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
    return x, new_cache


def forward(cfg: ArchConfig, params, tokens, *, unroll: int = 1, q_block: int = 0,
            remat: bool = False, collect_cache: bool = False):
    """Full-sequence forward.  Returns (hidden, cache|None)."""
    from repro.distributed.ctx import constrain_activation
    B, S = tokens.shape
    x = constrain_activation(take_rows(params["embed"], tokens))
    positions = jnp.arange(S)
    stack = _layer_stack(params)

    def body(x, lp):
        x, kv = _block(cfg, lp, x, positions=positions, q_block=q_block, unroll=unroll)
        return constrain_activation(x), kv if collect_cache else None

    fn = jax.checkpoint(body) if remat else body
    x, caches = jax.lax.scan(fn, x, stack, unroll=unroll)
    x = rms_norm(x, params["final_norm"])
    return x, caches


def logits_fn(cfg: ArchConfig, params, x: jax.Array) -> jax.Array:
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    if cfg.tie_embeddings:
        from .layers import deq
        return matmul(x, deq(head).T)
    return matmul(x, head)


def loss_fn(cfg: ArchConfig, params, batch, *, unroll: int = 1, q_block: int = 0,
            remat: bool = True) -> jax.Array:
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    x, _ = forward(cfg, params, inp, unroll=unroll, q_block=q_block, remat=remat)
    return softmax_xent(logits_fn(cfg, params, x), labels, cfg.vocab)


# ------------------------------------------------------------------------- serving

def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               kv_bits: int = 16):
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    if kv_bits == 8:
        return {
            "k": jnp.zeros((L, batch, max_len, KV, hd), jnp.int8),
            "v": jnp.zeros((L, batch, max_len, KV, hd), jnp.int8),
            "k_scale": jnp.zeros((L, batch, max_len, KV, 1), jnp.bfloat16),
            "v_scale": jnp.zeros((L, batch, max_len, KV, 1), jnp.bfloat16),
        }
    return {
        "k": jnp.zeros((L, batch, max_len, KV, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, KV, hd), dtype),
    }


def cache_specs(cfg: ArchConfig, kv_bits: int = 16, layout: str = "batch"
                ) -> Dict[str, Tuple[Optional[str], ...]]:
    """Logical axes for the cache leaves.  ``layout="slot"`` names axis 1
    "slot" instead of "batch": the slotted cache of continuous batching is
    the same memory, but slots are rows of a resident pool (requests come
    and go within them) rather than rows of one lockstep request batch, and
    the sharding rules resolve the two independently."""
    b = "slot" if layout == "slot" else "batch"
    s = {
        "k": ("layers", b, "kv_seq", "kv", None),
        "v": ("layers", b, "kv_seq", "kv", None),
    }
    if kv_bits == 8:
        s["k_scale"] = ("layers", b, "kv_seq", "kv", None)
        s["v_scale"] = ("layers", b, "kv_seq", "kv", None)
    return s


def prefill(cfg: ArchConfig, params, tokens, *, max_len: Optional[int] = None,
            unroll: int = 1, q_block: int = 0):
    """Run the prompt; return (last-position logits, cache padded to max_len)."""
    B, S = tokens.shape
    max_len = max_len or S
    x, caches = forward(cfg, params, tokens, unroll=unroll, q_block=q_block,
                        collect_cache=True)
    k, v = caches
    pad = [(0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0)]
    cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    logits = logits_fn(cfg, params, x[:, -1:, :])
    return logits, cache


def decode_step(cfg: ArchConfig, params, token, cache, pos, *, unroll: int = 1):
    """One generation step.  token: (B, 1) int32; pos: scalar position shared by
    the whole batch (lockstep) or (B,) per-slot positions (continuous batch)."""
    from repro.distributed.ctx import constrain_activation
    B = token.shape[0]
    x = constrain_activation(take_rows(params["embed"], token))
    positions = jnp.asarray(pos)[..., None] + jnp.arange(1)   # (1,) or (B, 1)
    stack = _layer_stack(params)
    q8 = "k_scale" in cache

    def body(x, xs):
        lp, *c = xs
        x, c = _block(cfg, lp, x, positions=positions, cache=tuple(c), pos=pos)
        return constrain_activation(x), c

    keys = ("k", "v", "k_scale", "v_scale") if q8 else ("k", "v")
    x, out = jax.lax.scan(body, x, (stack, *[cache[k] for k in keys]),
                          unroll=unroll)
    x = rms_norm(x, params["final_norm"])
    return logits_fn(cfg, params, x), dict(zip(keys, out))


# ------------------------------------------------- compressed-resident serving
#
# Per-layer weight-slot entry points (docs/SERVING.md §"Compressed-resident
# serving"): the same math as `prefill` / `decode_step` / `prefill_chunk`,
# but one layer at a time with the layer's weights passed as a slot dict
# (the keys `_layer_stack` would produce) instead of sliced from the stacked
# params by `lax.scan`.  The driver in `serving.engine.ServeSteps` loops the
# layers in execution order, so entropy-decoding layer l+1 can overlap layer
# l's compute.  Each function mirrors one scan iteration of its whole-tree
# twin op for op — that is the bit-identity contract
# `tests/test_resident_serving.py` pins.


def embed_step(cfg: ArchConfig, params, tokens):
    """Token embedding against the resident globals (the pre-loop line of
    `forward` / `decode_step`).  tokens: (B, S) int32."""
    from repro.distributed.ctx import constrain_activation
    return constrain_activation(take_rows(params["embed"], tokens))


def head_step(cfg: ArchConfig, params, x, *, last_only: bool = False):
    """Final norm + logits (the post-loop lines of the step functions).
    ``last_only`` reproduces `prefill`'s last-position slice."""
    x = rms_norm(x, params["final_norm"])
    if last_only:
        x = x[:, -1:, :]
    return logits_fn(cfg, params, x)


def resident_prefill_block(cfg: ArchConfig, lp, x, *, positions,
                           q_block: int = 0, unroll: int = 1):
    """One `forward`-collect-cache scan iteration: full causal attention over
    the prompt, returning the layer's (k, v) for the caller to write into
    the zero-padded cache at its layer row."""
    from repro.distributed.ctx import constrain_activation
    x, kv = _block(cfg, lp, x, positions=positions, q_block=q_block,
                   unroll=unroll)
    return constrain_activation(x), kv


def resident_block(cfg: ArchConfig, lp, x, cache, l, pos):
    """One `decode_step` / `prefill_chunk` scan iteration against the
    layer-stacked cache: slice layer ``l``'s rows, run the block, write them
    back.  ``pos`` follows the step functions' contract (scalar lockstep or
    (B,) per-slot); S comes from ``x``, so the same callable serves decode
    (S=1) and chunked prefill.

    ``lp`` values may be dense arrays, QT/QT4 triples, or — under
    ``CompressedResidentWeights(fused=True)`` — FusedQT payload handles:
    every weight reaches ``layers.matmul``, whose dispatch decodes fused
    handles inside the matmul instead of reading a prefetched dense tile.
    The handle's static geometry is layer-invariant, so this block still
    traces once for all layers."""
    from repro.distributed.ctx import constrain_activation
    S = x.shape[1]
    positions = jnp.asarray(pos)[..., None] + jnp.arange(S)   # (S,) or (B, S)
    keys = ("k", "v", "k_scale", "v_scale") if "k_scale" in cache \
        else ("k", "v")
    c = tuple(jax.lax.dynamic_index_in_dim(cache[k], l, 0, keepdims=False)
              for k in keys)
    x, c = _block(cfg, lp, x, positions=positions, cache=c, pos=pos)
    out = dict(cache)
    for k, ci in zip(keys, c):
        out[k] = jax.lax.dynamic_update_index_in_dim(cache[k], ci, l, 0)
    return constrain_activation(x), out


def prefill_chunk(cfg: ArchConfig, params, tokens, cache, pos, *,
                  unroll: int = 1):
    """Chunked prefill: write one prompt chunk into an existing slotted cache.

    tokens: (B, S) int32 chunk; cache: ``init_cache``-layout pytree; pos: (B,)
    int32 per-slot write offsets (the chunk occupies cache rows
    ``[pos, pos + S)``; the caller guarantees ``pos + S <= max_len``).
    Returns (logits for every chunk position (B, S, V), cache) — the caller
    picks the logit at the request's true last prompt position, so ragged
    prompts ride in fixed-shape chunks (pad tokens land in the cache but stay
    masked forever because ``kv_len`` never reaches them).

    This is the admission path of continuous batching: a new request prefills
    chunk by chunk through ONE compiled shape while the decode batch keeps
    stepping between chunks, then the filled cache rows are spliced into a
    free slot.
    """
    from repro.distributed.ctx import constrain_activation
    B, S = tokens.shape
    x = constrain_activation(take_rows(params["embed"], tokens))
    positions = pos[:, None] + jnp.arange(S)                  # (B, S)
    stack = _layer_stack(params)
    q8 = "k_scale" in cache

    def body(x, xs):
        lp, *c = xs
        x, c = _block(cfg, lp, x, positions=positions, cache=tuple(c), pos=pos)
        return constrain_activation(x), c

    keys = ("k", "v", "k_scale", "v_scale") if q8 else ("k", "v")
    x, out = jax.lax.scan(body, x, (stack, *[cache[k] for k in keys]),
                          unroll=unroll)
    x = rms_norm(x, params["final_norm"])
    return logits_fn(cfg, params, x), dict(zip(keys, out))


# ------------------------------------------------------------- paged KV cache
#
# Block-pool twins of the slot-batch step functions (docs/KV_CACHE.md).  The
# cache is a pool of fixed-size blocks, (L, n_blocks, block_size, KV, ·) per
# leaf, and every request's sequence is routed through a (B, max_blocks)
# block table: attention scatters the step's K/V into the table's blocks and
# gathers the logical sequence back out (``layers.gather_blocks`` /
# ``scatter_blocks``).  With dense bf16 blocks the gathered sequence holds
# bitwise the same live rows as the slot cache, so greedy decode is
# bit-identical to ``decode_step`` / ``prefill_chunk`` (the drift contract);
# quantized pools (``kv_bits`` 8/4) trade bounded greedy drift for 1.8-3.2x
# more tokens per HBM byte.  ``pos`` is always the (B,) per-slot vector —
# paged serving is a continuous-batching feature.


def init_kv_pool(cfg: ArchConfig, n_blocks: int, block_size: int,
                 kv_bits: int = 16):
    """Preallocate a paged KV block pool (block id 0 is the trash block)."""
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    if kv_bits == 16:
        return {
            "k": jnp.zeros((L, n_blocks, block_size, KV, hd), jnp.bfloat16),
            "v": jnp.zeros((L, n_blocks, block_size, KV, hd), jnp.bfloat16),
        }
    assert kv_bits in (8, 4), kv_bits
    if kv_bits == 4 and hd % 2:
        raise ValueError(f"kv_bits=4 nibble-packs head_dim pairs; "
                         f"hd={hd} is odd")
    hs = hd if kv_bits == 8 else hd // 2
    pool = {
        "k": jnp.zeros((L, n_blocks, block_size, KV, hs), jnp.uint8),
        "v": jnp.zeros((L, n_blocks, block_size, KV, hs), jnp.uint8),
    }
    for side in ("k", "v"):
        pool[f"{side}_scale"] = jnp.zeros((L, n_blocks, block_size, KV, 1),
                                          jnp.bfloat16)
        pool[f"{side}_zero"] = jnp.zeros((L, n_blocks, block_size, KV, 1),
                                         jnp.bfloat16)
    return pool


def _pool_meta(cfg: ArchConfig, pool) -> Tuple[Tuple[str, ...], int]:
    """(leaf order, kv_bits) — both static at trace time from pool shapes."""
    if "k_scale" not in pool:
        return ("k", "v"), 16
    bits = 8 if pool["k"].shape[-1] == cfg.hd else 4
    return ("k", "k_scale", "k_zero", "v", "v_scale", "v_zero"), bits


def _paged_attn(cfg: ArchConfig, lp: Dict[str, Any], x: jax.Array, *,
                pc: Dict[str, jax.Array], bt: jax.Array, pos: jax.Array):
    """Attention against one layer's block-pool slice ``pc``.

    Mirrors :func:`_attn`'s cached path op for op on the compute side — the
    only difference is where K/V rows live: ``scatter_blocks`` replaces
    ``update_kv_cache`` and ``gather_blocks`` materializes the (B, MB*BS)
    logical sequence the same ``gqa_attention`` masks by ``kv_len``.
    Quantized pools quantize the step's K/V per (token, head) before the
    scatter and dequantize the gathered sequence in-graph.
    """
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    positions = jnp.asarray(pos)[:, None] + jnp.arange(S)     # (B, S)
    h = rms_norm(x, lp["attn_norm"])
    q = matmul(h, lp["wq"]).reshape(B, S, H, hd)
    k = matmul(h, lp["wk"]).reshape(B, S, KV, hd)
    v = matmul(h, lp["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"])
        k = rms_norm(k, lp["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    _, bits = _pool_meta(cfg, pc)
    if bits == 16:
        new = {"k": scatter_blocks(pc["k"], bt, positions, k),
               "v": scatter_blocks(pc["v"], bt, positions, v)}
        ck = gather_blocks(new["k"], bt)
        cv = gather_blocks(new["v"], bt)
    else:
        new = {}
        for side, step_val in (("k", k), ("v", v)):
            sq, ss, sz = kv_quantize(step_val, bits)
            new[side] = scatter_blocks(pc[side], bt, positions, sq)
            new[f"{side}_scale"] = scatter_blocks(pc[f"{side}_scale"], bt,
                                                  positions, ss)
            new[f"{side}_zero"] = scatter_blocks(pc[f"{side}_zero"], bt,
                                                 positions, sz)
        ck = kv_dequantize(gather_blocks(new["k"], bt),
                           gather_blocks(new["k_scale"], bt),
                           gather_blocks(new["k_zero"], bt), bits)
        cv = kv_dequantize(gather_blocks(new["v"], bt),
                           gather_blocks(new["v_scale"], bt),
                           gather_blocks(new["v_zero"], bt), bits)
    attn = gqa_attention(q, ck, cv, causal=S > 1, q_offset=pos,
                         kv_len=jnp.asarray(pos) + S)
    out = matmul(attn.reshape(B, S, H * hd), lp["wo"])
    return out, new


def _paged_block(cfg: ArchConfig, lp, x, *, pc, bt, pos):
    attn_out, new = _paged_attn(cfg, lp, x, pc=pc, bt=bt, pos=pos)
    x = x + attn_out
    h = rms_norm(x, lp["mlp_norm"])
    x = x + swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
    return x, new


def paged_decode_step(cfg: ArchConfig, params, token, pool, bt, pos, *,
                      unroll: int = 1):
    """One fused generation step over a paged slot batch.

    token: (B, 1) int32; pool: ``init_kv_pool`` pytree; bt: (B, MB) int32
    block table (trash rows for inactive lanes); pos: (B,) per-slot kv_len.
    """
    from repro.distributed.ctx import constrain_activation
    x = constrain_activation(take_rows(params["embed"], token))
    stack = _layer_stack(params)
    keys, _ = _pool_meta(cfg, pool)

    def body(x, xs):
        lp, *pc = xs
        x, new = _paged_block(cfg, lp, x, pc=dict(zip(keys, pc)), bt=bt,
                              pos=pos)
        return constrain_activation(x), tuple(new[k] for k in keys)

    x, out = jax.lax.scan(body, x, (stack, *[pool[k] for k in keys]),
                          unroll=unroll)
    x = rms_norm(x, params["final_norm"])
    return logits_fn(cfg, params, x), dict(zip(keys, out))


def paged_prefill_chunk(cfg: ArchConfig, params, tokens, pool, bt, pos, *,
                        unroll: int = 1):
    """Chunked prefill through the block table (paged ``prefill_chunk``).

    tokens: (B, S) chunk; pos: (B,) chunk start offsets.  The chunk's rows
    land in the blocks ``bt`` names for positions [pos, pos + S); the caller
    guarantees those table entries are allocated (admission preallocates the
    whole request — see serving/kvcache/blocks.py).
    """
    from repro.distributed.ctx import constrain_activation
    x = constrain_activation(take_rows(params["embed"], tokens))
    stack = _layer_stack(params)
    keys, _ = _pool_meta(cfg, pool)

    def body(x, xs):
        lp, *pc = xs
        x, new = _paged_block(cfg, lp, x, pc=dict(zip(keys, pc)), bt=bt,
                              pos=pos)
        return constrain_activation(x), tuple(new[k] for k in keys)

    x, out = jax.lax.scan(body, x, (stack, *[pool[k] for k in keys]),
                          unroll=unroll)
    x = rms_norm(x, params["final_norm"])
    return logits_fn(cfg, params, x), dict(zip(keys, out))
