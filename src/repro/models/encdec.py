"""Encoder-decoder transformer backbone (seamless-m4t-medium).

The modality frontend is a STUB per the assignment: the batch carries
precomputed frame embeddings ``src_embeds`` (B, S_src, D) instead of raw audio;
``input_specs`` in the launch layer emits the matching ShapeDtypeStruct.

Shape-cell semantics (documented in DESIGN.md): the assigned ``seq_len``
applies to both the source frame count and the target token count for
train/prefill cells; decode cells run one target token against a ``seq_len``
self-attention KV cache plus the fixed ``seq_len`` cross-attention KV computed
at prefill.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import (Schema, Spec, gqa_attention, init_params, matmul, rms_norm,
                     rope, softmax_xent, swiglu, take_rows, update_kv_cache)


def _attn_schema(p: str, L: int, D: int, H: int, KV: int, hd: int, resid: float
                 ) -> Schema:
    return {
        f"{p}/norm": Spec((L, D), ("layers", None), "ones", jnp.float32),
        f"{p}/wq": Spec((L, D, H * hd), ("layers", "embed", "heads")),
        f"{p}/wk": Spec((L, D, KV * hd), ("layers", "embed", "kv")),
        f"{p}/wv": Spec((L, D, KV * hd), ("layers", "embed", "kv")),
        f"{p}/wo": Spec((L, H * hd, D), ("layers", "heads", "embed"), resid),
    }


def _mlp_schema(p: str, L: int, D: int, F: int, resid: float) -> Schema:
    return {
        f"{p}/norm": Spec((L, D), ("layers", None), "ones", jnp.float32),
        f"{p}/w_gate": Spec((L, D, F), ("layers", "embed", "mlp")),
        f"{p}/w_up": Spec((L, D, F), ("layers", "embed", "mlp")),
        f"{p}/w_down": Spec((L, F, D), ("layers", "mlp", "embed"), resid),
    }


def schema(cfg: ArchConfig) -> Schema:
    D, H, KV, hd, F = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_ff
    Le, Ld = cfg.enc_layers, cfg.n_layers
    Vp = cfg.padded_vocab()
    resid = 0.02 / (2 * (Le + Ld)) ** 0.5
    s: Schema = {
        "embed": Spec((Vp, D), ("vocab", "embed"), 0.02),
        "enc_final_norm": Spec((D,), (None,), "ones", jnp.float32),
        "dec_final_norm": Spec((D,), (None,), "ones", jnp.float32),
        "lm_head": Spec((D, Vp), ("embed", "vocab"), 0.02),
    }
    s.update(_attn_schema("enc/self", Le, D, H, KV, hd, resid))
    s.update(_mlp_schema("enc/mlp", Le, D, F, resid))
    s.update(_attn_schema("dec/self", Ld, D, H, KV, hd, resid))
    s.update(_attn_schema("dec/cross", Ld, D, H, KV, hd, resid))
    s.update(_mlp_schema("dec/mlp", Ld, D, F, resid))
    return s


def init(cfg: ArchConfig, key: jax.Array) -> Dict[str, jax.Array]:
    return init_params(schema(cfg), key)


def _stack(params: Dict[str, Any], prefix: str) -> Dict[str, Any]:
    pre = prefix + "/"
    return {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}


def _self_attn(cfg, lp, x, *, positions, causal, cache=None, pos=None,
               q_block=0, unroll=1):
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, lp["norm"])
    q = matmul(h, lp["wq"]).reshape(B, S, H, hd)
    k = matmul(h, lp["wk"]).reshape(B, S, KV, hd)
    v = matmul(h, lp["wv"]).reshape(B, S, KV, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if cache is None:
        attn = gqa_attention(q, k, v, causal=causal, q_block=q_block, unroll=unroll)
        new_cache = (k, v)
    else:
        ck, cv = update_kv_cache(cache[0], cache[1], k, v, pos)
        attn = gqa_attention(q, ck, cv, causal=False, kv_len=pos + 1)
        new_cache = (ck, cv)
    return x + matmul(attn.reshape(B, S, H * hd), lp["wo"]), new_cache


def _cross_attn(cfg, lp, x, enc_kv, *, q_block=0, unroll=1):
    """enc_kv: precomputed (k, v) each (B, S_src, KV, hd) — fixed during decode."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    h = rms_norm(x, lp["norm"])
    q = matmul(h, lp["wq"]).reshape(B, S, H, hd)
    attn = gqa_attention(q, enc_kv[0], enc_kv[1], causal=False, q_block=q_block,
                         unroll=unroll)
    return x + matmul(attn.reshape(B, S, H * hd), lp["wo"])


def _cross_kv(cfg, lp, enc_out):
    B, T, D = enc_out.shape
    KV, hd = cfg.n_kv_heads, cfg.hd
    k = matmul(enc_out, lp["wk"]).reshape(B, T, KV, hd)
    v = matmul(enc_out, lp["wv"]).reshape(B, T, KV, hd)
    return k, v


def _mlp(lp, x):
    return x + swiglu(rms_norm(x, lp["norm"]), lp["w_gate"], lp["w_up"], lp["w_down"])


def encode(cfg: ArchConfig, params, src_embeds: jax.Array, *, unroll: int = 1,
           q_block: int = 0, remat: bool = False) -> jax.Array:
    """Bidirectional encoder over precomputed frontend embeddings."""
    B, S, D = src_embeds.shape
    positions = jnp.arange(S)
    sa, ml = _stack(params, "enc/self"), _stack(params, "enc/mlp")

    from repro.distributed.ctx import constrain_activation

    def body(x, lps):
        lp_sa, lp_ml = lps
        x, _ = _self_attn(cfg, lp_sa, x, positions=positions, causal=False,
                          q_block=q_block, unroll=unroll)
        return constrain_activation(_mlp(lp_ml, x)), None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, src_embeds, (sa, ml), unroll=unroll)
    return rms_norm(x, params["enc_final_norm"])


def decode_train(cfg: ArchConfig, params, tokens, enc_out, *, unroll: int = 1,
                 q_block: int = 0, remat: bool = False) -> jax.Array:
    B, S = tokens.shape
    x = take_rows(params["embed"], tokens)
    positions = jnp.arange(S)
    sa = _stack(params, "dec/self")
    ca = _stack(params, "dec/cross")
    ml = _stack(params, "dec/mlp")

    from repro.distributed.ctx import constrain_activation

    def body(x, lps):
        lp_sa, lp_ca, lp_ml = lps
        x, _ = _self_attn(cfg, lp_sa, x, positions=positions, causal=True,
                          q_block=q_block, unroll=unroll)
        x = _cross_attn(cfg, lp_ca, x, _cross_kv(cfg, lp_ca, enc_out),
                        q_block=q_block, unroll=unroll)
        return constrain_activation(_mlp(lp_ml, x)), None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, (sa, ca, ml), unroll=unroll)
    return rms_norm(x, params["dec_final_norm"])


def logits_fn(cfg: ArchConfig, params, x: jax.Array) -> jax.Array:
    return matmul(x, params["lm_head"])


def loss_fn(cfg: ArchConfig, params, batch, *, unroll: int = 1, q_block: int = 0,
            remat: bool = True) -> jax.Array:
    """batch: {"src_embeds": (B, S_src, D), "tokens": (B, S_tgt)}."""
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    enc_out = encode(cfg, params, batch["src_embeds"], unroll=unroll,
                     q_block=q_block, remat=remat)
    x = decode_train(cfg, params, inp, enc_out, unroll=unroll, q_block=q_block,
                     remat=remat)
    return softmax_xent(logits_fn(cfg, params, x), labels, cfg.vocab)


# ------------------------------------------------------------------------- serving

def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               src_len: Optional[int] = None):
    Ld, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    src_len = src_len or max_len
    return {
        "k": jnp.zeros((Ld, batch, max_len, KV, hd), dtype),
        "v": jnp.zeros((Ld, batch, max_len, KV, hd), dtype),
        "xk": jnp.zeros((Ld, batch, src_len, KV, hd), dtype),
        "xv": jnp.zeros((Ld, batch, src_len, KV, hd), dtype),
    }


def cache_specs(cfg: ArchConfig) -> Dict[str, Tuple[Optional[str], ...]]:
    return {
        "k": ("layers", "batch", "kv_seq", "kv", None),
        "v": ("layers", "batch", "kv_seq", "kv", None),
        "xk": ("layers", "batch", "kv_seq", "kv", None),
        "xv": ("layers", "batch", "kv_seq", "kv", None),
    }


def prefill(cfg: ArchConfig, params, batch, *, max_len: Optional[int] = None,
            unroll: int = 1, q_block: int = 0):
    """batch: {"src_embeds", "tokens"} — runs encoder + target prefix; returns
    (last-position logits, cache with self-attn KV padded to max_len + cross KV)."""
    src_embeds, tokens = batch["src_embeds"], batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S
    enc_out = encode(cfg, params, src_embeds, unroll=unroll, q_block=q_block)

    x = take_rows(params["embed"], tokens)
    positions = jnp.arange(S)
    sa = _stack(params, "dec/self")
    ca = _stack(params, "dec/cross")
    ml = _stack(params, "dec/mlp")

    def body(x, lps):
        lp_sa, lp_ca, lp_ml = lps
        x, (k, v) = _self_attn(cfg, lp_sa, x, positions=positions, causal=True,
                               q_block=q_block, unroll=unroll)
        xk, xv = _cross_kv(cfg, lp_ca, enc_out)
        x = _cross_attn(cfg, lp_ca, x, (xk, xv), q_block=q_block, unroll=unroll)
        return _mlp(lp_ml, x), (k, v, xk, xv)

    x, (k, v, xk, xv) = jax.lax.scan(body, x, (sa, ca, ml), unroll=unroll)
    x = rms_norm(x, params["dec_final_norm"])
    pad = [(0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0)]
    cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad), "xk": xk, "xv": xv}
    return logits_fn(cfg, params, x[:, -1:, :]), cache


def decode_step(cfg: ArchConfig, params, token, cache, pos, *, unroll: int = 1):
    from repro.distributed.ctx import constrain_activation
    B = token.shape[0]
    x = constrain_activation(take_rows(params["embed"], token))
    positions = pos + jnp.arange(1)
    sa = _stack(params, "dec/self")
    ca = _stack(params, "dec/cross")
    ml = _stack(params, "dec/mlp")

    def body(x, xs):
        lp_sa, lp_ca, lp_ml, ck, cv, xk, xv = xs
        x, (ck, cv) = _self_attn(cfg, lp_sa, x, positions=positions, causal=False,
                                 cache=(ck, cv), pos=pos)
        x = _cross_attn(cfg, lp_ca, x, (xk, xv))
        return constrain_activation(_mlp(lp_ml, x)), (ck, cv)

    x, (ck, cv) = jax.lax.scan(
        body, x, (sa, ca, ml, cache["k"], cache["v"], cache["xk"], cache["xv"]),
        unroll=unroll)
    x = rms_norm(x, params["dec_final_norm"])
    return logits_fn(cfg, params, x), {"k": ck, "v": cv,
                                       "xk": cache["xk"], "xv": cache["xv"]}
