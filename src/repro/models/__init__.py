from . import api, layers
from .layers import QT
