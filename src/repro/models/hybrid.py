"""Jamba-style hybrid decoder: Mamba + attention interleave with MoE FFNs.

Layer pattern (arXiv:2403.19887): layers are grouped into *periods* of
``attn_period`` blocks; each period holds exactly ONE attention block (at the
middle position, matching Jamba's 1:7 attention:mamba ratio for period 8) and
``attn_period - 1`` Mamba-2 blocks.  Every block carries an FFN; blocks at odd
within-period positions use MoE (``moe.every_n == 2``), the rest a dense MLP.

Because the within-period pattern repeats exactly (``every_n`` divides
``attn_period``), parameters are stacked over PERIODS and iterated with one
``lax.scan``; the 8 per-position sub-blocks unroll inside the scan body.  This
keeps compile time O(period) while letting the dry-run unroll fully.

The 500k-token decode shape runs on this family: the 9 attention layers hold a
sharded KV cache (sequence-sharded over the data axis, flash-decoding-style
partial-softmax combine in the serving layer); the 63 Mamba layers carry O(1)
SSM state.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import dense, mamba2
from .layers import (Schema, Spec, init_params, matmul, rms_norm, softmax_xent,
                     swiglu, take_rows, update_kv_cache, gqa_attention, rope)
from .moe import moe_block_schema, moe_mlp, _padded_experts


def _layout(cfg: ArchConfig):
    """Per-period position layout: list of (mixer, ffn) strings."""
    period = cfg.attn_period
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)
    every = cfg.moe.every_n if cfg.moe else 0
    if every:
        assert period % every == 0, (period, every)
    attn_idx = period // 2
    out = []
    for j in range(period):
        mixer = "attn" if j == attn_idx else "mamba"
        ffn = "moe" if (every and j % every == every - 1) else "mlp"
        out.append((mixer, ffn))
    return out


def schema(cfg: ArchConfig) -> Schema:
    D, F = cfg.d_model, cfg.d_ff
    period = cfg.attn_period
    nP = cfg.n_layers // period
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ssm = cfg.ssm
    Din = ssm.d_inner(D)
    Hs, N, G = ssm.n_heads(D), ssm.d_state, 1
    d_in_proj = 2 * Din + 2 * G * N + Hs
    Vp = cfg.padded_vocab()
    resid = 0.02 / (2 * cfg.n_layers) ** 0.5
    s: Schema = {
        "embed": Spec((Vp, D), ("vocab", "embed"), 0.02),
        "final_norm": Spec((D,), (None,), "ones", jnp.float32),
        "lm_head": Spec((D, Vp), ("embed", "vocab"), 0.02),
    }
    for j, (mixer, ffn) in enumerate(_layout(cfg)):
        p = f"periods/pos{j}"
        if mixer == "attn":
            s[f"{p}/attn_norm"] = Spec((nP, D), ("layers", None), "ones", jnp.float32)
            s[f"{p}/wq"] = Spec((nP, D, H * hd), ("layers", "embed", "heads"))
            s[f"{p}/wk"] = Spec((nP, D, KV * hd), ("layers", "embed", "kv"))
            s[f"{p}/wv"] = Spec((nP, D, KV * hd), ("layers", "embed", "kv"))
            s[f"{p}/wo"] = Spec((nP, H * hd, D), ("layers", "heads", "embed"), resid)
        else:
            s.update(mamba2.mamba_schema(p, nP, D, ssm, resid))
        s[f"{p}/mlp_norm"] = Spec((nP, D), ("layers", None), "ones", jnp.float32)
        if ffn == "moe":
            Ep = _padded_experts(cfg)
            s.update(moe_block_schema(f"{p}/moe", nP, D, F, cfg.moe, Ep, resid))
        else:
            s[f"{p}/w_gate"] = Spec((nP, D, F), ("layers", "embed", "mlp"))
            s[f"{p}/w_up"] = Spec((nP, D, F), ("layers", "embed", "mlp"))
            s[f"{p}/w_down"] = Spec((nP, F, D), ("layers", "mlp", "embed"), resid)
    return s


def init(cfg: ArchConfig, key: jax.Array) -> Dict[str, jax.Array]:
    return init_params(schema(cfg), key)


def _period_stack(params: Dict[str, Any]) -> Dict[str, Any]:
    return {k.split("/", 1)[1]: v for k, v in params.items()
            if k.startswith("periods/")}


def _pos_params(pp: Dict[str, Any], j: int) -> Dict[str, Any]:
    pre = f"pos{j}/"
    return {k[len(pre):]: v for k, v in pp.items() if k.startswith(pre)}


def _ffn(cfg: ArchConfig, lp: Dict[str, Any], x: jax.Array, ffn_kind: str):
    h = rms_norm(x, lp["mlp_norm"])
    if ffn_kind == "moe":
        wts = {k.split("/", 1)[1]: v for k, v in lp.items() if k.startswith("moe/")}
        y, aux = moe_mlp(h, wts, cfg.moe, _padded_experts(cfg))
        return x + y, aux
    return x + swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"]), jnp.float32(0.0)


def _attn_block(cfg, lp, x, *, positions, cache=None, pos=None, q_block=0, unroll=1):
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, lp["attn_norm"])
    q = matmul(h, lp["wq"]).reshape(B, S, H, hd)
    k = matmul(h, lp["wk"]).reshape(B, S, KV, hd)
    v = matmul(h, lp["wv"]).reshape(B, S, KV, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if cache is None:
        attn = gqa_attention(q, k, v, causal=True, q_block=q_block, unroll=unroll)
        new_cache = (k, v)
    else:
        ck, cv = update_kv_cache(cache[0], cache[1], k, v, pos)
        attn = gqa_attention(q, ck, cv, causal=False, kv_len=pos + 1)
        new_cache = (ck, cv)
    return x + matmul(attn.reshape(B, S, H * hd), lp["wo"]), new_cache


def _period_body(cfg: ArchConfig, pp: Dict[str, Any], x: jax.Array, *,
                 positions, caches: Optional[Dict] = None, pos=None,
                 q_block: int = 0, unroll: int = 1, chunk: Optional[int] = None,
                 collect: bool = False, remat_inner: bool = False):
    """Apply one period's blocks.  caches: {"k","v","conv_x","conv_bc","ssm"}
    period-local.

    ``remat_inner`` checkpoints every sub-block individually: with only the
    period-level checkpoint, the backward replay keeps ALL eight sub-blocks'
    FSDP weight gathers live at once (~40 GiB/chip for jamba-398B); nesting
    bounds the live gathers to one sub-block.
    """
    from repro.distributed.ctx import constrain_activation
    new_kv = None
    new_conv_x, new_conv_bc, new_ssm = [], [], []
    aux_total = jnp.float32(0.0)
    mamba_i = 0
    decode = caches is not None and x.shape[1] == 1 and pos is not None

    def wrap(f):
        return jax.checkpoint(f) if remat_inner else f

    for j, (mixer, ffn_kind) in enumerate(_layout(cfg)):
        lp = _pos_params(pp, j)
        if mixer == "attn":
            cache = (caches["k"], caches["v"]) if decode else None
            x, kv = wrap(lambda lp, x: _attn_block(
                cfg, lp, x, positions=positions, cache=cache, pos=pos,
                q_block=q_block, unroll=unroll))(lp, x)
            new_kv = kv
        else:
            cs = (caches["conv_x"][mamba_i], caches["conv_bc"][mamba_i]) \
                if decode else None
            hs = caches["ssm"][mamba_i] if decode else None
            out, ((cx2, cbc2), hs2) = wrap(lambda lp, x: mamba2._mamba_block(
                cfg, lp, x, conv_state=cs, ssm_state=hs, chunk=chunk))(lp, x)
            x = x + out
            if decode or collect:
                new_conv_x.append(cx2)
                new_conv_bc.append(cbc2)
                new_ssm.append(hs2)
            mamba_i += 1
        x, aux = wrap(lambda lp, x: _ffn(cfg, lp, x, ffn_kind))(lp, x)
        if remat_inner:
            x = constrain_activation(x)
        aux_total = aux_total + aux
    out_caches = None
    if decode or collect:
        out_caches = {
            "k": new_kv[0], "v": new_kv[1],
            "conv_x": jnp.stack(new_conv_x),
            "conv_bc": jnp.stack(new_conv_bc), "ssm": jnp.stack(new_ssm),
        }
    return x, out_caches, aux_total


def forward(cfg: ArchConfig, params, tokens, *, unroll: int = 1, q_block: int = 0,
            remat: bool = False, collect_cache: bool = False,
            chunk: Optional[int] = None):
    from repro.distributed.ctx import constrain_activation
    B, S = tokens.shape
    x = constrain_activation(take_rows(params["embed"], tokens))
    positions = jnp.arange(S)
    stack = _period_stack(params)

    def body(carry, pp):
        x, aux_sum = carry
        x, caches, aux = _period_body(cfg, pp, x, positions=positions,
                                      q_block=q_block, unroll=unroll, chunk=chunk,
                                      collect=collect_cache, remat_inner=remat)
        return (constrain_activation(x), aux_sum + aux), \
            caches if collect_cache else None

    fn = jax.checkpoint(body) if remat else body
    (x, aux), caches = jax.lax.scan(fn, (x, jnp.float32(0.0)), stack, unroll=unroll)
    x = rms_norm(x, params["final_norm"])
    return x, caches, aux / cfg.n_layers


def logits_fn(cfg: ArchConfig, params, x: jax.Array) -> jax.Array:
    return matmul(x, params["lm_head"])


def loss_fn(cfg: ArchConfig, params, batch, *, unroll: int = 1, q_block: int = 0,
            remat: bool = True, aux_coef: float = 0.01,
            chunk: Optional[int] = None) -> jax.Array:
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    x, _, aux = forward(cfg, params, inp, unroll=unroll, q_block=q_block,
                        remat=remat, chunk=chunk)
    return softmax_xent(logits_fn(cfg, params, x), labels, cfg.vocab) + aux_coef * aux


# ------------------------------------------------------------------------- serving

def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    ssm = cfg.ssm
    period = cfg.attn_period
    nP = cfg.n_layers // period
    KV, hd = cfg.n_kv_heads, cfg.hd
    Din = ssm.d_inner(cfg.d_model)
    Hs, N, G = ssm.n_heads(cfg.d_model), ssm.d_state, 1
    n_mamba = period - 1
    return {
        "k": jnp.zeros((nP, batch, max_len, KV, hd), dtype),
        "v": jnp.zeros((nP, batch, max_len, KV, hd), dtype),
        "conv_x": jnp.zeros((nP, n_mamba, batch, ssm.d_conv - 1, Din), dtype),
        "conv_bc": jnp.zeros((nP, n_mamba, batch, ssm.d_conv - 1, 2 * G * N),
                             dtype),
        "ssm": jnp.zeros((nP, n_mamba, batch, Hs, ssm.head_dim, N), jnp.float32),
    }


def cache_specs(cfg: ArchConfig) -> Dict[str, Tuple[Optional[str], ...]]:
    return {
        "k": ("layers", "batch", "kv_seq", "kv", None),
        "v": ("layers", "batch", "kv_seq", "kv", None),
        "conv_x": ("layers", None, "batch", None, "mlp"),
        "conv_bc": ("layers", None, "batch", None, None),
        "ssm": ("layers", None, "batch", "heads", None, None),
    }


def prefill(cfg: ArchConfig, params, tokens, *, max_len: Optional[int] = None,
            unroll: int = 1, q_block: int = 0, chunk: Optional[int] = None):
    B, S = tokens.shape
    max_len = max_len or S
    x, caches, _ = forward(cfg, params, tokens, unroll=unroll, q_block=q_block,
                           collect_cache=True, chunk=chunk)
    pad = [(0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0)]
    cache = {
        "k": jnp.pad(caches["k"], pad), "v": jnp.pad(caches["v"], pad),
        "conv_x": caches["conv_x"], "conv_bc": caches["conv_bc"],
        "ssm": caches["ssm"],
    }
    return logits_fn(cfg, params, x[:, -1:, :]), cache


def decode_step(cfg: ArchConfig, params, token, cache, pos, *, unroll: int = 1):
    from repro.distributed.ctx import constrain_activation
    B = token.shape[0]
    x = constrain_activation(take_rows(params["embed"], token))
    positions = pos + jnp.arange(1)
    stack = _period_stack(params)

    def body(x, xs):
        pp, ck, cv, ccx, ccbc, cssm = xs
        caches = {"k": ck, "v": cv, "conv_x": ccx, "conv_bc": ccbc, "ssm": cssm}
        x, nc, _ = _period_body(cfg, pp, x, positions=positions, caches=caches,
                                pos=pos)
        return constrain_activation(x), \
            (nc["k"], nc["v"], nc["conv_x"], nc["conv_bc"], nc["ssm"])

    x, (ck, cv, ccx, ccbc, cssm) = jax.lax.scan(
        body, x, (stack, cache["k"], cache["v"], cache["conv_x"],
                  cache["conv_bc"], cache["ssm"]),
        unroll=unroll)
    x = rms_norm(x, params["final_norm"])
    return logits_fn(cfg, params, x), {"k": ck, "v": cv, "conv_x": ccx,
                                       "conv_bc": ccbc, "ssm": cssm}
