"""Mixture-of-Experts layers + the dbrx / qwen2-moe decoder families.

Dispatch design (TPU/JAX-native, see DESIGN.md §2): the router runs under plain pjit
(replicated over the model axis — cheap), while dispatch + expert compute run inside a
``shard_map`` over the whole mesh: every model-rank holds ``E_loc = E / |model|``
experts and all locally-resident tokens, gathers the tokens routed to its experts into
an ``(E_loc, C, D)`` capacity buffer (sort-free: one-hot cumsum positions + index
scatter, so the HLO is gather/scatter + bmm, no GSPMD surprises), and the per-rank
partial outputs are combined with a single ``psum`` over the model axis — the same
collective footprint as a Megatron TP MLP.  Capacity overflow drops tokens (GShard
semantics, ``capacity_factor`` controls the drop rate).

When no mesh context is installed (CPU smoke tests) the identical dispatch runs with
``E_loc = E`` on one device.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from .layers import (QT, Schema, Spec, deq, init_params, matmul, rms_norm,
                     softmax_xent, swiglu, take_rows)
from . import dense


# ----------------------------------------------------------------- EP mesh context

@dataclasses.dataclass(frozen=True)
class EPContext:
    """Installed by the distribution layer; models stay mesh-agnostic without it."""
    mesh: Any
    model_axis: str = "model"
    data_axes: Tuple[str, ...] = ("data",)
    batch_sharded: bool = True     # False for tiny-batch decode (batch replicated)


_EP_CTX: list = [None]


def set_ep_context(ctx: Optional[EPContext]) -> None:
    _EP_CTX[0] = ctx


def get_ep_context() -> Optional[EPContext]:
    return _EP_CTX[0]


# ------------------------------------------------------------------------ dispatch

def _capacity(n_tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(-(-n_tokens * top_k * cf // n_experts))  # ceil
    return max(4, -(-c // 4) * 4)                    # multiple of 4, >= 4


def _dispatch_compute(x2d: jax.Array, gates: jax.Array, idx: jax.Array,
                      w_gate: Any, w_up: Any, w_down: Any,
                      e0: jax.Array, E_loc: int, C: int) -> jax.Array:
    """Local-expert dispatch + compute.  x2d: (N, D); gates/idx: (N, K).

    Returns this rank's partial output (N, D) (zeros for tokens whose experts live on
    other ranks or that overflowed capacity).
    """
    N, D = x2d.shape
    K = idx.shape[-1]
    out = jnp.zeros((N, D), x2d.dtype)
    # slot assignment across ALL K choices at once so capacity is shared correctly
    eid = idx.reshape(-1)                                   # (N*K,) global expert ids
    local = (eid >= e0) & (eid < e0 + E_loc)
    el = jnp.where(local, eid - e0, E_loc)                  # E_loc = overflow bucket
    oh = jax.nn.one_hot(el, E_loc + 1, dtype=jnp.int32)     # (N*K, E_loc+1) small
    pos = (jnp.cumsum(oh, axis=0) - oh).max(axis=-1, initial=0, where=oh > 0)
    pos = jnp.where(local, pos, C)
    keep = local & (pos < C)
    slot = jnp.where(keep, el * C + pos, E_loc * C)         # last slot = trash

    tok = jnp.arange(N * K, dtype=jnp.int32) // K
    tok_for_slot = jnp.zeros((E_loc * C + 1,), jnp.int32).at[slot].set(tok, mode="drop")
    valid = jnp.zeros((E_loc * C + 1,), x2d.dtype).at[slot].set(1.0, mode="drop")

    buf = jnp.take(x2d, tok_for_slot[:-1], axis=0)          # (E_loc*C, D) gather
    buf = (buf * valid[:-1, None]).reshape(E_loc, C, D)

    h = jnp.einsum("ecd,edf->ecf", buf, deq(w_gate, x2d.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, deq(w_up, x2d.dtype))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, deq(w_down, x2d.dtype))
    y_flat = jnp.concatenate([y.reshape(E_loc * C, D),
                              jnp.zeros((1, D), y.dtype)], axis=0)

    # combine one top-k choice at a time to bound live memory at (N, D)
    slot_nk = slot.reshape(N, K)
    keep_nk = keep.reshape(N, K)
    for k in range(K):
        contrib = jnp.take(y_flat, slot_nk[:, k], axis=0)
        g = (gates[:, k] * keep_nk[:, k]).astype(x2d.dtype)
        out = out + contrib * g[:, None]
    return out


def _ep_body(x: jax.Array, gates: jax.Array, idx: jax.Array,
             w_gate, w_up, w_down, *, model_axis: str, E_loc: int, C: int,
             psum_axes: Tuple[str, ...] = ()):
    """psum_axes: extra axes to reduce over — the weight-stationary serving
    layout shards the expert FFN's hidden dim over the data axes (x is
    replicated there), so partial outputs sum over (model, *data)."""
    B, S, D = x.shape
    e0 = jax.lax.axis_index(model_axis) * E_loc
    out = _dispatch_compute(x.reshape(B * S, D), gates.reshape(B * S, -1),
                            idx.reshape(B * S, -1), w_gate, w_up, w_down,
                            e0, E_loc, C)
    return jax.lax.psum(out.reshape(B, S, D), (model_axis,) + tuple(psum_axes))


def moe_mlp(x: jax.Array, wts: Dict[str, Any], mcfg: MoEConfig, n_experts_padded: int,
            ) -> Tuple[jax.Array, jax.Array]:
    """MoE feed-forward.  Returns (y, load_balance_aux)."""
    B, S, D = x.shape
    E, K = n_experts_padded, mcfg.top_k
    logits = matmul(x, wts["router"]).astype(jnp.float32)       # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux: E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))                                # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (B * S * K)
    aux = E * jnp.sum(me * ce)

    ctx = get_ep_context()
    if ctx is None:
        C = _capacity(B * S, K, E, mcfg.capacity_factor)
        y = _dispatch_compute(
            x.reshape(B * S, D), gates.reshape(-1, K), idx.reshape(-1, K),
            wts["w_gate"], wts["w_up"], wts["w_down"],
            jnp.int32(0), E, C).reshape(B, S, D)
    else:
        mesh = ctx.mesh
        msize = mesh.shape[ctx.model_axis]
        assert E % msize == 0, (E, msize)
        E_loc = E // msize
        dsize = 1
        for a in ctx.data_axes:
            dsize *= mesh.shape[a]
        B_loc = B // dsize if ctx.batch_sharded else B
        C = _capacity(B_loc * S, K, E, mcfg.capacity_factor)
        P = jax.sharding.PartitionSpec
        bspec = (tuple(ctx.data_axes) if ctx.batch_sharded else None)
        # weight-stationary serving: x is replicated over the data axes, so
        # the expert FFN hidden dim shards over them and the combine psums
        # over (model, *data) — expert weights never cross the wire.
        stationary = not ctx.batch_sharded and bool(ctx.data_axes)
        f_axes = tuple(ctx.data_axes) if stationary else None
        body = partial(_ep_body, model_axis=ctx.model_axis, E_loc=E_loc, C=C,
                       psum_axes=f_axes or ())

        def wspec(w, f_dim):
            spec = [None, None, None]
            spec[0] = ctx.model_axis
            if f_axes:
                spec[f_dim] = f_axes
            if isinstance(w, tuple) and hasattr(w, "_fields"):
                return type(w)(P(*spec), P(), P())
            return P(*spec)

        y = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(bspec, None, None), P(bspec, None, None),
                      P(bspec, None, None), wspec(wts["w_gate"], 2),
                      wspec(wts["w_up"], 2), wspec(wts["w_down"], 1)),
            out_specs=P(bspec, None, None),
            check_vma=False,
        )(x, gates, idx, wts["w_gate"], wts["w_up"], wts["w_down"])

    if mcfg.shared_experts:
        y = y + swiglu(rm_identity(x), wts["shared_w_gate"], wts["shared_w_up"],
                       wts["shared_w_down"])
    return y, aux


def rm_identity(x):  # placeholder for shared-expert input (already normed upstream)
    return x


# ------------------------------------------------------- decoder family (dbrx/qwen2)

def _padded_experts(cfg: ArchConfig, multiple: int = 16) -> int:
    return cfg.moe.padded_experts(multiple)


def moe_block_schema(prefix: str, L: int, D: int, F: int, mcfg: MoEConfig, Ep: int,
                     resid: float) -> Schema:
    s: Schema = {
        f"{prefix}/router": Spec((L, D, Ep), ("layers", "embed", "expert"), 0.02,
                                 jnp.float32),
        f"{prefix}/w_gate": Spec((L, Ep, D, F),
                                 ("layers", "expert", "expert_embed", "expert_mlp")),
        f"{prefix}/w_up": Spec((L, Ep, D, F),
                               ("layers", "expert", "expert_embed", "expert_mlp")),
        f"{prefix}/w_down": Spec((L, Ep, F, D),
                                 ("layers", "expert", "expert_mlp", "expert_embed"),
                                 resid),
    }
    if mcfg.shared_experts:
        Fs = F * mcfg.shared_experts
        s[f"{prefix}/shared_w_gate"] = Spec((L, D, Fs), ("layers", "embed", "mlp"))
        s[f"{prefix}/shared_w_up"] = Spec((L, D, Fs), ("layers", "embed", "mlp"))
        s[f"{prefix}/shared_w_down"] = Spec((L, Fs, D), ("layers", "mlp", "embed"), resid)
    return s


def schema(cfg: ArchConfig) -> Schema:
    """dbrx / qwen2-moe: dense attention + MoE feed-forward every layer."""
    L, D = cfg.n_layers, cfg.d_model
    Ep = _padded_experts(cfg)
    resid = 0.02 / (2 * L) ** 0.5
    s = dense.schema(cfg)
    for k in ["layers/w_gate", "layers/w_up", "layers/w_down"]:
        del s[k]
    s.update(moe_block_schema("layers/moe", L, D, cfg.d_ff, cfg.moe, Ep, resid))
    return s


def init(cfg: ArchConfig, key: jax.Array) -> Dict[str, jax.Array]:
    return init_params(schema(cfg), key)


def _moe_wts(lp: Dict[str, Any]) -> Dict[str, Any]:
    return {k.split("/", 1)[1]: v for k, v in lp.items() if k.startswith("moe/")}


def _block(cfg: ArchConfig, lp, x, *, positions, cache=None, pos=None,
           q_block=0, unroll=1):
    attn_out, new_cache = dense._attn(cfg, lp, x, positions=positions, cache=cache,
                                      pos=pos, q_block=q_block, unroll=unroll)
    x = x + attn_out
    h = rms_norm(x, lp["mlp_norm"])
    y, aux = moe_mlp(h, _moe_wts(lp), cfg.moe, _padded_experts(cfg))
    return x + y, new_cache, aux


def forward(cfg: ArchConfig, params, tokens, *, unroll: int = 1, q_block: int = 0,
            remat: bool = False, collect_cache: bool = False):
    from repro.distributed.ctx import constrain_activation
    B, S = tokens.shape
    x = constrain_activation(take_rows(params["embed"], tokens))
    positions = jnp.arange(S)
    stack = dense._layer_stack(params)

    def body(carry, lp):
        x, aux_sum = carry
        x, kv, aux = _block(cfg, lp, x, positions=positions, q_block=q_block,
                            unroll=unroll)
        return (constrain_activation(x), aux_sum + aux), \
            kv if collect_cache else None

    fn = jax.checkpoint(body) if remat else body
    (x, aux), caches = jax.lax.scan(fn, (x, jnp.float32(0.0)), stack, unroll=unroll)
    x = rms_norm(x, params["final_norm"])
    return x, caches, aux / cfg.n_layers


def loss_fn(cfg: ArchConfig, params, batch, *, unroll: int = 1, q_block: int = 0,
            remat: bool = True, aux_coef: float = 0.01) -> jax.Array:
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    x, _, aux = forward(cfg, params, inp, unroll=unroll, q_block=q_block, remat=remat)
    return softmax_xent(dense.logits_fn(cfg, params, x), labels, cfg.vocab) \
        + aux_coef * aux


init_cache = dense.init_cache
cache_specs = dense.cache_specs


def prefill(cfg: ArchConfig, params, tokens, *, max_len: Optional[int] = None,
            unroll: int = 1, q_block: int = 0):
    B, S = tokens.shape
    max_len = max_len or S
    x, caches, _ = forward(cfg, params, tokens, unroll=unroll, q_block=q_block,
                           collect_cache=True)
    k, v = caches
    pad = [(0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0)]
    cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    return dense.logits_fn(cfg, params, x[:, -1:, :]), cache


def decode_step(cfg: ArchConfig, params, token, cache, pos, *, unroll: int = 1):
    from repro.distributed.ctx import constrain_activation
    B = token.shape[0]
    x = constrain_activation(take_rows(params["embed"], token))
    positions = jnp.asarray(pos)[..., None] + jnp.arange(1)   # (1,) or (B, 1)
    stack = dense._layer_stack(params)

    def body(x, xs):
        lp, ck, cv = xs
        x, (ck, cv), _ = _block(cfg, lp, x, positions=positions, cache=(ck, cv), pos=pos)
        return constrain_activation(x), (ck, cv)

    x, (ck, cv) = jax.lax.scan(body, x, (stack, cache["k"], cache["v"]), unroll=unroll)
    x = rms_norm(x, params["final_norm"])
    return dense.logits_fn(cfg, params, x), {"k": ck, "v": cv}


# ------------------------------------------------- compressed-resident serving
#
# Per-layer weight-slot twins of the step functions, mirroring the dense
# family's contract (see dense.resident_block and docs/SERVING.md
# §"Compressed-resident serving").  The slot dict carries the `moe/*`-
# prefixed expert weights exactly as `_layer_stack` would slice them, so
# `_moe_wts` resolves them unchanged; the MoE cache is always the plain
# (k, v) pair (the int8 KV path is dense-only today, as in `decode_step`).
#
# Under `CompressedResidentWeights(fused=True)` the 2-D attention weights
# arrive as FusedQT payload handles (decoded inside `layers.matmul`); the
# (L, E, D, F) expert stacks fail the fused tile contract (not a stacked
# matrix) and automatically stay on the unfused per-layer decode path —
# the per-tensor fallback `tests/differential/` pins.

embed_step = dense.embed_step
head_step = dense.head_step


def resident_prefill_block(cfg: ArchConfig, lp, x, *, positions,
                           q_block: int = 0, unroll: int = 1):
    """One `forward`-collect-cache scan iteration; the load-balance aux is
    dropped (serving never reads it, matching `prefill`)."""
    from repro.distributed.ctx import constrain_activation
    x, kv, _aux = _block(cfg, lp, x, positions=positions, q_block=q_block,
                         unroll=unroll)
    return constrain_activation(x), kv


def resident_block(cfg: ArchConfig, lp, x, cache, l, pos):
    """One `decode_step` / `prefill_chunk` scan iteration against the
    layer-stacked cache (see :func:`dense.resident_block`)."""
    from repro.distributed.ctx import constrain_activation
    S = x.shape[1]
    positions = jnp.asarray(pos)[..., None] + jnp.arange(S)   # (S,) or (B, S)
    ck = jax.lax.dynamic_index_in_dim(cache["k"], l, 0, keepdims=False)
    cv = jax.lax.dynamic_index_in_dim(cache["v"], l, 0, keepdims=False)
    x, (ck, cv), _aux = _block(cfg, lp, x, positions=positions,
                               cache=(ck, cv), pos=pos)
    out = {
        "k": jax.lax.dynamic_update_index_in_dim(cache["k"], ck, l, 0),
        "v": jax.lax.dynamic_update_index_in_dim(cache["v"], cv, l, 0),
    }
    return constrain_activation(x), out


def prefill_chunk(cfg: ArchConfig, params, tokens, cache, pos, *,
                  unroll: int = 1):
    """Chunked prefill into a slotted cache; see :func:`dense.prefill_chunk`.

    NOTE on dispatch capacity: the GShard capacity ``C`` is a function of the
    number of tokens in flight, so a chunk and a full-prompt prefill route
    identically only while no expert overflows — serve MoE with a
    ``capacity_factor`` that admits the worst case (``>= E / top_k``) when
    bit-reproducibility across batch packings matters.
    """
    from repro.distributed.ctx import constrain_activation
    B, S = tokens.shape
    x = constrain_activation(take_rows(params["embed"], tokens))
    positions = pos[:, None] + jnp.arange(S)                  # (B, S)
    stack = dense._layer_stack(params)

    def body(x, xs):
        lp, ck, cv = xs
        x, (ck, cv), _ = _block(cfg, lp, x, positions=positions,
                                cache=(ck, cv), pos=pos)
        return constrain_activation(x), (ck, cv)

    x, (ck, cv) = jax.lax.scan(body, x, (stack, cache["k"], cache["v"]),
                               unroll=unroll)
    x = rms_norm(x, params["final_norm"])
    return dense.logits_fn(cfg, params, x), {"k": ck, "v": cv}


# ------------------------------------------------------------- paged KV cache
#
# Paged twins of the step functions above (docs/KV_CACHE.md).  Attention is
# shared with the dense family (`dense._paged_attn` scatters/gathers through
# the block table); the MLP is the MoE dispatch with the load-balance aux
# dropped, matching `decode_step`.  Quantized pools work unchanged — the
# pool layout carries no family-specific leaves.

init_kv_pool = dense.init_kv_pool


def _paged_block(cfg: ArchConfig, lp, x, *, pc, bt, pos):
    attn_out, new = dense._paged_attn(cfg, lp, x, pc=pc, bt=bt, pos=pos)
    x = x + attn_out
    h = rms_norm(x, lp["mlp_norm"])
    y, _ = moe_mlp(h, _moe_wts(lp), cfg.moe, _padded_experts(cfg))
    return x + y, new


def paged_decode_step(cfg: ArchConfig, params, token, pool, bt, pos, *,
                      unroll: int = 1):
    from repro.distributed.ctx import constrain_activation
    x = constrain_activation(take_rows(params["embed"], token))
    stack = dense._layer_stack(params)
    keys, _ = dense._pool_meta(cfg, pool)

    def body(x, xs):
        lp, *pc = xs
        x, new = _paged_block(cfg, lp, x, pc=dict(zip(keys, pc)), bt=bt,
                              pos=pos)
        return constrain_activation(x), tuple(new[k] for k in keys)

    x, out = jax.lax.scan(body, x, (stack, *[pool[k] for k in keys]),
                          unroll=unroll)
    x = rms_norm(x, params["final_norm"])
    return dense.logits_fn(cfg, params, x), dict(zip(keys, out))


def paged_prefill_chunk(cfg: ArchConfig, params, tokens, pool, bt, pos, *,
                        unroll: int = 1):
    from repro.distributed.ctx import constrain_activation
    x = constrain_activation(take_rows(params["embed"], tokens))
    stack = dense._layer_stack(params)
    keys, _ = dense._pool_meta(cfg, pool)

    def body(x, xs):
        lp, *pc = xs
        x, new = _paged_block(cfg, lp, x, pc=dict(zip(keys, pc)), bt=bt,
                              pos=pos)
        return constrain_activation(x), tuple(new[k] for k in keys)

    x, out = jax.lax.scan(body, x, (stack, *[pool[k] for k in keys]),
                          unroll=unroll)
    x = rms_norm(x, params["final_norm"])
    return dense.logits_fn(cfg, params, x), dict(zip(keys, out))
